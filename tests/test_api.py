"""Session/Matrix facade: the operator API compiles to the qt_* layer.

Pins the api_redesign three ways: (1) the facade registers the *identical*
task graph as the direct free-function layer (eq (1) counts, kinds, flops,
simulated schedule); (2) operator algebra (lazy ``.T``, ``@``/``+``
routing, symmetric ops, NIL operands) matches dense numpy under both leaf
engines; (3) the satellite contracts — engine-rebind enforcement and
content-hash chunk dedup — hold.
"""
import numpy as np
import pytest

from _hyp import given, settings, st

from repro import Matrix, Session
from repro.core.engine import EngineRebindError, PallasEngine
from repro.core.multiply import (count_tasks_per_level, qt_multiply,
                                 total_flops, total_multiply_tasks)
from repro.core.patterns import (banded_mask, divide_space_order,
                                 overlap_mask, particle_cloud, random_mask,
                                 random_symmetric_mask, values_for_mask)
from repro.core.quadtree import QTParams, qt_from_dense
from repro.core.tasks import CTGraph
from repro.core.chunks import ChunkStore
from repro.core.quadtree import MatrixChunk
from repro.core.leaf import LeafMatrix
from repro.runtime.scheduler import Scheduler

N, LEAF_N, BS = 64, 16, 4
TOL = dict(atol=1e-4, rtol=1e-4)   # pallas packs float32; numpy is float64


def _session(engine="numpy", **kw):
    kw.setdefault("leaf_n", LEAF_N)
    kw.setdefault("bs", BS)
    return Session(engine=engine, **kw)


def _s2_mask(n=N):
    coords = particle_cloud(4, 3, seed=7)          # 64 basis functions
    order = divide_space_order(coords)
    return overlap_mask(coords, 4.0, order=order)


PATTERNS = {
    "random": lambda: random_mask(N, 0.12, seed=3),
    "banded": lambda: banded_mask(N, 6),
    "s2": _s2_mask,
    "nil": lambda: np.zeros((N, N), dtype=bool),
}


class TestFacadeCompilesToInternalLayer:
    """No behavior change: the facade registers the exact same graph."""

    def _inputs(self):
        a = values_for_mask(banded_mask(N, 5), seed=1)
        b = values_for_mask(random_mask(N, 0.15, seed=2), seed=2)
        return a, b

    def test_graph_identical_to_direct_qt_calls(self):
        a, b = self._inputs()
        params = QTParams(N, LEAF_N, BS)
        g = CTGraph()
        ra = qt_from_dense(g, a, params)
        rb = qt_from_dense(g, b, params)
        qt_multiply(g, params, ra, rb)

        sess = _session()
        _ = sess.from_dense(a) @ sess.from_dense(b)

        assert sess.task_counts() == g.count_kinds()
        assert sess.tasks_per_level() == count_tasks_per_level(g)
        assert sess.n_multiply_tasks == total_multiply_tasks(g)
        assert sess.flops == pytest.approx(total_flops(g))

    def test_simulated_schedule_identical_to_direct(self):
        """Same registration order + same seed => identical replay."""
        a, _ = self._inputs()
        params = QTParams(N, LEAF_N, BS)
        g = CTGraph()
        sched = Scheduler(seed=0)
        ra = qt_from_dense(g, a, params)
        rb = qt_from_dense(g, a, params)
        sched.run(g, n_workers=4, placement="parent-worker")
        sched.reset_stats()
        qt_multiply(g, params, ra, rb)
        want = sched.run(g)

        sess = _session(p=4, seed=0)
        A, B = sess.from_dense(a), sess.from_dense(a)
        sess.simulate()
        _ = A @ B
        got = sess.simulate(fresh_stats=True)

        assert got.bytes_received == want.bytes_received
        assert got.makespan == pytest.approx(want.makespan)
        assert got.steals == want.steals
        assert got.tasks_per_worker == want.tasks_per_worker

    def test_placement_aliases(self):
        sess = _session(placement="parent")
        assert sess.placement == "parent-worker"
        with pytest.raises(ValueError, match="unknown placement"):
            _session(placement="summa")

    def test_simulate_override_pins_config(self):
        """First-call p/placement overrides are pinned: later bare
        simulate() calls reuse them instead of the session defaults."""
        a = values_for_mask(banded_mask(N, 4), seed=1)
        sess = _session()                       # defaults: p=None, parent
        A = sess.from_dense(a)
        rep = sess.simulate(p=4, placement="random")
        assert rep.n_workers == 4 and rep.placement == "random"
        _ = A @ A
        rep2 = sess.simulate(fresh_stats=True)  # bare: reuse pinned config
        assert rep2.n_workers == 4 and rep2.placement == "random"
        with pytest.raises(ValueError, match="cannot re-run"):
            sess.simulate(p=8)

    def test_top_level_package_exports(self):
        import repro
        assert repro.Session is Session and repro.Matrix is Matrix
        assert repro.core.patterns.banded_mask is banded_mask
        assert hasattr(repro.runtime, "scheduler")
        with pytest.raises(AttributeError):
            repro.nonsense


class TestOperatorAlgebra:
    """Operator semantics against dense numpy (numpy engine)."""

    def setup_method(self):
        self.sess = _session()
        self.a = values_for_mask(banded_mask(N, 5), seed=1)
        self.b = values_for_mask(random_mask(N, 0.15, seed=2), seed=2)
        self.c = values_for_mask(random_mask(N, 0.1, seed=3), seed=3)
        self.A = self.sess.from_dense(self.a)
        self.B = self.sess.from_dense(self.b)
        self.C = self.sess.from_dense(self.c)

    def test_matmul_add(self):
        np.testing.assert_allclose((self.A @ self.B).to_dense(),
                                   self.a @ self.b, atol=1e-10)
        np.testing.assert_allclose((self.A + self.B).to_dense(),
                                   self.a + self.b, atol=1e-12)

    def test_lazy_transpose_folds_into_multiply(self):
        before = self.sess.task_counts()
        At = self.A.T
        assert self.sess.task_counts() == before      # no task registered
        np.testing.assert_allclose((At @ self.B).to_dense(),
                                   self.a.T @ self.b, atol=1e-10)
        np.testing.assert_allclose((self.A @ self.B.T).to_dense(),
                                   self.a @ self.b.T, atol=1e-10)
        # op(A) op(B) folding: still no transpose tasks in the graph
        assert "transpose" not in self.sess.task_counts()
        assert At.T.node == self.A.node and not At.T._t

    def test_transpose_materializes_for_add(self):
        got = (self.A.T + self.B).to_dense()
        np.testing.assert_allclose(got, self.a.T + self.b, atol=1e-12)
        assert self.sess.task_counts()["transpose"] > 0

    def test_transpose_materialization_cached(self):
        """Reusing a lazy .T handle registers the transpose program once."""
        _ = self.A.T + self.B
        n_transpose = self.sess.task_counts()["transpose"]
        _ = self.A.T + self.C        # same source node, fresh .T handle
        assert self.sess.task_counts()["transpose"] == n_transpose
        np.testing.assert_allclose((self.A.T + self.C).to_dense(),
                                   self.a.T + self.c, atol=1e-12)

    def test_mixed_chain(self):
        got = ((self.A @ self.B).T + self.C).to_dense()
        np.testing.assert_allclose(got, (self.a @ self.b).T + self.c,
                                   atol=1e-10)

    def test_readback_of_lazy_transpose(self):
        np.testing.assert_allclose(self.A.T.to_dense(), self.a.T,
                                   atol=1e-15)
        assert self.A.T.frob2() == pytest.approx(self.A.frob2())
        assert self.A.T.nnz_blocks() == self.A.nnz_blocks()

    def test_syrk(self):
        np.testing.assert_allclose(self.A.syrk().to_dense(),
                                   self.a @ self.a.T, atol=1e-10)
        np.testing.assert_allclose(self.A.syrk(trans=True).to_dense(),
                                   self.a.T @ self.a, atol=1e-10)
        # lazy .T folds into the trans flag
        np.testing.assert_allclose(self.A.T.syrk().to_dense(),
                                   self.a.T @ self.a, atol=1e-10)

    def test_symmetric_ops(self):
        s = values_for_mask(random_symmetric_mask(N, 0.1, seed=13),
                            seed=13, symmetric=True)
        S = self.sess.from_dense(s, upper=True)
        assert S.T is S                                # A == A^T
        np.testing.assert_allclose(S.sym_square().to_dense(), s @ s,
                                   atol=1e-10)
        np.testing.assert_allclose((S @ self.B).to_dense(), s @ self.b,
                                   atol=1e-10)          # sym_multiply left
        np.testing.assert_allclose((self.B @ S).to_dense(), self.b @ s,
                                   atol=1e-10)          # sym_multiply right
        np.testing.assert_allclose(
            S.sym_multiply(self.B, side="right").to_dense(), self.b @ s,
            atol=1e-10)

    def test_errors(self):
        s = values_for_mask(random_symmetric_mask(N, 0.1, seed=14),
                            seed=14, symmetric=True)
        S = self.sess.from_dense(s, upper=True)
        S2 = self.sess.from_dense(s, upper=True)
        with pytest.raises(ValueError, match="symmetric upper storage"):
            _ = S @ S2
        with pytest.raises(ValueError, match="cannot mix"):
            _ = S + self.A
        with pytest.raises(ValueError, match="upper storage"):
            self.A.sym_square()
        with pytest.raises(ValueError, match="sym_square"):
            S.syrk()
        other = _session()
        X = other.from_dense(self.a)
        with pytest.raises(ValueError, match="different Sessions"):
            _ = self.A @ X
        with pytest.raises(TypeError):
            _ = self.A @ self.a

    def test_nil_matrices(self):
        Z = self.sess.zeros(N)
        assert Z.is_nil and Z.T.is_nil
        assert (Z @ self.A).is_nil and (self.A @ Z).is_nil
        np.testing.assert_allclose((Z + self.A).to_dense(), self.a,
                                   atol=1e-15)
        np.testing.assert_allclose(Z.to_dense(), np.zeros((N, N)))
        assert Z.frob2() == 0.0 and Z.nnz_blocks() == 0

    def test_from_dense_classmethod_and_stats(self):
        A = Matrix.from_dense(self.sess, self.a)
        assert A.n == N and not A.is_nil
        st = A.stats()
        assert st["nnz_blocks"] == A.nnz_blocks() > 0
        assert st["leaf_chunks"] > 0


@pytest.mark.pallas
class TestEngineEquivalenceThroughFacade:
    """engine="numpy" vs engine="pallas" sessions agree on expression
    chains over the paper's pattern families, including all-NIL."""

    @pytest.mark.parametrize("pattern", sorted(PATTERNS))
    def test_mixed_chain(self, pattern):
        a = values_for_mask(PATTERNS[pattern](), seed=1)
        b = values_for_mask(banded_mask(N, 4), seed=2)
        c = values_for_mask(random_mask(N, 0.1, seed=3), seed=3)
        outs = {}
        for engine in ("numpy", "pallas"):
            sess = _session(engine=engine)
            A, B, C = (sess.from_dense(x) for x in (a, b, c))
            outs[engine] = ((A @ B).T + C).to_dense()
        np.testing.assert_allclose(outs["pallas"], outs["numpy"], **TOL)
        np.testing.assert_allclose(outs["numpy"], (a @ b).T + c,
                                   atol=1e-10)

    def test_deep_chain_orders_deferred_transpose(self):
        """((A @ B) @ C).T + D: the transposed leaf sits between two
        deferred waves — the engine must order its fill correctly."""
        a = values_for_mask(banded_mask(N, 5), seed=1)
        b = values_for_mask(random_mask(N, 0.15, seed=2), seed=2)
        c = values_for_mask(random_mask(N, 0.12, seed=3), seed=3)
        d = values_for_mask(banded_mask(N, 3), seed=4)
        want = ((a @ b) @ c).T + d
        for engine in ("numpy", "pallas"):
            sess = _session(engine=engine)
            A, B, C, D = (sess.from_dense(x) for x in (a, b, c, d))
            got = (((A @ B) @ C).T + D).to_dense()
            np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)

    def test_sym_square_equivalence(self):
        mask = _s2_mask()
        s = values_for_mask(mask | mask.T, seed=11, symmetric=True)
        outs = {}
        for engine in ("numpy", "pallas"):
            sess = _session(engine=engine)
            outs[engine] = sess.from_dense(
                s, upper=True).sym_square().to_dense()
        np.testing.assert_allclose(outs["pallas"], outs["numpy"], **TOL)
        np.testing.assert_allclose(outs["numpy"], s @ s, atol=1e-10)


@pytest.mark.pallas
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000),
       pattern=st.sampled_from(sorted(PATTERNS)))
def test_property_mixed_chain_engine_equivalence(seed, pattern):
    """((A @ B).T + C) agrees across engines for random operand draws,
    with A drawn from the pattern families including all-NIL."""
    a = values_for_mask(PATTERNS[pattern](), seed=seed)
    b = values_for_mask(random_mask(N, 0.1 + (seed % 3) * 0.1,
                                    seed=seed + 1), seed=seed + 1)
    c = values_for_mask(banded_mask(N, 2 + seed % 7), seed=seed + 2)
    outs = {}
    for engine in ("numpy", "pallas"):
        sess = _session(engine=engine)
        A, B, C = (sess.from_dense(x) for x in (a, b, c))
        outs[engine] = ((A @ B).T + C).to_dense()
    np.testing.assert_allclose(outs["pallas"], outs["numpy"], **TOL)
    np.testing.assert_allclose(outs["numpy"], (a @ b).T + c, atol=1e-10)


@pytest.mark.pallas
class TestEngineRebindEnforced:
    """Satellite: one stateful engine instance per CTGraph, enforced."""

    def test_rebind_raises_runtime_error(self):
        a = values_for_mask(banded_mask(N, 3), seed=34)
        e = PallasEngine()
        s1 = _session(engine=e)
        A = s1.from_dense(a)
        _ = A @ A
        s2 = _session(engine=e)
        B = s2.from_dense(a)
        with pytest.raises(RuntimeError, match="one engine per graph"):
            _ = B @ B

    def test_rebind_error_type(self):
        assert issubclass(EngineRebindError, RuntimeError)
        assert issubclass(EngineRebindError, ValueError)  # compat

    def test_flush_of_foreign_graph_rejected(self):
        e = PallasEngine()
        g1 = CTGraph(engine=e)
        e.flush(g1)     # binds
        g2 = CTGraph()
        with pytest.raises(RuntimeError, match="one engine per graph"):
            e.flush(g2)


def _leaf_chunk(a, bs=BS):
    return MatrixChunk(a.shape[0], leaf=LeafMatrix.from_dense(a, bs))


class TestChunkDedup:
    """Satellite: content-hash dedup at chunk registration."""

    def test_identical_data_returns_existing_id(self):
        a = values_for_mask(banded_mask(16, 3), seed=5)
        st_ = ChunkStore(2, dedup=True)
        c1 = st_.register(0, _leaf_chunk(a))
        c2 = st_.register(1, _leaf_chunk(a.copy()))     # byte-identical
        assert c1 == c2
        assert st_.stats[1].dedup_hits == 1
        assert st_.stats[1].owned_bytes == 0            # stored once, on w0
        c3 = st_.register(1, _leaf_chunk(a + 1.0))      # different bytes
        assert c3 != c1 and st_.stats[1].owned_bytes > 0

    def test_dedup_off_by_default(self):
        a = values_for_mask(banded_mask(16, 3), seed=5)
        st_ = ChunkStore(2)
        assert st_.register(0, _leaf_chunk(a)) != \
            st_.register(1, _leaf_chunk(a.copy()))

    def test_register_pushed_dedup_skips_push_comm(self):
        a = values_for_mask(banded_mask(16, 3), seed=6)
        st_ = ChunkStore(3, dedup=True)
        c1 = st_.register(0, _leaf_chunk(a))
        c2 = st_.register_pushed(1, 2, _leaf_chunk(a.copy()))
        assert c2 == c1
        assert st_.stats[2].bytes_received == 0         # nothing shipped
        assert st_.stats[2].bytes_pushed == 0
        # the creator just produced the bytes: its fetch is a cache hit
        st_.fetch(1, c1)
        assert st_.stats[1].bytes_received == 0
        assert st_.stats[1].cache_hits == 1

    def test_repeated_dedup_hits_do_not_inflate_cache_accounting(self):
        """Re-inserting an existing cache key (repeated dedup hits by the
        same creator) must not double-count _cache_used."""
        a = values_for_mask(banded_mask(16, 3), seed=8)
        st_ = ChunkStore(3, dedup=True, cache_bytes=10_000)
        cid = st_.register_pushed(1, 2, _leaf_chunk(a))     # fresh, pushed
        size = st_.cache_used(1)
        assert size > 0
        for _ in range(3):                                  # dedup hits
            assert st_.register_pushed(1, 0, _leaf_chunk(a.copy())) == cid
        assert st_.cache_used(1) == size                    # not inflated
        st_.fetch(1, cid)
        assert st_.stats[1].cache_hits == 1                 # entry is live

    def test_free_is_refcounted(self):
        a = values_for_mask(banded_mask(16, 3), seed=7)
        st_ = ChunkStore(1, dedup=True)
        c1 = st_.register(0, _leaf_chunk(a))
        st_.register(0, _leaf_chunk(a.copy()))          # refcount -> 2
        nbytes = st_.stats[0].owned_bytes
        st_.free(c1)
        assert st_.stats[0].owned_bytes == nbytes       # still referenced
        st_.free(c1)
        assert st_.stats[0].owned_bytes == 0
        # fingerprint slot released: re-registration stores fresh data
        c2 = st_.register(0, _leaf_chunk(a.copy()))
        assert st_.stats[0].owned_bytes == nbytes and c2 != c1

    def test_session_dedup_shrinks_owned_bytes(self):
        """simulate_runtime's shape: the same dense input built as two
        quadtrees is stored once under Session(dedup=True)."""
        a = values_for_mask(banded_mask(128, 6), seed=1, symmetric=True)
        owned, build_reps, mult_reps = {}, {}, {}
        for dedup in (False, True):
            sess = Session(leaf_n=32, bs=8, p=4, seed=0, dedup=dedup)
            A, B = sess.from_dense(a), sess.from_dense(a)
            build_reps[dedup] = sess.simulate()
            C = A @ B
            mult_reps[dedup] = sess.simulate(fresh_stats=True)
            np.testing.assert_allclose(C.to_dense(), a @ a, atol=1e-12)
            owned[dedup] = sum(s.owned_bytes
                               for s in sess.scheduler.store.stats)
        # every duplicated input leaf resolved to the existing chunk ...
        assert sum(build_reps[True].dedup_hits) > 0
        assert sum(build_reps[False].dedup_hits) == 0
        # ... shrinking owned-bytes accounting
        assert owned[True] < owned[False]
        saved = owned[False] - owned[True]
        assert sum(mult_reps[True].peak_owned) <= \
            sum(mult_reps[False].peak_owned) - saved // 2

    def test_dedup_hit_not_charged_as_push(self):
        """A dedup'd registration ships nothing: the wall-clock model and
        trace must agree with the store's push accounting."""
        a = values_for_mask(banded_mask(128, 6), seed=1, symmetric=True)
        sess = Session(leaf_n=32, bs=8, p=4, seed=0, dedup=True,
                       placement="random")
        A, B = sess.from_dense(a), sess.from_dense(a)
        rep = sess.simulate()
        assert sum(rep.dedup_hits) > 0
        traced = sum(ev.pushed_bytes for ev in rep.trace.events)
        assert traced == sum(rep.bytes_pushed)
