"""Multi-device scenarios run in a subprocess with virtual CPU devices.

Each scenario asserts internally and prints OK; tests/test_distributed.py
drives them via subprocess so the main pytest process keeps 1 jax device.
Usage: XLA_FLAGS=--xla_force_host_platform_device_count=N \
           python tests/dist_scenarios.py <scenario>
"""
import sys

import numpy as np


def _setup(n, bs, band_d, seed=1):
    from repro.core.patterns import (banded_mask, values_for_mask,
                                     block_mask_from_element_mask)
    a = values_for_mask(banded_mask(n, band_d), seed=seed).astype(np.float32)
    b = values_for_mask(banded_mask(n, band_d // 2 + 1),
                        seed=seed + 1).astype(np.float32)
    ma = block_mask_from_element_mask(np.abs(a) > 0, bs)
    mb = block_mask_from_element_mask(np.abs(b) > 0, bs)
    return a, b, ma, mb


def halo_correctness():
    import jax, jax.numpy as jnp
    from repro.core import distributed as dist
    n_dev = len(jax.devices())
    n, bs = 256, 8
    a, b, ma, mb = _setup(n, bs, 12)
    plan = dist.plan_distribution(ma, mb, bs, n_dev)
    ab, ar, ac = dist.distribute_morton(a, bs, plan)
    bb, br, bc = dist.distribute_morton(b, bs, plan)
    mesh = jax.make_mesh((n_dev,), ("dev",))
    cb, cr, cc, npairs = dist.halo_spmm(
        mesh, "dev", plan,
        *[jnp.asarray(x) for x in (ab, ar, ac, bb, br, bc)])
    out = dist.gather_dense(np.asarray(cb), np.asarray(cr),
                            np.asarray(cc), plan.grid, bs)
    np.testing.assert_allclose(out, a @ b, atol=1e-3)
    assert int(np.asarray(npairs).sum()) > 0
    print("OK halo_correctness")


def halo_random_pattern():
    """Locality-free pattern still computes correctly (just more halo)."""
    import jax, jax.numpy as jnp
    from repro.core import distributed as dist
    from repro.core.patterns import (random_mask, values_for_mask,
                                     block_mask_from_element_mask)
    n_dev = len(jax.devices())
    n, bs = 128, 8
    a = values_for_mask(random_mask(n, 0.05, seed=3), seed=3).astype(
        np.float32)
    ma = block_mask_from_element_mask(np.abs(a) > 0, bs)
    plan = dist.plan_distribution(ma, ma, bs, n_dev)
    ab, ar, ac = dist.distribute_morton(a, bs, plan)
    mesh = jax.make_mesh((n_dev,), ("dev",))
    cb, cr, cc, _ = dist.halo_spmm(
        mesh, "dev", plan,
        *[jnp.asarray(x) for x in (ab, ar, ac, ab, ar, ac)])
    out = dist.gather_dense(np.asarray(cb), np.asarray(cr),
                            np.asarray(cc), plan.grid, bs)
    np.testing.assert_allclose(out, a @ a, atol=1e-3)
    print("OK halo_random_pattern")


def summa_correctness():
    import jax, jax.numpy as jnp
    from repro.core import distributed as dist, spsumma
    n_dev = len(jax.devices())
    pgrid = spsumma.summa_pgrid(n_dev)
    n, bs = 256, 8
    a, b, ma, mb = _setup(n, bs, 12)
    sp = spsumma.plan_summa(ma, mb, bs, pgrid)
    ab, ar, ac = spsumma.distribute_panels(a, bs, sp)
    bb, br, bc = spsumma.distribute_panels(b, bs, sp)
    mesh = jax.make_mesh((pgrid, pgrid), ("pr", "pc"))
    cb, cr, cc, _ = spsumma.summa_spmm(
        mesh, ("pr", "pc"), sp,
        *[jnp.asarray(x) for x in (ab, ar, ac, bb, br, bc)])
    out = dist.gather_dense(np.asarray(cb), np.asarray(cr),
                            np.asarray(cc), sp.grid, bs)
    np.testing.assert_allclose(out, a @ b, atol=1e-3)
    print("OK summa_correctness")


def summa_random_permutation():
    """Random permutation (paper Fig 1 maneuver): still correct after
    inverse-permuting the result."""
    import jax, jax.numpy as jnp
    from repro.core import distributed as dist, spsumma
    n_dev = len(jax.devices())
    pgrid = int(np.sqrt(n_dev))
    n, bs = 256, 8
    a, b, ma, mb = _setup(n, bs, 12)
    grid = n // bs
    perm = spsumma.random_block_permutation(grid, seed=5)
    # plan from the permuted masks
    mp = np.ix_(perm, perm)
    sp = spsumma.plan_summa(ma[mp], mb[mp], bs, pgrid)
    ab, ar, ac = spsumma.distribute_panels(a, bs, sp, perm=perm)
    bb, br, bc = spsumma.distribute_panels(b, bs, sp, perm=perm)
    mesh = jax.make_mesh((pgrid, pgrid), ("pr", "pc"))
    cb, cr, cc, _ = spsumma.summa_spmm(
        mesh, ("pr", "pc"), sp,
        *[jnp.asarray(x) for x in (ab, ar, ac, bb, br, bc)])
    out = dist.gather_dense(np.asarray(cb), np.asarray(cr),
                            np.asarray(cc), sp.grid, bs)
    gp = np.repeat(perm, bs) * bs + np.tile(np.arange(bs), grid)
    want = (a @ b)[np.ix_(gp, gp)]
    np.testing.assert_allclose(out, want, atol=1e-3)
    print("OK summa_random_permutation")




def halo_pair_kernel():
    import jax, jax.numpy as jnp
    from repro.core import distributed as dist
    n_dev = len(jax.devices())
    n, bs = 128, 8
    a, b, ma, mb = _setup(n, bs, 10, seed=7)
    plan = dist.plan_distribution(ma, mb, bs, n_dev)
    ab, ar, ac = dist.distribute_morton(a, bs, plan)
    bb, br, bc = dist.distribute_morton(b, bs, plan)
    mesh = jax.make_mesh((n_dev,), ("dev",))
    cb, cr, cc, _ = dist.halo_spmm(
        mesh, "dev", plan,
        *[jnp.asarray(x) for x in (ab, ar, ac, bb, br, bc)],
        use_pair_kernel=True, interpret=True)
    out = dist.gather_dense(np.asarray(cb), np.asarray(cr),
                            np.asarray(cc), plan.grid, bs)
    np.testing.assert_allclose(out, a @ b, atol=1e-3)
    print("OK halo_pair_kernel")


def collective_bytes_comparison():
    """Halo ppermute traffic < SUMMA all_gather traffic on a banded case,
    and the HLO parser finds the expected op kinds."""
    import jax, jax.numpy as jnp
    from repro.core import distributed as dist, spsumma
    from repro.launch import roofline
    n_dev = len(jax.devices())
    pgrid = int(np.sqrt(n_dev))
    n, bs = 512, 8
    a, _, ma, _ = _setup(n, bs, 12)

    plan = dist.plan_distribution(ma, ma, bs, n_dev)
    ab, ar, ac = dist.distribute_morton(a, bs, plan)
    mesh = jax.make_mesh((n_dev,), ("dev",))
    fn = dist.make_halo_spmm(mesh, "dev", plan)
    args = [jnp.asarray(x) for x in (ab, ar, ac, ab, ar, ac)]
    chalo = fn.lower(*args).compile()
    halo_per, halo_counts = roofline.collective_bytes(chalo.as_text(),
                                                      per_op=True)
    assert halo_counts["collective-permute"] > 0
    assert halo_per["all-gather"] == 0

    sp = spsumma.plan_summa(ma, ma, bs, pgrid)
    ab2, ar2, ac2 = spsumma.distribute_panels(a, bs, sp)
    mesh2 = jax.make_mesh((pgrid, pgrid), ("pr", "pc"))

    def run(*xs):
        return spsumma.summa_spmm(mesh2, ("pr", "pc"), sp, *xs)

    args2 = [jnp.asarray(x) for x in (ab2, ar2, ac2, ab2, ar2, ac2)]
    csum = jax.jit(run).lower(*args2).compile()
    summa_per, summa_counts = roofline.collective_bytes(csum.as_text(),
                                                        per_op=True)
    assert summa_counts["all-gather"] > 0
    halo_total = sum(halo_per.values())
    summa_total = sum(summa_per.values())
    print(f"halo bytes/dev {halo_total}  summa bytes/dev {summa_total}")
    print("OK collective_bytes_comparison")




def demand_halo_v2():
    """Beyond-paper demand-routed halo: correct + far less traffic than
    the v1 ring (EXPERIMENTS.md §Perf iteration 1)."""
    import jax, jax.numpy as jnp
    from repro.core import distributed as dist
    from repro.launch import roofline
    n_dev = len(jax.devices())
    n, bs = 512, 8
    a, b, ma, mb = _setup(n, bs, 12)
    base = dist.plan_distribution(ma, mb, bs, n_dev)
    dplan = dist.plan_demand(ma, mb, bs, n_dev)
    ab, ar, ac = dist.distribute_morton(a, bs, base)
    bb, br, bc = dist.distribute_morton(b, bs, base)
    mesh = jax.make_mesh((n_dev,), ("dev",))
    fn2 = dist.make_demand_spmm(mesh, "dev", dplan)
    args = [jnp.asarray(x) for x in (ab, ar, ac, bb, br, bc)]
    cb, cr, cc, _ = fn2(*args)
    out = dist.gather_dense(np.asarray(cb), np.asarray(cr),
                            np.asarray(cc), dplan.grid, bs)
    np.testing.assert_allclose(out, a @ b, atol=1e-3)
    v2 = roofline.collective_bytes(fn2.lower(*args).compile().as_text())
    fn1 = dist.make_halo_spmm(mesh, "dev", base)
    v1 = roofline.collective_bytes(fn1.lower(*args).compile().as_text())
    assert v2 < v1, (v1, v2)
    print(f"v1={v1} v2={v2}")
    print("OK demand_halo_v2")


def mesh_engine_equivalence():
    """Session(engine="mesh") == host reference at the ambient device
    count: banded, random, symmetric and NIL-quadrant patterns, with
    transposes and a truncated multiply; comm counters stay monotone.

    Prints ``CHECKSUM <v>`` so the driver can assert results are
    identical across device counts (1 vs 4 vs 8).
    """
    import jax
    from repro import Session
    from repro.core.patterns import (banded_mask, random_mask,
                                     random_symmetric_mask, values_for_mask)
    n_dev = len(jax.devices())
    n = 128
    a = values_for_mask(banded_mask(n, 9), seed=1)
    b = values_for_mask(random_mask(n, 0.08, seed=2), seed=2)
    s = values_for_mask(random_symmetric_mask(n, 0.12, seed=3), seed=3,
                        symmetric=True)
    # NIL quadrants: zero out an off-diagonal quadrant entirely
    a[: n // 2, n // 2:] = 0.0

    sess = Session(engine="mesh", leaf_n=32, bs=8)
    A, B = sess.from_dense(a), sess.from_dense(b)
    S = sess.from_dense(s, upper=True)

    checks = []
    prev = np.zeros(n_dev, np.int64)
    for got_m, want in [
            (A @ B, a @ b),
            (A.T @ B, a.T @ b),
            (A @ B.T, a @ b.T),
            (A.multiply(B, tau=0.0), a @ b),
            (S.sym_square(), s @ s),
    ]:
        got = got_m.to_dense()
        np.testing.assert_allclose(got, want, atol=1e-3)
        checks.append(float(np.abs(got).sum()))
        st = sess.graph._engine.stats()
        cur = np.asarray(st["fetched_bytes"], np.int64)
        assert (cur >= prev).all(), "fetch counters must be monotone"
        prev = cur
    # truncated multiply: engine-pruned but close, and the same program
    # replays identically (structure frozen on the node)
    T = A.multiply(B, tau=1e-3)
    assert np.abs(T.to_dense() - a @ b).max() < 5e-2
    st = sess.graph._engine.stats()
    assert st["n_dev"] == n_dev
    assert sum(st["pushed_bytes"]) > 0
    if n_dev > 1:
        assert sum(st["fetched_blocks"]) > 0
    print("CHECKSUM " + " ".join(f"{c:.6f}" for c in checks))
    print("OK mesh_engine_equivalence")


def mesh_engine_counters():
    """Per-device fetch accounting: re-using resident operands is free
    (locality), rebinding a plan's inputs makes them stale (re-pushed)."""
    import jax
    from repro import Session
    n_dev = len(jax.devices())
    rng = np.random.default_rng(0)
    a = rng.standard_normal((128, 128)) * 0.1
    sess = Session(engine="mesh", leaf_n=32, bs=8, lazy=True)
    X = sess.from_dense(a, name="X")
    plan = sess.compile(X @ X)
    Y = plan.run()
    np.testing.assert_allclose(Y.to_dense(), a @ a, atol=1e-3)
    st1 = sess.graph._engine.stats()
    push1 = sum(st1["pushed_bytes"])
    # replay without rebinding: the *input* leaves keep their version, so
    # they stay device-resident and are not re-pushed — the replay's push
    # delta is strictly smaller than the first run's (only re-produced
    # intermediates go stale)
    plan.run()
    Y.to_dense()
    st2 = sess.graph._engine.stats()
    delta_replay = sum(st2["pushed_bytes"]) - push1
    assert delta_replay < push1, (delta_replay, push1)
    # rebind with new values: qt_rebind refills the input leaves in place
    # (version bump), so their device copies go stale and are re-pushed
    a2 = rng.standard_normal((128, 128)) * 0.1
    Z = plan.run(X=a2)
    np.testing.assert_allclose(Z.to_dense(), a2 @ a2, atol=1e-3)
    st3 = sess.graph._engine.stats()
    delta_rebind = sum(st3["pushed_bytes"]) - sum(st2["pushed_bytes"])
    assert delta_rebind > delta_replay, (delta_rebind, delta_replay)
    assert st3["n_dev"] == n_dev
    print("OK mesh_engine_counters")


def obs_mesh_pinned():
    """Unified metrics reproduce the published BENCH_mesh_comm.json
    record bit-for-bit: re-run its mesh p=4 full-scale cell and compare
    the engine counters, their MetricSet view, and the Perfetto export
    against the committed artifact."""
    import json
    import pathlib

    import jax
    from repro import Session
    from repro.core.patterns import banded_mask, values_for_mask
    from repro.launch.mesh_exec import MeshEngine
    from repro.obs import (chrome_trace, from_engine_stats,
                           mesh_stats_events, validate_metrics)

    n_dev = len(jax.devices())
    assert n_dev == 4, f"scenario needs 4 forced devices, got {n_dev}"
    root = pathlib.Path(__file__).parents[1]
    doc = json.loads((root / "BENCH_mesh_comm.json").read_text())
    assert not doc["quick"], "published artifact must be the full run"
    rec = [r for r in doc["records"]
           if r["scheme"] == "mesh" and r["p"] == 4][0]
    n = rec["n"]

    # exactly the bench_mesh_comm child's scenario
    a = values_for_mask(banded_mask(n, 12), seed=1)
    b = values_for_mask(banded_mask(n, 7), seed=2)
    sess = Session(engine=MeshEngine(n_dev=4), leaf_n=32, bs=8)
    A, B = sess.from_dense(a), sess.from_dense(b)
    C = A @ B
    np.testing.assert_allclose(C.to_dense(), a @ b, atol=1e-3)

    st = sess.engine_stats()
    assert max(st["fetched_bytes"]) == rec["max_fetched_bytes_per_dev"]
    assert sum(st["fetched_blocks"]) == rec["sum_fetched_blocks"]
    assert max(st["pushed_bytes"]) == rec["max_pushed_bytes_per_dev"]
    assert max(st["collective_bytes"]) == \
        rec["max_collective_bytes_per_dev"]
    assert st["waves"] == rec["waves"]

    # the unified schema carries the same numbers verbatim
    ms = from_engine_stats(st)
    assert ms.source == "engine:mesh"
    validate_metrics(ms.to_dict())
    assert ms["fetched_bytes"].per_worker == list(st["fetched_bytes"])
    assert max(ms["fetched_bytes"].per_worker) == \
        rec["max_fetched_bytes_per_dev"]
    assert ms["pushed_bytes"].total == sum(st["pushed_bytes"])

    # and the Perfetto export's counter tracks sum back to the totals
    tr = chrome_trace(mesh_stats_events(st))
    counters = [e for e in tr["traceEvents"] if e["ph"] == "C"
                and e["name"].startswith("fetched_bytes")]
    assert counters, "expected fetched_bytes counter events"
    last_by_dev = {}
    for e in sorted(counters, key=lambda e: e["ts"]):
        last_by_dev[e["tid"]] = e["args"]["bytes"]
    assert sum(last_by_dev.values()) == sum(st["fetched_bytes"])
    print("OK obs_mesh_pinned")


def summa_pgrid_validation():
    """p=6 regression: non-square device counts fail fast everywhere
    instead of silently sharding onto a 2x2 sub-grid."""
    import jax
    from repro.core import spsumma
    from repro.launch import mesh as lmesh
    n_dev = len(jax.devices())
    assert n_dev == 6, f"scenario needs 6 forced devices, got {n_dev}"
    for fn in (lambda: spsumma.summa_pgrid(6),
               lambda: lmesh.make_summa_mesh(),
               lambda: lmesh.make_summa_mesh(2)):
        try:
            fn()
        except ValueError as e:
            assert "perfect-square" in str(e) or "mis-shard" in str(e), e
        else:
            raise AssertionError("expected ValueError for p=6")
    # square counts still work
    assert spsumma.summa_pgrid(4) == 2
    sp = spsumma.plan_summa(np.ones((8, 8), bool), np.ones((8, 8), bool),
                            8, 2)
    assert sp.pgrid == 2
    print("OK summa_pgrid_validation")


if __name__ == "__main__":
    globals()[sys.argv[1]]()
