"""Multi-device scenarios run in a subprocess with virtual CPU devices.

Each scenario asserts internally and prints OK; tests/test_distributed.py
drives them via subprocess so the main pytest process keeps 1 jax device.
Usage: XLA_FLAGS=--xla_force_host_platform_device_count=N \
           python tests/dist_scenarios.py <scenario>
"""
import sys

import numpy as np


def _setup(n, bs, band_d, seed=1):
    from repro.core.patterns import (banded_mask, values_for_mask,
                                     block_mask_from_element_mask)
    a = values_for_mask(banded_mask(n, band_d), seed=seed).astype(np.float32)
    b = values_for_mask(banded_mask(n, band_d // 2 + 1),
                        seed=seed + 1).astype(np.float32)
    ma = block_mask_from_element_mask(np.abs(a) > 0, bs)
    mb = block_mask_from_element_mask(np.abs(b) > 0, bs)
    return a, b, ma, mb


def halo_correctness():
    import jax, jax.numpy as jnp
    from repro.core import distributed as dist
    n_dev = len(jax.devices())
    n, bs = 256, 8
    a, b, ma, mb = _setup(n, bs, 12)
    plan = dist.plan_distribution(ma, mb, bs, n_dev)
    ab, ar, ac = dist.distribute_morton(a, bs, plan)
    bb, br, bc = dist.distribute_morton(b, bs, plan)
    mesh = jax.make_mesh((n_dev,), ("dev",))
    cb, cr, cc, npairs = dist.halo_spmm(
        mesh, "dev", plan,
        *[jnp.asarray(x) for x in (ab, ar, ac, bb, br, bc)])
    out = dist.gather_dense(np.asarray(cb), np.asarray(cr),
                            np.asarray(cc), plan.grid, bs)
    np.testing.assert_allclose(out, a @ b, atol=1e-3)
    assert int(np.asarray(npairs).sum()) > 0
    print("OK halo_correctness")


def halo_random_pattern():
    """Locality-free pattern still computes correctly (just more halo)."""
    import jax, jax.numpy as jnp
    from repro.core import distributed as dist
    from repro.core.patterns import (random_mask, values_for_mask,
                                     block_mask_from_element_mask)
    n_dev = len(jax.devices())
    n, bs = 128, 8
    a = values_for_mask(random_mask(n, 0.05, seed=3), seed=3).astype(
        np.float32)
    ma = block_mask_from_element_mask(np.abs(a) > 0, bs)
    plan = dist.plan_distribution(ma, ma, bs, n_dev)
    ab, ar, ac = dist.distribute_morton(a, bs, plan)
    mesh = jax.make_mesh((n_dev,), ("dev",))
    cb, cr, cc, _ = dist.halo_spmm(
        mesh, "dev", plan,
        *[jnp.asarray(x) for x in (ab, ar, ac, ab, ar, ac)])
    out = dist.gather_dense(np.asarray(cb), np.asarray(cr),
                            np.asarray(cc), plan.grid, bs)
    np.testing.assert_allclose(out, a @ a, atol=1e-3)
    print("OK halo_random_pattern")


def summa_correctness():
    import jax, jax.numpy as jnp
    from repro.core import distributed as dist, spsumma
    n_dev = len(jax.devices())
    pgrid = int(np.sqrt(n_dev))
    assert pgrid * pgrid == n_dev
    n, bs = 256, 8
    a, b, ma, mb = _setup(n, bs, 12)
    sp = spsumma.plan_summa(ma, mb, bs, pgrid)
    ab, ar, ac = spsumma.distribute_panels(a, bs, sp)
    bb, br, bc = spsumma.distribute_panels(b, bs, sp)
    mesh = jax.make_mesh((pgrid, pgrid), ("pr", "pc"))
    cb, cr, cc, _ = spsumma.summa_spmm(
        mesh, ("pr", "pc"), sp,
        *[jnp.asarray(x) for x in (ab, ar, ac, bb, br, bc)])
    out = dist.gather_dense(np.asarray(cb), np.asarray(cr),
                            np.asarray(cc), sp.grid, bs)
    np.testing.assert_allclose(out, a @ b, atol=1e-3)
    print("OK summa_correctness")


def summa_random_permutation():
    """Random permutation (paper Fig 1 maneuver): still correct after
    inverse-permuting the result."""
    import jax, jax.numpy as jnp
    from repro.core import distributed as dist, spsumma
    n_dev = len(jax.devices())
    pgrid = int(np.sqrt(n_dev))
    n, bs = 256, 8
    a, b, ma, mb = _setup(n, bs, 12)
    grid = n // bs
    perm = spsumma.random_block_permutation(grid, seed=5)
    # plan from the permuted masks
    mp = np.ix_(perm, perm)
    sp = spsumma.plan_summa(ma[mp], mb[mp], bs, pgrid)
    ab, ar, ac = spsumma.distribute_panels(a, bs, sp, perm=perm)
    bb, br, bc = spsumma.distribute_panels(b, bs, sp, perm=perm)
    mesh = jax.make_mesh((pgrid, pgrid), ("pr", "pc"))
    cb, cr, cc, _ = spsumma.summa_spmm(
        mesh, ("pr", "pc"), sp,
        *[jnp.asarray(x) for x in (ab, ar, ac, bb, br, bc)])
    out = dist.gather_dense(np.asarray(cb), np.asarray(cr),
                            np.asarray(cc), sp.grid, bs)
    gp = np.repeat(perm, bs) * bs + np.tile(np.arange(bs), grid)
    want = (a @ b)[np.ix_(gp, gp)]
    np.testing.assert_allclose(out, want, atol=1e-3)
    print("OK summa_random_permutation")




def halo_pair_kernel():
    import jax, jax.numpy as jnp
    from repro.core import distributed as dist
    n_dev = len(jax.devices())
    n, bs = 128, 8
    a, b, ma, mb = _setup(n, bs, 10, seed=7)
    plan = dist.plan_distribution(ma, mb, bs, n_dev)
    ab, ar, ac = dist.distribute_morton(a, bs, plan)
    bb, br, bc = dist.distribute_morton(b, bs, plan)
    mesh = jax.make_mesh((n_dev,), ("dev",))
    cb, cr, cc, _ = dist.halo_spmm(
        mesh, "dev", plan,
        *[jnp.asarray(x) for x in (ab, ar, ac, bb, br, bc)],
        use_pair_kernel=True, interpret=True)
    out = dist.gather_dense(np.asarray(cb), np.asarray(cr),
                            np.asarray(cc), plan.grid, bs)
    np.testing.assert_allclose(out, a @ b, atol=1e-3)
    print("OK halo_pair_kernel")


def collective_bytes_comparison():
    """Halo ppermute traffic < SUMMA all_gather traffic on a banded case,
    and the HLO parser finds the expected op kinds."""
    import jax, jax.numpy as jnp
    from repro.core import distributed as dist, spsumma
    from repro.launch import roofline
    n_dev = len(jax.devices())
    pgrid = int(np.sqrt(n_dev))
    n, bs = 512, 8
    a, _, ma, _ = _setup(n, bs, 12)

    plan = dist.plan_distribution(ma, ma, bs, n_dev)
    ab, ar, ac = dist.distribute_morton(a, bs, plan)
    mesh = jax.make_mesh((n_dev,), ("dev",))
    fn = dist.make_halo_spmm(mesh, "dev", plan)
    args = [jnp.asarray(x) for x in (ab, ar, ac, ab, ar, ac)]
    chalo = fn.lower(*args).compile()
    halo_per, halo_counts = roofline.collective_bytes(chalo.as_text(),
                                                      per_op=True)
    assert halo_counts["collective-permute"] > 0
    assert halo_per["all-gather"] == 0

    sp = spsumma.plan_summa(ma, ma, bs, pgrid)
    ab2, ar2, ac2 = spsumma.distribute_panels(a, bs, sp)
    mesh2 = jax.make_mesh((pgrid, pgrid), ("pr", "pc"))

    def run(*xs):
        return spsumma.summa_spmm(mesh2, ("pr", "pc"), sp, *xs)

    args2 = [jnp.asarray(x) for x in (ab2, ar2, ac2, ab2, ar2, ac2)]
    csum = jax.jit(run).lower(*args2).compile()
    summa_per, summa_counts = roofline.collective_bytes(csum.as_text(),
                                                        per_op=True)
    assert summa_counts["all-gather"] > 0
    halo_total = sum(halo_per.values())
    summa_total = sum(summa_per.values())
    print(f"halo bytes/dev {halo_total}  summa bytes/dev {summa_total}")
    print("OK collective_bytes_comparison")




def demand_halo_v2():
    """Beyond-paper demand-routed halo: correct + far less traffic than
    the v1 ring (EXPERIMENTS.md §Perf iteration 1)."""
    import jax, jax.numpy as jnp
    from repro.core import distributed as dist
    from repro.launch import roofline
    n_dev = len(jax.devices())
    n, bs = 512, 8
    a, b, ma, mb = _setup(n, bs, 12)
    base = dist.plan_distribution(ma, mb, bs, n_dev)
    dplan = dist.plan_demand(ma, mb, bs, n_dev)
    ab, ar, ac = dist.distribute_morton(a, bs, base)
    bb, br, bc = dist.distribute_morton(b, bs, base)
    mesh = jax.make_mesh((n_dev,), ("dev",))
    fn2 = dist.make_demand_spmm(mesh, "dev", dplan)
    args = [jnp.asarray(x) for x in (ab, ar, ac, bb, br, bc)]
    cb, cr, cc, _ = fn2(*args)
    out = dist.gather_dense(np.asarray(cb), np.asarray(cr),
                            np.asarray(cc), dplan.grid, bs)
    np.testing.assert_allclose(out, a @ b, atol=1e-3)
    v2 = roofline.collective_bytes(fn2.lower(*args).compile().as_text())
    fn1 = dist.make_halo_spmm(mesh, "dev", base)
    v1 = roofline.collective_bytes(fn1.lower(*args).compile().as_text())
    assert v2 < v1, (v1, v2)
    print(f"v1={v1} v2={v2}")
    print("OK demand_halo_v2")


if __name__ == "__main__":
    globals()[sys.argv[1]]()
