"""Sparsity generators + §5 cost-analysis formulas vs exact counts."""
import numpy as np
import pytest

from repro.core import analysis as an
from repro.core.patterns import (banded_mask, banded_pairs,
                                 block_mask_from_element_mask,
                                 divide_space_order, overlap_mask,
                                 overlap_pairs, particle_cloud, random_mask,
                                 rmat_mask, rmat_pairs, values_for_mask)
from repro.core.tasks import CTGraph
from repro.core.quadtree import QTParams, qt_from_dense
from repro.core.multiply import count_tasks_per_level, qt_multiply


class TestPatterns:
    def test_banded_pairs_match_mask(self):
        n, d = 64, 5
        mask = banded_mask(n, d)
        rows, cols = banded_pairs(n, d)
        m2 = np.zeros((n, n), dtype=bool)
        m2[rows, cols] = True
        assert np.array_equal(mask, m2)

    @pytest.mark.parametrize("dim", [1, 2, 3])
    def test_overlap_pairs_match_mask(self, dim):
        coords = particle_cloud([64, 8, 4][dim - 1], dim, seed=1)
        order = divide_space_order(coords)
        mask = overlap_mask(coords, 4.0, order=order)
        rows, cols = overlap_pairs(coords, 4.0, order=order)
        m2 = np.zeros_like(mask)
        m2[rows, cols] = True
        assert np.array_equal(mask, m2)

    def test_overlap_symmetric_with_diagonal(self):
        coords = particle_cloud(32, 1, seed=2)
        mask = overlap_mask(coords, 4.0)
        assert np.array_equal(mask, mask.T)
        assert mask.diagonal().all()

    def test_divide_space_order_locality(self):
        """Consecutive indices in the ordering are spatially close."""
        coords = particle_cloud(128, 1, seed=3)
        order = divide_space_order(coords)
        pts = coords[order][:, 0]
        jumps = np.abs(np.diff(pts))
        assert np.median(jumps) < 4.0  # grid spacing 2, local moves dominate

    def test_rmat_mask_pairs_consistent(self):
        m = rmat_mask(8, 5.0, 0.5, seed=4)
        rows, cols = rmat_pairs(8, 5.0, 0.5, seed=4)
        m2 = np.zeros_like(m)
        m2[rows, cols] = True
        assert np.array_equal(m, m2)

    def test_rmat_locality_increases_with_a(self):
        """Larger a pushes work to lower levels (paper Fig 4 right)."""
        n = 1 << 9
        tasks = {}
        for a in (0.25, 0.9):
            rows, cols = rmat_pairs(9, 5.0, a, seed=5)
            per = an.count_tasks_per_level_pairs(rows, cols, n)
            tasks[a] = sum(per.values()) / max(per[9], 1)
        # high locality -> total/leaf ratio lower (leaf-dominated)
        assert tasks[0.9] < tasks[0.25]

    def test_block_mask_coarsen(self):
        mask = np.zeros((16, 16), dtype=bool)
        mask[3, 5] = True
        bm = block_mask_from_element_mask(mask, 4)
        assert bm.shape == (4, 4)
        assert bm[0, 1] and bm.sum() == 1


class TestAnalysis:
    def test_eq1_matches_simulation(self):
        """Eq (1) expectation vs empirical count, random pattern."""
        L, delta = 8, 0.02
        n = 1 << L
        counts = []
        for seed in range(5):
            rows, cols = np.nonzero(random_mask(n, delta, seed=seed))
            per = an.count_tasks_per_level_pairs(rows, cols, n)
            counts.append(per)
        for l in (4, 6, 8):
            emp = np.mean([c[l] for c in counts])
            exp = an.random_tasks_at_level(L, delta, l)
            assert abs(emp - exp) / max(exp, 1) < 0.25

    def test_eq2_eq3_bounds_hold(self):
        L, delta = 8, 0.02
        n = 1 << L
        rows, cols = np.nonzero(random_mask(n, delta, seed=0))
        per = an.count_tasks_per_level_pairs(rows, cols, n)
        for l, c in per.items():
            assert c <= an.random_bound_low(l) + 1e-9
            assert c <= an.random_bound_high(L, delta, l) * 1.5 + 1e-9

    def test_eq7_total_bound(self):
        L, delta = 8, 0.02
        n = 1 << L
        rows, cols = np.nonzero(random_mask(n, delta, seed=1))
        per = an.count_tasks_per_level_pairs(rows, cols, n)
        assert sum(per.values()) <= an.random_total_bound(n, delta)

    def test_banded_bounds_hold(self):
        L, k = 8, 3               # d = 2^k = 8
        n, d = 1 << L, 1 << k
        rows, cols = banded_pairs(n, d)
        per = an.count_tasks_per_level_pairs(rows, cols, n)
        for l, c in per.items():
            assert c <= an.banded_tasks_bound(L, k, l) + 1e-9
        assert sum(per.values()) <= an.banded_total_bound(n, d)

    def test_banded_leaf_level_dominates(self):
        """Fig 3: with locality, work concentrates at the lowest levels."""
        L, k = 10, 2
        n, d = 1 << L, 1 << k
        rows, cols = banded_pairs(n, d)
        per = an.count_tasks_per_level_pairs(rows, cols, n)
        assert per[L] > 0.5 * sum(per.values())

    def test_eq16_flops_exact(self):
        """Eq (16) equals the exact count of banded x banded scalar muls."""
        n, d = 64, 3
        a = banded_mask(n, d).astype(float)
        # count scalar multiplications: sum_k (nnz in col k of A) * (nnz row k of B)
        exact = 2.0 * int((a.sum(0) * a.sum(1)).sum())
        assert exact == an.banded_multiply_flops(n, d)

    def test_counts_pairs_equal_quadtree_blocks1(self):
        """The coordinate-list level counter reproduces the task graph's
        per-level multiply counts for a blocksize-1 quadtree."""
        n = 32
        params = QTParams(n, 1, 1)
        mask = random_mask(n, 0.1, seed=3)
        a = values_for_mask(mask, seed=3)
        g = CTGraph()
        ra = qt_from_dense(g, a, params)
        rb = qt_from_dense(g, a, params)
        qt_multiply(g, params, ra, rb)
        got = count_tasks_per_level(g)
        rows, cols = np.nonzero(mask)
        want = an.count_tasks_per_level_pairs(rows, cols, n)
        for l, c in got.items():
            assert want[l] == c

    def test_spsumma_formulas(self):
        assert an.spsumma_elements_fetched_per_process(5, 1000, 4) == \
            2 * 5 * 1000 / 2.0
        assert an.spsumma_weak_scaling_elements(5, 10, 16) == 2 * 5 * 10 * 4.0

    def test_exec_time_models_monotone(self):
        assert an.exec_time_banded(1 << 12, 8, 16) < \
            an.exec_time_banded(1 << 12, 8, 4)
        assert an.exec_time_random(1 << 12, 1e-3, 16) < \
            an.exec_time_random(1 << 12, 1e-3, 4)
