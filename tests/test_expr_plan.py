"""Lazy expression IR + compiled Plans (api/expr.py, api/plan.py).

Pins the api_redesign four ways:

1. **Pinned identity** — for each single op (``@``, ``+``, ``.T``-folded
   multiply, ``sym_square``, ``multiply(tau=)``) the lazy path compiles
   to a CTGraph with identical task kinds, per-level counts and simulated
   schedule as the eager path (which test_api.py pins to the qt_* layer).
2. **Plan reuse** — executing the same compiled Plan again with rebound
   inputs registers *zero* new tasks and matches a fresh eager
   computation numerically, on both leaf engines; per-iteration simulated
   task counts and store owned-bytes stay flat.
3. **Rewrite pipeline** — transpose folding, add flattening, scale
   folding and CSE produce correct numerics and the expected graph
   shrinkage.
4. **Satellites** — the new algebra (``A - B``, ``alpha * A``,
   ``trace()``), ``Session.free``, and Session constructor validation.
"""
import numpy as np
import pytest

from repro import Matrix, Plan, Session
from repro.api.expr import (Add, Input, MatMul, Scale, SymMul, Transpose,
                            rewrite)
from repro.core.engine import EngineRebindError, PallasEngine
from repro.core.patterns import (banded_mask, random_mask,
                                 random_symmetric_mask, values_for_mask)

N, LEAF_N, BS = 64, 16, 4
TOL = dict(atol=1e-4, rtol=1e-4)   # pallas packs float32; numpy is float64


def _session(engine="numpy", **kw):
    kw.setdefault("leaf_n", LEAF_N)
    kw.setdefault("bs", BS)
    return Session(engine=engine, **kw)


def _dense(seed=0, scale=0.1):
    """Full-support operand: its structure is closed under products, the
    shape iterative algorithms rebind plans with."""
    rng = np.random.default_rng(seed)
    return rng.standard_normal((N, N)) * scale


def _banded(width=5, seed=1):
    return values_for_mask(banded_mask(N, width), seed=seed)


def _decayed(seed=0, rate=3.0):
    """Full-support matrix with exponentially decaying off-diagonal
    magnitude: structure closed under products, plenty of prunable
    (tiny-norm) blocks for the SpAMM tests."""
    idx = np.arange(N)
    decay = np.exp(-np.abs(idx[:, None] - idx[None, :]) / rate)
    return _dense(seed=seed, scale=1.0) * decay


def _schedule(sess):
    """(kinds, per-level counts, simulated schedule) of a session."""
    rep = sess.simulate(fresh_stats=True)
    return (sess.task_counts(), sess.tasks_per_level(),
            rep.bytes_received, rep.tasks_per_worker, rep.makespan)


class TestPinnedIdentity:
    """Lazy compile == eager == qt_* for every single-op expression."""

    CASES = {
        "matmul": lambda A, B: A @ B,
        "add": lambda A, B: A + B,
        "transpose_folded_matmul": lambda A, B: A.T @ B,
        "matmul_tau": lambda A, B: A.multiply(B, tau=1e-3),
    }

    @pytest.mark.parametrize("case", sorted(CASES))
    def test_kinds_levels_schedule_identical(self, case):
        op = self.CASES[case]
        a, b = _banded(5, seed=1), _banded(7, seed=2)

        eager = _session(p=4, seed=0)
        A, B = eager.from_dense(a), eager.from_dense(b)
        eager.simulate()                      # build phase places inputs
        out_e = op(A, B)
        sched_e = _schedule(eager)

        lazy = _session(p=4, seed=0, lazy=True)
        Al, Bl = lazy.from_dense(a), lazy.from_dense(b)
        lazy.simulate()
        plan = lazy.compile(op(Al, Bl))
        out_l = plan.run()
        sched_l = _schedule(lazy)

        assert sched_e == sched_l
        np.testing.assert_allclose(out_l.to_dense(), out_e.to_dense(),
                                   atol=1e-12)

    def test_sym_square_identical(self):
        s = values_for_mask(random_symmetric_mask(N, 0.15, seed=3),
                            seed=3, symmetric=True)

        eager = _session(p=4, seed=0)
        S = eager.from_dense(s, upper=True)
        eager.simulate()
        out_e = S.sym_square()
        sched_e = _schedule(eager)

        lazy = _session(p=4, seed=0, lazy=True)
        Sl = lazy.from_dense(s, upper=True)
        lazy.simulate()
        out_l = lazy.compile(Sl.sym_square()).run()
        sched_l = _schedule(lazy)

        assert sched_e == sched_l
        np.testing.assert_allclose(out_l.to_dense(), out_e.to_dense(),
                                   atol=1e-12)

    def test_lazy_truncation_report_matches_eager(self):
        a, b = _decayed(seed=5), _decayed(seed=6)
        eager = _session()
        Ce = eager.from_dense(a).multiply(eager.from_dense(b), tau=1e-2)
        lazy = _session(lazy=True)
        Cl = lazy.compile(
            lazy.from_dense(a).multiply(lazy.from_dense(b), tau=1e-2)).run()
        assert Cl.truncation is not None
        assert Cl.truncation.to_dict() == Ce.truncation.to_dict()
        assert Cl.error_bound == Ce.error_bound > 0.0


class TestPlanReuse:
    """Re-running a compiled plan registers zero tasks and stays correct."""

    @pytest.mark.parametrize("engine", ["numpy",
                                        pytest.param("pallas",
                                                     marks=pytest.mark.pallas)])
    def test_zero_new_tasks_and_fresh_eager_numerics(self, engine):
        a = _dense(seed=0)
        tol = dict(atol=1e-12) if engine == "numpy" else TOL
        lazy = _session(engine=engine, lazy=True)
        X = lazy.from_dense(a, name="X")
        plan = lazy.compile(X @ X)
        Y = plan.run()
        np.testing.assert_allclose(Y.to_dense(), a @ a, **tol)
        n_nodes = len(lazy.graph.nodes)

        for it in range(3):
            Y = plan.run(X=Y)
            assert len(lazy.graph.nodes) == n_nodes  # zero new tasks
        want = np.linalg.matrix_power(a, 16)
        # fresh eager computation of the same final product
        fresh = _session(engine=engine)
        F = fresh.from_dense(np.linalg.matrix_power(a, 8))
        np.testing.assert_allclose(Y.to_dense(), (F @ F).to_dense(), **TOL)
        np.testing.assert_allclose((F @ F).to_dense(), want, **TOL)

    def test_rebind_dense_array(self):
        a, a2 = _dense(seed=1), _dense(seed=2)
        lazy = _session(lazy=True)
        X = lazy.from_dense(a, name="X")
        plan = lazy.compile(X @ X)
        plan.run()
        out = plan.run(X=a2)
        np.testing.assert_allclose(out.to_dense(), a2 @ a2, atol=1e-12)

    def test_plan_cached_by_structure_and_inputs(self):
        """Recompiling the same expression hits the cached plan; a
        different input set (or structure) compiles its own program —
        plans never implicitly rebind matrices the caller didn't pass to
        ``run``."""
        lazy = _session(lazy=True)
        A = lazy.from_dense(_dense(seed=1))
        B = lazy.from_dense(_dense(seed=2))
        p1 = lazy.compile(A @ A)
        assert lazy.compile(A @ A) is p1
        assert lazy.compile(B @ B) is not p1    # other inputs, own plan
        C = lazy.from_dense(_banded(4, seed=3))
        assert lazy.compile(C @ C) is not p1    # different structure
        assert lazy.compile(A @ B) is not p1    # X @ X is not X @ Y

    def test_lazy_readback_never_corrupts_other_matrices(self):
        """Forcing B @ B after A @ A (identical structure) must not
        overwrite A's values through the plan cache."""
        a, b = _dense(seed=21), _dense(seed=22)
        lazy = _session(lazy=True)
        A = lazy.from_dense(a)
        B = lazy.from_dense(b)
        np.testing.assert_allclose((A @ A).to_dense(), a @ a, atol=1e-12)
        np.testing.assert_allclose((B @ B).to_dense(), b @ b, atol=1e-12)
        np.testing.assert_allclose(A.to_dense(), a, atol=0)   # untouched
        np.testing.assert_allclose((A @ A).to_dense(), a @ a, atol=1e-12)

    def test_lazy_readback_flat_graph(self):
        """Forcing the same expression shape repeatedly reuses the cached
        plan: per-iteration graph size is constant (the motivation)."""
        a = _dense(seed=4)
        lazy = _session(lazy=True)
        X = lazy.from_dense(a, name="X")
        d1 = (X @ X).to_dense()
        np.testing.assert_allclose(d1, a @ a, atol=1e-12)
        n_nodes = len(lazy.graph.nodes)
        for _ in range(3):
            _ = (X @ X).to_dense()          # same plan, replayed
        assert len(lazy.graph.nodes) == n_nodes

    def test_per_iteration_simulation_flat(self):
        """Plan.simulate replays the fixed program: per-iteration task
        counts and store owned-bytes do not grow."""
        a = _dense(seed=5)
        lazy = _session(lazy=True, p=4, seed=0)
        X = lazy.from_dense(a, name="X")
        lazy.simulate()                     # build phase
        plan = lazy.compile(X @ X)
        Y = plan.run()
        reps = [plan.simulate()]
        owned = []
        for _ in range(3):
            Y = plan.run(X=Y)
            reps.append(plan.simulate())
            owned.append(sum(s.owned_bytes
                             for s in lazy.scheduler.store.stats))
        assert len({r.n_tasks for r in reps}) == 1
        assert reps[0].n_tasks == plan.n_tasks > 0
        assert len(set(owned)) == 1         # no chunk-store leak

    def test_plan_simulate_is_isolated_per_program(self):
        """The first Plan.simulate charges only the plan's own program —
        another compiled-but-unsimulated plan keeps its own report."""
        a = _dense(seed=34)
        lazy = _session(lazy=True, p=2, seed=0)
        X = lazy.from_dense(a, name="X")
        lazy.simulate()                     # build phase
        p_sq = lazy.compile(X @ X)
        Y = p_sq.run()
        p_pol = lazy.compile(2.0 * X - Y)
        p_pol.run()                         # both executed, none simulated
        rep_sq = p_sq.simulate()
        assert rep_sq.n_tasks == p_sq.n_tasks           # not sq + pol
        rep_pol = p_pol.simulate()
        assert rep_pol.n_tasks == p_pol.n_tasks
        # replays stay per-program too
        p_sq.run(X=Y)
        assert p_sq.simulate().n_tasks == p_sq.n_tasks

    def test_rebind_honors_lazy_transpose_flag(self):
        """plan.run(X=B.T) must bind Bᵀ's values, not silently B's."""
        a, b = _dense(seed=31), _dense(seed=32)
        lazy = _session(lazy=True)
        X = lazy.from_dense(a, name="X")
        plan = lazy.compile(X @ X)
        plan.run()
        B = lazy.from_dense(b)
        out = plan.run(X=B.T)
        np.testing.assert_allclose(out.to_dense(), b.T @ b.T, atol=1e-12)
        # the bound input's own transpose: X now holds bᵀ, so X.T is b
        out = plan.run(X=X.T)
        np.testing.assert_allclose(out.to_dense(), b @ b, atol=1e-12)

    def test_rebind_dense_upper_support_checked(self):
        """Out-of-structure values on an upper-storage input must raise,
        exactly as they do for plain storage."""
        rng = np.random.default_rng(33)
        blockdiag = np.zeros((N, N))
        h = N // 2
        for sl in (slice(0, h), slice(h, N)):
            blk = rng.standard_normal((h, h))
            blockdiag[sl, sl] = blk + blk.T
        lazy = _session(lazy=True)
        S = lazy.from_dense(blockdiag, upper=True, name="S")
        plan = lazy.compile(S.sym_square())
        plan.run()
        full = rng.standard_normal((N, N))
        full = full + full.T            # full support: off-diagonal too
        with pytest.raises(ValueError, match="structure mismatch"):
            plan.run(S=full)
        # same-support new values are fine
        out = plan.run(S=2.0 * blockdiag)
        np.testing.assert_allclose(out.to_dense(),
                                   (2.0 * blockdiag) @ (2.0 * blockdiag),
                                   atol=1e-9)

    def test_rebind_structure_mismatch_raises(self):
        lazy = _session(lazy=True)
        X = lazy.from_dense(_dense(seed=6), name="X")
        plan = lazy.compile(X @ X)
        plan.run()
        with pytest.raises(ValueError, match="structure mismatch"):
            plan.run(X=lazy.from_dense(_banded(3, seed=7)))
        with pytest.raises(ValueError, match="unknown plan input"):
            plan.run(Z=_dense(seed=6))

    def test_rebind_refreshes_norm_and_trace_caches(self):
        """Caches keyed to the old values (chunk norms, traces) must not
        survive a rebind+replay."""
        a, a2 = _dense(seed=8), _dense(seed=9)
        lazy = _session(lazy=True)
        X = lazy.from_dense(a, name="X")
        plan = lazy.compile(X @ X)
        Y = plan.run()
        t1, n1 = Y.trace(), Y.norm2()
        assert t1 == pytest.approx(np.trace(a @ a), abs=1e-10)
        plan.run(X=a2)
        assert Y.trace() == pytest.approx(np.trace(a2 @ a2), abs=1e-10)
        assert Y.norm2() == pytest.approx(((a2 @ a2) ** 2).sum(), rel=1e-10)
        assert (t1, n1) != (Y.trace(), Y.norm2())

    def test_truncated_plan_freezes_structure(self):
        """A tau>0 plan replays its compile-time pruning decisions: the
        task program is fixed, whatever the rebound norms say."""
        a = _decayed(seed=11)
        lazy = _session(lazy=True)
        X = lazy.from_dense(a, name="X")
        plan = lazy.compile(X.multiply(X, tau=1e-2))
        Y1 = plan.run()
        bound = plan.error_bound
        assert bound > 0.0                  # something was pruned
        y1 = Y1.to_dense()                  # snapshot before the replay
        n_nodes = len(lazy.graph.nodes)
        err1 = np.linalg.norm(y1 - a @ a)
        assert err1 <= bound + 1e-12
        # rescaled values would prune differently in a fresh compile;
        # the plan replays the frozen program instead, so the truncated
        # product scales exactly: Y(3a) = 9 Y(a) over the same kept pairs
        Y2 = plan.run(X=3.0 * a)
        assert len(lazy.graph.nodes) == n_nodes
        np.testing.assert_allclose(Y2.to_dense() / 9.0, y1, atol=1e-10)


class TestRewritePipeline:
    """Unit + integration coverage of the expression rewrites."""

    def setup_method(self):
        self.x = Input(1, N)
        self.y = Input(2, N)

    def test_double_transpose_cancels(self):
        assert rewrite(Transpose(Transpose(self.x))) == self.x

    def test_transpose_folds_into_multiply(self):
        got = rewrite(MatMul(Transpose(self.x), self.y))
        assert got == MatMul(self.x, self.y, ta=True, tb=False)
        got = rewrite(Transpose(MatMul(self.x, self.y)))
        assert got == MatMul(self.y, self.x, ta=True, tb=True)

    def test_transpose_of_upper_is_identity(self):
        s = Input(3, N, upper=True)
        assert rewrite(Transpose(s)) == s

    def test_sym_routing(self):
        s = Input(3, N, upper=True)
        assert rewrite(MatMul(s, self.x)) == SymMul(s, self.x, "left")
        assert rewrite(MatMul(self.x, s)) == SymMul(s, self.x, "right")

    def test_add_chain_flattens(self):
        z = Input(4, N)
        got = rewrite(Add((Add((self.x, self.y)), z)))
        assert got == Add((self.x, self.y, z))
        assert got == rewrite(Add((self.x, Add((self.y, z)))))

    def test_all_transposed_add_hoists(self):
        got = rewrite(Add((Transpose(self.x), Transpose(self.y))))
        assert got == Transpose(Add((self.x, self.y)))

    def test_scale_folding(self):
        got = rewrite(Scale(2.0, Scale(3.0, self.x)))
        assert got == Scale(6.0, self.x)
        assert rewrite(Scale(0.5, Scale(2.0, self.x))) == self.x
        assert rewrite(Scale(2.0, Transpose(self.x))) == \
            Transpose(Scale(2.0, self.x))

    def test_cse_lowers_shared_subexpression_once(self):
        """(X@X) + (X@X): the product is registered a single time."""
        a = _dense(seed=12)
        lazy = _session(lazy=True)
        X = lazy.from_dense(a)
        D = (X @ X) + (X @ X)
        np.testing.assert_allclose(D.to_dense(), 2 * (a @ a), atol=1e-11)
        single = _session()
        Xs = single.from_dense(a)
        _ = Xs @ Xs
        # one multiply program + the top-level adds; far below two programs
        n_mult_single = single.task_counts()["multiply"]
        assert lazy.task_counts()["multiply"] == n_mult_single

    def test_cross_op_transpose_fold_avoids_transpose_tasks(self):
        """Lazy (A@B).T + C folds to Bᵀ@Aᵀ + C: no transpose program."""
        a, b, c = _banded(5, 1), _banded(4, 2), _banded(3, 3)
        lazy = _session(lazy=True)
        A, B, C = (lazy.from_dense(x) for x in (a, b, c))
        got = ((A @ B).T + C).to_dense()
        np.testing.assert_allclose(got, (a @ b).T + c, atol=1e-11)
        assert "transpose" not in lazy.task_counts()
        # the eager facade materialises the transpose instead
        eager = _session()
        Ae, Be, Ce = (eager.from_dense(x) for x in (a, b, c))
        _ = (Ae @ Be).T + Ce
        assert eager.task_counts()["transpose"] > 0


class TestNewAlgebra:
    """Satellite: A - B, alpha * A, Matrix.trace()."""

    def setup_method(self):
        self.sess = _session()
        self.a = _banded(5, seed=1)
        self.b = values_for_mask(random_mask(N, 0.15, seed=2), seed=2)
        self.A = self.sess.from_dense(self.a)
        self.B = self.sess.from_dense(self.b)

    def test_sub(self):
        np.testing.assert_allclose((self.A - self.B).to_dense(),
                                   self.a - self.b, atol=1e-12)
        np.testing.assert_allclose((self.A - self.A).to_dense(),
                                   np.zeros((N, N)), atol=1e-12)

    def test_scalar_multiply(self):
        np.testing.assert_allclose((2.5 * self.A).to_dense(),
                                   2.5 * self.a, atol=1e-12)
        np.testing.assert_allclose((self.A * -0.5).to_dense(),
                                   -0.5 * self.a, atol=1e-12)
        np.testing.assert_allclose((-self.A).to_dense(), -self.a,
                                   atol=1e-12)
        np.testing.assert_allclose((2.0 * self.A.T).to_dense(),
                                   2.0 * self.a.T, atol=1e-12)
        with pytest.raises(TypeError):
            _ = self.A * self.B             # matrix * matrix is @

    def test_scale_special_cases(self):
        assert (0.0 * self.A).is_nil        # structurally NIL
        one = 1.0 * self.A
        assert one.node == self.A.node      # identifier copy, no task
        Z = self.sess.zeros(N)
        assert (2.0 * Z).is_nil

    def test_scale_preserves_upper(self):
        s = values_for_mask(random_symmetric_mask(N, 0.1, seed=13),
                            seed=13, symmetric=True)
        S = self.sess.from_dense(s, upper=True)
        H = 0.5 * S
        assert H.upper
        np.testing.assert_allclose(H.to_dense(), 0.5 * s, atol=1e-12)
        np.testing.assert_allclose((S - H).to_dense(), 0.5 * s, atol=1e-12)

    def test_trace(self):
        assert self.A.trace() == pytest.approx(np.trace(self.a), abs=1e-10)
        assert self.A.T.trace() == pytest.approx(np.trace(self.a),
                                                 abs=1e-10)
        assert self.sess.zeros(N).trace() == 0.0
        s = values_for_mask(random_symmetric_mask(N, 0.1, seed=14),
                            seed=14, symmetric=True)
        S = self.sess.from_dense(s, upper=True)
        assert S.trace() == pytest.approx(np.trace(s), abs=1e-10)
        C = self.A @ self.B
        assert C.trace() == pytest.approx(np.trace(self.a @ self.b),
                                          abs=1e-10)

    @pytest.mark.pallas
    def test_pallas_equivalence(self):
        outs = {}
        for engine in ("numpy", "pallas"):
            sess = _session(engine=engine)
            A, B = sess.from_dense(self.a), sess.from_dense(self.b)
            E = 2.0 * (A @ B) - B
            outs[engine] = E.to_dense()
            assert E.trace() == pytest.approx(
                np.trace(2.0 * (self.a @ self.b) - self.b), abs=1e-2)
        np.testing.assert_allclose(outs["pallas"], outs["numpy"], **TOL)
        np.testing.assert_allclose(outs["numpy"],
                                   2.0 * (self.a @ self.b) - self.b,
                                   atol=1e-10)


class TestSessionFree:
    """Satellite: intermediate-chunk garbage collection."""

    def test_free_releases_owned_bytes(self):
        sess = _session(p=2, seed=0)
        A = sess.from_dense(_banded(5, seed=1))
        B = sess.from_dense(_banded(6, seed=2))
        sess.simulate()
        C = A @ B
        sess.simulate(fresh_stats=True)
        store = sess.scheduler.store
        owned = sum(s.owned_bytes for s in store.stats)
        freed = sess.free(C)
        assert freed > 0
        assert sum(s.owned_bytes for s in store.stats) == owned - freed
        # placement entries of the freed tree are gone; double-free is a
        # no-op rather than a store KeyError
        assert sess.free(C) == 0

    def test_iterative_loop_with_free_stays_flat(self):
        """An eager X@X loop that frees each consumed intermediate keeps
        the store's owned bytes bounded."""
        a = _dense(seed=3)
        sess = _session(p=2, seed=0)
        X = sess.from_dense(a)
        sess.simulate()
        owned = []
        store = sess.scheduler.store
        for _ in range(4):
            Y = X @ X
            sess.simulate(fresh_stats=True)
            sess.free(X)
            X = Y
            owned.append(sum(s.owned_bytes for s in store.stats))
        # bounded: each iteration's net growth is one result tree, not
        # the whole history (X@X on full support has constant size)
        assert max(owned) - min(owned) <= owned[0]
        assert owned[-1] <= 2 * owned[0]

    def test_free_is_refcount_aware_with_dedup(self):
        """Content shared through dedup survives the first free."""
        a = _banded(5, seed=4)
        sess = _session(p=2, seed=0, dedup=True)
        A = sess.from_dense(a)
        B = sess.from_dense(a)          # dedup: leaf chunks shared with A
        rep = sess.simulate()
        assert sum(rep.dedup_hits) > 0
        store = sess.scheduler.store
        owned0 = sum(s.owned_bytes for s in store.stats)
        freed_b = sess.free(B)
        # B's leaves were refcounted copies of A's: only B's internal
        # (identifier) chunks are actually released
        assert 0 <= freed_b < owned0 / 2
        freed_a = sess.free(A)
        assert freed_a > freed_b        # the leaf data goes with A
        assert sum(s.owned_bytes for s in store.stats) == \
            owned0 - freed_a - freed_b

    def test_free_unsimulated_or_lazy_is_noop(self):
        sess = _session()
        A = sess.from_dense(_banded(3, seed=5))
        assert sess.free(A) == 0        # no scheduler yet
        lazy = _session(lazy=True)
        X = lazy.from_dense(_banded(3, seed=5))
        assert lazy.free(X @ X) == 0    # pending expression
        with pytest.raises(TypeError):
            sess.free("not a matrix")


class TestSessionValidation:
    """Satellite: constructor validation + facade error surfacing."""

    def test_unknown_placement_alias(self):
        with pytest.raises(ValueError, match="unknown placement"):
            _session(placement="summa")
        with pytest.raises(ValueError, match="unknown placement"):
            _session().simulate(placement="nope")
        assert _session(placement="rr").placement == "round-robin"

    def test_bad_engine_string_raises_at_construction(self):
        with pytest.raises(ValueError, match="unknown leaf engine"):
            _session(engine="cuda")
        with pytest.raises(ValueError, match="unknown leaf engine"):
            _session(engine=42)

    def test_engine_instance_accepted(self):
        e = PallasEngine()
        sess = _session(engine=e)
        assert sess.graph.engine is e

    @pytest.mark.pallas
    def test_rebind_error_surfaced_through_facade(self):
        a = _banded(3, seed=6)
        e = PallasEngine()
        s1 = _session(engine=e)
        A = s1.from_dense(a)
        _ = A @ A
        s2 = _session(engine=e, lazy=True)
        B = s2.from_dense(a)
        with pytest.raises(EngineRebindError, match="one engine per graph"):
            (B @ B).to_dense()

    def test_compile_validation(self):
        sess = _session()
        A = sess.from_dense(_banded(3, seed=7))
        with pytest.raises(ValueError, match="already materialised"):
            sess.compile(A)
        with pytest.raises(TypeError, match="Matrix or Expr"):
            sess.compile("X @ X")
        other = _session(lazy=True)
        X = other.from_dense(_banded(3, seed=7))
        with pytest.raises(ValueError, match="different Session"):
            sess.compile(X @ X)


class TestPlanApi:
    def test_named_and_default_slots(self):
        lazy = _session(lazy=True)
        X = lazy.from_dense(_dense(seed=1), name="X")
        Y = lazy.from_dense(_dense(seed=2))
        plan = lazy.compile(X @ Y)
        assert plan.input_names == ["X", "x1"]
        assert "X" in repr(plan) and "uncompiled" in repr(plan)
        plan.run()
        assert f"tasks={plan.n_tasks}" in repr(plan)

    def test_colliding_user_name_stays_bindable(self):
        """A user name that collides with an auto slot name must not
        shadow the other slot — every slot keeps a unique name."""
        lazy = _session(lazy=True)
        X = lazy.from_dense(_dense(seed=1), name="x1")
        Y = lazy.from_dense(_dense(seed=2))
        plan = lazy.compile(X @ Y)
        assert len(set(plan.input_names)) == 2
        assert plan.input_names[0] == "x1"
        plan.run()
        a2, b2 = _dense(seed=3), _dense(seed=4)
        out = plan.run(**{plan.input_names[0]: a2,
                          plan.input_names[1]: b2})
        np.testing.assert_allclose(out.to_dense(), a2 @ b2, atol=1e-12)

    def test_chained_truncated_expr_reports(self):
        """A multi-product truncated expression keeps the outermost
        product's report on the handle (eager chaining semantics) and
        the per-product sum on the plan."""
        a = _decayed(seed=23)
        lazy = _session(lazy=True, tau=1e-2)
        X = lazy.from_dense(a, name="X")
        plan = lazy.compile((X @ X) @ X)
        D = plan.run()
        assert len(plan.reports) == 2
        assert D.truncation is plan.reports[-1]     # outermost product
        assert D.error_bound > 0.0
        assert plan.error_bound == pytest.approx(
            sum(r.error_bound for r in plan.reports))

    def test_rebind_retires_stale_dedup_fingerprints(self):
        """With dedup=True, rebinding a chunk's values in place must also
        retire its content fingerprint: registering the *original* bytes
        again must not resolve to the rebound chunk."""
        a, a2 = _dense(seed=26), _dense(seed=27)
        lazy = _session(lazy=True, dedup=True, p=2, seed=0)
        X = lazy.from_dense(a, name="X")
        lazy.simulate()
        plan = lazy.compile(X @ X)
        plan.run()
        plan.simulate()
        plan.run(X=a2)              # X's chunks now hold a2's values
        A_again = lazy.from_dense(a)
        rep = lazy.simulate(fresh_stats=True)
        np.testing.assert_allclose(A_again.to_dense(), a, atol=0)
        # no dedup hit against the rebound (now-different) bytes
        assert sum(rep.dedup_hits) == 0

    def test_lazy_add_root_carries_no_truncation_report(self):
        """Eager parity: only a multiply-produced handle carries a
        TruncationReport; an add over a truncated product does not."""
        a = _decayed(seed=28)
        lazy = _session(lazy=True)
        X = lazy.from_dense(a, name="X")
        R = lazy.compile(X.multiply(X, tau=1e-2) + X).run()
        assert R.truncation is None and R.error_bound == 0.0
        eager = _session()
        Xe = eager.from_dense(a)
        Re = Xe.multiply(Xe, tau=1e-2) + Xe
        assert Re.truncation is None and Re.error_bound == 0.0

    def test_free_spares_session_cached_transposes(self):
        """free() must not release a materialised transpose shared
        through the session transpose cache: a later expression reusing
        it still fetches placed chunks."""
        a, b, c = _banded(5, 1), _banded(4, 2), _banded(3, 3)
        sess = _session(p=2, seed=0)
        A, B, C = (sess.from_dense(x) for x in (a, b, c))
        sess.simulate()
        R1 = A.T + B                    # materialises transpose(A), cached
        sess.simulate(fresh_stats=True)
        sess.free(R1)
        # the resolved transpose chunks (what dependency fetches look up)
        # must stay placed; alias entries may go, resolution covers them
        tnids = [sess.graph.resolve(n)
                 for n in sess._transpose_cache.values() if n is not None]
        assert tnids
        assert all(nid in sess.scheduler.placement for nid in tnids)
        R2 = A.T + C                    # reuses the cached transpose
        rep = sess.simulate(fresh_stats=True)
        np.testing.assert_allclose(R2.to_dense(), a.T + c, atol=1e-12)
        assert rep.n_tasks > 0

    def test_sym_tau_error_attribution(self):
        s = values_for_mask(random_symmetric_mask(N, 0.1, seed=29),
                            seed=29, symmetric=True)
        sess = _session()               # session tau = 0
        S = sess.from_dense(s, upper=True)
        with pytest.raises(ValueError, match="passed explicitly"):
            S.sym_square(tau=1e-3)
        sess2 = _session(tau=1e-3)
        S2 = sess2.from_dense(s, upper=True)
        with pytest.raises(ValueError, match="Session default"):
            S2.sym_square()

    def test_raw_expr_sym_tau_raises_in_rewrite(self):
        """Hand-built MatMul(tau>0) over an upper operand must fail
        loudly, matching the facade's untruncated-sym contract."""
        s = values_for_mask(random_symmetric_mask(N, 0.1, seed=24),
                            seed=24, symmetric=True)
        lazy = _session(lazy=True)
        S = lazy.from_dense(s, upper=True)
        B = lazy.from_dense(_banded(4, seed=25))
        with pytest.raises(ValueError, match="untruncated"):
            lazy.compile(MatMul(Input(S.node, N, upper=True),
                                Input(B.node, N), tau=1e-3))

    def test_run_before_simulate_required(self):
        lazy = _session(lazy=True)
        X = lazy.from_dense(_dense(seed=1))
        plan = lazy.compile(X @ X)
        with pytest.raises(RuntimeError, match="not executed"):
            plan.simulate()
        assert isinstance(plan, Plan)

    def test_compile_raw_expr(self):
        """compile() also accepts a hand-built Expr over bound inputs."""
        lazy = _session(lazy=True)
        A = lazy.from_dense(_dense(seed=3))
        e = MatMul(Input(A.node, N), Input(A.node, N))
        plan = lazy.compile(e)
        assert plan is lazy.compile(A @ A)      # same fingerprint
        out = plan.run()
        np.testing.assert_allclose(out.to_dense(),
                                   _dense(seed=3) @ _dense(seed=3),
                                   atol=1e-12)
        # an all-NIL expression compiles and lowers to the NIL matrix
        nil = lazy.compile(Scale(2.0, Input(None, N))).run()
        assert nil.is_nil

    def test_matrix_repr_and_flags(self):
        lazy = _session(lazy=True)
        X = lazy.from_dense(_dense(seed=1))
        C = X @ X
        assert C.is_lazy and "lazy" in repr(C)
        _ = C.to_dense()
        assert not C.is_lazy
        assert isinstance(Matrix.from_dense(lazy, _dense(seed=1)), Matrix)


class TestPlanStructureGuard:
    """Structure-mismatch rebinds raise typed PlanStructureError and the
    recompile=True escape hatch handles the changing-sparsity regime
    (the bugfix headline of the mesh-executor PR)."""

    ENGINES = ["numpy",
               pytest.param("pallas", marks=pytest.mark.pallas)]

    @pytest.mark.parametrize("engine", ENGINES)
    def test_denser_rebind_under_tau_raises_typed(self, engine):
        """Replaying a frozen truncation pair list against a denser input
        would silently drop contributions — it must raise, atomically,
        with the typed exception."""
        from repro import PlanStructureError
        a = _banded(5, seed=41)
        lazy = _session(engine=engine, lazy=True)
        X = lazy.from_dense(a, name="X")
        plan = lazy.compile(X.multiply(X, tau=1e-3))
        out1 = plan.run().to_dense()
        denser = _banded(25, seed=42)
        with pytest.raises(PlanStructureError):
            plan.run(X=denser)
        with pytest.raises(PlanStructureError):
            plan.run(X=lazy.from_dense(denser))
        # the failed rebind was atomic: the plan replays the old program
        # against the old values untouched
        np.testing.assert_allclose(plan.run().to_dense(), out1,
                                   atol=1e-12)

    @pytest.mark.parametrize("engine", ENGINES)
    def test_recompile_escape_hatch(self, engine):
        """recompile=True recompiles through the session cache on a
        structure mismatch and returns the correct denser result."""
        a = _banded(5, seed=43)
        tol = dict(atol=1e-12) if engine == "numpy" else TOL
        lazy = _session(engine=engine, lazy=True)
        X = lazy.from_dense(a, name="X")
        plan = lazy.compile(X.multiply(X, tau=1e-4))
        plan.run()
        denser = _banded(25, seed=44)
        out = plan.run(X=denser, recompile=True)
        got = out.to_dense()
        want = denser @ denser
        assert np.abs(got - want).max() < 1e-2      # tau-truncated
        # second recompile with the same (new) structure reuses the
        # recompiled plan instead of growing the session's plan cache
        n_plans = len(lazy._plans)
        out2 = plan.run(X=2.0 * denser, recompile=True)
        assert len(lazy._plans) == n_plans
        np.testing.assert_allclose(out2.to_dense(), 4.0 * got, **tol)
        # the original plan is still intact for the original structure
        np.testing.assert_allclose(plan.run(X=a).to_dense(),
                                   plan.run().to_dense(), atol=1e-12)

    def test_plan_structure_error_is_value_error(self):
        """Typed but backwards compatible: existing except ValueError
        handlers keep working."""
        from repro import PlanStructureError
        assert issubclass(PlanStructureError, ValueError)

    def test_recompile_kwarg_never_a_slot_name(self):
        """`recompile` is reserved: a same-structure run with
        recompile=True binds nothing and just replays."""
        lazy = _session(lazy=True)
        X = lazy.from_dense(_dense(seed=45), name="X")
        plan = lazy.compile(X @ X)
        out1 = plan.run().to_dense()
        np.testing.assert_allclose(plan.run(recompile=True).to_dense(),
                                   out1, atol=1e-12)
