"""Error-controlled truncated multiply (SpAMM-style, DESIGN.md §5).

Pins the truncation tentpole four ways:

1. **Error contract** (property tests): the measured truncation error
   ``||C_exact - C_tau||_F`` never exceeds the reported worst-case bound,
   across random/banded/S2 decay patterns, taus spanning ten decades, and
   both leaf engines.
2. **tau=0 identity** (pinned): a truncated multiply with tau=0 registers
   a task graph *identical* to the exact path — kinds, per-level counts,
   flops, and the simulated schedule — and its numeric result is
   bit-identical under the numpy engine.
3. **Monotonicity**: flops, task counts and communication demand are
   non-increasing in tau (the pruned-pair set only grows).
4. **Norm-cache maintenance**: cached norms stay consistent through
   ``A + B``, ``A.T``, ``sym_square``, engine wave fills, and
   ChunkStore free/dedup (no stale reads).
"""
import math

import numpy as np
import pytest

from _hyp import given, settings, st

from repro import Session
from repro.core import analysis as an
from repro.core.chunks import ChunkStore
from repro.core.leaf import LeafMatrix
from repro.core.multiply import TruncationReport, qt_multiply
from repro.core.patterns import (banded_mask, divide_space_order,
                                 overlap_mask, particle_cloud, random_mask,
                                 random_symmetric_mask, values_for_mask)
from repro.core.quadtree import (MatrixChunk, QTParams, qt_from_dense,
                                 qt_norm2)
from repro.core.tasks import CTGraph
from repro.runtime.scheduler import Scheduler

N, LEAF_N, BS = 64, 16, 4


def _decay(n=N, alpha=0.25):
    dist = np.abs(np.subtract.outer(np.arange(n), np.arange(n)))
    return np.exp(-alpha * dist)


def _s2_mask(n=N):
    coords = particle_cloud(4, 3, seed=7)          # 64 basis functions
    order = divide_space_order(coords)
    return overlap_mask(coords, 6.0, order=order)


# decay-valued operands: what truncation is *for* (paper §6.2 matrices)
PATTERNS = {
    "random": lambda seed: values_for_mask(
        random_mask(N, 0.2, seed=seed), seed=seed) * _decay(alpha=0.1),
    "banded": lambda seed: values_for_mask(
        banded_mask(N, 24), seed=seed) * _decay(alpha=0.2),
    "s2": lambda seed: values_for_mask(_s2_mask(), seed=seed)
    * _decay(alpha=0.15),
}


def _session(engine="numpy", **kw):
    kw.setdefault("leaf_n", LEAF_N)
    kw.setdefault("bs", BS)
    return Session(engine=engine, **kw)


def _err_slack(a, b):
    # float-rounding slack: the truncated leaf path sums block products
    # in a different order than the exact path, so a tau pruning nothing
    # can still differ by O(eps ||A|| ||B||); pallas adds float32 packing
    return 1e-4 * math.sqrt(float((a * a).sum()) * float((b * b).sum()))


def _check_bound(engine, pattern, seed, tau):
    a = PATTERNS[pattern](seed)
    b = PATTERNS[pattern](seed + 1)
    exact_sess = _session(engine=engine)
    exact = (exact_sess.from_dense(a) @ exact_sess.from_dense(b)).to_dense()

    sess = _session(engine=engine)
    C = sess.from_dense(a).multiply(sess.from_dense(b), tau=tau)
    err = float(np.linalg.norm(exact - C.to_dense()))
    assert err <= C.error_bound + _err_slack(a, b), (
        f"{engine}/{pattern} tau={tau}: measured {err} > "
        f"bound {C.error_bound}")
    return C


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 100), pattern=st.sampled_from(sorted(PATTERNS)),
       exp=st.integers(-8, 0))
def test_property_error_within_bound_numpy(seed, pattern, exp):
    """Measured error <= reported bound, numpy engine, tau over 9 decades."""
    _check_bound("numpy", pattern, seed, tau=10.0 ** exp)


@pytest.mark.pallas
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 100), pattern=st.sampled_from(sorted(PATTERNS)),
       exp=st.integers(-6, 0))
def test_property_error_within_bound_pallas(seed, pattern, exp):
    """Same contract through the deferred, cross-leaf-batched engine."""
    _check_bound("pallas", pattern, seed, tau=10.0 ** exp)


@pytest.mark.pallas
def test_validate_structure_cross_checks_norm_oracle():
    """PallasEngine(validate_structure=True) with tau>0 checks every leaf
    structure against bsmm.compute_c_structure_norms (f32-boundary-safe)."""
    from repro.core.engine import PallasEngine
    a = PATTERNS["banded"](41)
    b = PATTERNS["banded"](42)
    sess = _session(engine=PallasEngine(validate_structure=True))
    C = sess.from_dense(a).multiply(sess.from_dense(b), tau=1e-2)
    err = float(np.linalg.norm(a @ b - C.to_dense()))
    assert err <= C.error_bound + _err_slack(a, b)


@pytest.mark.pallas
def test_engines_agree_on_truncated_structure():
    """Both engines prune the same pairs: same result occupancy, same
    error bound, and numerics agree to float32 packing precision."""
    a = PATTERNS["banded"](3)
    b = PATTERNS["banded"](4)
    outs, bounds, nnz = {}, {}, {}
    for engine in ("numpy", "pallas"):
        sess = _session(engine=engine)
        C = sess.from_dense(a).multiply(sess.from_dense(b), tau=1e-2)
        outs[engine] = C.to_dense()
        bounds[engine] = C.error_bound
        nnz[engine] = C.nnz_blocks()
    assert nnz["numpy"] == nnz["pallas"]
    assert bounds["numpy"] == pytest.approx(bounds["pallas"], rel=1e-9)
    np.testing.assert_allclose(outs["pallas"], outs["numpy"],
                               atol=1e-4, rtol=1e-4)


class TestTauZeroIdentity:
    """tau=0 is graph-for-graph the exact multiply (pinned)."""

    def _inputs(self):
        return PATTERNS["banded"](1), PATTERNS["s2"](2)

    def test_graph_identical_kinds_counts_flops(self):
        a, b = self._inputs()
        params = QTParams(N, LEAF_N, BS)
        g = CTGraph()
        qt_multiply(g, params, qt_from_dense(g, a, params),
                    qt_from_dense(g, b, params))

        gt = CTGraph()
        rep = TruncationReport(tau=0.0)
        qt_multiply(gt, params, qt_from_dense(gt, a, params),
                    qt_from_dense(gt, b, params), tau=0.0, trunc=rep)

        assert g.count_kinds() == gt.count_kinds()
        from repro.core.multiply import count_tasks_per_level, total_flops
        assert count_tasks_per_level(g) == count_tasks_per_level(gt)
        assert total_flops(g) == pytest.approx(total_flops(gt))
        assert [n.kind for n in g.nodes] == [n.kind for n in gt.nodes]
        assert [n.parent for n in g.nodes] == [n.parent for n in gt.nodes]
        assert rep.error_bound == 0.0 and rep.pruned_subtrees == 0

    def test_simulated_schedule_identical(self):
        """Same registrations + same seed => bit-identical replay."""
        a, b = self._inputs()
        params = QTParams(N, LEAF_N, BS)
        reports = {}
        for tau in (None, 0.0):
            g = CTGraph()
            sched = Scheduler(seed=0)
            ra = qt_from_dense(g, a, params)
            rb = qt_from_dense(g, b, params)
            sched.run(g, n_workers=4, placement="parent-worker")
            sched.reset_stats()
            if tau is None:
                qt_multiply(g, params, ra, rb)
            else:
                qt_multiply(g, params, ra, rb, tau=tau,
                            trunc=TruncationReport(tau=tau))
            reports[tau] = sched.run(g)
        want, got = reports[None], reports[0.0]
        assert got.bytes_received == want.bytes_received
        assert got.tasks_per_worker == want.tasks_per_worker
        assert got.makespan == pytest.approx(want.makespan)
        assert got.steals == want.steals
        assert got.flops_executed == want.flops_executed

    def test_facade_tau_zero_bitwise_exact(self):
        a, b = self._inputs()
        s1, s2 = _session(), _session()
        exact = (s1.from_dense(a) @ s1.from_dense(b)).to_dense()
        trunc = s2.from_dense(a).multiply(s2.from_dense(b), tau=0.0)
        assert np.array_equal(trunc.to_dense(), exact)
        assert trunc.error_bound == 0.0
        assert s1.task_counts() == s2.task_counts()

    def test_session_default_tau_threads_through_matmul(self):
        a, b = self._inputs()
        sess = _session(tau=1e-2)
        C = sess.from_dense(a) @ sess.from_dense(b)       # uses session tau
        assert C.truncation is not None
        assert C.truncation.tau == 1e-2
        assert C.error_bound > 0.0
        sess0 = _session()
        C0 = sess0.from_dense(a) @ sess0.from_dense(b)
        assert sess.n_multiply_tasks < sess0.n_multiply_tasks or \
            sess.flops < sess0.flops

    def test_explicit_tau_on_symmetric_operand_raises(self):
        s = values_for_mask(random_symmetric_mask(N, 0.1, seed=5), seed=5,
                            symmetric=True)
        sess = _session()
        S = sess.from_dense(s, upper=True)
        B = sess.from_dense(PATTERNS["banded"](6))
        with pytest.raises(ValueError, match="plain"):
            S.multiply(B, tau=1e-3)

    def test_session_default_tau_on_sym_paths_raises(self):
        """The sym task programs are untruncated: a nonzero *session
        default* tau must raise too, not silently compute exactly —
        passing tau=0 explicitly is the documented opt-out."""
        s = values_for_mask(random_symmetric_mask(N, 0.1, seed=5), seed=5,
                            symmetric=True)
        sess = _session(tau=1e-3)
        S = sess.from_dense(s, upper=True)
        B = sess.from_dense(PATTERNS["banded"](6))
        with pytest.raises(ValueError, match="untruncated"):
            _ = S @ B
        with pytest.raises(ValueError, match="untruncated"):
            S.sym_square()
        with pytest.raises(ValueError, match="untruncated"):
            B.syrk()
        with pytest.raises(ValueError, match="untruncated"):
            S.sym_multiply(B)
        # tau=0 is the explicit exact-computation opt-out
        np.testing.assert_allclose(
            S.sym_multiply(B, tau=0.0).to_dense(), s @ B.to_dense(),
            atol=1e-10)
        np.testing.assert_allclose(S.sym_square(tau=0.0).to_dense(),
                                   s @ s, atol=1e-10)
        np.testing.assert_allclose(B.syrk(tau=0.0).to_dense(),
                                   B.to_dense() @ B.to_dense().T,
                                   atol=1e-10)


class TestMonotonicity:
    """The pruned set only grows with tau: costs are non-increasing."""

    def test_flops_tasks_demand_monotone_in_tau(self):
        a = PATTERNS["banded"](11)
        b = PATTERNS["banded"](12)
        taus = (0.0, 1e-6, 1e-4, 1e-2, 1e-1, 1.0)
        flops, tasks, demand, bounds = [], [], [], []
        for tau in taus:
            sess = _session()
            A, B = sess.from_dense(a), sess.from_dense(b)
            n0 = len(sess.graph.nodes)
            C = A.multiply(B, tau=tau)
            flops.append(sess.flops)
            tasks.append(sess.n_multiply_tasks)
            demand.append(an.task_comm_demand(sess.graph, n0))
            bounds.append(C.error_bound)
        assert an.is_monotone_nonincreasing(flops)
        assert an.is_monotone_nonincreasing(tasks)
        assert an.is_monotone_nonincreasing(demand)
        assert bounds == sorted(bounds)     # bound grows with tau
        assert flops[-1] < flops[0]         # and the sweep visibly prunes
        assert demand[-1] < demand[0]

    def test_subtree_prune_covers_descendants_once(self):
        """A high-level prune records one bound covering its subtree and
        the result is NIL there (no descendant tasks registered)."""
        a = PATTERNS["banded"](13)
        sess = _session()
        A = sess.from_dense(a)
        C = A.multiply(A, tau=1e6)          # absurd tau: prune at the root
        assert C.is_nil
        rep = C.truncation
        assert rep.pruned_subtrees == 1 and rep.pruned_leaf_pairs == 0
        assert rep.pruned_by_level == {0: 1}
        assert rep.error_bound == pytest.approx(
            math.sqrt(A.norm2() * A.norm2()))
        # no multiply tasks at all were registered
        assert sess.n_multiply_tasks == 0


class TestNormCacheMaintenance:
    """Cached norms stay consistent through the maintained ops."""

    def test_add_transpose_sym_square_norms_consistent(self):
        a = PATTERNS["banded"](21)
        b = PATTERNS["random"](22)
        s = values_for_mask(random_symmetric_mask(N, 0.15, seed=23),
                            seed=23, symmetric=True)
        sess = _session()
        A, B = sess.from_dense(a), sess.from_dense(b)
        S = sess.from_dense(s, upper=True)
        g = sess.graph
        for M, dense in ((A + B, a + b),
                         ((A.T + B), a.T + b),
                         (S.sym_square(), s @ s),
                         (A @ B, a @ b)):
            want = float((dense * dense).sum())
            assert qt_norm2(g, M.node) == pytest.approx(want, rel=1e-12)
            # cached: the chunk now carries the value
            root = g.value_of(M.node)
            assert root.norm2 == pytest.approx(want, rel=1e-12)
            # and a second read returns the cached value exactly
            assert qt_norm2(g, M.node) == root.norm2

    def test_leaf_transpose_carries_caches(self):
        a = PATTERNS["banded"](24)[:LEAF_N, :LEAF_N]
        leaf = LeafMatrix.from_dense(a, BS)
        total = leaf.norm2()                        # populate caches
        t = leaf.transpose()
        assert t._norm2_tot == total                # maintained, not None
        for (i, j), v in leaf._bnorm2.items():
            assert t._bnorm2[(j, i)] == v
        assert t.norm2() == pytest.approx(float((a * a).sum()))

    def test_engine_fill_invalidates_placeholder_norms(self):
        """Pallas placeholder leaves are zero until flush: norms read
        after the wave fill must reflect the real data."""
        a = PATTERNS["banded"](25)
        b = PATTERNS["banded"](26)
        sess = _session(engine="pallas")
        C = sess.from_dense(a) @ sess.from_dense(b)
        want = float(np.linalg.norm(a @ b) ** 2)
        # frob2/norm2 flush first, then walk the (invalidated) caches
        assert C.frob2() == pytest.approx(want, rel=1e-4)
        assert C.norm2() == pytest.approx(want, rel=1e-4)

    def test_truncated_multiply_of_computed_operand(self):
        """Chained truncation: norms of an engine-produced operand are
        read after its wave ran (the root-entry flush)."""
        a = PATTERNS["banded"](27)
        for engine in ("numpy", "pallas"):
            sess = _session(engine=engine)
            A = sess.from_dense(a)
            AB = A @ A
            C = AB.multiply(A, tau=1e-3)
            exact_sess = _session(engine=engine)
            E = exact_sess.from_dense(a)
            exact = ((E @ E) @ E).to_dense()
            err = float(np.linalg.norm(exact - C.to_dense()))
            assert err <= C.error_bound + _err_slack(a @ a, a)

    def test_unpack_blocks_invalidates(self):
        from repro.core.leaf import alloc_structure, unpack_blocks
        leaf = alloc_structure(LEAF_N, BS, [(0, 0), (1, 1)])
        assert leaf.norm2() == 0.0                  # caches the zeros
        unpack_blocks(leaf, [(0, 0), (1, 1)],
                      np.ones((2, BS, BS)))
        assert leaf.norm2() == pytest.approx(2.0 * BS * BS)


def _leaf_chunk(a, bs=BS):
    return MatrixChunk(a.shape[0], leaf=LeafMatrix.from_dense(a, bs))


class TestChunkStoreNormCache:
    """Satellite: no stale norm reads through dedup'd reuse and free."""

    def test_norm_cached_and_freed(self):
        a = PATTERNS["banded"](31)[:16, :16]
        store = ChunkStore(2)
        cid = store.register(0, _leaf_chunk(a))
        want = float((a * a).sum())
        assert store.norm2_of(cid) == pytest.approx(want)
        assert store._norm2[(cid.owner, cid.local)] == pytest.approx(want)
        store.free(cid)
        assert (cid.owner, cid.local) not in store._norm2
        assert store.norm2_of(None) == 0.0

    def test_dedup_reuse_no_stale_norm(self):
        a = PATTERNS["banded"](32)[:16, :16]
        store = ChunkStore(1, dedup=True)
        c1 = store.register(0, _leaf_chunk(a))
        c2 = store.register(0, _leaf_chunk(a.copy()))    # dedup hit
        assert c1 == c2
        assert store.norm2_of(c1) == pytest.approx(float((a * a).sum()))
        store.free(c1)                                    # refcount 2 -> 1
        assert store.norm2_of(c1) == pytest.approx(float((a * a).sum()))
        store.free(c1)                                    # data gone
        assert (c1.owner, c1.local) not in store._norm2
        # fingerprint slot released: new data gets a fresh id and norm
        c3 = store.register(0, _leaf_chunk(2.0 * a))
        assert c3 != c1
        assert store.norm2_of(c3) == pytest.approx(4.0 * float((a * a).sum()))

    def test_internal_chunks_opt_out(self):
        store = ChunkStore(1)
        cid = store.register(0, MatrixChunk(32, children=(None,) * 4))
        assert store.norm2_of(cid) is None
