"""Distributed spmm tests — each scenario runs in a subprocess with virtual
CPU devices (XLA device count must be set before jax init, so the main
pytest process can't host them)."""
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core import distributed as dist
from repro.core.patterns import (banded_mask, block_mask_from_element_mask,
                                 values_for_mask)

_SCRIPT = pathlib.Path(__file__).parent / "dist_scenarios.py"


def _run(scenario: str, n_dev: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = str(pathlib.Path(__file__).parents[1] / "src")
    res = subprocess.run([sys.executable, str(_SCRIPT), scenario],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert res.returncode == 0, \
        f"{scenario} failed:\n{res.stdout}\n{res.stderr}"
    assert f"OK {scenario}" in res.stdout
    return res.stdout


@pytest.mark.parametrize("scenario,n_dev", [
    ("halo_correctness", 8),
    ("halo_random_pattern", 4),
    ("summa_correctness", 4),
    ("summa_random_permutation", 4),
    ("halo_pair_kernel", 4),
    ("collective_bytes_comparison", 16),
    ("demand_halo_v2", 8),
])
def test_scenario(scenario, n_dev):
    _run(scenario, n_dev)


class TestPlanning:
    """Host-side planning is pure numpy — testable in-process."""

    def _plan(self, n=256, bs=8, n_dev=8, d=12):
        a = values_for_mask(banded_mask(n, d), seed=1).astype(np.float32)
        ma = block_mask_from_element_mask(np.abs(a) > 0, bs)
        return a, ma, dist.plan_distribution(ma, ma, bs, n_dev)

    def test_capacities_cover_worst_device(self):
        a, ma, plan = self._plan()
        owner = dist.morton_owner(plan.grid, plan.n_dev)
        per_dev = np.bincount(owner[ma].ravel(), minlength=plan.n_dev)
        assert plan.cap_d >= per_dev.max()

    def test_distribute_roundtrip(self):
        a, ma, plan = self._plan()
        ab, ar, ac = dist.distribute_morton(a, 8, plan)
        back = dist.gather_dense(ab, ar, ac, plan.grid, 8)
        np.testing.assert_allclose(back, a)

    def test_morton_owner_ranges_contiguous(self):
        owner = dist.morton_owner(16, 4)
        # each device's cells form one contiguous Morton range
        from repro.core import morton
        rows = np.repeat(np.arange(16), 16)
        cols = np.tile(np.arange(16), 16)
        z = morton.encode(rows, cols).astype(np.int64)
        o = owner[rows, cols]
        order = np.argsort(z)
        assert (np.diff(o[order]) >= 0).all()

    def test_morton_quadrants_are_subtrees(self):
        """n_dev = 4: each device owns exactly one quadrant subtree."""
        owner = dist.morton_owner(8, 4)
        assert (owner[:4, :4] == 0).all()
        assert (owner[:4, 4:] == 1).all()
        assert (owner[4:, :4] == 2).all()
        assert (owner[4:, 4:] == 3).all()

    @pytest.mark.parametrize("fn", [dist.morton_owner, dist.rowmajor_owner])
    def test_owner_balanced_when_not_divisible(self, fn):
        """grid*grid % n_dev != 0 must not emit owner ids >= n_dev."""
        for grid, n_dev in [(4, 3), (8, 5), (4, 7), (16, 6)]:
            owner = fn(grid, n_dev)
            assert owner.max() < n_dev
            assert owner.min() == 0
            counts = np.bincount(owner.ravel(), minlength=n_dev)
            # balanced clipped split: sizes differ by at most one
            assert counts.max() - counts.min() <= 1

    @pytest.mark.parametrize("fn", [dist.morton_owner, dist.rowmajor_owner])
    def test_owner_more_devices_than_cells(self, fn):
        """n_dev > grid*grid used to raise ZeroDivisionError."""
        owner = fn(4, 20)
        assert owner.max() < 20
        counts = np.bincount(owner.ravel(), minlength=20)
        assert counts.max() == 1      # no device owns more than one cell

    def test_owner_divisible_case_unchanged(self):
        """Divisible splits keep the classic z // per assignment (the
        on-device _owned_mask computes ownership the same way)."""
        from repro.core import morton
        grid, n_dev = 8, 4
        rows = np.repeat(np.arange(grid), grid)
        cols = np.tile(np.arange(grid), grid)
        z = morton.encode(rows, cols).astype(np.int64)
        per = (grid * grid) // n_dev
        assert (dist.morton_owner(grid, n_dev)[rows, cols] == z // per).all()
        lin = np.arange(grid * grid).reshape(grid, grid)
        np.testing.assert_array_equal(dist.rowmajor_owner(grid, n_dev),
                                      lin // per)

    def test_owned_mask_consistent_with_morton_owner(self):
        """The traced per-device ownership mask must agree with the host
        owner map for every n_dev, including non-divisible splits —
        otherwise halo_spmm silently drops blocks owned by nobody."""
        for grid, n_dev in [(8, 4), (4, 3), (8, 5), (4, 7)]:
            owner = dist.morton_owner(grid, n_dev)
            for dev in range(n_dev):
                mask = np.asarray(dist._owned_mask(grid, n_dev, dev))
                np.testing.assert_array_equal(mask, owner == dev,
                                              err_msg=f"{grid=} {n_dev=} "
                                              f"{dev=}")

    def test_halo_hops_smaller_for_narrow_band(self):
        _, _, wide = self._plan(d=24)
        _, _, narrow = self._plan(d=6)
        assert narrow.halo_hops <= wide.halo_hops

    def test_plan_pair_caps_monotone_levels(self):
        _, _, plan = self._plan()
        assert len(plan.pair_caps) == int(np.log2(plan.grid))
        assert all(c > 0 for c in plan.pair_caps)
