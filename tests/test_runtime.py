"""Runtime fault-tolerance + wire-compression units (runtime/fault.py,
runtime/compression.py) — the suite promised by the fault module docstring.

Covers failure/straggler detection timing (HeartbeatMonitor), the
deterministic failure schedule (FaultInjector), the restartable training
loop with real (small) state and real injected failures (TrainingRunner),
and the int8 quantize/dequantize error bounds the gradient-compression
path advertises.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.runtime.compression import (compressed_grad_tree, dequantize_int8,
                                       quantize_int8)
from repro.runtime.fault import (FaultInjector, HeartbeatMonitor,
                                 TrainingRunner, WorkerFailure)


class TestHeartbeatMonitor:

    def test_silent_worker_declared_failed(self):
        mon = HeartbeatMonitor(n_workers=3, timeout=10.0)
        assert mon.failed_workers() == []
        mon.last_seen[1] -= 11.0        # silent past the timeout
        assert mon.failed_workers() == [1]
        mon.beat(1)                     # heartbeat arrives: recovered
        assert mon.failed_workers() == []

    def test_multiple_failures_reported_sorted(self):
        mon = HeartbeatMonitor(n_workers=4, timeout=5.0)
        mon.last_seen[2] -= 6.0
        mon.last_seen[0] -= 7.0
        assert mon.failed_workers() == [0, 2]

    def test_straggler_flagged_against_fleet_median(self):
        mon = HeartbeatMonitor(n_workers=4, straggler_factor=2.0)
        for step in range(6):
            for w in range(4):
                mon.beat(w, step_time=1.0 if w != 3 else 3.5)
        assert mon.stragglers() == [3]

    def test_straggler_uses_recent_window(self):
        """Only the last 5 step times count: a recovered worker clears."""
        mon = HeartbeatMonitor(n_workers=3, straggler_factor=2.0)
        for _ in range(5):
            for w in range(3):
                mon.beat(w, step_time=4.0 if w == 0 else 1.0)
        assert mon.stragglers() == [0]
        for _ in range(5):              # worker 0 back to fleet speed
            for w in range(3):
                mon.beat(w, step_time=1.0)
        assert mon.stragglers() == []

    def test_no_step_times_no_stragglers(self):
        mon = HeartbeatMonitor(n_workers=2)
        assert mon.stragglers() == []

    def test_no_step_times_emits_no_warning(self):
        """Regression: np.nanmedian over an all-NaN window used to emit
        an 'All-NaN slice' RuntimeWarning before the guard."""
        import warnings
        mon = HeartbeatMonitor(n_workers=3)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            assert mon.stragglers() == []

    def test_injectable_clock_drives_virtual_time(self):
        now = [100.0]
        mon = HeartbeatMonitor(n_workers=2, timeout=5.0,
                               clock=lambda: now[0])
        assert mon.failed_workers() == []
        now[0] += 6.0                   # both workers silent past timeout
        assert mon.failed_workers() == [0, 1]
        mon.beat(1)                     # heartbeat stamped at virtual now
        assert mon.failed_workers() == [0]
        assert mon.last_seen[1] == 106.0


class TestFaultInjector:

    def test_raises_at_scheduled_step_once(self):
        inj = FaultInjector(fail_at={3: 1})
        for step in (0, 1, 2):
            inj.check(step)
        with pytest.raises(WorkerFailure) as ei:
            inj.check(3)
        assert ei.value.worker == 1 and ei.value.step == 3
        inj.check(3)                    # schedule entry consumed: no raise

    def test_deterministic_schedule(self):
        """Two injectors with the same schedule fail identically."""
        def run(inj):
            hits = []
            for step in range(10):
                try:
                    inj.check(step)
                except WorkerFailure as e:
                    hits.append((e.step, e.worker))
            return hits

        sched = {2: 0, 7: 3}
        assert run(FaultInjector(dict(sched))) == \
            run(FaultInjector(dict(sched))) == [(2, 0), (7, 3)]

    def test_list_schedule_two_failures_same_step(self):
        """The dict form can hold one failure per step; the list form
        expresses two, fired one-shot in order across restarts."""
        inj = FaultInjector(fail_at=[(3, 1), (3, 2), (5, 0)])
        assert inj.schedule == [(3, 1), (3, 2), (5, 0)]
        inj.check(2)
        with pytest.raises(WorkerFailure) as e1:
            inj.check(3)
        assert (e1.value.step, e1.value.worker) == (3, 1)
        with pytest.raises(WorkerFailure) as e2:
            inj.check(3)                # the restarted run hits step 3 again
        assert (e2.value.step, e2.value.worker) == (3, 2)
        inj.check(3)                    # both consumed
        with pytest.raises(WorkerFailure):
            inj.check(5)
        assert inj.schedule == []

    def test_list_schedule_sorted_soonest_first(self):
        inj = FaultInjector(fail_at=[(7, 0), (2, 3)])
        assert inj.schedule == [(2, 3), (7, 0)]

    def test_dict_form_still_accepted(self):
        inj = FaultInjector(fail_at={4: 2})
        assert inj.schedule == [(4, 2)]
        with pytest.raises(WorkerFailure):
            inj.check(4)


class TestTrainingRunner:

    def _runner(self, tmp_path, fail_at, ckpt_every=2, max_restarts=3):
        def step_fn(state, batch):
            return state + batch, {"loss": float(jnp.sum(state))}

        def batch_fn(step):
            return jnp.ones(()) * (step + 1)

        ckpt = CheckpointManager(tmp_path, keep=3)
        return TrainingRunner(step_fn=step_fn, batch_fn=batch_fn, ckpt=ckpt,
                              ckpt_every=ckpt_every,
                              max_restarts=max_restarts,
                              injector=FaultInjector(dict(fail_at)))

    def test_restart_resumes_exactly(self, tmp_path):
        """A mid-run failure restores the last checkpoint and the final
        state matches the failure-free run (pure step_fn + stateless
        batch_fn => bitwise resumable)."""
        n_steps = 7
        clean, _ = self._runner(tmp_path / "clean", {}).run(
            jnp.zeros(()), n_steps)
        state, hist = self._runner(tmp_path / "faulty", {5: 0}).run(
            jnp.zeros(()), n_steps)
        assert hist["restarts"] == 1
        assert hist["restored_from"] == [4]     # ckpt_every=2 -> step 4
        np.testing.assert_allclose(np.asarray(state), np.asarray(clean))

    def test_too_many_failures_reraise(self, tmp_path):
        runner = self._runner(tmp_path, {1: 0, 2: 0}, max_restarts=1)
        with pytest.raises(WorkerFailure):
            runner.run(jnp.zeros(()), 5)


class TestInt8Compression:

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_roundtrip_error_bound(self, seed):
        """Per-element error <= scale/2 = max|g| / 254 (symmetric int8)."""
        rng = np.random.default_rng(seed)
        g = jnp.asarray(rng.standard_normal((64, 33)) * 10.0 ** seed,
                        dtype=jnp.float32)
        q, scale = quantize_int8(g)
        assert q.dtype == jnp.int8
        assert float(scale) == pytest.approx(float(jnp.max(jnp.abs(g))) / 127,
                                             rel=1e-6)
        back = dequantize_int8(q, scale)
        err = np.abs(np.asarray(back) - np.asarray(g))
        assert float(err.max()) <= float(scale) / 2 * (1 + 1e-6)
        # relative error on the wire format's own terms: <1% of max|g|
        assert float(err.max()) <= 0.01 * float(jnp.max(jnp.abs(g)))

    def test_zero_tensor_safe(self):
        q, scale = quantize_int8(jnp.zeros((8, 8)))
        assert float(jnp.max(jnp.abs(dequantize_int8(q, scale)))) == 0.0

    def test_extremes_map_to_full_range(self):
        g = jnp.asarray([-3.0, 0.0, 3.0])
        q, _ = quantize_int8(g)
        assert int(q[0]) == -127 and int(q[2]) == 127

    def test_grad_tree_roundtrip_preserves_structure(self):
        rng = np.random.default_rng(3)
        grads = {"w": jnp.asarray(rng.standard_normal((16, 4)),
                                  dtype=jnp.float32),
                 "b": jnp.asarray(rng.standard_normal(4),
                                  dtype=jnp.bfloat16)}
        out = compressed_grad_tree(grads)
        assert set(out) == {"w", "b"}
        for k in out:
            assert out[k].shape == grads[k].shape
            assert out[k].dtype == grads[k].dtype
            ref = np.asarray(grads[k], dtype=np.float32)
            err = np.abs(np.asarray(out[k], dtype=np.float32) - ref)
            bound = np.abs(ref).max() / 254 + 0.02 * np.abs(ref).max()
            assert float(err.max()) <= float(bound)
