"""Runtime simulator: placement policies, comm scaling (Table 1),
determinism, steal accounting, traces and critical paths."""
import numpy as np
import pytest

from repro.core.patterns import banded_mask, values_for_mask
from repro.core.quadtree import QTParams, qt_from_dense, qt_to_dense
from repro.core.multiply import qt_multiply
from repro.core.tasks import CostModel, CTGraph
from repro.core import analysis as an
from repro.runtime.scheduler import PLACEMENTS, Scheduler, simulate
from repro.runtime.trace import critical_path


def _weak_scaling_run(p, placement, seed=0, n_per=128, d=24, leaf_n=32,
                      bs=8, cost=None):
    """Build-then-multiply on a banded matrix with N proportional to p."""
    n = n_per * p
    params = QTParams(n, leaf_n, bs)
    a = values_for_mask(banded_mask(n, d), seed=1, symmetric=True)
    g = CTGraph()
    sched = Scheduler(seed=seed, cost=cost)
    ra = qt_from_dense(g, a, params)
    rb = qt_from_dense(g, a, params)
    sched.run(g, n_workers=p, placement=placement)
    sched.reset_stats()
    rc = qt_multiply(g, params, ra, rb)
    rep = sched.run(g)
    return g, params, a, rc, sched, rep


class TestPlacementPolicies:
    def test_parent_worker_chunks_follow_execution(self):
        g, _, _, _, sched, rep = _weak_scaling_run(4, "parent-worker")
        for nid, cid in sched.placement.items():
            assert cid.owner == sched._owner_of_node[g.resolve(nid)]
        assert rep.bytes_pushed == [0, 0, 0, 0]

    def test_round_robin_spreads_ownership(self):
        g, _, _, _, sched, rep = _weak_scaling_run(4, "round-robin")
        owners = [cid.owner for cid in sched.placement.values()]
        counts = np.bincount(owners, minlength=4)
        assert counts.min() > 0
        assert counts.max() - counts.min() <= len(set(owners))  # near-even
        assert sum(rep.bytes_pushed) > 0

    def test_random_placement_pushes_chunks(self):
        g, _, _, _, sched, rep = _weak_scaling_run(4, "random")
        moved = sum(cid.owner != sched._owner_of_node[g.resolve(nid)]
                    for nid, cid in sched.placement.items())
        assert moved > 0
        assert sum(rep.bytes_pushed) > 0
        # pushes are part of the received bytes (the owner got the data)
        for recv, pushed in zip(rep.bytes_received, rep.bytes_pushed):
            assert recv >= pushed

    def test_correct_result_under_any_placement(self):
        for placement in PLACEMENTS:
            g, params, a, rc, _, _ = _weak_scaling_run(2, placement,
                                                       n_per=64)
            np.testing.assert_allclose(qt_to_dense(g, rc, params), a @ a,
                                       atol=1e-12)

    def test_unknown_placement_rejected(self):
        g = CTGraph()
        g.register_chunk("x", None)
        with pytest.raises(ValueError, match="unknown placement"):
            simulate(g, 2, placement="summa")

    def test_config_pinned_after_first_run(self):
        g = CTGraph()
        g.register_chunk("x", QTParams(8, 8, 4))
        sched = Scheduler()
        sched.run(g, n_workers=2, placement="parent-worker")
        with pytest.raises(ValueError, match="cannot re-run"):
            sched.run(g, n_workers=4)
        with pytest.raises(ValueError, match="cannot re-run"):
            sched.run(g, placement="random")


class TestCommScalingTable1:
    """The paper's central claim as a regression (Table 1, Figs 12-13).

    Weak scaling (N proportional to p) on a banded matrix: when chunk
    placement follows the work-stealing execution (parent-worker), the max
    per-worker bytes received stays essentially flat from p=4 to p=16.
    Locality-oblivious random placement pays a gap that exceeds the
    sqrt(p/4) SpSUMMA growth rate of eq (17) at p=16 — both against the
    locality-aware curve at the same p and against the p=4 reference.
    """

    @pytest.fixture(scope="class")
    def sweep(self):
        out = {}
        for placement in ("parent-worker", "random"):
            for p in (4, 16):
                *_, rep = _weak_scaling_run(p, placement)
                out[(placement, p)] = rep
        return out

    def test_parent_worker_flat(self, sweep):
        lo = sweep[("parent-worker", 4)].max_bytes_received
        hi = sweep[("parent-worker", 16)].max_bytes_received
        assert hi <= 2.0 * lo, f"locality-aware comm grew {hi / lo:.2f}x"

    def test_random_placement_pays_spsumma_rate(self, sweep):
        rate = np.sqrt(16 / 4)          # eq (17) growth from p=4 to p=16
        # avg per-worker bytes: the oblivious policy exceeds the rate
        aware = sweep[("parent-worker", 16)].avg_bytes_received
        oblivious = sweep[("random", 16)].avg_bytes_received
        assert oblivious >= rate * aware, \
            f"avg locality gap only {oblivious / aware:.2f}x at p=16"
        # max per-worker bytes: same story modulo single-straggler noise
        aware_max = sweep[("parent-worker", 16)].max_bytes_received
        obliv_max = sweep[("random", 16)].max_bytes_received
        assert obliv_max >= 0.9 * rate * aware_max, \
            f"max locality gap only {obliv_max / aware_max:.2f}x at p=16"
        # and vs the p=4 locality-aware reference the growth is far above it
        ref4 = sweep[("parent-worker", 4)].max_bytes_received
        assert obliv_max >= rate * ref4

    def test_comm_summary_consistency(self, sweep):
        rep = sweep[("parent-worker", 16)]
        s = an.comm_summary(rep.bytes_received)
        assert s["n_workers"] == 16
        assert s["max_bytes"] == rep.max_bytes_received
        assert s["imbalance"] >= 1.0


class TestDeterminism:
    def test_fixed_seed_identical_schedule_and_stats(self):
        reps = []
        for _ in range(2):
            *_, sched, rep = _weak_scaling_run(8, "random", seed=7,
                                               n_per=32)
            reps.append((rep, rep.trace.schedule(), dict(sched.placement)))
        (ra, sa, pa), (rb, sb, pb) = reps
        assert sa == sb                      # identical task -> worker map
        assert pa == pb                      # identical chunk placement
        assert ra.bytes_received == rb.bytes_received
        assert ra.makespan == rb.makespan
        assert ra.steals == rb.steals


class TestReplayAfterWorkerDeath:
    """release(forget_owner=True) + replay must never touch a dead
    worker's store (DESIGN.md §10; fault injection itself is pinned in
    tests/test_fault.py)."""

    def test_replay_reads_nothing_from_dead_worker(self):
        from repro.runtime.recovery import FaultSchedule, kill

        g, params, a, rc, sched, rep = _weak_scaling_run(
            4, "parent-worker", n_per=64)
        want = qt_to_dense(g, rc, params)
        # mid-run death + lineage recovery on a replay of the multiply
        nids = sorted(nid for nid in sched.placement
                      if g.nodes[nid].alias_of is None)
        sched.replay(g, nids, faults=FaultSchedule(
            events=[kill(0.5 * rep.makespan, 1)]))
        assert 1 not in sched.live_workers()
        # recovery has rebuilt every lost chunk somewhere alive
        assert all(cid.owner != 1 for cid in sched.placement.values())
        np.testing.assert_array_equal(qt_to_dense(g, rc, params), want)
        # a fresh release+replay over the dead-worker pool: no task may
        # execute on worker 1 and no chunk may be fetched from its store
        sched.reset_stats()
        rep2 = sched.replay(g, nids)
        assert rep2.tasks_per_worker[1] == 0
        assert all(ev.worker != 1 for ev in rep2.trace.events)
        assert all(cid.owner != 1 for cid in sched.placement.values())
        np.testing.assert_array_equal(qt_to_dense(g, rc, params), want)


class TestStealAccounting:
    def test_steal_latency_charged(self):
        cheap = CostModel(steal_latency_s=0.0)
        dear = CostModel(steal_latency_s=5e-3)
        *_, r0 = _weak_scaling_run(8, "parent-worker", n_per=32, cost=cheap)
        *_, r1 = _weak_scaling_run(8, "parent-worker", n_per=32, cost=dear)
        assert r0.steals > 0 and r1.steals > 0
        assert r0.steal_time_s == 0.0
        assert r1.steal_time_s == pytest.approx(r1.steals * 5e-3)
        assert r1.makespan > r0.makespan

    def test_stolen_tasks_marked_in_trace(self):
        *_, rep = _weak_scaling_run(8, "parent-worker", n_per=32)
        assert len(rep.trace.stolen_tasks()) == rep.steals


class TestTraceAndCriticalPath:
    def test_trace_covers_phase(self):
        g, *_, rep = _weak_scaling_run(4, "parent-worker", n_per=32)
        assert len(rep.trace) == sum(rep.tasks_per_worker)
        assert rep.trace.makespan() == pytest.approx(rep.makespan)

    def test_brent_bound_holds(self):
        *_, rep = _weak_scaling_run(4, "parent-worker", n_per=32)
        crit = rep.crit
        assert crit.length_s <= rep.makespan * (1 + 1e-9)
        assert crit.brent_bound(rep.n_workers) <= rep.makespan * (1 + 1e-9)
        assert crit.work_s == pytest.approx(sum(rep.busy_time))
        assert 0 < rep.parallel_efficiency <= 1 + 1e-9

    def test_critical_path_is_dependency_chain(self):
        g, *_, rep = _weak_scaling_run(2, "parent-worker", n_per=32)
        path = rep.crit.path
        assert len(path) >= 2
        for up, down in zip(path, path[1:]):
            node = g.nodes[down]
            preds = {g.resolve(d.nid) for d in node.deps
                     if d.nid is not None}
            if node.parent is not None:
                preds.add(node.parent)
            assert up in preds

    def test_critical_path_excludes_earlier_phase(self):
        g, params, a, rc, sched, rep = _weak_scaling_run(
            2, "parent-worker", n_per=32)
        this_phase = {ev.nid for ev in rep.trace.events}
        build_phase = {n.nid for n in g.nodes} - this_phase
        crit = critical_path(g, rep.trace, done_before=build_phase)
        assert crit.n_tasks == len(rep.trace)
        assert crit.length_s == pytest.approx(rep.crit.length_s)

    def test_gantt_renders(self):
        *_, rep = _weak_scaling_run(2, "parent-worker", n_per=32)
        art = rep.trace.gantt(width=40)
        lines = art.splitlines()
        assert len(lines) == 3              # 2 workers + time axis
        assert "#" in lines[0]

    def test_report_to_dict_json_ready(self):
        import json
        *_, rep = _weak_scaling_run(2, "parent-worker", n_per=32)
        d = rep.to_dict()
        json.dumps(d)   # must be serialisable
        assert d["n_workers"] == 2
        assert d["critical_path_s"] > 0


class TestAnalysisHelpers:
    def test_growth_and_brent(self):
        assert an.growth_ratios([1.0, 2.0, 3.0]) == [2.0, 1.5]
        assert an.weak_scaling_growth({4: 1.0, 16: 1.5}) == 1.5
        assert an.brent_bound(10.0, 2.0, 4) == 2.5
        assert an.brent_bound(10.0, 4.0, 4) == 4.0
        assert an.parallel_efficiency(8.0, 1.0, 8) == 1.0
        s = an.critical_path_summary(8.0, 1.0, 4, 2.5)
        assert s["brent_bound_s"] == 2.0
        assert s["avg_parallelism"] == 8.0
