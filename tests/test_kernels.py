"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape)
    return jnp.asarray(x, dtype)


class TestBatchedGemm:
    @pytest.mark.parametrize("bs", [8, 16, 32, 64])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, bs, dtype):
        p = 16
        a, b = _rand((p, bs, bs), dtype), _rand((p, bs, bs), dtype)
        out = ops.batched_gemm(a, b, use_pallas=True, interpret=True)
        want = ref.batched_gemm_ref(a, b)
        tol = 1e-5 if dtype == jnp.float32 else 5e-2 * bs
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), atol=tol)

    @pytest.mark.parametrize("p", [2, 6, 24])
    def test_odd_batch_sizes(self, p):
        a, b = _rand((p, 16, 16), jnp.float32), _rand((p, 16, 16),
                                                      jnp.float32)
        out = ops.batched_gemm(a, b, use_pallas=True, interpret=True)
        np.testing.assert_allclose(out, ref.batched_gemm_ref(a, b),
                                   atol=1e-5)

    def test_xla_fallback_identical_contract(self):
        a, b = _rand((8, 16, 16), jnp.float32), _rand((8, 16, 16),
                                                      jnp.float32)
        np.testing.assert_allclose(
            ops.batched_gemm(a, b, use_pallas=False),
            ops.batched_gemm(a, b, use_pallas=True, interpret=True),
            atol=1e-5)


class TestBsmmPairs:
    def _case(self, cap_a, cap_b, cap_c, n_pairs, bs, dtype=jnp.float32,
              seed=0):
        rng = np.random.default_rng(seed)
        ab = jnp.asarray(rng.standard_normal((cap_a, bs, bs)), dtype)
        bb = jnp.asarray(rng.standard_normal((cap_b, bs, bs)), dtype)
        sa = jnp.asarray(rng.integers(0, cap_a, n_pairs), jnp.int32)
        sb = jnp.asarray(rng.integers(0, cap_b, n_pairs), jnp.int32)
        seg = jnp.sort(jnp.asarray(rng.integers(0, cap_c, n_pairs),
                                   jnp.int32))
        return ab, bb, sa, sb, seg

    @pytest.mark.parametrize("bs", [8, 16, 32])
    def test_sweep_block_sizes(self, bs):
        ab, bb, sa, sb, seg = self._case(12, 12, 6, 30, bs)
        out = ops.bsmm_pairs(ab, bb, sa, sb, seg, cap_c=6,
                             use_pallas=True, interpret=True)
        want = ref.bsmm_pairs_ref(ab, bb, sa, sb, seg, 6)
        np.testing.assert_allclose(out, want, atol=1e-4)

    def test_invalid_pairs_dropped(self):
        ab, bb, sa, sb, seg = self._case(8, 8, 4, 16, 8)
        seg = seg.at[-5:].set(4)  # invalid marker == cap_c
        out = ops.bsmm_pairs(ab, bb, sa, sb, seg, cap_c=4,
                             use_pallas=True, interpret=True)
        want = ref.bsmm_pairs_ref(ab, bb, sa, sb, seg, 4)
        np.testing.assert_allclose(out, want, atol=1e-4)

    def test_unvisited_slots_zero(self):
        """C slots with no contributing pair must come back zero."""
        bs = 8
        ab = _rand((4, bs, bs), jnp.float32)
        bb = _rand((4, bs, bs), jnp.float32)
        # all pairs hit slot 0; slots 1..3 unvisited
        sa = jnp.zeros((4,), jnp.int32)
        sb = jnp.zeros((4,), jnp.int32)
        seg = jnp.zeros((4,), jnp.int32)
        out = ops.bsmm_pairs(ab, bb, sa, sb, seg, cap_c=4,
                             use_pallas=True, interpret=True)
        assert np.all(np.asarray(out[1:]) == 0)

    def test_bfloat16(self):
        ab, bb, sa, sb, seg = self._case(8, 8, 4, 16, 16, dtype=jnp.bfloat16)
        out = ops.bsmm_pairs(ab, bb, sa, sb, seg, cap_c=4,
                             use_pallas=True, interpret=True)
        want = ref.bsmm_pairs_ref(ab, bb, sa, sb, seg, 4)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), atol=1.0)


class TestBandedAttention:
    @pytest.mark.parametrize("window,block", [(16, 16), (32, 16), (32, 32)])
    def test_sweep_windows(self, window, block):
        h, s, d = 2, 64, 16
        q, k, v = (_rand((h, s, d), jnp.float32) for _ in range(3))
        out = ops.banded_attention(q, k, v, window=window, block_q=block,
                                   block_kv=block, use_pallas=True,
                                   interpret=True)
        want = ref.banded_attention_ref(q, k, v, window)
        np.testing.assert_allclose(out, want, atol=2e-5)

    def test_noncausal(self):
        h, s, d = 1, 64, 8
        q, k, v = (_rand((h, s, d), jnp.float32) for _ in range(3))
        out = ops.banded_attention(q, k, v, window=16, block_q=16,
                                   block_kv=16, causal=False,
                                   use_pallas=True, interpret=True)
        want = ref.banded_attention_ref(q, k, v, 16, causal=False)
        np.testing.assert_allclose(out, want, atol=2e-5)

    def test_window_covers_all_equals_full_attention(self):
        """window >= S reduces to ordinary causal attention."""
        h, s, d = 1, 32, 8
        q, k, v = (_rand((h, s, d), jnp.float32) for _ in range(3))
        out = ops.banded_attention(q, k, v, window=32, block_q=16,
                                   block_kv=16, use_pallas=True,
                                   interpret=True)
        scores = jnp.einsum("hqd,hkd->hqk", q, k) / np.sqrt(d)
        mask = np.tril(np.ones((s, s), bool))
        scores = jnp.where(mask[None], scores, -jnp.inf)
        want = jnp.einsum("hqk,hkd->hqd", jax.nn.softmax(scores, -1), v)
        np.testing.assert_allclose(out, want, atol=2e-5)

    def test_bfloat16(self):
        h, s, d = 2, 64, 16
        q, k, v = (_rand((h, s, d), jnp.bfloat16) for _ in range(3))
        out = ops.banded_attention(q, k, v, window=16, block_q=16,
                                   block_kv=16, use_pallas=True,
                                   interpret=True)
        want = ref.banded_attention_ref(q, k, v, 16)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), atol=3e-2)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_property_attention_rows_sum_via_uniform_v(seed):
    """With v = all-ones, banded attention returns exactly ones
    (softmax weights sum to 1 over the band)."""
    h, s, d = 1, 32, 8
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((h, s, d)), jnp.float32)
    v = jnp.ones((h, s, d), jnp.float32)
    out = ops.banded_attention(q, k, v, window=16, block_q=16, block_kv=16,
                               use_pallas=True, interpret=True)
    np.testing.assert_allclose(out, np.ones((h, s, d)), atol=1e-5)
