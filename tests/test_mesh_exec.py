"""Mesh-executor equivalence suite (launch/mesh_exec.py, DESIGN.md §7).

Pins the mesh leaf backend three ways:

1. **Numerical equivalence** — ``Session(engine="mesh")`` matches the
   numpy reference engine over banded/random/symmetric patterns,
   including NIL quadrants, folded transposes, and the truncated
   multiply, in-process on the ambient (single) jax device.
2. **Device-count invariance** — the same program run under 1, 4 and 8
   forced host devices produces identical results (subprocess scenarios:
   XLA device count must be set before jax initialises) with monotone
   per-device communication counters, and the SpSUMMA baseline fails
   fast on the non-square p=6.
3. **Lifecycle** — ``Session.free`` drops the executor's device-resident
   buffers and ownership/residency bookkeeping (free-then-reuse), and
   plan rebinds bump block versions so stale device copies are
   re-pushed, never silently reused.
"""
import os
import pathlib
import re
import subprocess
import sys

import numpy as np
import pytest

from repro import Session
from repro.core.patterns import (banded_mask, random_mask,
                                 random_symmetric_mask, values_for_mask)

N, LEAF_N, BS = 64, 16, 4
TOL = dict(atol=1e-4)          # mesh packs float32; numpy is float64

_SCRIPT = pathlib.Path(__file__).parent / "dist_scenarios.py"


def _run(scenario: str, n_dev: int):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = str(pathlib.Path(__file__).parents[1] / "src")
    res = subprocess.run([sys.executable, str(_SCRIPT), scenario],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert res.returncode == 0, \
        f"{scenario} failed:\n{res.stdout}\n{res.stderr}"
    assert f"OK {scenario}" in res.stdout
    return res.stdout


def _pair(engine="mesh"):
    mesh = Session(engine=engine, leaf_n=LEAF_N, bs=BS)
    ref = Session(engine="numpy", leaf_n=LEAF_N, bs=BS)
    return mesh, ref


class TestEquivalence:
    """mesh == numpy engine, in-process (ambient device count)."""

    PATTERNS = {
        "banded": lambda: values_for_mask(banded_mask(N, 5), seed=1),
        "random": lambda: values_for_mask(random_mask(N, 0.1, seed=2),
                                          seed=2),
        "nil_quadrant": lambda: np.triu(
            values_for_mask(banded_mask(N, 9), seed=3)),
    }

    @pytest.mark.parametrize("pattern", sorted(PATTERNS))
    def test_multiply(self, pattern):
        a = self.PATTERNS[pattern]()
        b = values_for_mask(banded_mask(N, 7), seed=4)
        mesh, ref = _pair()
        got = (mesh.from_dense(a) @ mesh.from_dense(b)).to_dense()
        want = (ref.from_dense(a) @ ref.from_dense(b)).to_dense()
        np.testing.assert_allclose(got, want, **TOL)

    @pytest.mark.parametrize("case", ["at_b", "a_bt", "at_bt"])
    def test_transposes(self, case):
        a = values_for_mask(banded_mask(N, 5), seed=5)
        b = values_for_mask(random_mask(N, 0.15, seed=6), seed=6)
        op = {"at_b": lambda A, B: A.T @ B,
              "a_bt": lambda A, B: A @ B.T,
              "at_bt": lambda A, B: (B @ A).T}[case]
        mesh, ref = _pair()
        got = op(mesh.from_dense(a), mesh.from_dense(b)).to_dense()
        want = op(ref.from_dense(a), ref.from_dense(b)).to_dense()
        np.testing.assert_allclose(got, want, **TOL)

    def test_sym_square(self):
        s = values_for_mask(random_symmetric_mask(N, 0.15, seed=7),
                            seed=7, symmetric=True)
        mesh, ref = _pair()
        got = mesh.from_dense(s, upper=True).sym_square().to_dense()
        want = ref.from_dense(s, upper=True).sym_square().to_dense()
        np.testing.assert_allclose(got, want, **TOL)

    def test_truncated_multiply_same_structure(self):
        """tau prunes identically on both engines (structure comes from
        leaf_task_pairs on both), numbers agree on the surviving work."""
        idx = np.arange(N)
        decay = np.exp(-np.abs(idx[:, None] - idx[None, :]) / 3.0)
        rng = np.random.default_rng(8)
        a = rng.standard_normal((N, N)) * decay
        mesh, ref = _pair()
        gm = mesh.from_dense(a).multiply(mesh.from_dense(a), tau=1e-2)
        gr = ref.from_dense(a).multiply(ref.from_dense(a), tau=1e-2)
        np.testing.assert_allclose(gm.to_dense(), gr.to_dense(), **TOL)
        assert abs(gm.error_bound - gr.error_bound) < 1e-10

    def test_nil_stays_nil(self):
        """An all-zero quadrant product is NIL on the mesh engine too."""
        a = np.zeros((N, N))
        a[: N // 2, : N // 2] = values_for_mask(
            banded_mask(N // 2, 5), seed=9)
        mesh, ref = _pair()
        got = (mesh.from_dense(a) @ mesh.from_dense(a))
        want = (ref.from_dense(a) @ ref.from_dense(a))
        assert mesh.graph.is_nil(got.node) == ref.graph.is_nil(want.node)
        np.testing.assert_allclose(got.to_dense(), want.to_dense(), **TOL)

    def test_task_graph_identical_to_numpy(self):
        """Structure (task kinds/counts) is engine-independent."""
        a = values_for_mask(banded_mask(N, 5), seed=1)
        mesh, ref = _pair()
        (mesh.from_dense(a) @ mesh.from_dense(a)).to_dense()
        (ref.from_dense(a) @ ref.from_dense(a)).to_dense()
        assert mesh.task_counts() == ref.task_counts()


class TestLifecycle:
    def test_free_then_reuse(self):
        """Session.free drops device-resident buffers + residency; the
        session keeps computing correctly afterwards."""
        rng = np.random.default_rng(0)
        a = rng.standard_normal((N, N)) * 0.1
        sess = Session(engine="mesh", leaf_n=LEAF_N, bs=BS)
        M = sess.from_dense(a)
        P = M @ M
        P.to_dense()
        st1 = sess.engine_stats()
        assert st1["device_leaves"] > 0
        sess.free(P)
        st2 = sess.engine_stats()
        assert st2["device_leaves"] < st1["device_leaves"]
        assert st2["device_blocks"] < st1["device_blocks"]
        # counters never go backwards on free
        assert st2["fetched_bytes"] == st1["fetched_bytes"]
        assert st2["pushed_bytes"] == st1["pushed_bytes"]
        Q = M @ M.T
        np.testing.assert_allclose(Q.to_dense(), a @ a.T, **TOL)

    def test_rebind_bumps_version_and_repushes(self):
        """A plan rebind refills input leaves in place: device copies go
        stale (version bump) and are re-pushed, not silently reused."""
        rng = np.random.default_rng(1)
        a = rng.standard_normal((N, N)) * 0.1
        sess = Session(engine="mesh", leaf_n=LEAF_N, bs=BS, lazy=True)
        X = sess.from_dense(a, name="X")
        plan = sess.compile(X @ X)
        Y = plan.run()
        np.testing.assert_allclose(Y.to_dense(), a @ a, **TOL)
        st1 = sess.engine_stats()
        a2 = rng.standard_normal((N, N)) * 0.1
        Z = plan.run(X=a2)
        np.testing.assert_allclose(Z.to_dense(), a2 @ a2, **TOL)
        st2 = sess.engine_stats()
        assert sum(st2["pushed_bytes"]) > sum(st1["pushed_bytes"])

    def test_engine_stats_shape(self):
        a = values_for_mask(banded_mask(N, 5), seed=1)
        sess = Session(engine="mesh", leaf_n=LEAF_N, bs=BS)
        (sess.from_dense(a) @ sess.from_dense(a)).to_dense()
        st = sess.engine_stats()
        assert st["backend"] == "mesh"
        n = st["n_dev"]
        assert n >= 1
        for key in ("fetched_bytes", "fetched_blocks", "pushed_bytes",
                    "collective_bytes"):
            assert len(st[key]) == n
            assert all(v >= 0 for v in st[key])
        assert st["waves"] == len(st["comm_log"]) > 0


@pytest.mark.slow
class TestDeviceCounts:
    """Forced-host-device runs (subprocess: XLA device count is fixed at
    jax init, so the main pytest process can't host them)."""

    @pytest.mark.parametrize("n_dev", [1, 4, 8])
    def test_equivalence(self, n_dev):
        _run("mesh_engine_equivalence", n_dev)

    def test_identical_results_across_device_counts(self):
        sums = set()
        for n_dev in (1, 4, 8):
            out = _run("mesh_engine_equivalence", n_dev)
            m = re.search(r"CHECKSUM (.*)", out)
            assert m, out
            sums.add(m.group(1).strip())
        assert len(sums) == 1, f"results differ across device counts: {sums}"

    @pytest.mark.parametrize("n_dev", [1, 4])
    def test_counters(self, n_dev):
        _run("mesh_engine_counters", n_dev)

    def test_summa_p6_fails_fast(self):
        _run("summa_pgrid_validation", 6)
