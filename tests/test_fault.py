"""Fault-tolerant, elastic scheduling with lineage recovery (DESIGN.md §10).

Pins the bugfix-PR claims four ways:

1. **Store mechanics** — ``drop_worker`` loses exactly the dead worker's
   slice, ``add_worker`` grows every per-worker structure, ``replicate``
   makes a physical (non-deduped) copy charged to the destination.
2. **Recovery policies** — lineage recompute re-runs the *minimal* task
   closure (strict subset of the DAG); ``"none"`` restarts the phase and
   costs more; replication re-points at survivors (zero recompute after a
   single failure) and restores the factor.
3. **Bitwise identity** — the simulator never touches task values: every
   faulted run (kill, straggler, join/leave, double kill) produces output
   bitwise identical to the fault-free run, on numpy and pallas engines,
   eagerly and through compiled-Plan replay.
4. **Observability** — kills/recoveries emit ``fault.*`` spans; SimReport
   carries the recovery counters only when a schedule was injected, so
   fault-free reports/metrics keep their exact legacy shape.
"""
import numpy as np
import pytest

from repro import Session
from repro.core.chunks import ChunkStore
from repro.core.patterns import banded_mask, values_for_mask
from repro.runtime.recovery import (ACTIONS, RECOVERIES, FaultEvent,
                                    FaultSchedule, as_fault_schedule, join,
                                    kill, leave, slow)

N, LEAF_N, BS, P = 128, 32, 8, 4


def _operands(n=N, d=12):
    a = values_for_mask(banded_mask(n, d), seed=1, symmetric=True)
    b = values_for_mask(banded_mask(n, d), seed=2, symmetric=True)
    return a, b


def _multiply_session(engine="numpy", build_faults=None, **kw):
    """Session with A, B built (simulated) and C = A @ B pending."""
    kw.setdefault("leaf_n", LEAF_N)
    kw.setdefault("bs", BS)
    kw.setdefault("p", P)
    kw.setdefault("seed", 0)
    a, b = _operands()
    sess = Session(engine=engine, **kw)
    A, B = sess.from_dense(a), sess.from_dense(b)
    sess.simulate(faults=build_faults)          # build phase
    return sess, A @ B


@pytest.fixture(scope="module")
def baseline():
    """Fault-free multiply: (report, dense result)."""
    sess, C = _multiply_session()
    rep = sess.simulate(fresh_stats=True)
    return rep, C.to_dense()


class TestChunkStoreFaults:
    def test_drop_worker_loses_only_that_slice(self):
        store = ChunkStore(n_workers=3)
        c0 = store.register(0, np.ones(4), nbytes=32)
        c1 = store.register(1, np.full(4, 2.0), nbytes=32)
        store.fetch(2, c1)                       # worker 2 caches c1
        n_chunks, n_bytes = store.drop_worker(1)
        assert (n_chunks, n_bytes) == (1, 32)
        assert np.array_equal(store.fetch(0, c0), np.ones(4))
        with pytest.raises(KeyError):
            store.fetch(2, c1)

    def test_drop_worker_purges_dedup_index(self):
        store = ChunkStore(n_workers=2)
        v = np.arange(4.0)
        c1 = store.register(1, v, nbytes=32)
        store.drop_worker(1)
        # same content must not dedup-resolve to the dead worker's chunk
        c0 = store.register(0, v.copy(), nbytes=32)
        assert c0.owner == 0 and c0 != c1
        assert np.array_equal(store.fetch(0, c0), v)

    def test_add_worker_grows_every_structure(self):
        store = ChunkStore(n_workers=2)
        w = store.add_worker()
        assert w == 2 and store.n_workers == 3
        assert len(store.stats) == 3
        c = store.register(w, np.ones(2), nbytes=16)
        assert c.owner == w
        assert store.stats[w].owned_bytes == 16

    def test_replicate_is_physical_copy_charged_to_dst(self):
        store = ChunkStore(n_workers=2)
        v = np.arange(8.0)
        c = store.register(0, v, nbytes=64)
        r = store.replicate(c, 1)
        assert r.owner == 1 and r != c           # no dedup collapse
        assert store.stats[1].owned_bytes == 64
        assert store.stats[1].bytes_received == 64
        store.drop_worker(0)
        assert np.array_equal(store.fetch(1, r), v)   # copy survives


class TestFaultSchedule:
    def test_event_validation(self):
        with pytest.raises(ValueError, match="unknown fault action"):
            FaultEvent(0.0, "explode", 0)
        with pytest.raises(ValueError, match="must be >= 0"):
            kill(-1.0, 0)
        with pytest.raises(ValueError, match="needs a worker"):
            FaultEvent(0.0, "kill")
        with pytest.raises(ValueError, match="factor must be > 0"):
            slow(0.0, 1, 0.0)
        assert join(1.0).worker is None          # join needs no worker

    def test_schedule_validation_and_sorting(self):
        with pytest.raises(ValueError, match="unknown recovery"):
            FaultSchedule(recovery="checkpoint")
        with pytest.raises(ValueError, match="replicas"):
            FaultSchedule(recovery="replication", replicas=0)
        fs = FaultSchedule(events=[kill(2.0, 1), slow(1.0, 0, 2.0),
                                   kill(2.0, 0)])
        assert [e.t for e in fs.events] == [1.0, 2.0, 2.0]
        # stable: same-time kills stay in given order
        assert [e.worker for e in fs.events[1:]] == [1, 0]
        assert fs.kill_times() == {1: 2.0, 0: 2.0}

    def test_as_fault_schedule_forms(self):
        assert as_fault_schedule(None) is None
        fs = FaultSchedule(events=[kill(1.0, 0)], recovery="none")
        assert as_fault_schedule(fs) is fs
        fs2 = as_fault_schedule([kill(1.0, 0), (0.5, "slow", 1, 3.0)])
        assert isinstance(fs2, FaultSchedule)
        assert fs2.recovery == "lineage"         # default policy
        assert [e.action for e in fs2.events] == ["slow", "kill"]

    def test_exports(self):
        import repro.runtime as rt
        for name in ("FaultEvent", "FaultSchedule", "RecoveryManager",
                     "kill", "slow", "join", "leave"):
            assert getattr(rt, name) is not None
        assert set(RECOVERIES) == {"none", "replication", "lineage"}
        assert set(ACTIONS) == {"kill", "slow", "join", "leave"}


class TestLineageRecovery:
    def test_kill_recovers_and_result_is_bitwise_identical(self, baseline):
        rep0, dense0 = baseline
        sess, C = _multiply_session()
        fs = FaultSchedule(events=[kill(0.5 * rep0.makespan, 2)],
                           recovery="lineage")
        rep = sess.simulate(fresh_stats=True, faults=fs)
        assert rep.workers_failed == [2]
        assert rep.n_failures == 1
        assert rep.chunks_lost > 0 and rep.bytes_lost > 0
        # minimal closure: a strict subset of the phase's DAG re-ran
        assert 0 < rep.tasks_recomputed < rep0.n_tasks
        assert np.array_equal(C.to_dense(), dense0)

    def test_dead_worker_owns_nothing_after_recovery(self, baseline):
        rep0, _ = baseline
        sess, C = _multiply_session()
        sess.simulate(fresh_stats=True,
                      faults=FaultSchedule(events=[kill(
                          0.5 * rep0.makespan, 1)]))
        sched = sess.scheduler
        assert all(cid.owner != 1 for cid in sched.placement.values())
        assert 1 not in sched.live_workers()

    def test_none_policy_restarts_phase_and_costs_more(self, baseline):
        rep0, dense0 = baseline
        t_kill = 0.5 * rep0.makespan

        sess_l, C_l = _multiply_session()
        rep_l = sess_l.simulate(fresh_stats=True, faults=FaultSchedule(
            events=[kill(t_kill, 2)], recovery="lineage"))
        sess_n, C_n = _multiply_session()
        rep_n = sess_n.simulate(fresh_stats=True, faults=FaultSchedule(
            events=[kill(t_kill, 2)], recovery="none"))

        # full re-run of everything done so far dwarfs the lineage closure
        assert rep_n.tasks_recomputed > rep_l.tasks_recomputed
        assert rep_n.makespan >= rep_l.makespan
        assert np.array_equal(C_n.to_dense(), dense0)
        assert np.array_equal(C_l.to_dense(), dense0)

    def test_replication_bounds_recompute(self, baseline):
        rep0, dense0 = baseline
        fs_build = FaultSchedule(events=[], recovery="replication")
        sess, C = _multiply_session(build_faults=fs_build)
        rep = sess.simulate(fresh_stats=True, faults=FaultSchedule(
            events=[kill(0.5 * rep0.makespan, 2)],
            recovery="replication", replicas=2))
        # one failure with r=2: every lost chunk had a surviving copy
        assert rep.tasks_recomputed == 0
        assert rep.chunks_recovered > 0
        assert rep.bytes_rereplicated > 0        # factor restored
        assert np.array_equal(C.to_dense(), dense0)

    def test_two_kills_at_same_instant(self, baseline):
        rep0, dense0 = baseline
        t = 0.4 * rep0.makespan
        sess, C = _multiply_session()
        rep = sess.simulate(fresh_stats=True, faults=FaultSchedule(
            events=[kill(t, 1), kill(t, 3)]))
        assert rep.workers_failed == [1, 3]
        applied = [e for e in rep.fault_events if not e.get("skipped")]
        assert [e["worker"] for e in applied] == [1, 3]  # schedule order
        assert np.array_equal(C.to_dense(), dense0)

    def test_kill_after_makespan_never_fires(self, baseline):
        rep0, dense0 = baseline
        sess, C = _multiply_session()
        rep = sess.simulate(fresh_stats=True, faults=FaultSchedule(
            events=[kill(10.0 * rep0.makespan + 1.0, 2)]))
        assert rep.workers_failed == []
        assert rep.tasks_recomputed == 0 and rep.chunks_lost == 0
        assert np.array_equal(C.to_dense(), dense0)

    def test_deterministic_under_identical_schedule(self, baseline):
        rep0, _ = baseline
        fs = FaultSchedule(events=[kill(0.5 * rep0.makespan, 2)])
        reps = []
        for _ in range(2):
            sess, C = _multiply_session()
            rep = sess.simulate(fresh_stats=True, faults=fs)
            reps.append((rep.to_dict(), C.to_dense()))
        d0, d1 = reps[0][0], reps[1][0]
        d0.pop("trace", None), d1.pop("trace", None)
        assert d0 == d1
        assert np.array_equal(reps[0][1], reps[1][1])

    def test_degradation_vs_fault_free(self, baseline):
        rep0, _ = baseline
        sess, C = _multiply_session()
        rep = sess.simulate(fresh_stats=True, faults=FaultSchedule(
            events=[kill(0.5 * rep0.makespan, 2)]))
        deg = rep.degradation_vs(rep0)
        assert deg >= 1.0                        # a failure never helps
        assert deg == rep.makespan / rep0.makespan

    def test_every_worker_dead_raises(self, baseline):
        rep0, _ = baseline
        sess, _ = _multiply_session()
        evs = [kill(0.1 * rep0.makespan, w) for w in range(P)]
        with pytest.raises(RuntimeError, match="every worker is dead"):
            sess.simulate(fresh_stats=True, faults=FaultSchedule(events=evs))


class TestFaultFreeNeutrality:
    """An injected schedule must not perturb fault-free numerics/reports."""

    def test_empty_schedule_is_report_identical(self, baseline):
        rep0, dense0 = baseline
        sess, C = _multiply_session()
        rep = sess.simulate(fresh_stats=True,
                            faults=FaultSchedule(events=[]))
        assert rep.makespan == rep0.makespan
        d0, d1 = rep0.to_dict(), rep.to_dict()
        d0.pop("trace", None), d1.pop("trace", None)
        assert d0 == d1
        assert np.array_equal(C.to_dense(), dense0)

    def test_fault_free_report_has_no_recovery_keys(self, baseline):
        rep0, _ = baseline
        d = rep0.to_dict()
        assert "tasks_recomputed" not in d and "workers_failed" not in d
        sess, _ = _multiply_session()
        rep = sess.simulate(fresh_stats=True, faults=FaultSchedule(
            events=[kill(0.5 * rep0.makespan, 2)]))
        d = rep.to_dict()
        assert d["workers_failed"] == [2]
        assert d["tasks_recomputed"] > 0

    def test_metrics_grow_recovery_counters_only_under_faults(self,
                                                              baseline):
        from repro.obs.metrics import from_sim_report
        rep0, _ = baseline
        assert "tasks_recomputed" not in from_sim_report(rep0).names()
        sess, _ = _multiply_session()
        rep = sess.simulate(fresh_stats=True, faults=FaultSchedule(
            events=[kill(0.5 * rep0.makespan, 2)]))
        ms = from_sim_report(rep)
        assert ms["workers_failed"].total == 1
        assert ms["tasks_recomputed"].total == rep.tasks_recomputed


class TestElasticity:
    def test_join_grows_pool_and_new_worker_executes(self, baseline):
        rep0, dense0 = baseline
        sess, C = _multiply_session()
        rep = sess.simulate(fresh_stats=True, faults=FaultSchedule(
            events=[join(0.2 * rep0.makespan)]))
        assert rep.n_workers == P + 1
        assert len(rep.tasks_per_worker) == P + 1
        assert rep.tasks_per_worker[P] > 0       # the joiner stole work
        assert np.array_equal(C.to_dense(), dense0)

    def test_leave_is_graceful(self, baseline):
        rep0, dense0 = baseline
        t_leave = 0.3 * rep0.makespan
        sess, C = _multiply_session()
        rep = sess.simulate(fresh_stats=True, faults=FaultSchedule(
            events=[leave(t_leave, 1)]))
        # chunks survive: nothing lost, nothing recomputed
        assert rep.chunks_lost == 0 and rep.tasks_recomputed == 0
        assert rep.workers_failed == []          # leave is not a death
        assert all(ev.worker != 1 for ev in rep.trace.events
                   if ev.start > t_leave)
        assert np.array_equal(C.to_dense(), dense0)

    def test_straggler_slows_makespan_not_values(self, baseline):
        rep0, dense0 = baseline
        sess, C = _multiply_session()
        rep = sess.simulate(fresh_stats=True, faults=FaultSchedule(
            events=[slow(0.0, 0, 8.0)]))
        assert rep.makespan > rep0.makespan
        assert np.array_equal(C.to_dense(), dense0)

    def test_unit_slow_factor_is_bitwise_neutral(self, baseline):
        rep0, dense0 = baseline
        sess, C = _multiply_session()
        rep = sess.simulate(fresh_stats=True, faults=FaultSchedule(
            events=[slow(0.0, 0, 1.0)]))
        assert rep.makespan == rep0.makespan     # *1.0 is IEEE-neutral
        assert np.array_equal(C.to_dense(), dense0)

    def test_kill_of_unknown_or_dead_worker_is_skipped(self, baseline):
        rep0, dense0 = baseline
        t = 0.5 * rep0.makespan
        sess, C = _multiply_session()
        rep = sess.simulate(fresh_stats=True, faults=FaultSchedule(
            events=[kill(t, 2), kill(t + 1e-6, 2), kill(t + 2e-6, 99)]))
        assert rep.workers_failed == [2]
        skipped = [e for e in rep.fault_events if e.get("skipped")]
        assert len(skipped) == 2
        assert np.array_equal(C.to_dense(), dense0)


class TestObservability:
    def test_fault_spans_emitted(self, baseline):
        rep0, _ = baseline
        a, b = _operands()
        sess = Session(leaf_n=LEAF_N, bs=BS, p=P, seed=0, trace=True)
        A, B = sess.from_dense(a), sess.from_dense(b)
        sess.simulate()
        C = A @ B
        sess.simulate(fresh_stats=True, faults=FaultSchedule(
            events=[kill(0.5 * rep0.makespan, 2)]))
        C.to_dense()
        tr = sess.tracer
        kills = tr.find("fault.kill")
        assert len(kills) == 1
        assert kills[0].attrs["worker"] == 2
        assert kills[0].attrs["chunks_lost"] > 0
        recs = tr.find("fault.recover")
        assert len(recs) == 1
        assert recs[0].attrs["tasks_recomputed"] > 0
        assert recs[0].attrs["policy"] == "lineage"

    def test_fault_events_json_ready(self, baseline):
        import json
        rep0, _ = baseline
        sess, _ = _multiply_session()
        rep = sess.simulate(fresh_stats=True, faults=FaultSchedule(
            events=[kill(0.5 * rep0.makespan, 2), join(0.6 * rep0.makespan)]))
        json.dumps(rep.to_dict())                # must not raise
        actions = [e["action"] for e in rep.fault_events]
        assert actions == ["kill", "join"]


class TestPlanReplayUnderFaults:
    """The acceptance pin: failure-injected Plan replay is bitwise
    identical to the failure-free replay, on both leaf engines."""

    @pytest.mark.parametrize("engine", ["numpy",
                                        pytest.param("pallas",
                                                     marks=pytest.mark.pallas)])
    def test_replay_bitwise_identical_under_kill(self, engine):
        a, _ = _operands()

        def run(faults):
            sess = Session(engine=engine, leaf_n=LEAF_N, bs=BS, p=P,
                           seed=0, lazy=True)
            X = sess.from_dense(a, name="X")
            plan = sess.compile(X @ X)
            plan.run()
            rep0 = plan.simulate()               # fault-free replay: M0
            rep = plan.simulate(faults=faults(rep0.makespan))
            Y = plan.run(X=a)                    # reuse plan post-recovery
            return Y.to_dense(), rep

        base, rep_b = run(lambda M0: None)
        faulted, rep_f = run(lambda M0: FaultSchedule(
            events=[kill(0.5 * M0, 2)]))
        assert rep_f.tasks_recomputed > 0        # the fault really fired
        assert rep_b.tasks_recomputed == 0
        assert np.array_equal(base, faulted)     # bitwise, not allclose

    @pytest.mark.slow
    def test_replay_every_policy_identical(self):
        a, _ = _operands()
        outs = {}
        for policy in (None, "lineage", "none", "replication"):
            sess = Session(leaf_n=LEAF_N, bs=BS, p=P, seed=0, lazy=True)
            X = sess.from_dense(a, name="X")
            plan = sess.compile(X @ X)
            plan.run()
            rep0 = plan.simulate()
            if policy is not None:
                fs = FaultSchedule(events=[kill(0.5 * rep0.makespan, 1)],
                                   recovery=policy)
                plan.simulate(faults=fs)
            out = plan.run(X=a).to_dense()
            outs[policy or "fault-free"] = out
        base = outs.pop("fault-free")
        for policy, out in outs.items():
            assert np.array_equal(base, out), policy


class TestReplayReleaseAfterDeath:
    """Satellite: replay/release vs dead-worker state (scheduler level)."""

    def test_fresh_replay_avoids_dead_worker(self, baseline):
        rep0, dense0 = baseline
        sess, C = _multiply_session()
        sess.simulate(fresh_stats=True, faults=FaultSchedule(
            events=[kill(0.5 * rep0.makespan, 2)]))
        dense1 = C.to_dense()
        sched, g = sess.scheduler, sess.graph
        nids = sorted(nid for nid in sched.placement
                      if g.nodes[nid].alias_of is None)
        sched.reset_stats()
        rep = sched.replay(g, nids)
        # nothing may run on, or be placed on, the dead worker
        assert all(cid.owner != 2 for cid in sched.placement.values())
        assert rep.tasks_per_worker[2] == 0
        assert all(ev.worker != 2 for ev in rep.trace.events)
        assert np.array_equal(C.to_dense(), dense1)
        assert np.array_equal(dense1, dense0)
