"""Shared pytest config: sys.path for intra-suite imports + slow gating.

Markers (registered in pytest.ini):
  slow   — long-running tests; deselected unless ``--slow`` is given so the
           tier-1 command (``python -m pytest -x -q``) stays fast.
  pallas — exercises the Pallas kernels (interpret mode on CPU, compiled on
           TPU); select just these with ``-m pallas``.
"""
import os
import sys

import pytest

# make tests/_hyp.py (and friends) importable under any pytest importmode
sys.path.insert(0, os.path.dirname(__file__))


def pytest_addoption(parser):
    parser.addoption("--slow", action="store_true", default=False,
                     help="also run tests marked @pytest.mark.slow")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--slow"):
        return
    skip_slow = pytest.mark.skip(reason="slow test: pass --slow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
