"""Shared hypothesis shim for the property-based tests.

When ``hypothesis`` is installed the real ``given``/``settings``/strategies
are re-exported unchanged.  Without it (the optional dep is not part of the
baked toolchain) a tiny deterministic fallback runs each property test over a
fixed number of seeded examples, so ``python -m pytest -x -q`` collects and
runs green either way.

The fallback implements exactly the strategy surface this suite uses:
``st.integers(lo, hi)``, ``st.floats(lo, hi)``, ``st.sampled_from(seq)``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    # fallback cap: keeps the no-hypothesis tier fast; the CI job with
    # hypothesis installed runs the full declared max_examples
    _FALLBACK_MAX_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

    st = _Strategies()

    def settings(max_examples: int = _FALLBACK_MAX_EXAMPLES, **_ignored):
        def deco(fn):
            fn._hyp_max_examples = min(max_examples, _FALLBACK_MAX_EXAMPLES)
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_hyp_max_examples",
                            _FALLBACK_MAX_EXAMPLES)
                # seeded per test so examples are stable across runs
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)
            # hide the property parameters from pytest's fixture resolution
            wrapper.__signature__ = inspect.Signature()
            del wrapper.__wrapped__
            return wrapper
        return deco
