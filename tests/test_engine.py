"""Leaf execution engine: numpy reference vs Pallas batched backend.

Every quadtree operation is run through both backends on the paper's pattern
families (random, banded, and the S2 electronic-structure overlap pattern)
and checked against dense numpy.  The pallas backend runs the actual kernel
bodies in interpret mode on CPU, with cross-leaf batched waves.
"""
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.core.engine import (LeafPayload, NumpyEngine, PallasEngine,
                               leaf_task_pairs, make_engine)
from repro.core.leaf import LeafMatrix, alloc_structure, unpack_blocks
from repro.core.multiply import (count_tasks_per_level, qt_add, qt_multiply,
                                 qt_sym_multiply, qt_sym_square, qt_syrk,
                                 total_flops, total_multiply_tasks)
from repro.core.patterns import (banded_mask, divide_space_order,
                                 overlap_mask, particle_cloud, random_mask,
                                 random_symmetric_mask, values_for_mask)
from repro.core.quadtree import QTParams, qt_from_dense, qt_to_dense
from repro.core.tasks import ClusterSim, CTGraph

PARAMS = QTParams(n=64, leaf_n=16, bs=4)
TOL = dict(atol=1e-4, rtol=1e-4)   # pallas packs float32; numpy is float64


def _s2_mask(n=64):
    """The paper's §6.2 application pattern: 3-D particle-cloud overlap
    matrix in recursive divide-space ordering (symmetric by construction)."""
    coords = particle_cloud(4, 3, seed=7)          # 64 basis functions
    order = divide_space_order(coords)
    return overlap_mask(coords, 4.0, order=order)


PATTERNS = {
    "random": lambda: random_mask(64, 0.12, seed=3),
    "banded": lambda: banded_mask(64, 6),
    "s2": _s2_mask,
}
ENGINES = ["pallas-pairs", "pallas-gemm"]


def _engine(spec):
    if spec == "pallas-pairs":
        return PallasEngine(kernel="pairs")
    if spec == "pallas-gemm":
        return PallasEngine(kernel="gemm")
    return make_engine(spec)


def _both(build, check):
    """Run ``build(g) -> root id`` under each backend and check results."""
    outs = {}
    graphs = {}
    for spec in ["numpy"] + ENGINES:
        g = CTGraph(engine=_engine(spec))
        rc = build(g)
        outs[spec] = qt_to_dense(g, rc, PARAMS)
        graphs[spec] = g
    for spec in ENGINES:
        np.testing.assert_allclose(outs[spec], outs["numpy"], **TOL)
    check(outs["numpy"])
    return graphs


@pytest.mark.pallas
class TestMultiplyEquivalence:
    @pytest.mark.parametrize("pattern", sorted(PATTERNS))
    def test_multiply(self, pattern):
        a = values_for_mask(PATTERNS[pattern](), seed=1)
        b = values_for_mask(PATTERNS[pattern](), seed=2)

        def build(g):
            ra = qt_from_dense(g, a, PARAMS)
            rb = qt_from_dense(g, b, PARAMS)
            return qt_multiply(g, PARAMS, ra, rb)

        _both(build, lambda out: np.testing.assert_allclose(out, a @ b,
                                                            atol=1e-10))

    @pytest.mark.parametrize("ta,tb", [(True, False), (False, True),
                                       (True, True)])
    def test_multiply_transposes(self, ta, tb):
        a = values_for_mask(banded_mask(64, 5), seed=4)
        b = values_for_mask(random_mask(64, 0.1, seed=5), seed=5)

        def build(g):
            ra = qt_from_dense(g, a, PARAMS)
            rb = qt_from_dense(g, b, PARAMS)
            return qt_multiply(g, PARAMS, ra, rb, ta=ta, tb=tb)

        want = (a.T if ta else a) @ (b.T if tb else b)
        _both(build, lambda out: np.testing.assert_allclose(out, want,
                                                            atol=1e-10))

    def test_add(self):
        a = values_for_mask(banded_mask(64, 4), seed=6)
        b = values_for_mask(random_mask(64, 0.08, seed=7), seed=7)

        def build(g):
            ra = qt_from_dense(g, a, PARAMS)
            rb = qt_from_dense(g, b, PARAMS)
            return qt_add(g, PARAMS, ra, rb)

        _both(build, lambda out: np.testing.assert_allclose(out, a + b,
                                                            atol=1e-12))

    def test_all_zero_leaves_and_nil_quadrants(self):
        # middle band of rows zero -> whole leaf rows NIL; only the upper-left
        # quadrant of B occupied -> three root children NIL
        a = values_for_mask(banded_mask(64, 6), seed=8)
        a[16:48, :] = 0.0
        b = np.zeros((64, 64))
        b[:32, :32] = values_for_mask(random_mask(32, 0.3, seed=9), seed=9)

        def build(g):
            ra = qt_from_dense(g, a, PARAMS)
            rb = qt_from_dense(g, b, PARAMS)
            return qt_multiply(g, PARAMS, ra, rb)

        _both(build, lambda out: np.testing.assert_allclose(out, a @ b,
                                                            atol=1e-10))

    def test_disjoint_product_is_structurally_nil(self):
        a = np.zeros((64, 64)); a[:16, 48:] = 1.0
        b = np.zeros((64, 64)); b[:16, :16] = 1.0
        for spec in ["numpy"] + ENGINES:
            g = CTGraph(engine=_engine(spec))
            ra = qt_from_dense(g, a, PARAMS)
            rb = qt_from_dense(g, b, PARAMS)
            rc = qt_multiply(g, PARAMS, ra, rb)
            assert rc is None or np.allclose(qt_to_dense(g, rc, PARAMS), 0)


@pytest.mark.pallas
class TestSymmetricEquivalence:
    @pytest.mark.parametrize("pattern", sorted(PATTERNS))
    def test_sym_square(self, pattern):
        mask = PATTERNS[pattern]()
        s = values_for_mask(mask | mask.T, seed=11, symmetric=True)

        def build(g):
            rs = qt_from_dense(g, s, PARAMS, upper=True)
            return qt_sym_square(g, PARAMS, rs)

        _both(build, lambda out: np.testing.assert_allclose(out, s @ s,
                                                            atol=1e-10))

    @pytest.mark.parametrize("trans", [False, True])
    def test_syrk(self, trans):
        a = values_for_mask(banded_mask(64, 6), seed=12)

        def build(g):
            ra = qt_from_dense(g, a, PARAMS)
            return qt_syrk(g, PARAMS, ra, trans=trans)

        want = a.T @ a if trans else a @ a.T
        _both(build, lambda out: np.testing.assert_allclose(out, want,
                                                            atol=1e-10))

    @pytest.mark.parametrize("side", ["left", "right"])
    def test_sym_multiply(self, side):
        s = values_for_mask(random_symmetric_mask(64, 0.1, seed=13),
                            seed=13, symmetric=True)
        b = values_for_mask(banded_mask(64, 5), seed=14)

        def build(g):
            rs = qt_from_dense(g, s, PARAMS, upper=True)
            rb = qt_from_dense(g, b, PARAMS)
            return qt_sym_multiply(g, PARAMS, rs, rb, side=side)

        want = s @ b if side == "left" else b @ s
        _both(build, lambda out: np.testing.assert_allclose(out, want,
                                                            atol=1e-10))


@pytest.mark.pallas
class TestGraphInvariance:
    """The executor refactor must not change the task graph: structure,
    counts and flop attribution are backend-independent."""

    def _graphs(self):
        a = values_for_mask(banded_mask(64, 5), seed=20)
        b = values_for_mask(random_mask(64, 0.1, seed=21), seed=21)

        def build(g):
            ra = qt_from_dense(g, a, PARAMS)
            rb = qt_from_dense(g, b, PARAMS)
            return qt_multiply(g, PARAMS, ra, rb)

        return _both(build, lambda out: None)

    def test_task_counts_and_flops_match(self):
        graphs = self._graphs()
        ref = graphs["numpy"]
        for spec in ENGINES:
            g = graphs[spec]
            assert total_multiply_tasks(g) == total_multiply_tasks(ref)
            assert count_tasks_per_level(g) == count_tasks_per_level(ref)
            assert total_flops(g) == pytest.approx(total_flops(ref))
            assert g.count_kinds() == ref.count_kinds()

    def test_wave_stats_account_for_all_pairs(self):
        graphs = self._graphs()
        for spec in ENGINES:
            g = graphs[spec]
            st_ = g.engine.stats()
            assert st_["waves"] >= 1
            bs = PARAMS.bs
            # every structural pair ran in a batched wave, exactly once
            assert st_["batched_pairs"] == total_flops(g) / (2.0 * bs ** 3)
            assert st_["padded_pairs"] >= st_["batched_pairs"]
            assert st_["kernel_wall_s"] > 0.0

    def test_cluster_sim_equivalent_across_backends(self):
        """Same task graph + flops => same simulated schedule; makespans
        agree to the (small) fetch-time delta from pallas chunks being
        float32 (half the bytes of numpy's float64 leaves)."""
        a = values_for_mask(banded_mask(64, 5), seed=22)
        results = {}
        for spec in ["numpy", "pallas-pairs"]:
            g = CTGraph(engine=_engine(spec))
            ra = qt_from_dense(g, a, PARAMS)
            rb = qt_from_dense(g, a, PARAMS)
            sim = ClusterSim(4, seed=0)
            sim.run(g)
            sim.reset_stats()
            qt_multiply(g, PARAMS, ra, rb)
            results[spec] = sim.run(g)
        ref, got = results["numpy"], results["pallas-pairs"]
        assert sum(got.tasks_per_worker) == sum(ref.tasks_per_worker)
        assert got.makespan == pytest.approx(ref.makespan, rel=0.02)


@pytest.mark.pallas
class TestEngineUnit:
    def test_make_engine_specs(self):
        assert isinstance(make_engine(None), NumpyEngine)
        assert isinstance(make_engine("numpy"), NumpyEngine)
        assert isinstance(make_engine("pallas"), PallasEngine)
        e = PallasEngine(kernel="gemm")
        assert make_engine(e) is e
        with pytest.raises(ValueError):
            make_engine("cuda")

    def test_leaf_task_pairs_matches_leafstats(self):
        """Structural pair count == the numpy backend's block_multiplies."""
        from repro.core.leaf import LeafStats, leaf_multiply, leaf_sym_square
        a = LeafMatrix.from_dense(
            values_for_mask(random_mask(16, 0.4, seed=30), seed=30), 4)
        b = LeafMatrix.from_dense(
            values_for_mask(random_mask(16, 0.4, seed=31), seed=31), 4)
        stats = LeafStats()
        leaf_multiply(a, b, stats=stats)
        pairs, upper = leaf_task_pairs(LeafPayload("multiply"), a, b)
        assert not upper and len(pairs) == stats.block_multiplies

        s = values_for_mask(random_symmetric_mask(16, 0.4, seed=32),
                            seed=32, symmetric=True)
        su = LeafMatrix.from_dense(s, 4, upper=True)
        stats = LeafStats()
        leaf_sym_square(su, stats=stats)
        pairs, upper = leaf_task_pairs(LeafPayload("sym_square"), su, None)
        assert upper and len(pairs) == stats.block_multiplies

    def test_structure_matches_compute_c_structure(self):
        """Pure-Python output structure == the bsmm boolean-matmul structure
        (validate_structure cross-checks every leaf task at registration)."""
        a = values_for_mask(random_mask(64, 0.15, seed=40), seed=40)
        s = values_for_mask(random_symmetric_mask(64, 0.15, seed=41),
                            seed=41, symmetric=True)
        g = CTGraph(engine=PallasEngine(validate_structure=True))
        ra = qt_from_dense(g, a, PARAMS)
        rb = qt_from_dense(g, a, PARAMS)
        qt_multiply(g, PARAMS, ra, rb, tb=True)
        rs = qt_from_dense(g, s, PARAMS, upper=True)
        qt_sym_square(g, PARAMS, rs)
        g.flush()   # would have asserted on any structure mismatch

    @pytest.mark.parametrize("spec", ["numpy"] + ENGINES)
    def test_upper_operand_to_plain_multiply_rejected(self, spec):
        """Both backends refuse a plain multiply on upper-storage leaves
        (the host-library contract) instead of silently dropping the
        mirrored lower triangle."""
        s = values_for_mask(random_symmetric_mask(64, 0.2, seed=35),
                            seed=35, symmetric=True)
        b = values_for_mask(banded_mask(64, 4), seed=36)
        g = CTGraph(engine=_engine(spec))
        rs = qt_from_dense(g, s, PARAMS, upper=True)
        rb = qt_from_dense(g, b, PARAMS)
        with pytest.raises(AssertionError):
            qt_multiply(g, PARAMS, rs, rb)

    def test_alloc_unpack_roundtrip(self):
        a = LeafMatrix.from_dense(
            values_for_mask(banded_mask(16, 3), seed=33), 4)
        keys = list(a.blocks)
        out = alloc_structure(16, 4, keys)
        assert list(out.blocks) == keys
        assert all(np.all(blk == 0) for blk in out.blocks.values())
        held = [out.blocks[k] for k in keys]    # downstream references
        unpack_blocks(out, keys, np.stack([a.blocks[k] for k in keys]))
        np.testing.assert_allclose(out.to_dense(), a.to_dense())
        # in-place fill: previously-taken references see the new data
        assert all(h is out.blocks[k] for h, k in zip(held, keys))

    def test_engine_instance_bound_to_one_graph(self):
        a = values_for_mask(banded_mask(64, 3), seed=34)
        e = PallasEngine()
        g1 = CTGraph(engine=e)
        ra = qt_from_dense(g1, a, PARAMS)
        qt_multiply(g1, PARAMS, ra, ra)
        g2 = CTGraph(engine=e)
        rb = qt_from_dense(g2, a, PARAMS)
        with pytest.raises(ValueError, match="one engine per graph"):
            qt_multiply(g2, PARAMS, rb, rb)


@pytest.mark.pallas
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), fill=st.floats(0.05, 0.4),
       kernel=st.sampled_from(["pairs", "gemm"]))
def test_property_engine_equivalence(seed, fill, kernel):
    a = values_for_mask(random_mask(64, fill, seed=seed), seed=seed)
    b = values_for_mask(random_mask(64, fill, seed=seed + 1), seed=seed + 1)
    outs = {}
    for eng in ("numpy", kernel):
        spec = "numpy" if eng == "numpy" else PallasEngine(kernel=kernel)
        g = CTGraph(engine=spec)
        ra = qt_from_dense(g, a, PARAMS)
        rb = qt_from_dense(g, b, PARAMS)
        rc = qt_multiply(g, PARAMS, ra, rb)
        outs[eng] = qt_to_dense(g, rc, PARAMS)
    np.testing.assert_allclose(outs[kernel], outs["numpy"], **TOL)
    np.testing.assert_allclose(outs["numpy"], a @ b, atol=1e-10)
