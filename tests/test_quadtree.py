"""Quadtree matrix library vs dense numpy (paper §3, Algorithms 1-2)."""
import numpy as np
import pytest

from repro.core.tasks import CTGraph
from repro.core.quadtree import (QTParams, qt_from_coo, qt_from_dense,
                                 qt_to_dense, qt_stats)
from repro.core.multiply import (qt_add, qt_multiply, qt_sym_multiply,
                                 qt_sym_square, qt_syrk,
                                 count_tasks_per_level, total_add_tasks,
                                 total_multiply_tasks)
from repro.core.patterns import (banded_mask, random_mask,
                                 random_symmetric_mask, values_for_mask)

PARAMS = QTParams(n=64, leaf_n=16, bs=4)


def _mk(mask, seed, symmetric=False):
    return values_for_mask(mask, seed=seed, symmetric=symmetric)


def _roundtrip(a, params=PARAMS, upper=False):
    g = CTGraph()
    r = qt_from_dense(g, a, params, upper=upper)
    return qt_to_dense(g, r, params), g, r


class TestConstruction:
    def test_roundtrip_banded(self):
        a = _mk(banded_mask(64, 5), 0)
        out, _, _ = _roundtrip(a)
        np.testing.assert_allclose(out, a)

    def test_roundtrip_random(self):
        a = _mk(random_mask(64, 0.05, seed=3), 1)
        out, _, _ = _roundtrip(a)
        np.testing.assert_allclose(out, a)

    def test_roundtrip_upper_symmetric(self):
        a = _mk(random_symmetric_mask(64, 0.1, seed=4), 2, symmetric=True)
        out, _, _ = _roundtrip(a, upper=True)
        np.testing.assert_allclose(out, a)

    def test_zero_matrix_is_nil(self):
        g = CTGraph()
        r = qt_from_dense(g, np.zeros((64, 64)), PARAMS)
        assert r is None

    def test_nil_subtrees_pruned(self):
        # only upper-left leaf occupied -> three root children NIL
        a = np.zeros((64, 64))
        a[:8, :8] = 1.0
        _, g, r = _roundtrip(a)
        root = g.value_of(r)
        assert root.child(0, 1) is None
        assert root.child(1, 0) is None
        assert root.child(1, 1) is None

    def test_from_coo_matches_from_dense(self):
        mask = banded_mask(64, 3)
        rows, cols = np.nonzero(mask)

        def vf(r, c):
            return (r * 64 + c).astype(np.float64) / 1000.0

        g = CTGraph()
        r1 = qt_from_coo(g, rows, cols, PARAMS, value_fn=vf)
        dense = np.zeros((64, 64))
        dense[rows, cols] = vf(rows, cols)
        out = qt_to_dense(g, r1, PARAMS)
        np.testing.assert_allclose(out, dense)

    def test_stats(self):
        a = _mk(banded_mask(64, 5), 0)
        _, g, r = _roundtrip(a)
        st = qt_stats(g, r)
        assert st["depth"] == 2  # 64 -> 32 -> 16 leaves
        assert st["leaf_chunks"] > 0
        assert st["nnz_blocks"] > 0


class TestMultiply:
    @pytest.mark.parametrize("ta,tb", [(False, False), (True, False),
                                       (False, True), (True, True)])
    def test_multiply_transposes(self, ta, tb):
        a = _mk(banded_mask(64, 7), 10)
        b = _mk(random_mask(64, 0.08, seed=5), 11)
        g = CTGraph()
        ra = qt_from_dense(g, a, PARAMS)
        rb = qt_from_dense(g, b, PARAMS)
        rc = qt_multiply(g, PARAMS, ra, rb, ta=ta, tb=tb)
        out = qt_to_dense(g, rc, PARAMS)
        aa = a.T if ta else a
        bb = b.T if tb else b
        np.testing.assert_allclose(out, aa @ bb, atol=1e-12)

    def test_multiply_nil_either(self):
        a = _mk(banded_mask(64, 3), 1)
        g = CTGraph()
        ra = qt_from_dense(g, a, PARAMS)
        assert qt_multiply(g, PARAMS, ra, None) is None
        assert qt_multiply(g, PARAMS, None, ra) is None

    def test_add(self):
        a = _mk(banded_mask(64, 4), 1)
        b = _mk(random_mask(64, 0.05, seed=2), 2)
        g = CTGraph()
        ra = qt_from_dense(g, a, PARAMS)
        rb = qt_from_dense(g, b, PARAMS)
        rc = qt_add(g, PARAMS, ra, rb)
        np.testing.assert_allclose(qt_to_dense(g, rc, PARAMS), a + b)

    def test_add_single_nil_aliases(self):
        a = _mk(banded_mask(64, 4), 1)
        g = CTGraph()
        ra = qt_from_dense(g, a, PARAMS)
        n_before = len(g.nodes)
        rc = qt_add(g, PARAMS, ra, None)
        assert rc == ra              # identifier copy, no new chunk
        assert len(g.nodes) == n_before

    def test_disjoint_product_is_nil(self):
        # A occupies left half columns, B occupies bottom-left; A*B has
        # k-range overlap only where A cols meet B rows
        a = np.zeros((64, 64)); a[:16, 48:] = 1.0
        b = np.zeros((64, 64)); b[:16, :16] = 1.0
        g = CTGraph()
        ra = qt_from_dense(g, a, PARAMS)
        rb = qt_from_dense(g, b, PARAMS)
        rc = qt_multiply(g, PARAMS, ra, rb)
        assert rc is None or np.allclose(qt_to_dense(g, rc, PARAMS), 0)


class TestSymmetric:
    def test_sym_square(self):
        s = _mk(random_symmetric_mask(64, 0.08, seed=7), 3, symmetric=True)
        g = CTGraph()
        rs = qt_from_dense(g, s, PARAMS, upper=True)
        rc = qt_sym_square(g, PARAMS, rs)
        np.testing.assert_allclose(qt_to_dense(g, rc, PARAMS), s @ s,
                                   atol=1e-12)

    @pytest.mark.parametrize("trans", [False, True])
    def test_syrk(self, trans):
        a = _mk(banded_mask(64, 6), 8)
        g = CTGraph()
        ra = qt_from_dense(g, a, PARAMS)
        rc = qt_syrk(g, PARAMS, ra, trans=trans)
        ref = a.T @ a if trans else a @ a.T
        np.testing.assert_allclose(qt_to_dense(g, rc, PARAMS), ref,
                                   atol=1e-12)

    @pytest.mark.parametrize("side", ["left", "right"])
    def test_sym_multiply(self, side):
        s = _mk(random_symmetric_mask(64, 0.1, seed=9), 4, symmetric=True)
        b = _mk(banded_mask(64, 5), 5)
        g = CTGraph()
        rs = qt_from_dense(g, s, PARAMS, upper=True)
        rb = qt_from_dense(g, b, PARAMS)
        rc = qt_sym_multiply(g, PARAMS, rs, rb, side=side)
        ref = s @ b if side == "left" else b @ s
        np.testing.assert_allclose(qt_to_dense(g, rc, PARAMS), ref,
                                   atol=1e-12)

    def test_sym_square_halves_leaf_multiplies(self):
        """§3.3/Fig 9: symmetric square does ~half the multiply work."""
        from repro.core.multiply import total_flops
        s = _mk(banded_mask(64, 15), 6, symmetric=True)
        s = (s + s.T) / 2
        g1 = CTGraph()
        rs = qt_from_dense(g1, s, PARAMS, upper=True)
        qt_sym_square(g1, PARAMS, rs)
        f_sym = total_flops(g1)
        g2 = CTGraph()
        ra = qt_from_dense(g2, s, PARAMS)
        rb = qt_from_dense(g2, s, PARAMS)
        qt_multiply(g2, PARAMS, ra, rb)
        f_reg = total_flops(g2)
        assert f_sym < 0.75 * f_reg  # ~0.5 plus diagonal overhead


class TestTaskCounts:
    def test_more_multiplies_than_adds(self):
        """§5: addition tasks strictly bounded by multiplication tasks."""
        for seed in range(3):
            a = _mk(random_mask(64, 0.1, seed=seed), seed)
            b = _mk(random_mask(64, 0.1, seed=seed + 10), seed + 1)
            g = CTGraph()
            ra = qt_from_dense(g, a, PARAMS)
            rb = qt_from_dense(g, b, PARAMS)
            qt_multiply(g, PARAMS, ra, rb)
            assert total_add_tasks(g) < total_multiply_tasks(g)

    def test_per_level_counts(self):
        a = _mk(banded_mask(64, 3), 0)
        g = CTGraph()
        ra = qt_from_dense(g, a, PARAMS)
        rb = qt_from_dense(g, a, PARAMS)
        qt_multiply(g, PARAMS, ra, rb)
        per = count_tasks_per_level(g)
        assert set(per) <= {0, 1, 2}
        assert per[0] == 1  # one root multiply
        # banded: leaf level dominates (locality, Fig 3 right)
        assert per[2] > per[1] > 0
