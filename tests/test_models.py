"""Per-arch smoke tests + model-level invariants (reduced configs, CPU)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import layers as L
from repro.models import model as M
from repro.models import ssm
from repro.models.config import (ALL_SHAPES, applicable_shapes,
                                 input_specs, SHAPES_BY_NAME)


def _batch_for(cfg, b, s, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.frontend == "frames":
        return {"frames": jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)), cfg.jdtype),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32)}
    if cfg.frontend == "patches":
        st_ = s - cfg.n_patches
        return {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (b, st_)), jnp.int32),
            "patches": jnp.asarray(
                rng.standard_normal((b, cfg.n_patches, cfg.d_model)),
                cfg.jdtype),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab, (b, st_)),
                                   jnp.int32)}
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                  jnp.int32),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32)}


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    """REQUIRED per-arch smoke: reduced config, one forward/train step,
    output shapes + no NaNs."""

    def test_forward_shapes_and_finite(self, arch):
        cfg = get_smoke_config(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch_for(cfg, 2, 64)
        logits, aux = M.forward(cfg, params, batch)
        s_out = 64 if cfg.frontend != "patches" else 64
        assert logits.shape == (2, s_out, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()

    def test_train_step_decreases_loss(self, arch):
        cfg = get_smoke_config(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch_for(cfg, 2, 64)

        @jax.jit
        def step(p):
            (loss, _), g = jax.value_and_grad(
                lambda q: M.loss_fn(cfg, q, batch), has_aux=True)(p)
            p = jax.tree.map(
                lambda w, gw: (w.astype(jnp.float32)
                               - 0.2 * gw.astype(jnp.float32)
                               ).astype(w.dtype), p, g)
            return loss, p

        l0, params = step(params)
        for _ in range(3):
            l1, params = step(params)
        assert np.isfinite(float(l0)) and np.isfinite(float(l1))
        assert float(l1) < float(l0)

    def test_decode_step(self, arch):
        cfg = get_smoke_config(arch)
        if cfg.is_encoder_only:
            pytest.skip("encoder-only: no decode step")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        cache = M.init_cache(cfg, 2, 16)
        logits, cache2 = M.decode_step(
            cfg, params, jnp.zeros((2,), jnp.int32), cache, jnp.int32(0))
        assert logits.shape == (2, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()
        assert jax.tree.structure(cache) == jax.tree.structure(cache2)

    def test_full_config_param_count_plausible(self, arch):
        cfg = get_config(arch)
        n = cfg.param_count()
        # billions within the advertised ballpark
        expected = {
            "llama3_2_3b": 3.2e9, "stablelm_12b": 11.6e9,
            "h2o_danube3_4b": 3.8e9, "olmo_1b": 1.2e9,
            "phi3_5_moe": 42e9, "mixtral_8x7b": 47e9,
            "hubert_xlarge": 0.95e9, "falcon_mamba_7b": 7e9,
            "zamba2_2_7b": 2.4e9, "internvl2_2b": 1.7e9,
        }[arch]
        assert 0.7 * expected < n < 1.35 * expected

    def test_applicable_shapes_policy(self, arch):
        cfg = get_config(arch)
        names = {s.name for s in applicable_shapes(cfg)}
        assert {"train_4k", "prefill_32k"} <= names
        if cfg.is_encoder_only:
            assert "decode_32k" not in names
        if cfg.mixer == "attention" and not cfg.swa_window:
            assert "long_500k" not in names      # quadratic attention skip
        if cfg.mixer in ("mamba1", "mamba2"):
            assert "long_500k" in names

    def test_input_specs_no_allocation(self, arch):
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            specs = input_specs(cfg, shape)
            for v in jax.tree.leaves(specs):
                assert isinstance(v, jax.ShapeDtypeStruct)


class TestDecodeConsistency:
    """Token-by-token decode reproduces the full forward pass."""

    @pytest.mark.parametrize("arch", ["llama3_2_3b", "h2o_danube3_4b",
                                      "falcon_mamba_7b", "zamba2_2_7b",
                                      "phi3_5_moe"])
    def test_decode_matches_forward(self, arch):
        cfg = get_smoke_config(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(1))
        s = 16
        rng = np.random.default_rng(2)
        tokens = rng.integers(0, cfg.vocab, (2, s)).astype(np.int32)
        logits_full, _ = M.forward(cfg, params,
                                   {"tokens": jnp.asarray(tokens)},
                                   remat=False)
        cache = M.init_cache(cfg, 2, s)
        outs = []
        for t in range(s):
            lg, cache = M.decode_step(cfg, params,
                                      jnp.asarray(tokens[:, t]), cache,
                                      jnp.int32(t))
            outs.append(lg)
        logits_dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(logits_dec, np.float32),
            np.asarray(logits_full, np.float32), atol=2e-2, rtol=1e-2)


class TestLayerInvariants:
    def test_rmsnorm_scale_identity_at_zero(self):
        x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 8)),
                        jnp.float32)
        out = L.rms_norm(x, jnp.zeros((8,)))
        norm = np.sqrt((np.asarray(out) ** 2).mean(-1))
        np.testing.assert_allclose(norm, 1.0, atol=1e-4)

    def test_nonparam_ln_zero_mean_unit_var(self):
        x = jnp.asarray(np.random.default_rng(1).standard_normal((4, 32)),
                        jnp.float32)
        out = np.asarray(L.nonparam_layer_norm(x))
        np.testing.assert_allclose(out.mean(-1), 0.0, atol=1e-5)
        np.testing.assert_allclose(out.var(-1), 1.0, atol=1e-3)

    def test_rope_preserves_norm_and_relativity(self):
        """RoPE is a rotation (norm preserved); scores depend only on
        relative positions."""
        rng = np.random.default_rng(3)
        hd = 8
        q = jnp.asarray(rng.standard_normal((1, 4, 1, hd)), jnp.float32)
        pos0 = jnp.asarray([[0, 1, 2, 3]])
        pos5 = pos0 + 5
        q0 = L.apply_rope(q, pos0, 1e4)
        q5 = L.apply_rope(q, pos5, 1e4)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(q0), axis=-1),
            np.linalg.norm(np.asarray(q), axis=-1), rtol=1e-5)
        # relative dot products invariant to absolute offset
        d0 = np.einsum("bshd,bthd->bst", np.asarray(q0), np.asarray(q0))
        d5 = np.einsum("bshd,bthd->bst", np.asarray(q5), np.asarray(q5))
        np.testing.assert_allclose(d0, d5, atol=1e-4)

    def test_moe_capacity_drop(self):
        """Over-capacity tokens contribute zero, never garbage."""
        rng = np.random.default_rng(4)
        # capacity rounds up to 16 for TP-shardability; 64 tokens on one
        # preferred expert still overflow it
        t, d, e, ff = 64, 4, 2, 8
        x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
        rw = jnp.asarray(np.stack([np.ones(d), -np.ones(d)], 1),
                         jnp.float32)  # all tokens prefer expert 0 or 1
        wg = jnp.asarray(rng.standard_normal((e, d, ff)), jnp.float32)
        wu = jnp.asarray(rng.standard_normal((e, d, ff)), jnp.float32)
        wd = jnp.asarray(rng.standard_normal((e, ff, d)), jnp.float32)
        out, _ = L.moe_ffn(x, rw, wg, wu, wd, top_k=1,
                           capacity_factor=0.25)
        assert np.isfinite(np.asarray(out)).all()
        # at least some tokens dropped -> some rows exactly zero
        zeros = (np.abs(np.asarray(out)).sum(-1) == 0).sum()
        assert zeros > 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), s=st.sampled_from([8, 16]))
def test_property_ssm_step_matches_forward(seed, s):
    """mamba1 chunked forward == sequential stepping (any chunking)."""
    rng = np.random.default_rng(seed)
    d, di, n, k, dtr = 4, 8, 2, 3, 2
    p = {
        "in_proj": jnp.asarray(rng.standard_normal((d, 2 * di)) * .3,
                               jnp.float32),
        "conv": jnp.asarray(rng.standard_normal((di, k)) * .3, jnp.float32),
        "x_proj": jnp.asarray(rng.standard_normal((di, dtr + 2 * n)) * .3,
                              jnp.float32),
        "dt_proj": jnp.asarray(rng.standard_normal((dtr, di)) * .3,
                               jnp.float32),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.zeros((di, n), jnp.float32),
        "D": jnp.zeros((di,), jnp.float32),
        "out_proj": jnp.asarray(rng.standard_normal((di, d)) * .3,
                                jnp.float32),
    }
    u = jnp.asarray(rng.standard_normal((1, s, d)), jnp.float32)
    y_full = ssm.mamba1_forward(p, u, state=n, chunk=4)
    stt = ssm.MambaState(jnp.zeros((1, k - 1, di)), jnp.zeros((1, di, n)))
    ys = []
    for t in range(s):
        y, stt = ssm.mamba1_step(p, u[:, t], stt, state=n)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_full), atol=1e-5)
