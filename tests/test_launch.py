"""Launch layer: sharding rules, roofline parser, report collation."""
import json
import os
import pathlib
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.launch import roofline as RL
from repro.configs import ARCH_IDS, get_config
from repro.launch.sharding import _fix_spec, _trailing_rule
from repro.models.config import SHAPES_BY_NAME, applicable_shapes


class TestRooflineParser:
    HLO = """
HloModule test
  %pp = f32[56,8,8]{2,1,0} collective-permute(%x), channel_id=1
  %ag = bf16[4096,128]{1,0} all-gather(%y), dimensions={0}
  %ar.start = f32[1024]{0} all-reduce-start(%z)
  %ar.done = f32[1024]{0} all-reduce-done(%ar.start)
  %rs = f32[256]{0} reduce-scatter(%w), dimensions={0}
  %aa = s32[64]{0} all-to-all(%v), dimensions={0}
  %dot = f32[128,128]{1,0} dot(%a, %b)
"""

    def test_collective_bytes(self):
        per, counts = RL.collective_bytes(self.HLO, per_op=True)
        assert per["collective-permute"] == 56 * 8 * 8 * 4
        assert per["all-gather"] == 4096 * 128 * 2
        assert per["all-reduce"] == 1024 * 4      # -start counted, -done not
        assert counts["all-reduce"] == 1
        assert per["reduce-scatter"] == 256 * 4
        assert per["all-to-all"] == 64 * 4

    def test_dot_not_counted(self):
        total = RL.collective_bytes("%d = f32[8,8]{1,0} dot(%a, %b)")
        assert total == 0

    def test_roofline_terms(self):
        rf = RL.Roofline(flops=197e12, hbm_bytes=819e9, coll_bytes=50e9,
                         n_chips=4, hw=RL.Hardware(), model_flops=4e14)
        assert abs(rf.t_compute - 1.0) < 1e-9
        assert abs(rf.t_memory - 1.0) < 1e-9
        assert abs(rf.t_collective - 1.0) < 1e-9
        assert rf.useful_fraction == pytest.approx(4e14 / (197e12 * 4))

    def test_bottleneck_selection(self):
        rf = RL.Roofline(flops=1, hbm_bytes=1e12, coll_bytes=1,
                         n_chips=1, hw=RL.Hardware())
        assert rf.bottleneck == "memory"


class TestShardingRules:
    def test_fix_spec_moves_to_divisible_dim(self):
        mesh = jax.make_mesh((1,), ("model",))

        class FakeMesh:
            shape = {"model": 16}

        spec = _fix_spec(FakeMesh(), (28, 128, 32768, 8, 128),
                         [None, None, None, "model", None])
        # kv=8 not divisible by 16 -> moved to hd=128 (trailing preference)
        assert spec == [None, None, None, None, "model"]

    def test_fix_spec_drops_when_nothing_fits(self):
        class FakeMesh:
            shape = {"model": 16}

        spec = _fix_spec(FakeMesh(), (3, 5), ["model", None])
        assert spec == [None, None]

    def test_trailing_rules_cover_all_param_names(self):
        from repro.models import model as M
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            shapes = M.param_shapes(cfg)

            def walk(d):
                for k, v in d.items():
                    if isinstance(v, dict):
                        walk(v)
                    else:
                        rule = _trailing_rule(cfg, k, v)
                        assert len(rule) <= len(v), (arch, k, v, rule)

            walk(shapes)

    @pytest.mark.parametrize("arch", ["llama3_2_3b", "phi3_5_moe",
                                      "falcon_mamba_7b", "zamba2_2_7b"])
    def test_big_params_are_model_sharded(self, arch):
        """Every >=8M-element param must be sharded on some axis."""
        from repro.models import model as M
        from repro.launch.sharding import param_spec

        class FakeMesh:
            shape = {"model": 16}

        cfg = get_config(arch)
        shapes = M.param_shapes(cfg)

        def walk(d, path=()):
            for k, v in d.items():
                if isinstance(v, dict):
                    walk(v, path + (k,))
                else:
                    n = int(np.prod(v))
                    if n >= (1 << 23):
                        rule = _trailing_rule(cfg, k, v)
                        assert any(r is not None for r in rule), \
                            (arch, k, v)

        walk(shapes)


class TestTrainStepOn8Devices:
    """End-to-end sharded train step on virtual devices (subprocess)."""

    def test_sharded_train_step(self):
        script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.launch.sharding import TrainStep
from repro.models import model as M
from repro.models.config import ShapeSpec
from repro.optim import adamw_init

cfg = get_smoke_config("llama3_2_3b")
mesh = jax.make_mesh((4, 2), ("data", "model"))
shape = ShapeSpec("t", "train", 64, 8)
b = TrainStep(cfg, mesh, zero1=True)
params = M.init_params(cfg, jax.random.PRNGKey(0))
ps = b.param_shardings()
params = jax.tree.map(jax.device_put, params, ps)
opt = adamw_init(params)
opt = jax.device_put(opt, b.opt_shardings())
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 64)),
                               jnp.int32),
         "targets": jnp.asarray(rng.integers(0, cfg.vocab, (8, 64)),
                                jnp.int32)}
batch = jax.device_put(batch, jax.tree.map(lambda s: s.sharding,
                                           b.batch_shardings(shape)))
step = b.jitted(shape, donate=False)
l0 = None
for i in range(4):
    params, opt, metrics = step(params, opt, batch)
    if l0 is None:
        l0 = float(metrics["loss"])
l1 = float(metrics["loss"])
assert np.isfinite(l0) and np.isfinite(l1) and l1 < l0, (l0, l1)
print("OK sharded_train_step", l0, "->", l1)
"""
        env = dict(os.environ)
        env["PYTHONPATH"] = str(pathlib.Path(__file__).parents[1] / "src")
        res = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, env=env,
                             timeout=900)
        assert res.returncode == 0, res.stderr[-2000:]
        assert "OK sharded_train_step" in res.stdout


class TestReport:
    def test_report_tables(self, tmp_path):
        row = {
            "arch": "olmo_1b", "shape": "train_4k", "mesh": "16x16",
            "n_chips": 256, "t_lower_s": 1, "t_compile_s": 8,
            "mem": {"argument_bytes": 1 << 28, "output_bytes": 0,
                    "temp_bytes": 1 << 30,
                    "peak_bytes": (1 << 28) + (1 << 30)},
            "collective_counts": {"all-reduce": 3},
            "roofline": {"t_compute_s": 0.1, "t_memory_s": 0.2,
                         "t_collective_s": 0.05, "bottleneck": "memory",
                         "dev_gflops": 1.0, "dev_hbm_gb": 1.0,
                         "dev_coll_gb": 0.1, "model_gflops": 100.0,
                         "useful_fraction": 0.5, "mfu_bound": 0.1},
        }
        (tmp_path / "olmo.json").write_text(json.dumps(row))
        from repro.launch import report
        rows = report.load(tmp_path)
        t1 = report.dryrun_table(rows)
        t2 = report.roofline_table(rows)
        assert "olmo_1b" in t1 and "memory" in t2
