"""Block-sparse leaf matrix library vs dense numpy (paper §4.1, Fig 2)."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.leaf import (LeafMatrix, LeafStats, leaf_add, leaf_multiply,
                             leaf_scale, leaf_sym_multiply, leaf_sym_square,
                             leaf_syrk, leaf_truncate, multiply_batches)
from repro.core.patterns import (banded_mask, random_mask,
                                 random_symmetric_mask, values_for_mask)


def _mk(n, bs, fill, seed, symmetric=False, upper=False):
    mask = random_mask(n, fill, seed=seed)
    if symmetric:
        mask = mask | mask.T
    a = values_for_mask(mask, seed=seed, symmetric=symmetric)
    return LeafMatrix.from_dense(a, bs, upper=upper), a


class TestRoundtrip:
    @pytest.mark.parametrize("bs", [2, 4, 8])
    def test_dense_roundtrip(self, bs):
        m, a = _mk(32, bs, 0.2, 0)
        np.testing.assert_allclose(m.to_dense(), a)

    def test_upper_roundtrip(self):
        m, a = _mk(32, 4, 0.3, 1, symmetric=True, upper=True)
        np.testing.assert_allclose(m.to_dense(), a)

    def test_zero_blocks_not_stored(self):
        a = np.zeros((32, 32))
        a[0, 0] = 1.0
        m = LeafMatrix.from_dense(a, 4)
        assert m.n_nonzero_blocks() == 1


class TestOps:
    def test_multiply(self):
        ma, a = _mk(32, 4, 0.3, 2)
        mb, b = _mk(32, 4, 0.3, 3)
        st_ = LeafStats()
        c = leaf_multiply(ma, mb, stats=st_)
        np.testing.assert_allclose(c.to_dense(), a @ b, atol=1e-12)
        assert st_.block_multiplies > 0
        assert st_.flops == 2.0 * st_.block_multiplies * 4 ** 3

    @pytest.mark.parametrize("ta,tb", [(True, False), (False, True),
                                       (True, True)])
    def test_multiply_transposed(self, ta, tb):
        ma, a = _mk(32, 4, 0.3, 4)
        mb, b = _mk(32, 4, 0.3, 5)
        c = leaf_multiply(ma, mb, ta=ta, tb=tb)
        ref = (a.T if ta else a) @ (b.T if tb else b)
        np.testing.assert_allclose(c.to_dense(), ref, atol=1e-12)

    def test_add(self):
        ma, a = _mk(32, 4, 0.2, 6)
        mb, b = _mk(32, 4, 0.2, 7)
        np.testing.assert_allclose(leaf_add(ma, mb).to_dense(), a + b)

    def test_add_nil(self):
        ma, a = _mk(32, 4, 0.2, 8)
        assert leaf_add(ma, None) is ma
        assert leaf_add(None, ma) is ma
        assert leaf_add(None, None) is None

    def test_sym_square(self):
        mu, s = _mk(32, 4, 0.3, 9, symmetric=True, upper=True)
        st_ = LeafStats()
        c = leaf_sym_square(mu, stats=st_)
        assert c.upper
        np.testing.assert_allclose(c.to_dense(), s @ s, atol=1e-12)

    def test_sym_square_halves_work(self):
        mu, s = _mk(32, 4, 0.6, 10, symmetric=True, upper=True)
        st_sym = LeafStats()
        leaf_sym_square(mu, stats=st_sym)
        full = LeafMatrix.from_dense(s, 4)
        st_reg = LeafStats()
        leaf_multiply(full, full, stats=st_reg)
        assert st_sym.block_multiplies < 0.75 * st_reg.block_multiplies

    @pytest.mark.parametrize("trans", [False, True])
    def test_syrk(self, trans):
        ma, a = _mk(32, 4, 0.3, 11)
        c = leaf_syrk(ma, trans=trans)
        ref = a.T @ a if trans else a @ a.T
        np.testing.assert_allclose(c.to_dense(), ref, atol=1e-12)

    @pytest.mark.parametrize("side", ["left", "right"])
    def test_sym_multiply(self, side):
        ms, s = _mk(32, 4, 0.3, 12, symmetric=True, upper=True)
        mb, b = _mk(32, 4, 0.3, 13)
        c = leaf_sym_multiply(ms, mb, side=side)
        ref = s @ b if side == "left" else b @ s
        np.testing.assert_allclose(c.to_dense(), ref, atol=1e-12)

    def test_scale(self):
        ma, a = _mk(32, 4, 0.2, 14)
        np.testing.assert_allclose(leaf_scale(ma, -2.5).to_dense(), -2.5 * a)

    def test_truncate_frobenius(self):
        """§6.2: dropped blocks' Frobenius norm stays within tau."""
        ma, a = _mk(32, 4, 0.5, 15)
        tau = 0.5 * np.linalg.norm(a, "fro")
        t = leaf_truncate(ma, tau)
        err = np.linalg.norm(t.to_dense() - a, "fro")
        assert err <= tau + 1e-12
        assert t.n_nonzero_blocks() < ma.n_nonzero_blocks()


class TestBatchedSchedule:
    """Fig 2: multiplication as a sum of outer products; within-batch
    independence (no two multiplies in a batch write the same C block)."""

    def test_batches_cover_all_products(self):
        ma, a = _mk(32, 4, 0.4, 16)
        mb, b = _mk(32, 4, 0.4, 17)
        prods = set()
        for batch in multiply_batches(ma, mb):
            for (i, j, k) in batch:
                assert (i, k) in ma.blocks and (k, j) in mb.blocks
                prods.add((i, j, k))
        expect = {(i, j, k)
                  for (i, k) in ma.blocks for (k2, j) in mb.blocks
                  if k2 == k}
        assert prods == expect

    def test_within_batch_outputs_distinct(self):
        ma, _ = _mk(32, 4, 0.5, 18)
        mb, _ = _mk(32, 4, 0.5, 19)
        for batch in multiply_batches(ma, mb):
            outs = [(i, j) for (i, j, k) in batch]
            assert len(outs) == len(set(outs))


@settings(max_examples=25, deadline=None)
@given(
    bs=st.sampled_from([2, 4]),
    grid=st.integers(2, 6),
    fill=st.floats(0.05, 0.9),
    seed=st.integers(0, 2 ** 16),
)
def test_property_multiply_matches_dense(bs, grid, fill, seed):
    n = bs * grid
    a = values_for_mask(random_mask(n, fill, seed=seed), seed=seed)
    b = values_for_mask(random_mask(n, fill, seed=seed + 1), seed=seed + 1)
    ma = LeafMatrix.from_dense(a, bs)
    mb = LeafMatrix.from_dense(b, bs)
    np.testing.assert_allclose(leaf_multiply(ma, mb).to_dense(), a @ b,
                               atol=1e-10)


@settings(max_examples=25, deadline=None)
@given(
    grid=st.integers(2, 6),
    fill=st.floats(0.05, 0.9),
    seed=st.integers(0, 2 ** 16),
)
def test_property_sym_square_matches_dense(grid, fill, seed):
    bs = 4
    n = bs * grid
    s = values_for_mask(random_symmetric_mask(n, fill, seed=seed),
                        seed=seed, symmetric=True)
    mu = LeafMatrix.from_dense(s, bs, upper=True)
    np.testing.assert_allclose(leaf_sym_square(mu).to_dense(), s @ s,
                               atol=1e-10)
