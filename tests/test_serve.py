"""Plan-serving subsystem tests (DESIGN.md §9).

Pins the serving contracts:
* interleaved concurrent requests return exactly the answers serial
  execution returns (numpy and pallas engines);
* cross-plan coalesced waves are numerically identical to uncoalesced
  per-plan flushing;
* admission control rejects with machine-readable reasons;
* cache hit/evict accounting: shared-cache reuse after warmup, zero new
  task registrations, LRU bounds on the per-session plan caches, and
  ``recompile=True`` successors landing in the shared cache.
"""
import numpy as np
import pytest

from repro import Session
from repro.api.lru import LRUCache
from repro.serve import (AdmissionError, PlanServer, Request, ServeConfig,
                         SharedPlanCache, WaveCoalescer)

LEAF, BS = 16, 4
TOL = dict(atol=1e-4, rtol=1e-4)    # pallas packs float32; numpy is float64


def _mats(n=32, k=3, seed=0):
    rng = np.random.default_rng(seed)
    return {f"M{i}": rng.standard_normal((n, n)) for i in range(k)}


def _x0(n=32, seed=1):
    """A dense symmetric iterate with eigenvalues in [0, 1]."""
    rng = np.random.default_rng(seed)
    h = rng.standard_normal((n, n))
    h = (h + h.T) / 2
    w, v = np.linalg.eigh(h)
    return v @ np.diag((w.max() - w) / (w.max() - w.min())) @ v.T


def _server(engine="pallas", **kw):
    cfg = dict(engine=engine, n_sessions=2, max_inflight=4, max_queue=32,
               leaf_n=LEAF, bs=BS)
    cfg.update(kw)
    return PlanServer(ServeConfig(**cfg))


def _serve_serial(mats, reqs, engine):
    """Reference: each request served alone in a fresh single-slot server."""
    out = []
    for r in reqs:
        srv = _server(engine=engine, n_sessions=1, max_inflight=1)
        for nm, a in mats.items():
            srv.register(nm, a)
        t = srv.submit(r)
        srv.drain()
        assert t.done, t.error
        out.append(t.result)
    return out


class TestDeterminism:
    @pytest.mark.parametrize("engine", ["numpy", "pallas"])
    def test_interleaved_equals_serial(self, engine):
        """Concurrent batched serving returns the serial answers exactly."""
        mats = _mats()
        names = sorted(mats)
        reqs = [Request.multiply(a, b)
                for a in names for b in names][:6]
        serial = _serve_serial(mats, reqs, engine)

        srv = _server(engine=engine)
        for nm, a in mats.items():
            srv.register(nm, a)
        tickets = [srv.submit(r) for r in reqs]
        srv.drain()
        for t, want in zip(tickets, serial):
            assert t.done, t.error
            np.testing.assert_array_equal(t.result, want)

    @pytest.mark.pallas
    def test_coalesced_pinned_to_uncoalesced(self):
        """Cross-plan merged waves change nothing numerically (bitwise)."""
        mats = _mats()
        reqs = [Request.multiply("M0", "M1"), Request.multiply("M1", "M2"),
                Request.multiply("M2", "M0"), Request.multiply("M0", "M0")]
        serial = _serve_serial(mats, reqs, "pallas")

        srv = _server(max_inflight=4)
        for nm, a in mats.items():
            srv.register(nm, a)
        tickets = [srv.submit(r) for r in reqs]
        srv.drain()
        assert srv.coalescer.merged_waves > 0, \
            "expected cross-plan wave coalescing in a full batch"
        for t, want in zip(tickets, serial):
            np.testing.assert_array_equal(t.result, want)

    @pytest.mark.parametrize("engine", ["numpy", "pallas"])
    def test_sp2_matches_reference_recurrence(self, engine):
        """The per-ticket SP2 state machine equals the float64 recurrence."""
        n = 32
        x0 = _x0(n)
        ne, iters = 10.0, 3
        # float64 reference of the same trace-branching polynomial; assert
        # every branch decision has a margin far above float32 trace noise
        # so the served float32 iterates take the same branches
        x = x0
        for _ in range(iters):
            tr = np.trace(x)
            assert abs(tr - ne) > 0.05, "degenerate test: trace at threshold"
            x = x @ x if tr > ne else 2 * x - x @ x

        srv = _server(engine=engine)
        srv.register("X", x0)
        t = srv.submit(Request.sp2("X", ne=ne, iters=iters))
        srv.drain()
        assert t.done, t.error
        np.testing.assert_allclose(t.result, x, atol=1e-3, rtol=1e-3)
        assert len(t.replay_s) >= iters     # one unit per polynomial term

    def test_mixed_workload_converges(self):
        """Multiply and sp2 requests interleave in one server."""
        n = 32
        mats = _mats(n)
        x0 = _x0(n)
        srv = _server()
        for nm, a in mats.items():
            srv.register(nm, a)
        srv.register("X", x0)
        tm = srv.submit(Request.multiply("M0", "M1"))
        ts = srv.submit(Request.sp2("X", ne=n / 2, iters=5))
        tm2 = srv.submit(Request.multiply("M2", "M2"))
        srv.drain()
        assert tm.done and ts.done and tm2.done
        np.testing.assert_allclose(tm.result, mats["M0"] @ mats["M1"], **TOL)
        np.testing.assert_allclose(tm2.result, mats["M2"] @ mats["M2"],
                                   **TOL)
        # purification drives the iterate toward idempotency (X² ~ X)
        err = np.linalg.norm(ts.result @ ts.result - ts.result)
        assert err < np.linalg.norm(x0 @ x0 - x0)


class TestAdmission:
    def test_queue_full_rejects_with_reason(self):
        mats = _mats()
        srv = _server(max_queue=3)
        for nm, a in mats.items():
            srv.register(nm, a)
        for _ in range(3):
            srv.submit(Request.multiply("M0", "M1"))
        with pytest.raises(AdmissionError) as ei:
            srv.submit(Request.multiply("M0", "M1"))
        assert ei.value.reason == "queue_full"
        assert srv.counters["rejected"] == 1
        srv.drain()                             # queued work still completes
        assert srv.counters["completed"] == 3

    def test_unknown_matrix_rejects(self):
        srv = _server()
        with pytest.raises(AdmissionError) as ei:
            srv.submit(Request.multiply("nope", "nada"))
        assert ei.value.reason == "unknown_matrix"

    def test_bad_request_rejects(self):
        srv = _server()
        srv.register("A", np.eye(32))
        with pytest.raises(AdmissionError) as ei:
            srv.submit(Request.sp2("A", ne=1.0, iters=0))
        assert ei.value.reason == "bad_request"
        with pytest.raises(AdmissionError) as ei:
            srv.submit(Request(kind="frobnicate"))
        assert ei.value.reason == "bad_request"

    def test_max_inflight_bounds_batch(self):
        mats = _mats()
        srv = _server(max_inflight=2)
        for nm, a in mats.items():
            srv.register(nm, a)
        tickets = [srv.submit(Request.multiply("M0", "M1"))
                   for _ in range(5)]
        srv.step()
        assert sum(1 for t in tickets if t.status != "queued") == 2
        srv.drain()
        assert all(t.done for t in tickets)


class TestCacheAccounting:
    def test_shared_cache_hits_after_warmup_zero_new_tasks(self):
        mats = _mats()
        srv = _server()
        for nm, a in mats.items():
            srv.register(nm, a)
        reqs = [Request.multiply("M0", "M1"), Request.multiply("M1", "M2")]
        for r in reqs:
            srv.submit(r)
        srv.drain()
        warm_tasks = srv.task_count()
        h0 = srv.cache.counters()["hits"]
        tickets = [srv.submit(r) for r in reqs * 3]
        srv.drain()
        assert all(t.done for t in tickets)
        assert srv.task_count() == warm_tasks, "warm requests registered tasks"
        assert srv.cache.counters()["hits"] > h0
        assert all(t.cache_hits >= 1 and t.cache_misses == 0
                   for t in tickets)

    def test_session_plan_cache_lru_bounds_and_metrics(self):
        sess = Session(lazy=True, leaf_n=LEAF, bs=BS, plan_cache_cap=2)
        rng = np.random.default_rng(0)
        ms = [sess.from_dense(rng.standard_normal((32, 32)))
              for _ in range(3)]
        plans = [sess.compile(m @ m) for m in ms]
        assert len(sess._plans) == 2            # LRU evicted the oldest
        assert sess._plans.evictions == 1
        assert sess.compile(ms[1] @ ms[1]) is plans[1]   # still cached
        pc = next(m for m in sess.metrics() if m.source == "plan-cache")
        assert pc["plan_cache_evictions"].total == 1
        assert pc["plan_cache_hits"].total >= 1

    def test_eager_session_metrics_unchanged(self):
        """Plan-cache counters appear only once the cache is touched."""
        sess = Session(leaf_n=LEAF, bs=BS)
        a = sess.from_dense(np.eye(32))
        (a @ a).to_dense()
        assert [m.source for m in sess.metrics()] == ["engine:numpy"]

    def test_recompiled_successors_register_in_shared_cache(self):
        """plan.run(recompile=True) plans land in the cross-session cache."""
        sess = Session(lazy=True, leaf_n=LEAF, bs=BS)
        cache = SharedPlanCache()
        cache.attach(sess)
        rng = np.random.default_rng(0)
        # compiled structure: single top-left leaf; the dense rebind
        # below cannot fit it, forcing the recompile path
        sparse = np.zeros((32, 32))
        sparse[:LEAF, :LEAF] = rng.standard_normal((LEAF, LEAF))
        x = sess.from_dense(sparse, name="X")
        plan = sess.compile(x @ x)
        plan.run()
        n_keys = len(cache)
        dense = rng.standard_normal((32, 32))
        out = plan.run(X=dense, recompile=True)
        np.testing.assert_allclose(out.to_dense(), dense @ dense, atol=1e-10)
        assert len(plan._recompiled) == 1
        succ = next(iter(plan._recompiled.values()))
        assert len(cache) == n_keys + 1
        assert succ in cache.lookup(succ.struct_key)

    def test_recompiled_cache_is_bounded(self):
        from repro.api.plan import RECOMPILED_CAP
        n = 64                      # 4x4 leaf grid
        sess = Session(lazy=True, leaf_n=LEAF, bs=BS)

        def leaf_pattern(pos, val):
            v = np.zeros((n, n))
            v[:LEAF, :LEAF] = val   # (0,0) always set: X @ X stays nonzero
            i, j = pos
            v[i * LEAF:(i + 1) * LEAF, j * LEAF:(j + 1) * LEAF] = val
            return v

        x = sess.from_dense(leaf_pattern((3, 3), 1.0), name="X")
        plan = sess.compile(x @ x)
        plan.run()
        # every rebind occupies a leaf outside the compiled structure and
        # outside every earlier successor's structure -> a fresh successor
        # each run, so the LRU cap is what bounds the set
        for k in range(RECOMPILED_CAP + 3):
            pos = divmod(k + 1, 4)          # (0,1)..(3,0), never (0,0)/(3,3)
            plan.run(X=leaf_pattern(pos, 1.0 + k), recompile=True)
        assert len(plan._recompiled) == RECOMPILED_CAP

    def test_lru_cache_primitive(self):
        evicted = []
        c = LRUCache(cap=2, on_evict=lambda k, v: evicted.append(k))
        c.put("a", 1)
        c.put("b", 2)
        assert c.get("a") == 1                  # refreshes recency
        c.put("c", 3)                           # evicts b (LRU)
        assert evicted == ["b"]
        assert c.get("b") is None
        assert set(c.keys()) == {"a", "c"}
        assert c.counters() == {"hits": 1, "misses": 1, "evictions": 1,
                                "size": 2, "cap": 2}
        assert c.setdefault("a", 99) == 1       # no overwrite
        c2 = LRUCache(cap=0)                    # unbounded
        for i in range(100):
            c2.put(i, i)
        assert len(c2) == 100 and c2.evictions == 0

    def test_setdefault_refreshes_recency(self):
        """Regression: setdefault on an existing key used to leave it at
        its stale slot, so a hot entry re-touched only through setdefault
        was the first one evicted under cap pressure."""
        c = LRUCache(cap=2)
        c.put("hot", 1)
        c.put("b", 2)
        assert c.setdefault("hot", 99) == 1     # touch via setdefault only
        c.put("c", 3)                           # cap pressure evicts LRU
        assert "hot" in c and "b" not in c
        assert c.peek("hot") == 1
        # refresh must not perturb the hit/miss counters
        assert c.counters()["hits"] == 0 and c.counters()["misses"] == 0


class TestTargetedFlush:
    @pytest.mark.pallas
    def test_rebind_flushes_only_entangled_leaves(self):
        """Rebinding one plan's input leaves another plan's waves pending."""
        sess = Session(engine="pallas", lazy=True, leaf_n=LEAF, bs=BS)
        rng = np.random.default_rng(0)
        a = sess.from_dense(rng.standard_normal((32, 32)), name="A")
        b = sess.from_dense(rng.standard_normal((32, 32)), name="B")
        pa = sess.compile(a @ a)
        pb = sess.compile(b @ b)
        pa.run()
        pb.run()
        sess.flush()
        # defer pa's replay, then rebind pb's *unrelated* input: the
        # engine must keep pa's waves pending for coalescing
        va = rng.standard_normal((32, 32))
        vb = rng.standard_normal((32, 32))
        out_a = pa.run(A=va, flush=False)
        eng = sess.graph.engine
        assert eng._pending, "replay should have deferred kernel work"
        n_pending = len(eng._pending)
        out_b = pb.run(B=vb, flush=False)
        assert len(eng._pending) > n_pending, \
            "rebinding an unrelated plan's input flushed foreign waves"
        sess.flush()
        np.testing.assert_allclose(out_a.to_dense(), va @ va, **TOL)
        np.testing.assert_allclose(out_b.to_dense(), vb @ vb, **TOL)

    @pytest.mark.pallas
    def test_deferred_run_readback_correct(self):
        """flush=False + explicit flush computes the same values."""
        sess = Session(engine="pallas", lazy=True, leaf_n=LEAF, bs=BS)
        rng = np.random.default_rng(0)
        v = rng.standard_normal((32, 32))
        x = sess.from_dense(v, name="X")
        plan = sess.compile(x @ x)
        ref = plan.run().to_dense()
        v2 = rng.standard_normal((32, 32))
        out = plan.run(X=v2, flush=False)
        sess.flush()
        np.testing.assert_allclose(out.to_dense(), v2 @ v2, **TOL)
        out3 = plan.run(X=v).to_dense()         # same values -> same bits
        np.testing.assert_array_equal(out3, ref)


class TestCoalescerUnit:
    @pytest.mark.pallas
    def test_coalescer_merges_across_sessions(self):
        """Two sessions' deferred waves become one fused dispatch."""
        rng = np.random.default_rng(0)
        sessions = [Session(engine="pallas", lazy=True, leaf_n=LEAF, bs=BS)
                    for _ in range(2)]
        plans, vals = [], []
        for sess in sessions:
            v = rng.standard_normal((32, 32))
            x = sess.from_dense(v, name="X")
            p = sess.compile(x @ x)
            p.run()
            sess.flush()
            plans.append(p)
            vals.append(v)
        outs = [p.run(X=v, flush=False) for p, v in zip(plans, vals)]
        co = WaveCoalescer()
        assert co.flush([s.graph for s in sessions]) >= 1
        assert co.merged_waves >= 1, "same batch_key should merge"
        assert co.merged_tasks >= 2
        for out, v in zip(outs, vals):
            np.testing.assert_allclose(out.to_dense(), v @ v, **TOL)

    def test_coalescer_handles_numpy_graphs(self):
        """Immediate engines pass through the coalescer unharmed."""
        sess = Session(leaf_n=LEAF, bs=BS)
        a = sess.from_dense(np.eye(32))
        c = a @ a
        co = WaveCoalescer()
        assert co.flush([sess.graph]) == 0
        np.testing.assert_array_equal(c.to_dense(), np.eye(32))


class TestServeObservability:
    def test_request_and_batch_spans(self):
        mats = _mats()
        srv = _server(trace=True)
        for nm, a in mats.items():
            srv.register(nm, a)
        t = srv.submit(Request.multiply("M0", "M1"))
        srv.drain()
        names = [s.name for s in srv.tracer.spans]
        assert "serve.batch" in names
        req_spans = [s for s in srv.tracer.spans
                     if s.name == "serve.request"]
        assert len(req_spans) == 1
        at = req_spans[0].attrs
        assert at["status"] == "done" and at["kind"] == "multiply"
        assert at["bytes"] == t.bytes > 0
        assert at["cache_misses"] == 1

    def test_server_metrics_schema(self):
        from repro.obs.metrics import validate_metrics
        mats = _mats()
        srv = _server()
        for nm, a in mats.items():
            srv.register(nm, a)
        srv.submit(Request.multiply("M0", "M1"))
        srv.drain()
        sets = srv.metrics()
        sources = [m.source for m in sets]
        assert "serve" in sources and "serve-cache" in sources \
            and "serve-coalescer" in sources
        for ms in sets:
            validate_metrics(ms.to_dict())
        serve = next(m for m in sets if m.source == "serve")
        assert serve["requests_completed"].total == 1

    def test_ticket_accounting(self):
        mats = _mats()
        srv = _server()
        for nm, a in mats.items():
            srv.register(nm, a)
        t1 = srv.submit(Request.multiply("M0", "M1"))
        srv.drain()
        t2 = srv.submit(Request.multiply("M0", "M1"))
        srv.drain()
        assert t1.cache_misses == 1 and t1.compile_s > 0
        assert t2.cache_hits == 1 and t2.compile_s == 0
        assert t1.latency_s > 0 and t2.latency_s > 0
        assert t2.replay_s and t1.batches == t2.batches == 1


class TestSolverServing:
    """PR 10 satellites: congruence requests and replica pre-warming."""

    @pytest.mark.parametrize("engine", ["numpy", "pallas"])
    def test_congruence_request(self, engine):
        n = 32
        rng = np.random.default_rng(7)
        z = np.triu(0.1 * rng.standard_normal((n, n)) + np.eye(n))
        f = rng.standard_normal((n, n))
        f = (f + f.T) / 2
        srv = _server(engine=engine)
        srv.register("Z", z)
        srv.register("F", f)
        t = srv.submit(Request.congruence("Z", "F"))
        srv.drain()
        assert t.done, t.error
        np.testing.assert_allclose(t.result, z.T @ f @ z, **TOL)

    def test_congruence_unknown_matrix_rejected(self):
        srv = _server()
        with pytest.raises(AdmissionError) as ei:
            srv.submit(Request.congruence("Z", "F"))
        assert ei.value.reason == "unknown_matrix"

    @pytest.mark.parametrize("engine", ["numpy", "pallas"])
    def test_prewarm_zero_cold_compiles(self, engine):
        """With prewarm=True, registration compiles one replica of the
        iterate shapes per pooled session: SP2 traffic then never pays a
        cold compile, on any session the batch loop picks."""
        x0 = _x0()
        warm = _server(engine=engine, prewarm=True)
        warm.register("X", x0)
        tickets = [warm.submit(Request.sp2("X", ne=16.0, iters=3))
                   for _ in range(3)]
        warm.drain()
        assert all(t.done for t in tickets)
        assert warm.counters["cold_compiles"] == 0
        assert all(t.compile_s == 0.0 for t in tickets)
        # the same traffic on a cold server pays at least one compile
        cold = _server(engine=engine, prewarm=False)
        cold.register("X", x0)
        t = cold.submit(Request.sp2("X", ne=16.0, iters=3))
        cold.drain()
        assert t.done, t.error
        assert cold.counters["cold_compiles"] >= 1

    def test_prewarm_matches_cold_results(self):
        x0 = _x0()
        results = []
        for pw in (False, True):
            srv = _server(engine="numpy", prewarm=pw, n_sessions=1,
                          max_inflight=1)
            srv.register("X", x0)
            t = srv.submit(Request.sp2("X", ne=12.0, iters=4))
            srv.drain()
            assert t.done, t.error
            results.append(t.result)
        np.testing.assert_allclose(results[0], results[1], atol=0, rtol=0)
