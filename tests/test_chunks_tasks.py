"""Chunks-and-Tasks runtime semantics + work-stealing cluster simulation."""
import numpy as np

from repro.core.chunks import ChunkStore, ChunkId
from repro.core.tasks import CTGraph, ClusterSim, CostModel
from repro.core.quadtree import QTParams, qt_from_dense, qt_to_dense
from repro.core.multiply import qt_multiply, qt_sym_square
from repro.core.patterns import banded_mask, values_for_mask


class _Blob:
    def __init__(self, nb):
        self._nb = nb

    def nbytes(self):
        return self._nb


class TestChunkStore:
    def test_owner_embedded_in_id(self):
        st = ChunkStore(4)
        cid = st.register(2, _Blob(100))
        assert isinstance(cid, ChunkId)
        assert cid.owner == 2

    def test_register_is_local_no_comm(self):
        st = ChunkStore(4)
        st.register(1, _Blob(1000))
        assert st.total_bytes_received() == 0

    def test_remote_fetch_accounted_once_with_cache(self):
        st = ChunkStore(2)
        cid = st.register(0, _Blob(512))
        st.fetch(1, cid)
        st.fetch(1, cid)  # cache hit
        assert st.stats[1].bytes_received == 512
        assert st.stats[1].messages_received == 1
        assert st.stats[1].cache_hits == 1

    def test_local_fetch_free(self):
        st = ChunkStore(2)
        cid = st.register(0, _Blob(512))
        st.fetch(0, cid)
        assert st.stats[0].bytes_received == 0
        assert st.stats[0].bytes_received_local == 512

    def test_cache_eviction_lru(self):
        st = ChunkStore(2, cache_bytes=1000)
        a = st.register(0, _Blob(600))
        b = st.register(0, _Blob(600))
        st.fetch(1, a)
        st.fetch(1, b)   # evicts a
        st.fetch(1, a)   # re-fetch: comm again
        assert st.stats[1].bytes_received == 1800

    def test_nil_fetch_returns_none(self):
        st = ChunkStore(1)
        assert st.fetch(0, None) is None

    def test_free_invalidates_remote_caches(self):
        """free() must drop cached copies everywhere: stale entries pinned
        _cache_used forever and could serve wrong bytes on id reuse."""
        st = ChunkStore(3, cache_bytes=10_000)
        cid = st.register(0, _Blob(600))
        st.fetch(1, cid)
        st.fetch(2, cid)
        assert st.cache_used(1) == 600 and st.cache_used(2) == 600
        st.free(cid)
        assert st.cache_used(1) == 0 and st.cache_used(2) == 0
        assert st.stats[0].owned_bytes == 0

    def test_free_then_eviction_reaccounts(self):
        """Post-free, the cache budget is actually available again: a new
        chunk fits without evicting, and a re-fetch re-accounts comm."""
        st = ChunkStore(2, cache_bytes=1000)
        a = st.register(0, _Blob(600))
        st.fetch(1, a)
        st.free(a)                      # cache slot reclaimed
        b = st.register(0, _Blob(600))
        c = st.register(0, _Blob(300))
        st.fetch(1, b)
        st.fetch(1, c)                  # both fit: 900 <= 1000, no evict
        assert st.cache_used(1) == 900
        st.fetch(1, b), st.fetch(1, c)  # cache hits, no extra comm
        assert st.stats[1].bytes_received == 600 + 600 + 300
        assert st.stats[1].cache_hits == 2

    def test_register_pushed_accounts_owner_reception(self):
        """Placement away from the creator ships the data to the owner."""
        st = ChunkStore(2)
        cid = st.register_pushed(0, 1, _Blob(512))
        assert cid.owner == 1
        assert st.stats[1].bytes_received == 512
        assert st.stats[1].bytes_pushed == 512
        assert st.stats[1].messages_received == 1
        # the creator keeps a cached copy: its own fetch is free
        st.fetch(0, cid)
        assert st.stats[0].bytes_received == 0
        assert st.stats[0].cache_hits == 1

    def test_register_pushed_local_is_plain_register(self):
        st = ChunkStore(2)
        cid = st.register_pushed(1, 1, _Blob(512))
        assert cid.owner == 1
        assert st.stats[1].bytes_received == 0
        assert st.stats[1].bytes_pushed == 0

    def test_peak_owned_tracks_frees(self):
        st = ChunkStore(1)
        a = st.register(0, _Blob(100))
        b = st.register(0, _Blob(200))
        st.free(a)
        c = st.register(0, _Blob(50))
        assert st.stats[0].peak_owned_bytes == 300
        assert st.stats[0].owned_bytes == 250
        st.free(b), st.free(c)
        assert st.stats[0].owned_bytes == 0


def _build_and_multiply(n=128, d=5, p=4, seed=0):
    params = QTParams(n, 16, 4)
    a = values_for_mask(banded_mask(n, d), seed=1)
    g = CTGraph()
    ra = qt_from_dense(g, a, params)
    rb = qt_from_dense(g, a, params)
    sim = ClusterSim(p, seed=seed)
    sim.run(g)           # build phase places input chunks
    sim.reset_stats()
    n_build = len(g.nodes)
    rc = qt_multiply(g, params, ra, rb)
    res = sim.run(g)     # multiply phase
    return g, params, a, rc, sim, res, n_build


class TestClusterSim:
    def test_all_tasks_executed(self):
        g, _, _, _, _, res, n_build = _build_and_multiply()
        assert sum(res.tasks_per_worker) == len(g.nodes) - n_build

    def test_correctness_independent_of_schedule(self):
        g, params, a, rc, _, _, _ = _build_and_multiply(seed=0)
        out = qt_to_dense(g, rc, params)
        np.testing.assert_allclose(out, a @ a, atol=1e-12)

    def test_single_worker_no_comm(self):
        _, _, _, _, _, res, _ = _build_and_multiply(p=1)
        assert res.bytes_received == [0]
        assert res.steals == 0

    def test_multi_worker_balances_work(self):
        _, _, _, _, _, res, _ = _build_and_multiply(n=256, p=4)
        t = res.tasks_per_worker
        assert min(t) > 0            # everyone got work via stealing
        assert res.steals > 0

    def test_makespan_shrinks_with_workers(self):
        _, _, _, _, _, r1, _ = _build_and_multiply(n=256, p=1)
        _, _, _, _, _, r8, _ = _build_and_multiply(n=256, p=8)
        assert r8.makespan < r1.makespan

    def test_comm_deterministic_given_seed(self):
        _, _, _, _, _, ra, _ = _build_and_multiply(seed=7)
        _, _, _, _, _, rb, _ = _build_and_multiply(seed=7)
        assert ra.bytes_received == rb.bytes_received
        assert ra.makespan == rb.makespan

    def test_chunk_placement_follows_execution(self):
        """Chunks are owned by the worker that ran the producing task."""
        g, params, a, rc, sim, _, _ = _build_and_multiply()
        for nid, cid in sim.placement.items():
            owner_node = sim._owner_of_node[g.resolve(nid)]
            assert cid.owner == owner_node

    def test_symmetric_square_in_sim(self):
        n = 128
        params = QTParams(n, 16, 4)
        s = values_for_mask(banded_mask(n, 5), seed=2, symmetric=True)
        g = CTGraph()
        rs = qt_from_dense(g, s, params, upper=True)
        sim = ClusterSim(4)
        sim.run(g)
        rc = qt_sym_square(g, params, rs)
        sim.run(g)
        np.testing.assert_allclose(qt_to_dense(g, rc, params), s @ s,
                                   atol=1e-12)

    def test_cost_model_fields(self):
        cm = CostModel(flops_per_s=1e9, task_overhead_s=0.0)
        _, _, _, _, _, res, _ = _build_and_multiply()
        assert res.makespan > 0
        assert all(0 <= f <= 1.0 + 1e-9 for f in res.active_fraction)
