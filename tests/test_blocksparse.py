"""TPU block-sparse engine: packing, mask pyramid, pair enumeration, bsmm."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import blocksparse as bsp
from repro.core.bsmm import (bsmm, bsmm_from_dense, compute_c_structure,
                             pair_counts_per_level, useful_flops)
from repro.core.patterns import (banded_mask, block_mask_from_element_mask,
                                 random_mask, values_for_mask)


def _dense(n, pattern, seed):
    return values_for_mask(pattern, seed=seed).astype(np.float32)


def _pack(a, bs, cap):
    return bsp.from_dense(jnp.asarray(a), bs, cap)


class TestFormat:
    @pytest.mark.parametrize("bs", [4, 8])
    def test_roundtrip(self, bs):
        a = _dense(64, banded_mask(64, 6), 0)
        m = _pack(a, bs, 200)
        np.testing.assert_allclose(bsp.to_dense(m), a)

    def test_nnzb_counts_occupied(self):
        a = _dense(64, banded_mask(64, 3), 1)
        m = _pack(a, 8, 64)
        occ = block_mask_from_element_mask(np.abs(a) > 0, 8)
        assert int(m.nnzb) == occ.sum()

    def test_slot_map_consistent(self):
        a = _dense(64, random_mask(64, 0.1, seed=2), 2)
        m = _pack(a, 8, 64)
        slot = np.asarray(m.slot)
        rows, cols = np.asarray(m.rows), np.asarray(m.cols)
        for s in range(int(m.nnzb)):
            assert slot[rows[s], cols[s]] == s
        # padding coordinates resolve to -1
        assert (slot[-1, :] == -1).all() and (slot[:, -1] == -1).all()

    def test_capacity_padding_zero(self):
        a = _dense(32, banded_mask(32, 2), 3)
        m = _pack(a, 8, 50)
        blocks = np.asarray(m.blocks)
        assert np.all(blocks[int(m.nnzb):] == 0)

    def test_from_blocks(self):
        bs, grid = 4, 4
        rows, cols = np.array([0, 2]), np.array([1, 3])
        blocks = jnp.asarray(np.random.default_rng(0).standard_normal(
            (2, bs, bs)), jnp.float32)
        m = bsp.from_blocks(rows, cols, blocks, grid, cap=8)
        d = np.asarray(bsp.to_dense(m))
        np.testing.assert_allclose(d[0:4, 4:8], blocks[0])
        np.testing.assert_allclose(d[8:12, 12:16], blocks[1])
        assert (d != 0).sum() == (np.asarray(blocks) != 0).sum()

    def test_jit_from_dense(self):
        f = jax.jit(lambda x: bsp.from_dense(x, 8, 64).nnzb)
        a = _dense(64, banded_mask(64, 3), 4)
        assert int(f(jnp.asarray(a))) > 0


class TestMaskPyramid:
    def test_pyramid_levels(self):
        mask = jnp.zeros((8, 8), bool).at[3, 5].set(True)
        pyr = bsp.mask_pyramid(mask)
        assert [p.shape[0] for p in pyr] == [8, 4, 2, 1]
        assert bool(pyr[1][1, 2])    # (3//2, 5//2)
        assert bool(pyr[2][0, 1])
        assert bool(pyr[3][0, 0])
        assert int(pyr[1].sum()) == 1

    def test_pyramid_is_quadtree_nil_structure(self):
        """False at a coarse level == NIL chunk for the whole subtree."""
        mask = np.zeros((8, 8), bool)
        mask[:4, :4] = np.random.default_rng(0).random((4, 4)) < 0.5
        mask[0, 0] = True
        pyr = bsp.mask_pyramid(jnp.asarray(mask))
        assert not bool(pyr[2][0, 1])  # right half entirely NIL
        assert not bool(pyr[2][1, 0])
        assert not bool(pyr[2][1, 1])


class TestPairEnumeration:
    def _masks(self, n, bs, seed):
        a = random_mask(n, 0.15, seed=seed)
        b = random_mask(n, 0.15, seed=seed + 1)
        return (block_mask_from_element_mask(a, bs),
                block_mask_from_element_mask(b, bs))

    def test_hier_matches_flat(self):
        ma, mb = self._masks(64, 4, 0)
        caps = bsp.plan_caps(ma, mb, slack=2.0)
        ph, ch = bsp.enumerate_pairs_hier(jnp.asarray(ma), jnp.asarray(mb),
                                          caps)
        pf, cf = bsp.enumerate_pairs_flat(jnp.asarray(ma), jnp.asarray(mb),
                                          caps[-1])
        assert int(ch) == int(cf)
        sh = {tuple(r) for r in np.asarray(ph)[:int(ch)]}
        sf = {tuple(r) for r in np.asarray(pf)[:int(cf)]}
        assert sh == sf

    def test_counts_match_plan(self):
        """Surviving triples per level == the paper's task counts."""
        ma, mb = self._masks(64, 4, 3)
        per = pair_counts_per_level(ma, mb)
        # leaf level exact count = sum_k colA_k rowB_k
        exact = int((ma.sum(0).astype(np.int64) * mb.sum(1)).sum())
        assert per[max(per)] == exact

    def test_empty_masks(self):
        g = 8
        z = jnp.zeros((g, g), bool)
        caps = [8] * 3
        pairs, cnt = bsp.enumerate_pairs_hier(z, z, caps)
        assert int(cnt) == 0

    def test_overflow_truncates_deterministically(self):
        ma, mb = self._masks(64, 4, 5)
        caps = bsp.plan_caps(ma, mb)
        caps[-1] = 64  # force overflow at leaf level
        pairs, cnt = bsp.enumerate_pairs_hier(jnp.asarray(ma),
                                              jnp.asarray(mb), caps)
        assert pairs.shape[0] == 64
        assert int(cnt) > 64  # reports the true count for overflow detection


class TestBsmm:
    def _run(self, n, bs, pa, pb, hierarchical=True, use_pair_kernel=False):
        a = values_for_mask(pa, seed=0).astype(np.float32)
        b = values_for_mask(pb, seed=1).astype(np.float32)
        ma = block_mask_from_element_mask(np.abs(a) > 0, bs)
        mb = block_mask_from_element_mask(np.abs(b) > 0, bs)
        caps = bsp.plan_caps(ma, mb)
        cap_c = bsp.plan_c_cap(ma, mb)
        cap_ab = max(int(ma.sum()), int(mb.sum()), 8)
        A = _pack(a, bs, cap_ab)
        B = _pack(b, bs, cap_ab)
        c, info = bsmm(A, B, pair_caps=caps, cap_c=cap_c,
                       hierarchical=hierarchical,
                       use_pair_kernel=use_pair_kernel,
                       interpret=use_pair_kernel)
        return np.asarray(bsp.to_dense(c)), a @ b, info

    def test_banded(self):
        out, want, info = self._run(64, 4, banded_mask(64, 6),
                                    banded_mask(64, 4))
        np.testing.assert_allclose(out, want, atol=1e-4)
        assert int(info["n_pairs"]) <= info["pair_cap"]

    def test_random(self):
        out, want, _ = self._run(64, 8, random_mask(64, 0.1, seed=3),
                                 random_mask(64, 0.15, seed=4))
        np.testing.assert_allclose(out, want, atol=1e-4)

    def test_flat_matches_hier(self):
        o1, want, _ = self._run(64, 4, banded_mask(64, 5),
                                random_mask(64, 0.1, seed=5))
        o2, _, _ = self._run(64, 4, banded_mask(64, 5),
                             random_mask(64, 0.1, seed=5),
                             hierarchical=False)
        np.testing.assert_allclose(o1, want, atol=1e-4)
        np.testing.assert_allclose(o1, o2, atol=1e-5)

    def test_pair_kernel_path(self):
        out, want, _ = self._run(64, 8, banded_mask(64, 8),
                                 banded_mask(64, 8), use_pair_kernel=True)
        np.testing.assert_allclose(out, want, atol=1e-4)

    def test_c_structure(self):
        ma = jnp.asarray(np.eye(4, dtype=bool))
        mb = jnp.asarray(np.eye(4, dtype=bool))
        rows, cols, slot, cnt = compute_c_structure(ma, mb, 8)
        assert int(cnt) == 4
        assert np.all(np.asarray(rows)[:4] == np.asarray(cols)[:4])

    def test_useful_flops(self):
        ma = np.eye(4, dtype=bool)
        assert useful_flops(ma, ma, 8) == 2.0 * 8 ** 3 * 4

    def test_end_to_end_jit_wrapper(self):
        a = values_for_mask(banded_mask(32, 3), seed=7).astype(np.float32)
        ma = block_mask_from_element_mask(np.abs(a) > 0, 4)
        caps = tuple(bsp.plan_caps(ma, ma))
        out, info = bsmm_from_dense(
            jnp.asarray(a), jnp.asarray(a), bs=4, cap_a=64, cap_b=64,
            cap_c=bsp.plan_c_cap(ma, ma), pair_caps=caps)
        np.testing.assert_allclose(np.asarray(out), a @ a, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), fill=st.floats(0.05, 0.5),
       bs=st.sampled_from([4, 8]))
def test_property_bsmm_matches_dense(seed, fill, bs):
    n = 32
    a = values_for_mask(random_mask(n, fill, seed=seed),
                        seed=seed).astype(np.float32)
    b = values_for_mask(random_mask(n, fill, seed=seed + 1),
                        seed=seed + 1).astype(np.float32)
    ma = block_mask_from_element_mask(np.abs(a) > 0, bs)
    mb = block_mask_from_element_mask(np.abs(b) > 0, bs)
    caps = bsp.plan_caps(ma, mb)
    A = bsp.from_dense(jnp.asarray(a), bs, (n // bs) ** 2)
    B = bsp.from_dense(jnp.asarray(b), bs, (n // bs) ** 2)
    c, _ = bsmm(A, B, pair_caps=caps, cap_c=bsp.plan_c_cap(ma, mb))
    np.testing.assert_allclose(np.asarray(bsp.to_dense(c)), a @ b, atol=1e-3)
