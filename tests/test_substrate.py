"""Optimizer, data pipeline, checkpointing, fault-tolerance runtime."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.checkpoint import CheckpointManager, load_checkpoint, \
    save_checkpoint
from repro.checkpoint.store import latest_step
from repro.data import SyntheticLM
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule)
from repro.runtime import (FaultInjector, HeartbeatMonitor, TrainingRunner,
                           compressed_grad_tree, dequantize_int8,
                           elastic_remesh_plan, quantize_int8)
from repro.runtime.fault import WorkerFailure


class TestAdamW:
    def test_converges_quadratic(self):
        """AdamW drives a quadratic toward its (decayed) minimum."""
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros(3)}
        opt = adamw_init(params)

        @jax.jit
        def step(params, opt):
            g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
            return adamw_update(params, g, opt, lr=0.05,
                                weight_decay=0.0)

        for _ in range(300):
            params, opt = step(params, opt)
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(target), atol=1e-2)

    def test_moments_are_f32_for_bf16_params(self):
        params = {"w": jnp.zeros(4, jnp.bfloat16)}
        opt = adamw_init(params)
        assert opt.m["w"].dtype == jnp.float32
        g = {"w": jnp.ones(4, jnp.bfloat16)}
        p2, opt2 = adamw_update(params, g, opt, lr=0.1)
        assert p2["w"].dtype == jnp.bfloat16
        assert opt2.v["w"].dtype == jnp.float32

    def test_weight_decay_pulls_to_zero(self):
        params = {"w": jnp.ones(4) * 10}
        opt = adamw_init(params)
        g = {"w": jnp.zeros(4)}
        for _ in range(50):
            params, opt = adamw_update(params, g, opt, lr=0.1,
                                       weight_decay=0.5)
        assert np.abs(np.asarray(params["w"])).max() < 10

    def test_clip_global_norm(self):
        g = {"a": jnp.ones(4) * 100, "b": jnp.ones(2) * 100}
        clipped, gn = clip_by_global_norm(g, 1.0)
        total = np.sqrt(sum((np.asarray(x) ** 2).sum()
                            for x in jax.tree.leaves(clipped)))
        np.testing.assert_allclose(total, 1.0, rtol=1e-5)
        assert float(gn) > 1.0

    def test_cosine_schedule(self):
        lr0 = cosine_schedule(jnp.int32(0), peak_lr=1.0, warmup=10,
                              total=100)
        lr_peak = cosine_schedule(jnp.int32(10), peak_lr=1.0, warmup=10,
                                  total=100)
        lr_end = cosine_schedule(jnp.int32(100), peak_lr=1.0, warmup=10,
                                 total=100)
        assert float(lr0) == 0.0
        np.testing.assert_allclose(float(lr_peak), 1.0, atol=0.01)
        np.testing.assert_allclose(float(lr_end), 0.1, atol=0.01)


class TestData:
    def test_deterministic(self):
        d = SyntheticLM(vocab=100, seq_len=32, global_batch=4, seed=1)
        b1 = d.batch_at(7)
        b2 = d.batch_at(7)
        assert np.array_equal(b1["tokens"], b2["tokens"])

    def test_targets_are_shifted_inputs(self):
        d = SyntheticLM(vocab=100, seq_len=32, global_batch=2, seed=1)
        b = d.batch_at(0)
        seq = d.sequence(0)
        assert np.array_equal(b["tokens"][0], seq[:-1])
        assert np.array_equal(b["targets"][0], seq[1:])

    def test_shards_disjoint_and_union_complete(self):
        d = SyntheticLM(vocab=100, seq_len=16, global_batch=8, seed=2)
        full = d.batch_at(3)["tokens"]
        parts = [d.batch_at(3, shard=i, n_shards=4)["tokens"]
                 for i in range(4)]
        assert np.array_equal(np.concatenate(parts), full)

    def test_different_steps_differ(self):
        d = SyntheticLM(vocab=1000, seq_len=64, global_batch=2, seed=3)
        assert not np.array_equal(d.batch_at(0)["tokens"],
                                  d.batch_at(1)["tokens"])

    def test_tokens_in_vocab(self):
        d = SyntheticLM(vocab=37, seq_len=128, global_batch=2, seed=4)
        t = d.batch_at(0)["tokens"]
        assert t.min() >= 0 and t.max() < 37


class TestCheckpoint:
    def _tree(self, seed=0):
        rng = np.random.default_rng(seed)
        return {"w": jnp.asarray(rng.standard_normal((4, 4)), jnp.float32),
                "b": {"x": jnp.asarray(rng.standard_normal(3),
                                       jnp.bfloat16)}}

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        save_checkpoint(tmp_path, 5, tree)
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        out, step = load_checkpoint(tmp_path, 5, like)
        assert step == 5
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_uncommitted_ignored(self, tmp_path):
        tree = self._tree()
        save_checkpoint(tmp_path, 1, tree)
        # fake a partial (uncommitted) later checkpoint
        (tmp_path / "step_00000002").mkdir()
        assert latest_step(tmp_path) == 1

    def test_manager_retention_and_restore(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, self._tree(s), blocking=True)
        mgr.wait()
        assert latest_step(tmp_path) == 4
        steps = sorted(int(p.stem.split("_")[1])
                       for p in pathlib.Path(tmp_path).glob(
                           "step_*.COMMITTED"))
        assert steps == [3, 4]
        out, step = mgr.restore_latest(self._tree())
        assert step == 4

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=1)
        mgr.save(7, self._tree(7), blocking=False)
        mgr.wait()
        assert latest_step(tmp_path) == 7


class TestRuntime:
    def test_heartbeat_failure_detection(self):
        mon = HeartbeatMonitor(n_workers=3, timeout=0.0)
        import time
        mon.beat(0)
        time.sleep(0.01)
        assert 1 in mon.failed_workers()
        assert 2 in mon.failed_workers()

    def test_straggler_detection(self):
        mon = HeartbeatMonitor(n_workers=4, straggler_factor=2.0)
        for w in range(4):
            for _ in range(5):
                mon.beat(w, step_time=1.0 if w != 3 else 5.0)
        assert mon.stragglers() == [3]

    def test_fault_injector(self):
        inj = FaultInjector({3: 1})
        inj.check(2)
        with pytest.raises(WorkerFailure):
            inj.check(3)
        inj.check(3)  # consumed

    def test_training_runner_restart_resumes(self, tmp_path):
        """Counter 'model': state increments per step; failure at step 12
        restores the step-10 checkpoint and finishes with the exact total."""
        def step_fn(state, batch):
            return state + 1, {"loss": float(100 - state)}

        runner = TrainingRunner(
            step_fn, lambda s: None, CheckpointManager(tmp_path, keep=2),
            ckpt_every=5, injector=FaultInjector({12: 0}))
        state, hist = runner.run(jnp.int32(0), 20)
        assert int(state) == 20
        assert hist["restarts"] == 1

    def test_training_runner_no_checkpoint_restarts_from_zero(self,
                                                              tmp_path):
        def step_fn(state, batch):
            return state + 1, {"loss": 0.0}

        runner = TrainingRunner(
            step_fn, lambda s: None, CheckpointManager(tmp_path, keep=2),
            ckpt_every=100, injector=FaultInjector({3: 0}))
        state, hist = runner.run(jnp.int32(0), 10)
        assert int(state) == 10
        assert hist["restarts"] == 1

    def test_elastic_plan(self):
        plan = elastic_remesh_plan((16, 16), ("data", "model"), n_failed=5)
        assert plan.new_shape == (15, 16)
        assert plan.microbatch_scale == 2
        with pytest.raises(RuntimeError):
            elastic_remesh_plan((2, 2), ("data", "model"), n_failed=4)

    def test_compression_error_bound(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
        q, s = quantize_int8(g)
        back = dequantize_int8(q, s)
        err = np.abs(np.asarray(back - g)).max()
        assert err <= float(s) / 2 + 1e-7       # half-ULP of the grid
        assert q.dtype == jnp.int8

    def test_compressed_tree_shapes_dtypes(self):
        tree = {"a": jnp.ones((3, 3), jnp.bfloat16),
                "b": jnp.ones(5, jnp.float32)}
        out = compressed_grad_tree(tree)
        assert out["a"].dtype == jnp.bfloat16
        assert out["b"].shape == (5,)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10000), scale=st.floats(1e-3, 1e3))
def test_property_quantization_relative_error(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(256) * scale, jnp.float32)
    q, s = quantize_int8(g)
    back = dequantize_int8(q, s)
    # max error bounded by half a quantization step
    assert np.abs(np.asarray(back - g)).max() <= float(s) * 0.5 + 1e-6
