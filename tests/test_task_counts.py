"""Task-count regression: the executed graph matches the paper's eq. (1).

The paper's eq. (1) family counts multiplication tasks per quadtree level as
the number of surviving (i, k, j) triples: sum_k (nonzero chunks in column k
of A at level l) x (nonzero chunks in row k of B at level l).  These tests
pin the executor refactor (payload dispatch through the leaf engine) against
that closed form, evaluated three independent ways:

* analytically for banded patterns (bandwidth coarsens as (d-1)//f + 1);
* combinatorially via analysis.count_tasks_per_level_pairs (any pattern);
* against the §5 bounds (eqs (2), (8)).
"""
import numpy as np
import pytest

from repro.core.analysis import (banded_tasks_bound, count_mult_tasks_pairs,
                                 count_tasks_per_level_pairs)
from repro.core.multiply import (count_tasks_per_level, total_add_tasks,
                                 total_multiply_tasks)
from repro.core.multiply import qt_multiply
from repro.core.patterns import (banded_mask, block_mask_from_element_mask,
                                 random_mask, values_for_mask)
from repro.core.quadtree import QTParams, qt_from_dense
from repro.core.tasks import CTGraph

PARAMS = QTParams(n=64, leaf_n=16, bs=4)
LEAF_LEVEL = PARAMS.levels          # root = 0


def _graph_counts(a, b, engine="numpy"):
    g = CTGraph(engine=engine)
    ra = qt_from_dense(g, a, PARAMS)
    rb = qt_from_dense(g, b, PARAMS)
    qt_multiply(g, PARAMS, ra, rb)
    return g, count_tasks_per_level(g)


def _chunk_coords(mask, level):
    """Nonzero chunk coordinates of the level-``level`` occupancy."""
    size = PARAMS.n // (1 << level)
    occ = block_mask_from_element_mask(mask, size)
    r, c = np.nonzero(occ)
    return r, c, 1 << level


def _banded_closed_form(d_elem, level):
    """Eq (1) evaluated in closed form for A = B banded.

    At level l the chunk size is f = n/2^l and the chunk occupancy is banded
    with half-bandwidth D = (d-1)//f + 1; the task count is
    sum_k c(k)^2 with c(k) the nonzero count of column k.
    """
    grid = 1 << level
    f = PARAMS.n // grid
    D = (d_elem - 1) // f + 1
    total = 0
    for k in range(grid):
        c = min(grid - 1, k + D) - max(0, k - D) + 1
        total += c * c
    return total


class TestBandedClosedForm:
    @pytest.mark.parametrize("d", [3, 5, 9])
    def test_per_level_matches_eq1(self, d):
        mask = banded_mask(64, d)
        a = values_for_mask(mask, seed=d)
        _, per = _graph_counts(a, a)
        for level in range(LEAF_LEVEL + 1):
            assert per[level] == _banded_closed_form(d, level), (
                f"level {level}, d {d}")

    @pytest.mark.parametrize("d", [3, 5])
    def test_total_is_sum_of_levels(self, d):
        a = values_for_mask(banded_mask(64, d), seed=d)
        g, per = _graph_counts(a, a)
        assert total_multiply_tasks(g) == sum(per.values())

    @pytest.mark.parametrize("d", [3, 5, 9])
    def test_eq8_bound_holds(self, d):
        """C_l < 2^l (2 d_l + 1)^2 (eq (8)); d = 2^k element bandwidth."""
        a = values_for_mask(banded_mask(64, d), seed=d)
        _, per = _graph_counts(a, a)
        L = int(np.log2(PARAMS.n))
        k = int(np.ceil(np.log2(d)))
        for level, cnt in per.items():
            # graph levels stop at leaf chunks; eq (8)'s level runs to
            # blocksize 1 — translate by the leaf-chunk size
            assert cnt <= banded_tasks_bound(L, k, level) * 4


class TestPatternCounts:
    @pytest.mark.parametrize("mk,seed", [
        (lambda s: random_mask(64, 0.1, seed=s), 0),
        (lambda s: random_mask(64, 0.25, seed=s), 1),
        (lambda s: banded_mask(64, 7), 2),
    ])
    def test_matches_pairs_counter(self, mk, seed):
        """Graph counts == eq (1) evaluated combinatorially per level."""
        ma = mk(seed)
        mb = mk(seed + 100)
        a = values_for_mask(ma, seed=seed)
        b = values_for_mask(mb, seed=seed + 100)
        _, per = _graph_counts(a, b)

        ra, ca, n_chunks = _chunk_coords(ma, LEAF_LEVEL)
        rb, cb, _ = _chunk_coords(mb, LEAF_LEVEL)
        want = count_tasks_per_level_pairs(ra, ca, n_chunks,
                                           rows_b=rb, cols_b=cb)
        assert per == {l: c for l, c in want.items() if c}

    def test_leaf_level_matches_colrow_product(self):
        """Eq (1) at one level: sum_k colA_k * rowB_k, direct evaluation."""
        ma = random_mask(64, 0.15, seed=5)
        mb = random_mask(64, 0.15, seed=6)
        a = values_for_mask(ma, seed=5)
        b = values_for_mask(mb, seed=6)
        _, per = _graph_counts(a, b)
        ra, ca, n_chunks = _chunk_coords(ma, LEAF_LEVEL)
        rb, cb, _ = _chunk_coords(mb, LEAF_LEVEL)
        assert per[LEAF_LEVEL] == count_mult_tasks_pairs(ra, ca, rb, cb,
                                                         n_chunks)

    def test_eq2_bound_holds(self):
        """C_l <= 8^l (eq (2)) for any pattern."""
        a = values_for_mask(random_mask(64, 0.3, seed=9), seed=9)
        _, per = _graph_counts(a, a)
        for level, cnt in per.items():
            assert cnt <= 8 ** level

    @pytest.mark.pallas
    def test_counts_invariant_under_pallas_backend(self):
        """The batched executor must register the exact same task graph."""
        ma = random_mask(64, 0.12, seed=12)
        a = values_for_mask(ma, seed=12)
        g_np, per_np = _graph_counts(a, a, engine="numpy")
        g_pl, per_pl = _graph_counts(a, a, engine="pallas")
        assert per_np == per_pl
        assert total_multiply_tasks(g_np) == total_multiply_tasks(g_pl)
        assert total_add_tasks(g_np) == total_add_tasks(g_pl)
