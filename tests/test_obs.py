"""Observability layer (DESIGN.md §8): span tracer, unified metrics,
Perfetto export, plan profiles — and its two load-bearing contracts:
the no-op path changes nothing, and the unified counters carry the
legacy values verbatim (bit-for-bit against the published artifacts).
"""
import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro import Session
from repro.core.patterns import banded_mask, values_for_mask
from repro.obs import (NOOP, Counter, MetricSet, Tracer, as_tracer,
                       chrome_trace, from_engine_stats, from_sim_report,
                       from_truncation, mesh_stats_events, sim_trace_events,
                       span_events, text_report, validate_metrics,
                       write_chrome_trace)
from repro.runtime.trace import TaskEvent, Trace, critical_path

_ROOT = pathlib.Path(__file__).parents[1]
# benchmarks/ is a repo-root package (for benchmarks._artifact)
if str(_ROOT) not in sys.path:
    sys.path.insert(0, str(_ROOT))


def _banded(n=64, d=9, seed=1):
    return values_for_mask(banded_mask(n, d), seed=seed)


class TestTracer:
    def test_as_tracer(self):
        assert as_tracer(None) is NOOP
        assert as_tracer(False) is NOOP
        assert isinstance(as_tracer(True), Tracer)
        tr = Tracer()
        assert as_tracer(tr) is tr
        with pytest.raises(ValueError):
            as_tracer("yes")

    def test_noop_is_inert(self):
        assert not NOOP.enabled
        assert NOOP.spans == ()
        with NOOP.span("x", track="t", k=1) as sp:
            sp.set(more=2)          # chainable, records nothing
        assert NOOP.spans == ()
        assert len(NOOP.find("x")) == 0

    def test_nesting_depth_and_attrs(self):
        tr = Tracer()
        with tr.span("outer", track="a", k=1) as so:
            with tr.span("inner", track="b") as si:
                si.set(q=2)
            so.set(done=True)
        outer, = tr.find("outer")
        inner, = tr.find("inner")
        assert outer.depth == 0 and inner.depth == 1
        assert outer.attrs == {"k": 1, "done": True}
        assert inner.attrs == {"q": 2}
        assert outer.t0 <= inner.t0 <= inner.t1 <= outer.t1
        # ordered() sorts by start time; spans list is close order
        assert [s.name for s in tr.ordered()] == ["outer", "inner"]
        assert [s.name for s in tr.spans] == ["inner", "outer"]
        assert len(tr) == 2
        tr.clear()
        assert len(tr) == 0


class TestSessionSpans:
    def test_numpy_engine_taxonomy(self):
        a = _banded()
        sess = Session(trace=True, leaf_n=32, bs=8)
        A = sess.from_dense(a)
        C = A @ A
        sess.simulate(p=4)
        names = {s.name for s in sess.tracer.spans}
        assert {"qt.from_dense", "qt.multiply",
                "session.simulate"} <= names
        mul, = sess.tracer.find("qt.multiply")
        assert mul.track == "graph"
        assert mul.attrs["n"] == 64 and mul.attrs["tasks"] > 0
        sim, = sess.tracer.find("session.simulate")
        assert sim.attrs["tasks"] > 0 and sim.attrs["makespan_s"] > 0
        np.testing.assert_allclose(C.to_dense(), a @ a, rtol=1e-9)

    @pytest.mark.pallas
    def test_pallas_engine_wave_spans(self):
        a = _banded()
        sess = Session(engine="pallas", trace=True, leaf_n=32, bs=8)
        A = sess.from_dense(a)
        got = (A @ A).to_dense()
        np.testing.assert_allclose(got, a @ a, rtol=1e-3, atol=1e-5)
        waves = sess.tracer.find("engine.wave")
        assert waves and all(w.track == "engine" for w in waves)
        w = waves[0]
        assert w.attrs["kernel"] and w.attrs["bs"] == 8
        assert w.attrs["pairs"] > 0 and w.attrs["bytes_packed"] > 0
        disp = sess.tracer.find("kernel.dispatch")
        assert disp and all(d.depth > w.depth or d.t0 >= w.t0
                            for d in disp)
        # dispatch spans nest inside their wave span
        assert any(w.t0 <= d.t0 and d.t1 <= w.t1 for d in disp)

    def test_tracing_context_manager(self):
        a = _banded()
        sess = Session(leaf_n=32, bs=8)
        assert sess.tracer is NOOP
        with sess.tracing() as tr:
            A = sess.from_dense(a)
            _ = A @ A
        assert sess.tracer is NOOP
        assert sess.graph.tracer is NOOP
        assert tr.find("qt.multiply")
        # exception still restores the previous tracer
        with pytest.raises(RuntimeError):
            with sess.tracing():
                raise RuntimeError("boom")
        assert sess.tracer is NOOP


class TestNoopInert:
    """Tracing off vs on: identical task program and schedule."""

    def _run(self, trace):
        a = _banded(128, 12)
        sess = Session(leaf_n=32, bs=8, trace=trace, seed=0)
        A = sess.from_dense(a)
        B = sess.from_dense(a)
        _ = A @ B
        rep = sess.simulate(p=4)
        return sess, rep

    def test_graph_and_schedule_identical(self):
        s_off, r_off = self._run(False)
        s_on, r_on = self._run(True)
        assert s_off.task_counts() == s_on.task_counts()
        assert len(s_off.graph.nodes) == len(s_on.graph.nodes)
        assert r_off.trace.schedule() == r_on.trace.schedule()
        assert r_off.makespan == r_on.makespan
        assert list(r_off.bytes_received) == list(r_on.bytes_received)


class TestMetrics:
    def test_counter_invariants(self):
        c = Counter("x", "B", [1, 2, 3])
        assert c.total == 6 and c.max == 3
        d = c.to_dict()
        assert d == {"name": "x", "unit": "B", "per_worker": [1, 2, 3],
                     "total": 6}

    def test_metricset_mapping_and_validation(self):
        ms = MetricSet("test")
        ms.add("a", "B", [1, 2])
        ms.add("b", "s", 0.5)               # scalar -> one-element list
        assert "a" in ms and ms["a"].total == 3
        assert ms["b"].per_worker == [0.5]
        assert set(ms.names()) == {"a", "b"}
        doc = ms.to_dict()
        validate_metrics(doc)
        assert MetricSet.from_dict(doc).to_dict() == doc
        doc["counters"][0]["total"] = 999
        with pytest.raises(ValueError):
            validate_metrics(doc)

    def test_sim_report_counters_equal_legacy(self):
        a = _banded(128, 12)
        sess = Session(leaf_n=32, bs=8, seed=0)
        A = sess.from_dense(a)
        _ = A @ A
        rep = sess.simulate(p=4)
        ms = rep.to_metrics()
        assert ms.source == "simulator"
        validate_metrics(ms.to_dict())
        assert ms["bytes_received"].per_worker == list(rep.bytes_received)
        assert ms["bytes_pushed"].per_worker == list(rep.bytes_pushed)
        assert ms["tasks_executed"].per_worker == list(rep.tasks_per_worker)
        assert ms["steals"].total == rep.steals
        assert ms["makespan"].per_worker == [rep.makespan]
        assert from_sim_report(rep).to_dict() == ms.to_dict()

    @pytest.mark.pallas
    def test_engine_stats_counters_equal_legacy(self):
        a = _banded()
        sess = Session(engine="pallas", leaf_n=32, bs=8)
        A = sess.from_dense(a)
        _ = (A @ A).to_dense()
        st = sess.engine_stats()
        ms = from_engine_stats(st)
        assert ms.source == "engine:pallas"
        validate_metrics(ms.to_dict())
        assert ms["waves"].total == st["waves"]
        assert ms["batched_pairs"].total == st["batched_pairs"]
        assert ms["bytes_packed"].total == st["bytes_packed"]

    def test_truncation_counters(self):
        a = _banded(128, 12)
        sess = Session(leaf_n=32, bs=8)
        A = sess.from_dense(a)
        M = A.multiply(A, tau=1e-3)
        rep = M.truncation
        ms = from_truncation(rep)
        validate_metrics(ms.to_dict())
        assert ms["pruned_leaf_pairs"].total == rep.pruned_leaf_pairs
        assert ms["error_bound"].total == rep.error_bound

    def test_session_metrics_sources(self):
        a = _banded()
        sess = Session(leaf_n=32, bs=8)
        A = sess.from_dense(a)
        _ = A @ A
        sess.simulate(p=2)
        sources = [ms.source for ms in sess.metrics()]
        assert sources == ["engine:numpy", "simulator"]
        report = text_report(*sess.metrics())
        assert "bytes_received" in report and "simulator" in report


class TestExport:
    def _sim(self):
        a = _banded(128, 12)
        sess = Session(leaf_n=32, bs=8, seed=0)
        A = sess.from_dense(a)
        _ = A @ A
        return sess, sess.simulate(p=4)

    @staticmethod
    def _assert_monotone(doc):
        ts = [e["ts"] for e in doc["traceEvents"] if e["ph"] != "M"]
        assert ts == sorted(ts)
        assert all(t >= 0 for t in ts)

    def test_sim_trace_chrome_export(self, tmp_path):
        sess, rep = self._sim()
        doc = chrome_trace(sim_trace_events(rep.trace))
        # valid JSON, monotone timestamps, workers as named threads
        doc = json.loads(json.dumps(doc))
        self._assert_monotone(doc)
        evs = doc["traceEvents"]
        names = {e["args"]["name"] for e in evs if e["ph"] == "M"
                 and e["name"] == "thread_name"}
        assert {"worker 0", "worker 3"} <= names
        slices = [e for e in evs if e["ph"] == "X"]
        assert len(slices) == len(rep.trace.events)
        # cumulative received-bytes counters end at the legacy totals
        last = {}
        for e in evs:
            if e["ph"] == "C":
                last[e["name"]] = e["args"]["bytes"]
        assert sum(last.values()) == sum(rep.bytes_received)
        out = tmp_path / "sim.trace.json"
        write_chrome_trace(out, sim_trace_events(rep.trace))
        assert "traceEvents" in json.loads(out.read_text())

    def test_span_events_export(self, tmp_path):
        a = _banded()
        sess = Session(trace=True, leaf_n=32, bs=8)
        A = sess.from_dense(a)
        _ = A @ A
        sess.simulate(p=2)
        doc = chrome_trace(span_events(sess.tracer))
        self._assert_monotone(doc)
        slices = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
        assert {"qt.multiply", "session.simulate"} <= slices
        # combined export: spans + simulator on distinct pid tracks
        both = chrome_trace(span_events(sess.tracer),
                            sim_trace_events(sess._last_report.trace))
        self._assert_monotone(both)
        pids = {e["pid"] for e in both["traceEvents"]}
        assert len(pids) == 2

    def test_mesh_stats_events_from_log(self):
        # synthetic stats dict in MeshEngine.stats() shape: the exporter
        # itself needs no devices
        st = {"n_dev": 2,
              "wave_log": [{"kernel": "k", "bs": 8, "tasks": 3,
                            "pairs": 5, "padded_pairs": 6, "c_blocks": 4,
                            "wall_s": 0.25}] * 2,
              "comm_log": [
                  {"fetched_bytes_by_dev": [256, 0],
                   "pushed_bytes_by_dev": [0, 512],
                   "collective_bytes_by_dev": [256, 0]},
                  {"fetched_bytes_by_dev": [0, 128],
                   "pushed_bytes_by_dev": [64, 0],
                   "collective_bytes_by_dev": [0, 128]},
              ]}
        doc = chrome_trace(mesh_stats_events(st))
        self._assert_monotone(doc)
        fetched = [e for e in doc["traceEvents"] if e["ph"] == "C"
                   and e["name"].startswith("fetched_bytes")]
        finals = {}
        for e in fetched:       # cumulative: last value per device wins
            finals[e["tid"]] = e["args"]["bytes"]
        assert finals == {0: 256, 1: 128}
        slices = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        assert len(slices) == 4     # 2 waves x 2 devices
        assert {e["dur"] for e in slices} == {0.25 * 1e6}


class TestPinnedArtifacts:
    """Unified counters reproduce the published BENCH values bit-for-bit."""

    def test_sim_cache_miss_bytes_match_comm_scaling(self):
        doc = json.loads((_ROOT / "BENCH_comm_scaling.json").read_text())
        assert doc["schema"] == 1 and doc["bench"] == "comm_scaling"
        rec = [r for r in doc["records"]
               if r["pattern"] == "banded"
               and r["placement"] == "parent-worker" and r["p"] == 4][0]
        # re-run that record's exact cell (bench_comm_scaling.run_banded
        # at the quick sizes) and compare through the unified schema
        n = rec["n"]
        a = values_for_mask(banded_mask(n, 24), seed=1, symmetric=True)
        sess = Session(leaf_n=32, bs=8, placement="parent-worker", seed=0)
        A = sess.from_dense(a)
        B = sess.from_dense(a)
        sess.simulate(p=4)
        _ = A @ B
        rep = sess.simulate(fresh_stats=True)
        ms = rep.to_metrics()
        assert ms["bytes_received"].max == int(round(rec["max_MB"] * 1e6))
        total = sum(rep.bytes_received)
        assert ms["bytes_received"].total == total
        assert abs(total / len(rep.bytes_received)
                   - rec["avg_MB"] * 1e6) < 0.5

    @pytest.mark.slow
    def test_mesh_fetched_bytes_match_mesh_comm(self):
        # subprocess: XLA device count must be set before jax init
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        env["PYTHONPATH"] = str(_ROOT / "src")
        res = subprocess.run(
            [sys.executable, str(_ROOT / "tests" / "dist_scenarios.py"),
             "obs_mesh_pinned"],
            capture_output=True, text=True, env=env, timeout=600)
        assert res.returncode == 0, \
            f"obs_mesh_pinned failed:\n{res.stdout}\n{res.stderr}"
        assert "OK obs_mesh_pinned" in res.stdout


class TestPlanProfile:
    def test_profile_shape_and_metrics(self):
        a = _banded(128, 12)
        sess = Session(lazy=True, leaf_n=32, bs=8)
        X = sess.from_dense(a, name="X")
        plan = sess.compile(X @ X)
        plan.run()
        plan.run()                          # zero-task replay
        prof = plan.profile()
        assert prof["schema"] == 1
        assert prof["inputs"] == ["X"]
        assert prof["runs"] == 2 and prof["n_tasks"] > 0
        assert prof["compile_s"] > 0
        assert len(prof["replay_s"]) == 1
        assert prof["waves"] == []          # immediate numpy backend
        for ms in prof["metrics"]:
            validate_metrics(ms)
        assert prof["metrics"][0]["source"] == "engine:numpy"
        assert json.loads(json.dumps(prof)) == prof

    @pytest.mark.pallas
    def test_profile_waves_on_pallas(self):
        a = _banded(128, 12)
        sess = Session(engine="pallas", lazy=True, leaf_n=32, bs=8)
        X = sess.from_dense(a, name="X")
        plan = sess.compile(X @ X)
        plan.run()
        sess.flush()
        prof = plan.profile()
        assert prof["waves"], "pallas plan should record waves"
        w = prof["waves"][0]
        assert w["bs"] == 8 and w["pairs"] > 0
        assert 0.0 <= w["padding_waste"] < 1.0
        assert w["bytes_packed"] > 0


class TestTraceRegressions:
    """Satellite fixes in runtime/trace.py."""

    def test_gantt_zero_duration_tail_event(self):
        tr = Trace(2)
        tr.append(TaskEvent(nid=0, kind="a", worker=0, start=0.0, end=1.0))
        # zero-duration event exactly at the makespan: start * scale
        # lands on column `width` — must clamp, not IndexError
        tr.append(TaskEvent(nid=1, kind="b", worker=1, start=1.0, end=1.0))
        chart = tr.gantt(width=10)
        lines = chart.splitlines()
        assert lines[0].startswith("w0")
        assert "#" in lines[1]          # the tail event still renders

    def test_gantt_empty_trace(self):
        assert Trace(2).gantt() == "(empty trace)"

    def test_critical_path_empty_trace(self):
        sess = Session(leaf_n=32, bs=8)
        cp = critical_path(sess.graph, Trace(2))
        assert cp.work_s == 0.0 and cp.length_s == 0.0
        assert cp.path == [] and cp.n_tasks == 0

    def test_critical_path_all_done_before(self):
        a = _banded()
        sess = Session(leaf_n=32, bs=8)
        A = sess.from_dense(a)
        _ = A @ A
        rep = sess.simulate(p=2)
        done = {ev.nid for ev in rep.trace.events}
        # a later phase that re-simulates nothing: empty trace + full
        # done_before set must yield the zero path, not raise
        cp = critical_path(sess.graph, Trace(2), done)
        assert cp.length_s == 0.0 and cp.n_tasks == 0


class TestArtifactEnvelope:
    def test_envelope_and_validation(self, tmp_path):
        from benchmarks._artifact import (artifact, validate_artifact,
                                          write_artifact)
        doc = artifact("x", {"v": 1}, params={"p": 2})
        assert doc == {"schema": 1, "bench": "x", "params": {"p": 2},
                       "v": 1}
        validate_artifact(doc)
        with pytest.raises(ValueError):
            validate_artifact({"bench": "x"})
        out = write_artifact(tmp_path / "a.json", "y", {"k": [1, 2]})
        loaded = json.loads(pathlib.Path(out).read_text())
        assert loaded["bench"] == "y" and loaded["k"] == [1, 2]

    def test_published_artifacts_carry_envelope(self):
        for name in ("BENCH_comm_scaling.json", "BENCH_mesh_comm.json"):
            p = _ROOT / name
            if not p.exists():
                pytest.skip(f"{name} not present")
            doc = json.loads(p.read_text())
            assert doc["schema"] == 1
            assert doc["bench"] == name[6:-5]
            assert isinstance(doc["params"], dict)
