"""Electronic-structure solver suite (src/repro/solvers, DESIGN.md §11).

Pins the solver tentpole end to end:

1. **Triangular task programs** — ``qt_inv_chol`` / ``qt_tri_solve`` /
   ``qt_extract`` match dense references on both engines, produce
   genuinely triangular quadtrees, and reject singular input.
2. **Inverse factorization** — every method's Z satisfies
   ``||Z^T S Z - I||_F`` at the *reported* residual on banded / S2 /
   random-decay SPD patterns (both engines); localized refinement
   touches fewer multiply subtrees than global refinement.
3. **Accuracy-scaled chains** — the measured chain error never exceeds
   the accumulated TruncationReport bound, and flops are monotone in the
   target accuracy.
4. **SCF pipeline** — the density matrix matches the dense
   eigendecomposition reference; unchanged-structure SP2 replays
   register zero new tasks; drifting-sparsity rebinds (denser *and*
   sparser) run through ``recompile=True`` with successor reuse visible
   in ``Session.metrics()`` ("plan-recompile").
"""
import math

import numpy as np
import pytest

from repro import Session
from repro.core.patterns import (banded_mask, divide_space_order,
                                 overlap_mask, particle_cloud, random_mask,
                                 values_for_mask)
from repro.solvers import (TauPolicy, inverse_factor, multiply_chain,
                           scf_density)

N, LEAF_N, BS = 64, 16, 4
TOL = dict(atol=2e-4, rtol=2e-4)   # pallas packs float32; numpy is float64
ENGINES = ("numpy", "pallas")
PATTERNS = ("banded", "s2", "random")


def _session(engine="numpy", **kw):
    kw.setdefault("leaf_n", LEAF_N)
    kw.setdefault("bs", BS)
    return Session(engine=engine, **kw)


def _spd(pattern: str, n: int = N, seed: int = 0) -> np.ndarray:
    """Diagonally dominant SPD matrix with the named sparsity/decay."""
    rng = np.random.default_rng(seed)
    if pattern == "banded":
        dist = np.abs(np.subtract.outer(np.arange(n), np.arange(n)))
        a = values_for_mask(banded_mask(n, 8), seed=seed) * 0.5 ** dist
    elif pattern == "s2":
        coords = particle_cloud(4, 3, seed=seed)       # 64 particles
        order = divide_space_order(coords)
        mask = overlap_mask(coords, 14.0, order=order)
        pts = coords[order]
        dist = np.sqrt(((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1))
        a = np.zeros((n, n))
        m = len(coords)
        a[:m, :m] = values_for_mask(mask, seed=seed + 1) * np.exp(-0.7 * dist)
    else:                                              # random decay
        a = values_for_mask(random_mask(n, 0.15, seed=seed), seed=seed + 1)
        a *= 10.0 ** (-4.0 * rng.random((n, n)))
    a = (a + a.T) / 2.0
    # scale off-diagonal mass below the unit diagonal: strictly
    # diagonally dominant => SPD, conditioning independent of the draw
    off = np.abs(a).sum(axis=1) - np.abs(np.diag(a))
    a *= 0.45 / max(off.max(), 1e-12)
    np.fill_diagonal(a, 1.0)
    return a


def _chain_factors(k: int = 4, seed: int = 3) -> list:
    """Near-identity decayed factors (keeps chain norms O(1))."""
    rng = np.random.default_rng(seed)
    idx = np.arange(N)
    decay = np.exp(-0.6 * np.abs(idx[:, None] - idx[None, :]))
    return [np.eye(N) + 0.25 * decay * rng.standard_normal((N, N))
            for _ in range(k)]


# ---------------------------------------------------------------- core ops
class TestTriangularPrograms:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_inv_chol_matches_dense(self, engine):
        s = _spd("banded")
        sess = _session(engine)
        Z = sess.from_dense(s, upper=True).inv_chol()
        zd = Z.to_dense()
        # unique inverse Cholesky factor: inv of the upper chol factor
        ref = np.linalg.solve(np.linalg.cholesky(s).T, np.eye(N))
        np.testing.assert_allclose(zd, ref, **TOL)
        assert np.allclose(np.tril(zd, -1), 0.0), "Z not upper triangular"
        np.testing.assert_allclose(zd.T @ s @ zd, np.eye(N), **TOL)

    @pytest.mark.parametrize("engine", ENGINES)
    def test_tri_solve_matches_dense(self, engine):
        s = _spd("banded")
        r = np.linalg.cholesky(s).T
        b = np.random.default_rng(5).standard_normal((N, N)) * 0.3
        sess = _session(engine)
        X = sess.from_dense(r).tri_solve(sess.from_dense(b))
        np.testing.assert_allclose(X.to_dense(), np.linalg.solve(r, b),
                                   **TOL)

    def test_engine_parity_task_structure(self):
        """Both engines register the identical solve-program graph."""
        s = _spd("banded")
        counts = {}
        for engine in ENGINES:
            sess = _session(engine)
            Z = sess.from_dense(s, upper=True).inv_chol()
            Z.to_dense()
            counts[engine] = (sess.task_counts(), Z.nnz_blocks())
        assert counts["numpy"] == counts["pallas"]

    @pytest.mark.parametrize("engine", ENGINES)
    def test_principal_submatrix(self, engine):
        s = _spd("banded")
        sess = _session(engine)
        S = sess.from_dense(s, upper=True)
        half = N // 2
        np.testing.assert_allclose(
            S.principal_submatrix([0]).to_dense(), s[:half, :half], **TOL)
        np.testing.assert_allclose(
            S.principal_submatrix([3, 0]).to_dense(),
            s[half:half + N // 4, half:half + N // 4], **TOL)

    def test_principal_submatrix_rejects_off_diagonal_of_upper(self):
        sess = _session()
        S = sess.from_dense(_spd("banded"), upper=True)
        with pytest.raises(ValueError, match="diagonal"):
            S.principal_submatrix([1])

    def test_extract_shares_subtree_chunks(self):
        """Extraction is an alias: no leaf task is re-registered."""
        sess = _session()
        S = sess.from_dense(_spd("banded"), upper=True)
        before = sess.task_counts()
        S.principal_submatrix([0])
        after = sess.task_counts()
        assert after.get("leaf", 0) == before.get("leaf", 0)
        assert after.get("extract", 0) == before.get("extract", 0) + 1

    def test_singular_raises(self):
        sess = _session()
        z = sess.zeros(N, upper=True)
        with pytest.raises(ValueError, match="singular|positive definite"):
            z.inv_chol()
        r = sess.zeros(N)
        b = sess.from_dense(np.eye(N))
        with pytest.raises(ValueError, match="singular"):
            r.tri_solve(b)

    def test_operand_storage_checks(self):
        sess = _session()
        plain = sess.from_dense(_spd("banded"))
        upper = sess.from_dense(_spd("banded"), upper=True)
        with pytest.raises(ValueError, match="upper storage"):
            plain.inv_chol()
        with pytest.raises(ValueError, match="plain"):
            upper.tri_solve(plain)


# ------------------------------------------------------- inverse factor
class TestInverseFactor:
    @pytest.mark.parametrize("engine", ENGINES)
    @pytest.mark.parametrize("pattern", PATTERNS)
    def test_recursive_matches_dense(self, engine, pattern):
        s = _spd(pattern)
        sess = _session(engine)
        Z, rep = inverse_factor(sess.from_dense(s, upper=True))
        zd = Z.to_dense()
        measured = np.linalg.norm(zd.T @ s @ zd - np.eye(N))
        # the reported residual is itself a quadtree readback: it must
        # agree with the dense measurement up to engine arithmetic
        assert abs(measured - rep.residual) <= 1e-4
        assert measured <= 5e-5, f"{pattern}: residual {measured}"
        ref = np.linalg.solve(np.linalg.cholesky(s).T, np.eye(N))
        np.testing.assert_allclose(zd, ref, **TOL)

    @pytest.mark.parametrize("engine", ENGINES)
    def test_localized_converges_with_fewer_touched_subtrees(self, engine):
        s = _spd("banded")
        tol = 1e-4
        sess_l = _session(engine)
        Z_l, rep_l = inverse_factor(sess_l.from_dense(s, upper=True),
                                    method="localized", tol=tol, tau=1e-7)
        sess_g = _session(engine)
        Z_g, rep_g = inverse_factor(sess_g.from_dense(s, upper=True),
                                    method="global", tol=tol)
        assert rep_l.converged and rep_g.converged
        assert rep_l.residual <= 2 * tol and rep_g.residual <= 2 * tol
        assert rep_l.splits >= 1
        assert rep_l.multiply_tasks < rep_g.multiply_tasks, (
            f"localized touched {rep_l.multiply_tasks} multiply subtrees, "
            f"global {rep_g.multiply_tasks}")

    def test_report_fields_and_schema(self):
        sess = _session()
        _, rep = inverse_factor(
            sess.from_dense(_spd("banded"), upper=True),
            method="global", tol=1e-6)
        assert rep.iterations >= 1
        assert rep.residuals and rep.residuals[-1] <= 1e-6
        # refinement residuals contract monotonically (order-2 iteration)
        assert all(b <= a * 1.01 for a, b in
                   zip(rep.residuals, rep.residuals[1:]))
        d = rep.to_dict()
        assert d["schema"] == 1 and d["method"] == "global"
        assert d["flops"] > 0 and d["multiply_tasks"] > 0

    def test_validation(self):
        sess = _session()
        with pytest.raises(ValueError, match="upper"):
            inverse_factor(sess.from_dense(_spd("banded")))
        with pytest.raises(ValueError, match="method"):
            inverse_factor(sess.from_dense(_spd("banded"), upper=True),
                           method="qr")


# ---------------------------------------------------------------- chains
class TestMultiplyChain:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_error_le_accumulated_bound(self, engine):
        mats = _chain_factors()
        exact = mats[0]
        for a in mats[1:]:
            exact = exact @ a
        sess = _session(engine)
        ms = [sess.from_dense(a) for a in mats]
        P, rep = multiply_chain(ms, policy=TauPolicy(target=1e-2))
        err = np.linalg.norm(P.to_dense() - exact)
        # float32 packing adds engine arithmetic on top of truncation
        slack = 1e-3 if engine == "pallas" else 1e-9
        assert err <= rep.accumulated_bound + slack
        assert rep.accumulated_bound <= 1e-2
        assert len(rep.taus) == len(mats) - 1 == rep.steps
        assert all(t > 0.0 for t in rep.taus)

    def test_flops_monotone_in_target_accuracy(self):
        mats = _chain_factors()
        flops, bounds = [], []
        for target in (1e-1, 1e-3, 1e-5, 0.0):
            sess = _session()
            ms = [sess.from_dense(a) for a in mats]
            policy = TauPolicy(target=target) if target else None
            _, rep = multiply_chain(ms, policy=policy)
            flops.append(rep.flops)
            bounds.append(rep.accumulated_bound)
        # tighter target => less pruning => more executed flops
        assert all(a <= b for a, b in zip(flops, flops[1:])), flops
        assert all(b >= a for a, b in zip(bounds[1:], bounds[:-1])), bounds
        assert bounds[-1] == 0.0            # exact chain: nothing pruned

    def test_budget_feedback_adapts(self):
        """Measured step bounds feed back: committed error never exceeds
        the target even though the policy only estimates prune counts."""
        mats = _chain_factors(k=6, seed=9)
        sess = _session()
        ms = [sess.from_dense(a) for a in mats]
        _, rep = multiply_chain(ms, policy=TauPolicy(target=1e-4))
        assert rep.accumulated_bound <= 1e-4

    def test_validation(self):
        sess = _session()
        a = sess.from_dense(_chain_factors()[0])
        with pytest.raises(ValueError, match="two"):
            multiply_chain([a])
        with pytest.raises(ValueError, match="plain"):
            multiply_chain([a, sess.from_dense(_spd("banded"), upper=True)])
        with pytest.raises(ValueError, match="target"):
            TauPolicy(target=-1.0)
        with pytest.raises(ValueError, match="safety"):
            TauPolicy(target=1.0, safety=0.5)


# ------------------------------------------------------------------- scf
class TestSCF:
    def _fock(self, seed=11):
        rng = np.random.default_rng(seed)
        idx = np.arange(N)
        f = -np.exp(-0.4 * np.abs(np.subtract.outer(idx, idx)))
        f += 0.05 * rng.standard_normal((N, N))
        return (f + f.T) / 2.0

    def _reference(self, f, s, n_occ):
        z = np.linalg.solve(np.linalg.cholesky(s).T, np.eye(N))
        w, v = np.linalg.eigh(z.T @ f @ z)
        c = v[:, :n_occ]
        return z @ (c @ c.T) @ z.T

    @pytest.mark.parametrize("engine", ENGINES)
    def test_density_matches_dense_reference(self, engine):
        f, s = self._fock(), _spd("banded")
        n_occ = N // 2
        sess = _session(engine, lazy=True)
        D, rep = scf_density(sess, f, s, n_occ, tol=1e-6)
        assert rep.converged
        assert abs(rep.occupation - n_occ) <= 1e-3
        assert rep.factor.residual <= 1e-4
        np.testing.assert_allclose(D.to_dense(),
                                   self._reference(f, s, n_occ),
                                   atol=5e-3, rtol=5e-3)

    def test_unchanged_structure_replays_zero_tasks(self):
        f, s = self._fock(), _spd("banded")
        sess = _session(lazy=True)
        _, rep = scf_density(sess, f, s, N // 2, tol=1e-6)
        assert rep.sp2_iterations > 2
        assert rep.replay_tasks == 0, (
            "structure-preserving SP2 replays registered "
            f"{rep.replay_tasks} new tasks")
        assert rep.recompile_misses == 0 and rep.recompile_hits == 0

    @pytest.mark.parametrize("engine", ENGINES)
    def test_drifting_structure_recompiles_with_successor_reuse(
            self, engine):
        """Denser and sparser rebinds both route through recompile=True;
        repeated structures hit the successor cache (metrics source
        "plan-recompile")."""
        base = values_for_mask(banded_mask(N, 10), seed=1) * 0.1
        rng = np.random.default_rng(2)
        denser = base + 0.05 * rng.standard_normal((N, N))   # full support
        sparser = values_for_mask(random_mask(N, 0.04, seed=3), seed=4) * 0.1
        sess = _session(engine, lazy=True)
        X = sess.from_dense(base, name="X")
        plan = sess.compile(X @ X)
        np.testing.assert_allclose(plan.run().to_dense(), base @ base, **TOL)
        # sparser first: once a full-support successor exists it absorbs
        # every subset-support rebind, which would mask the sparser miss
        for x in (sparser, denser, sparser * 2.0, denser * 0.5):
            out = plan.run(X=x, recompile=True).to_dense()
            np.testing.assert_allclose(out, x @ x, **TOL)
        ms = {m.source: m for m in sess.metrics()}
        assert "plan-recompile" in ms, "drift never surfaced in metrics"
        got = {c.name: c.total for c in ms["plan-recompile"]}
        # two fresh structures compiled once each, then reused once each
        assert got["plan_recompile_misses"] == 2
        assert got["plan_recompile_hits"] == 2

    def test_sp2_drift_via_filter_tol(self):
        """A full SCF with inter-iteration thresholding drifts structure
        (fill-in grows past the sparse compile, then stabilizes into
        successor hits) and still converges to the reference density."""
        # decay-only Fock: dense noise would defeat the threshold
        idx = np.arange(N)
        f = -np.exp(-0.4 * np.abs(np.subtract.outer(idx, idx)))
        f = (f + f.T) / 2.0
        s = _spd("banded")
        n_occ = N // 2
        sess = _session(lazy=True)
        D, rep = scf_density(sess, f, s, n_occ, tol=1e-6, filter_tol=1e-7)
        assert rep.converged
        assert rep.recompile_misses >= 1, "thresholding never drifted"
        assert rep.recompile_hits >= 1, "no successor was ever reused"
        np.testing.assert_allclose(D.to_dense(),
                                   self._reference(f, s, n_occ),
                                   atol=5e-3, rtol=5e-3)

    def test_requires_lazy_session(self):
        with pytest.raises(ValueError, match="lazy"):
            scf_density(_session(), self._fock(), _spd("banded"), N // 2)

    def test_report_schema(self):
        f, s = self._fock(), _spd("banded")
        sess = _session(lazy=True)
        _, rep = scf_density(sess, f, s, N // 2, tol=1e-5)
        d = rep.to_dict()
        assert d["schema"] == 1
        assert d["factor"]["schema"] == 1
        assert len(d["traces"]) == rep.sp2_iterations + 1
