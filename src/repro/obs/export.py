"""Exporters: Chrome trace-event JSON (Perfetto-loadable) + text report.

Everything here emits the Trace Event Format that ``chrome://tracing``
and https://ui.perfetto.dev load directly: a ``{"traceEvents": [...]}``
object whose events are complete slices (``"ph": "X"`` with ``ts``/
``dur`` in microseconds), counter samples (``"ph": "C"``) and metadata
rows (``"ph": "M"``) naming processes/threads.  Three sources export:

* :func:`sim_trace_events` — the runtime simulator's
  :class:`~repro.runtime.trace.Trace`: one *process* ("simulator"),
  workers as threads/tracks, every simulated task as a slice carrying
  its communication attributes, plus per-worker cumulative
  ``bytes_received`` counter tracks (the Figs 11-13 quantity over time);
* :func:`span_events` — a recording :class:`~repro.obs.tracer.Tracer`:
  each span track as a thread, spans as slices (nesting renders
  natively since child slices sit inside their parents' intervals);
* :func:`mesh_stats_events` — a mesh engine :meth:`stats` dict:
  devices as threads, waves as slices laid out on the measured
  cumulative wall clock, with per-device counter tracks for the
  measured fetched/pushed/collective bytes.

All assemblers sort events by timestamp (tests assert monotonicity) and
:func:`write_chrome_trace` writes the loadable file.
"""
from __future__ import annotations

import json
import pathlib
from typing import Optional

from .metrics import MetricSet

__all__ = ["sim_trace_events", "span_events", "mesh_stats_events",
           "chrome_trace", "write_chrome_trace", "text_report"]

#: stable process ids per source so combined traces don't collide
PID_SPANS, PID_SIM, PID_MESH = 0, 1, 2


def _meta(pid: int, name: str, tid: Optional[int] = None,
          tname: Optional[str] = None) -> list[dict]:
    ev = [{"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
           "args": {"name": name}}]
    if tid is not None:
        ev.append({"name": "thread_name", "ph": "M", "pid": pid,
                   "tid": tid, "args": {"name": tname}})
    return ev


def sim_trace_events(trace, counters: bool = True) -> list[dict]:
    """Trace events of one simulated phase: workers as tracks.

    ``trace`` is a :class:`~repro.runtime.trace.Trace`; virtual seconds
    map to trace microseconds.  With ``counters=True`` each worker also
    gets a cumulative ``bytes_received`` counter track sampled at every
    task completion.
    """
    events: list[dict] = _meta(PID_SIM, "simulator (virtual time)")
    for w in range(trace.n_workers):
        events += _meta(PID_SIM, "", w, f"worker {w}")[1:]
    received = [0] * trace.n_workers
    for ev in trace.events:
        events.append({
            "name": ev.kind, "ph": "X", "pid": PID_SIM, "tid": ev.worker,
            "ts": ev.start * 1e6, "dur": max(ev.end - ev.start, 0.0) * 1e6,
            "args": {"nid": ev.nid, "stolen": ev.stolen,
                     "remote_bytes": ev.remote_bytes,
                     "remote_msgs": ev.remote_msgs,
                     "pushed_bytes": ev.pushed_bytes},
        })
        if counters:
            received[ev.worker] += ev.remote_bytes
            events.append({
                "name": f"bytes_received w{ev.worker}", "ph": "C",
                "pid": PID_SIM, "tid": ev.worker, "ts": ev.end * 1e6,
                "args": {"bytes": received[ev.worker]},
            })
    return events


def span_events(tracer) -> list[dict]:
    """Trace events of a recording tracer: span tracks as threads."""
    events: list[dict] = _meta(PID_SPANS, "spans (wall time)")
    tids: dict[str, int] = {}
    for sp in tracer.ordered():
        tid = tids.get(sp.track)
        if tid is None:
            tid = tids[sp.track] = len(tids)
            events += _meta(PID_SPANS, "", tid, sp.track)[1:]
        events.append({
            "name": sp.name, "ph": "X", "pid": PID_SPANS, "tid": tid,
            "ts": sp.t0 * 1e6, "dur": max(sp.duration, 0.0) * 1e6,
            "args": dict(sp.attrs),
        })
    return events


def mesh_stats_events(stats: dict) -> list[dict]:
    """Trace events of a mesh run: devices as tracks, waves as slices.

    Wave slices are laid out sequentially on the measured cumulative
    wall clock (``wall_s`` per wave).  When the per-wave counter deltas
    are present in ``comm_log`` (``fetched_bytes_by_dev`` etc.), each
    device gets cumulative counter tracks of the measured bytes — the
    Table-1 metric over time.
    """
    n_dev = int(stats.get("n_dev") or 0)
    events: list[dict] = _meta(PID_MESH, "mesh devices (measured)")
    for d in range(n_dev):
        events += _meta(PID_MESH, "", d, f"device {d}")[1:]
    cum = {"fetched_bytes": [0] * n_dev, "pushed_bytes": [0] * n_dev,
           "collective_bytes": [0] * n_dev}
    t = 0.0
    waves = stats.get("wave_log", [])
    comm = stats.get("comm_log", [])
    for i, w in enumerate(waves):
        c = comm[i] if i < len(comm) else {}
        dur = float(w.get("wall_s", 0.0))
        for d in range(n_dev):
            events.append({
                "name": f"wave {i} (bs={w.get('bs')})", "ph": "X",
                "pid": PID_MESH, "tid": d, "ts": t * 1e6, "dur": dur * 1e6,
                "args": {k: w[k] for k in ("kernel", "tasks", "pairs",
                                           "padded_pairs", "c_blocks")
                         if k in w},
            })
            for key in cum:
                deltas = c.get(f"{key}_by_dev")
                if deltas is None:
                    continue
                cum[key][d] += deltas[d]
                events.append({
                    "name": f"{key} d{d}", "ph": "C", "pid": PID_MESH,
                    "tid": d, "ts": (t + dur) * 1e6,
                    "args": {"bytes": cum[key][d]},
                })
        t += dur
    return events


def chrome_trace(*event_lists) -> dict:
    """Assemble event lists into one loadable trace object.

    Metadata events sort first (ts 0); slice/counter events are sorted
    by timestamp so the stream is monotone (asserted by tests).
    """
    meta, timed = [], []
    for evs in event_lists:
        for ev in evs:
            (meta if ev.get("ph") == "M" else timed).append(ev)
    timed.sort(key=lambda ev: ev["ts"])
    return {"traceEvents": meta + timed, "displayTimeUnit": "ms"}


def write_chrome_trace(path, *event_lists) -> pathlib.Path:
    """Write a ``.trace.json`` file Perfetto/chrome://tracing can load.

    Accepts raw event lists or an already-assembled trace object.
    """
    if len(event_lists) == 1 and isinstance(event_lists[0], dict):
        obj = event_lists[0]
    else:
        obj = chrome_trace(*event_lists)
    path = pathlib.Path(path)
    path.write_text(json.dumps(obj, indent=1, sort_keys=True) + "\n")
    return path


def text_report(*metric_sets, title: str = "metrics") -> str:
    """Compact fixed-width table of one or more :class:`MetricSet`."""
    lines = [f"== {title} =="]
    for ms in metric_sets:
        if not isinstance(ms, MetricSet):
            ms = MetricSet.from_dict(ms)
        if ms.source:
            lines.append(f"-- {ms.source}")
        lines.append(f"{'counter':<22} {'unit':<7} {'total':>14} "
                     f"{'max/worker':>14} {'workers':>8}")
        for c in ms:
            tot = f"{c.total:.6g}" if isinstance(c.total, float) \
                else f"{c.total}"
            mx = f"{c.max:.6g}" if isinstance(c.max, float) else f"{c.max}"
            lines.append(f"{c.name:<22} {c.unit:<7} {tot:>14} {mx:>14} "
                         f"{len(c.per_worker):>8}")
    return "\n".join(lines)
