"""Structured span tracer: nested timed spans with attributes (DESIGN.md §8).

One tracing substrate for the whole stack.  A :class:`Span` is a named,
timed interval with attributes, a *track* (the Perfetto row it renders
on) and a nesting depth; the taxonomy threaded through the repo is::

    session.simulate                 api/session.py   one simulator phase
    plan.compile / plan.run          api/plan.py      lowering vs (re)execution
      plan.rebind / plan.replay     api/plan.py      run sub-phases
    qt.multiply / qt.from_dense ...  core/multiply.py, core/quadtree.py
    engine.flush                     core/tasks.py    deferred-wave drain
      engine.wave                   core/engine.py   one cross-leaf batch
        kernel.dispatch             core/engine.py   the fused kernel call
        collective.ppermute         launch/mesh_exec ring-shift shipments

Tracing is **off by default**: every instrumented call site holds a
:data:`NOOP` tracer whose :meth:`~NoopTracer.span` returns a shared,
stateless context manager — no allocation beyond the argument dict, no
timing calls, no growth.  The no-op path changes *nothing* observable
(task graph, schedule, counters); ``Session(trace=True)`` or
``Session.tracing()`` swaps in a recording :class:`Tracer`.

Design constraints (enforced by tests/test_obs.py and
benchmarks/bench_profile_overhead.py):

* spans are **coarse** — per plan run, per simulator phase, per engine
  wave; never per task — so the recording overhead stays < 3% on a
  registration-bound workload;
* instrumentation is purely additive: it never touches RNG state,
  registration order, or chunk contents;
* span records are plain data (name, t0, t1, track, depth, attrs) so
  exporters (:mod:`repro.obs.export`) need no back-references.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

__all__ = ["Span", "Tracer", "NoopTracer", "NOOP"]


@dataclasses.dataclass
class Span:
    """One closed span: a timed interval on a track, with attributes."""
    name: str
    t0: float               # seconds since the tracer's epoch
    t1: float
    track: str = "main"
    depth: int = 0          # nesting depth at open time (0 = top level)
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        return {"name": self.name, "t0": self.t0, "t1": self.t1,
                "track": self.track, "depth": self.depth,
                "attrs": dict(self.attrs)}


class _LiveSpan:
    """An open span (the ``with tracer.span(...)`` handle)."""

    __slots__ = ("_tr", "name", "track", "attrs", "_t0", "_depth")

    def __init__(self, tr: "Tracer", name: str, track: str, attrs: dict):
        self._tr = tr
        self.name = name
        self.track = track
        self.attrs = attrs

    def set(self, **attrs) -> "_LiveSpan":
        """Attach (or update) attributes; chainable, valid until close."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "_LiveSpan":
        self._depth = len(self._tr._stack)
        self._tr._stack.append(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter()
        tr = self._tr
        tr._stack.pop()
        tr.spans.append(Span(self.name, self._t0 - tr.epoch,
                             t1 - tr.epoch, self.track, self._depth,
                             self.attrs))
        return False


class Tracer:
    """Recording tracer: collects :class:`Span` records in close order.

    >>> tr = Tracer()
    >>> with tr.span("plan.run", runs=1) as sp:
    ...     with tr.span("engine.wave", track="engine"):
    ...         pass
    ...     sp.set(tasks=42)
    >>> [s.name for s in tr.spans]
    ['engine.wave', 'plan.run']

    Spans close inner-first; :meth:`ordered` returns them sorted by start
    time (the order exporters want).  ``epoch`` is the perf_counter value
    at construction, so all ``t0``/``t1`` are small relative offsets.
    """

    enabled = True

    def __init__(self):
        self.spans: list[Span] = []
        self._stack: list[_LiveSpan] = []
        self.epoch = time.perf_counter()

    def span(self, name: str, track: str = "main", **attrs) -> _LiveSpan:
        """Open a nested span; use as a context manager."""
        return _LiveSpan(self, name, track, attrs)

    def instant(self, name: str, track: str = "main", **attrs) -> None:
        """Record a zero-duration marker (Perfetto instant event)."""
        t = time.perf_counter() - self.epoch
        self.spans.append(Span(name, t, t, track, len(self._stack), attrs))

    def ordered(self) -> list[Span]:
        """Spans sorted by start time (stable for equal starts)."""
        return sorted(self.spans, key=lambda s: s.t0)

    def find(self, name: str) -> list[Span]:
        """All closed spans with this name, in close order."""
        return [s for s in self.spans if s.name == name]

    def total(self, name: str) -> float:
        """Summed duration of all spans with this name."""
        return sum(s.duration for s in self.spans if s.name == name)

    def clear(self) -> None:
        self.spans.clear()

    def __len__(self) -> int:
        return len(self.spans)


class _NoopSpan:
    """Shared, stateless stand-in for a live span (no timing, no record)."""

    __slots__ = ()

    def set(self, **attrs) -> "_NoopSpan":
        return self

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NOOP_SPAN = _NoopSpan()


class NoopTracer:
    """The default tracer: every operation is a near-zero-cost no-op.

    ``spans`` is an empty tuple (shared, immutable) so reporting code can
    treat both tracer kinds uniformly.
    """

    enabled = False
    spans: tuple = ()

    def span(self, name: str, track: str = "main", **attrs) -> _NoopSpan:
        return _NOOP_SPAN

    def instant(self, name: str, track: str = "main", **attrs) -> None:
        pass

    def ordered(self) -> list:
        return []

    def find(self, name: str) -> list:
        return []

    def total(self, name: str) -> float:
        return 0.0

    def clear(self) -> None:
        pass

    def __len__(self) -> int:
        return 0


#: process-wide shared no-op tracer; identity-comparable (`tr is NOOP`)
NOOP = NoopTracer()


def as_tracer(spec) -> "Tracer | NoopTracer":
    """Resolve a trace spec: False/None -> NOOP, True -> new Tracer,
    an existing tracer instance passes through."""
    if spec is None or spec is False:
        return NOOP
    if spec is True:
        return Tracer()
    if isinstance(spec, (Tracer, NoopTracer)):
        return spec
    raise ValueError(f"trace: expected bool or a Tracer, got {spec!r}")
