"""Observability layer: span tracing, unified counters, Perfetto export.

See DESIGN.md §8.  Three small modules:

* :mod:`repro.obs.tracer` — nested timed spans with attributes; a
  shared no-op tracer (:data:`NOOP`) is the default everywhere.
* :mod:`repro.obs.metrics` — the unified counter schema
  (``name, unit, per_worker[], total``) plus converters from the
  legacy counter families (SimReport, engine stats, TruncationReport).
* :mod:`repro.obs.export` — Chrome trace-event JSON (Perfetto) from
  simulator traces, tracer spans and mesh runs; compact text report.
"""
from .tracer import NOOP, NoopTracer, Span, Tracer, as_tracer
from .metrics import (Counter, MetricSet, SCHEMA_VERSION, from_engine_stats,
                      from_sim_report, from_truncation, validate_metrics)
from .export import (chrome_trace, mesh_stats_events, sim_trace_events,
                     span_events, text_report, write_chrome_trace)

__all__ = [
    "NOOP", "NoopTracer", "Span", "Tracer", "as_tracer",
    "Counter", "MetricSet", "SCHEMA_VERSION", "from_engine_stats",
    "from_sim_report", "from_truncation", "validate_metrics",
    "chrome_trace", "mesh_stats_events", "sim_trace_events",
    "span_events", "text_report", "write_chrome_trace",
]
