"""Unified counter registry: one schema for every counter family (§8).

The repo accumulated three disjoint ways of counting the paper's central
quantity (communication volume per process) plus assorted work counters:

* the simulator's :class:`~repro.runtime.scheduler.SimReport` /
  ``WorkerStats`` (modelled bytes received/pushed, cache hits, flops);
* the mesh executor's *measured* per-device numpy counters
  (``fetched_bytes`` / ``pushed_bytes`` / ``collective_bytes`` — the
  Table-1 metric, launch/mesh_exec.py);
* per-feature dicts: the Pallas engine's wave stats and the SpAMM
  :class:`~repro.core.multiply.TruncationReport`.

This module puts them all behind one shape, so benchmarks/tests/reports
assert on one schema regardless of engine::

    {"schema": 1, "source": "simulator",
     "counters": [{"name": "bytes_received", "unit": "B",
                   "per_worker": [...], "total": ...}, ...]}

``per_worker`` is the per-worker/per-device breakdown (a single-element
list for global counters); ``total`` is always its sum.  Converters are
lossless over the counter values: ``from_sim_report(rep)`` carries
exactly the lists ``rep`` carries (pinned by tests/test_obs.py), so the
unified view reproduces the legacy numbers bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["SCHEMA_VERSION", "Counter", "MetricSet", "from_sim_report",
           "from_engine_stats", "from_truncation", "validate_metrics"]

SCHEMA_VERSION = 1


@dataclasses.dataclass
class Counter:
    """One named counter: a per-worker breakdown plus derived total."""
    name: str
    unit: str                   # "B", "blocks", "msgs", "tasks", "flop", "s"
    per_worker: list

    @property
    def total(self):
        return sum(self.per_worker)

    @property
    def max(self):
        return max(self.per_worker) if self.per_worker else 0

    def to_dict(self) -> dict:
        return {"name": self.name, "unit": self.unit,
                "per_worker": list(self.per_worker), "total": self.total}


class MetricSet:
    """Ordered registry of :class:`Counter` rows from one source."""

    def __init__(self, source: str = ""):
        self.source = source
        self._counters: dict[str, Counter] = {}

    def add(self, name: str, unit: str, per_worker) -> Counter:
        """Register a counter; a scalar becomes a one-element breakdown."""
        if isinstance(per_worker, (int, float)):
            per_worker = [per_worker]
        c = Counter(name, unit, [v for v in per_worker])
        self._counters[name] = c
        return c

    def merge(self, other: "MetricSet", prefix: str = "") -> "MetricSet":
        """Fold another set's counters in (optionally name-prefixed)."""
        for c in other:
            self.add(prefix + c.name, c.unit, c.per_worker)
        return self

    # -- mapping surface -----------------------------------------------------
    def __getitem__(self, name: str) -> Counter:
        return self._counters[name]

    def __contains__(self, name: str) -> bool:
        return name in self._counters

    def __iter__(self):
        return iter(self._counters.values())

    def __len__(self) -> int:
        return len(self._counters)

    def get(self, name: str, default=None):
        return self._counters.get(name, default)

    def names(self) -> list[str]:
        return list(self._counters)

    def __repr__(self) -> str:
        return (f"MetricSet(source={self.source!r}, "
                f"counters={self.names()})")

    # -- serialisation -------------------------------------------------------
    def to_dict(self) -> dict:
        return {"schema": SCHEMA_VERSION, "source": self.source,
                "counters": [c.to_dict() for c in self]}

    @classmethod
    def from_dict(cls, d: dict) -> "MetricSet":
        validate_metrics(d)
        ms = cls(d.get("source", ""))
        for c in d["counters"]:
            ms.add(c["name"], c["unit"], c["per_worker"])
        return ms


def validate_metrics(d: dict) -> dict:
    """Assert ``d`` has the unified metrics shape; returns it unchanged."""
    if not isinstance(d, dict) or d.get("schema") != SCHEMA_VERSION:
        raise ValueError(f"not a metrics dict (schema={SCHEMA_VERSION}): "
                         f"{type(d)} {d if isinstance(d, dict) else ''}")
    counters = d.get("counters")
    if not isinstance(counters, list):
        raise ValueError("metrics dict missing 'counters' list")
    for c in counters:
        missing = {"name", "unit", "per_worker", "total"} - set(c)
        if missing:
            raise ValueError(f"counter {c.get('name')!r} missing {missing}")
        if sum(c["per_worker"]) != c["total"]:
            raise ValueError(
                f"counter {c['name']!r}: total {c['total']} != "
                f"sum(per_worker) {sum(c['per_worker'])}")
    return d


# ---------------------------------------------------------------------------
# Converters from the legacy counter families
# ---------------------------------------------------------------------------

def from_sim_report(rep) -> MetricSet:
    """Unified view of a :class:`~repro.runtime.scheduler.SimReport`.

    The per-worker lists are carried over verbatim: ``bytes_received`` is
    the paper's cache-miss communication metric (Figs 11-13), identical
    to ``rep.bytes_received``.
    """
    ms = MetricSet("simulator")
    ms.add("bytes_received", "B", rep.bytes_received)
    ms.add("bytes_pushed", "B", rep.bytes_pushed)
    ms.add("messages_received", "msgs", rep.messages_received)
    ms.add("cache_hits", "hits", rep.cache_hits)
    ms.add("dedup_hits", "hits", rep.dedup_hits)
    ms.add("peak_owned_bytes", "B", rep.peak_owned)
    ms.add("tasks_executed", "tasks", rep.tasks_per_worker)
    ms.add("flops_executed", "flop", rep.flops_executed)
    ms.add("busy_time", "s", rep.busy_time)
    ms.add("steals", "steals", rep.steals)
    ms.add("makespan", "s", rep.makespan)
    # recovery counters (DESIGN.md §10) appear only when a fault was
    # actually injected, so fault-free metric sets — including the pinned
    # bit-for-bit artifact reproductions — keep their exact legacy shape
    if getattr(rep, "fault_events", None) or getattr(rep, "workers_failed",
                                                     None):
        ms.add("workers_failed", "workers", len(rep.workers_failed))
        ms.add("chunks_lost", "chunks", rep.chunks_lost)
        ms.add("bytes_lost", "B", rep.bytes_lost)
        ms.add("tasks_recomputed", "tasks", rep.tasks_recomputed)
        ms.add("bytes_rereplicated", "B", rep.bytes_rereplicated)
        ms.add("chunks_recovered", "chunks", rep.chunks_recovered)
    return ms


def from_engine_stats(stats: dict) -> MetricSet:
    """Unified view of a leaf engine's :meth:`stats` dict.

    Handles all three backends: the numpy engine (no wave machinery —
    an empty set tagged ``engine:numpy``), the Pallas engine (global
    wave/pair/padding/bytes counters) and the mesh engine (adds the
    measured per-device fetch/push/collective byte counters — the
    Table-1 numbers — carried over verbatim from
    :meth:`~repro.launch.mesh_exec.MeshEngine.stats`).
    """
    ms = MetricSet(f"engine:{stats.get('backend', 'numpy')}")
    if "waves" in stats:
        ms.add("waves", "waves", stats["waves"])
        ms.add("batched_pairs", "pairs", stats["batched_pairs"])
        ms.add("padded_pairs", "pairs", stats["padded_pairs"])
        ms.add("c_blocks", "blocks", stats["c_blocks"])
        ms.add("kernel_wall_s", "s", stats["kernel_wall_s"])
        ms.add("bytes_packed", "B", stats["bytes_packed"])
    # mesh executor: measured per-device communication counters
    for name, unit in (("fetched_bytes", "B"), ("fetched_blocks", "blocks"),
                       ("pushed_bytes", "B"), ("collective_bytes", "B")):
        if name in stats:
            ms.add(name, unit, stats[name])
    return ms


def from_truncation(report) -> MetricSet:
    """Unified view of a :class:`~repro.core.multiply.TruncationReport`."""
    ms = MetricSet("truncation")
    ms.add("pruned_subtrees", "subtrees", report.pruned_subtrees)
    ms.add("pruned_leaf_pairs", "pairs", report.pruned_leaf_pairs)
    ms.add("pruned_flops", "flop", report.pruned_flops)
    ms.add("error_bound", "frob", report.error_bound)
    return ms
