"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (MHA kv=32) d_ff=10240
vocab=32000, ssm_state=64; Mamba2 backbone + ONE shared attention block
applied every 6 layers (zamba2-style shared transformer block).
[arXiv:2411.15242; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32000, head_dim=80,
    mixer="mamba2", ssm_state=64, ssm_head_dim=64, d_conv=4, expand=2,
    attn_every=6, norm="rmsnorm", mlp="swiglu",
    rope_theta=10000.0,
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
    head_dim=16, ssm_state=8, ssm_head_dim=16, attn_every=2,
    dtype="float32")
