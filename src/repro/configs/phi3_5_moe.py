"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2. [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=6400, vocab=32064, head_dim=128,
    n_experts=16, top_k=2, rope_theta=10000.0, norm="rmsnorm",
    mlp="swiglu",
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab=256,
    head_dim=16, n_experts=4, top_k=2, moe_capacity_factor=8.0, dtype="float32")
