"""llama3.2-3b [dense] — 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256. [hf:meta-llama/Llama-3.2-1B family; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense", n_layers=28, d_model=3072,
    n_heads=24, n_kv_heads=8, d_ff=8192, vocab=128256, head_dim=128,
    rope_theta=500000.0, norm="rmsnorm", mlp="swiglu",
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16, dtype="float32")
