"""internvl2-2b [vlm] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553; InternViT frontend is a STUB (input_specs supplies
precomputed patch embeddings), InternLM2-style LM backbone.
[arXiv:2404.16821; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, d_ff=8192, vocab=92553, head_dim=128,
    frontend="patches", n_patches=256, rope_theta=1000000.0,
    norm="rmsnorm", mlp="swiglu",
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16, n_patches=8, dtype="float32")
