"""falcon-mamba-7b [ssm] — 64L d_model=4096 attention-free d_ff=0
vocab=65024, ssm_state=16 (Mamba1 architecture). [arXiv:2410.05355;
unverified]

Arch-applicability note (DESIGN.md): the paper's attention/banded
block-sparse technique does not apply to the attention-free mixer; the
SSM scan is the mixer. Included per instructions."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab=65024, mixer="mamba1",
    ssm_state=16, d_conv=4, expand=2, norm="rmsnorm",
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, d_model=32, vocab=128, ssm_state=4, dtype="float32")
