"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000, head_dim=128,
    n_experts=8, top_k=2, swa_window=4096, rope_theta=1000000.0,
    norm="rmsnorm", mlp="swiglu",
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16, n_experts=4, top_k=2, moe_capacity_factor=8.0, swa_window=32, dtype="float32")
