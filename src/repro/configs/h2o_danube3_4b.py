"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000; llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense", n_layers=24, d_model=3840,
    n_heads=32, n_kv_heads=8, d_ff=10240, vocab=32000, head_dim=120,
    swa_window=4096, rope_theta=10000.0, norm="rmsnorm", mlp="swiglu",
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16, swa_window=32, dtype="float32")
