"""hubert-xlarge [audio] — 48L d_model=1280 16H (MHA) d_ff=5120 vocab=504;
encoder-only (bidirectional), gelu MLP; the conv waveform frontend is a
STUB (input_specs supplies precomputed frame embeddings).
[arXiv:2106.07447; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio", n_layers=48, d_model=1280,
    n_heads=16, n_kv_heads=16, d_ff=5120, vocab=504, head_dim=80,
    causal=False, frontend="frames", norm="rmsnorm", mlp="gelu",
    tie_embeddings=False, rope_theta=10000.0,
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=64,
    head_dim=16, dtype="float32")
