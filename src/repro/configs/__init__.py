"""Assigned-architecture registry: ``get_config(arch_id)``.

One module per architecture (exact configs from the task brief, sources in
each file's docstring).  ``--arch <id>`` in the launchers resolves here.
"""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "llama3_2_3b",
    "stablelm_12b",
    "h2o_danube3_4b",
    "olmo_1b",
    "phi3_5_moe",
    "mixtral_8x7b",
    "hubert_xlarge",
    "falcon_mamba_7b",
    "zamba2_2_7b",
    "internvl2_2b",
)

# accept the dashed names from the brief too
ALIASES = {
    "llama3.2-3b": "llama3_2_3b",
    "stablelm-12b": "stablelm_12b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "olmo-1b": "olmo_1b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "mixtral-8x7b": "mixtral_8x7b",
    "hubert-xlarge": "hubert_xlarge",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "zamba2-2.7b": "zamba2_2_7b",
    "internvl2-2b": "internvl2_2b",
}


def get_config(arch: str):
    arch = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str):
    arch = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE_CONFIG
