"""olmo-1b [dense] — 16L d_model=2048 16H (GQA kv=16 == MHA) d_ff=8192
vocab=50304; non-parametric LayerNorm. [arXiv:2402.00838; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=8192, vocab=50304, head_dim=128,
    rope_theta=10000.0, norm="nonparam_ln", mlp="swiglu",
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
    head_dim=16, dtype="float32")
