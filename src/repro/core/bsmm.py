"""Capacity-bounded block-sparse matrix-matrix multiply under jit.

The TPU rendering of the paper's multiply (Algorithm 1 + §4.1):

1. **Enumerate** surviving (i, k, j) triples hierarchically through the mask
   pyramid (quadtree NIL-pruning, cost ∝ the paper's task count);
2. **Gather** the A[i,k] and B[k,j] packed blocks (the paper's chunk fetch);
3. **Batched GEMM** all pairs at once — the paper's sum-of-outer-products /
   cuBLAS-batched-gemm structure (Fig 2), here one MXU-shaped Pallas (or
   XLA) batch matmul;
4. **Scatter-add** products into C's packed slots via segment-sum — the
   paper's addition-task tree collapsed into one associative reduction.

All shapes are static: capacities come from host-side planning
(:func:`~repro.core.blocksparse.plan_caps`) or from the §5 closed-form
bounds.  Overflow beyond capacity drops blocks (callers assert against
``count`` in tests).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .blocksparse import (BlockSparse, enumerate_pairs_flat,
                          enumerate_pairs_hier, from_dense, mask_pyramid,
                          to_dense)

GemmFn = Callable[[jax.Array, jax.Array], jax.Array]


def _default_gemm(a: jax.Array, b: jax.Array) -> jax.Array:
    """(p, bs, bs) x (p, bs, bs) batched GEMM.

    Routed through kernels.ops so the Pallas kernel (with internal block_t
    padding) runs on TPU while CPU gets the XLA reference — the same
    backend-dispatch contract as the leaf engine.
    """
    from repro.kernels import ops as kops
    return kops.batched_gemm(a, b)


def _structure_from_occupancy(mc: jax.Array, cap_c: int
                              ) -> tuple[jax.Array, jax.Array, jax.Array,
                                         jax.Array]:
    """Row-major slot numbering of an occupancy matrix (shared helper)."""
    g = mc.shape[0]
    crows, ccols = jnp.nonzero(mc, size=cap_c, fill_value=g)
    crows = crows.astype(jnp.int32)
    ccols = ccols.astype(jnp.int32)
    valid = crows < g
    cslot = jnp.full((g + 1, g + 1), -1, jnp.int32)
    cslot = cslot.at[crows, ccols].set(
        jnp.where(valid, jnp.arange(cap_c, dtype=jnp.int32), -1))
    cslot = cslot.at[g, :].set(-1).at[:, g].set(-1)
    return crows, ccols, cslot, jnp.sum(mc).astype(jnp.int32)


def compute_c_structure(mask_a: jax.Array, mask_b: jax.Array, cap_c: int
                        ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Occupancy of C = A @ B: rows, cols, slot map, count (jit-compatible).

    The boolean matmul is the one-shot equivalent of the create-from-ids
    task tree: it tells us which C blocks exist before any flop is spent.
    """
    mc = (jnp.matmul(mask_a.astype(jnp.int32), mask_b.astype(jnp.int32)) > 0)
    return _structure_from_occupancy(mc, cap_c)


def compute_c_structure_norms(norm_a: jax.Array, norm_b: jax.Array,
                              tau: float, cap_c: int
                              ) -> tuple[jax.Array, jax.Array, jax.Array,
                                         jax.Array]:
    """Norm-weighted occupancy of C = A @ B under SpAMM truncation.

    ``norm_a[i, k]`` / ``norm_b[k, j]`` are per-block Frobenius norms
    (0 for structurally absent blocks).  Block C[i, j] survives iff some
    inner index k satisfies ``norm_a[i, k] * norm_b[k, j] >= tau`` — a
    max-times ("tropical") matmul replacing the boolean one, evaluated
    as one einsum-free broadcast so it stays jit-compatible.  ``tau <= 0``
    delegates to the exact :func:`compute_c_structure` on the nonzero
    masks (the ``>= tau`` test would otherwise mark every cell occupied,
    absent blocks included).
    """
    if tau <= 0.0:
        return compute_c_structure(norm_a > 0, norm_b > 0, cap_c)
    # max over k of norm_a[i,k] * norm_b[k,j]: (g,g) @ (g,g) tropical product
    best = jnp.max(norm_a[:, :, None] * norm_b[None, :, :], axis=1)
    mc = best >= tau
    return _structure_from_occupancy(mc, cap_c)


def bsmm(a: BlockSparse, b: BlockSparse, *,
         pair_caps: Sequence[int], cap_c: int,
         gemm_fn: Optional[GemmFn] = None,
         hierarchical: bool = True,
         use_pair_kernel: bool = False,
         interpret: bool = False) -> tuple[BlockSparse, dict]:
    """C = A @ B, block-sparse x block-sparse -> block-sparse.

    ``use_pair_kernel=True`` runs the fused Pallas gather-GEMM-scatter
    (kernels/bsmm_pairs.py) instead of gather + batched GEMM + segment-sum.
    Returns (C, info); info carries the dynamic counts (pairs, c blocks) so
    callers can assert no capacity overflow occurred.
    """
    assert a.grid == b.grid and a.bs == b.bs
    g, bs = a.grid, a.bs
    gemm = gemm_fn or _default_gemm

    mask_a, mask_b = a.mask(), b.mask()
    if hierarchical:
        pairs, n_pairs = enumerate_pairs_hier(mask_a, mask_b, pair_caps)
    else:
        pairs, n_pairs = enumerate_pairs_flat(mask_a, mask_b, pair_caps[-1])

    crows, ccols, cslot, n_c = compute_c_structure(mask_a, mask_b, cap_c)

    pi, pk, pj = pairs[:, 0], pairs[:, 1], pairs[:, 2]
    # slot lookups; padding triples (coords == g) resolve to -1
    sa = a.slot[pi, pk]
    sb = b.slot[pk, pj]
    sc = cslot[pi, pj]
    pvalid = (sa >= 0) & (sb >= 0) & (sc >= 0)
    seg = jnp.where(pvalid, sc, cap_c)          # park invalid in extra bin

    if use_pair_kernel:
        from repro.kernels import ops as kops
        order = jnp.argsort(seg)                # kernel needs ascending seg
        c_blocks = kops.bsmm_pairs(
            a.blocks, b.blocks,
            jnp.maximum(sa, 0)[order], jnp.maximum(sb, 0)[order],
            seg[order], cap_c=cap_c, use_pallas=True, interpret=interpret)
    else:
        a_blocks = a.blocks[jnp.maximum(sa, 0)]
        b_blocks = b.blocks[jnp.maximum(sb, 0)]
        prods = gemm(a_blocks, b_blocks)
        prods = jnp.where(pvalid[:, None, None], prods, 0)
        c_blocks = jax.ops.segment_sum(
            prods, seg, num_segments=cap_c + 1)[:cap_c]

    c = BlockSparse(c_blocks.astype(a.blocks.dtype), crows, ccols, n_c, cslot)
    return c, {"n_pairs": n_pairs, "n_c_blocks": n_c,
               "pair_cap": pairs.shape[0], "c_cap": cap_c}


def bsmm_dense_ref(a_dense: jax.Array, b_dense: jax.Array) -> jax.Array:
    """Oracle: plain dense product."""
    return a_dense @ b_dense


@partial(jax.jit, static_argnames=("bs", "cap_a", "cap_b", "cap_c",
                                   "pair_caps", "hierarchical"))
def bsmm_from_dense(a_dense: jax.Array, b_dense: jax.Array, *, bs: int,
                    cap_a: int, cap_b: int, cap_c: int,
                    pair_caps: tuple, hierarchical: bool = True
                    ) -> tuple[jax.Array, dict]:
    """End-to-end jit: pack -> multiply -> unpack (test/bench convenience)."""
    a = from_dense(a_dense, bs, cap_a)
    b = from_dense(b_dense, bs, cap_b)
    c, info = bsmm(a, b, pair_caps=list(pair_caps), cap_c=cap_c)
    return to_dense(c), info


# ---------------------------------------------------------------------------
# Work accounting (bridges to §5 / Figs 3-4 at the block level)
# ---------------------------------------------------------------------------

def pair_counts_per_level(mask_a: np.ndarray, mask_b: np.ndarray
                          ) -> dict[int, int]:
    """Exact surviving-triple counts per quadtree level for C = A B.

    Level convention matches the paper: 0 = root, L = leaf.  These equal the
    paper's multiplication-task counts when blocksize == leaf size.
    """
    from .blocksparse import _np_pyramid
    pyr_a = _np_pyramid(np.asarray(mask_a))
    pyr_b = _np_pyramid(np.asarray(mask_b))
    L = len(pyr_a) - 1
    out = {}
    for l in range(L + 1):
        a_l = pyr_a[L - l].astype(np.int64)
        b_l = pyr_b[L - l].astype(np.int64)
        out[l] = int((a_l.sum(0) * b_l.sum(1)).sum())
    return out


def useful_flops(mask_a: np.ndarray, mask_b: np.ndarray, bs: int) -> float:
    """2 * bs^3 * (# leaf-level pairs): the flops a perfect engine performs."""
    counts = pair_counts_per_level(mask_a, mask_b)
    return 2.0 * bs ** 3 * counts[max(counts)]
