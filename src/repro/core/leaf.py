"""Block-sparse leaf matrix type (paper §4.1).

Faithful host-side implementation of the paper's leaf matrix library:

* uniform blocksize ``bs`` (paper targets 16-64); only nonzero ``bs x bs``
  submatrix blocks are allocated;
* multiplication is expressed as a **sum of outer products** (paper Fig 2):
  for every inner block index k, the batch of independent small GEMMs
  ``C[i,j] += A[i,k] @ B[k,j]`` is executed together — this is the structure
  the paper maps onto the cuBLAS batched-gemm API, and the structure our
  Pallas leaf kernel (kernels/batched_gemm.py) maps onto the MXU;
* symmetric operations (symmetric square, symmetric rank-k, symmetric
  multiply) operate on **upper-triangular block storage** and exploit symmetry
  to halve the multiply count (paper §3.3, Fig 9 right).

Everything is deterministic and validated against dense numpy in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import numpy as np


@dataclasses.dataclass
class LeafStats:
    """Work counters accumulated by leaf operations (feeds Figs 5-9)."""
    block_multiplies: int = 0
    flops: float = 0.0
    batches: int = 0

    def add(self, other: "LeafStats") -> None:
        self.block_multiplies += other.block_multiplies
        self.flops += other.flops
        self.batches += other.batches


class LeafMatrix:
    """Block-sparse matrix with uniform blocksize; dict of nonzero blocks.

    ``blocks[(i, j)]`` is the dense ``bs x bs`` block at block-row i /
    block-col j.  ``upper=True`` marks symmetric upper-triangular block
    storage: only blocks with i <= j are present and the full matrix is
    ``U + U^T - diag(U)`` with symmetric diagonal blocks.
    """

    __slots__ = ("n", "bs", "blocks", "upper", "dtype",
                 "_bnorm2", "_norm2_tot", "_trace", "_version")

    def __init__(self, n: int, bs: int, blocks: Optional[dict] = None,
                 upper: bool = False, dtype=np.float64):
        assert n % bs == 0, "leaf dimension must be divisible by blocksize"
        self.n = n
        self.bs = bs
        self.blocks: dict[tuple[int, int], np.ndarray] = blocks or {}
        self.upper = upper
        self.dtype = dtype
        # squared-Frobenius norm caches (per stored block + total), filled
        # lazily and dropped by invalidate_norms() whenever block data is
        # mutated in place (engine wave fills, deferred adds/transposes);
        # the trace cache follows the same lifecycle
        self._bnorm2: Optional[dict[tuple[int, int], float]] = None
        self._norm2_tot: Optional[float] = None
        self._trace: Optional[float] = None
        # monotone mutation counter: bumped with every cache
        # invalidation so device-resident copies of this leaf's blocks
        # (mesh engine) can detect staleness without hashing values
        self._version = 0

    # -- construction -------------------------------------------------------
    @classmethod
    def from_dense(cls, a: np.ndarray, bs: int, upper: bool = False,
                   tol: float = 0.0) -> "LeafMatrix":
        n = a.shape[0]
        assert a.shape == (n, n)
        g = n // bs
        m = cls(n, bs, upper=upper, dtype=a.dtype)
        for i in range(g):
            j0 = i if upper else 0
            for j in range(j0, g):
                blk = a[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs]
                if np.any(np.abs(blk) > tol):
                    m.blocks[(i, j)] = np.ascontiguousarray(blk)
        return m

    def to_dense(self) -> np.ndarray:
        a = np.zeros((self.n, self.n), dtype=self.dtype)
        bs = self.bs
        for (i, j), blk in self.blocks.items():
            a[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs] = blk
        if self.upper:
            full = a + a.T
            d = np.arange(self.n)
            # diagonal blocks were stored full & symmetric: undo the doubling
            g = self.n // bs
            for i in range(g):
                if (i, i) in self.blocks:
                    blk = self.blocks[(i, i)]
                    full[i * bs:(i + 1) * bs, i * bs:(i + 1) * bs] = blk
            _ = d
            return full
        return a

    # -- bookkeeping ---------------------------------------------------------
    def nbytes(self) -> int:
        itemsize = np.dtype(self.dtype).itemsize
        return len(self.blocks) * self.bs * self.bs * itemsize + 32

    @property
    def grid(self) -> int:
        return self.n // self.bs

    def n_nonzero_blocks(self) -> int:
        return len(self.blocks)

    def fill_factor(self) -> float:
        return len(self.blocks) / max(1, self.grid ** 2)

    def is_zero(self) -> bool:
        return not self.blocks

    # -- norm caches (truncated multiply, DESIGN.md §5) ----------------------
    def block_norm2(self, key: tuple[int, int]) -> float:
        """Squared Frobenius norm of one stored block, cached.

        The cache is what makes SpAMM-style pruning cheap: the truncated
        multiply queries every candidate block pair, but each block is
        reduced once.
        """
        if self._bnorm2 is None:
            self._bnorm2 = {}
        v = self._bnorm2.get(key)
        if v is None:
            blk = self.blocks[key]
            v = float((blk * blk).sum())
            self._bnorm2[key] = v
        return v

    def norm2(self) -> float:
        """Squared Frobenius norm of the *stored* blocks, cached.

        For upper-triangular storage this is the norm of the stored upper
        triangle; the full symmetric norm (off-diagonal blocks counted
        twice) is assembled at the quadtree layer (qt_norm2).
        """
        if self._norm2_tot is None:
            self._norm2_tot = float(
                sum(self.block_norm2(k) for k in self.blocks))
        return self._norm2_tot

    def trace(self) -> float:
        """Trace of the leaf, cached like :meth:`norm2`.

        Only diagonal blocks contribute; for upper-triangular storage the
        diagonal blocks are stored full, so the same reduction applies.
        """
        if self._trace is None:
            self._trace = float(sum(
                np.trace(blk) for (i, j), blk in self.blocks.items()
                if i == j))
        return self._trace

    def invalidate_norms(self) -> None:
        """Drop norm/trace caches after in-place mutation of block data."""
        self._bnorm2 = None
        self._norm2_tot = None
        self._trace = None
        self._version += 1

    def frob2(self) -> float:
        return self.norm2()

    # -- structure views ------------------------------------------------------
    def cols_by_k(self) -> dict[int, list[tuple[int, np.ndarray]]]:
        """Blocks grouped by block-column (the 'k' of A in C += A[:,k] B[k,:])."""
        out: dict[int, list[tuple[int, np.ndarray]]] = {}
        for (i, j), blk in self.blocks.items():
            out.setdefault(j, []).append((i, blk))
        return out

    def rows_by_k(self) -> dict[int, list[tuple[int, np.ndarray]]]:
        out: dict[int, list[tuple[int, np.ndarray]]] = {}
        for (i, j), blk in self.blocks.items():
            out.setdefault(i, []).append((j, blk))
        return out

    def transpose(self) -> "LeafMatrix":
        assert not self.upper
        out = LeafMatrix(self.n, self.bs, dtype=self.dtype)
        for (i, j), blk in self.blocks.items():
            out.blocks[(j, i)] = np.ascontiguousarray(blk.T)
        # norms are transpose-invariant: carry the caches over (maintained,
        # not recomputed) with keys mirrored
        if self._bnorm2 is not None:
            out._bnorm2 = {(j, i): v for (i, j), v in self._bnorm2.items()}
        out._norm2_tot = self._norm2_tot
        return out

    def symmetrize_full(self) -> "LeafMatrix":
        """Expand upper-triangular storage to full block storage."""
        assert self.upper
        out = LeafMatrix(self.n, self.bs, dtype=self.dtype)
        for (i, j), blk in self.blocks.items():
            out.blocks[(i, j)] = blk
            if i != j:
                out.blocks[(j, i)] = np.ascontiguousarray(blk.T)
        return out


# ---------------------------------------------------------------------------
# Block structure allocation / unpacking — the bridge between the
# dict-of-blocks host format and the packed (P, bs, bs) arrays the batched
# kernels produce (paper §4.1: leaf data is handed to the accelerator as one
# batch; the engine gathers operands pair-wise, results come back packed).
# ---------------------------------------------------------------------------

def unpack_blocks(leaf: LeafMatrix, keys: Iterable[tuple[int, int]],
                  data: np.ndarray) -> None:
    """Fill existing blocks *in place* from a packed (P, bs, bs) array.

    In-place assignment (rather than rebinding) is what lets the engine fill
    placeholder blocks after downstream tasks already hold references.
    Norm caches computed against the zero placeholders are dropped.
    """
    for key, blk in zip(keys, data):
        leaf.blocks[key][...] = blk
    leaf.invalidate_norms()


def alloc_structure(n: int, bs: int, keys: Iterable[tuple[int, int]],
                    upper: bool = False, dtype=np.float64) -> LeafMatrix:
    """Leaf with the given block structure, all blocks zero-allocated."""
    out = LeafMatrix(n, bs, upper=upper, dtype=dtype)
    for key in keys:
        out.blocks[key] = np.zeros((bs, bs), dtype)
    return out


# ---------------------------------------------------------------------------
# Batched-GEMM schedule (Fig 2): one batch per inner block index k; all
# multiplies in a batch are independent (distinct output blocks).
# ---------------------------------------------------------------------------

def multiply_batches(a: LeafMatrix, b: LeafMatrix
                     ) -> Iterable[list[tuple[int, int, int]]]:
    """Yield, per inner index k, the batch [(i, j, k), ...] of block GEMMs."""
    a_cols = a.cols_by_k()
    b_rows = b.rows_by_k()
    for k in sorted(set(a_cols) & set(b_rows)):
        yield [(i, j, k) for i, _ in a_cols[k] for j, _ in b_rows[k]]


def leaf_multiply(a: LeafMatrix, b: LeafMatrix, ta: bool = False,
                  tb: bool = False, stats: Optional[LeafStats] = None
                  ) -> LeafMatrix:
    """C = op(A) op(B) with op in {identity, transpose} (paper §3.2).

    Executed as a sum of outer products over the inner block index: for each
    k the batch of independent block GEMMs is evaluated with one vectorised
    einsum (the host stand-in for one batched-gemm call).
    """
    assert not a.upper and not b.upper
    aa = a.transpose() if ta else a
    bb = b.transpose() if tb else b
    assert aa.n == bb.n
    out = LeafMatrix(aa.n, aa.bs, dtype=np.result_type(a.dtype, b.dtype))
    a_cols = aa.cols_by_k()
    b_rows = bb.rows_by_k()
    bs = aa.bs
    nmul = 0
    nbatch = 0
    for k in set(a_cols) & set(b_rows):
        ai, ablk = zip(*a_cols[k])
        bj, bblk = zip(*b_rows[k])
        prod = np.einsum("aik,bkj->abij", np.stack(ablk), np.stack(bblk),
                         optimize=True)
        for x, i in enumerate(ai):
            for y, j in enumerate(bj):
                cur = out.blocks.get((i, j))
                if cur is None:
                    out.blocks[(i, j)] = prod[x, y].copy()
                else:
                    cur += prod[x, y]
        nmul += len(ai) * len(bj)
        nbatch += 1
    if stats is not None:
        stats.block_multiplies += nmul
        stats.flops += 2.0 * nmul * bs ** 3
        stats.batches += nbatch
    return out


def leaf_add(a: Optional[LeafMatrix], b: Optional[LeafMatrix]
             ) -> Optional[LeafMatrix]:
    """C = A + B; either operand may be None (NIL)."""
    if a is None:
        return b
    if b is None:
        return a
    assert a.n == b.n and a.bs == b.bs and a.upper == b.upper
    out = LeafMatrix(a.n, a.bs, upper=a.upper,
                     dtype=np.result_type(a.dtype, b.dtype))
    for key, blk in a.blocks.items():
        out.blocks[key] = blk.copy()
    for key, blk in b.blocks.items():
        cur = out.blocks.get(key)
        if cur is None:
            out.blocks[key] = blk.copy()
        else:
            cur += blk
    return out


def _upper_from_full(full: LeafMatrix) -> LeafMatrix:
    out = LeafMatrix(full.n, full.bs, upper=True, dtype=full.dtype)
    for (i, j), blk in full.blocks.items():
        if i <= j:
            out.blocks[(i, j)] = blk
    return out


def leaf_sym_square(a: LeafMatrix, stats: Optional[LeafStats] = None
                    ) -> LeafMatrix:
    """C = A^2, A symmetric in upper-triangular block storage (paper §3.3).

    Exploits symmetry: only the upper triangle of C is computed.  Block pair
    (i,k),(k,j) contributes to C[i,j] with i<=j only; using A_ik = A_ki^T the
    multiply count is roughly half of the general product.
    """
    assert a.upper
    bs = a.bs
    out = LeafMatrix(a.n, bs, upper=True, dtype=a.dtype)
    full = a.symmetrize_full()  # structure view; no extra multiplies counted
    a_cols = full.cols_by_k()
    a_rows = full.rows_by_k()
    nmul = 0
    for k, col in a_cols.items():
        # C[i,j] += A[i,k] A[k,j]  for i <= j; A[k,j] = full blocks row k
        row = a_rows.get(k, [])
        for i, ablk in col:
            for j, bblk in row:
                if i > j:
                    continue  # lower triangle skipped: the symmetry saving
                cur = out.blocks.get((i, j))
                prod = ablk @ bblk
                if cur is None:
                    out.blocks[(i, j)] = prod
                else:
                    cur += prod
                nmul += 1
    if stats is not None:
        stats.block_multiplies += nmul
        stats.flops += 2.0 * nmul * bs ** 3
        stats.batches += len(a_cols)
    return out


def leaf_syrk(a: LeafMatrix, trans: bool = False,
              stats: Optional[LeafStats] = None) -> LeafMatrix:
    """C = A A^T (trans=False) or A^T A (trans=True), C upper storage."""
    assert not a.upper
    bs = a.bs
    out = LeafMatrix(a.n, bs, upper=True, dtype=a.dtype)
    # C[i,j] = sum_k A[i,k] A[j,k]^T   (or A[k,i]^T A[k,j])
    groups = a.rows_by_k() if not trans else None
    nmul = 0
    if not trans:
        rows = a.rows_by_k()
        for i in rows:
            for j in rows:
                if i > j:
                    continue
                ks = {k: blk for k, blk in rows[i]}
                for k, bjk in rows[j]:
                    if k in ks:
                        prod = ks[k] @ bjk.T
                        cur = out.blocks.get((i, j))
                        if cur is None:
                            out.blocks[(i, j)] = prod
                        else:
                            cur += prod
                        nmul += 1
    else:
        cols = a.cols_by_k()
        for i in cols:
            for j in cols:
                if i > j:
                    continue
                ks = {k: blk for k, blk in cols[i]}
                for k, bkj in cols[j]:
                    if k in ks:
                        prod = ks[k].T @ bkj
                        cur = out.blocks.get((i, j))
                        if cur is None:
                            out.blocks[(i, j)] = prod
                        else:
                            cur += prod
                        nmul += 1
    _ = groups
    if stats is not None:
        stats.block_multiplies += nmul
        stats.flops += 2.0 * nmul * bs ** 3
        stats.batches += 1
    return out


def leaf_sym_multiply(s: LeafMatrix, b: LeafMatrix, side: str = "left",
                      stats: Optional[LeafStats] = None) -> LeafMatrix:
    """C = S B (side='left') or C = B S (side='right'), S symmetric upper."""
    assert s.upper and not b.upper
    full = s.symmetrize_full()
    if side == "left":
        return leaf_multiply(full, b, stats=stats)
    return leaf_multiply(b, full, stats=stats)


def leaf_scale(a: LeafMatrix, alpha: float) -> LeafMatrix:
    out = LeafMatrix(a.n, a.bs, upper=a.upper, dtype=a.dtype)
    for key, blk in a.blocks.items():
        out.blocks[key] = alpha * blk
    return out


def inv_chol_keys(grid: int) -> list[tuple[int, int]]:
    """Deterministic block structure of a leaf inverse Cholesky factor.

    The inverse factor of a dense-diagonal SPD leaf has a full upper
    triangle in general; emitting every i <= j block (zeros included)
    regardless of the numeric values keeps the structure a function of
    the *input structure* only, so the numpy and Pallas engines build
    identical chunk trees (Plan fingerprints and rebinding rely on that).
    """
    return [(i, j) for i in range(grid) for j in range(i, grid)]


def tri_solve_keys(b_keys: Iterable[tuple[int, int]], grid: int
                   ) -> list[tuple[int, int]]:
    """Deterministic block structure of X = R^{-1} B, R upper triangular.

    Back substitution propagates block (k, j) of B upward into rows
    i <= k of X, so column j of X occupies rows 0..max_k(k, j in B).
    Like :func:`inv_chol_keys` this depends only on B's structure —
    identical across engines by construction.
    """
    top: dict[int, int] = {}
    for (k, j) in b_keys:
        top[j] = max(top.get(j, -1), k)
    return sorted((i, j) for j, kmax in top.items() for i in range(kmax + 1))


def leaf_inv_chol(s: LeafMatrix, stats: Optional[LeafStats] = None
                  ) -> LeafMatrix:
    """Z = inv(U) for S = U^T U: the leaf-level inverse Cholesky factor.

    ``s`` is an SPD leaf in symmetric upper block storage; the result is
    upper triangular in *plain* storage with the deterministic
    :func:`inv_chol_keys` structure (zero blocks kept — see there).
    """
    assert s.upper
    sd = s.to_dense()
    u = np.linalg.cholesky(sd).T                    # S = U^T U, U upper
    z = np.linalg.solve(u, np.eye(s.n, dtype=sd.dtype))
    bs = s.bs
    out = LeafMatrix(s.n, bs, dtype=sd.dtype)
    for (i, j) in inv_chol_keys(s.grid):
        out.blocks[(i, j)] = np.ascontiguousarray(
            z[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs])
    if stats is not None:
        stats.flops += float(s.n) ** 3              # ~n^3/3 chol + ~2n^3/3 inv
        stats.batches += 1
    return out


def leaf_tri_solve(r: LeafMatrix, b: LeafMatrix,
                   stats: Optional[LeafStats] = None) -> LeafMatrix:
    """X = R^{-1} B with R upper triangular (plain storage), leaf level.

    Output structure is the deterministic :func:`tri_solve_keys` set
    (zero blocks kept), so both engines agree block-for-block.
    """
    assert not r.upper and not b.upper and r.n == b.n and r.bs == b.bs
    rd = r.to_dense()
    bd = b.to_dense()
    x = np.linalg.solve(rd, bd)
    bs = r.bs
    out = LeafMatrix(r.n, bs, dtype=np.result_type(rd.dtype, bd.dtype))
    for (i, j) in tri_solve_keys(b.blocks, r.grid):
        out.blocks[(i, j)] = np.ascontiguousarray(
            x[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs])
    if stats is not None:
        stats.flops += float(r.n) ** 2 * b.grid * b.bs
        stats.batches += 1
    return out


def leaf_truncate(a: LeafMatrix, tau_frob: float) -> LeafMatrix:
    """Drop smallest blocks while ||dropped||_F <= tau (paper §6.2 truncation)."""
    items = sorted(a.blocks.items(), key=lambda kv: (kv[1] ** 2).sum())
    budget = tau_frob * tau_frob
    out = LeafMatrix(a.n, a.bs, upper=a.upper, dtype=a.dtype)
    acc = 0.0
    for key, blk in items:
        w = float((blk * blk).sum())
        if acc + w <= budget:
            acc += w
            continue
        out.blocks[key] = blk
    return out
