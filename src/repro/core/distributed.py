"""Locality-aware distributed block-sparse matmul (shard_map + ppermute).

The TPU-native rendering of the paper's central claim (Table 1): if data
and work placement *follow the quadtree*, matrices whose sparsity has
spatial locality (banded, overlap) need only **O(1) communication per
device in weak scaling**, vs O(sqrt(p)) for SUMMA-style static schedules.

Mapping (DESIGN.md §3):

* paper: chunk placement follows work-stealing over the recursive task tree
  -> here: each device owns a contiguous **Morton range** of leaf blocks —
  exactly the leaf sets of quadtree subtrees, so "placement follows the
  recursion" holds statically;
* paper: runtime fetches remote chunks on demand, chunk cache amortizes
  -> here: a **bounded halo exchange**: ``halo_hops`` ring ppermute steps
  in each direction collect every remote block a device can possibly need.
  ``halo_hops`` is computed from the actual block masks at plan time
  (sparsity detected from data, not assumed) and is O(1) for banded /
  overlap patterns regardless of p;
* paper: NIL pruning at every level (Algorithm 1 line 2)
  -> here: per-device hierarchical pair enumeration constrained to the
  device's owned C cells (mask_c pyramid).

The SUMMA baseline to compare against lives in core/spsumma.py; both lower
to HLO whose collective bytes are parsed by launch/roofline.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from . import morton
from .blocksparse import _np_pyramid, enumerate_pairs_hier, mask_pyramid


# ---------------------------------------------------------------------------
# Host-side planning: ownership, capacities, halo distance
# ---------------------------------------------------------------------------

def _balanced_owner(lin: np.ndarray, cells: int, n_dev: int) -> np.ndarray:
    """Linear cell index -> device id; balanced contiguous split.

    Device ``d`` owns cells ``[d*cells//n_dev, (d+1)*cells//n_dev)`` — sizes
    differ by at most one, every id is ``< n_dev``, and when ``n_dev``
    divides ``cells`` this reduces to the classic ``lin // per``.  Handles
    ``cells % n_dev != 0`` (the old ``lin // per`` emitted ids >= n_dev)
    and ``n_dev > cells`` (the old code divided by zero).
    """
    # closed form of the split: owner(z) = d iff
    # d*cells//n_dev <= z < (d+1)*cells//n_dev.  The traced _owned_mask
    # uses the same expression — keep them in lockstep.
    return (((lin.astype(np.int64) + 1) * n_dev - 1) // cells
            ).astype(np.int32)


def morton_owner(grid: int, n_dev: int) -> np.ndarray:
    """(grid, grid) -> device id; contiguous Morton ranges."""
    rows = np.repeat(np.arange(grid), grid)
    cols = np.tile(np.arange(grid), grid)
    z = morton.encode(rows, cols).astype(np.int64)
    return _balanced_owner(z, grid * grid, n_dev).reshape(grid, grid)


def rowmajor_owner(grid: int, n_dev: int) -> np.ndarray:
    """Non-locality-aware baseline ownership: row-major block ranges."""
    lin = np.arange(grid * grid, dtype=np.int64).reshape(grid, grid)
    return _balanced_owner(lin, grid * grid, n_dev)


@dataclasses.dataclass(frozen=True)
class DistPlan:
    """Static plan for one distributed multiply (trace-time constants)."""
    grid: int
    bs: int
    n_dev: int
    cap_d: int            # owned-block capacity per device (A and B)
    cap_c_d: int          # owned-C-block capacity per device
    halo_hops: int        # ring hops each direction
    pair_caps: tuple      # per-level pair capacities (per device)

    @property
    def halo_cap(self) -> int:
        return (2 * self.halo_hops + 1) * self.cap_d


def plan_distribution(mask_a: np.ndarray, mask_b: np.ndarray, bs: int,
                      n_dev: int, slack: float = 1.3,
                      round_to: int = 8) -> DistPlan:
    """Inspect actual block occupancy (dynamic detection, paper abstract)
    and derive all static capacities + the halo distance."""
    grid = mask_a.shape[0]
    owner = morton_owner(grid, n_dev)
    ma, mb = np.asarray(mask_a), np.asarray(mask_b)
    mc = (ma.astype(np.int64) @ mb.astype(np.int64)) > 0

    def _cap(x):
        return max(round_to,
                   int(np.ceil(x * slack / round_to)) * round_to)

    cap_d = _cap(max(np.bincount(owner[ma].ravel(), minlength=n_dev).max(),
                     np.bincount(owner[mb].ravel(), minlength=n_dev).max()))
    cap_c_d = _cap(np.bincount(owner[mc].ravel(), minlength=n_dev).max())

    # halo distance: max |owner(A[i,k]) - owner(C[i,j])| over contributing
    # pairs, same for B — measured on the coarsest level where it is cheap
    # and exact at leaf level via per-device row/col reach.
    hops = 1
    ii, kk = np.nonzero(ma)
    kk2, jj = np.nonzero(mb)
    # for each k, owners of A blocks in col k and B blocks in row k must
    # reach owners of C blocks (i, j); bound via per-cell owner differences
    oa = owner[ii, kk]
    ob = owner[kk2, jj]
    # C owners that need each A block: owners of row i of C
    ci, cj = np.nonzero(mc)
    oc = owner[ci, cj]
    row_min = np.full(grid, n_dev, np.int64)
    row_max = np.full(grid, -1, np.int64)
    np.minimum.at(row_min, ci, oc)
    np.maximum.at(row_max, ci, oc)
    col_min = np.full(grid, n_dev, np.int64)
    col_max = np.full(grid, -1, np.int64)
    np.minimum.at(col_min, cj, oc)
    np.maximum.at(col_max, cj, oc)
    ha = np.maximum(np.abs(row_max[ii] - oa), np.abs(oa - row_min[ii]))
    hb = np.maximum(np.abs(col_max[jj] - ob), np.abs(ob - col_min[jj]))
    if len(ha):
        hops = max(hops, int(ha.max()))
    if len(hb):
        hops = max(hops, int(hb.max()))
    hops = min(hops, n_dev // 2 if n_dev > 1 else 0)

    # per-level pair caps: max over devices of constrained triple counts.
    # vectorized & exact: P = A_l @ B_l counts triples per coarse C cell;
    # a coarse Morton cell covers a CONTIGUOUS device range [lo, hi] (its
    # fine cells are one Morton interval), and hierarchical enumeration
    # charges the whole cell to every device in that range -> range-add
    # via a difference array.
    levels = int(np.log2(grid))
    pyr_a, pyr_b = _np_pyramid(ma), _np_pyramid(mb)
    cells = grid * grid
    pair_caps = []
    for l in range(1, levels + 1):
        a_l = pyr_a[levels - l].astype(np.float64)
        b_l = pyr_b[levels - l].astype(np.float64)
        gl = a_l.shape[0]
        factor = grid // gl
        prod = a_l @ b_l                         # triples per C cell
        ci, cj = np.nonzero(prod > 0)
        vals = prod[ci, cj]
        z = morton.encode(ci, cj).astype(np.int64)
        # owners of the coarse cell's first/last fine Morton cell under the
        # balanced clipped split (consistent with morton_owner/_owned_mask)
        lo = ((z * factor * factor + 1) * n_dev - 1) // cells
        hi = (((z + 1) * factor * factor) * n_dev - 1) // cells
        diff = np.zeros(n_dev + 1, np.float64)
        np.add.at(diff, lo, vals)
        np.add.at(diff, np.minimum(hi + 1, n_dev), -vals)
        counts = np.cumsum(diff)[:n_dev]
        pair_caps.append(_cap(max(int(counts.max()), 8)))
    return DistPlan(grid=grid, bs=bs, n_dev=n_dev, cap_d=cap_d,
                    cap_c_d=cap_c_d, halo_hops=hops,
                    pair_caps=tuple(pair_caps))


def _coarsen_bool(m: np.ndarray, factor: int) -> np.ndarray:
    if factor == 1:
        return m
    g = m.shape[0] // factor
    return m.reshape(g, factor, g, factor).any(axis=(1, 3))


def distribute_morton(dense: np.ndarray, bs: int, plan: DistPlan,
                      owner_map: Optional[np.ndarray] = None
                      ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pack a dense matrix into per-device Morton-owned block arrays.

    Returns (blocks, rows, cols): (n_dev, cap_d, bs, bs), (n_dev, cap_d)x2,
    padding coordinates == grid.  Host-side numpy (input construction is a
    data-pipeline job; the paper does it with Chunks and Tasks programs).
    """
    grid, n_dev, cap = plan.grid, plan.n_dev, plan.cap_d
    owner = morton_owner(grid, n_dev) if owner_map is None else owner_map
    tiles = dense.reshape(grid, bs, grid, bs).transpose(0, 2, 1, 3)
    occ = np.abs(tiles).max(axis=(2, 3)) > 0
    blocks = np.zeros((n_dev, cap, bs, bs), dense.dtype)
    rows = np.full((n_dev, cap), grid, np.int32)
    cols = np.full((n_dev, cap), grid, np.int32)
    fill = np.zeros(n_dev, np.int64)
    ii, jj = np.nonzero(occ)
    for i, j in zip(ii, jj):
        d = owner[i, j]
        s = fill[d]
        assert s < cap, f"device {d} overflow (cap {cap})"
        blocks[d, s] = tiles[i, j]
        rows[d, s] = i
        cols[d, s] = j
        fill[d] += 1
    return blocks, rows, cols


def gather_dense(blocks: np.ndarray, rows: np.ndarray, cols: np.ndarray,
                 grid: int, bs: int) -> np.ndarray:
    """Inverse of distribute_morton (testing convenience)."""
    out = np.zeros((grid * bs, grid * bs), blocks.dtype)
    n_dev, cap = rows.shape
    for d in range(n_dev):
        for s in range(cap):
            i, j = rows[d, s], cols[d, s]
            if i < grid:
                out[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs] += \
                    blocks[d, s]
    return out


# ---------------------------------------------------------------------------
# The distributed multiply (per-device body under shard_map)
# ---------------------------------------------------------------------------

def _slot_map(rows: jax.Array, cols: jax.Array, grid: int) -> jax.Array:
    cap = rows.shape[0]
    slot = jnp.full((grid + 1, grid + 1), -1, jnp.int32)
    slot = slot.at[rows, cols].set(jnp.arange(cap, dtype=jnp.int32))
    return slot.at[grid, :].set(-1).at[:, grid].set(-1)


def _owned_mask(grid: int, n_dev: int, dev: jax.Array) -> jax.Array:
    """(grid, grid) bool: cells in this device's Morton range (traceable).

    Uses the closed form of the balanced clipped split — owner(z) =
    ((z+1)*n_dev - 1) // cells assigns z to device d iff
    d*cells//n_dev <= z < (d+1)*cells//n_dev — so it agrees with
    :func:`morton_owner` for every n_dev, divisible or not.
    """
    r = jax.lax.broadcasted_iota(jnp.int32, (grid, grid), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (grid, grid), 1)
    z = morton.jnp_encode(r, c).astype(jnp.int32)
    # int32 is safe while grid*grid*n_dev < 2^31 (true for any real mesh)
    owner = ((z + 1) * n_dev - 1) // (grid * grid)
    return owner == dev


def halo_spmm(mesh: Mesh, axis: str, plan: DistPlan,
              a_blocks, a_rows, a_cols, b_blocks, b_rows, b_cols,
              use_pair_kernel: bool = False, interpret: bool = False):
    """C = A @ B with Morton ownership and bounded ring halo exchange.

    All arrays carry a leading n_dev axis sharded over ``axis``.  Returns
    (c_blocks, c_rows, c_cols, n_pairs) with the same leading axis.
    Collective footprint: 2 * halo_hops ppermutes of the A and B shards —
    O(1) bytes/device in weak scaling for local patterns (Table 1).
    """
    g, bs, n_dev = plan.grid, plan.bs, plan.n_dev
    hops, cap_c = plan.halo_hops, plan.cap_c_d

    def body(ab, ar, ac, bb, br, bc):
        ab, ar, ac = ab[0], ar[0], ac[0]
        bb, br, bc = bb[0], br[0], bc[0]
        dev = jax.lax.axis_index(axis)

        def ring(x, shift):
            perm = [(i, (i + shift) % n_dev) for i in range(n_dev)]
            return jax.lax.ppermute(x, axis, perm)

        halo_ab, halo_ar, halo_ac = [ab], [ar], [ac]
        halo_bb, halo_br, halo_bc = [bb], [br], [bc]
        fa, fb = (ab, ar, ac), (bb, br, bc)
        ba, bbk = (ab, ar, ac), (bb, br, bc)
        for _ in range(hops):
            fa = tuple(ring(x, +1) for x in fa)
            ba = tuple(ring(x, -1) for x in ba)
            fb = tuple(ring(x, +1) for x in fb)
            bbk = tuple(ring(x, -1) for x in bbk)
            halo_ab += [fa[0], ba[0]]
            halo_ar += [fa[1], ba[1]]
            halo_ac += [fa[2], ba[2]]
            halo_bb += [fb[0], bbk[0]]
            halo_br += [fb[1], bbk[1]]
            halo_bc += [fb[2], bbk[2]]
        A = jnp.concatenate(halo_ab)
        Ar = jnp.concatenate(halo_ar)
        Ac = jnp.concatenate(halo_ac)
        B = jnp.concatenate(halo_bb)
        Br = jnp.concatenate(halo_br)
        Bc = jnp.concatenate(halo_bc)

        slot_a = _slot_map(Ar, Ac, g)
        slot_b = _slot_map(Br, Bc, g)
        mask_a = slot_a[:g, :g] >= 0
        mask_b = slot_b[:g, :g] >= 0
        owned = _owned_mask(g, n_dev, dev)
        mask_c = (jnp.matmul(mask_a.astype(jnp.int32),
                             mask_b.astype(jnp.int32)) > 0) & owned

        crows, ccols = jnp.nonzero(mask_c, size=cap_c, fill_value=g)
        crows, ccols = crows.astype(jnp.int32), ccols.astype(jnp.int32)
        cslot = _slot_map(crows, ccols, g)

        pairs, n_pairs = enumerate_pairs_hier(
            mask_a, mask_b, list(plan.pair_caps), mask_c=mask_c)
        pi, pk, pj = pairs[:, 0], pairs[:, 1], pairs[:, 2]
        sa, sb, sc = slot_a[pi, pk], slot_b[pk, pj], cslot[pi, pj]
        pvalid = (sa >= 0) & (sb >= 0) & (sc >= 0)
        seg = jnp.where(pvalid, sc, cap_c)

        if use_pair_kernel:
            from repro.kernels import ops as kops
            order = jnp.argsort(seg)
            cb = kops.bsmm_pairs(
                A, B, jnp.maximum(sa, 0)[order],
                jnp.maximum(sb, 0)[order], seg[order],
                cap_c=cap_c, use_pallas=True, interpret=interpret)
        else:
            prods = jnp.einsum(
                "pik,pkj->pij", A[jnp.maximum(sa, 0)],
                B[jnp.maximum(sb, 0)],
                preferred_element_type=jnp.float32).astype(A.dtype)
            prods = jnp.where(pvalid[:, None, None], prods, 0)
            cb = jax.ops.segment_sum(
                prods, seg, num_segments=cap_c + 1)[:cap_c]

        return (cb[None], crows[None], ccols[None], n_pairs[None])

    spec = P(axis)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(spec,) * 6,
        out_specs=(spec, spec, spec, spec),
        check_rep=False)
    return fn(a_blocks, a_rows, a_cols, b_blocks, b_rows, b_cols)


def make_halo_spmm(mesh: Mesh, axis: str, plan: DistPlan,
                   use_pair_kernel: bool = False, interpret: bool = False):
    """jit-able closure over the static plan (for lowering / benchmarks)."""

    @jax.jit
    def run(a_blocks, a_rows, a_cols, b_blocks, b_rows, b_cols):
        return halo_spmm(mesh, axis, plan, a_blocks, a_rows, a_cols,
                         b_blocks, b_rows, b_cols,
                         use_pair_kernel=use_pair_kernel,
                         interpret=interpret)

    return run


# ---------------------------------------------------------------------------
# v2: demand-routed sparse halo (beyond-paper optimization, EXPERIMENTS §Perf)
#
# The v1 ring floods every device with every neighbour's full shard out to
# the WORST-CASE owner distance.  Morton quadrant boundaries make that
# distance grow with p for banded matrices (a band cell just across the
# half-matrix boundary lives ~p/4 devices away), so v1's bytes/device grow
# with p — v1 fails to deliver the paper's O(1).
#
# v2 plans, per directed owner-distance s, exactly which blocks any device
# must ship to the device s hops ahead (the paper's "runtime fetches the
# chunks a task needs" made static).  Each active shift becomes ONE
# collective-permute whose payload is the max-over-devices shipped-block
# count; inactive shifts vanish.  For banded matrices the active shifts
# are the small neighbourhood + a geometric set of quadrant-boundary
# shifts with tiny payloads -> near-O(1) bytes/device in weak scaling.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DemandPlan:
    grid: int
    bs: int
    n_dev: int
    cap_d: int
    cap_c_d: int
    pair_caps: tuple
    # per active shift: (shift, capA, capB); tables live in arrays below
    shifts: tuple                 # tuple of (s, capA_s, capB_s)
    # selection tables, sharded over devices at call time:
    selA: "np.ndarray"            # (n_shifts, n_dev, max_capA) slot or -1
    selB: "np.ndarray"            # (n_shifts, n_dev, max_capB)

    @property
    def halo_cap(self) -> int:
        return self.cap_d + sum(ca + cb for _, ca, cb in self.shifts)


def _leaf_pairs(mask_a: np.ndarray, mask_b: np.ndarray):
    """All (i, k, j) with A[i,k] and B[k,j] nonzero (planning scale)."""
    ii, kk = np.nonzero(mask_a)
    kb, jb = np.nonzero(mask_b)
    order_a = np.argsort(kk, kind="stable")
    order_b = np.argsort(kb, kind="stable")
    ii, kk = ii[order_a], kk[order_a]
    kb, jb = kb[order_b], jb[order_b]
    g = mask_a.shape[0]
    a_start = np.searchsorted(kk, np.arange(g + 1))
    b_start = np.searchsorted(kb, np.arange(g + 1))
    I, K, J = [], [], []
    for k in range(g):
        a0, a1 = a_start[k], a_start[k + 1]
        b0, b1 = b_start[k], b_start[k + 1]
        if a0 == a1 or b0 == b1:
            continue
        na, nb = a1 - a0, b1 - b0
        I.append(np.repeat(ii[a0:a1], nb))
        K.append(np.full(na * nb, k, np.int64))
        J.append(np.tile(jb[b0:b1], na))
    if not I:
        z = np.empty(0, np.int64)
        return z, z, z
    return np.concatenate(I), np.concatenate(K), np.concatenate(J)


def _local_slot_numbers(mask: np.ndarray, owner: np.ndarray, n_dev: int):
    """slot_of[i, j]: index of block (i,j) within its owner's packed shard
    (row-major fill order — matches distribute_morton)."""
    slot_of = np.full(mask.shape, -1, np.int64)
    fill = np.zeros(n_dev, np.int64)
    for i, j in zip(*np.nonzero(mask)):
        d = owner[i, j]
        slot_of[i, j] = fill[d]
        fill[d] += 1
    return slot_of, fill


def plan_demand(mask_a: np.ndarray, mask_b: np.ndarray, bs: int,
                n_dev: int, slack: float = 1.3, round_to: int = 8
                ) -> DemandPlan:
    grid = mask_a.shape[0]
    owner = morton_owner(grid, n_dev)
    ma, mb = np.asarray(mask_a), np.asarray(mask_b)
    mc = (ma.astype(np.int64) @ mb.astype(np.int64)) > 0

    def _cap(x):
        return max(round_to, int(np.ceil(x * slack / round_to)) * round_to)

    cap_d = _cap(max(np.bincount(owner[ma].ravel(), minlength=n_dev).max(),
                     np.bincount(owner[mb].ravel(), minlength=n_dev).max()))
    cap_c_d = _cap(np.bincount(owner[mc].ravel(), minlength=n_dev).max())

    slotA, _ = _local_slot_numbers(ma, owner, n_dev)
    slotB, _ = _local_slot_numbers(mb, owner, n_dev)

    I, K, J = _leaf_pairs(ma, mb)
    oA, oB, oC = owner[I, K], owner[K, J], owner[I, J]
    sA = (oC - oA) % n_dev
    sB = (oC - oB) % n_dev

    # unique (shift, src_dev, block) shipments
    def shipments(shift_arr, src_dev, slot_of, rows, cols):
        out = {}
        key = (shift_arr.astype(np.int64) << 40) | \
            (src_dev.astype(np.int64) << 24) | slot_of[rows, cols]
        uniq, idx = np.unique(key, return_index=True)
        sh = (uniq >> 40).astype(np.int64)
        sd = ((uniq >> 24) & 0xFFFF).astype(np.int64)
        sl = (uniq & 0xFFFFFF).astype(np.int64)
        for s in np.unique(sh):
            if s == 0:
                continue
            m = sh == s
            out[int(s)] = (sd[m], sl[m])
        return out

    shipA = shipments(sA, oA, slotA, I, K)
    shipB = shipments(sB, oB, slotB, K, J)

    all_shifts = sorted(set(shipA) | set(shipB))
    shifts = []
    selA_list, selB_list = [], []
    for s in all_shifts:
        def table(ship):
            if s not in ship:
                return np.full((n_dev, 1), -1, np.int64), 0
            sd, sl = ship[s]
            counts = np.bincount(sd, minlength=n_dev)
            cap = int(counts.max())
            tbl = np.full((n_dev, cap), -1, np.int64)
            fill = np.zeros(n_dev, np.int64)
            for d, slot in zip(sd, sl):
                tbl[d, fill[d]] = slot
                fill[d] += 1
            return tbl, cap

        ta, ca = table(shipA)
        tb, cb = table(shipB)
        shifts.append((int(s), ca, cb))
        selA_list.append(ta)
        selB_list.append(tb)

    max_ca = max((c for _, c, _ in shifts), default=1) or 1
    max_cb = max((c for _, _, c in shifts), default=1) or 1
    selA = np.full((len(shifts), n_dev, max_ca), -1, np.int64)
    selB = np.full((len(shifts), n_dev, max_cb), -1, np.int64)
    for x, (ta, tb) in enumerate(zip(selA_list, selB_list)):
        selA[x, :, :ta.shape[1]] = ta
        selB[x, :, :tb.shape[1]] = tb

    # per-level pair caps: reuse the exact constrained counter from v1
    base = plan_distribution(mask_a, mask_b, bs, n_dev, slack=slack,
                             round_to=round_to)
    return DemandPlan(grid=grid, bs=bs, n_dev=n_dev, cap_d=cap_d,
                      cap_c_d=cap_c_d, pair_caps=base.pair_caps,
                      shifts=tuple(shifts),
                      selA=selA.astype(np.int32),
                      selB=selB.astype(np.int32))


def demand_spmm(mesh: Mesh, axis: str, plan: DemandPlan,
                a_blocks, a_rows, a_cols, b_blocks, b_rows, b_cols):
    """C = A @ B with demand-routed halo (see module comment).

    Selection tables ride in as device-sharded arrays; every active shift
    is one collective-permute of exactly the needed blocks.
    """
    g, bs, n_dev = plan.grid, plan.bs, plan.n_dev
    cap_c = plan.cap_c_d
    selA = jnp.asarray(plan.selA).transpose(1, 0, 2)  # (n_dev, S, capA)
    selB = jnp.asarray(plan.selB).transpose(1, 0, 2)

    def body(ab, ar, ac, bb, br, bc, sa_tbl, sb_tbl):
        ab, ar, ac = ab[0], ar[0], ac[0]
        bb, br, bc = bb[0], br[0], bc[0]
        sa_tbl, sb_tbl = sa_tbl[0], sb_tbl[0]
        dev = jax.lax.axis_index(axis)

        halo_ab, halo_ar, halo_ac = [ab], [ar], [ac]
        halo_bb, halo_br, halo_bc = [bb], [br], [bc]
        for x, (s, ca, cb) in enumerate(plan.shifts):
            perm = [(i, (i + s) % n_dev) for i in range(n_dev)]
            if ca:
                idx = sa_tbl[x, :ca]
                ok = idx >= 0
                blk = jnp.where(ok[:, None, None],
                                ab[jnp.maximum(idx, 0)], 0)
                rr = jnp.where(ok, ar[jnp.maximum(idx, 0)], g)
                cc = jnp.where(ok, ac[jnp.maximum(idx, 0)], g)
                halo_ab.append(jax.lax.ppermute(blk, axis, perm))
                halo_ar.append(jax.lax.ppermute(rr, axis, perm))
                halo_ac.append(jax.lax.ppermute(cc, axis, perm))
            if cb:
                idx = sb_tbl[x, :cb]
                ok = idx >= 0
                blk = jnp.where(ok[:, None, None],
                                bb[jnp.maximum(idx, 0)], 0)
                rr = jnp.where(ok, br[jnp.maximum(idx, 0)], g)
                cc = jnp.where(ok, bc[jnp.maximum(idx, 0)], g)
                halo_bb.append(jax.lax.ppermute(blk, axis, perm))
                halo_br.append(jax.lax.ppermute(rr, axis, perm))
                halo_bc.append(jax.lax.ppermute(cc, axis, perm))

        A = jnp.concatenate(halo_ab)
        Ar = jnp.concatenate(halo_ar)
        Ac = jnp.concatenate(halo_ac)
        B = jnp.concatenate(halo_bb)
        Br = jnp.concatenate(halo_br)
        Bc = jnp.concatenate(halo_bc)

        slot_a = _slot_map(Ar, Ac, g)
        slot_b = _slot_map(Br, Bc, g)
        mask_a = slot_a[:g, :g] >= 0
        mask_b = slot_b[:g, :g] >= 0
        owned = _owned_mask(g, n_dev, dev)
        mask_c = (jnp.matmul(mask_a.astype(jnp.int32),
                             mask_b.astype(jnp.int32)) > 0) & owned

        crows, ccols = jnp.nonzero(mask_c, size=cap_c, fill_value=g)
        crows, ccols = crows.astype(jnp.int32), ccols.astype(jnp.int32)
        cslot = _slot_map(crows, ccols, g)

        pairs, n_pairs = enumerate_pairs_hier(
            mask_a, mask_b, list(plan.pair_caps), mask_c=mask_c)
        pi, pk, pj = pairs[:, 0], pairs[:, 1], pairs[:, 2]
        sa, sb, sc = slot_a[pi, pk], slot_b[pk, pj], cslot[pi, pj]
        pvalid = (sa >= 0) & (sb >= 0) & (sc >= 0)
        seg = jnp.where(pvalid, sc, cap_c)
        prods = jnp.einsum(
            "pik,pkj->pij", A[jnp.maximum(sa, 0)], B[jnp.maximum(sb, 0)],
            preferred_element_type=jnp.float32).astype(A.dtype)
        prods = jnp.where(pvalid[:, None, None], prods, 0)
        cb_ = jax.ops.segment_sum(prods, seg, num_segments=cap_c + 1)[:cap_c]
        return cb_[None], crows[None], ccols[None], n_pairs[None]

    spec = P(axis)
    fn = shard_map(body, mesh=mesh, in_specs=(spec,) * 8,
                   out_specs=(spec,) * 4, check_rep=False)
    return fn(a_blocks, a_rows, a_cols, b_blocks, b_rows, b_cols,
              selA, selB)


def make_demand_spmm(mesh: Mesh, axis: str, plan: DemandPlan):
    @jax.jit
    def run(a_blocks, a_rows, a_cols, b_blocks, b_rows, b_cols):
        return demand_spmm(mesh, axis, plan, a_blocks, a_rows, a_cols,
                           b_blocks, b_rows, b_cols)

    return run
