"""Morton (Z-order) curve utilities.

The quadtree hierarchy of the paper *is* a Morton ordering: the path from the
root to a leaf (choosing one of 4 children at each of L levels) spells out the
bit-interleaved (row, col) address of the leaf block.  We exploit this to turn
the paper's "placement follows the recursion" property into a static,
locality-preserving block layout on a TPU mesh: a contiguous Morton range of
leaf blocks is exactly the leaf set of a quadtree subtree.

Pure numpy/jnp — usable both host-side (quadtree library) and inside jit
(distributed bsmm).
"""
from __future__ import annotations

import numpy as np

try:  # jnp variants used inside jit; numpy fallback keeps this importable early
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jnp = None

_B = [0x5555555555555555, 0x3333333333333333,
      0x0F0F0F0F0F0F0F0F, 0x00FF00FF00FF00FF,
      0x0000FFFF0000FFFF]


def _part1by1(x: np.ndarray) -> np.ndarray:
    """Insert a zero bit between each bit of x (supports values < 2**32)."""
    x = np.asarray(x, dtype=np.uint64)
    x = (x | (x << np.uint64(16))) & np.uint64(_B[4])
    x = (x | (x << np.uint64(8))) & np.uint64(_B[3])
    x = (x | (x << np.uint64(4))) & np.uint64(_B[2])
    x = (x | (x << np.uint64(2))) & np.uint64(_B[1])
    x = (x | (x << np.uint64(1))) & np.uint64(_B[0])
    return x


def _compact1by1(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, dtype=np.uint64) & np.uint64(_B[0])
    x = (x | (x >> np.uint64(1))) & np.uint64(_B[1])
    x = (x | (x >> np.uint64(2))) & np.uint64(_B[2])
    x = (x | (x >> np.uint64(4))) & np.uint64(_B[3])
    x = (x | (x >> np.uint64(8))) & np.uint64(_B[4])
    x = (x | (x >> np.uint64(16))) & np.uint64(0x00000000FFFFFFFF)
    return x


def encode(row, col) -> np.ndarray:
    """Morton code with row bits at odd positions, col bits at even positions.

    encode(r, c) = interleave(r, c); sorting by the code walks the quadtree
    depth-first (Z shape within every 2x2 at every level).
    """
    return (_part1by1(row) << np.uint64(1)) | _part1by1(col)


def decode(code) -> tuple[np.ndarray, np.ndarray]:
    code = np.asarray(code, dtype=np.uint64)
    return _compact1by1(code >> np.uint64(1)), _compact1by1(code)


def morton_permutation(grid: int) -> np.ndarray:
    """perm[z] = row-major index of the z-th block in Morton order.

    ``grid`` must be a power of two.  Useful to relabel a (grid x grid) block
    matrix so that contiguous ranges = quadtree subtrees.
    """
    assert grid & (grid - 1) == 0, "grid must be a power of two"
    rows = np.repeat(np.arange(grid), grid)
    cols = np.tile(np.arange(grid), grid)
    z = encode(rows, cols).astype(np.int64)
    perm = np.empty(grid * grid, dtype=np.int64)
    perm[z] = np.arange(grid * grid)
    return perm


def owner_of_block(row, col, grid: int, n_devices: int) -> np.ndarray:
    """Device owning leaf block (row, col) under Morton-range distribution.

    The Morton range [0, grid^2) is split into n_devices equal contiguous
    chunks; each chunk is a union of quadtree subtrees (exactly one subtree
    when n_devices is a power of 4).  This reproduces the paper's
    placement-follows-recursion property statically.
    """
    z = encode(row, col).astype(np.int64)
    per = (grid * grid) // n_devices
    return z // per


# ---- jnp versions (traceable) -------------------------------------------

def _jnp_part1by1(x):
    x = x.astype(jnp.uint32)
    x = (x | (x << 8)) & jnp.uint32(0x00FF00FF)
    x = (x | (x << 4)) & jnp.uint32(0x0F0F0F0F)
    x = (x | (x << 2)) & jnp.uint32(0x33333333)
    x = (x | (x << 1)) & jnp.uint32(0x55555555)
    return x


def jnp_encode(row, col):
    """Traceable Morton encode for block indices < 2**16."""
    return (_jnp_part1by1(row) << 1) | _jnp_part1by1(col)


def level_of(code: int, leaf_level: int, level: int) -> int:
    """Ancestor Morton code at ``level`` of a leaf code at ``leaf_level``."""
    return code >> (2 * (leaf_level - level))
