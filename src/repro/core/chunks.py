"""Chunks: the data half of the Chunks and Tasks programming model (paper §2).

This is a faithful single-process simulation of the CHT-MPI semantics the
paper relies on:

* A *chunk* is an immutable piece of data.  ``register_chunk`` transfers
  ownership to the runtime and returns a :class:`ChunkId`; after registration
  the object is read-only (we enforce this by hashing at registration and
  verifying on every fetch in debug mode).
* The **owner worker rank is embedded in the chunk id** (paper §2.1) so any
  worker can locate data without a central directory.
* Each worker has a bounded LRU **chunk cache**; fetching a remote chunk is
  accounted as communication (bytes received) only on cache miss — this is the
  quantity plotted in Figs 11-13.
* ``NIL`` chunk ids represent zero submatrices and may appear at any level.

The store also records per-worker peak owned bytes (Fig 11 left).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Optional

NIL: Optional["ChunkId"] = None  # NIL chunk identifier == None, as in the paper


@dataclasses.dataclass(frozen=True)
class ChunkId:
    """Identifier chosen by the runtime; owner rank embedded (paper §2)."""
    owner: int
    local: int

    def __repr__(self) -> str:  # compact for logs
        return f"c{self.owner}.{self.local}"


class Chunk:
    """Base class for user chunk types; subclasses define nbytes()."""

    def nbytes(self) -> int:
        raise NotImplementedError


@dataclasses.dataclass
class WorkerStats:
    bytes_received: int = 0           # data fetched from other workers
    bytes_received_local: int = 0     # same-worker fetches (no comm)
    bytes_pushed: int = 0             # subset of bytes_received: placement pushes
    messages_received: int = 0        # number of remote fetches (latency proxy)
    cache_hits: int = 0
    owned_bytes: int = 0
    peak_owned_bytes: int = 0
    tasks_executed: int = 0
    busy_time: float = 0.0
    dedup_hits: int = 0               # registrations resolved by content hash
    flops_executed: float = 0.0       # useful flops of tasks run on this worker


def content_fingerprint(obj: Any) -> Any:
    """Content hash of a chunk, or None when the type opts out of dedup.

    Duck-typed on a ``content_fingerprint()`` method so only chunk types
    that can vouch for byte-identity (leaf matrix chunks) participate.
    """
    fp = getattr(obj, "content_fingerprint", None)
    return fp() if fp is not None else None


def content_norm2(obj: Any) -> Optional[float]:
    """Squared Frobenius norm of a chunk's payload, or None.

    Duck-typed on a ``content_norm2()`` method so only chunk types whose
    norm is meaningful from the bytes alone (leaf matrix chunks)
    participate — internal quadtree chunks hold graph-local child ids and
    opt out, exactly as they do for dedup fingerprints.
    """
    fn = getattr(obj, "content_norm2", None)
    return fn() if fn is not None else None


class ChunkStore:
    """All workers' chunks + caches + communication accounting.

    ``dedup=True`` enables content-hash deduplication: registering data
    byte-identical to an existing live chunk (e.g. the same dense input
    built as two quadtrees) returns the *existing* :class:`ChunkId`
    instead of storing a second copy, shrinking owned-bytes accounting.
    Deduplicated ids are reference counted so :meth:`free` only deletes
    the data when the last registration is freed.  Note that with dedup a
    chunk id may point at a different worker than the one that registered
    it, so the parent-worker placement invariant (owner == creator) holds
    only up to content identity.
    """

    def __init__(self, n_workers: int, cache_bytes: int = 1 << 62,
                 dedup: bool = False):
        self.n_workers = n_workers
        self.cache_bytes = cache_bytes
        self.dedup = dedup
        self._data: list[dict[int, Any]] = [dict() for _ in range(n_workers)]
        self._sizes: list[dict[int, int]] = [dict() for _ in range(n_workers)]
        self._next: list[int] = [0] * n_workers
        # per-worker LRU cache: (owner, local) -> size
        self._cache: list[OrderedDict[tuple[int, int], int]] = [
            OrderedDict() for _ in range(n_workers)]
        self._cache_used: list[int] = [0] * n_workers
        self.stats = [WorkerStats() for _ in range(n_workers)]
        # dedup bookkeeping: fingerprint <-> (owner, local), refcounts
        self._by_fp: dict[Any, tuple[int, int]] = {}
        self._fp_of: dict[tuple[int, int], Any] = {}
        self._refs: dict[tuple[int, int], int] = {}
        # chunk-norm cache (truncated multiply, DESIGN.md §5): computed on
        # first norm2_of and dropped by free() so a dedup-released slot
        # can never serve a stale norm to a later registration
        self._norm2: dict[tuple[int, int], float] = {}

    # -- registration -----------------------------------------------------
    def _dedup_lookup(self, worker: int, obj: Any
                      ) -> tuple[Optional[ChunkId], Any]:
        """(existing id, fingerprint) for ``obj`` under dedup; (None, fp)
        on miss; (None, None) when dedup is off or the type opts out."""
        if not self.dedup:
            return None, None
        fp = content_fingerprint(obj)
        if fp is None:
            return None, None
        key = self._by_fp.get(fp)
        if key is None:
            return None, fp
        self._refs[key] += 1
        self.stats[worker].dedup_hits += 1
        return ChunkId(*key), fp

    _FP_UNSET = object()    # sentinel: fingerprint not yet computed

    def register(self, worker: int, obj: Any, nbytes: int | None = None,
                 _fp: Any = _FP_UNSET) -> ChunkId:
        """Register ``obj`` on ``worker``; returns runtime-chosen id.

        No communication: a chunk is owned by the worker that created it.
        With ``dedup`` enabled, byte-identical data returns the existing id.
        ``_fp`` carries a fingerprint already computed (and missed) by
        :meth:`register_pushed` so the block bytes are hashed only once.
        """
        if _fp is ChunkStore._FP_UNSET:
            hit, fp = self._dedup_lookup(worker, obj)
            if hit is not None:
                return hit
        else:
            fp = _fp
        if nbytes is None:
            nbytes = obj.nbytes() if isinstance(obj, Chunk) else _default_nbytes(obj)
        local = self._next[worker]
        self._next[worker] += 1
        self._data[worker][local] = obj
        self._sizes[worker][local] = nbytes
        st = self.stats[worker]
        st.owned_bytes += nbytes
        st.peak_owned_bytes = max(st.peak_owned_bytes, st.owned_bytes)
        if fp is not None:
            key = (worker, local)
            self._by_fp[fp] = key
            self._fp_of[key] = fp
            self._refs[key] = 1
        return ChunkId(worker, local)

    def register_pushed(self, creator: int, owner: int, obj: Any,
                        nbytes: int | None = None) -> ChunkId:
        """Register a chunk created by ``creator`` but placed on ``owner``.

        Models a locality-oblivious placement policy: when the runtime
        assigns ownership away from the creating worker, the data must be
        *sent* there — the owner receives ``nbytes`` over the network.  The
        creator keeps a cached copy (it just produced the data), so its own
        subsequent fetches hit the cache.

        With ``dedup`` enabled, byte-identical data short-circuits to the
        existing id: nothing is shipped (no push accounting) and the
        creator — which just produced the same bytes — gets a cache entry.
        """
        hit, fp = self._dedup_lookup(creator, obj)
        if hit is not None:
            if hit.owner != creator:
                self._cache_insert(creator, (hit.owner, hit.local),
                                   self._sizes[hit.owner][hit.local])
            return hit
        if nbytes is None:
            nbytes = obj.nbytes() if isinstance(obj, Chunk) else _default_nbytes(obj)
        cid = self.register(owner, obj, nbytes, _fp=fp)
        if owner != creator:
            st = self.stats[owner]
            st.bytes_received += nbytes
            st.bytes_pushed += nbytes
            st.messages_received += 1
            self._cache_insert(creator, (owner, cid.local), nbytes)
        return cid

    # -- fetch --------------------------------------------------------------
    def fetch(self, worker: int, cid: Optional[ChunkId]) -> Any:
        """Fetch chunk for use by ``worker``; accounts communication.

        Fetching NIL returns None (the runtime would invoke the fallback
        execute, Alg 1/2 line 2).
        """
        if cid is None:
            return None
        obj = self._data[cid.owner][cid.local]
        size = self._sizes[cid.owner][cid.local]
        st = self.stats[worker]
        if cid.owner == worker:
            st.bytes_received_local += size
            return obj
        key = (cid.owner, cid.local)
        cache = self._cache[worker]
        if key in cache:
            cache.move_to_end(key)
            st.cache_hits += 1
            return obj
        # remote fetch: communication happens
        st.bytes_received += size
        st.messages_received += 1
        self._cache_insert(worker, key, size)
        return obj

    def _cache_insert(self, worker: int, key: tuple[int, int], size: int
                      ) -> None:
        cache = self._cache[worker]
        if key in cache:                # re-insert: replace, don't double-count
            self._cache_used[worker] -= cache[key]
        cache[key] = size
        cache.move_to_end(key)
        self._cache_used[worker] += size
        while self._cache_used[worker] > self.cache_bytes and cache:
            _, evicted = cache.popitem(last=False)
            self._cache_used[worker] -= evicted

    def cache_used(self, worker: int) -> int:
        """Bytes currently held in ``worker``'s chunk cache."""
        return self._cache_used[worker]

    def size_of(self, cid: Optional[ChunkId]) -> int:
        if cid is None:
            return 0
        return self._sizes[cid.owner][cid.local]

    def norm2_of(self, cid: Optional[ChunkId]) -> Optional[float]:
        """Cached squared Frobenius norm of a chunk's payload.

        Returns 0.0 for NIL and None for chunk types that opt out (see
        :func:`content_norm2`).  The cache entry lives exactly as long as
        the chunk: :meth:`free` drops it, so dedup'd reuse of a released
        fingerprint can never read a stale norm.
        """
        if cid is None:
            return 0.0
        key = (cid.owner, cid.local)
        v = self._norm2.get(key)
        if v is None:
            v = content_norm2(self._data[cid.owner][cid.local])
            if v is not None:
                self._norm2[key] = v
        return v

    def invalidate_norm2(self, cid: Optional[ChunkId]) -> None:
        """Drop the cached norm of a chunk whose payload was rebound.

        Plan replay (api/plan.py) refreshes input chunk *values* in place
        — same structure, same bytes count, new numbers — so any norm
        this store cached against the old bytes is stale.
        """
        if cid is None:
            return
        self._norm2.pop((cid.owner, cid.local), None)

    def invalidate_content(self, cid: Optional[ChunkId]) -> None:
        """Drop every cache keyed to a rebound chunk's *old bytes*.

        Beyond the norm cache this retires the chunk's dedup fingerprint:
        a later registration of data byte-identical to the original
        values must not resolve to a chunk that now holds different
        numbers.  The refcount bookkeeping stays intact (``free`` still
        works); only future fingerprint lookups are prevented — the
        rebound bytes are conservatively left unindexed.
        """
        if cid is None:
            return
        key = (cid.owner, cid.local)
        self._norm2.pop(key, None)
        fp = self._fp_of.get(key)
        if fp is not None and self._by_fp.get(fp) == key:
            del self._by_fp[fp]

    def free(self, cid: Optional[ChunkId]) -> None:
        """Model chunk deletion (temporaries freed by the library user).

        Cached copies on other workers are invalidated too: a freed id's
        ``(owner, local)`` slot may be reused by a later registration, and a
        stale cache entry would both pin ``_cache_used`` forever and serve
        the *old* bytes for the new id.
        """
        if cid is None:
            return
        key = (cid.owner, cid.local)
        if key in self._refs:           # dedup'd id: last free wins
            self._refs[key] -= 1
            if self._refs[key] > 0:
                return
            del self._refs[key]
            fp = self._fp_of.pop(key)
            if self._by_fp.get(fp) == key:
                del self._by_fp[fp]
        size = self._sizes[cid.owner].pop(cid.local)
        del self._data[cid.owner][cid.local]
        self._norm2.pop(key, None)
        self.stats[cid.owner].owned_bytes -= size
        for w in range(self.n_workers):
            if key in self._cache[w]:
                del self._cache[w][key]
                self._cache_used[w] -= size

    # -- fault tolerance (runtime/recovery.py; DESIGN.md §10) ---------------
    def drop_worker(self, worker: int) -> tuple[int, int]:
        """Model worker death: its owned chunks and cache vanish.

        Every other worker's cached copies of the dead worker's chunks
        are dropped too — the ``(owner, local)`` slots may be reused by a
        later registration once recovery re-places the data.  Per-worker
        statistics are kept (the report still shows what the worker did
        before dying).  Returns ``(n_chunks, n_bytes)`` lost.
        """
        lost_keys = [(worker, local) for local in self._data[worker]]
        n_chunks = len(lost_keys)
        n_bytes = sum(self._sizes[worker].values())
        self._data[worker].clear()
        self._sizes[worker].clear()
        self.stats[worker].owned_bytes = 0
        self._cache[worker].clear()
        self._cache_used[worker] = 0
        for key in lost_keys:
            self._norm2.pop(key, None)
            self._refs.pop(key, None)
            fp = self._fp_of.pop(key, None)
            if fp is not None and self._by_fp.get(fp) == key:
                del self._by_fp[fp]
        for w in range(self.n_workers):
            if w == worker:
                continue
            cache = self._cache[w]
            for key in [k for k in cache if k[0] == worker]:
                self._cache_used[w] -= cache.pop(key)
        return n_chunks, n_bytes

    def add_worker(self) -> int:
        """Grow the store by one worker (elastic join); returns its rank."""
        w = self.n_workers
        self.n_workers += 1
        self._data.append({})
        self._sizes.append({})
        self._next.append(0)
        self._cache.append(OrderedDict())
        self._cache_used.append(0)
        self.stats.append(WorkerStats())
        return w

    def replicate(self, cid: ChunkId, dst: int) -> ChunkId:
        """Copy a live chunk onto ``dst`` (r-way replication, DESIGN.md §10).

        Bypasses dedup on purpose: the point is a second *physical* copy
        that survives the primary owner's death, so the replica must not
        resolve to the primary's fingerprint.  The transfer is accounted
        on ``dst`` exactly like a placement push.
        """
        obj = self._data[cid.owner][cid.local]
        nbytes = self._sizes[cid.owner][cid.local]
        local = self._next[dst]
        self._next[dst] += 1
        self._data[dst][local] = obj
        self._sizes[dst][local] = nbytes
        st = self.stats[dst]
        st.owned_bytes += nbytes
        st.peak_owned_bytes = max(st.peak_owned_bytes, st.owned_bytes)
        if dst != cid.owner:
            st.bytes_received += nbytes
            st.bytes_pushed += nbytes
            st.messages_received += 1
        return ChunkId(dst, local)

    # -- aggregate stats ----------------------------------------------------
    def total_bytes_received(self) -> int:
        return sum(s.bytes_received for s in self.stats)

    def per_worker_bytes_received(self) -> list[int]:
        return [s.bytes_received for s in self.stats]

    def per_worker_peak_owned(self) -> list[int]:
        return [s.peak_owned_bytes for s in self.stats]


def _default_nbytes(obj: Any) -> int:
    if hasattr(obj, "nbytes"):
        nb = obj.nbytes
        return int(nb() if callable(nb) else nb)
    return 64  # small header-only objects (parameter chunks etc.)
