"""Tasks: the work half of the Chunks and Tasks model + CHT-MPI-style scheduler.

Faithful simulation of the execution semantics the paper's results rest on
(§2.1), in two phases:

**Phase A — task registration & evaluation** (:class:`CTGraph`): the matrix
algorithms (multiply.py) run as ordinary recursive Python, but every
``register_task`` call records a node in a task DAG: parent/child structure
(the "local task tree"), data dependencies, whether each dependency is fetched
as chunk *content* or passed as a chunk *identifier* (createFromIds tasks pass
ids only — no data transfer), the produced chunk's size, and a cost model of
the task's work.  Values are computed eagerly so correctness is testable
against dense numpy.

**Phase B — cluster simulation** (:mod:`repro.runtime.scheduler`, fronted
here by :class:`ClusterSim`): a virtual-time discrete-event simulation of
CHT-MPI's scheduling on ``p`` workers:

* each worker owns the tasks registered by tasks it executed (no master);
* idle workers **steal from a random victim, from the oldest end** of the
  victim's deque — "work stealing always occurs as high up as possible in the
  local task tree of the victim process" (paper §2.1);
* a task's children become available only after the parent executes;
* chunk placement *follows execution*: the output chunk lives on the worker
  that ran the task (paper §2.1: "each chunk object is by default owned by the
  worker process that created that chunk");
* fetching a remote chunk is accounted as communication unless it is in the
  worker's bounded LRU chunk cache (ChunkStore).

This yields the quantities of Figs 9-14: per-worker bytes received, makespan
under a machine model, peak memory, and task counts.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Any, Callable, Optional

from .chunks import ChunkStore, ChunkId
from repro.obs.tracer import NOOP

NILVAL = None


@dataclasses.dataclass
class Dep:
    """Dependency on another node's output chunk.

    fetch=True  -> task consumes chunk *content* (communication on miss)
    fetch=False -> task consumes the chunk *identifier* only (createFromIds)
    """
    nid: Optional[int]          # producer node id; None == NIL chunk id
    fetch: bool = True


@dataclasses.dataclass
class Node:
    nid: int
    kind: str
    parent: Optional[int]
    deps: list[Dep]
    children: list[int] = dataclasses.field(default_factory=list)
    value: Any = None               # chunk object produced (or None for NIL)
    alias_of: Optional[int] = None  # result is another node's chunk (no new chunk)
    out_nbytes: int = 0
    cost: float = 0.0               # modelled execution time (seconds)
    flops: float = 0.0              # useful flops (leaf compute)
    level: int = -1                 # quadtree level of the task (-1 = n/a)
    payload: Any = None             # batchable leaf-op description (engine.py)
    # structural decisions frozen at first execution so a Plan replay
    # (api/plan.py) re-runs the *same* program: today this is the
    # surviving block-pair list of a truncated leaf multiply, whose
    # norm test would otherwise re-evaluate against the rebound values
    replay: Any = None


@dataclasses.dataclass
class CostModel:
    """Wall-time model of one worker (defaults ~ one Erik-node CPU core)."""
    flops_per_s: float = 5e10       # leaf matrix compute rate
    task_overhead_s: float = 20e-6  # per-task administration (register/schedule)
    bandwidth_Bps: float = 6e9      # FDR InfiniBand-ish
    latency_s: float = 2e-6
    steal_latency_s: float = 50e-6


class CTGraph:
    """Phase A: records the task DAG while computing values eagerly.

    Leaf-level matrix work is routed through a pluggable **leaf engine**
    (engine.py): tasks registered with a ``payload`` carry a batchable
    description of their work instead of an opaque closure, and the engine
    decides whether to execute immediately (numpy backend) or defer and
    batch across the whole graph (pallas backend).  Call :meth:`flush`
    before reading numeric chunk contents; graph *structure* (NIL-ness,
    task counts, flops attribution) is always final at registration.
    """

    def __init__(self, engine: Any = None) -> None:
        self.nodes: list[Node] = []
        self._parent: Optional[int] = None
        self._engine_spec = engine
        self._engine: Any = None
        # observability: a no-op tracer unless Session(trace=...) swaps in
        # a recording one; instrumentation never alters graph structure
        self.tracer = NOOP

    @property
    def engine(self):
        """The resolved leaf engine (constructed lazily)."""
        if self._engine is None:
            from .engine import make_engine
            self._engine = make_engine(self._engine_spec)
        return self._engine

    def flush(self) -> None:
        """Execute any deferred leaf work (batched waves on the engine)."""
        if self._engine is not None:
            if self.tracer.enabled:
                with self.tracer.span("engine.flush", track="engine"):
                    self._engine.flush(self)
            else:
                self._engine.flush(self)

    # -- core API used by the matrix library --------------------------------
    def register_task(self, kind: str, fn: Optional[Callable[..., Any]],
                      deps: list[Dep], cost: float = 0.0,
                      flops: float = 0.0, payload: Any = None) -> int:
        """Register & eagerly execute a task; returns its node id.

        ``fn`` receives the dep *values* (None for NIL / non-fetch deps get the
        producing node id instead of content) and returns either:
        * a chunk object (with .nbytes() or .nbytes) — a new chunk,
        * an ``Alias(nid)`` — result is another node's chunk,
        * None — NIL result.
        ``fn`` may recursively register subtasks; parentage is tracked.

        Alternatively pass ``payload`` (a :class:`~repro.core.engine
        .LeafPayload`) instead of ``fn``: the task is dispatched through the
        graph's leaf engine, which may batch it with other leaf tasks.
        """
        nid = len(self.nodes)
        node = Node(nid=nid, kind=kind, parent=self._parent, deps=deps,
                    cost=cost, flops=flops, payload=payload)
        self.nodes.append(node)
        if self._parent is not None:
            self.nodes[self._parent].children.append(nid)
        saved = self._parent
        self._parent = nid
        try:
            if payload is not None:
                res = self.engine.execute(self, node, payload)
            else:
                vals = [self.value_of(d.nid) if d.fetch else d.nid
                        for d in deps]
                res = fn(*vals)
        finally:
            self._parent = saved
        if isinstance(res, Alias):
            node.alias_of = res.nid
            node.value = self.value_of(res.nid) if res.nid is not None else None
        else:
            node.value = res
            node.out_nbytes = _nbytes(res)
        return nid

    def register_chunk(self, kind: str, obj: Any) -> int:
        """A task that only materialises a chunk (zero-cost source node)."""
        return self.register_task(kind, lambda: obj, [], cost=0.0)

    def value_of(self, nid: Optional[int]) -> Any:
        if nid is None:
            return None
        n = self.nodes[nid]
        seen = set()
        while n.alias_of is not None:
            if n.nid in seen:  # pragma: no cover - defensive
                raise RuntimeError("alias cycle")
            seen.add(n.nid)
            n = self.nodes[n.alias_of]
        return n.value

    def resolve(self, nid: Optional[int]) -> Optional[int]:
        """Follow alias links to the node that actually owns the chunk."""
        if nid is None:
            return None
        n = self.nodes[nid]
        while n.alias_of is not None:
            n = self.nodes[n.alias_of]
        return n.nid

    def is_nil(self, nid: Optional[int]) -> bool:
        return nid is None or self.value_of(nid) is None

    # -- statistics (Figs 3-4) ----------------------------------------------
    def count_kinds(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for n in self.nodes:
            out[n.kind] = out.get(n.kind, 0) + 1
        return out


@dataclasses.dataclass
class Alias:
    nid: Optional[int]


def _nbytes(obj: Any) -> int:
    if obj is None:
        return 0
    nb = getattr(obj, "nbytes", None)
    if nb is None:
        return 64
    return int(nb() if callable(nb) else nb)


# ---------------------------------------------------------------------------
# Phase B: work-stealing cluster simulation — lives in runtime/scheduler.py.
# ClusterSim is kept as the historical front door: a thin wrapper over
# repro.runtime.scheduler.Scheduler pinned to the paper's locality-aware
# "parent-worker" chunk placement.
# ---------------------------------------------------------------------------

class ClusterSim:
    """Discrete-event work-stealing simulation of a CHT-MPI cluster.

    Thin compatibility wrapper over
    :class:`repro.runtime.scheduler.Scheduler` with the paper's
    ``parent-worker`` placement (chunk ownership follows execution).  Use
    the Scheduler directly for pluggable placement policies, execution
    traces, and critical-path statistics.

    Persistent across phases: chunk placements from a previous ``run`` (e.g.
    the task program that *built* the input matrices, cf. paper §7 "the data
    distribution of input matrices was a result of the task executions that
    generated those matrices") carry over to the next (the multiply), so the
    multiply's communication is measured against a realistic distribution.
    """

    def __init__(self, n_workers: int, cache_bytes: int = 1 << 62,
                 cost: CostModel | None = None, seed: int = 0,
                 placement: str = "parent-worker"):
        from repro.runtime.scheduler import Scheduler  # lazy: no cycle
        self.p = n_workers
        self._sched = Scheduler(cost=cost, cache_bytes=cache_bytes,
                                seed=seed)
        self._placement_policy = placement

    @property
    def cost(self) -> CostModel:
        return self._sched.cost

    @property
    def rng(self) -> random.Random:
        return self._sched.rng

    @property
    def store(self) -> ChunkStore:
        if self._sched.store is None:
            self._sched._configure(self.p, self._placement_policy)
        return self._sched.store

    @property
    def placement(self) -> dict[int, ChunkId]:
        return self._sched.placement

    @property
    def _owner_of_node(self) -> dict[int, int]:
        return self._sched._owner_of_node

    def reset_stats(self) -> None:
        self.store  # ensure configured
        self._sched.reset_stats()

    def run(self, g: CTGraph, roots: list[int] | None = None,
            start_worker: int = 0) -> "SimResult":
        """Simulate execution of all not-yet-simulated nodes of ``g``."""
        return self._sched.run(g, n_workers=self.p,
                               placement=self._placement_policy,
                               start_worker=start_worker)


def __getattr__(name: str):
    # SimResult now lives in the runtime subsystem (as SimReport); keep the
    # old name importable from here.
    if name in ("SimResult", "SimReport"):
        from repro.runtime.scheduler import SimReport
        return SimReport
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
