"""Tasks: the work half of the Chunks and Tasks model + CHT-MPI-style scheduler.

Faithful simulation of the execution semantics the paper's results rest on
(§2.1), in two phases:

**Phase A — task registration & evaluation** (:class:`CTGraph`): the matrix
algorithms (multiply.py) run as ordinary recursive Python, but every
``register_task`` call records a node in a task DAG: parent/child structure
(the "local task tree"), data dependencies, whether each dependency is fetched
as chunk *content* or passed as a chunk *identifier* (createFromIds tasks pass
ids only — no data transfer), the produced chunk's size, and a cost model of
the task's work.  Values are computed eagerly so correctness is testable
against dense numpy.

**Phase B — cluster simulation** (:class:`ClusterSim`): a virtual-time
discrete-event simulation of CHT-MPI's scheduling on ``p`` workers:

* each worker owns the tasks registered by tasks it executed (no master);
* idle workers **steal from a random victim, from the oldest end** of the
  victim's deque — "work stealing always occurs as high up as possible in the
  local task tree of the victim process" (paper §2.1);
* a task's children become available only after the parent executes;
* chunk placement *follows execution*: the output chunk lives on the worker
  that ran the task (paper §2.1: "each chunk object is by default owned by the
  worker process that created that chunk");
* fetching a remote chunk is accounted as communication unless it is in the
  worker's bounded LRU chunk cache (ChunkStore).

This yields the quantities of Figs 9-14: per-worker bytes received, makespan
under a machine model, peak memory, and task counts.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Any, Callable, Optional

from .chunks import ChunkStore, ChunkId

NILVAL = None


@dataclasses.dataclass
class Dep:
    """Dependency on another node's output chunk.

    fetch=True  -> task consumes chunk *content* (communication on miss)
    fetch=False -> task consumes the chunk *identifier* only (createFromIds)
    """
    nid: Optional[int]          # producer node id; None == NIL chunk id
    fetch: bool = True


@dataclasses.dataclass
class Node:
    nid: int
    kind: str
    parent: Optional[int]
    deps: list[Dep]
    children: list[int] = dataclasses.field(default_factory=list)
    value: Any = None               # chunk object produced (or None for NIL)
    alias_of: Optional[int] = None  # result is another node's chunk (no new chunk)
    out_nbytes: int = 0
    cost: float = 0.0               # modelled execution time (seconds)
    flops: float = 0.0              # useful flops (leaf compute)
    level: int = -1                 # quadtree level of the task (-1 = n/a)
    payload: Any = None             # batchable leaf-op description (engine.py)


@dataclasses.dataclass
class CostModel:
    """Wall-time model of one worker (defaults ~ one Erik-node CPU core)."""
    flops_per_s: float = 5e10       # leaf matrix compute rate
    task_overhead_s: float = 20e-6  # per-task administration (register/schedule)
    bandwidth_Bps: float = 6e9      # FDR InfiniBand-ish
    latency_s: float = 2e-6
    steal_latency_s: float = 50e-6


class CTGraph:
    """Phase A: records the task DAG while computing values eagerly.

    Leaf-level matrix work is routed through a pluggable **leaf engine**
    (engine.py): tasks registered with a ``payload`` carry a batchable
    description of their work instead of an opaque closure, and the engine
    decides whether to execute immediately (numpy backend) or defer and
    batch across the whole graph (pallas backend).  Call :meth:`flush`
    before reading numeric chunk contents; graph *structure* (NIL-ness,
    task counts, flops attribution) is always final at registration.
    """

    def __init__(self, engine: Any = None) -> None:
        self.nodes: list[Node] = []
        self._parent: Optional[int] = None
        self._engine_spec = engine
        self._engine: Any = None

    @property
    def engine(self):
        """The resolved leaf engine (constructed lazily)."""
        if self._engine is None:
            from .engine import make_engine
            self._engine = make_engine(self._engine_spec)
        return self._engine

    def flush(self) -> None:
        """Execute any deferred leaf work (batched waves on the engine)."""
        if self._engine is not None:
            self._engine.flush(self)

    # -- core API used by the matrix library --------------------------------
    def register_task(self, kind: str, fn: Optional[Callable[..., Any]],
                      deps: list[Dep], cost: float = 0.0,
                      flops: float = 0.0, payload: Any = None) -> int:
        """Register & eagerly execute a task; returns its node id.

        ``fn`` receives the dep *values* (None for NIL / non-fetch deps get the
        producing node id instead of content) and returns either:
        * a chunk object (with .nbytes() or .nbytes) — a new chunk,
        * an ``Alias(nid)`` — result is another node's chunk,
        * None — NIL result.
        ``fn`` may recursively register subtasks; parentage is tracked.

        Alternatively pass ``payload`` (a :class:`~repro.core.engine
        .LeafPayload`) instead of ``fn``: the task is dispatched through the
        graph's leaf engine, which may batch it with other leaf tasks.
        """
        nid = len(self.nodes)
        node = Node(nid=nid, kind=kind, parent=self._parent, deps=deps,
                    cost=cost, flops=flops, payload=payload)
        self.nodes.append(node)
        if self._parent is not None:
            self.nodes[self._parent].children.append(nid)
        saved = self._parent
        self._parent = nid
        try:
            if payload is not None:
                res = self.engine.execute(self, node, payload)
            else:
                vals = [self.value_of(d.nid) if d.fetch else d.nid
                        for d in deps]
                res = fn(*vals)
        finally:
            self._parent = saved
        if isinstance(res, Alias):
            node.alias_of = res.nid
            node.value = self.value_of(res.nid) if res.nid is not None else None
        else:
            node.value = res
            node.out_nbytes = _nbytes(res)
        return nid

    def register_chunk(self, kind: str, obj: Any) -> int:
        """A task that only materialises a chunk (zero-cost source node)."""
        return self.register_task(kind, lambda: obj, [], cost=0.0)

    def value_of(self, nid: Optional[int]) -> Any:
        if nid is None:
            return None
        n = self.nodes[nid]
        seen = set()
        while n.alias_of is not None:
            if n.nid in seen:  # pragma: no cover - defensive
                raise RuntimeError("alias cycle")
            seen.add(n.nid)
            n = self.nodes[n.alias_of]
        return n.value

    def resolve(self, nid: Optional[int]) -> Optional[int]:
        """Follow alias links to the node that actually owns the chunk."""
        if nid is None:
            return None
        n = self.nodes[nid]
        while n.alias_of is not None:
            n = self.nodes[n.alias_of]
        return n.nid

    def is_nil(self, nid: Optional[int]) -> bool:
        return nid is None or self.value_of(nid) is None

    # -- statistics (Figs 3-4) ----------------------------------------------
    def count_kinds(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for n in self.nodes:
            out[n.kind] = out.get(n.kind, 0) + 1
        return out


@dataclasses.dataclass
class Alias:
    nid: Optional[int]


def _nbytes(obj: Any) -> int:
    if obj is None:
        return 0
    nb = getattr(obj, "nbytes", None)
    if nb is None:
        return 64
    return int(nb() if callable(nb) else nb)


# ---------------------------------------------------------------------------
# Phase B: work-stealing cluster simulation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SimResult:
    makespan: float
    bytes_received: list[int]
    messages_received: list[int]
    peak_owned: list[int]
    tasks_per_worker: list[int]
    busy_time: list[float]
    steals: int

    @property
    def avg_bytes_received(self) -> float:
        return sum(self.bytes_received) / len(self.bytes_received)

    @property
    def active_fraction(self) -> list[float]:
        return [b / self.makespan if self.makespan > 0 else 0.0
                for b in self.busy_time]


class ClusterSim:
    """Discrete-event work-stealing simulation of a CHT-MPI cluster.

    Persistent across phases: chunk placements from a previous ``run`` (e.g.
    the task program that *built* the input matrices, cf. paper §7 "the data
    distribution of input matrices was a result of the task executions that
    generated those matrices") carry over to the next (the multiply), so the
    multiply's communication is measured against a realistic distribution.
    """

    def __init__(self, n_workers: int, cache_bytes: int = 1 << 62,
                 cost: CostModel | None = None, seed: int = 0):
        self.p = n_workers
        self.store = ChunkStore(n_workers, cache_bytes)
        self.cost = cost or CostModel()
        self.rng = random.Random(seed)
        self.placement: dict[int, ChunkId] = {}  # node id -> chunk id
        self._owner_of_node: dict[int, int] = {}

    def reset_stats(self) -> None:
        for s in self.store.stats:
            s.bytes_received = 0
            s.bytes_received_local = 0
            s.messages_received = 0
            s.cache_hits = 0
            s.tasks_executed = 0
            s.busy_time = 0.0

    def run(self, g: CTGraph, roots: list[int] | None = None,
            start_worker: int = 0) -> SimResult:
        """Simulate execution of all not-yet-simulated nodes of ``g``."""
        g.flush()   # batched leaf waves must run so per-task flops are final
        todo = [n for n in g.nodes if n.nid not in self._owner_of_node]
        if not todo:
            return self._result(0.0, 0)
        todo_ids = {n.nid for n in todo}

        pending: dict[int, int] = {}      # nid -> unmet dep count
        dependents: dict[int, list[int]] = {}
        registered: dict[int, bool] = {}
        done: set[int] = set(self._owner_of_node)

        for n in todo:
            cnt = 0
            for d in n.deps:
                dn = g.resolve(d.nid)
                if dn is not None and dn in todo_ids and dn not in done:
                    cnt += 1
                    dependents.setdefault(dn, []).append(n.nid)
            # alias target must complete before the alias is "done" for
            # scheduling purposes? No: alias resolution is metadata only.
            pending[n.nid] = cnt
            registered[n.nid] = (n.parent is None or n.parent not in todo_ids)

        deques: list[list[int]] = [[] for _ in range(self.p)]
        free_at = [0.0] * self.p
        n_steals = 0

        def push_ready(nid: int, worker: int) -> None:
            self._owner_of_node[nid] = worker
            deques[worker].append(nid)

        # roots (registered, deps met) start on start_worker
        for n in todo:
            if registered[n.nid] and pending[n.nid] == 0:
                push_ready(n.nid, start_worker)

        # virtual time: run worker with earliest free time that has work;
        # idle workers steal.
        time_now = 0.0
        import heapq
        heap = [(0.0, w) for w in range(self.p)]
        heapq.heapify(heap)
        executed = 0
        total = len(todo)
        blocked: list[tuple[float, int]] = []  # workers waiting for work

        while executed < total:
            if not heap:
                # all workers blocked; advance time to next completion —
                # but completions are processed inline, so if heap is empty
                # and work remains, tasks must be waiting on deps: re-arm
                # blocked workers at the current time.
                if not blocked:
                    raise RuntimeError("deadlock in task graph simulation")
                t = min(b[0] for b in blocked)
                for bt, w in blocked:
                    heapq.heappush(heap, (max(bt, t), w))
                blocked = []
                continue
            t, w = heapq.heappop(heap)
            time_now = max(time_now, t)
            nid = None
            if deques[w]:
                nid = deques[w].pop()          # own work: newest first (LIFO)
            else:
                victims = [v for v in range(self.p) if deques[v]]
                if victims:
                    v = self.rng.choice(victims)
                    nid = deques[v].pop(0)     # steal oldest = highest in tree
                    self._owner_of_node[nid] = w
                    t += self.cost.steal_latency_s
                    n_steals += 1
            if nid is None:
                blocked.append((t, w))
                continue

            node = g.nodes[nid]
            # fetch inputs
            fetch_time = 0.0
            for d in node.deps:
                if not d.fetch:
                    continue
                dn = g.resolve(d.nid)
                cid = self.placement.get(dn) if dn is not None else None
                if cid is not None:
                    before = self.store.stats[w].bytes_received
                    msgs_before = self.store.stats[w].messages_received
                    self.store.fetch(w, cid)
                    dbytes = self.store.stats[w].bytes_received - before
                    dmsgs = self.store.stats[w].messages_received - msgs_before
                    fetch_time += dbytes / self.cost.bandwidth_Bps \
                        + dmsgs * self.cost.latency_s
            dur = (self.cost.task_overhead_s + node.cost
                   + node.flops / self.cost.flops_per_s + fetch_time)
            t_end = t + dur
            st = self.store.stats[w]
            st.tasks_executed += 1
            st.busy_time += dur

            # produce output chunk
            if node.alias_of is None and node.value is not None:
                cid = self.store.register(w, node.value, node.out_nbytes)
                self.placement[nid] = cid
            elif node.alias_of is not None:
                rn = g.resolve(nid)
                if rn in self.placement:
                    self.placement[nid] = self.placement[rn]

            done.add(nid)
            executed += 1
            # children become registered
            for c in node.children:
                if c in registered and not registered[c]:
                    registered[c] = True
                    if pending[c] == 0:
                        push_ready(c, w)
            # dependents
            for dep_nid in dependents.get(nid, ()):  # noqa: B007
                pending[dep_nid] -= 1
                if pending[dep_nid] == 0 and registered[dep_nid]:
                    push_ready(dep_nid, self._owner_of_node.get(
                        g.nodes[dep_nid].parent, w)
                        if g.nodes[dep_nid].parent is not None else w)
            # aliases of nid that already executed get placements lazily via
            # resolve(); nothing to do here.
            free_at[w] = t_end
            heapq.heappush(heap, (t_end, w))
            # wake blocked workers — there may be new work
            if blocked:
                for bt, bw in blocked:
                    heapq.heappush(heap, (max(bt, time_now), bw))
                blocked = []

        makespan = max(free_at)
        return self._result(makespan, n_steals)

    def _result(self, makespan: float, steals: int) -> SimResult:
        st = self.store.stats
        return SimResult(
            makespan=makespan,
            bytes_received=[s.bytes_received for s in st],
            messages_received=[s.messages_received for s in st],
            peak_owned=[s.peak_owned_bytes for s in st],
            tasks_per_worker=[s.tasks_executed for s in st],
            busy_time=[s.busy_time for s in st],
            steals=steals,
        )
