"""Recursive triangular task programs: solve and inverse Cholesky.

The electronic-structure solver family (arXiv:1901.07993) needs two more
quadtree task programs beyond the multiply/add/sym set of
:mod:`repro.core.multiply`:

* :func:`qt_inv_chol` — the recursive **inverse Cholesky** factorization
  of an SPD matrix S in symmetric upper storage: Z upper triangular with
  ``Z^T S Z = I``.  For the 2x2 block partition ``S = [[A, B], [B^T, C]]``

  .. math::

      Z = \\begin{pmatrix} Z_A & -Z_A (Z_A^T B) Z_C \\\\
                           0   & Z_C \\end{pmatrix},

  where ``Z_A = qt_inv_chol(A)`` and ``Z_C = qt_inv_chol(C - T^T T)``
  with ``T = Z_A^T B`` — the Schur complement ``C - B^T A^{-1} B``
  computed via ``A^{-1} = Z_A Z_A^T`` as a rank-k update (qt_syrk), so
  the correction stays in symmetric upper storage like C itself.

* :func:`qt_tri_solve` — recursive **triangular solve** ``X = R^{-1} B``
  with R upper triangular: the bottom block row solves against R11
  alone, the top one back-substitutes ``X0j = R00^{-1}(B0j - R01 X1j)``.

Both follow the structure of the existing symmetric programs: NIL
short-circuits at registration (zero subtrees of B cost nothing), leaf
tasks are :class:`~repro.core.engine.LeafPayload` kinds (``inv_chol``,
``tri_solve``) so the deferred Pallas backend batches every ready leaf
of one shape into a single kernels/tri.py call, and internal levels are
create-from-identifier tasks.  Triangular results use *plain* storage
with the strictly-lower quadrant NIL at every level (they are
triangular, not symmetric), so downstream multiplies see an ordinary —
and notably sparse — quadtree.

A NIL diagonal block of the input is a singular matrix: both programs
raise instead of silently producing a NIL result.
"""
from __future__ import annotations

from typing import Optional

from .engine import LeafPayload
from .multiply import (_level_of, _register_create, qt_add, qt_multiply,
                       qt_scale, qt_syrk)
from .quadtree import CTGraph, MatrixChunk, QTParams
from .tasks import Alias, Dep

__all__ = ["qt_tri_solve", "qt_inv_chol", "SOLVE_TASK_KINDS"]

#: task kinds this module registers (for task-count assertions)
SOLVE_TASK_KINDS = ("tri_solve", "inv_chol")


def qt_tri_solve(g: CTGraph, params: QTParams, r: Optional[int],
                 b: Optional[int]) -> Optional[int]:
    """X = R^{-1} B; R upper triangular in plain storage (see module doc)."""
    if g.is_nil(b):
        return None
    if g.is_nil(r):
        raise ValueError(
            "qt_tri_solve: NIL triangular operand (singular matrix)")
    rc: MatrixChunk = g.value_of(r)
    bc: MatrixChunk = g.value_of(b)
    assert not rc.upper and not bc.upper and rc.n == bc.n
    level = _level_of(params, rc.n)

    if rc.is_leaf:
        nid = g.register_task(
            "tri_solve", None, [Dep(r), Dep(b)],
            payload=LeafPayload("tri_solve", a=r, b=b))
        g.nodes[nid].level = level
        return nid

    def fn(rv: MatrixChunk, bv: MatrixChunk):
        r00, r01, r10, r11 = rv.children
        assert g.is_nil(r10), "qt_tri_solve: R is not upper triangular"
        b00, b01, b10, b11 = bv.children
        x10 = qt_tri_solve(g, params, r11, b10)
        x11 = qt_tri_solve(g, params, r11, b11)
        # back substitution: X0j = R00^{-1} (B0j - R01 X1j)
        x00 = qt_tri_solve(g, params, r00, qt_add(
            g, params, b00,
            qt_scale(g, params, qt_multiply(g, params, r01, x10), -1.0)))
        x01 = qt_tri_solve(g, params, r00, qt_add(
            g, params, b01,
            qt_scale(g, params, qt_multiply(g, params, r01, x11), -1.0)))
        return Alias(_register_create(g, rv.n, (x00, x01, x10, x11), False,
                                      level))

    nid = g.register_task("tri_solve", fn, [Dep(r), Dep(b)])
    g.nodes[nid].level = level
    return nid


def qt_inv_chol(g: CTGraph, params: QTParams, s: Optional[int]
                ) -> Optional[int]:
    """Z upper triangular with Z^T S Z = I; S SPD in symmetric upper
    storage (see module doc for the recursion)."""
    if g.is_nil(s):
        raise ValueError(
            "qt_inv_chol: NIL matrix is singular (not positive definite)")
    sc: MatrixChunk = g.value_of(s)
    assert sc.upper
    level = _level_of(params, sc.n)

    if sc.is_leaf:
        nid = g.register_task(
            "inv_chol", None, [Dep(s)],
            payload=LeafPayload("inv_chol", a=s))
        g.nodes[nid].level = level
        return nid

    def fn(sv: MatrixChunk):
        s00, s01, _, s11 = sv.children
        za = qt_inv_chol(g, params, s00)
        # T = Z_A^T B; Schur correction B^T A^{-1} B = T^T T (upper)
        t = qt_multiply(g, params, za, s01, ta=True)
        corr = qt_scale(g, params, qt_syrk(g, params, t, trans=True), -1.0)
        zc = qt_inv_chol(g, params, qt_add(g, params, s11, corr))
        # off-diagonal Y = -Z_A T Z_C  (= -A^{-1} B Z_C)
        y = qt_scale(g, params, qt_multiply(
            g, params, za, qt_multiply(g, params, t, zc)), -1.0)
        return Alias(_register_create(g, sv.n, (za, y, None, zc), False,
                                      level))

    nid = g.register_task("inv_chol", fn, [Dep(s)])
    g.nodes[nid].level = level
    return nid
