"""Sparsity-pattern generators used throughout the paper's evaluation.

All generators return boolean occupancy matrices over an ``n x n`` element (or
block) grid.  They correspond to the four pattern families in §5/§6:

* ``banded``        — bandwidth 2d+1 (Fig 3 right, Figs 9, 12-14)
* ``random``        — uniform iid density delta (Fig 3 left)
* ``overlap``       — D-dimensional particle clouds with cutoff radius R and
                      recursive divide-space ordering (Fig 4 left, Figs 10-11)
* ``rmat``          — R-MAT graphs with tunable locality parameter a (Fig 4 right)

Element values, when requested, are deterministic given ``seed``.
"""
from __future__ import annotations

import numpy as np


def banded_mask(n: int, d: int) -> np.ndarray:
    """Boolean mask of a banded matrix with bandwidth 2d+1."""
    idx = np.arange(n)
    return np.abs(idx[:, None] - idx[None, :]) <= d


def random_mask(n: int, delta: float, seed: int = 0) -> np.ndarray:
    """Uniform iid sparsity: P[A_ij != 0] = delta, independent everywhere."""
    rng = np.random.default_rng(seed)
    return rng.random((n, n)) < delta


def random_symmetric_mask(n: int, delta: float, seed: int = 0) -> np.ndarray:
    m = random_mask(n, delta, seed)
    return m | m.T


# ---------------------------------------------------------------------------
# Overlap matrices: particles on a jittered D-dimensional grid, one basis
# function per particle, A_ij nonzero iff dist(i, j) < R.  Ordering via the
# recursive divide-space procedure (median splits along the widest axis),
# which is what gives the quadtree its locality (paper §5.1 and Ergo default).
# ---------------------------------------------------------------------------

def particle_cloud(n_per_dim: int, dim: int, spacing: float = 2.0,
                   jitter: float = 1.0, seed: int = 0) -> np.ndarray:
    """Hydrogen-like particles on a D-dim grid with uniform random jitter."""
    rng = np.random.default_rng(seed)
    axes = [np.arange(n_per_dim, dtype=np.float64) * spacing] * dim
    grid = np.stack(np.meshgrid(*axes, indexing="ij"), axis=-1).reshape(-1, dim)
    return grid + rng.uniform(-jitter, jitter, size=grid.shape)


def divide_space_order(coords: np.ndarray) -> np.ndarray:
    """Recursive divide-space ordering (paper's/Ergo's default ordering).

    Recursively split the particle set in half by the median coordinate along
    the widest axis of its bounding box.  Returns a permutation of particle
    indices; consecutive indices are spatially close, so near-diagonal matrix
    entries correspond to nearby particles — the source of data locality.
    """
    order: list[int] = []

    def rec(idx: np.ndarray) -> None:
        if len(idx) <= 1:
            order.extend(idx.tolist())
            return
        pts = coords[idx]
        widths = pts.max(axis=0) - pts.min(axis=0)
        axis = int(np.argmax(widths))
        mid = len(idx) // 2
        part = np.argpartition(pts[:, axis], mid - 1)
        rec(idx[part[:mid]])
        rec(idx[part[mid:]])

    rec(np.arange(len(coords)))
    return np.asarray(order, dtype=np.int64)


def overlap_mask(coords: np.ndarray, radius: float,
                 order: np.ndarray | None = None,
                 chunk: int = 2048) -> np.ndarray:
    """A_ij = ||x_i - x_j|| < radius, rows/cols permuted by ``order``."""
    if order is None:
        order = divide_space_order(coords)
    pts = coords[order]
    n = len(pts)
    out = np.zeros((n, n), dtype=bool)
    for s in range(0, n, chunk):  # chunked pairwise distances: O(n^2) memory-safe
        e = min(s + chunk, n)
        d2 = ((pts[s:e, None, :] - pts[None, :, :]) ** 2).sum(-1)
        out[s:e] = d2 < radius * radius
    return out


# ---------------------------------------------------------------------------
# R-MAT (recursive matrix) graphs — locality tunable via the ``a`` parameter.
# a = 0.25 => essentially uniform random; a -> 1 => strongly diagonal/local.
# Paper §5.1: b = c = d = (1 - a) / 3.
# ---------------------------------------------------------------------------

def rmat_mask(scale: int, edges_per_row: float, a: float,
              seed: int = 0, symmetric: bool = False) -> np.ndarray:
    n = 1 << scale
    n_edges = int(edges_per_row * n)
    rng = np.random.default_rng(seed)
    bcd = (1.0 - a) / 3.0
    # quadrant probabilities [ (0,0)=a, (0,1)=b, (1,0)=c, (1,1)=d ]
    probs = np.array([a, bcd, bcd, bcd])
    # vectorised: draw quadrant choices for all edges x all bit levels at once
    choices = rng.choice(4, size=(n_edges, scale), p=probs)
    row_bits = (choices >> 1) & 1
    col_bits = choices & 1
    weights = (1 << np.arange(scale - 1, -1, -1)).astype(np.int64)
    rows = (row_bits * weights).sum(axis=1)
    cols = (col_bits * weights).sum(axis=1)
    m = np.zeros((n, n), dtype=bool)
    m[rows, cols] = True  # duplicate edges collapse, as in the paper
    if symmetric:
        m |= m.T
    return m


# ---------------------------------------------------------------------------
# Values for masks (deterministic, well-conditioned for correctness tests).
# ---------------------------------------------------------------------------

def values_for_mask(mask: np.ndarray, seed: int = 0,
                    symmetric: bool = False,
                    dtype=np.float64) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.standard_normal(mask.shape).astype(dtype)
    if symmetric:
        a = (a + a.T) / 2.0
        m = np.asarray(mask) | np.asarray(mask).T
    else:
        m = np.asarray(mask)
    return np.where(m, a, 0.0).astype(dtype)


def block_mask_from_element_mask(mask: np.ndarray, bs: int) -> np.ndarray:
    """Occupancy of bs x bs blocks given an element-level mask (n divisible by bs)."""
    n = mask.shape[0]
    g = n // bs
    return mask.reshape(g, bs, g, bs).any(axis=(1, 3))


# ---------------------------------------------------------------------------
# Sparse (coordinate-list) variants — needed at paper scale (n = 65536+ in
# Fig 4) where dense boolean masks would take O(n^2) memory.
# ---------------------------------------------------------------------------

def banded_pairs(n: int, d: int) -> tuple[np.ndarray, np.ndarray]:
    """(rows, cols) of the nonzeros of a banded matrix, bandwidth 2d+1."""
    rows = np.repeat(np.arange(n), 2 * d + 1)
    cols = rows + np.tile(np.arange(-d, d + 1), n)
    ok = (cols >= 0) & (cols < n)
    return rows[ok], cols[ok]


def overlap_pairs(coords: np.ndarray, radius: float,
                  order: np.ndarray | None = None
                  ) -> tuple[np.ndarray, np.ndarray]:
    """(rows, cols) with ||x_i - x_j|| < radius via cell-list neighbour search.

    O(n * 3^D * avg_cell_occupancy) instead of O(n^2); rows/cols are indices
    in the (divide-space) ordered numbering.
    """
    if order is None:
        order = divide_space_order(coords)
    pts = coords[order]
    n, dim = pts.shape
    lo = pts.min(axis=0)
    cell = np.maximum(radius, 1e-12)
    cid = np.floor((pts - lo) / cell).astype(np.int64)
    ncell = cid.max(axis=0) + 1
    # linearise cell ids
    mult = np.cumprod(np.concatenate([[1], ncell[:-1]]))
    lin = cid @ mult
    order_by_cell = np.argsort(lin, kind="stable")
    lin_sorted = lin[order_by_cell]
    starts = np.searchsorted(lin_sorted, np.arange(0, int(ncell.prod()) + 1))
    # neighbour cell offsets
    from itertools import product as _prod
    offs = np.array(list(_prod(*[(-1, 0, 1)] * dim)), dtype=np.int64)
    rows_out: list[np.ndarray] = []
    cols_out: list[np.ndarray] = []
    r2 = radius * radius
    for off in offs:
        nb = cid + off
        ok = np.all((nb >= 0) & (nb < ncell), axis=1)
        nb_lin = nb[ok] @ mult
        src = np.nonzero(ok)[0]
        # for each source particle, candidate targets = particles in cell nb_lin
        s, e = starts[nb_lin], starts[nb_lin + 1]
        cnt = e - s
        if cnt.sum() == 0:
            continue
        rep_src = np.repeat(src, cnt)
        # gather candidate indices
        idx = np.concatenate([order_by_cell[a:b] for a, b in zip(s, e)]) \
            if len(s) else np.empty(0, np.int64)
        d2 = ((pts[rep_src] - pts[idx]) ** 2).sum(axis=1)
        keep = d2 < r2
        rows_out.append(rep_src[keep])
        cols_out.append(idx[keep])
    rows = np.concatenate(rows_out) if rows_out else np.empty(0, np.int64)
    cols = np.concatenate(cols_out) if cols_out else np.empty(0, np.int64)
    return rows, cols


def rmat_pairs(scale: int, edges_per_row: float, a: float, seed: int = 0,
               symmetric: bool = False) -> tuple[np.ndarray, np.ndarray]:
    n = 1 << scale
    n_edges = int(edges_per_row * n)
    rng = np.random.default_rng(seed)
    bcd = (1.0 - a) / 3.0
    probs = np.array([a, bcd, bcd, bcd])
    choices = rng.choice(4, size=(n_edges, scale), p=probs)
    weights = (1 << np.arange(scale - 1, -1, -1)).astype(np.int64)
    rows = (((choices >> 1) & 1) * weights).sum(axis=1)
    cols = ((choices & 1) * weights).sum(axis=1)
    uniq = np.unique(rows * n + cols)
    rows, cols = uniq // n, uniq % n
    if symmetric:
        allr = np.concatenate([rows, cols])
        allc = np.concatenate([cols, rows])
        uniq = np.unique(allr * n + allc)
        rows, cols = uniq // n, uniq % n
    return rows, cols


def coarsen_pairs(rows: np.ndarray, cols: np.ndarray, factor: int
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Occupancy coordinates one-or-more quadtree levels up (dedup)."""
    n_max = int(max(rows.max(initial=0), cols.max(initial=0))) + 1
    g = (n_max + factor - 1) // factor
    r, c = rows // factor, cols // factor
    uniq = np.unique(r * g + c)
    return uniq // g, uniq % g
