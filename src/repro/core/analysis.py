"""Computational-cost analysis of the quadtree representation (paper §5).

Closed-form task-count and communication models, eqs (1)-(17), plus exact
combinatorial counters that evaluate the same quantities from nonzero
coordinate lists (used to verify the bounds in Figs 3-4 and to drive the
communication-scaling benchmarks of Figs 12-14).

Level convention matches the paper: level l = 0 is the root, l = L the leaf
level, blocksize 1 at the leaves, matrix dimension N = 2^L.
"""
from __future__ import annotations

import numpy as np


# ---------------------------------------------------------------------------
# Random (uniform iid) sparsity — eqs (1)-(7)
# ---------------------------------------------------------------------------

def random_tasks_at_level(L: int, delta: float, l: int) -> float:
    """Eq (1): expected multiplication tasks at level l, E = 8^l * delta_l^2."""
    n_l = 2.0 ** (2 * (L - l))
    # 1 - (1-delta)^{n_l} computed stably via expm1/log1p
    delta_l = -np.expm1(n_l * np.log1p(-min(delta, 1.0 - 1e-300)))
    return (8.0 ** l) * delta_l ** 2


def random_bound_low(l: int) -> float:
    """Eq (2): C_l <= 8^l (tight at low levels)."""
    return 8.0 ** l


def random_bound_high(L: int, delta: float, l: int) -> float:
    """Eq (3): C_l <= 16^L delta^2 / 2^l (tight at high levels)."""
    return (16.0 ** L) * delta * delta / (2.0 ** l)


def random_total_bound(N: int, delta: float) -> float:
    """Eq (7): total tasks < (3 + 1/7) (delta N^2)^{3/2}."""
    return (22.0 / 7.0) * (delta * N * N) ** 1.5


# ---------------------------------------------------------------------------
# Banded sparsity — eqs (8)-(11)
# ---------------------------------------------------------------------------

def banded_d_at_level(L: int, k: int, l: int) -> int:
    """Eq (9): half-bandwidth of the level-l block occupancy, d = 2^k."""
    return 1 if l < L - k else 2 ** (l - (L - k))


def banded_tasks_bound(L: int, k: int, l: int) -> float:
    """Eq (8): C_l < 2^l (2 d_l + 1)^2."""
    d_l = banded_d_at_level(L, k, l)
    return (2.0 ** l) * (2 * d_l + 1) ** 2


def banded_total_bound(N: int, d: int) -> float:
    """Eq (11): total < (4+4/7) d^2 N + (5+1/3) d N + 2 N + 9 N / d."""
    return (32.0 / 7.0 * d * d + 16.0 / 3.0 * d + 2.0 + 9.0 / d) * N


def banded_multiply_flops(N: int, d: int) -> float:
    """Eq (16): scalar mul+add count for banded x banded, bandwidth 2d+1."""
    return 2.0 * (N * (2 * d + 1) ** 2 - (5.0 / 3.0) * d * (d + 1) * (2 * d + 1))


# ---------------------------------------------------------------------------
# Overlap (D-dimensional particle) sparsity — eq (12) scaling model
# ---------------------------------------------------------------------------

def overlap_tasks_model(L: int, dim: int, R_over_h_leaf: float, l: int
                        ) -> float:
    """Eq (12) + surrounding discussion: C_l ~ 2^l M_l^2.

    M_l = 3^D at high levels (boxes wider than R); at low levels M_l is
    proportional to the volume of a D-sphere of radius R/h_l with
    h_l ∝ 2^{(L-l)/D}.
    """
    h_ratio = 2.0 ** ((L - l) / dim)     # box width at level l / leaf width
    m_low = (R_over_h_leaf / h_ratio) ** dim
    m_l = min(3.0 ** dim, max(1.0, m_low))
    return (2.0 ** l) * m_l * m_l


# ---------------------------------------------------------------------------
# Execution-time models — eqs (13)-(14)
# ---------------------------------------------------------------------------

def exec_time_random(N: int, delta: float, p: int, c_work: float = 1.0,
                     c_crit: float = 1.0) -> float:
    """Eq (13): O((delta N^2)^{3/2} / p + log(N)^2)."""
    return c_work * (delta * N * N) ** 1.5 / p + c_crit * np.log2(N) ** 2


def exec_time_banded(N: int, d: int, p: int, c_work: float = 1.0,
                     c_crit: float = 1.0) -> float:
    """Eq (14): O(d^2 N / p + log(N)^2)."""
    return c_work * d * d * N / p + c_crit * np.log2(N) ** 2


# ---------------------------------------------------------------------------
# SpSUMMA communication — eqs (15), (17) and Table 1
# ---------------------------------------------------------------------------

def spsumma_elements_fetched_per_process(m: float, N: int, p: int) -> float:
    """Eq (15): 2 m N / sqrt(p) matrix elements fetched per process."""
    return 2.0 * m * N / np.sqrt(p)


def spsumma_weak_scaling_elements(m: float, k: float, p: int) -> float:
    """Eq (17): with N = k p (weak scaling), 2 m k sqrt(p) elements."""
    return 2.0 * m * k * np.sqrt(p)


# ---------------------------------------------------------------------------
# Exact counters from coordinate lists (drive Figs 3-4 at paper scale)
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# Per-worker communication / critical-path summaries of simulator runs
# (consumed by benchmarks/bench_comm_scaling.py, bench_weak_scaling.py and
# tests/test_scheduler.py; see repro.runtime.scheduler)
# ---------------------------------------------------------------------------

def comm_summary(bytes_received: list[int] | np.ndarray) -> dict:
    """Per-worker communication summary (the quantities of Figs 11-13).

    ``imbalance`` is max/avg — 1.0 means perfectly even reception; the
    paper's locality argument is about the *max* (the straggler's bytes).
    """
    b = np.asarray(bytes_received, dtype=np.float64)
    avg = float(b.mean())
    return {
        "n_workers": int(b.size),
        "total_bytes": float(b.sum()),
        "avg_bytes": avg,
        "max_bytes": float(b.max()),
        "min_bytes": float(b.min()),
        "imbalance": float(b.max() / avg) if avg > 0 else 1.0,
    }


def growth_ratios(values: list[float]) -> list[float]:
    """Successive ratios v[i+1]/v[i] of a scaling series (0-safe)."""
    out = []
    for lo, hi in zip(values, values[1:]):
        out.append(float(hi) / float(lo) if lo > 0 else float("inf"))
    return out


def weak_scaling_growth(series: dict[int, float]) -> float:
    """Last/first of a {p: metric} weak-scaling series.

    ~1 means the per-worker metric is flat (the paper's O(1) claim for
    local patterns under locality-aware placement, Table 1); compare with
    ``sqrt(p_last / p_first)`` for the SpSUMMA rate of eq (17).
    """
    ps = sorted(series)
    first = series[ps[0]]
    return series[ps[-1]] / first if first > 0 else float("inf")


def brent_bound(work_s: float, critical_path_s: float, p: int) -> float:
    """Greedy-scheduling makespan lower bound max(T1/p, Tinf) (§5.3)."""
    return max(work_s / p, critical_path_s)


def parallel_efficiency(work_s: float, makespan_s: float, p: int) -> float:
    """T1 / (p * makespan): fraction of worker-time spent on useful work."""
    return work_s / (p * makespan_s) if makespan_s > 0 else 0.0


def avg_parallelism(work_s: float, critical_path_s: float) -> float:
    """T1 / Tinf: how many workers the DAG can keep busy on average."""
    return work_s / critical_path_s if critical_path_s > 0 else 0.0


def truncation_summary(exact, truncated) -> dict:
    """Reduction won by a truncated multiply, from two simulator phases.

    ``exact``/``truncated`` are :class:`~repro.runtime.scheduler.SimReport`
    objects (or anything duck-typed alike) of the exact and the tau-pruned
    multiply phase over the same inputs.  Ratios are truncated/exact:
    below 1.0 means the pruning visibly shrank the quantity (tasks,
    fetched bytes, executed flops, critical path, makespan).
    """
    def ratio(t, e):
        return float(t) / float(e) if e else 1.0

    ex_bytes = sum(exact.bytes_received)
    tr_bytes = sum(truncated.bytes_received)
    out = {
        "task_ratio": ratio(truncated.n_tasks, exact.n_tasks),
        "bytes_ratio": ratio(tr_bytes, ex_bytes),
        "flops_ratio": ratio(truncated.total_flops, exact.total_flops),
        "makespan_ratio": ratio(truncated.makespan, exact.makespan),
        "n_tasks": (exact.n_tasks, truncated.n_tasks),
        "bytes_received": (ex_bytes, tr_bytes),
        "total_flops": (exact.total_flops, truncated.total_flops),
    }
    if exact.crit is not None and truncated.crit is not None:
        out["critical_path_ratio"] = ratio(truncated.crit.length_s,
                                           exact.crit.length_s)
    return out


def task_comm_demand(g, start: int = 0) -> int:
    """Fetched-dependency data volume of ``g.nodes[start:]`` in bytes.

    For every task registered at or after ``start``, sums the chunk sizes
    of its content-fetched dependencies (identifier-only deps move no
    data).  This is the communication *demand* the scheduler replays —
    what a cache-less cluster would receive — and unlike one stochastic
    work-stealing replay it is a pure graph quantity: truncation prunes
    tasks and shrinks result chunks, so demand decreases monotonically
    in tau.  Pass ``start`` = the node count before a phase to isolate
    that phase (e.g. the multiply registered after the build).
    """
    total = 0
    for n in g.nodes[start:]:
        for d in n.deps:
            if not d.fetch:
                continue
            dn = g.resolve(d.nid)
            if dn is not None:
                total += g.nodes[dn].out_nbytes
    return total


def is_monotone_nonincreasing(values, rtol: float = 0.0) -> bool:
    """True iff the series never grows by more than ``rtol`` relative.

    Used by the truncation benchmark: flops/tasks must be exactly
    non-increasing in tau (rtol=0); simulated communication is allowed a
    small scheduler-noise tolerance.
    """
    vals = [float(v) for v in values]
    for lo, hi in zip(vals, vals[1:]):
        if hi > lo * (1.0 + rtol) + 1e-12:
            return False
    return True


def critical_path_summary(work_s: float, critical_path_s: float,
                          p: int, makespan_s: float) -> dict:
    """Eq (13)/(14)-style decomposition of one simulated phase."""
    return {
        "work_s": work_s,
        "critical_path_s": critical_path_s,
        "avg_parallelism": avg_parallelism(work_s, critical_path_s),
        "brent_bound_s": brent_bound(work_s, critical_path_s, p),
        "makespan_s": makespan_s,
        "parallel_efficiency": parallel_efficiency(work_s, makespan_s, p),
    }


# ---------------------------------------------------------------------------
# Exact counters from coordinate lists (Figs 3-4 at paper scale), continued
# ---------------------------------------------------------------------------

def count_mult_tasks_pairs(rows_a: np.ndarray, cols_a: np.ndarray,
                           rows_b: np.ndarray, cols_b: np.ndarray,
                           n: int) -> int:
    """Number of (i,k,j) with A[i,k] != 0 and B[k,j] != 0.

    This is exactly the number of multiplication tasks at the level whose
    occupancy is given by the coordinate lists (paper counts both-nonzero
    products only).
    """
    col_count_a = np.bincount(cols_a, minlength=n).astype(np.int64)
    row_count_b = np.bincount(rows_b, minlength=n).astype(np.int64)
    return int(col_count_a @ row_count_b)


def count_tasks_per_level_pairs(rows: np.ndarray, cols: np.ndarray,
                                n: int,
                                rows_b: np.ndarray | None = None,
                                cols_b: np.ndarray | None = None
                                ) -> dict[int, int]:
    """Multiplication tasks at every quadtree level for C = A B.

    ``n`` must be a power of two; level L = log2(n) has blocksize 1.
    Occupancy at level l is the union of leaf occupancy coarsened by
    2^{L-l}; counts use :func:`count_mult_tasks_pairs` per level.
    """
    if rows_b is None:
        rows_b, cols_b = rows, cols
    L = int(np.log2(n))
    out: dict[int, int] = {}
    ra, ca = np.asarray(rows), np.asarray(cols)
    rb, cb = np.asarray(rows_b), np.asarray(cols_b)
    size = n
    for l in range(L, -1, -1):
        out[l] = count_mult_tasks_pairs(ra, ca, rb, cb, size)
        if l > 0:
            ra, ca = _coarsen(ra, ca, size)
            rb, cb = _coarsen(rb, cb, size)
            size //= 2
    return out


def _coarsen(rows: np.ndarray, cols: np.ndarray, n: int
             ) -> tuple[np.ndarray, np.ndarray]:
    g = n // 2
    uniq = np.unique((rows // 2) * g + (cols // 2))
    return uniq // g, uniq % g


def nnz_per_row(rows: np.ndarray, n: int) -> float:
    return len(rows) / n
