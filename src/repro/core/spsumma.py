"""Sparse SUMMA baseline (Buluc & Gilbert [46]) on a shard_map process grid.

The comparison target of the paper (Table 1, Figs 12-14): a static 2D
sqrt(p) x sqrt(p) decomposition where each device owns one panel of A, B
and C; stage-free formulation via all_gather of the A row-slab along the
process-grid columns and the B col-slab along the rows, then a local
sparse multiply.  Communication per device is the whole row/col slab:
(sqrt(p)-1)/sqrt(p) * (|A_row| + |B_col|) bytes — eq (15)'s 2mN/sqrt(p)
elements — growing as sqrt(p) in weak scaling, with or without data
locality in the pattern.

An optional host-side **random permutation** of block rows/cols mimics the
load-balancing maneuver of [21, 22] that the paper argues *destroys*
locality (Fig 1).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .blocksparse import enumerate_pairs_flat


def summa_pgrid(p: int) -> int:
    """sqrt(p), validated: SpSUMMA runs on a square process grid.

    A non-square device count used to fall through ``int(np.sqrt(p))``
    and silently shard onto a smaller sub-grid (p=6 -> 2x2, two devices
    idle and every measured slab-byte count wrong).  Fail fast instead.
    """
    p = int(p)
    if p < 1:
        raise ValueError(f"SpSUMMA needs at least one device, got p={p}")
    pgrid = int(round(p ** 0.5))
    if pgrid * pgrid != p:
        raise ValueError(
            f"SpSUMMA needs a perfect-square device count for its "
            f"sqrt(p) x sqrt(p) process grid; got p={p}. Use p in "
            f"{{1, 4, 9, 16, ...}} or the parent-worker mesh engine "
            f"(Session(engine='mesh')), which accepts any device count.")
    return pgrid


@dataclasses.dataclass(frozen=True)
class SummaPlan:
    grid: int              # global block grid
    bs: int
    pgrid: int             # process grid is pgrid x pgrid
    cap_panel: int         # max nonzero blocks in any owned panel
    cap_c_panel: int
    cap_pairs: int         # local multiply pair capacity

    @property
    def n_dev(self) -> int:
        return self.pgrid ** 2

    @property
    def panel(self) -> int:        # blocks per panel side
        return self.grid // self.pgrid


def plan_summa(mask_a: np.ndarray, mask_b: np.ndarray, bs: int,
               pgrid: int, slack: float = 1.3, round_to: int = 8
               ) -> SummaPlan:
    grid = mask_a.shape[0]
    summa_pgrid(pgrid * pgrid)      # pgrid must be a positive integer
    if grid % pgrid != 0:
        raise ValueError(
            f"SpSUMMA panel split needs the block grid ({grid}) to be "
            f"divisible by pgrid ({pgrid}); pad the matrix or pick a "
            f"device count whose sqrt divides the grid.")
    panel = grid // pgrid
    ma, mb = np.asarray(mask_a), np.asarray(mask_b)
    mc = (ma.astype(np.int64) @ mb.astype(np.int64)) > 0

    def _panels(m):
        return m.reshape(pgrid, panel, pgrid, panel).sum(axis=(1, 3))

    def _cap(x):
        return max(round_to, int(np.ceil(x * slack / round_to)) * round_to)

    cap_panel = _cap(int(max(_panels(ma).max(), _panels(mb).max())))
    cap_c_panel = _cap(int(_panels(mc).max()))
    # local pairs: row-slab of A x col-slab of B restricted to own panel
    worst = 0
    for r in range(pgrid):
        for c in range(pgrid):
            a_slab = ma[r * panel:(r + 1) * panel, :].astype(np.int64)
            b_slab = mb[:, c * panel:(c + 1) * panel].astype(np.int64)
            worst = max(worst, int((a_slab.sum(0) * b_slab.sum(1)).sum()))
    cap_pairs = _cap(worst)
    return SummaPlan(grid=grid, bs=bs, pgrid=pgrid, cap_panel=cap_panel,
                     cap_c_panel=cap_c_panel, cap_pairs=cap_pairs)


def random_block_permutation(grid: int, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).permutation(grid)


def distribute_panels(dense: np.ndarray, bs: int, plan: SummaPlan,
                      perm: Optional[np.ndarray] = None
                      ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pack a dense matrix into (n_dev, cap_panel, bs, bs) 2D-panel shards.

    Coordinates are *global* block indices (after the optional random
    permutation), padding == grid.  Device order is row-major over the
    process grid.
    """
    grid, pgrid, panel, cap = plan.grid, plan.pgrid, plan.panel, \
        plan.cap_panel
    if perm is not None:
        gp = np.repeat(perm, bs) * bs + np.tile(np.arange(bs), grid)
        dense = dense[np.ix_(gp, gp)]
    tiles = dense.reshape(grid, bs, grid, bs).transpose(0, 2, 1, 3)
    occ = np.abs(tiles).max(axis=(2, 3)) > 0
    n_dev = plan.n_dev
    blocks = np.zeros((n_dev, cap, bs, bs), dense.dtype)
    rows = np.full((n_dev, cap), grid, np.int32)
    cols = np.full((n_dev, cap), grid, np.int32)
    fill = np.zeros(n_dev, np.int64)
    for i, j in zip(*np.nonzero(occ)):
        d = (i // panel) * pgrid + (j // panel)
        s = fill[d]
        assert s < cap
        blocks[d, s] = tiles[i, j]
        rows[d, s] = i
        cols[d, s] = j
        fill[d] += 1
    return blocks, rows, cols


def summa_spmm(mesh: Mesh, axes: tuple[str, str], plan: SummaPlan,
               a_blocks, a_rows, a_cols, b_blocks, b_rows, b_cols):
    """C = A @ B via SpSUMMA all_gathers on a (pr, pc) process grid.

    Arrays carry a leading n_dev axis laid out row-major over (pr, pc) and
    sharded over both mesh axes.  Returns (c_blocks, c_rows, c_cols,
    n_pairs) with the same leading layout.
    """
    g, bs, pgrid = plan.grid, plan.bs, plan.pgrid
    cap_c, cap_pairs = plan.cap_c_panel, plan.cap_pairs
    ax_r, ax_c = axes

    def body(ab, ar, ac, bb, br, bc):
        ab, ar, ac = ab[0], ar[0], ac[0]
        bb, br, bc = bb[0], br[0], bc[0]
        pr = jax.lax.axis_index(ax_r)
        pc = jax.lax.axis_index(ax_c)

        # the SpSUMMA communication: row-slab of A, col-slab of B
        A = jax.lax.all_gather(ab, ax_c).reshape(-1, bs, bs)
        Ar = jax.lax.all_gather(ar, ax_c).reshape(-1)
        Ac = jax.lax.all_gather(ac, ax_c).reshape(-1)
        B = jax.lax.all_gather(bb, ax_r).reshape(-1, bs, bs)
        Br = jax.lax.all_gather(br, ax_r).reshape(-1)
        Bc = jax.lax.all_gather(bc, ax_r).reshape(-1)

        slot_a = jnp.full((g + 1, g + 1), -1, jnp.int32).at[Ar, Ac].set(
            jnp.arange(Ar.shape[0], dtype=jnp.int32))
        slot_a = slot_a.at[g, :].set(-1).at[:, g].set(-1)
        slot_b = jnp.full((g + 1, g + 1), -1, jnp.int32).at[Br, Bc].set(
            jnp.arange(Br.shape[0], dtype=jnp.int32))
        slot_b = slot_b.at[g, :].set(-1).at[:, g].set(-1)
        mask_a = slot_a[:g, :g] >= 0
        mask_b = slot_b[:g, :g] >= 0

        panel = g // pgrid
        r_idx = jax.lax.broadcasted_iota(jnp.int32, (g, g), 0)
        c_idx = jax.lax.broadcasted_iota(jnp.int32, (g, g), 1)
        owned = ((r_idx // panel == pr) & (c_idx // panel == pc))
        mask_c = (jnp.matmul(mask_a.astype(jnp.int32),
                             mask_b.astype(jnp.int32)) > 0) & owned

        crows, ccols = jnp.nonzero(mask_c, size=cap_c, fill_value=g)
        crows, ccols = crows.astype(jnp.int32), ccols.astype(jnp.int32)
        cslot = jnp.full((g + 1, g + 1), -1, jnp.int32).at[crows, ccols].set(
            jnp.arange(cap_c, dtype=jnp.int32))
        cslot = cslot.at[g, :].set(-1).at[:, g].set(-1)

        m3 = mask_a[:, :, None] & mask_b[None, :, :] & mask_c[:, None, :]
        pi, pk, pj = jnp.nonzero(m3, size=cap_pairs, fill_value=g)
        n_pairs = jnp.sum(m3).astype(jnp.int32)
        sa, sb, sc = slot_a[pi, pk], slot_b[pk, pj], cslot[pi, pj]
        pvalid = (sa >= 0) & (sb >= 0) & (sc >= 0)
        prods = jnp.einsum(
            "pik,pkj->pij", A[jnp.maximum(sa, 0)], B[jnp.maximum(sb, 0)],
            preferred_element_type=jnp.float32).astype(A.dtype)
        prods = jnp.where(pvalid[:, None, None], prods, 0)
        seg = jnp.where(pvalid, sc, cap_c)
        cb = jax.ops.segment_sum(prods, seg, num_segments=cap_c + 1)[:cap_c]
        return cb[None], crows[None], ccols[None], n_pairs[None]

    spec = P((ax_r, ax_c))
    fn = shard_map(body, mesh=mesh, in_specs=(spec,) * 6,
                   out_specs=(spec,) * 4, check_rep=False)
    return fn(a_blocks, a_rows, a_cols, b_blocks, b_rows, b_cols)
