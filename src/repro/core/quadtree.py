"""Quadtree representation of matrices in the Chunks and Tasks model (paper §3).

Matrices are sparse quadtrees of chunks: at every non-leaf level a matrix
chunk holds the chunk identifiers of its four submatrices (NIL for zero
submatrices — possible at *any* level); at the lowest level a block-sparse
:class:`~repro.core.leaf.LeafMatrix` is stored.  Matrix chunks carry their own
dimension and the leaf-dimension threshold but no global information (offsets
etc.), exactly as in §3.1.

Construction itself is a task program (paper §7: "generation of input matrices
... was performed using Chunks and Tasks programs"), so in the cluster
simulation the *data distribution of the inputs follows from work stealing*,
which is what makes the communication measurements of Figs 11-13 meaningful.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from .chunks import Chunk
from .leaf import LeafMatrix
from .tasks import Alias, CTGraph, Dep


@dataclasses.dataclass(frozen=True)
class QTParams(Chunk):
    """Matrix-parameters chunk type (§3.1): dims + leaf config."""
    n: int          # global matrix dimension (power-of-two multiple of leaf_n)
    leaf_n: int     # max leaf matrix dimension
    bs: int         # internal blocksize of the block-sparse leaf type

    def nbytes(self) -> int:
        return 24

    @property
    def levels(self) -> int:
        """Number of quadtree levels below the root (root = level 0)."""
        lv = 0
        n = self.n
        while n > self.leaf_n:
            n //= 2
            lv += 1
        return lv


class MatrixChunk(Chunk):
    """Basic matrix chunk (§3.1): leaf payload or 4 child chunk identifiers."""

    __slots__ = ("n", "leaf", "children", "upper", "norm2", "trace")

    def __init__(self, n: int, leaf: Optional[LeafMatrix] = None,
                 children: Optional[tuple] = None, upper: bool = False):
        self.n = n
        self.leaf = leaf
        self.children = children  # (c00, c01, c10, c11) node ids or None
        self.upper = upper
        # cached squared Frobenius norm of the *full* (symmetric-expanded)
        # submatrix this chunk roots; None until computed by qt_norm2.
        # Chunk contents are write-once (placeholder leaves are filled
        # exactly once by an engine flush), so a value computed after a
        # flush stays valid for the chunk's lifetime — until a Plan
        # rebind/replay (api/plan.py) refreshes the values in place, which
        # drops these caches through qt_invalidate_caches.  The trace
        # cache follows the same rules.
        self.norm2: Optional[float] = None
        self.trace: Optional[float] = None

    @property
    def is_leaf(self) -> bool:
        return self.leaf is not None

    def child(self, m: int, n: int) -> Optional[int]:
        """Child chunk identifier at block-row m, block-col n (0-based)."""
        return self.children[2 * m + n]

    def nbytes(self) -> int:
        if self.leaf is not None:
            return self.leaf.nbytes()
        return 64  # four identifiers + dimension info

    def content_fingerprint(self) -> Optional[bytes]:
        """Content hash for :class:`~repro.core.chunks.ChunkStore` dedup.

        Leaf chunks hash their dimensions, storage flags and block bytes;
        internal chunks opt out (their children are graph-local node ids,
        so byte-equality across registrations is not meaningful).
        """
        if self.leaf is None:
            return None
        import hashlib

        lf = self.leaf
        h = hashlib.sha1()
        h.update(f"leaf:{self.n}:{lf.bs}:{int(self.upper)}:"
                 f"{np.dtype(lf.dtype).str}".encode())
        for key in sorted(lf.blocks):
            h.update(str(key).encode())
            h.update(np.ascontiguousarray(lf.blocks[key]).tobytes())
        return h.digest()

    def content_norm2(self) -> Optional[float]:
        """Squared Frobenius norm for :meth:`ChunkStore.norm2_of`.

        Leaf chunks report the full (symmetric-expanded) norm of their
        block data; internal chunks opt out — their children are
        graph-local node ids, so the norm is a property of the quadtree
        walk (:func:`qt_norm2`), not of this chunk's bytes.
        """
        if self.leaf is None:
            return None
        if not self.upper:
            return self.leaf.norm2()
        tot = 0.0
        for (i, j) in self.leaf.blocks:
            w = self.leaf.block_norm2((i, j))
            tot += w if i == j else 2 * w
        return tot


# ---------------------------------------------------------------------------
# Construction task programs
# ---------------------------------------------------------------------------

def qt_from_dense(g: CTGraph, a: np.ndarray, params: QTParams,
                  upper: bool = False, tol: float = 0.0) -> Optional[int]:
    """Register the task tree that builds the quadtree for dense ``a``.

    Returns the root chunk's node id, or None (NIL) for an all-zero matrix.
    ``upper=True`` builds symmetric upper-triangular storage: the strictly
    lower quadrant is NIL at every level and leaves use upper block storage
    (block rows i <= j kept; diagonal blocks stored full and symmetric).
    ``a`` must then be the full symmetric matrix.
    """
    assert a.shape == (params.n, params.n)

    def build(sub: np.ndarray, up: bool) -> Optional[int]:
        n = sub.shape[0]
        if not np.any(np.abs(sub) > tol):
            return None
        if n <= params.leaf_n:
            leaf = LeafMatrix.from_dense(sub, params.bs, upper=up, tol=tol)
            if leaf.is_zero():
                return None
            return g.register_task(
                "create", lambda lf=leaf, nn=n, uu=up: MatrixChunk(
                    nn, leaf=lf, upper=uu), [])

        def fn() -> MatrixChunk:
            h = n // 2
            c00 = build(sub[:h, :h], up)
            c01 = build(sub[:h, h:], False)
            c10 = None if up else build(sub[h:, :h], False)
            c11 = build(sub[h:, h:], up)
            return MatrixChunk(n, children=(c00, c01, c10, c11), upper=up)

        return g.register_task("create", fn, [])

    tr = g.tracer
    if tr.enabled:
        n0 = len(g.nodes)
        with tr.span("qt.from_dense", track="graph", n=params.n,
                     leaf_n=params.leaf_n, bs=params.bs) as sp:
            nid = build(a, upper)
            sp.set(tasks=len(g.nodes) - n0, nil=nid is None)
        return nid
    return build(a, upper)


def qt_from_coo(g: CTGraph, rows: np.ndarray, cols: np.ndarray,
                params: QTParams,
                value_fn: Optional[Callable] = None,
                upper: bool = False) -> Optional[int]:
    """Build a quadtree from nonzero coordinates without a dense matrix.

    ``value_fn(r, c) -> np.ndarray`` produces deterministic element values for
    index arrays; defaults to a hash-based pseudo-random generator so tests
    at paper-scale dimensions need no O(n^2) memory.
    """
    if value_fn is None:
        def value_fn(r, c):
            h = (r.astype(np.uint64) * np.uint64(2654435761)
                 ^ c.astype(np.uint64) * np.uint64(40503)) & np.uint64(0xFFFF)
            return (h.astype(np.float64) / 65535.0) - 0.5

    if upper:
        # keep whole upper-triangle *blocks*: diagonal leaf blocks stay full
        keep = (cols // params.bs) >= (rows // params.bs)
        rows, cols = rows[keep], cols[keep]

    def build(r: np.ndarray, c: np.ndarray, n: int, r0: int, c0: int,
              up: bool) -> Optional[int]:
        if len(r) == 0:
            return None
        if n <= params.leaf_n:
            rr, cc = r - r0, c - c0
            vals = value_fn(r, c)

            def mk(rr=rr, cc=cc, vals=vals, nn=n, uu=up) -> MatrixChunk:
                leaf = LeafMatrix(nn, params.bs, upper=uu)
                bi, bj = rr // params.bs, cc // params.bs
                order = np.lexsort((cc, rr))
                for t in order:
                    key = (int(bi[t]), int(bj[t]))
                    blk = leaf.blocks.get(key)
                    if blk is None:
                        blk = np.zeros((params.bs, params.bs))
                        leaf.blocks[key] = blk
                    blk[rr[t] % params.bs, cc[t] % params.bs] = vals[t]
                return MatrixChunk(nn, leaf=leaf, upper=uu)

            return g.register_task("create", mk, [])

        def fn() -> MatrixChunk:
            h = n // 2
            top = r < r0 + h
            left = c < c0 + h
            c00 = build(r[top & left], c[top & left], h, r0, c0, up)
            c01 = build(r[top & ~left], c[top & ~left], h, r0, c0 + h, False)
            c10 = None if up else build(r[~top & left], c[~top & left],
                                        h, r0 + h, c0, False)
            c11 = build(r[~top & ~left], c[~top & ~left], h, r0 + h, c0 + h,
                        up)
            return MatrixChunk(n, children=(c00, c01, c10, c11), upper=up)

        return g.register_task("create", fn, [])

    tr = g.tracer
    if tr.enabled:
        n0 = len(g.nodes)
        with tr.span("qt.from_coo", track="graph", n=params.n,
                     nnz=int(len(np.asarray(rows)))) as sp:
            nid = build(np.asarray(rows), np.asarray(cols), params.n,
                        0, 0, upper)
            sp.set(tasks=len(g.nodes) - n0, nil=nid is None)
        return nid
    return build(np.asarray(rows), np.asarray(cols), params.n, 0, 0, upper)


def qt_extract(g: CTGraph, params: QTParams, a: Optional[int],
               path) -> tuple[Optional[int], QTParams]:
    """Principal-submatrix extraction: descend a quadrant path (§3.1).

    ``path`` is a sequence of child indices (0..3, row-major: 0 and 3 are
    the diagonal quadrants) naming the subtree to extract; each step
    halves the dimension.  Returns ``(nid, sub_params)`` where ``nid``
    aliases the existing child chunk — chunks are immutable and carry
    their own dimension (no global offsets, §3.1), so a subtree *is* a
    complete matrix of the smaller dimension as-is, and its cached
    norm2/trace values (and those of everything below it) carry over
    untouched rather than being recomputed.

    The localized inverse-factorization solver (arXiv:1901.07993) builds
    on this: principal submatrices of the overlap matrix are factorized
    independently and refined, touching only local subtrees.
    """
    path = tuple(path)
    n = params.n
    for idx in path:
        if idx not in (0, 1, 2, 3):
            raise ValueError(f"qt_extract: bad quadrant index {idx!r}")
        if n <= params.leaf_n:
            raise ValueError(
                "qt_extract: path descends below the leaf level "
                f"(n={n}, leaf_n={params.leaf_n})")
        n //= 2
    sub_params = QTParams(n, params.leaf_n, params.bs)
    if not path:
        return a, sub_params            # identity extraction
    if g.value_of(a) is None:
        return None, sub_params         # every subtree of NIL is NIL

    def fn(_: object) -> Alias:
        nid = a
        for idx in path:
            chunk: Optional[MatrixChunk] = g.value_of(nid)
            if chunk is None:
                return Alias(None)
            assert not chunk.is_leaf, "qt_extract: hit a leaf mid-path"
            nid = chunk.children[idx]
        return Alias(nid)

    # fetch=False: extraction routes identifiers, it never reads leaf data
    out = g.register_task("extract", fn, [Dep(a, fetch=False)])
    g.nodes[out].level = len(path)
    if g.value_of(out) is None:
        return None, sub_params
    return out, sub_params


# ---------------------------------------------------------------------------
# Readback / stats (host-side; not part of the task program)
# ---------------------------------------------------------------------------

def qt_to_dense(g: CTGraph, nid: Optional[int], params: QTParams
                ) -> np.ndarray:
    """Read a quadtree matrix back to dense.

    Symmetric upper-storage trees are expanded to the full symmetric matrix
    (the lower quadrant at each level is the transpose of the stored upper
    one; upper-storage leaves expand to full symmetric leaves).
    """
    g.flush()   # deferred leaf waves must have filled block data

    def read(nid: Optional[int], n: int) -> np.ndarray:
        chunk: Optional[MatrixChunk] = g.value_of(nid)
        if chunk is None:
            return np.zeros((n, n))
        if chunk.is_leaf:
            return chunk.leaf.to_dense()  # full symmetric when upper storage
        out = np.zeros((n, n))
        h = n // 2
        out[:h, :h] = read(chunk.child(0, 0), h)
        out[:h, h:] = read(chunk.child(0, 1), h)
        out[h:, h:] = read(chunk.child(1, 1), h)
        if chunk.upper:
            out[h:, :h] = out[:h, h:].T
        else:
            out[h:, :h] = read(chunk.child(1, 0), h)
        return out

    return read(nid, params.n)


def qt_stats(g: CTGraph, nid: Optional[int]) -> dict:
    """Leaf blocks / bytes / max depth of a quadtree matrix."""
    out = {"leaf_chunks": 0, "internal_chunks": 0, "nnz_blocks": 0,
           "bytes": 0, "depth": 0}

    def walk(nid: Optional[int], depth: int) -> None:
        chunk: Optional[MatrixChunk] = g.value_of(nid)
        if chunk is None:
            return
        out["depth"] = max(out["depth"], depth)
        out["bytes"] += chunk.nbytes()
        if chunk.is_leaf:
            out["leaf_chunks"] += 1
            out["nnz_blocks"] += chunk.leaf.n_nonzero_blocks()
            return
        out["internal_chunks"] += 1
        for c in chunk.children:
            walk(c, depth + 1)

    walk(nid, 0)
    return out


def qt_frob2(g: CTGraph, nid: Optional[int]) -> float:
    """Squared Frobenius norm of a quadtree matrix (alias of qt_norm2)."""
    return qt_norm2(g, nid)


def qt_norm2(g: CTGraph, nid: Optional[int]) -> float:
    """Squared Frobenius norm, cached at every quadtree node (DESIGN.md §5).

    Flushes first so deferred leaf waves have filled their placeholder
    blocks; after a flush every registered chunk's content is final
    (block fills are write-once), so the per-node caches stay valid even
    as later task programs extend the graph with *new* chunks.
    """
    g.flush()   # deferred leaf waves must have filled block data
    return _norm2(g, nid)


def _norm2(g: CTGraph, nid: Optional[int]) -> float:
    """Non-flushing cached norm walk; callers must ensure chunk data is
    final (the truncated multiply flushes once at its root entry)."""
    chunk: Optional[MatrixChunk] = g.value_of(nid)
    if chunk is None:
        return 0.0
    if chunk.norm2 is not None:
        return chunk.norm2
    if chunk.is_leaf:
        tot = chunk.content_norm2()     # full symmetric-expanded leaf norm
    else:
        tot = 0.0
        for idx, c in enumerate(chunk.children):
            w = _norm2(g, c)
            if chunk.upper and idx == 1:  # off-diagonal counted twice
                w *= 2
            tot += w
    chunk.norm2 = tot
    return tot


def qt_trace(g: CTGraph, nid: Optional[int]) -> float:
    """Trace of a quadtree matrix, cached at every node like qt_norm2.

    Only the diagonal path (c00/c11 at every level) is walked; symmetric
    upper storage needs no special casing because the diagonal quadrants
    are stored and diagonal leaf blocks are kept full.
    """
    g.flush()   # deferred leaf waves must have filled block data
    return _trace(g, nid)


def _trace(g: CTGraph, nid: Optional[int]) -> float:
    chunk: Optional[MatrixChunk] = g.value_of(nid)
    if chunk is None:
        return 0.0
    if chunk.trace is not None:
        return chunk.trace
    if chunk.is_leaf:
        tot = chunk.leaf.trace()
    else:
        tot = _trace(g, chunk.child(0, 0)) + _trace(g, chunk.child(1, 1))
    chunk.trace = tot
    return tot


# ---------------------------------------------------------------------------
# Input rebinding (compiled-Plan re-execution, api/plan.py)
#
# A Plan replays a fixed task program against *refreshed input values*: the
# quadtree structure — NIL pattern, leaf block occupancy — is part of the
# plan's fingerprint and must not change, so rebinding is an in-place fill
# of the existing leaf blocks plus cache invalidation.  No tasks are
# registered and no chunks are created.  Structure mismatches raise
# :class:`PlanStructureError` *before any block is mutated* (validate
# pass, then fill pass), so a failed rebind leaves the compiled input —
# and therefore the plan — fully usable; ``plan.run(..., recompile=True)``
# relies on this atomicity to fall back to a fresh compile.
# ---------------------------------------------------------------------------

class PlanStructureError(ValueError):
    """A rebound plan input's sparsity structure differs from the structure
    frozen into the compiled fingerprint.

    A compiled :class:`~repro.api.plan.Plan` replays a *fixed* task
    program — including truncation pair lists frozen at compile time — so
    values that fall outside the compiled structure (a denser iterate in
    a purification loop, a different NIL pattern) cannot be replayed:
    the stale program would silently drop their contributions.  Either
    build a fresh matrix and plan for the new structure, or pass
    ``recompile=True`` to :meth:`~repro.api.plan.Plan.run` to recompile
    through the session's plan cache transparently.  Subclasses
    ``ValueError`` for backwards compatibility with callers that caught
    the untyped error this used to be.
    """


def _subtree_leaf_ids(g: CTGraph, nid: Optional[int]) -> set:
    """``id(LeafMatrix)`` of every leaf under ``nid`` (NIL-aware)."""
    ids: set = set()

    def walk(n: Optional[int]) -> None:
        chunk: Optional[MatrixChunk] = g.value_of(n)
        if chunk is None:
            return
        if chunk.is_leaf:
            ids.add(id(chunk.leaf))
        else:
            for c in chunk.children:
                walk(c)

    walk(nid)
    return ids


def _flush_if_entangled(g: CTGraph, leaf_ids: set) -> None:
    """Flush only when deferred work touches one of these leaves.

    Rebind overwrites leaf payloads in place, so any pending task reading
    or writing them must run first.  But an *unconditional* flush here
    would drain every other in-flight plan's deferred waves as a side
    effect, defeating the serving layer's cross-plan wave coalescing
    (DESIGN.md §9) — so unrelated pending work is left untouched.
    """
    eng = g._engine
    if eng is not None and eng.has_pending_for(leaf_ids):
        g.flush()


def qt_rebind_dense(g: CTGraph, nid: Optional[int], a: np.ndarray,
                    params: QTParams) -> None:
    """Refill a built quadtree's leaf values from a dense array, in place.

    ``a`` must be supported on the tree's existing structure: any entry
    outside a stored leaf block (or inside a NIL subtree) must be zero —
    structure changes raise :class:`PlanStructureError` before anything
    is written (a fresh matrix and plan, or ``Plan.run(recompile=True)``,
    handle a different sparsity structure).  For symmetric upper storage
    pass the full symmetric matrix, exactly as :func:`qt_from_dense`
    expects.
    """
    a = np.asarray(a)
    assert a.shape == (params.n, params.n)
    # placeholder leaves must be final before we overwrite them
    _flush_if_entangled(g, _subtree_leaf_ids(g, nid))

    def check(nid: Optional[int], sub: np.ndarray) -> None:
        chunk: Optional[MatrixChunk] = g.value_of(nid)
        if chunk is None:
            if np.any(sub != 0.0):
                raise PlanStructureError(
                    "rebind structure mismatch: new values are nonzero "
                    "inside a NIL subtree of the compiled input; build a "
                    "new matrix (and plan) for a different sparsity "
                    "structure, or run the plan with recompile=True")
            return
        if chunk.is_leaf:
            lf = chunk.leaf
            bs = lf.bs
            grid = lf.n // bs
            for bi in range(grid):
                bj0 = bi if lf.upper else 0
                for bj in range(bj0, grid):
                    if (bi, bj) in lf.blocks:
                        continue
                    blk = sub[bi * bs:(bi + 1) * bs, bj * bs:(bj + 1) * bs]
                    if np.any(blk != 0.0):
                        raise PlanStructureError(
                            "rebind structure mismatch: new values fall "
                            "outside the compiled input's leaf block "
                            "structure; build a new matrix (and plan), "
                            "or run the plan with recompile=True")
        else:
            h = chunk.n // 2
            check(chunk.child(0, 0), sub[:h, :h])
            check(chunk.child(0, 1), sub[:h, h:])
            if not chunk.upper:
                check(chunk.child(1, 0), sub[h:, :h])
            check(chunk.child(1, 1), sub[h:, h:])

    def fill(nid: Optional[int], sub: np.ndarray) -> None:
        chunk: Optional[MatrixChunk] = g.value_of(nid)
        if chunk is None:
            return
        if chunk.is_leaf:
            lf = chunk.leaf
            bs = lf.bs
            for (i, j), blk in lf.blocks.items():
                blk[...] = sub[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs]
            lf.invalidate_norms()
        else:
            h = chunk.n // 2
            fill(chunk.child(0, 0), sub[:h, :h])
            fill(chunk.child(0, 1), sub[:h, h:])
            if not chunk.upper:
                fill(chunk.child(1, 0), sub[h:, :h])
            fill(chunk.child(1, 1), sub[h:, h:])
        chunk.norm2 = None
        chunk.trace = None

    check(nid, a)   # atomic: raise before the first block is written
    fill(nid, a)


def qt_rebind_from(g: CTGraph, dst: Optional[int], src: Optional[int]
                   ) -> None:
    """Copy leaf values from one quadtree into a structure-identical other.

    This is the iterative-algorithm hot path: feeding a plan's output back
    into its input slot copies the values *before* the replay starts, so
    rebinding an input to the plan's own previous output is safe.  Raises
    :class:`PlanStructureError` on any structural difference (NIL
    pattern, leaf keys) — before any destination block is written, so the
    compiled input survives a failed rebind untouched.
    """
    # src leaves are read, dst leaves overwritten: both must be settled
    _flush_if_entangled(g, _subtree_leaf_ids(g, dst)
                        | _subtree_leaf_ids(g, src))

    def check(d: Optional[int], s: Optional[int]) -> None:
        dc: Optional[MatrixChunk] = g.value_of(d)
        sc: Optional[MatrixChunk] = g.value_of(s)
        if (dc is None) != (sc is None):
            raise PlanStructureError(
                "rebind structure mismatch: NIL pattern differs between "
                "the compiled input and the new operand; build a new "
                "plan, or run the existing one with recompile=True")
        if dc is None:
            return
        if dc.is_leaf != sc.is_leaf or dc.n != sc.n:
            raise PlanStructureError(
                "rebind structure mismatch: quadtree shapes differ")
        if dc.is_leaf:
            if set(dc.leaf.blocks) != set(sc.leaf.blocks):
                raise PlanStructureError(
                    "rebind structure mismatch: leaf block occupancy "
                    "differs between the compiled input and the new "
                    "operand; build a new plan, or run the existing one "
                    "with recompile=True")
        else:
            for i in range(4):
                check(dc.children[i], sc.children[i])

    def copy(d: Optional[int], s: Optional[int]) -> None:
        dc: Optional[MatrixChunk] = g.value_of(d)
        sc: Optional[MatrixChunk] = g.value_of(s)
        if dc is None:
            return
        if dc.is_leaf:
            for key, blk in sc.leaf.blocks.items():
                dc.leaf.blocks[key][...] = blk
            dc.leaf.invalidate_norms()
        else:
            for i in range(4):
                copy(dc.children[i], sc.children[i])
        dc.norm2 = None
        dc.trace = None

    check(dst, src)   # atomic: raise before the first block is written
    copy(dst, src)


def qt_invalidate_caches(g: CTGraph, nids) -> None:
    """Drop chunk-level norm/trace caches of the given nodes' chunks.

    Plan replay refreshes chunk values in place; every cache computed from
    the old values (chunk norms used by SpAMM pruning, traces) must go.
    Leaf-level caches are dropped by the engines' in-place fills; this
    covers the chunk objects themselves, including internal create-level
    chunks whose norms aggregate their subtrees.
    """
    for nid in nids:
        chunk = g.nodes[nid].value
        if isinstance(chunk, MatrixChunk):
            chunk.norm2 = None
            chunk.trace = None
            if chunk.leaf is not None:
                chunk.leaf.invalidate_norms()


def qt_structure_fp(g: CTGraph, nid: Optional[int]) -> str:
    """Structural fingerprint of a quadtree: NIL pattern + leaf occupancy.

    Values are deliberately excluded — two matrices with the same
    structure fingerprint are interchangeable as compiled-plan inputs
    (same task program, same chunk shapes), differing only in the numbers
    a rebind fills in.  Structure is final at registration (deferred
    engines allocate placeholder blocks up front), so no flush is needed.
    """
    import hashlib

    h = hashlib.sha1()

    def walk(nid: Optional[int]) -> None:
        chunk: Optional[MatrixChunk] = g.value_of(nid)
        if chunk is None:
            h.update(b"N")
            return
        if chunk.is_leaf:
            h.update(f"L{chunk.n}:{chunk.leaf.bs}:{int(chunk.upper)}:"
                     f"{sorted(chunk.leaf.blocks)}".encode())
            return
        h.update(f"I{chunk.n}:{int(chunk.upper)}(".encode())
        for c in chunk.children:
            walk(c)
        h.update(b")")

    walk(nid)
    return h.hexdigest()


_ = Dep  # re-export convenience for callers building custom task programs
