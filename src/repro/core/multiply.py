"""Quadtree matrix operation task types (paper §3.2-§3.3, Algorithms 1-2).

Implemented task types (names match the paper):

* ``multiply``      — C = op(A) op(B), op ∈ {id, transpose}  (Algorithm 1)
* ``add``           — C = A + B                               (Algorithm 2)
* ``create``        — creation from submatrix identifiers     (§3.2)
* ``transpose``     — C = Aᵀ materialised (facade fallback when a lazy
                      transpose meets an op with no op(A) slot, e.g. add)
* ``sym_square``    — C = A², A symmetric upper storage       (§3.3)
* ``syrk``          — C = A Aᵀ or AᵀA, C upper storage        (§3.3)
* ``sym_multiply``  — C = S B or B S, S symmetric upper       (§3.3)

NIL handling follows Algorithms 1-2 line 2 / fallback-execute semantics: a
task with a NIL input is never *executed* with data — here we resolve the NIL
check at registration time (equivalently: the runtime short-circuits to the
fallback), so ``count_kinds()['multiply']`` equals the paper's "number of
multiplication tasks" (eq. (1) counts both-nonzero products only).

Additions with exactly one NIL operand alias the other chunk id (Alg 2 lines
15-18: "C = A" is an identifier copy, no new chunk, no work).

Leaf-level tasks carry a batchable :class:`~repro.core.engine.LeafPayload`
instead of an opaque closure and are dispatched through the graph's leaf
engine (engine.py): ``CTGraph(engine="numpy")`` executes them immediately
with the host library, ``CTGraph(engine="pallas")`` defers and batches them
across the whole quadtree into fused kernel waves (§4.1 batched leaf work).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from .engine import LeafPayload
from .quadtree import MatrixChunk, QTParams, _norm2
from .tasks import Alias, CTGraph, Dep


def _level_of(params: QTParams, n: int) -> int:
    return int(round(math.log2(params.n // n)))


@dataclasses.dataclass
class TruncationReport:
    """Running record of one error-controlled truncated multiply.

    ``error_bound`` is a worst-case bound on ``||C_exact - C_tau||_F``:
    every pruned product P = op(A') op(B') satisfies
    ``||P||_F <= ||A'||_F ||B'||_F < tau`` (submultiplicativity), and by
    the triangle inequality the total error of dropping a set of products
    is at most the sum of their individual bounds.  Subtree prunes (any
    quadtree level) and within-leaf block-pair prunes both contribute;
    a subtree pruned as a whole is counted once, covering all its
    descendants.  See DESIGN.md §5 for the derivation.
    """
    tau: float
    error_bound: float = 0.0        # running worst-case ||C_exact - C_tau||_F
    pruned_subtrees: int = 0        # recursive products pruned, any level
    pruned_leaf_pairs: int = 0      # block pairs pruned inside leaf tasks
    pruned_flops: float = 0.0       # leaf-pair flops avoided (2 bs^3 each)
    pruned_by_level: dict[int, int] = dataclasses.field(default_factory=dict)

    def record_subtree(self, bound: float, level: int) -> None:
        self.error_bound += bound
        self.pruned_subtrees += 1
        self.pruned_by_level[level] = self.pruned_by_level.get(level, 0) + 1

    def record_leaf_pair(self, bound: float, flops: float) -> None:
        self.error_bound += bound
        self.pruned_leaf_pairs += 1
        self.pruned_flops += flops

    def to_dict(self) -> dict:
        return {
            "tau": self.tau,
            "error_bound": self.error_bound,
            "pruned_subtrees": self.pruned_subtrees,
            "pruned_leaf_pairs": self.pruned_leaf_pairs,
            "pruned_flops": self.pruned_flops,
            "pruned_by_level": dict(self.pruned_by_level),
        }


def _register_create(g: CTGraph, n: int, cids: tuple, upper: bool,
                     level: int) -> Optional[int]:
    """Creation-from-submatrix-identifiers task (§3.2).

    Consumes chunk *identifiers* (fetch=False: no data transfer) and produces
    the small internal matrix chunk.  Returns NIL if every child is NIL.
    """
    if all(g.is_nil(c) for c in cids):
        return None

    def fn(*ids) -> MatrixChunk:
        norm = tuple(None if g.is_nil(i) else i for i in ids)
        return MatrixChunk(n, children=norm, upper=upper)

    nid = g.register_task("create", fn,
                          [Dep(c, fetch=False) for c in cids])
    g.nodes[nid].level = level
    return nid


def qt_add(g: CTGraph, params: QTParams, a: Optional[int], b: Optional[int]
           ) -> Optional[int]:
    """C = A + B (Algorithm 2). Single-NIL cases alias, both-NIL is NIL."""
    if g.is_nil(a):
        return b if not g.is_nil(b) else None
    if g.is_nil(b):
        return a

    ac: MatrixChunk = g.value_of(a)
    bc: MatrixChunk = g.value_of(b)
    assert ac.n == bc.n and ac.upper == bc.upper
    level = _level_of(params, ac.n)

    if ac.is_leaf:
        nid = g.register_task("add", None, [Dep(a), Dep(b)],
                              payload=LeafPayload("add", a=a, b=b))
        g.nodes[nid].level = level
        return nid

    def fn(av: MatrixChunk, bv: MatrixChunk):
        cids = tuple(
            qt_add(g, params, av.children[i], bv.children[i])
            for i in range(4))
        return Alias(_register_create(g, av.n, cids, av.upper, level))

    nid = g.register_task("add", fn, [Dep(a), Dep(b)])
    g.nodes[nid].level = level
    return nid


def qt_multiply(g: CTGraph, params: QTParams, a: Optional[int],
                b: Optional[int], ta: bool = False, tb: bool = False,
                tau: float = 0.0,
                trunc: Optional[TruncationReport] = None) -> Optional[int]:
    """C = op(A) op(B) (Algorithm 1 + transposed variants, §3.2).

    ``tau > 0`` enables SpAMM-style hierarchical norm truncation
    (DESIGN.md §5): at *every* recursion level the product is pruned to
    NIL when ``||A'||_F ||B'||_F < tau`` (cached subtree norms,
    :func:`~repro.core.quadtree.qt_norm2`), and inside surviving leaf
    tasks block pairs are pruned by the same test on cached per-block
    norms — pruned pairs never reach the leaf engine, so they never
    enter a Pallas wave.  Each prune's bound is accumulated into
    ``trunc`` (a :class:`TruncationReport`), whose ``error_bound`` is a
    worst-case bound on ``||C_exact - C_tau||_F``.  Norms are
    transpose-invariant, so ``ta``/``tb`` need no special casing.

    ``tau == 0`` is *graph-for-graph identical* to the exact multiply
    (pinned by tests/test_truncation.py): no flush, no norm reads, no
    pruning — the strict ``< tau`` test can never fire.
    """
    # root-entry span (recursive calls see subtree dimensions < params.n);
    # instrumentation only — registration is identical either way
    tr = g.tracer
    if tr.enabled and not g.is_nil(a) and g.value_of(a).n == params.n:
        n0 = len(g.nodes)
        with tr.span("qt.multiply", track="graph", n=params.n, tau=tau,
                     ta=ta, tb=tb) as sp:
            nid = _qt_multiply(g, params, a, b, ta, tb, tau, trunc)
            sp.set(tasks=len(g.nodes) - n0, nil=nid is None)
        return nid
    return _qt_multiply(g, params, a, b, ta, tb, tau, trunc)


def _qt_multiply(g: CTGraph, params: QTParams, a: Optional[int],
                 b: Optional[int], ta: bool = False, tb: bool = False,
                 tau: float = 0.0,
                 trunc: Optional[TruncationReport] = None) -> Optional[int]:
    if g.is_nil(a) or g.is_nil(b):
        return None
    ac: MatrixChunk = g.value_of(a)
    level = _level_of(params, ac.n)

    if tau > 0.0:
        if ac.n == params.n:
            # root entry: deferred waves must have filled the operands'
            # blocks before their norms mean anything.  Recursive calls
            # skip this (flushing mid-registration would fragment the
            # engine's cross-leaf batching of the product's own leaves).
            g.flush()
        bound = math.sqrt(_norm2(g, a) * _norm2(g, b))
        if bound < tau:
            if trunc is not None:
                trunc.record_subtree(bound, level)
            return None

    if ac.is_leaf:
        nid = g.register_task(
            "multiply", None, [Dep(a), Dep(b)],
            payload=LeafPayload("multiply", a=a, b=b, ta=ta, tb=tb,
                                tau=tau, trunc=trunc))
        g.nodes[nid].level = level
        return nid

    def fn(av: MatrixChunk, bv: MatrixChunk):
        def asub(m: int, k: int) -> Optional[int]:
            return av.child(k, m) if ta else av.child(m, k)

        def bsub(k: int, n: int) -> Optional[int]:
            return bv.child(n, k) if tb else bv.child(k, n)

        cids = []
        for m in (0, 1):
            for n in (0, 1):
                y1 = qt_multiply(g, params, asub(m, 0), bsub(0, n), ta, tb,
                                 tau=tau, trunc=trunc)
                y2 = qt_multiply(g, params, asub(m, 1), bsub(1, n), ta, tb,
                                 tau=tau, trunc=trunc)
                cids.append(qt_add(g, params, y1, y2))
        return Alias(_register_create(g, av.n, tuple(cids), False, level))

    nid = g.register_task("multiply", fn, [Dep(a), Dep(b)])
    g.nodes[nid].level = level
    return nid


def qt_transpose(g: CTGraph, params: QTParams, a: Optional[int]
                 ) -> Optional[int]:
    """C = Aᵀ, materialised.

    Multiplies fold op(A) into the task itself (Algorithm 1's op(A) op(B));
    this explicit task program exists for the cases with no op slot, e.g.
    adding a transposed matrix.  Internal levels are identifier shuffling
    (create-from-ids); leaf transposes are dispatched through the leaf
    engine as payloads so deferred backends order them after the waves
    that fill their inputs.  Symmetric upper-storage trees satisfy A = Aᵀ
    and return the same identifier (no task, no new chunk).
    """
    if g.is_nil(a):
        return None
    ac: MatrixChunk = g.value_of(a)
    if ac.upper:
        return a
    level = _level_of(params, ac.n)

    if ac.is_leaf:
        nid = g.register_task("transpose", None, [Dep(a)],
                              payload=LeafPayload("transpose", a=a))
        g.nodes[nid].level = level
        return nid

    def fn(av: MatrixChunk):
        c00, c01, c10, c11 = av.children
        cids = (qt_transpose(g, params, c00), qt_transpose(g, params, c10),
                qt_transpose(g, params, c01), qt_transpose(g, params, c11))
        created = _register_create(g, av.n, cids, False, level)
        if created is not None:
            if av.norm2 is not None:
                # the Frobenius norm is transpose-invariant: maintain the
                # cache instead of recomputing it on the result subtree
                g.value_of(created).norm2 = av.norm2
            if av.trace is not None:    # so is the trace
                g.value_of(created).trace = av.trace
        return Alias(created)

    nid = g.register_task("transpose", fn, [Dep(a)])
    g.nodes[nid].level = level
    return nid


def qt_scale(g: CTGraph, params: QTParams, a: Optional[int], alpha: float
             ) -> Optional[int]:
    """C = alpha * A (facade satellite: scalar algebra for SP2-style loops).

    ``alpha == 1`` is an identifier copy (no task, no new chunk) and
    ``alpha == 0`` is structurally NIL, mirroring the NIL short-circuits
    of Algorithms 1-2.  Internal levels are identifier shuffling
    (create-from-ids); leaf scaling is dispatched through the leaf engine
    so deferred backends order it after the waves filling its input.
    Storage flags (symmetric upper) are preserved.
    """
    if g.is_nil(a) or alpha == 0.0:
        return None
    if alpha == 1.0:
        return a
    ac: MatrixChunk = g.value_of(a)
    level = _level_of(params, ac.n)

    if ac.is_leaf:
        nid = g.register_task("scale", None, [Dep(a)],
                              payload=LeafPayload("scale", a=a, alpha=alpha))
        g.nodes[nid].level = level
        return nid

    def fn(av: MatrixChunk):
        cids = tuple(qt_scale(g, params, c, alpha) for c in av.children)
        created = _register_create(g, av.n, cids, av.upper, level)
        if created is not None and av.norm2 is not None:
            # ||alpha A||_F^2 = alpha^2 ||A||_F^2: maintain the cache
            g.value_of(created).norm2 = av.norm2 * alpha * alpha
        return Alias(created)

    nid = g.register_task("scale", fn, [Dep(a)])
    g.nodes[nid].level = level
    return nid


def qt_replay(g: CTGraph, nids, *, flush: bool = True) -> None:
    """Re-execute the numeric work of an already-registered task program.

    ``nids`` is the (ascending) node-id range a compiled Plan registered.
    Registration order is dependency order for leaf payload tasks (their
    operand ids always precede them), so one forward sweep re-dispatches
    every payload task through the graph's leaf engine —
    :meth:`~repro.core.engine.LeafEngine.reexecute` fills the *existing*
    chunks in place, registering nothing — and a final flush runs the
    deferred backends' batched waves.  Structural nodes (creates,
    recursion containers, aliases) hold only identifiers and need no
    recomputation.

    ``flush=False`` leaves the re-dispatched work deferred so a serving
    front end can coalesce the ready waves of several plans into shared
    batched dispatches before flushing once (DESIGN.md §9).
    """
    for nid in nids:
        node = g.nodes[nid]
        if node.payload is not None and node.value is not None:
            g.engine.reexecute(g, node, node.payload)
    if flush:
        g.flush()


def qt_sym_square(g: CTGraph, params: QTParams, a: Optional[int]
                  ) -> Optional[int]:
    """C = A², A symmetric in upper-triangular storage (§3.3)."""
    if g.is_nil(a):
        return None
    ac: MatrixChunk = g.value_of(a)
    assert ac.upper
    level = _level_of(params, ac.n)

    if ac.is_leaf:
        nid = g.register_task("sym_square", None, [Dep(a)],
                              payload=LeafPayload("sym_square", a=a))
        g.nodes[nid].level = level
        return nid

    def fn(av: MatrixChunk):
        a00, a01, _, a11 = av.children
        c00 = qt_add(g, params,
                     qt_sym_square(g, params, a00),
                     qt_syrk(g, params, a01, trans=False))
        c01 = qt_add(g, params,
                     qt_sym_multiply(g, params, a00, a01, side="left"),
                     qt_sym_multiply(g, params, a11, a01, side="right"))
        c11 = qt_add(g, params,
                     qt_sym_square(g, params, a11),
                     qt_syrk(g, params, a01, trans=True))
        return Alias(_register_create(g, av.n, (c00, c01, None, c11), True,
                                      level))

    nid = g.register_task("sym_square", fn, [Dep(a)])
    g.nodes[nid].level = level
    return nid


def qt_syrk(g: CTGraph, params: QTParams, a: Optional[int],
            trans: bool = False) -> Optional[int]:
    """C = A Aᵀ (trans=False) or AᵀA (trans=True); C upper storage (§3.3)."""
    if g.is_nil(a):
        return None
    ac: MatrixChunk = g.value_of(a)
    assert not ac.upper
    level = _level_of(params, ac.n)

    if ac.is_leaf:
        nid = g.register_task("syrk", None, [Dep(a)],
                              payload=LeafPayload("syrk", a=a, trans=trans))
        g.nodes[nid].level = level
        return nid

    def fn(av: MatrixChunk):
        a00, a01, a10, a11 = av.children
        if not trans:   # C = A Aᵀ
            c00 = qt_add(g, params, qt_syrk(g, params, a00, False),
                         qt_syrk(g, params, a01, False))
            c01 = qt_add(g, params,
                         qt_multiply(g, params, a00, a10, tb=True),
                         qt_multiply(g, params, a01, a11, tb=True))
            c11 = qt_add(g, params, qt_syrk(g, params, a10, False),
                         qt_syrk(g, params, a11, False))
        else:           # C = Aᵀ A
            c00 = qt_add(g, params, qt_syrk(g, params, a00, True),
                         qt_syrk(g, params, a10, True))
            c01 = qt_add(g, params,
                         qt_multiply(g, params, a00, a01, ta=True),
                         qt_multiply(g, params, a10, a11, ta=True))
            c11 = qt_add(g, params, qt_syrk(g, params, a01, True),
                         qt_syrk(g, params, a11, True))
        return Alias(_register_create(g, av.n, (c00, c01, None, c11), True,
                                      level))

    nid = g.register_task("syrk", fn, [Dep(a)])
    g.nodes[nid].level = level
    return nid


def qt_sym_multiply(g: CTGraph, params: QTParams, s: Optional[int],
                    b: Optional[int], side: str = "left") -> Optional[int]:
    """C = S B (side='left') or C = B S (side='right'); S symmetric upper."""
    if g.is_nil(s) or g.is_nil(b):
        return None
    sc: MatrixChunk = g.value_of(s)
    bc: MatrixChunk = g.value_of(b)
    assert sc.upper and not bc.upper
    level = _level_of(params, sc.n)

    if sc.is_leaf:
        nid = g.register_task(
            "sym_multiply", None, [Dep(s), Dep(b)],
            payload=LeafPayload("sym_multiply", a=s, b=b, side=side))
        g.nodes[nid].level = level
        return nid

    def fn(sv: MatrixChunk, bv: MatrixChunk):
        s00, s01, _, s11 = sv.children
        b00, b01, b10, b11 = bv.children
        if side == "left":      # C = S B;  S10 = S01ᵀ implicit
            c00 = qt_add(g, params,
                         qt_sym_multiply(g, params, s00, b00, "left"),
                         qt_multiply(g, params, s01, b10))
            c01 = qt_add(g, params,
                         qt_sym_multiply(g, params, s00, b01, "left"),
                         qt_multiply(g, params, s01, b11))
            c10 = qt_add(g, params,
                         qt_multiply(g, params, s01, b00, ta=True),
                         qt_sym_multiply(g, params, s11, b10, "left"))
            c11 = qt_add(g, params,
                         qt_multiply(g, params, s01, b01, ta=True),
                         qt_sym_multiply(g, params, s11, b11, "left"))
        else:                    # C = B S
            c00 = qt_add(g, params,
                         qt_sym_multiply(g, params, s00, b00, "right"),
                         qt_multiply(g, params, b01, s01, tb=True))
            c01 = qt_add(g, params,
                         qt_multiply(g, params, b00, s01),
                         qt_sym_multiply(g, params, s11, b01, "right"))
            c10 = qt_add(g, params,
                         qt_sym_multiply(g, params, s00, b10, "right"),
                         qt_multiply(g, params, b11, s01, tb=True))
            c11 = qt_add(g, params,
                         qt_multiply(g, params, b10, s01),
                         qt_sym_multiply(g, params, s11, b11, "right"))
        return Alias(_register_create(g, sv.n, (c00, c01, c10, c11), False,
                                      level))

    nid = g.register_task("sym_multiply", fn, [Dep(s), Dep(b)])
    g.nodes[nid].level = level
    return nid


# ---------------------------------------------------------------------------
# Counting utilities (Figs 3-4)
# ---------------------------------------------------------------------------

MULTIPLY_KINDS = ("multiply", "sym_square", "syrk", "sym_multiply")


def count_tasks_per_level(g: CTGraph, kinds=MULTIPLY_KINDS
                          ) -> dict[int, int]:
    out: dict[int, int] = {}
    for n in g.nodes:
        if n.kind in kinds and n.level >= 0:
            out[n.level] = out.get(n.level, 0) + 1
    return out


def total_multiply_tasks(g: CTGraph) -> int:
    return sum(1 for n in g.nodes if n.kind in MULTIPLY_KINDS)


def total_add_tasks(g: CTGraph) -> int:
    return sum(1 for n in g.nodes if n.kind == "add")


def total_flops(g: CTGraph) -> float:
    return sum(n.flops for n in g.nodes)
