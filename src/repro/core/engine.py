"""Leaf execution engine: pluggable backends for leaf-level multiply work.

The paper's performance story (§4.1) is that leaf-level multiplication work
is *batched* and offloaded: "in case GPUs are available, both CPUs and GPUs
are used for leaf-level multiplication work", with the small block GEMMs
mapped onto the cuBLAS batched-gemm API.  This module is the repo's rendering
of that pluggable leaf engine:

* :class:`NumpyEngine` — the reference backend; executes each leaf task
  immediately with the host library (core/leaf.py), preserving the original
  per-task semantics exactly.
* :class:`PallasEngine` — the accelerator backend.  Leaf multiply/syrk/
  sym_square/sym_multiply tasks are *not* executed at registration: their
  output **structure** is computed up front (via
  :func:`repro.core.bsmm.compute_c_structure` on the leaf occupancy masks —
  the create-from-ids tree collapsed to one boolean matmul) and zero
  placeholder blocks are allocated, while the numeric work is deferred.  At
  flush time the engine harvests *all* pending leaf tasks across the whole
  quadtree, packs every surviving block pair of every leaf into one
  ``(P, bs, bs)`` operand stream, and executes **one fused kernel call per
  wave** — ``kernels.bsmm_pairs`` (gather-GEMM-scatter) or
  ``kernels.batched_gemm`` + host scatter-add.  This lifts the paper's Fig 2
  outer-product batching from per-leaf to per-graph: cross-leaf batching.

Correctness of deferral rests on a structural fact both backends share: the
*occupancy* of every leaf result is determined by the operand masks alone
(einsum over structurally-present pairs), so NIL propagation — and therefore
the task graph, task counts and flop attribution — is identical across
backends; only the numeric fill is deferred.  Numerically the backends agree
to float32 precision: the pallas backend packs operands as float32 and its
kernels accumulate in float32, so its result leaves are float32 even when
the inputs are float64 (see the PallasEngine docstring).

Flop/byte attribution: each task's ``node.flops`` is set at registration
from its structural pair count (identical formula to the numpy backend's
LeafStats), so :class:`~repro.core.tasks.ClusterSim` sees per-task work
regardless of backend; the fused-wave reality (kernel wall time, pair and
padding counts, bytes packed) is recorded in :meth:`PallasEngine.stats`.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Optional

import numpy as np

from .leaf import (LeafMatrix, LeafStats, alloc_structure, inv_chol_keys,
                   leaf_add, leaf_inv_chol, leaf_multiply, leaf_scale,
                   leaf_sym_multiply, leaf_sym_square, leaf_syrk,
                   leaf_tri_solve, tri_solve_keys, unpack_blocks)
from .quadtree import MatrixChunk
from repro.obs.tracer import NOOP

#: leaf-payload kinds executed host-side (no kernel wave)
HOST_KINDS = ("add", "transpose", "scale")

#: leaf-payload kinds dispatched through the batched triangular kernels
#: (kernels/tri.py) — their own wave family, never mixed into GEMM waves
SOLVE_KINDS = ("tri_solve", "inv_chol")


@dataclasses.dataclass(frozen=True)
class LeafPayload:
    """Batchable description of a leaf task (replaces opaque closures).

    ``a``/``b`` are producer *node ids* in the registering CTGraph; the
    engine resolves them to chunks at execution time.  Only the fields
    relevant to ``kind`` are meaningful.
    """
    # multiply|sym_square|syrk|sym_multiply|add|transpose|scale
    # |tri_solve|inv_chol
    kind: str
    a: Optional[int] = None
    b: Optional[int] = None
    ta: bool = False                # multiply: transpose A
    tb: bool = False                # multiply: transpose B
    trans: bool = False             # syrk: A^T A instead of A A^T
    side: str = "left"              # sym_multiply: S B vs B S
    tau: float = 0.0                # multiply: SpAMM block-pair threshold
    alpha: float = 1.0              # scale: C = alpha * A
    # TruncationReport accumulating pruned-pair bounds; excluded from
    # eq/hash (it is an accumulator identity, not part of the task's value)
    trunc: Any = dataclasses.field(default=None, compare=False)


class EngineRebindError(RuntimeError, ValueError):
    """A stateful engine instance was bound to a second CTGraph.

    Deferred waves and flop/bytes stats are per-graph state: silently
    rebinding would flush foreign work as a side effect and conflate the
    reports.  Subclasses ValueError for backwards compatibility with code
    that caught the original exception type.
    """


class LeafEngine:
    """Backend interface consumed by :class:`~repro.core.tasks.CTGraph`."""

    name = "abstract"
    #: observability hook; stateful backends resolve the bound graph's
    #: tracer instead (see PallasEngine.tracer)
    tracer = NOOP

    def execute(self, g, node, payload: LeafPayload) -> Optional[MatrixChunk]:
        """Execute (or defer) one leaf task; returns its chunk or None=NIL."""
        raise NotImplementedError

    def reexecute(self, g, node, payload: LeafPayload) -> None:
        """Recompute an already-executed leaf task's numbers *in place*.

        Compiled-Plan replay (api/plan.py): the task's output chunk
        already exists with its final block structure; only the numbers
        are refreshed from the (rebound) operand chunks.  Must register
        no tasks and allocate no chunks.  Truncated multiplies replay the
        block-pair list frozen on ``node.replay`` at first execution so
        the program — not the norms of the new values — decides the
        structure.
        """
        raise NotImplementedError

    def flush(self, g) -> None:
        """Run all deferred work; afterwards every chunk holds real numbers."""

    def free_chunks(self, g, nids) -> None:
        """Drop engine-side state tied to these chunks (Session.free).

        Stateless backends keep nothing per chunk; the mesh executor
        overrides this to release device-resident block buffers and
        ownership/residency bookkeeping for the freed leaves.
        """

    def has_pending_for(self, leaf_ids) -> bool:
        """Whether any deferred task reads or writes one of these leaves.

        ``leaf_ids`` is a set of ``id(LeafMatrix)`` values.  Immediate
        backends keep nothing deferred; the batched backends override
        this so callers that overwrite leaf values in place (the plan
        rebind hooks) can flush *only when their target is actually
        entangled with pending work* — leaving unrelated deferred waves
        intact for cross-plan coalescing (DESIGN.md §9).
        """
        return False

    def stats(self) -> dict:
        return {}


def make_engine(spec: Any) -> LeafEngine:
    """Resolve an engine spec: None/'numpy', 'pallas', or an instance."""
    if spec is None or spec == "numpy":
        return NumpyEngine()
    if spec == "pallas":
        return PallasEngine()
    if spec == "mesh":
        # lazy import: the mesh executor pulls in jax device state, which
        # must stay out of processes that only simulate
        from repro.launch.mesh_exec import MeshEngine
        return MeshEngine()
    if isinstance(spec, LeafEngine):
        return spec
    raise ValueError(f"unknown leaf engine spec: {spec!r}")


# ---------------------------------------------------------------------------
# Structure enumeration shared by both backends' bookkeeping
# ---------------------------------------------------------------------------

def _plain_items(leaf: LeafMatrix, trans: bool):
    """(row, col, stored_key, transpose_flag) of op(A), op in {id, T}."""
    for (i, j) in leaf.blocks:
        if trans:
            yield j, i, (i, j), True
        else:
            yield i, j, (i, j), False


def _full_items(leaf: LeafMatrix):
    """Full symmetric structure view of an upper-storage leaf."""
    for (i, j) in leaf.blocks:
        yield i, j, (i, j), False
        if i != j:
            yield j, i, (i, j), True


def leaf_task_pairs(payload: LeafPayload, a_leaf: LeafMatrix,
                    b_leaf: Optional[LeafMatrix]):
    """All surviving block GEMMs of one leaf task.

    Returns ``(pairs, upper_out)`` where each pair is
    ``(src_a, key_a, trans_a, src_b, key_b, trans_b, out_key)`` with src in
    {'a', 'b'} naming which operand leaf the stored block comes from.  The
    pair count equals the numpy backend's LeafStats.block_multiplies.
    """
    k = payload.kind
    if k == "multiply":
        assert not a_leaf.upper and not b_leaf.upper  # host-library contract
        first = ("a", _plain_items(a_leaf, payload.ta))
        second = ("b", _plain_items(b_leaf, payload.tb))
        upper = False
    elif k == "sym_square":
        assert a_leaf.upper
        first = ("a", _full_items(a_leaf))
        second = ("a", _full_items(a_leaf))
        upper = True
    elif k == "syrk":
        assert not a_leaf.upper
        if payload.trans:   # C = A^T A
            first = ("a", _plain_items(a_leaf, True))
            second = ("a", _plain_items(a_leaf, False))
        else:               # C = A A^T
            first = ("a", _plain_items(a_leaf, False))
            second = ("a", _plain_items(a_leaf, True))
        upper = True
    elif k == "sym_multiply":
        assert a_leaf.upper and not b_leaf.upper
        if payload.side == "left":      # C = S B
            first = ("a", _full_items(a_leaf))
            second = ("b", _plain_items(b_leaf, False))
        else:                            # C = B S
            first = ("b", _plain_items(b_leaf, False))
            second = ("a", _full_items(a_leaf))
        upper = False
    else:
        raise ValueError(f"not a multiply-kind payload: {k}")

    cols: dict[int, list] = {}
    for i, kk, key, tr in first[1]:
        cols.setdefault(kk, []).append((i, first[0], key, tr))
    rows: dict[int, list] = {}
    for kk, j, key, tr in second[1]:
        rows.setdefault(kk, []).append((j, second[0], key, tr))

    pairs = []
    for kk in cols.keys() & rows.keys():
        for i, sa, ka, tra in cols[kk]:
            for j, sb, kb, trb in rows[kk]:
                if upper and i > j:
                    continue        # lower triangle skipped: symmetry saving
                pairs.append((sa, ka, tra, sb, kb, trb, (i, j)))

    if payload.tau > 0.0 and k == "multiply":
        # SpAMM pruning inside the leaf (DESIGN.md §5): a block pair whose
        # norm product is below tau is dropped *structurally* — both
        # backends take their structure from this list, so pruned pairs
        # never enter a Pallas wave and never touch the host library.
        # Block norms are transpose-invariant: the stored key's cached
        # norm is valid for either orientation.
        srcs = {"a": a_leaf, "b": b_leaf}
        flops_each = 2.0 * a_leaf.bs ** 3
        kept = []
        for pr in pairs:
            sa, ka, _, sb, kb, _, _ = pr[:7]
            bound = math.sqrt(srcs[sa].block_norm2(ka)
                              * srcs[sb].block_norm2(kb))
            if bound < payload.tau:
                if payload.trunc is not None:
                    payload.trunc.record_leaf_pair(bound, flops_each)
            else:
                kept.append(pr)
        pairs = kept
    return pairs, upper


# ---------------------------------------------------------------------------
# Reference backend
# ---------------------------------------------------------------------------

def execute_pairs_host(a_leaf: LeafMatrix, b_leaf: Optional[LeafMatrix],
                       pairs: list, upper: bool,
                       stats: Optional[LeafStats] = None) -> LeafMatrix:
    """Evaluate a leaf task from its (possibly pruned) block-pair list.

    This is the host-side twin of the Pallas wave: the structure comes
    from :func:`leaf_task_pairs`, so a truncated multiply produces the
    same block occupancy on both backends by construction.
    """
    dtype = a_leaf.dtype if b_leaf is None \
        else np.result_type(a_leaf.dtype, b_leaf.dtype)
    out = LeafMatrix(a_leaf.n, a_leaf.bs, upper=upper, dtype=dtype)
    srcs = {"a": a_leaf, "b": b_leaf}
    for sa, ka, tra, sb, kb, trb, out_key in pairs:
        ab = srcs[sa].blocks[ka]
        bb = srcs[sb].blocks[kb]
        prod = (ab.T if tra else ab) @ (bb.T if trb else bb)
        cur = out.blocks.get(out_key)
        if cur is None:
            out.blocks[out_key] = prod
        else:
            cur += prod
    if stats is not None:
        stats.block_multiplies += len(pairs)
        stats.flops += 2.0 * len(pairs) * a_leaf.bs ** 3
        stats.batches += 1 if pairs else 0
    return out


class NumpyEngine(LeafEngine):
    """Immediate per-task execution with the host leaf library (§4.1)."""

    name = "numpy"

    def _compute(self, g, node, payload: LeafPayload,
                 av: MatrixChunk, bv: Optional[MatrixChunk], st: LeafStats
                 ) -> tuple[LeafMatrix, bool]:
        """The numeric work of one leaf task; shared by execute/reexecute."""
        k = payload.kind
        if k == "multiply" and payload.tau > 0.0:
            # truncated path: structure (incl. SpAMM pair pruning) comes
            # from leaf_task_pairs — identical to the pallas backend's —
            # and the surviving pairs are evaluated with the host library.
            # The pair list is frozen on the node so a Plan replay re-runs
            # the same program instead of re-pruning against new norms.
            if node.replay is None:
                node.replay = leaf_task_pairs(payload, av.leaf, bv.leaf)
            pairs, upper = node.replay
            res = execute_pairs_host(av.leaf, bv.leaf, pairs, upper, st)
        elif k == "multiply":
            res = leaf_multiply(av.leaf, bv.leaf, ta=payload.ta,
                                tb=payload.tb, stats=st)
            upper = False
        elif k == "sym_square":
            res = leaf_sym_square(av.leaf, stats=st)
            upper = True
        elif k == "syrk":
            res = leaf_syrk(av.leaf, trans=payload.trans, stats=st)
            upper = True
        elif k == "sym_multiply":
            res = leaf_sym_multiply(av.leaf, bv.leaf, side=payload.side,
                                    stats=st)
            upper = False
        elif k == "add":
            res = leaf_add(av.leaf, bv.leaf)
            upper = av.upper
        elif k == "transpose":
            res = av.leaf.transpose()
            upper = False
        elif k == "scale":
            res = leaf_scale(av.leaf, payload.alpha)
            upper = av.upper
        elif k == "inv_chol":
            res = leaf_inv_chol(av.leaf, stats=st)
            upper = False
        elif k == "tri_solve":
            res = leaf_tri_solve(av.leaf, bv.leaf, stats=st)
            upper = False
        else:
            raise ValueError(f"unknown leaf payload kind: {k}")
        return res, upper

    def execute(self, g, node, payload: LeafPayload) -> Optional[MatrixChunk]:
        av: MatrixChunk = g.value_of(payload.a)
        bv: Optional[MatrixChunk] = (
            g.value_of(payload.b) if payload.b is not None else None)
        st = LeafStats()
        res, upper = self._compute(g, node, payload, av, bv, st)
        node.flops = st.flops
        # multiply kinds prune structurally-empty results to NIL; adds of
        # two non-NIL leaves always produce a chunk (Alg 2 semantics) —
        # matching the pallas backend's structural behavior exactly.
        # Solve kinds always produce a chunk: their structure is the
        # deterministic inv_chol_keys/tri_solve_keys set, never empty.
        if payload.kind not in HOST_KINDS \
                and payload.kind not in SOLVE_KINDS and res.is_zero():
            return None
        return MatrixChunk(av.n, leaf=res, upper=upper)

    def reexecute(self, g, node, payload: LeafPayload) -> None:
        av: MatrixChunk = g.value_of(payload.a)
        bv: Optional[MatrixChunk] = (
            g.value_of(payload.b) if payload.b is not None else None)
        res, _ = self._compute(g, node, payload, av, bv, LeafStats())
        out: MatrixChunk = g.value_of(node.nid)
        dst = out.leaf
        if set(res.blocks) != set(dst.blocks):   # pragma: no cover - guard
            raise RuntimeError(
                "replay structure drift: leaf block occupancy changed "
                "between plan compilation and replay")
        for key, blk in res.blocks.items():
            dst.blocks[key][...] = blk
        dst.invalidate_norms()
        out.norm2 = None
        out.trace = None


# ---------------------------------------------------------------------------
# Batched accelerator backend
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Pending:
    nid: int
    payload: LeafPayload
    out: LeafMatrix
    a_leaf: LeafMatrix
    b_leaf: Optional[LeafMatrix]
    pairs: Optional[list] = None    # multiply kinds only


class PallasEngine(LeafEngine):
    """Deferred, cross-leaf-batched execution through the Pallas kernels.

    Precision contract: operands are packed float32 and the kernels
    accumulate in float32 (jax runs without x64 here), so engine-produced
    leaves are float32 regardless of input dtype — expect ~1e-7 relative
    agreement with the float64 numpy backend, not bitwise equality.

    kernel   : 'pairs' -> one fused kernels.bsmm_pairs gather-GEMM-scatter
               call per wave; 'gemm' -> one kernels.batched_gemm call per
               wave + host scatter-add (the cuBLAS-batched-gemm shape).
    interpret: None -> auto (Pallas interpret mode off-TPU, compiled on TPU;
               resolved by kernels.ops).
    block_t  : batch tile of the batched_gemm kernel, which zero-pads the
               wave to a multiple of it internally.
    validate_structure : cross-check the pure-Python output structure of
               every leaf task against bsmm.compute_c_structure (the boolean
               matmul the TPU path uses).  Costs one eager JAX call per leaf
               task; meant for tests.
    """

    name = "pallas"

    @property
    def tracer(self):
        """The bound graph's tracer (NOOP until bound / when tracing off)."""
        g = self._graph
        return getattr(g, "tracer", NOOP) if g is not None else NOOP

    def __init__(self, kernel: str = "pairs",
                 interpret: Optional[bool] = None, block_t: int = 8,
                 validate_structure: bool = False):
        assert kernel in ("pairs", "gemm")
        self.kernel = kernel
        self.interpret = interpret
        self.block_t = block_t
        self.validate_structure = validate_structure
        self._pending: list[_Pending] = []
        self._unfilled: set[int] = set()     # id() of placeholder out leaves
        self._waves: list[dict] = []
        self._graph = None                   # bound CTGraph (one per engine)

    # -- registration-time: structure only ----------------------------------
    def _bind(self, g) -> None:
        """One engine instance serves one graph: pending waves and stats are
        per-graph state, so sharing would flush foreign work as a side
        effect and conflate the flop/bytes report."""
        if g is None:
            return
        if self._graph is None:
            self._graph = g
        elif g is not self._graph:
            raise EngineRebindError(
                "this PallasEngine instance is already bound to another "
                "CTGraph; create one engine per graph")

    def execute(self, g, node, payload: LeafPayload) -> Optional[MatrixChunk]:
        self._bind(g)
        av: MatrixChunk = g.value_of(payload.a)
        bv: Optional[MatrixChunk] = (
            g.value_of(payload.b) if payload.b is not None else None)
        a_leaf = av.leaf
        b_leaf = bv.leaf if bv is not None else None

        if payload.kind == "add":
            # adds run host-side (no kernel), so input precision is kept:
            # float64 for original data, float32 when fed by kernel results
            out = alloc_structure(
                a_leaf.n, a_leaf.bs,
                list(dict.fromkeys(list(a_leaf.blocks) + list(b_leaf.blocks))),
                upper=a_leaf.upper,
                dtype=np.result_type(a_leaf.dtype, b_leaf.dtype))
            self._defer(_Pending(node.nid, payload, out, a_leaf, b_leaf))
            return MatrixChunk(av.n, leaf=out, upper=av.upper)

        if payload.kind == "transpose":
            # host-side like add; deferred so it orders after the wave that
            # fills its input (structure is final at registration)
            out = alloc_structure(a_leaf.n, a_leaf.bs,
                                  [(j, i) for (i, j) in a_leaf.blocks],
                                  upper=False, dtype=a_leaf.dtype)
            self._defer(_Pending(node.nid, payload, out, a_leaf, None))
            return MatrixChunk(av.n, leaf=out)

        if payload.kind == "scale":
            # host-side like add/transpose: same structure, scaled numbers
            out = alloc_structure(a_leaf.n, a_leaf.bs, list(a_leaf.blocks),
                                  upper=a_leaf.upper, dtype=a_leaf.dtype)
            self._defer(_Pending(node.nid, payload, out, a_leaf, None))
            return MatrixChunk(av.n, leaf=out, upper=av.upper)

        if payload.kind in SOLVE_KINDS:
            # structure is a function of the operand structure alone
            # (deterministic keys, zero blocks kept — see core/leaf.py),
            # so deferral is safe exactly like the multiply kinds; the
            # numeric fill joins a batched triangular wave at flush
            if payload.kind == "inv_chol":
                keys = inv_chol_keys(a_leaf.grid)
            else:
                keys = tri_solve_keys(b_leaf.blocks, a_leaf.grid)
            node.flops = float(a_leaf.n) ** 3
            out = alloc_structure(a_leaf.n, a_leaf.bs, keys, upper=False,
                                  dtype=self._out_dtype(a_leaf, b_leaf))
            self._defer(_Pending(node.nid, payload, out, a_leaf, b_leaf))
            return MatrixChunk(av.n, leaf=out, upper=False)

        pairs, upper = leaf_task_pairs(payload, a_leaf, b_leaf)
        if payload.tau > 0.0:
            # freeze the surviving pairs for Plan replay (see qt_replay):
            # the norm test must not re-evaluate against rebound values
            node.replay = (pairs, upper)
        node.flops = 2.0 * len(pairs) * a_leaf.bs ** 3
        # output occupancy in row-major slot order (the same order
        # bsmm.compute_c_structure assigns; see validate_structure)
        keys = sorted({p[6] for p in pairs})
        if self.validate_structure:
            oracle = self._c_keys(payload, a_leaf, b_leaf, upper)
            if payload.tau > 0.0:
                # the jnp oracle evaluates the tau test in float32; allow
                # it to disagree only on pairs within f32 rounding of the
                # boundary by bracketing with slightly shifted taus
                def keys_at(t):
                    probe = dataclasses.replace(payload, tau=t, trunc=None)
                    prs, _ = leaf_task_pairs(probe, a_leaf, b_leaf)
                    return {p[6] for p in prs}
                strict = keys_at(payload.tau * (1 + 1e-5))
                loose = keys_at(payload.tau * (1 - 1e-5))
                assert strict <= set(oracle) <= loose
            else:
                assert keys == oracle
        if not keys:
            return None
        out = alloc_structure(a_leaf.n, a_leaf.bs, keys, upper=upper,
                              dtype=self._out_dtype(a_leaf, b_leaf))
        self._defer(_Pending(node.nid, payload, out, a_leaf, b_leaf, pairs))
        return MatrixChunk(av.n, leaf=out, upper=upper)

    @staticmethod
    def _out_dtype(a_leaf, b_leaf):
        # kernels compute in float32 (f32 accumulation on the MXU, and jax
        # runs without x64 here): engine-produced leaves are float32 so the
        # stored dtype and bytes accounting are truthful about precision
        _ = a_leaf, b_leaf
        return np.float32

    def _defer(self, entry: _Pending) -> None:
        self._pending.append(entry)
        self._unfilled.add(id(entry.out))

    def _c_keys(self, payload, a_leaf, b_leaf, upper) -> list:
        """Output occupancy via the one-shot boolean matmul of bsmm.

        The operand masks are the op-applied structure views; the C keys come
        back in compute_c_structure's row-major slot order, which fixes the
        packed output slot numbering of the flush wave.  A truncated
        multiply (``payload.tau > 0``) cross-checks against the
        norm-weighted structure (:func:`~repro.core.bsmm
        .compute_c_structure_norms`) instead: a C block survives only if
        some inner pair's norm product clears tau.
        """
        from .bsmm import compute_c_structure, compute_c_structure_norms
        import jax.numpy as jnp

        grid = a_leaf.grid
        if payload.kind == "multiply" and payload.tau > 0.0:
            na = np.zeros((grid, grid))
            nb = np.zeros((grid, grid))
            for i, k, key, _ in _plain_items(a_leaf, payload.ta):
                na[i, k] = math.sqrt(a_leaf.block_norm2(key))
            for k, j, key, _ in _plain_items(b_leaf, payload.tb):
                nb[k, j] = math.sqrt(b_leaf.block_norm2(key))
            crows, ccols, _, cnt = compute_c_structure_norms(
                jnp.asarray(na), jnp.asarray(nb), payload.tau,
                cap_c=grid * grid)
            cnt = int(cnt)
            return [(int(r), int(c)) for r, c
                    in zip(np.asarray(crows)[:cnt], np.asarray(ccols)[:cnt])]

        ma = np.zeros((grid, grid), bool)
        mb = np.zeros((grid, grid), bool)
        kfirst = payload.kind
        if kfirst == "multiply":
            for i, k, _, _ in _plain_items(a_leaf, payload.ta):
                ma[i, k] = True
            for k, j, _, _ in _plain_items(b_leaf, payload.tb):
                mb[k, j] = True
        elif kfirst == "sym_square":
            for i, k, _, _ in _full_items(a_leaf):
                ma[i, k] = True
            mb = ma
        elif kfirst == "syrk":
            for i, k, _, _ in _plain_items(a_leaf, payload.trans):
                ma[i, k] = True
            mb = ma.T
        elif kfirst == "sym_multiply":
            if payload.side == "left":
                for i, k, _, _ in _full_items(a_leaf):
                    ma[i, k] = True
                for k, j, _, _ in _plain_items(b_leaf, False):
                    mb[k, j] = True
            else:
                for i, k, _, _ in _plain_items(b_leaf, False):
                    ma[i, k] = True
                for k, j, _, _ in _full_items(a_leaf):
                    mb[k, j] = True
        crows, ccols, _, cnt = compute_c_structure(
            jnp.asarray(ma), jnp.asarray(mb), cap_c=grid * grid)
        cnt = int(cnt)
        keys = [(int(r), int(c)) for r, c
                in zip(np.asarray(crows)[:cnt], np.asarray(ccols)[:cnt])]
        if upper:
            keys = [k for k in keys if k[0] <= k[1]]
        return keys

    # -- flush: batched waves ------------------------------------------------
    def _ready(self, t: _Pending) -> bool:
        if id(t.a_leaf) in self._unfilled:
            return False
        return t.b_leaf is None or id(t.b_leaf) not in self._unfilled

    def batch_key(self, t: _Pending) -> tuple:
        """Wave-compatibility key of a deferred kernel task.

        Tasks agreeing on ``(kernel, leaf_n, bs, dtype)`` may share one
        fused dispatch — within this engine's waves and, through the
        serving layer's cross-plan coalescer (:mod:`repro.serve`),
        across engines of different sessions.
        """
        return (self.kernel, t.out.n, t.out.bs,
                np.dtype(t.out.dtype).name)

    def has_pending_for(self, leaf_ids) -> bool:
        for t in self._pending:
            if id(t.out) in leaf_ids or id(t.a_leaf) in leaf_ids or \
                    (t.b_leaf is not None and id(t.b_leaf) in leaf_ids):
                return True
        return False

    def ready_wave(self) -> dict:
        """Ready deferred kernel tasks, grouped by :meth:`batch_key`.

        Read-only: nothing is executed or committed.  The cross-plan
        coalescer merges groups with equal keys across engines before
        dispatching; :meth:`flush` consumes the same grouping locally.
        """
        groups: dict[tuple, list[_Pending]] = {}
        for t in self._pending:
            if t.payload.kind not in HOST_KINDS \
                    and t.payload.kind not in SOLVE_KINDS \
                    and self._ready(t):
                groups.setdefault(self.batch_key(t), []).append(t)
        return groups

    def solve_wave(self) -> dict:
        """Ready deferred triangular-solve tasks, grouped for batching.

        Solve kinds never join GEMM waves: they dispatch through
        kernels/tri.py one batched call per ``(kind, leaf_n, bs)`` group.
        """
        groups: dict[tuple, list[_Pending]] = {}
        for t in self._pending:
            if t.payload.kind in SOLVE_KINDS and self._ready(t):
                key = (t.payload.kind, t.out.n, t.out.bs)
                groups.setdefault(key, []).append(t)
        return groups

    def run_solve_ready(self) -> bool:
        """Dispatch every ready batched triangular wave; True if any ran."""
        progressed = False
        for key, tasks in sorted(self.solve_wave().items()):
            kind, n, bs = key
            tr = self.tracer
            if tr.enabled:
                with tr.span("engine.wave", track="engine") as sp:
                    self._waves.append(dispatch_solve_wave(
                        tasks, kind=kind, n=n, bs=bs))
                    sp.set(**self._wave_span_attrs())
            else:
                self._waves.append(dispatch_solve_wave(
                    tasks, kind=kind, n=n, bs=bs))
            self._waves[-1].setdefault("batch_key", list(key))
            self.commit_tasks(tasks)
            progressed = True
        return progressed

    def run_host_ready(self) -> bool:
        """Execute every ready host-side fill (add/transpose/scale).

        Returns True if anything ran — the progress signal both
        :meth:`flush` and the coalescer's drain loop use.
        """
        progressed = False
        rest = []
        for t in self._pending:
            if t.payload.kind in HOST_KINDS and self._ready(t):
                if t.payload.kind == "add":
                    self._run_add(t)
                elif t.payload.kind == "scale":
                    self._run_scale(t)
                else:
                    self._run_transpose(t)
                self._unfilled.discard(id(t.out))
                progressed = True
            else:
                rest.append(t)
        self._pending = rest
        return progressed

    def commit_tasks(self, tasks: list, wave_record: Optional[dict] = None
                     ) -> None:
        """Retire externally executed tasks (cross-engine coalescer).

        The coalescer packs this engine's share of a merged wave into one
        dispatch it runs itself, then commits the share here so the next
        flush does not re-run it.  ``wave_record`` (this engine's slice
        of the merged wave's accounting) lands in the wave log.
        """
        done = {id(t) for t in tasks}
        for t in tasks:
            self._unfilled.discard(id(t.out))
        self._pending = [t for t in self._pending if id(t) not in done]
        if wave_record is not None:
            self._waves.append(wave_record)

    def flush(self, g=None) -> None:
        # tasks leave self._pending only after their wave succeeded, so a
        # kernel failure leaves the deferred work intact and a later flush
        # retries it (block fills are idempotent in-place assignments)
        self._bind(g)
        while self._pending:
            groups = self.ready_wave()
            if groups:
                self._run_wave(groups)   # commits per group (see below)
            progressed = bool(groups)
            progressed |= self.run_host_ready()
            progressed |= self.run_solve_ready()
            if self._pending and not progressed:
                raise RuntimeError(
                    "leaf engine deadlock: unresolvable leaf dependencies")

    @staticmethod
    def _run_add(t: _Pending) -> None:
        for key, blk in t.out.blocks.items():
            a = t.a_leaf.blocks.get(key)
            b = t.b_leaf.blocks.get(key)
            if a is None:
                blk[...] = b
            elif b is None:
                blk[...] = a
            else:
                np.add(a, b, out=blk, casting="unsafe")
        t.out.invalidate_norms()

    @staticmethod
    def _run_transpose(t: _Pending) -> None:
        for (i, j), blk in t.a_leaf.blocks.items():
            t.out.blocks[(j, i)][...] = blk.T
        t.out.invalidate_norms()

    @staticmethod
    def _run_scale(t: _Pending) -> None:
        for key, blk in t.a_leaf.blocks.items():
            np.multiply(blk, t.payload.alpha, out=t.out.blocks[key],
                        casting="unsafe")
        t.out.invalidate_norms()

    def reexecute(self, g, node, payload: LeafPayload) -> None:
        """Re-defer an already-executed leaf task against its existing
        output chunk; the next flush re-runs the batched waves/host fills
        in dependency order, writing the same placeholder blocks."""
        self._bind(g)
        av: MatrixChunk = g.value_of(payload.a)
        bv: Optional[MatrixChunk] = (
            g.value_of(payload.b) if payload.b is not None else None)
        a_leaf = av.leaf
        b_leaf = bv.leaf if bv is not None else None
        out: MatrixChunk = g.value_of(node.nid)
        if payload.kind in HOST_KINDS or payload.kind in SOLVE_KINDS:
            # host fills and solve waves assign (not scatter-add) every
            # output block, so re-deferring without zeroing is exact
            self._defer(_Pending(node.nid, payload, out.leaf, a_leaf,
                                 b_leaf))
        else:
            if payload.tau > 0.0:
                pairs, _ = node.replay      # frozen at first execution
            else:
                probe = dataclasses.replace(payload, trunc=None)
                pairs, _ = leaf_task_pairs(probe, a_leaf, b_leaf)
            # zero first: waves only scatter-add into surviving out slots
            for blk in out.leaf.blocks.values():
                blk[...] = 0.0
            self._defer(_Pending(node.nid, payload, out.leaf, a_leaf,
                                 b_leaf, pairs))
        out.norm2 = None
        out.trace = None

    def _wave_span_attrs(self) -> dict:
        """Attributes of the just-committed wave for its engine.wave span."""
        w = self._waves[-1]
        return {k: w[k] for k in ("kernel", "bs", "tasks", "pairs",
                                  "padded_pairs", "c_blocks", "bytes_packed")
                if k in w}

    def _run_wave(self, groups: dict[tuple, list[_Pending]]) -> None:
        tr = self.tracer
        for key, tasks in sorted(groups.items()):
            if tr.enabled:
                with tr.span("engine.wave", track="engine") as sp:
                    self._run_group(key[2], tasks)
                    sp.set(**self._wave_span_attrs())
            else:
                self._run_group(key[2], tasks)
            self._waves[-1].setdefault("batch_key", list(key))
            # commit this group immediately: a failure in a *later* group
            # must not leave these tasks pending, or a retrying flush would
            # re-run them and double-count their wave record in stats()
            self.commit_tasks(tasks)

    def _run_group(self, bs: int, tasks: list[_Pending]) -> None:
        """Pack every block pair of every leaf task into one kernel call."""
        self._waves.append(dispatch_packed_wave(
            tasks, bs, kernel=self.kernel, block_t=self.block_t,
            interpret=self.interpret, tracer=self.tracer))

    # -- reporting -----------------------------------------------------------
    def stats(self) -> dict:
        return {
            "backend": self.name,
            "kernel": self.kernel,
            "waves": len(self._waves),
            "batched_pairs": sum(w["pairs"] for w in self._waves),
            "padded_pairs": sum(w["padded_pairs"] for w in self._waves),
            "c_blocks": sum(w["c_blocks"] for w in self._waves),
            "kernel_wall_s": sum(w["wall_s"] for w in self._waves),
            "bytes_packed": sum(w["bytes_packed"] for w in self._waves),
            "wave_log": list(self._waves),
        }


def dispatch_solve_wave(tasks: list[_Pending], *, kind: str, n: int,
                        bs: int) -> dict:
    """One batched triangular-kernel call for every ready solve leaf.

    Leaves are densified host-side (symmetric upper storage expands to
    full), stacked ``(P, n, n)`` in float32, run through
    :mod:`repro.kernels.tri`, and scattered back into each task's
    pre-allocated deterministic block structure.  Returns a wave record
    with the same accounting fields as the GEMM waves (``pairs`` counts
    leaves here — one "pair" of dense operands per task).
    """
    import jax.numpy as jnp
    from repro.kernels import tri as ktri

    a_pack = np.stack([t.a_leaf.to_dense() for t in tasks]).astype(np.float32)
    t0 = time.perf_counter()
    if kind == "inv_chol":
        res = np.asarray(ktri.batched_inv_chol(jnp.asarray(a_pack)))
        b_bytes = 0
    else:
        b_pack = np.stack([t.b_leaf.to_dense()
                           for t in tasks]).astype(np.float32)
        res = np.asarray(ktri.batched_tri_solve(
            jnp.asarray(a_pack), jnp.asarray(b_pack)))
        b_bytes = b_pack.nbytes
    wall = time.perf_counter() - t0

    c_blocks = 0
    for t, x in zip(tasks, res):
        keys = list(t.out.blocks)
        data = np.stack([np.ascontiguousarray(
            x[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs]) for i, j in keys])
        unpack_blocks(t.out, keys, data)
        c_blocks += len(keys)
    return {
        "kernel": kind, "bs": bs, "tasks": len(tasks),
        "pairs": len(tasks), "padded_pairs": len(tasks),
        "c_blocks": int(c_blocks), "wall_s": wall,
        "bytes_packed": int(a_pack.nbytes + b_bytes
                            + res.astype(np.float32).nbytes),
    }


def dispatch_packed_wave(tasks: list[_Pending], bs: int, *, kernel: str,
                         block_t: int, interpret: bool,
                         tracer=NOOP) -> dict:
    """Pack every block pair of every leaf task into one kernel call.

    Module-level so the cross-plan coalescer (:mod:`repro.serve.coalesce`)
    can merge same-``batch_key`` tasks *from several engines* into one
    dispatch.  Fills each task's output leaf in place and returns the wave
    record (the caller appends it to the owning engine's wave log).

    Numerical identity with per-engine dispatch: output slots are numbered
    task-by-task in structure order and pairs are sorted by a *stable*
    argsort on segment id, so every output block accumulates its products
    in the same order regardless of which other tasks share the wave.
    """
    import jax.numpy as jnp
    from repro.kernels import ops as kops

    # global output slot numbering: task-by-task, structure order
    slot_base: list[int] = []
    n_slots = 0
    for t in tasks:
        slot_base.append(n_slots)
        n_slots += len(t.out.blocks)

    # operands are packed *uniquely* — one slot per distinct
    # (leaf, key, transpose) block — and pairs address them through
    # sa/sb indices, which is exactly the slot-indexed gather the
    # bsmm_pairs scalar-prefetch kernel is built around
    n_pairs = sum(len(t.pairs) for t in tasks)
    a_slots: dict[tuple, int] = {}
    b_slots: dict[tuple, int] = {}
    a_list: list[np.ndarray] = []
    b_list: list[np.ndarray] = []

    def slot_of(slots, lst, leaf, key, tr):
        sk = (id(leaf), key, tr)
        s = slots.get(sk)
        if s is None:
            s = len(lst)
            slots[sk] = s
            blk = leaf.blocks[key]
            lst.append(blk.T if tr else blk)
        return s

    sa = np.empty((n_pairs,), np.int32)
    sb = np.empty((n_pairs,), np.int32)
    seg = np.empty((n_pairs,), np.int32)
    p = 0
    for base, t in zip(slot_base, tasks):
        key_slot = {key: base + i for i, key in enumerate(t.out.blocks)}
        srcs = {"a": t.a_leaf, "b": t.b_leaf}
        for src_a, ka, tra, src_b, kb, trb, out_key in t.pairs:
            sa[p] = slot_of(a_slots, a_list, srcs[src_a], ka, tra)
            sb[p] = slot_of(b_slots, b_list, srcs[src_b], kb, trb)
            seg[p] = key_slot[out_key]
            p += 1
    a_pack = np.stack(a_list).astype(np.float32)
    b_pack = np.stack(b_list).astype(np.float32)

    # ascending segment ids (bsmm_pairs accumulation contract)
    order = np.argsort(seg, kind="stable")
    sa, sb, seg = sa[order], sb[order], seg[order]

    t0 = time.perf_counter()
    with tracer.span("kernel.dispatch", track="engine",
                     kernel=kernel, bs=bs,
                     pairs=int(n_pairs), c_blocks=int(n_slots)):
        if kernel == "pairs":
            c = kops.bsmm_pairs(
                jnp.asarray(a_pack), jnp.asarray(b_pack),
                jnp.asarray(sa), jnp.asarray(sb),
                jnp.asarray(seg), cap_c=n_slots, use_pallas=True,
                interpret=interpret)
            c = np.asarray(c)
            padded = n_pairs
        else:
            # host gather feeds the cuBLAS-shaped batch; batched_gemm
            # zero-pads to a block_t multiple internally
            prods = np.asarray(kops.batched_gemm(
                jnp.asarray(a_pack[sa]), jnp.asarray(b_pack[sb]),
                block_t=block_t, use_pallas=True,
                interpret=interpret))
            c = np.zeros((n_slots, bs, bs), np.float32)
            np.add.at(c, seg, prods)
            padded = n_pairs + (-n_pairs) % block_t
    wall = time.perf_counter() - t0

    record = {
        "kernel": kernel, "bs": bs, "tasks": len(tasks),
        "pairs": int(n_pairs), "padded_pairs": int(padded),
        "unique_blocks": len(a_list) + len(b_list),
        "c_blocks": int(n_slots), "wall_s": wall,
        "bytes_packed": int(a_pack.nbytes + b_pack.nbytes + c.nbytes),
    }
    for base, t in zip(slot_base, tasks):
        unpack_blocks(t.out, list(t.out.blocks),
                      c[base:base + len(t.out.blocks)])
    return record
