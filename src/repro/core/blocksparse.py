"""TPU-native static-shape block-sparse matrix format (DESIGN.md §3).

This is the XLA/TPU rendering of the paper's quadtree matrix chunk (§3.1):

* a **packed block array** holds only nonzero ``bs x bs`` blocks, with a
  static *capacity* ``cap`` (XLA needs static shapes; capacity-bounded
  dynamic sparsity via ``jnp.nonzero(size=cap)`` keeps the paper's
  "no a-priori knowledge, no symbolic step" property — occupancy is detected
  from the data at runtime, inside jit);
* a **slot map** ``slot[i, k] -> packed index`` replaces the chunk-identifier
  indirection of the Chunks and Tasks runtime;
* the **mask pyramid** (:func:`mask_pyramid`) is the quadtree itself: boolean
  occupancy at every level, level 0 = root.  NIL chunk identifiers at any
  level (paper §3.1) == False entries at any pyramid level.

Everything in this module is jit-compatible; shapes depend only on
``(n, bs, cap)`` which are trace-time constants.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BlockSparse:
    """Packed block-sparse matrix with static capacity.

    blocks : (cap, bs, bs)  packed nonzero blocks (padding slots are zero)
    rows   : (cap,) int32   block-row of each slot; ``grid`` marks padding
    cols   : (cap,) int32   block-col of each slot; ``grid`` marks padding
    nnzb   : () int32       number of valid slots
    slot   : (grid+1, grid+1) int32  packed index of block (i,k); -1 = empty.
             The extra row/col absorbs padding coordinates.
    """
    blocks: jax.Array
    rows: jax.Array
    cols: jax.Array
    nnzb: jax.Array
    slot: jax.Array

    # -- pytree plumbing (grid/bs/cap derivable from array shapes) ----------
    def tree_flatten(self):
        return (self.blocks, self.rows, self.cols, self.nnzb, self.slot), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)

    # -- static properties ---------------------------------------------------
    @property
    def cap(self) -> int:
        return self.blocks.shape[0]

    @property
    def bs(self) -> int:
        return self.blocks.shape[1]

    @property
    def grid(self) -> int:
        return self.slot.shape[0] - 1

    @property
    def n(self) -> int:
        return self.grid * self.bs

    # -- views ---------------------------------------------------------------
    def mask(self) -> jax.Array:
        """(grid, grid) bool occupancy — quadtree leaf level."""
        return self.slot[:-1, :-1] >= 0

    def valid(self) -> jax.Array:
        """(cap,) bool — which packed slots hold real blocks."""
        return self.rows < self.grid


def from_dense(a: jax.Array, bs: int, cap: int) -> BlockSparse:
    """Detect occupancy and pack nonzero blocks (jit-compatible).

    Zero blocks are detected from the data — the XLA analogue of the
    library "dynamically detecting" sparsity (paper abstract).
    """
    n = a.shape[0]
    assert a.shape == (n, n) and n % bs == 0
    g = n // bs
    tiles = a.reshape(g, bs, g, bs).transpose(0, 2, 1, 3)
    occ = jnp.any(tiles != 0, axis=(2, 3))
    rows, cols = jnp.nonzero(occ, size=cap, fill_value=g)
    nnzb = jnp.sum(occ).astype(jnp.int32)
    rows = rows.astype(jnp.int32)
    cols = cols.astype(jnp.int32)
    valid = rows < g
    data = tiles[jnp.minimum(rows, g - 1), jnp.minimum(cols, g - 1)]
    data = jnp.where(valid[:, None, None], data, 0)
    slot = jnp.full((g + 1, g + 1), -1, dtype=jnp.int32)
    slot = slot.at[rows, cols].set(
        jnp.where(valid, jnp.arange(cap, dtype=jnp.int32), -1))
    # padding rows/cols == g all hit slot[g, g]; reset it unless genuinely set
    slot = slot.at[g, :].set(-1).at[:, g].set(-1)
    return BlockSparse(data, rows, cols, nnzb, slot)


def from_blocks(rows: np.ndarray, cols: np.ndarray, blocks: jax.Array,
                grid: int, cap: int) -> BlockSparse:
    """Pack an explicit (rows, cols, blocks) triplet list (host-side setup)."""
    k = len(rows)
    assert k <= cap, f"{k} blocks exceed capacity {cap}"
    bs = blocks.shape[-1]
    data = jnp.zeros((cap, bs, bs), blocks.dtype).at[:k].set(blocks)
    r = jnp.full((cap,), grid, jnp.int32).at[:k].set(
        jnp.asarray(rows, jnp.int32))
    c = jnp.full((cap,), grid, jnp.int32).at[:k].set(
        jnp.asarray(cols, jnp.int32))
    slot = jnp.full((grid + 1, grid + 1), -1, jnp.int32)
    slot = slot.at[r[:k], c[:k]].set(jnp.arange(k, dtype=jnp.int32))
    return BlockSparse(data, r, c, jnp.int32(k), slot)


def to_dense(m: BlockSparse) -> jax.Array:
    g, bs = m.grid, m.bs
    tiles = jnp.zeros((g + 1, g + 1, bs, bs), m.blocks.dtype)
    tiles = tiles.at[m.rows, m.cols].add(m.blocks)
    return tiles[:g, :g].transpose(0, 2, 1, 3).reshape(g * bs, g * bs)


def mask_pyramid(mask: jax.Array) -> list[jax.Array]:
    """Quadtree occupancy masks, finest (leaf) first, 1x1 root last.

    ``pyramid[0]`` is the (grid, grid) leaf mask; each coarser level ORs 2x2
    children — a NIL submatrix at level l == False at pyramid[L - l].
    """
    g = mask.shape[0]
    assert g & (g - 1) == 0, "grid must be a power of two"
    out = [mask]
    while g > 1:
        g //= 2
        mask = mask.reshape(g, 2, g, 2).any(axis=(1, 3))
        out.append(mask)
    return out


# ---------------------------------------------------------------------------
# Pair enumeration — Algorithm 1 rendered statically.
#
# The recursive task expansion of Algorithm 1 ("for m, n, k in {1,2}: register
# multiply(A_mk, B_kn)") becomes a level-by-level expansion of surviving
# (i, k, j) triples: each triple at grid G has 8 children at grid 2G, and a
# child survives iff A's and B's occupancy masks at that level are both
# nonzero — exactly the NIL check on line 2 of Algorithm 1.  The number of
# surviving triples per level is the paper's "number of multiplication tasks
# at level l" (eq. (1)/(8)), so enumeration work is proportional to the
# paper's task count, not to grid^3.
# ---------------------------------------------------------------------------

_CHILD_OFFSETS = np.array(
    [[di, dk, dj] for di in (0, 1) for dk in (0, 1) for dj in (0, 1)],
    dtype=np.int32)  # (8, 3)


def enumerate_pairs_hier(mask_a: jax.Array, mask_b: jax.Array,
                         caps: Sequence[int],
                         mask_c: Optional[jax.Array] = None
                         ) -> tuple[jax.Array, jax.Array]:
    """Hierarchically enumerate (i, k, j) with A[i,k] and B[k,j] nonzero.

    caps[l] bounds the number of surviving triples at level l+1 (level 0 is
    the 1x1 root, always 1 triple).  Returns (pairs, count): pairs is
    (caps[-1], 3) int32 with padding rows equal to ``grid`` (out of range),
    count the number of valid triples.

    ``mask_c``, when given, additionally requires the *output* cell (i, j)
    to be set at every level — used by the distributed engine to restrict
    enumeration to the C blocks a device owns (the quadtree analogue of
    "only compute your own submatrix products").

    Capacity overflow drops triples deterministically (the first ``cap`` in
    row-major order are kept) — callers size caps from the §5 bounds or via
    :func:`plan_caps`.
    """
    g = mask_a.shape[0]
    levels = int(np.log2(g))
    assert len(caps) == levels, f"need {levels} caps, got {len(caps)}"
    pyr_a = mask_pyramid(mask_a)   # [leaf ... root]
    pyr_b = mask_pyramid(mask_b)
    pyr_c = mask_pyramid(mask_c) if mask_c is not None else None

    pairs = jnp.zeros((1, 3), jnp.int32)   # the root triple (0, 0, 0)
    alive = pyr_a[-1][0, 0] & pyr_b[-1][0, 0]
    count = alive.astype(jnp.int32)
    offs = jnp.asarray(_CHILD_OFFSETS)

    for l in range(levels):
        ma = pyr_a[levels - 1 - l]    # mask at the children's level
        mb = pyr_b[levels - 1 - l]
        gl = ma.shape[0]
        cap_prev = pairs.shape[0]
        parent_valid = jnp.arange(cap_prev) < count
        children = pairs[:, None, :] * 2 + offs[None, :, :]
        flat = children.reshape(-1, 3)
        i, k, j = flat[:, 0], flat[:, 1], flat[:, 2]
        inb = (i < gl) & (k < gl) & (j < gl)
        ic, kc, jc = (jnp.minimum(i, gl - 1), jnp.minimum(k, gl - 1),
                      jnp.minimum(j, gl - 1))
        ok = (inb & ma[ic, kc] & mb[kc, jc]
              & jnp.repeat(parent_valid, 8))
        if pyr_c is not None:
            ok = ok & pyr_c[levels - 1 - l][ic, jc]
        idx = jnp.nonzero(ok, size=caps[l], fill_value=flat.shape[0])[0]
        count = jnp.sum(ok).astype(jnp.int32)
        padded = jnp.concatenate(
            [flat, jnp.full((1, 3), 2 * gl, jnp.int32)], axis=0)
        pairs = padded[jnp.minimum(idx, flat.shape[0])]
        # clamp padding coordinates into "out of range" marker gl
        pairs = jnp.where((jnp.arange(caps[l]) < count)[:, None], pairs, gl)
    return pairs, count


def enumerate_pairs_flat(mask_a: jax.Array, mask_b: jax.Array,
                         cap: int) -> tuple[jax.Array, jax.Array]:
    """O(grid^3) reference enumeration (the 'no locality exploitation'
    baseline — what a SUMMA-style static schedule effectively pays)."""
    g = mask_a.shape[0]
    m3 = mask_a[:, :, None] & mask_b[None, :, :]      # (i, k, j)
    i, k, j = jnp.nonzero(m3, size=cap, fill_value=g)
    pairs = jnp.stack([i, k, j], axis=1).astype(jnp.int32)
    return pairs, jnp.sum(m3).astype(jnp.int32)


def plan_caps(mask_a: np.ndarray, mask_b: np.ndarray,
              slack: float = 1.25, round_to: int = 64) -> list[int]:
    """Host-side capacity schedule: exact per-level surviving-triple counts
    (the paper's task counts, Figs 3-4) with head-room.  Runs on concrete
    masks before tracing; the jit'd program is specialized to these caps."""
    g = mask_a.shape[0]
    levels = int(np.log2(g))
    ma, mb = np.asarray(mask_a), np.asarray(mask_b)
    caps = []
    pyr_a, pyr_b = _np_pyramid(ma), _np_pyramid(mb)
    for l in range(levels):
        a_l = pyr_a[levels - 1 - l].astype(np.int64)
        b_l = pyr_b[levels - 1 - l].astype(np.int64)
        cnt = int((a_l.sum(0) * b_l.sum(1)).sum())  # sum_k colA_k * rowB_k
        cap = max(round_to, int(np.ceil(cnt * slack / round_to)) * round_to)
        caps.append(cap)
    return caps


def _np_pyramid(mask: np.ndarray) -> list[np.ndarray]:
    out = [mask]
    g = mask.shape[0]
    while g > 1:
        g //= 2
        mask = mask.reshape(g, 2, g, 2).any(axis=(1, 3))
        out.append(mask)
    return out


def plan_c_cap(mask_a: np.ndarray, mask_b: np.ndarray,
               slack: float = 1.25, round_to: int = 64) -> int:
    """Host-side capacity for the C occupancy (mask_a @ mask_b)."""
    prod = (np.asarray(mask_a, np.int64) @ np.asarray(mask_b, np.int64)) > 0
    cnt = int(prod.sum())
    return max(round_to, int(np.ceil(cnt * slack / round_to)) * round_to)
