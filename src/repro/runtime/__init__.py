"""Runtime subsystems: cluster simulator, fault handling, elasticity.

Submodules are imported lazily (PEP 562): the Chunks-and-Tasks scheduler
(`scheduler`, `trace`) is pure numpy/stdlib and must stay importable — and
fast to import — without touching the jax-backed modules (`compression`,
`fault`, `elastic`).
"""
_EXPORTS = {
    # discrete-event Chunks-and-Tasks runtime simulator (DESIGN.md §4)
    "Scheduler": ("scheduler", "Scheduler"),
    "SimReport": ("scheduler", "SimReport"),
    "PLACEMENTS": ("scheduler", "PLACEMENTS"),
    "simulate": ("scheduler", "simulate"),
    "Trace": ("trace", "Trace"),
    "TaskEvent": ("trace", "TaskEvent"),
    "CriticalPath": ("trace", "CriticalPath"),
    "critical_path": ("trace", "critical_path"),
    # fault schedules + recovery policies for the simulator (DESIGN.md §10)
    "FaultEvent": ("recovery", "FaultEvent"),
    "FaultSchedule": ("recovery", "FaultSchedule"),
    "RecoveryManager": ("recovery", "RecoveryManager"),
    "kill": ("recovery", "kill"),
    "slow": ("recovery", "slow"),
    "join": ("recovery", "join"),
    "leave": ("recovery", "leave"),
    # gradient compression (jax)
    "compressed_grad_tree": ("compression", "compressed_grad_tree"),
    "dequantize_int8": ("compression", "dequantize_int8"),
    "quantize_int8": ("compression", "quantize_int8"),
    # fault tolerance (jax)
    "FaultInjector": ("fault", "FaultInjector"),
    "HeartbeatMonitor": ("fault", "HeartbeatMonitor"),
    "TrainingRunner": ("fault", "TrainingRunner"),
    # elastic remeshing (jax)
    "elastic_remesh_plan": ("elastic", "elastic_remesh_plan"),
    "reshard_tree": ("elastic", "reshard_tree"),
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    try:
        mod_name, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    mod = importlib.import_module(f".{mod_name}", __name__)
    return getattr(mod, attr)


def __dir__():
    return sorted(__all__)
