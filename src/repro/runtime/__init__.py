from .compression import (compressed_grad_tree, dequantize_int8,  # noqa
                           quantize_int8)
from .fault import FaultInjector, HeartbeatMonitor, TrainingRunner  # noqa
from .elastic import elastic_remesh_plan, reshard_tree  # noqa: F401
