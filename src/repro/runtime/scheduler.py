"""Chunks-and-Tasks runtime simulator: work-stealing scheduler with
locality-aware chunk placement (paper §2, Figs 9 and 11-14; DESIGN.md §4).

A discrete-event simulation that replays a recorded :class:`CTGraph` task
DAG over ``p`` virtual workers with CHT-MPI's scheduling semantics:

* **Task tree scheduling** — every worker keeps a deque of ready tasks.
  A task's children enter the deque of the worker that executed the parent;
  own work is popped newest-first (depth-first), keeping execution inside
  one subtree.
* **Randomized work stealing (§2.1)** — an idle worker picks a uniformly
  random victim among workers with ready tasks and steals from the *oldest*
  end of the victim's deque: "work stealing always occurs as high up as
  possible in the local task tree of the victim process".  Every steal pays
  :attr:`CostModel.steal_latency_s` on the thief's clock.
* **Chunk placement** — the output chunk of a task is registered with the
  :class:`ChunkStore` when the task completes.  *Where* it lands is the
  pluggable placement policy:

  - ``parent-worker`` (paper §2.1, the locality-aware default): the chunk is
    owned by the worker that executed the producing task — "each chunk
    object is by default owned by the worker process that created that
    chunk".  Placement *follows* the work-stealing execution over the
    quadtree, which is what makes per-worker communication essentially
    constant in weak scaling for matrices with data locality (Table 1).
  - ``round-robin`` / ``random`` (locality-oblivious baselines): ownership
    is assigned independently of execution; the producing worker must ship
    the chunk to its owner (the owner *receives* the bytes) and every later
    consumer fetches it remotely.

* **Communication accounting** — all input fetches are routed through the
  worker-local bounded LRU chunk cache of :class:`ChunkStore`; bytes
  received, messages, cache hits and peak owned bytes per worker are
  accounted exactly as plotted in Figs 11-13.
* **Modelled wall clock** — task duration is
  ``task_overhead_s + cost + flops / flops_per_s + fetch + push`` where
  each cache-miss fetch pays ``latency_s + nbytes / bandwidth_Bps`` and a
  non-local placement pays the same for the push.  This yields makespans,
  simulated speedup curves (Fig 9) and active fractions.

The simulator is *persistent across phases*: chunk placements from an
earlier :meth:`Scheduler.run` (e.g. the task program that built the input
matrices — paper §7: "the data distribution of input matrices was a result
of the task executions that generated those matrices") carry over to the
next run, so the multiply's communication is measured against a realistic
input distribution.  Call :meth:`reset_stats` between phases to isolate one
phase's communication.
"""
from __future__ import annotations

import dataclasses
import heapq
import random
from typing import Optional

from repro.core.chunks import ChunkId, ChunkStore
from repro.core.tasks import CostModel, CTGraph

from .recovery import FaultSchedule, RecoveryManager, as_fault_schedule
from .trace import CriticalPath, TaskEvent, Trace, critical_path

PLACEMENTS = ("parent-worker", "round-robin", "random")

__all__ = ["Scheduler", "SimReport", "PLACEMENTS"]


@dataclasses.dataclass
class SimReport:
    """Per-phase statistics of one :meth:`Scheduler.run` (Figs 9, 11-13)."""
    makespan: float
    bytes_received: list[int]
    messages_received: list[int]
    peak_owned: list[int]
    tasks_per_worker: list[int]
    busy_time: list[float]
    steals: int
    n_workers: int = 1
    placement: str = "parent-worker"
    bytes_pushed: list[int] = dataclasses.field(default_factory=list)
    cache_hits: list[int] = dataclasses.field(default_factory=list)
    dedup_hits: list[int] = dataclasses.field(default_factory=list)
    flops_executed: list[float] = dataclasses.field(default_factory=list)
    steal_time_s: float = 0.0
    trace: Optional[Trace] = None
    crit: Optional[CriticalPath] = None
    # fault/recovery counters (DESIGN.md §10): all zero on fault-free runs
    chunks_lost: int = 0
    bytes_lost: int = 0
    tasks_recomputed: int = 0
    bytes_rereplicated: int = 0
    chunks_recovered: int = 0
    workers_failed: list[int] = dataclasses.field(default_factory=list)
    fault_events: list[dict] = dataclasses.field(default_factory=list)

    @property
    def n_failures(self) -> int:
        """Worker deaths applied during (or inherited by) this run."""
        return len(self.workers_failed)

    def degradation_vs(self, baseline: "SimReport") -> float:
        """Makespan ratio against a fault-free reference run."""
        if baseline.makespan <= 0:
            return float("inf") if self.makespan > 0 else 1.0
        return self.makespan / baseline.makespan

    @property
    def avg_bytes_received(self) -> float:
        return sum(self.bytes_received) / len(self.bytes_received)

    @property
    def max_bytes_received(self) -> int:
        return max(self.bytes_received)

    @property
    def n_tasks(self) -> int:
        """Tasks executed in this phase (truncation shrinks it)."""
        return sum(self.tasks_per_worker)

    @property
    def total_flops(self) -> float:
        """Useful flops executed in this phase across workers."""
        return sum(self.flops_executed)

    @property
    def active_fraction(self) -> list[float]:
        return [b / self.makespan if self.makespan > 0 else 0.0
                for b in self.busy_time]

    @property
    def work_s(self) -> float:
        """T1: total busy time across workers."""
        return sum(self.busy_time)

    @property
    def parallel_efficiency(self) -> float:
        from repro.core.analysis import parallel_efficiency
        return parallel_efficiency(self.work_s, self.makespan,
                                   self.n_workers)

    def to_metrics(self):
        """This report in the unified counter schema (DESIGN.md §8).

        Returns a :class:`~repro.obs.metrics.MetricSet` whose per-worker
        lists are this report's fields verbatim — ``bytes_received`` is
        the paper's cache-miss communication metric (Figs 11-13).
        """
        from repro.obs.metrics import from_sim_report
        return from_sim_report(self)

    def to_dict(self) -> dict:
        d = {
            "n_workers": self.n_workers,
            "placement": self.placement,
            "makespan_s": self.makespan,
            "bytes_received": self.bytes_received,
            "bytes_pushed": self.bytes_pushed,
            "messages_received": self.messages_received,
            "peak_owned": self.peak_owned,
            "tasks_per_worker": self.tasks_per_worker,
            "n_tasks": self.n_tasks,
            "total_flops": self.total_flops,
            "steals": self.steals,
            "parallel_efficiency": self.parallel_efficiency,
        }
        if self.crit is not None:
            d.update(self.crit.to_dict())
        if self.fault_events or self.workers_failed:
            d.update({
                "workers_failed": list(self.workers_failed),
                "fault_events": list(self.fault_events),
                "chunks_lost": self.chunks_lost,
                "bytes_lost": self.bytes_lost,
                "tasks_recomputed": self.tasks_recomputed,
                "bytes_rereplicated": self.bytes_rereplicated,
                "chunks_recovered": self.chunks_recovered,
            })
        return d


def _pop_enabled(dq: list, now: float, newest: bool
                 ) -> Optional[tuple[int, float]]:
    """Pop an entry already enabled at ``now``, or None.

    Entries carry (nid, ready_time); ones with ready_time > now are not yet
    visible to a worker whose clock is ``now`` (their enabling completion
    lies in its future).  ``newest=True`` scans newest-first (own work,
    LIFO), ``newest=False`` oldest-first (steals go as high up the victim's
    task tree as possible).
    """
    order = range(len(dq) - 1, -1, -1) if newest else range(len(dq))
    for i in order:
        if dq[i][1] <= now:
            return dq.pop(i)
    return None


def _place(policy: str, creator: int, chunk_idx: int, p: int,
           rng: random.Random) -> int:
    if policy == "parent-worker":
        return creator
    if policy == "round-robin":
        return chunk_idx % p
    if policy == "random":
        return rng.randrange(p)
    raise ValueError(f"unknown placement {policy!r}; pick one of {PLACEMENTS}")


class Scheduler:
    """Discrete-event CHT-MPI cluster simulator over a :class:`CTGraph`.

    >>> sched = Scheduler(seed=0)
    >>> sched.run(g, n_workers=8)                   # build phase
    >>> sched.reset_stats()
    >>> rc = qt_multiply(g, params, ra, rb)
    >>> rep = sched.run(g, n_workers=8, placement="parent-worker")
    >>> rep.max_bytes_received, rep.makespan, rep.crit.length_s

    ``n_workers`` and ``placement`` are fixed by the first :meth:`run`;
    later runs may omit them but must not change them (the chunk store and
    ownership map are worker-count-specific).
    """

    def __init__(self, cost: CostModel | None = None,
                 cache_bytes: int = 1 << 62, seed: int = 0,
                 dedup: bool = False):
        self.cost = cost or CostModel()
        self.cache_bytes = cache_bytes
        self.dedup = dedup
        self.seed = seed
        self.rng = random.Random(seed)
        self.store: Optional[ChunkStore] = None
        self.n_workers: Optional[int] = None
        self.placement_policy: Optional[str] = None
        self.placement: dict[int, ChunkId] = {}   # node id -> chunk id
        self._owner_of_node: dict[int, int] = {}  # node id -> executing worker
        self._chunk_counter = 0                   # round-robin state
        # fault state persists across runs: a worker killed mid-phase stays
        # dead for every later phase/replay on this scheduler
        self._dead: set[int] = set()
        self._left: set[int] = set()              # graceful departures
        self._slow: dict[int, float] = {}         # straggler factors
        self.recovery = RecoveryManager(self)

    # -- worker liveness ----------------------------------------------------
    def live_workers(self) -> list[int]:
        """Workers currently able to run tasks / own new chunks."""
        return [w for w in range(self.n_workers)
                if w not in self._dead and w not in self._left]

    def _remap(self, worker: int) -> int:
        """A live stand-in for ``worker`` (itself when alive)."""
        if worker not in self._dead and worker not in self._left:
            return worker
        live = self.live_workers()
        if not live:
            raise RuntimeError("fault simulation: every worker is dead")
        return live[worker % len(live)]

    # -- lifecycle ----------------------------------------------------------
    def _configure(self, n_workers: Optional[int], placement: Optional[str]
                   ) -> None:
        if self.store is None:
            self.n_workers = n_workers or 1
            self.placement_policy = placement or "parent-worker"
            if self.placement_policy not in PLACEMENTS:
                raise ValueError(
                    f"unknown placement {self.placement_policy!r}; "
                    f"pick one of {PLACEMENTS}")
            self.store = ChunkStore(self.n_workers, self.cache_bytes,
                                    dedup=self.dedup)
        else:
            if n_workers is not None and n_workers != self.n_workers:
                raise ValueError(
                    f"scheduler already configured for {self.n_workers} "
                    f"workers; cannot re-run with {n_workers}")
            if placement is not None and placement != self.placement_policy:
                raise ValueError(
                    f"scheduler already configured for placement "
                    f"{self.placement_policy!r}; cannot re-run with "
                    f"{placement!r}")

    def reset_stats(self) -> None:
        """Zero per-worker counters; keep placements (phase isolation)."""
        if self.store is None:       # nothing simulated yet: nothing to zero
            return
        for s in self.store.stats:
            s.bytes_received = 0
            s.bytes_received_local = 0
            s.bytes_pushed = 0
            s.messages_received = 0
            s.cache_hits = 0
            s.tasks_executed = 0
            s.busy_time = 0.0
            s.dedup_hits = 0
            s.flops_executed = 0.0

    def replay(self, g: CTGraph, nids,
               faults: Optional[FaultSchedule] = None) -> SimReport:
        """Re-simulate an already-simulated *fixed* task program.

        Compiled-Plan re-execution (api/plan.py) registers zero new
        tasks, so a plain :meth:`run` would find nothing to do.  This
        marks the given nodes un-simulated again — freeing the chunks
        their previous execution placed (placements of everything
        *outside* the program, e.g. the input matrices, persist, so input
        fetches are charged against the realistic distribution exactly as
        in a first run) — and replays them through the normal
        discrete-event loop.  Combine with :meth:`reset_stats` to isolate
        one iteration's communication.
        """
        if self.store is None:          # nothing simulated yet: plain run
            return self.run(g, only=self.unsimulated_closure(g, nids),
                            faults=faults)
        self.release(g, nids, forget_owner=True)
        # restrict the re-run to the program (plus any genuinely
        # unsimulated prerequisites): other pending work — e.g. another
        # compiled-but-not-yet-simulated plan — keeps its own report
        return self.run(g, only=self.unsimulated_closure(g, nids),
                        faults=faults)

    def release(self, g: CTGraph, nids, forget_owner: bool = False) -> None:
        """Free the chunks these nodes placed; drop their placement
        entries.  Alias nodes lose only their placement entry (the
        resolved producer owns the chunk); ``forget_owner=True``
        additionally marks the nodes un-simulated so the next
        :meth:`run` executes them again (replay).  This is the single
        place placement/ownership bookkeeping is unwound — both program
        replay and :meth:`Session.free` go through it.
        """
        for nid in nids:
            if forget_owner:
                self._owner_of_node.pop(nid, None)
            cid = self.placement.pop(nid, None)
            node = g.nodes[nid]
            if cid is not None and node.alias_of is None \
                    and node.value is not None:
                self.store.free(cid)
            for rcid in self.recovery.drop_replicas(nid):
                self.store.free(rcid)

    def has_simulated(self, nids) -> bool:
        """Whether any of these nodes has already been executed on the
        virtual cluster (public accessor for Plan.simulate)."""
        return any(nid in self._owner_of_node for nid in nids)

    def unsimulated_closure(self, g: CTGraph, nids) -> set:
        """Not-yet-simulated nodes needed to simulate ``nids``.

        Walks dependencies (their producers must be placed), parents (a
        task becomes runnable only when its parent executed) and children
        (a container's subtree belongs to its program) over unsimulated
        nodes only.  This is the ``only`` filter for a restricted
        :meth:`run`: a fixed program simulates by itself, without
        sweeping in unrelated pending work.
        """
        seen: set = set()
        stack = list(nids)
        while stack:
            nid = stack.pop()
            if nid is None or nid in seen or nid in self._owner_of_node:
                continue
            seen.add(nid)
            node = g.nodes[nid]
            for d in node.deps:
                stack.append(g.resolve(d.nid))
            if node.parent is not None:
                stack.append(node.parent)
            stack.extend(node.children)
        return seen

    # -- the discrete-event loop -------------------------------------------
    def run(self, g: CTGraph, n_workers: Optional[int] = None,
            placement: Optional[str] = None, start_worker: int = 0,
            only: Optional[set] = None,
            faults: Optional[FaultSchedule] = None) -> SimReport:
        """Simulate all not-yet-simulated nodes of ``g``; returns stats.

        ``only`` restricts the pass to a node subset (see
        :meth:`unsimulated_closure`): nodes outside it stay pending for a
        later run.

        ``faults`` injects a deterministic :class:`~repro.runtime.
        recovery.FaultSchedule` into this run's simulated timeline:
        worker deaths drop the dead worker's ChunkStore slice and recover
        by replica re-pointing or lineage recompute (the schedule's
        ``recovery`` policy), stragglers scale a worker's compute time,
        and join/leave events grow/shrink the pool mid-run.  Events later
        than the run's end never fire; dead/left workers stay out of the
        pool for every later run on this scheduler.  Fault handling never
        touches task *values* — only placement, timing and the recovery
        counters — so results stay bitwise identical to a fault-free run.
        """
        self._configure(n_workers, placement)
        schedule = as_fault_schedule(faults)
        self.recovery.begin_run(schedule)
        events = list(schedule.events) if schedule is not None else []
        g.flush()   # batched leaf waves must run so per-task flops are final
        tr = g.tracer
        todo = [n for n in g.nodes if n.nid not in self._owner_of_node
                and (only is None or n.nid in only)]
        trace = Trace(self.n_workers)
        if not todo:
            return self._report(0.0, 0, 0.0, trace, g, set())
        todo_ids = {n.nid for n in todo}
        done_before = set(self._owner_of_node)
        done_run: set = set()           # nids completed in *this* run

        # dependency bookkeeping: a task is runnable once its parent has
        # executed (it is then "registered") and all fetched deps are done.
        # ready_after[nid] is the virtual time of the last enabling event
        # (parent or dependency completion): execution may not start before
        # it, no matter how idle a worker's clock is.
        pending: dict[int, int] = {}
        dependents: dict[int, list[int]] = {}
        registered: dict[int, bool] = {}
        ready_after: dict[int, float] = {}
        for n in todo:
            cnt = 0
            for d in n.deps:
                dn = g.resolve(d.nid)
                if dn is not None and dn in todo_ids:
                    cnt += 1
                    dependents.setdefault(dn, []).append(n.nid)
            pending[n.nid] = cnt
            registered[n.nid] = (n.parent is None or n.parent not in todo_ids)
            ready_after[n.nid] = 0.0

        deques: list[list[tuple[int, float]]] = [
            [] for _ in range(self.n_workers)]
        free_at = [0.0] * self.n_workers
        n_steals = 0
        steal_time = 0.0
        # tasks whose worker died mid-execution (redistributed at the kill)
        aborted: dict[int, list[tuple[int, float]]] = {}
        kill_time = schedule.kill_times() if schedule is not None else {}

        def push_ready(nid: int, worker: int) -> None:
            worker = self._remap(worker)
            self._owner_of_node[nid] = worker
            deques[worker].append((nid, ready_after[nid]))

        for n in todo:
            if registered[n.nid] and pending[n.nid] == 0:
                push_ready(n.nid, start_worker)

        time_now = 0.0
        # fault events ride the same heap as negative sentinel ids: an
        # event at time t pops before any worker whose clock reaches t,
        # and same-time events apply in schedule order
        n_ev = len(events)
        heap = [(0.0, w) for w in self.live_workers()]
        heap += [(ev.t, i - n_ev) for i, ev in enumerate(events)]
        heapq.heapify(heap)
        executed = 0
        total = len(todo)
        blocked: list[tuple[float, int]] = []   # workers with no ready work

        def wake_blocked(tmin: float) -> None:
            nonlocal blocked
            for bt, bw in blocked:
                heapq.heappush(heap, (max(bt, tmin), bw))
            blocked = []

        def inject(nids, t_ev: float) -> list:
            """Put already-executed nodes back on the todo list (lineage
            recompute).  Returns the nids actually (re-)enqueued."""
            nonlocal total
            injected = []
            for nid in sorted(nids):
                if nid in todo_ids and nid not in done_run:
                    continue            # still pending: nothing to redo
                done_run.discard(nid)
                todo_ids.add(nid)
                ready_after[nid] = t_ev
                par = g.nodes[nid].parent
                # runnable once the parent executed: parents re-injected in
                # the same batch have lower nids and were re-added already
                registered[nid] = (par is None or par not in todo_ids
                                   or par in done_run)
                injected.append(nid)
            if not injected:
                return injected
            total += len(injected)
            # rebuild dependency counts from scratch: a re-injected
            # producer flips its consumers' satisfied edges back on
            dependents.clear()
            for x in sorted(todo_ids):
                if x in done_run:
                    continue
                cnt = 0
                for d in g.nodes[x].deps:
                    dn = g.resolve(d.nid)
                    if dn is not None and dn in todo_ids \
                            and dn not in done_run:
                        cnt += 1
                        dependents.setdefault(dn, []).append(x)
                pending[x] = cnt
            # queued entries whose deps were just lost are not runnable
            # anymore; they re-enter when the recomputed dep completes
            for dq in deques:
                dq[:] = [(q, rt) for q, rt in dq if pending[q] == 0]
            live = self.live_workers()
            qi = 0
            for nid in injected:
                if registered[nid] and pending[nid] == 0:
                    push_ready(nid, live[qi % len(live)])
                    qi += 1
            return injected

        def apply_event(ev) -> None:
            log = {"t": ev.t, "action": ev.action, "worker": ev.worker}
            if ev.action == "join":
                w_new = self.store.add_worker()
                self.n_workers = self.store.n_workers
                deques.append([])
                free_at.append(ev.t)
                trace.n_workers = self.n_workers
                heapq.heappush(heap, (ev.t, w_new))
                log["worker"] = w_new
                tr.instant("fault.join", track="fault", worker=w_new,
                           t_sim=ev.t)
            elif ev.action == "slow":
                self._slow[ev.worker] = float(ev.factor)
                log["factor"] = ev.factor
                tr.instant("fault.slow", track="fault", worker=ev.worker,
                           factor=ev.factor, t_sim=ev.t)
            else:                       # "kill" / "leave"
                w = ev.worker
                if not (0 <= w < self.n_workers) or w in self._dead \
                        or w in self._left:
                    log["skipped"] = True
                    self.recovery.events_applied.append(log)
                    return
                orphans = list(deques[w]) + aborted.pop(w, [])
                deques[w].clear()
                if ev.action == "leave":
                    self._left.add(w)
                    tr.instant("fault.leave", track="fault", worker=w,
                               t_sim=ev.t)
                else:
                    self._dead.add(w)
                    n_chunks, n_bytes = self.store.drop_worker(w)
                    self.recovery.chunks_lost += n_chunks
                    self.recovery.bytes_lost += n_bytes
                    log.update(chunks_lost=n_chunks, bytes_lost=n_bytes)
                    tr.instant("fault.kill", track="fault", worker=w,
                               t_sim=ev.t, chunks_lost=n_chunks,
                               bytes_lost=n_bytes)
                    with tr.span("fault.recover", track="fault", worker=w,
                                 t_sim=ev.t,
                                 policy=self.recovery.policy or "lineage"
                                 ) as sp:
                        recompute = self.recovery.on_death(g, w, done_run)
                        injected = []
                        if recompute:
                            self.release(g, sorted(recompute),
                                         forget_owner=True)
                            closure = self.unsimulated_closure(g, recompute)
                            injected = inject(closure, ev.t)
                            self.recovery.tasks_recomputed += len(injected)
                        log["tasks_recomputed"] = len(injected)
                        sp.set(tasks_recomputed=len(injected),
                               chunks_recovered=self.recovery
                               .chunks_recovered)
                # survivors inherit the lost worker's queued-but-unexecuted
                # tasks (only entries still runnable after the rewiring)
                live = self.live_workers()
                if not live:
                    raise RuntimeError(
                        "fault simulation: every worker is dead")
                runnable = [(q, rt) for q, rt in orphans
                            if q in todo_ids and q not in done_run
                            and pending.get(q, 1) == 0]
                for i, (q, rt) in enumerate(runnable):
                    tgt = live[i % len(live)]
                    self._owner_of_node[q] = tgt
                    deques[tgt].append((q, max(rt, ev.t)))
            self.recovery.events_applied.append(log)
            wake_blocked(ev.t)

        while executed < total:
            if not heap:
                if not blocked:
                    raise RuntimeError("deadlock in task graph simulation")
                t = min(b[0] for b in blocked)
                for bt, w in blocked:
                    heapq.heappush(heap, (max(bt, t), w))
                blocked = []
                continue
            t, w = heapq.heappop(heap)
            if w < 0:                   # fault-event sentinel
                apply_event(events[w + n_ev])
                continue
            if w in self._dead or w in self._left:
                continue                # stale entry of a removed worker
            time_now = max(time_now, t)
            nid = None
            stolen = False
            got = _pop_enabled(deques[w], t, newest=True)   # own work first
            if got is not None:
                nid, _ = got
            else:
                victims = [v for v in self.live_workers() if v != w
                           and any(rt <= t for _, rt in deques[v])]
                if victims:
                    v = self.rng.choice(victims)
                    nid, _ = _pop_enabled(deques[v], t, newest=False)
                    self._owner_of_node[nid] = w
                    t += self.cost.steal_latency_s
                    steal_time += self.cost.steal_latency_s
                    n_steals += 1
                    stolen = True
            if nid is None:
                # nothing enabled yet anywhere at this worker's clock: wait
                # for the next enabling event (if one is pending) or block
                future = [rt for dq in deques for _, rt in dq]
                if future:
                    heapq.heappush(heap, (min(future), w))
                else:
                    blocked.append((t, w))
                continue

            node = g.nodes[nid]
            st = self.store.stats[w]
            # fetch inputs through the chunk cache (misses = communication)
            fetch_time = 0.0
            rb0, rm0 = st.bytes_received, st.messages_received
            for d in node.deps:
                if not d.fetch:
                    continue
                dn = g.resolve(d.nid)
                cid = self.placement.get(dn) if dn is not None else None
                if cid is not None:
                    before = st.bytes_received
                    msgs_before = st.messages_received
                    self.store.fetch(w, cid)
                    dbytes = st.bytes_received - before
                    dmsgs = st.messages_received - msgs_before
                    fetch_time += dbytes / self.cost.bandwidth_Bps \
                        + dmsgs * self.cost.latency_s
            remote_bytes = st.bytes_received - rb0
            remote_msgs = st.messages_received - rm0

            # straggler factor scales the compute term only (fetch/push are
            # network time); slow == 1.0 is bitwise-neutral
            compute = (self.cost.task_overhead_s + node.cost
                       + node.flops / self.cost.flops_per_s) \
                * self._slow.get(w, 1.0)
            t_kill = kill_time.get(w)
            if t_kill is not None and t + compute + fetch_time > t_kill:
                # the worker dies before this task can commit: the partial
                # work is wasted and the task returns to the pool when the
                # kill event fires (its chunk is never placed)
                st.busy_time += max(0.0, t_kill - t)
                aborted.setdefault(w, []).append((nid, ready_after[nid]))
                continue

            # produce + place the output chunk
            push_time = 0.0
            pushed_bytes = 0
            if node.alias_of is None and node.value is not None:
                owner = _place(self.placement_policy, w, self._chunk_counter,
                               self.n_workers, self.rng)
                self._chunk_counter += 1
                owner = self._remap(owner)
                # charge ship time only for bytes the store actually moved:
                # a dedup hit resolves to an existing chunk id, no transfer
                pushed_before = self.store.stats[owner].bytes_pushed
                cid = self.store.register_pushed(w, owner, node.value,
                                                 node.out_nbytes)
                self.placement[nid] = cid
                shipped = self.store.stats[owner].bytes_pushed - pushed_before
                if shipped:
                    pushed_bytes = shipped
                    push_time = shipped / self.cost.bandwidth_Bps \
                        + self.cost.latency_s
                # r-way replication at registration (DESIGN.md §10)
                rbytes, rmsgs = self.recovery.on_place(
                    nid, cid, node.out_nbytes, self.live_workers())
                if rbytes:
                    push_time += rbytes / self.cost.bandwidth_Bps \
                        + rmsgs * self.cost.latency_s
            elif node.alias_of is not None:
                rn = g.resolve(nid)
                if rn in self.placement:
                    self.placement[nid] = self.placement[rn]

            dur = compute + fetch_time + push_time
            t_end = t + dur
            st.tasks_executed += 1
            st.busy_time += dur
            st.flops_executed += node.flops
            trace.append(TaskEvent(nid=nid, kind=node.kind, worker=w,
                                   start=t, end=t_end, stolen=stolen,
                                   remote_bytes=remote_bytes,
                                   remote_msgs=remote_msgs,
                                   pushed_bytes=pushed_bytes))

            executed += 1
            done_run.add(nid)
            for c in node.children:
                if c in registered and not registered[c]:
                    registered[c] = True
                    ready_after[c] = max(ready_after[c], t_end)
                    if pending[c] == 0:
                        push_ready(c, w)
            for dep_nid in dependents.get(nid, ()):
                pending[dep_nid] -= 1
                ready_after[dep_nid] = max(ready_after[dep_nid], t_end)
                if pending[dep_nid] == 0 and registered[dep_nid]:
                    parent = g.nodes[dep_nid].parent
                    push_ready(dep_nid,
                               self._owner_of_node.get(parent, w)
                               if parent is not None else w)
            free_at[w] = t_end
            heapq.heappush(heap, (t_end, w))
            if blocked:
                for bt, bw in blocked:
                    heapq.heappush(heap, (max(bt, time_now), bw))
                blocked = []

        makespan = max(free_at)
        return self._report(makespan, n_steals, steal_time, trace, g,
                            done_before)

    def _report(self, makespan: float, steals: int, steal_time: float,
                trace: Trace, g: CTGraph, done_before: set) -> SimReport:
        st = self.store.stats
        crit = critical_path(g, trace, done_before) if len(trace) else None
        rec = self.recovery
        return SimReport(
            chunks_lost=rec.chunks_lost,
            bytes_lost=rec.bytes_lost,
            tasks_recomputed=rec.tasks_recomputed,
            bytes_rereplicated=rec.bytes_rereplicated,
            chunks_recovered=rec.chunks_recovered,
            workers_failed=sorted(self._dead),
            fault_events=list(rec.events_applied),
            makespan=makespan,
            bytes_received=[s.bytes_received for s in st],
            messages_received=[s.messages_received for s in st],
            peak_owned=[s.peak_owned_bytes for s in st],
            tasks_per_worker=[s.tasks_executed for s in st],
            busy_time=[s.busy_time for s in st],
            steals=steals,
            n_workers=self.n_workers,
            placement=self.placement_policy,
            bytes_pushed=[s.bytes_pushed for s in st],
            cache_hits=[s.cache_hits for s in st],
            dedup_hits=[s.dedup_hits for s in st],
            flops_executed=[s.flops_executed for s in st],
            steal_time_s=steal_time,
            trace=trace,
            crit=crit,
        )


def simulate(g: CTGraph, n_workers: int, placement: str = "parent-worker",
             cost: CostModel | None = None, cache_bytes: int = 1 << 62,
             seed: int = 0,
             faults: Optional[FaultSchedule] = None) -> SimReport:
    """One-shot convenience: simulate the whole graph in a single phase."""
    sched = Scheduler(cost=cost, cache_bytes=cache_bytes, seed=seed)
    return sched.run(g, n_workers=n_workers, placement=placement,
                     faults=faults)
