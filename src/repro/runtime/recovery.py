"""Fault schedules and chunk recovery for the runtime simulator (§10).

The Chunks and Tasks model advertises fault tolerance as a consequence of
its two core invariants: chunks are immutable, and every task's inputs
(the lineage) are recorded at registration.  This module is the simulator
side of that claim — it defines

* :class:`FaultEvent` / :class:`FaultSchedule` — a deterministic schedule
  of worker deaths, stragglers and elastic join/leave events in
  *simulated* time, passed to ``Session.simulate(faults=...)`` /
  ``Scheduler.run(faults=...)``;
* :class:`RecoveryManager` — the per-:class:`~repro.runtime.scheduler.
  Scheduler` policy object that reacts to a death.  Two recovery modes
  plus a deliberately bad baseline:

  - ``"lineage"`` (default): walk the recorded producer graph
    (``Scheduler.unsimulated_closure``) and re-enqueue the *minimal* task
    closure that regenerates the lost chunks — nothing else re-runs.
  - ``"replication"``: keep ``replicas`` physical copies of every placed
    chunk on distinct workers (made at registration time, ring-successor
    placement); a death re-points placements at a surviving copy and
    re-replicates to restore the factor.  Recompute only happens when
    every copy died, so replication *bounds* recompute work at the price
    of r× memory and registration bandwidth.
  - ``"none"``: the no-fault-tolerance baseline — a death restarts the
    whole phase (every task completed so far re-runs), which is what a
    plain SPMD job without checkpoints would do.

Wall-clock effects (aborted in-flight work, redistribution, recompute)
are modelled inside the discrete-event loop of
:mod:`repro.runtime.scheduler`; this module owns only the policy and its
bookkeeping (replica maps, recovery counters).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.chunks import ChunkId

__all__ = ["ACTIONS", "RECOVERIES", "FaultEvent", "FaultSchedule",
           "RecoveryManager", "as_fault_schedule", "kill", "slow", "join",
           "leave"]

ACTIONS = ("kill", "slow", "join", "leave")
RECOVERIES = ("none", "replication", "lineage")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled event at simulated time ``t`` (seconds).

    Actions: ``"kill"`` (worker dies, its owned chunks are lost, its
    in-flight task is wasted), ``"slow"`` (worker's compute time is
    multiplied by ``factor`` from ``t`` on — a straggler), ``"join"``
    (a fresh worker enters the pool and starts stealing), ``"leave"``
    (graceful departure: the worker stops taking work but its chunks
    stay readable — think preemption with data drain).
    """
    t: float
    action: str
    worker: Optional[int] = None
    factor: float = 1.0

    def __post_init__(self):
        if self.action not in ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r}; "
                             f"pick one of {ACTIONS}")
        if self.t < 0:
            raise ValueError(f"fault event time must be >= 0, got {self.t}")
        if self.action != "join" and self.worker is None:
            raise ValueError(f"{self.action!r} event needs a worker index")
        if self.action == "slow" and self.factor <= 0:
            raise ValueError(f"slow factor must be > 0, got {self.factor}")


def kill(t: float, worker: int) -> FaultEvent:
    """Worker death at simulated time ``t``."""
    return FaultEvent(t, "kill", worker)


def slow(t: float, worker: int, factor: float) -> FaultEvent:
    """Straggler: ``worker`` computes ``factor``× slower from ``t`` on."""
    return FaultEvent(t, "slow", worker, factor)


def join(t: float) -> FaultEvent:
    """Elastic join: a new worker enters the pool at ``t``."""
    return FaultEvent(t, "join")


def leave(t: float, worker: int) -> FaultEvent:
    """Graceful leave: stop scheduling onto ``worker``; chunks survive."""
    return FaultEvent(t, "leave", worker)


@dataclasses.dataclass
class FaultSchedule:
    """A deterministic fault scenario: events + recovery policy.

    ``events`` accepts :class:`FaultEvent` instances or plain
    ``(t, action, ...)`` tuples and is kept sorted by time (stable, so
    same-time events apply in the order given — two kills at one instant
    are expressible).  Events later than the end of the run never fire.
    An *empty* schedule with ``recovery="replication"`` is meaningful:
    it turns on r-way replication at registration for that run (e.g. the
    build phase) without injecting any failure.
    """
    events: Sequence = ()
    recovery: str = "lineage"
    replicas: int = 2

    def __post_init__(self):
        if self.recovery not in RECOVERIES:
            raise ValueError(f"unknown recovery policy {self.recovery!r}; "
                             f"pick one of {RECOVERIES}")
        if self.replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {self.replicas}")
        evs = [e if isinstance(e, FaultEvent) else FaultEvent(*e)
               for e in self.events]
        self.events = sorted(evs, key=lambda e: e.t)

    def kill_times(self) -> dict:
        """worker -> simulated time of its (first) scheduled death."""
        kt: dict = {}
        for e in self.events:
            if e.action == "kill" and e.worker not in kt:
                kt[e.worker] = e.t
        return kt


def as_fault_schedule(faults) -> Optional[FaultSchedule]:
    """Normalise ``faults``: None, a FaultSchedule, or an event iterable."""
    if faults is None or isinstance(faults, FaultSchedule):
        return faults
    return FaultSchedule(events=list(faults))


class RecoveryManager:
    """Recovery policy + bookkeeping for one :class:`Scheduler`.

    Counters are zeroed by :meth:`begin_run` and surface on the run's
    :class:`~repro.runtime.scheduler.SimReport`.  The replica map
    persists across runs (replicas made during the build phase protect
    the input matrices through later multiply phases).
    """

    def __init__(self, sched):
        self.sched = sched
        self.policy: Optional[str] = None    # None while no schedule active
        self.replicas = 2
        # producer node id -> replica ChunkIds (copies beyond the placement)
        self._replica_of: dict[int, list] = {}
        self.chunks_lost = 0
        self.bytes_lost = 0
        self.bytes_rereplicated = 0
        self.tasks_recomputed = 0
        self.chunks_recovered = 0
        self.events_applied: list[dict] = []

    def begin_run(self, schedule: Optional[FaultSchedule]) -> None:
        self.chunks_lost = 0
        self.bytes_lost = 0
        self.bytes_rereplicated = 0
        self.tasks_recomputed = 0
        self.chunks_recovered = 0
        self.events_applied = []
        if schedule is None:
            self.policy = None
        else:
            self.policy = schedule.recovery
            self.replicas = schedule.replicas

    # -- r-way replication at registration ----------------------------------
    def on_place(self, nid: int, cid: ChunkId, nbytes: int,
                 live: list) -> tuple[int, int]:
        """Replicate a freshly placed chunk onto ``replicas - 1`` other
        live workers; returns ``(bytes shipped, messages)`` so the
        scheduler can charge the transfer on the producing task."""
        if self.policy != "replication" or nbytes <= 0:
            return 0, 0
        reps, shipped = self._make_replicas(cid, nbytes, live, existing=())
        if reps:
            self._replica_of[nid] = reps
        return shipped, len(reps)

    def _make_replicas(self, cid: ChunkId, nbytes: int, live: list,
                       existing) -> tuple[list, int]:
        """Copies on ring-successor live workers not already holding one."""
        holders = {cid.owner} | {r.owner for r in existing}
        ring = sorted(v for v in live if v not in holders)
        # start after the owner so replicas spread around the ring
        ring = [v for v in ring if v > cid.owner] + \
               [v for v in ring if v < cid.owner]
        need = self.replicas - len(holders)
        reps: list = []
        shipped = 0
        for dst in ring[:max(0, need)]:
            reps.append(self.sched.store.replicate(cid, dst))
            shipped += nbytes
        return reps, shipped

    def drop_replicas(self, nid: int) -> list:
        """Release bookkeeping when a node's chunks are freed; returns
        the replica ids the caller must free from the store."""
        return self._replica_of.pop(nid, [])

    # -- death ---------------------------------------------------------------
    def on_death(self, g, w: int, done_run: set) -> set:
        """Chunk-loss recovery after ``store.drop_worker(w)``.

        Pops every placement owned by the dead worker, re-points lost
        chunks at surviving replicas where the policy keeps them, and
        returns the producer node ids whose outputs are irrecoverably
        lost — the seed of the lineage recompute closure (under policy
        ``"none"`` that seed is the whole phase so far: a full re-run).
        """
        sched = self.sched
        placement = sched.placement
        live = sched.live_workers()
        lost = sorted(nid for nid, cid in placement.items()
                      if cid.owner == w)
        for nid in lost:
            placement.pop(nid, None)
        # producers whose output chunk vanished; aliases merely lose their
        # placement entry (fetches resolve through the producer anyway)
        producers = {nid for nid in lost
                     if g.nodes[nid].alias_of is None
                     and g.nodes[nid].value is not None}
        recompute: set = set()
        if self.policy == "replication":
            # 1) re-point lost placements at a surviving replica
            for nid in sorted(producers):
                reps = [r for r in self._replica_of.get(nid, ())
                        if r.owner != w]
                if reps:
                    placement[nid] = reps.pop(0)
                    self._replica_of[nid] = reps
                    self.chunks_recovered += 1
                else:
                    self._replica_of.pop(nid, None)
                    recompute.add(nid)   # every copy died: fall back
            # 2) drop replicas that lived on the dead worker, then restore
            #    the replication factor from each surviving primary
            for nid in sorted(self._replica_of):
                reps = [r for r in self._replica_of[nid] if r.owner != w]
                prim = placement.get(nid)
                if prim is None or prim.owner == w:
                    self._replica_of.pop(nid)
                    continue
                more, shipped = self._make_replicas(
                    prim, sched.store.size_of(prim), live, existing=reps)
                self.bytes_rereplicated += shipped
                reps += more
                if reps:
                    self._replica_of[nid] = reps
                else:
                    self._replica_of.pop(nid)
        elif self.policy == "none":
            # no fault tolerance: the phase restarts from scratch
            recompute = set(done_run) | producers
        else:                            # "lineage" (also the default)
            recompute = producers
        if self.policy != "replication":
            # any replicas from an earlier replication run lose their
            # dead-worker copies regardless of the current policy
            for nid in list(self._replica_of):
                alive = [r for r in self._replica_of[nid] if r.owner != w]
                if alive:
                    self._replica_of[nid] = alive
                else:
                    self._replica_of.pop(nid)
        return recompute
