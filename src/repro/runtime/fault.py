"""Fault tolerance: heartbeats, failure detection, checkpoint/restart,
straggler mitigation.

On a real 1000+-node deployment the SPMD job cannot absorb a node loss in
place: the runtime's job is (a) to *detect* failures/stragglers fast, (b)
to bound lost work via frequent async checkpoints, and (c) to restart —
possibly on fewer nodes (elastic re-shard, runtime/elastic.py).  This
module implements that control loop in a hardware-independent way:

* ``HeartbeatMonitor`` — per-worker last-seen timestamps; a worker silent
  for ``timeout`` is declared failed; a worker whose step time exceeds
  ``straggler_factor`` x the fleet median is flagged a straggler (the
  launcher's response: exclude-and-rescale or swap-in a hot spare);
* ``FaultInjector`` — deterministic failure schedule for tests/drills
  (fail worker w at step s);
* ``TrainingRunner`` — the restartable training loop: checkpoint every
  ``ckpt_every``, on failure restore the latest committed checkpoint and
  continue (on a re-planned mesh if the world shrank).  Exercised in
  tests/test_runtime.py with real (small) models and real failures.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import numpy as np

from repro.checkpoint import CheckpointManager


class WorkerFailure(RuntimeError):
    def __init__(self, worker: int, step: int):
        super().__init__(f"worker {worker} failed at step {step}")
        self.worker = worker
        self.step = step


@dataclasses.dataclass
class HeartbeatMonitor:
    """Per-worker liveness/straggler detection over an injectable clock.

    ``clock`` defaults to wall time (:func:`time.monotonic`); the runtime
    simulator passes its own callable so heartbeats, timeouts and
    straggler detection can all be driven in *virtual* time.
    """
    n_workers: int
    timeout: float = 30.0
    straggler_factor: float = 2.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        now = self.clock()
        self.last_seen = np.full(self.n_workers, now)
        self.step_times: list[list[float]] = [[] for _ in
                                              range(self.n_workers)]

    def beat(self, worker: int, step_time: Optional[float] = None):
        self.last_seen[worker] = self.clock()
        if step_time is not None:
            self.step_times[worker].append(step_time)

    def failed_workers(self) -> list[int]:
        now = self.clock()
        return [w for w in range(self.n_workers)
                if now - self.last_seen[w] > self.timeout]

    def stragglers(self) -> list[int]:
        recent = [np.mean(t[-5:]) if t else np.nan
                  for t in self.step_times]
        # before any worker reports a step time every entry is NaN and
        # np.nanmedian would emit an "All-NaN slice" RuntimeWarning
        if not any(np.isfinite(r) for r in recent):
            return []
        med = np.nanmedian(recent)
        if not np.isfinite(med):
            return []
        return [w for w, t in enumerate(recent)
                if np.isfinite(t) and t > self.straggler_factor * med]


@dataclasses.dataclass
class FaultInjector:
    """Deterministic failure schedule: raises WorkerFailure when reached.

    ``fail_at`` is a list of ``(step, worker)`` pairs with one-shot pop
    semantics: each entry fires exactly once, soonest step first, so two
    failures at the *same* step are expressible — the first ``check(s)``
    raises the first entry and the restarted run's next ``check(s)``
    raises the second.  The legacy ``{step: worker}`` dict form is still
    accepted (it can hold at most one failure per step).
    """
    fail_at: Any

    def __post_init__(self):
        pairs = (self.fail_at.items() if isinstance(self.fail_at, dict)
                 else self.fail_at)
        self._schedule = sorted((int(s), int(w)) for s, w in pairs)

    @property
    def schedule(self) -> list:
        """Remaining ``(step, worker)`` failures, soonest first."""
        return list(self._schedule)

    def check(self, step: int):
        if self._schedule and self._schedule[0][0] == step:
            s, w = self._schedule.pop(0)
            raise WorkerFailure(w, s)


@dataclasses.dataclass
class TrainingRunner:
    """Restartable loop: step_fn is pure (state, batch) -> (state, metrics).

    ``state`` is any pytree (params+opt).  ``batch_fn(step)`` supplies the
    batch — stateless access lets a restart resume mid-stream exactly
    (data/pipeline.py contract).
    """
    step_fn: Callable
    batch_fn: Callable
    ckpt: CheckpointManager
    ckpt_every: int = 25
    max_restarts: int = 3
    injector: Optional[FaultInjector] = None
    on_restart: Optional[Callable] = None   # state <- on_restart(state)

    def run(self, state, n_steps: int) -> tuple:
        """Returns (state, history dict)."""
        history = {"loss": [], "restarts": 0, "restored_from": []}
        step = 0
        restarts = 0
        # always have a restore point (a failure before the first periodic
        # checkpoint must not resume with partially-advanced state)
        self.ckpt.save(0, (0, state), blocking=True)
        while step < n_steps:
            try:
                while step < n_steps:
                    if self.injector is not None:
                        self.injector.check(step)
                    state, metrics = self.step_fn(state,
                                                  self.batch_fn(step))
                    loss = metrics.get("loss")
                    if loss is not None:
                        history["loss"].append(float(loss))
                    step += 1
                    if step % self.ckpt_every == 0:
                        self.ckpt.save(step, (step, state))
            except WorkerFailure:
                restarts += 1
                history["restarts"] = restarts
                if restarts > self.max_restarts:
                    raise
                restored, _ = self.ckpt.restore_latest((step, state))
                step, state = restored
                step = int(np.asarray(step))
                history["restored_from"].append(step)
                if self.on_restart is not None:
                    state = self.on_restart(state)
        self.ckpt.wait()
        return state, history
