"""Elastic re-scaling: re-plan the mesh after losing nodes and reshard.

Policy for the production 16x16 pod (DESIGN.md):

* the model axis must keep its size (tensor-parallel degree is baked into
  the layer math), so capacity changes come out of the **data axis**;
* losing up to d-1 data rows degrades data parallelism 16 -> 16-k and the
  global batch either shrinks proportionally or is preserved via more
  gradient-accumulation microbatches (the launcher picks);
* params/opt-state move to the new mesh by ``jax.device_put`` with the
  re-derived shardings (checkpoint/store.py restore path does the same
  thing across restarts — same code path, exercised in tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh

from repro.launch.sharding import param_shardings


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    old_shape: tuple
    new_shape: tuple
    axis_names: tuple
    lost_devices: int
    microbatch_scale: int     # extra grad-accumulation to keep global batch

    @property
    def new_device_count(self) -> int:
        n = 1
        for s in self.new_shape:
            n *= s
        return n


def elastic_remesh_plan(mesh_shape: tuple, axis_names: tuple,
                        n_failed: int, *, data_axis: str = "data",
                        keep_global_batch: bool = True) -> RemeshPlan:
    """Shrink the data axis by enough rows to cover ``n_failed`` chips."""
    shape = dict(zip(axis_names, mesh_shape))
    row = 1
    for a, s in shape.items():
        if a != data_axis:
            row *= s
    rows_lost = -(-n_failed // row)              # ceil
    if rows_lost >= shape[data_axis]:
        raise RuntimeError("not enough healthy rows to rebuild the mesh")
    new_shape = dict(shape)
    new_shape[data_axis] = shape[data_axis] - rows_lost
    scale = 1
    if keep_global_batch:
        # keep global batch by extra accumulation (rounded up)
        scale = -(-shape[data_axis] // new_shape[data_axis])
    return RemeshPlan(
        old_shape=tuple(shape[a] for a in axis_names),
        new_shape=tuple(new_shape[a] for a in axis_names),
        axis_names=axis_names,
        lost_devices=n_failed,
        microbatch_scale=scale)


def reshard_tree(tree, cfg, new_mesh: Mesh):
    """Move params (or any tree with param-rule shardings) onto new_mesh."""
    sh = param_shardings(cfg, new_mesh)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, sh)
