"""Execution traces of the Chunks-and-Tasks runtime simulator (DESIGN.md §4).

The scheduler records one :class:`TaskEvent` per executed task.  From the
trace (plus the task graph, whose node ids are topologically ordered — a
task is always registered after its dependencies and its parent) we derive
the schedule-independent quantities the paper's execution-time model rests
on (§5.3, eqs (13)-(14)):

* ``T1``   — total work: the serial execution time of all simulated tasks;
* ``Tinf`` — the critical path: the longest dependency chain, i.e. the
  wall time on infinitely many workers.  The makespan of any greedy
  work-stealing schedule obeys Brent's bound ``max(T1/p, Tinf)`` and is at
  most ``T1/p + Tinf``; the paper's polylog weak-scaling claim is exactly
  "Tinf is O(log^2 N) while T1/p stays constant".

The trace also renders an ASCII Gantt chart (worker occupancy over time)
and serialises to plain dicts for the benchmark JSON files.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["TaskEvent", "Trace", "CriticalPath", "critical_path"]


@dataclasses.dataclass(frozen=True)
class TaskEvent:
    """One executed task: where and when it ran, what it cost."""
    nid: int
    kind: str
    worker: int
    start: float
    end: float
    stolen: bool = False
    remote_bytes: int = 0     # cache-miss bytes fetched for the inputs
    remote_msgs: int = 0
    pushed_bytes: int = 0     # output chunk pushed to a non-local owner

    @property
    def duration(self) -> float:
        return self.end - self.start


@dataclasses.dataclass
class CriticalPath:
    """T1 / Tinf summary of one simulated phase (paper eqs (13)-(14))."""
    work_s: float              # T1: sum of task durations
    length_s: float            # Tinf: longest dependency chain
    path: list[int]            # node ids along the critical chain, root-first
    n_tasks: int

    @property
    def avg_parallelism(self) -> float:
        from repro.core.analysis import avg_parallelism
        return avg_parallelism(self.work_s, self.length_s)

    def brent_bound(self, p: int) -> float:
        """Greedy-schedule lower bound max(T1/p, Tinf)."""
        from repro.core.analysis import brent_bound
        return brent_bound(self.work_s, self.length_s, p)

    def to_dict(self) -> dict:
        return {"work_s": self.work_s, "critical_path_s": self.length_s,
                "avg_parallelism": self.avg_parallelism,
                "n_tasks": self.n_tasks}


class Trace:
    """Ordered record of task executions for one :meth:`Scheduler.run`."""

    def __init__(self, n_workers: int):
        self.n_workers = n_workers
        self.events: list[TaskEvent] = []

    def append(self, ev: TaskEvent) -> None:
        self.events.append(ev)

    def __len__(self) -> int:
        return len(self.events)

    # -- schedule queries ---------------------------------------------------
    def schedule(self) -> dict[int, int]:
        """node id -> worker that executed it."""
        return {ev.nid: ev.worker for ev in self.events}

    def by_worker(self) -> list[list[TaskEvent]]:
        out: list[list[TaskEvent]] = [[] for _ in range(self.n_workers)]
        for ev in self.events:
            out[ev.worker].append(ev)
        return out

    def stolen_tasks(self) -> list[int]:
        return [ev.nid for ev in self.events if ev.stolen]

    def makespan(self) -> float:
        return max((ev.end for ev in self.events), default=0.0)

    # -- rendering / export -------------------------------------------------
    def gantt(self, width: int = 72) -> str:
        """ASCII occupancy chart: one row per worker, ``#`` busy, ``.`` idle.

        Each column is a makespan/width time slice; a slice is busy if any
        task execution overlaps it.  ``*`` marks a slice containing a stolen
        task's execution start.
        """
        span = self.makespan()
        if span <= 0 or not self.events:
            return "(empty trace)"
        rows = [["."] * width for _ in range(self.n_workers)]
        scale = width / span
        for ev in self.events:
            # clamp into [0, width-1]: a zero-duration tail event has
            # start == makespan, which scales to column `width` exactly
            lo = max(0, min(int(ev.start * scale), width - 1))
            hi = max(lo, min(int(ev.end * scale), width - 1))
            for c in range(lo, hi + 1):
                rows[ev.worker][c] = "#"
            if ev.stolen:
                rows[ev.worker][lo] = "*"
        lines = [f"w{w:<3d} |{''.join(r)}|" for w, r in enumerate(rows)]
        lines.append(f"     0{' ' * (width - 10)}{span * 1e3:8.2f} ms")
        return "\n".join(lines)

    def to_dicts(self) -> list[dict]:
        return [dataclasses.asdict(ev) for ev in self.events]


def critical_path(graph, trace: Trace,
                  done_before: Optional[set] = None) -> CriticalPath:
    """T1/Tinf of the traced phase from *actual simulated durations*.

    Precedence edges: resolved data dependencies, and parent -> child (a
    child task only becomes known to the runtime when its parent executes).
    Node ids are registration-ordered, hence topological — one forward pass
    suffices.  Nodes in ``done_before`` (simulated in an earlier phase, e.g.
    the matrix-construction program) contribute zero: the phase starts with
    them already materialised.

    An empty trace — nothing executed this phase, or every node already in
    ``done_before`` — yields the zero :class:`CriticalPath` rather than
    raising.
    """
    if not trace.events:
        return CriticalPath(work_s=0.0, length_s=0.0, path=[], n_tasks=0)
    done_before = done_before or set()
    # a fault-recovery run re-executes lost producers, so a nid can appear
    # twice; only the last execution's chunk survives, so keep the last
    # event per nid (processing order stays completion order, which keeps
    # the finish/pred pass acyclic even across forward alias links)
    last = {ev.nid: i for i, ev in enumerate(trace.events)}
    events = [ev for i, ev in enumerate(trace.events) if last[ev.nid] == i]
    dur: dict[int, float] = {}
    for ev in events:
        dur[ev.nid] = ev.duration
    finish: dict[int, float] = {}
    pred: dict[int, Optional[int]] = {}
    best_nid: Optional[int] = None
    for ev in events:                 # events appended in completion order,
        nid = ev.nid                  # but we walk edges by node id anyway
        node = graph.nodes[nid]
        t0, p0 = 0.0, None
        preds = [d.nid for d in node.deps] + [node.parent]
        for raw in preds:
            dn = graph.resolve(raw) if raw is not None else None
            if dn is None or dn in done_before or dn not in finish:
                continue
            if finish[dn] > t0:
                t0, p0 = finish[dn], dn
        finish[nid] = t0 + dur[nid]
        pred[nid] = p0
        if best_nid is None or finish[nid] > finish[best_nid]:
            best_nid = nid
    path: list[int] = []
    cur = best_nid
    while cur is not None and cur not in path:
        path.append(cur)
        cur = pred[cur]
    path.reverse()
    return CriticalPath(work_s=float(sum(dur.values())),
                        length_s=finish[best_nid],
                        path=path, n_tasks=len(trace.events))
