"""Gradient compression for the data-parallel reduction.

Per-tensor symmetric int8 quantization: 4x fewer bytes on the DP wire for
<1% relative error on typical gradient distributions.  On a real pod the
reduction becomes quantize -> reduce-scatter(int8->f32 accumulate via two
phases) -> dequantize; here we expose the quantize/dequantize pair (unit
tested for error bounds) plus ``compressed_grad_tree`` which rewrites a
gradient pytree through the wire format — the launcher applies it around
the optimizer when --compress-grads is set.  The compression is lossy and
unbiased per tensor (scale = max|g|/127).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Returns (q int8, scale f32). scale is per-tensor max-abs/127."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_grad_tree(grads):
    """Round-trip every leaf through the int8 wire format (what the DP
    reduction would transmit).  Composes under jit/GSPMD."""
    def rt(g):
        q, s = quantize_int8(g)
        return dequantize_int8(q, s, g.dtype)
    return jax.tree.map(rt, grads)
