"""Deterministic synthetic data pipeline.

Produces reproducible token streams without any external corpus:

* tokens are a position/seed hash (stationary, full-vocab coverage) with a
  learnable n-gram structure mixed in so losses actually decrease;
* document boundaries are simulated (documents of geometric length packed
  back-to-back, BOS-separated) — the packing path real pipelines need;
* shard-aware: ``batch_at(step, shard, n_shards)`` yields only this host's
  slice, so multi-host training reads disjoint data without coordination;
* stateless access by step index — restart/elastic-rescale resume exactly
  (fault-tolerance substrate depends on this).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: float = 512.0
    bos: int = 0

    def _doc_tokens(self, doc_id: np.ndarray, offset: np.ndarray
                    ) -> np.ndarray:
        """Deterministic per-document token stream with bigram structure."""
        h = (doc_id.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
             + offset.astype(np.uint64) * np.uint64(0xBF58476D1CE4E5B9)
             + np.uint64(self.seed))
        h ^= h >> np.uint64(31)
        h *= np.uint64(0x94D049BB133111EB)
        h ^= h >> np.uint64(29)
        base = (h % np.uint64(self.vocab)).astype(np.int64)
        # bigram structure: even offsets determine the next token
        nxt = (base * 31 + 17) % self.vocab
        return np.where(offset % 2 == 0, base, nxt).astype(np.int32)

    def sequence(self, seq_id: int) -> np.ndarray:
        """One packed sequence of seq_len + 1 tokens (inputs + shifted)."""
        rng = np.random.default_rng((self.seed << 20) ^ seq_id)
        toks = np.empty(self.seq_len + 1, np.int32)
        pos = 0
        doc = seq_id << 16
        while pos < self.seq_len + 1:
            dlen = 1 + int(rng.geometric(1.0 / self.mean_doc_len))
            dlen = min(dlen, self.seq_len + 1 - pos)
            off = np.arange(dlen)
            toks[pos:pos + dlen] = self._doc_tokens(
                np.full(dlen, doc, np.int64), off)
            toks[pos] = self.bos                     # document boundary
            pos += dlen
            doc += 1
        return toks

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1
                 ) -> dict:
        """{tokens, targets} for this shard at this step (stateless)."""
        assert self.global_batch % n_shards == 0
        per = self.global_batch // n_shards
        seqs = np.stack([
            self.sequence(step * self.global_batch + shard * per + i)
            for i in range(per)])
        return {"tokens": seqs[:, :-1], "targets": seqs[:, 1:]}


def make_batch_specs(cfg, shape, mesh, batch_axes: tuple) -> dict:
    """NamedSharding specs for each batch field (batch dim over data axes)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = NamedSharding(mesh, P(batch_axes))
    from repro.models.config import input_specs
    return input_specs(cfg, shape, batch_sharding=sh)
