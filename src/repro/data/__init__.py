from .pipeline import SyntheticLM, make_batch_specs  # noqa: F401
