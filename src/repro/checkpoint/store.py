"""Sharded checkpoint save/restore with async write and atomic commit.

Layout (one directory per step):

    <dir>/step_000100/
        manifest.json        # tree structure, shapes, dtypes, step
        leaf_00000.npy ...   # one file per pytree leaf (host-gathered)
    <dir>/step_000100.COMMITTED   # marker written last (atomic rename)

Design notes for the 1000+-node target (documented here, exercised at
this repo's scale in tests):

* each leaf is gathered to host and written once — on a real pod slice
  this becomes per-host shard files (process_index suffix) with the same
  manifest/commit protocol; the commit marker is what restart trusts;
* ``CheckpointManager`` writes asynchronously on a worker thread (training
  continues; ``wait()`` joins before the next save), keeps the last
  ``keep`` checkpoints, and ``restore_latest`` ignores uncommitted
  (partially written) directories — crash-during-save is safe;
* restore takes a target sharding tree and ``jax.device_put``s each leaf,
  so a checkpoint saved on one mesh can be restored onto another
  (elastic re-scale path; see runtime/elastic.py).
"""
from __future__ import annotations

import concurrent.futures as futures
import json
import pathlib
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(directory, step: int, tree, *, blocking: bool = True
                    ) -> pathlib.Path:
    """Write a checkpoint; returns the committed path."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    dest = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or dtype == "bfloat16":
            arr = arr.astype(np.float32)   # npy-safe container (exact)
        np.save(tmp / f"leaf_{i:05d}.npy", arr)
        manifest["leaves"].append(
            {"shape": list(arr.shape), "dtype": dtype})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if dest.exists():
        shutil.rmtree(dest)
    tmp.rename(dest)                               # atomic commit
    (directory / f"step_{step:08d}.COMMITTED").touch()
    return dest


def load_checkpoint(directory, step: int, like, *, shardings=None):
    """Restore into the structure of ``like`` (arrays or ShapeDtypeStructs).

    ``shardings``: optional matching tree of NamedShardings — leaves are
    device_put with them (cross-mesh restore)."""
    directory = pathlib.Path(directory)
    src = directory / f"step_{step:08d}"
    if not (directory / f"step_{step:08d}.COMMITTED").exists():
        raise FileNotFoundError(f"checkpoint step {step} not committed")
    manifest = json.loads((src / "manifest.json").read_text())
    leaves, treedef = _flatten(like)
    assert len(leaves) == len(manifest["leaves"]), "tree mismatch"
    sh_leaves = jax.tree_util.tree_leaves(shardings) if shardings \
        else [None] * len(leaves)
    out = []
    for i, (ref, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = np.load(src / f"leaf_{i:05d}.npy")
        ref_shape = tuple(getattr(ref, "shape", np.shape(ref)))
        assert tuple(arr.shape) == ref_shape, \
            f"leaf {i}: {arr.shape} != {ref_shape}"
        dtype = getattr(ref, "dtype", arr.dtype)
        if sh is not None:
            out.append(jax.device_put(arr.astype(dtype), sh))
        else:
            out.append(jax.numpy.asarray(arr.astype(dtype)))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]


def latest_step(directory) -> Optional[int]:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = sorted(int(p.stem.split("_")[1])
                   for p in directory.glob("step_*.COMMITTED"))
    return steps[-1] if steps else None


class CheckpointManager:
    """Async save + retention + latest-restore."""

    def __init__(self, directory, keep: int = 3):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self._pool = futures.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[futures.Future] = None

    def save(self, step: int, tree, blocking: bool = False):
        self.wait()
        # device_get on the caller thread (consistent snapshot), write async
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        if blocking:
            save_checkpoint(self.directory, step, host_tree)
            self._gc()
            return
        self._pending = self._pool.submit(self._save_and_gc, step,
                                          host_tree)

    def _save_and_gc(self, step, host_tree):
        save_checkpoint(self.directory, step, host_tree)
        self._gc()

    def _gc(self):
        steps = sorted(int(p.stem.split("_")[1])
                       for p in self.directory.glob("step_*.COMMITTED"))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.directory / f"step_{s:08d}",
                          ignore_errors=True)
            (self.directory / f"step_{s:08d}.COMMITTED").unlink(
                missing_ok=True)

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def restore_latest(self, like, shardings=None):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return load_checkpoint(self.directory, step, like,
                               shardings=shardings)
