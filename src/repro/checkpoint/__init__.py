from .store import (CheckpointManager, load_checkpoint,  # noqa: F401
                    save_checkpoint)
