"""repro: locality-aware block-sparse matmul in the Chunks and Tasks model.

Public API: the :class:`Session`/:class:`Matrix` facade (``repro.api``) —
operator-overloaded quadtree matrices over one context object.  The
subsystems remain importable directly (``repro.core``, ``repro.runtime``,
``repro.kernels``, ...); the facade is a thin compiler onto them.

Imports are lazy (PEP 562) so ``import repro`` stays cheap and pulling in
a submodule never drags jax into processes that don't need it.
"""

__all__ = ["Session", "Matrix", "Plan", "PlanStructureError",
           "api", "core", "runtime", "serve"]

_SUBPACKAGES = ("api", "core", "runtime", "kernels", "serve")


def __getattr__(name):
    if name in ("Session", "Matrix", "Plan", "PlanStructureError"):
        from repro import api
        return getattr(api, name)
    if name in _SUBPACKAGES:
        import importlib
        return importlib.import_module(f"repro.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
