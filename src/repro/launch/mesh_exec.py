"""Real device-mesh executor for the quadtree multiply (DESIGN.md §7).

:class:`MeshEngine` promotes the simulator's parent-worker placement into
an executing backend: every deferred leaf-engine wave is partitioned over
the devices of a 1-D jax mesh (``launch.mesh.make_spmm_mesh``), operand
blocks move between devices through explicit, *counted* ring collectives
(``jax.lax.ppermute``), and the per-device block GEMMs run as one
``shard_map``-sharded :func:`repro.kernels.ops.batched_gemm` /
:func:`~repro.kernels.ops.bsmm_pairs` dispatch per wave.  The per-device
communication volume reported by :meth:`stats` is therefore *measured
from the shipments actually performed*, not derived from the simulator's
cost model.

Ownership (the paper's parent-worker rendering, §6/Table 1):

* each wave's tasks are split contiguously over the devices in
  registration order (the quadtree's DFS order, which is Morton/locality
  order for the leaves) using the same closed-form balanced split as
  ``core.distributed``;
* a leaf produced by a task lives on the device that ran the task;
* an input leaf is homed on the first device that touches it.

Data movement model per wave:

* **push** — host -> home device upload of an operand block not already
  device-resident at its current ``LeafMatrix._version`` (first touch, or
  stale after a plan rebind refilled the leaf);
* **fetch** — a remote operand block a device needs, shipped from its
  home by a ring shift; counted once per (block, version, device) — a
  re-used resident block costs nothing, which is exactly the locality the
  parent-worker placement is supposed to buy;
* **collective** — the raw padded payload the ring shifts move (SPMD
  shipping is rectangular: every device sends the same padded count per
  shift, so this is an upper envelope of fetch).

What is *not* real here: devices are whatever jax exposes (forced host
devices in CI — ``XLA_FLAGS=--xla_force_host_platform_device_count=8``),
and wave staging/unpacking still round-trips through the host like the
parent :class:`~repro.core.engine.PallasEngine` does.  The sharding,
collectives and per-device counters are real.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.core.engine import PallasEngine, _Pending
from repro.core.leaf import unpack_blocks


class MeshEngine(PallasEngine):
    """Device-sharded leaf backend: ``Session(engine="mesh")``.

    Parameters
    ----------
    n_dev : devices to shard over (default: all visible jax devices).
    kernel : ``"gemm"`` (batched_gemm + segment_sum scatter, the default)
        or ``"pairs"`` (the fused bsmm_pairs gather-GEMM-scatter shape).
    use_pallas / interpret : forwarded to :mod:`repro.kernels.ops`;
        ``None`` auto-selects (Pallas on TPU, XLA reference elsewhere).
    block_t : batch tile of the batched_gemm kernel.

    Inherits the deferral machinery, NIL/structure semantics, host-side
    add/transpose/scale fills and the float32 precision contract of
    :class:`~repro.core.engine.PallasEngine`; only wave *execution* (and
    the communication bookkeeping that comes with it) is replaced.
    """

    name = "mesh"

    def __init__(self, n_dev: Optional[int] = None, kernel: str = "gemm",
                 interpret: Optional[bool] = None,
                 use_pallas: Optional[bool] = None, block_t: int = 8):
        super().__init__(kernel=kernel, interpret=interpret,
                         block_t=block_t)
        self.use_pallas = use_pallas
        self._n_dev_req = n_dev
        self._mesh = None
        self.n_dev = 0                      # resolved at first wave
        # leaf id -> owning device (parent-worker: producer owns)
        self._owner: dict[int, int] = {}
        # per-device residency: slot key (leaf_id, block_key, trans) ->
        # LeafMatrix._version present on that device
        self._resident: list[dict] = []
        # leaf id -> device-side output shard reference (jax.Array) kept
        # so produced blocks stay device-resident between waves;
        # Session.free drops these through free_chunks
        self._dev_out: dict[int, object] = {}
        self._fetched_bytes = np.zeros(0, np.int64)
        self._fetched_blocks = np.zeros(0, np.int64)
        self._pushed_bytes = np.zeros(0, np.int64)
        self._collective_bytes = np.zeros(0, np.int64)
        self._comm_log: list[dict] = []

    # -- mesh ----------------------------------------------------------------
    def _ensure_mesh(self):
        if self._mesh is None:
            import jax

            from .mesh import make_spmm_mesh

            avail = jax.device_count()
            n = self._n_dev_req or avail
            if n > avail:
                raise ValueError(
                    f"MeshEngine: n_dev={n} requested but only {avail} "
                    f"jax devices are visible (force host devices with "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count=N "
                    f"before jax initialises)")
            self.n_dev = n
            self._mesh = make_spmm_mesh(n)
            z = lambda: np.zeros(n, np.int64)
            self._fetched_bytes = z()
            self._fetched_blocks = z()
            self._pushed_bytes = z()
            self._collective_bytes = z()
            self._resident = [dict() for _ in range(n)]
        return self._mesh

    # -- wave execution ------------------------------------------------------
    def _run_group(self, bs: int, tasks: list[_Pending]) -> None:
        """One device-sharded dispatch for every block pair of the wave."""
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.kernels import ops as kops

        mesh = self._ensure_mesh()
        n_dev = self.n_dev
        bsz = bs * bs * 4               # float32 wire format
        t0 = time.perf_counter()
        # per-device counter snapshot: the wave's comm_log entry (and its
        # engine.wave span) carries this wave's deltas, not the running sums
        fetched0 = self._fetched_bytes.copy()
        fblocks0 = self._fetched_blocks.copy()
        pushed0 = self._pushed_bytes.copy()
        coll0 = self._collective_bytes.copy()

        # 1. task ownership: contiguous balanced split in registration
        # (quadtree DFS ~ Morton) order — core.distributed's closed form
        nt = len(tasks)
        owners = ((np.arange(nt, dtype=np.int64) + 1) * n_dev - 1) // nt
        owners = owners.astype(np.int32)

        # 2. operand slots: one per distinct (leaf, key, transpose),
        # homed on the leaf's owning device (producer, else first touch)
        slot_home: dict[tuple, int] = {}
        slot_val: dict[tuple, np.ndarray] = {}
        slot_ver: dict[tuple, int] = {}
        needs: list[dict] = [dict() for _ in range(n_dev)]  # ordered sets
        for t, dev in zip(tasks, owners):
            dev = int(dev)
            self._owner[id(t.out)] = dev
            srcs = {"a": t.a_leaf, "b": t.b_leaf}
            for src_a, ka, tra, src_b, kb, trb, _ in t.pairs:
                for src, kk, tr in ((src_a, ka, tra), (src_b, kb, trb)):
                    leaf = srcs[src]
                    sk = (id(leaf), kk, tr)
                    if sk not in slot_home:
                        home = self._owner.setdefault(id(leaf), dev)
                        slot_home[sk] = home
                        blk = leaf.blocks[kk]
                        slot_val[sk] = np.asarray(
                            blk.T if tr else blk, np.float32)
                        slot_ver[sk] = getattr(leaf, "_version", 0)
                    needs[dev].setdefault(sk)

        # 3. per-device own pools (+ push accounting: host -> home device
        # uploads of blocks not resident at their current version)
        own_keys: list[list] = [[] for _ in range(n_dev)]
        own_pos: dict[tuple, int] = {}
        for sk, h in slot_home.items():
            own_pos[sk] = len(own_keys[h])
            own_keys[h].append(sk)
            if self._resident[h].get(sk) != slot_ver[sk]:
                self._resident[h][sk] = slot_ver[sk]
                self._pushed_bytes[h] += bsz
        cap_own = max(1, max((len(k) for k in own_keys), default=1))
        own_pool = np.zeros((n_dev, cap_own, bs, bs), np.float32)
        for d in range(n_dev):
            for i, sk in enumerate(own_keys[d]):
                own_pool[d, i] = slot_val[sk]

        # 4. shipments grouped by ring shift s = (dst - home) mod n_dev;
        # SPMD tables: per shift every device sends the same padded count
        ship: dict[int, list[list]] = {}    # shift -> per-src slot keys
        fetched_now = 0
        for d in range(n_dev):
            for sk in needs[d]:
                h = slot_home[sk]
                if h == d:
                    continue
                s = (d - h) % n_dev
                ship.setdefault(s, [[] for _ in range(n_dev)])[h].append(sk)
                if self._resident[d].get(sk) != slot_ver[sk]:
                    self._resident[d][sk] = slot_ver[sk]
                    self._fetched_bytes[d] += bsz
                    self._fetched_blocks[d] += 1
                    fetched_now += 1
        shifts = sorted(ship)
        cnts = [max(len(lst) for lst in ship[s]) for s in shifts]
        sels = []
        for s, cnt in zip(shifts, cnts):
            sel = np.zeros((n_dev, cnt), np.int32)
            for src in range(n_dev):
                for i, sk in enumerate(ship[s][src]):
                    sel[src, i] = own_pos[sk]
            sels.append(sel)
        # pool position of slot sk as seen by device d: the own segment,
        # then one recv segment per shift at a static offset
        seg_off = {}
        off = cap_own
        for s, cnt in zip(shifts, cnts):
            seg_off[s] = off
            off += cnt
        pool_len = off

        def pos_on(d: int, sk: tuple) -> int:
            h = slot_home[sk]
            if h == d:
                return own_pos[sk]
            s = (d - h) % n_dev
            return seg_off[s] + ship[s][h].index(sk)

        # 5. per-device pair tables (sa/sb into the halo'd pool, seg into
        # the device-local output slots; cap-padded, seg=cap_c invalid)
        out_base: list[int] = []
        n_out = [0] * n_dev
        for t, dev in zip(tasks, owners):
            out_base.append(n_out[int(dev)])
            n_out[int(dev)] += len(t.out.blocks)
        cap_c = max(1, max(n_out))
        dev_pairs: list[list] = [[] for _ in range(n_dev)]
        n_pairs = 0
        for t, dev, base in zip(tasks, owners, out_base):
            dev = int(dev)
            key_slot = {key: base + i
                        for i, key in enumerate(t.out.blocks)}
            srcs = {"a": t.a_leaf, "b": t.b_leaf}
            for src_a, ka, tra, src_b, kb, trb, out_key in t.pairs:
                dev_pairs[dev].append(
                    (pos_on(dev, (id(srcs[src_a]), ka, tra)),
                     pos_on(dev, (id(srcs[src_b]), kb, trb)),
                     key_slot[out_key]))
                n_pairs += 1
        cap_p = max(1, max(len(p) for p in dev_pairs))
        sa = np.zeros((n_dev, cap_p), np.int32)
        sb = np.zeros((n_dev, cap_p), np.int32)
        seg = np.full((n_dev, cap_p), cap_c, np.int32)
        for d in range(n_dev):
            # ascending output slots (bsmm_pairs accumulation contract;
            # the cap_c padding sorts to the tail)
            for i, (pa, pb, pc) in enumerate(
                    sorted(dev_pairs[d], key=lambda x: x[2])):
                sa[d, i], sb[d, i], seg[d, i] = pa, pb, pc

        # 6. the sharded dispatch: ring-shift the halos, run the kernel
        kernel, use_pallas, interpret, block_t = (
            self.kernel, self.use_pallas, self.interpret, self.block_t)

        def body(own, sa_, sb_, seg_, *sels_):
            own = own[0]
            sa1, sb1, seg1 = sa_[0], sb_[0], seg_[0]
            parts = [own]
            for shift, sel in zip(shifts, sels_):
                send = own[sel[0]]
                perm = [(r, (r + shift) % n_dev) for r in range(n_dev)]
                parts.append(jax.lax.ppermute(send, "dev", perm))
            pool = jnp.concatenate(parts, axis=0) if len(parts) > 1 \
                else parts[0]
            if kernel == "pairs":
                c = kops.bsmm_pairs(pool, pool, sa1, sb1, seg1,
                                    cap_c=cap_c, use_pallas=use_pallas,
                                    interpret=interpret)
            else:
                prods = kops.batched_gemm(pool[sa1], pool[sb1],
                                          block_t=block_t,
                                          use_pallas=use_pallas,
                                          interpret=interpret)
                prods = jnp.where((seg1 < cap_c)[:, None, None], prods, 0)
                c = jax.ops.segment_sum(
                    prods.astype(jnp.float32), jnp.minimum(seg1, cap_c),
                    num_segments=cap_c + 1)[:cap_c]
            return c[None]

        spec = P("dev")
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(spec,) * (4 + len(sels)),
            out_specs=spec, check_rep=False)
        tr = self.tracer
        if tr.enabled and shifts:
            tr.instant("collective.ppermute", track="engine",
                       shifts=len(shifts),
                       shipped_blocks=int(sum(len(lst) for s in shifts
                                              for lst in ship[s])),
                       padded_shipped_blocks=int(sum(cnts) * n_dev))
        with tr.span("kernel.dispatch", track="engine", kernel=kernel,
                     bs=bs, n_dev=n_dev, pairs=int(n_pairs)):
            c_dev = jax.jit(fn)(own_pool, sa, sb, seg, *sels)
            c_np = np.asarray(c_dev)

        # 7. scatter into the placeholder out leaves; produced blocks are
        # now resident on their owner (backed by the retained shard ref)
        for t, dev, base in zip(tasks, owners, out_base):
            dev = int(dev)
            keys = list(t.out.blocks)
            unpack_blocks(t.out, keys, c_np[dev, base:base + len(keys)])
            self._dev_out[id(t.out)] = c_dev
            ver = getattr(t.out, "_version", 0)
            for key in keys:
                self._resident[dev][(id(t.out), key, False)] = ver

        wall = time.perf_counter() - t0
        shipped = sum(len(lst) for s in shifts for lst in ship[s])
        padded_ship = sum(cnts) * n_dev
        self._collective_bytes += sum(cnts) * bsz   # every device receives
        self._waves.append({
            "kernel": kernel, "bs": bs, "tasks": nt, "pairs": int(n_pairs),
            "padded_pairs": int(cap_p * n_dev),
            "unique_blocks": len(slot_home), "c_blocks": int(sum(n_out)),
            "wall_s": wall,
            "bytes_packed": int(own_pool.nbytes + c_np.nbytes),
        })
        self._comm_log.append({
            "bs": bs, "n_dev": n_dev, "tasks": nt, "pairs": int(n_pairs),
            "shifts": len(shifts), "shipped_blocks": int(shipped),
            "padded_shipped_blocks": int(padded_ship),
            "fetched_blocks": int(fetched_now),
            "pool_len": int(pool_len), "cap_c": int(cap_c),
            "wall_s": wall,
            # this wave's measured per-device counter deltas (exported as
            # Perfetto counter tracks; see obs/export.mesh_stats_events)
            "fetched_bytes_by_dev": (self._fetched_bytes - fetched0).tolist(),
            "fetched_blocks_by_dev": (self._fetched_blocks - fblocks0).tolist(),
            "pushed_bytes_by_dev": (self._pushed_bytes - pushed0).tolist(),
            "collective_bytes_by_dev": (self._collective_bytes - coll0).tolist(),
        })

    def _wave_span_attrs(self) -> dict:
        """Wave span attrs: batch shape plus this wave's per-device comm
        deltas (the Table-1 metric, measured)."""
        attrs = super()._wave_span_attrs()
        c = self._comm_log[-1]
        attrs.update({k: c[k] for k in
                      ("n_dev", "shifts", "shipped_blocks",
                       "fetched_bytes_by_dev", "pushed_bytes_by_dev",
                       "collective_bytes_by_dev")})
        return attrs

    # -- lifecycle -----------------------------------------------------------
    def free_chunks(self, g, nids) -> None:
        """Drop ownership, residency and device shard refs of freed leaves."""
        freed: set[int] = set()
        for nid in nids:
            chunk = g.value_of(nid)
            leaf = getattr(chunk, "leaf", None)
            if leaf is not None:
                freed.add(id(leaf))
        if not freed:
            return
        for lid in freed:
            self._owner.pop(lid, None)
            self._dev_out.pop(lid, None)
        for res in self._resident:
            for sk in [sk for sk in res if sk[0] in freed]:
                del res[sk]

    # -- reporting -----------------------------------------------------------
    def stats(self) -> dict:
        out = super().stats()
        out.update({
            "n_dev": self.n_dev,
            "fetched_bytes": self._fetched_bytes.tolist(),
            "fetched_blocks": self._fetched_blocks.tolist(),
            "pushed_bytes": self._pushed_bytes.tolist(),
            "collective_bytes": self._collective_bytes.tolist(),
            "device_blocks": sum(len(r) for r in self._resident),
            "device_leaves": len(self._dev_out),
            "comm_log": list(self._comm_log),
        })
        return out
