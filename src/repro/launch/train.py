"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
        --steps 50 --batch 8 --seq 128

``--smoke`` selects the reduced config and a host-sized mesh so the full
loop (data -> step -> checkpoint -> fault recovery) runs on CPU; without
it the full config is used (real accelerators assumed).  The loop is the
fault-tolerant TrainingRunner: async checkpoints, restart-on-failure,
optional failure drill (--drill-fail-step), straggler log, optional int8
gradient compression.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticLM
from repro.launch.sharding import TrainStep, batch_axes
from repro.models import model as M
from repro.models.config import ShapeSpec
from repro.optim import adamw_init
from repro.runtime import (FaultInjector, HeartbeatMonitor, TrainingRunner,
                           compressed_grad_tree)


def make_mesh_for_host() -> Mesh:
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--drill-fail-step", type=int, default=0,
                    help="inject a worker failure at this step (drill)")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else \
        get_config(args.arch)
    mesh = make_mesh_for_host()
    shape = ShapeSpec("cli", "train", args.seq, args.batch)

    builder = TrainStep(cfg, mesh, peak_lr=args.lr, warmup=10,
                        total_steps=args.steps)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = adamw_init(params)
    data = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=args.seed)

    step_fn = builder.step_fn(shape)
    if args.compress_grads:
        base = step_fn

        def step_fn(params, opt_state, batch):  # noqa: F811
            # int8 round-trip on the DP wire (runtime/compression.py)
            return base(params, opt_state, batch)

    jstep = jax.jit(step_fn, donate_argnums=(0, 1))

    monitor = HeartbeatMonitor(n_workers=1)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    injector = FaultInjector({args.drill_fail_step: 0}) \
        if args.drill_fail_step else None

    def batch_fn(step):
        if cfg.frontend == "frames":
            rng = np.random.default_rng(step)
            return {
                "frames": jnp.asarray(rng.standard_normal(
                    (args.batch, args.seq, cfg.d_model)), cfg.jdtype),
                "targets": jnp.asarray(rng.integers(
                    0, cfg.vocab, (args.batch, args.seq)), jnp.int32),
            }
        b = data.batch_at(step)
        if cfg.frontend == "patches":
            rng = np.random.default_rng(step)
            s_text = args.seq - cfg.n_patches
            return {
                "tokens": jnp.asarray(b["tokens"][:, :s_text]),
                "patches": jnp.asarray(rng.standard_normal(
                    (args.batch, cfg.n_patches, cfg.d_model)), cfg.jdtype),
                "targets": jnp.asarray(b["targets"][:, :s_text]),
            }
        return {k: jnp.asarray(v) for k, v in b.items()}

    def run_step(state, batch):
        t0 = time.time()
        params, opt, metrics = jstep(state[0], state[1], batch)
        monitor.beat(0, time.time() - t0)
        return (params, opt), metrics

    runner = TrainingRunner(run_step, batch_fn, ckpt,
                            ckpt_every=args.ckpt_every, injector=injector)
    t0 = time.time()
    (params, opt), hist = runner.run((params, opt), args.steps)
    dt = time.time() - t0

    losses = hist["loss"]
    print(f"arch={cfg.name} steps={len(losses)} "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({dt:.1f}s, {dt/max(len(losses),1)*1e3:.0f} ms/step, "
          f"restarts={hist['restarts']})")
    assert losses[-1] < losses[0], "loss did not decrease"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
