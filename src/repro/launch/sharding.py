"""Parameter/activation sharding rules and train/serve step builders.

Name-based partition rules (MaxText-style logical axes, simplified):
tensor-parallel over the ``model`` axis for the big projection dims,
batch over ``data`` (+ ``pod`` when multi-pod), optional ZeRO-1 sharding
of optimizer moments over the data axis.

Shardings may be uneven (e.g. llama's 24 q-heads, internvl's odd vocab);
GSPMD pads internally — fine for jit, which is why the model layer uses
jit + sharding rules rather than shard_map.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as M
from repro.models.config import ModelConfig, ShapeSpec, input_specs
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, \
    cosine_schedule

MODEL_AXIS = "model"


def _trailing_rule(cfg: ModelConfig, name: str, shape: tuple
                   ) -> tuple:
    """PartitionSpec entries for the trailing (non-stack) dims of a param."""
    mdl = MODEL_AXIS
    if cfg.n_experts and name in ("w_gate", "w_up", "w_down"):
        # (E, d, ff) / (E, ff, d): expert-parallel when E divides the axis,
        # else shard the ff dim inside every expert
        if name in ("w_gate", "w_up"):
            return (mdl, None, None) if cfg.n_experts % 16 == 0 \
                else (None, None, mdl)
        return (mdl, None, None) if cfg.n_experts % 16 == 0 \
            else (None, mdl, None)
    rules = {
        "embed": (mdl, None),
        "unembed": (mdl, None),
        "patch_proj": (None, None),
        "final_norm": (None,),
        "wq": (None, mdl), "wk": (None, mdl), "wv": (None, mdl),
        "wo": (mdl, None),
        "w_gate": (None, mdl), "w_up": (None, mdl), "w_down": (mdl, None),
        "w1": (None, mdl), "w2": (mdl, None),
        "router": (None, None),
        "in_proj": (None, mdl),
        "out_proj": (mdl, None),
        "x_proj": (mdl, None),
        "dt_proj": (None, mdl),
        "conv": (mdl, None),
        "norm_scale": (mdl,),
        "norm_attn": (None,), "norm_mlp": (None,), "norm_mixer": (None,),
        "dt_bias": (mdl,),
        "D": (mdl,),
    }
    if name == "A_log":
        return (mdl, None) if len(shape) >= 2 and \
            shape[-1] == cfg.ssm_state and cfg.mixer == "mamba1" else (mdl,)
    if name in rules:
        return rules[name]
    return tuple(None for _ in shape)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def _fix_spec(mesh: Mesh, shape: tuple, spec: list) -> list:
    """jit in_shardings require divisibility: move a sharded entry to
    another divisible dim, else drop it (replicate)."""
    spec = list(spec)
    for i, entry in enumerate(spec):
        if entry is None:
            continue
        if shape[i] % _axis_size(mesh, entry) == 0:
            continue
        # prefer trailing dims (hd, ff, ...) as the new home
        for j in range(len(spec) - 1, -1, -1):
            if spec[j] is None and \
                    shape[j] % _axis_size(mesh, entry) == 0 and \
                    shape[j] >= _axis_size(mesh, entry):
                spec[j] = entry
                break
        spec[i] = None
    return spec


def param_spec(cfg: ModelConfig, path: tuple, shape: tuple,
               mesh: Optional[Mesh] = None) -> P:
    name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    trailing = _trailing_rule(cfg, name, shape)
    lead = len(shape) - len(trailing)
    assert lead >= 0, (name, shape, trailing)
    spec = [None] * lead + list(trailing)
    if mesh is not None:
        spec = _fix_spec(mesh, shape, spec)
    return P(*spec)


def param_shardings(cfg: ModelConfig, mesh: Mesh):
    """NamedSharding pytree matching the params tree."""
    abstract = M.abstract_params(cfg)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(cfg, path, leaf.shape, mesh)),
        abstract)


def zero1_shardings(cfg: ModelConfig, mesh: Mesh, data_axes: tuple):
    """ZeRO-1: optimizer moments additionally sharded over the data axes on
    the first dimension the param spec leaves unsharded AND divisible
    (usually the layer stack) — each data replica owns a slice."""
    abstract = M.abstract_params(cfg)
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]

    def spec(path, leaf):
        base = list(param_spec(cfg, path, leaf.shape, mesh))
        for i, (entry, dim) in enumerate(zip(base, leaf.shape)):
            if entry is None and dim % n_data == 0 and dim >= n_data:
                base[i] = data_axes if len(data_axes) > 1 else data_axes[0]
                break
        return NamedSharding(mesh, P(*base))

    return jax.tree_util.tree_map_with_path(spec, abstract)


def batch_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


@dataclasses.dataclass
class TrainStep:
    """Compiled-step builder for one (cfg, mesh) pair.

    * ``microbatch``: gradient-accumulation factor (scan over microbatches)
      — bounds activation memory at B_device/microbatch per pass;
    * gradients are pinned to the ZeRO sharding (data-axis sharded) via
      with_sharding_constraint, so GSPMD emits reduce-scatter instead of
      all-reduce for the DP gradient sync and the f32 gradient/moment
      buffers are 1/|data| per device (ZeRO-1/2 style).
    """
    cfg: ModelConfig
    mesh: Mesh
    zero1: bool = True
    microbatch: int = 0          # 0 = auto
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10000
    clip_norm: float = 1.0

    def auto_microbatch(self, shape: ShapeSpec) -> int:
        """Pick accumulation so activations fit: target <= ~2 GiB of
        layer-input remat buffers per device."""
        if self.microbatch:
            return self.microbatch
        ax = batch_axes(self.mesh)
        n_data = 1
        for a in ax:
            n_data *= self.mesh.shape[a]
        b_dev = max(1, shape.global_batch // n_data)
        cfg = self.cfg
        n_stack = cfg.n_layers
        bytes_per_b = shape.seq_len * cfg.d_model * 2 * n_stack
        budget = 2 * 2 ** 30
        micro = 1
        while b_dev // micro > 1 and (b_dev // micro) * bytes_per_b > budget:
            micro *= 2
        return min(micro, b_dev)

    def param_shardings(self):
        return param_shardings(self.cfg, self.mesh)

    def opt_shardings(self):
        ps = self.param_shardings()
        moments = zero1_shardings(self.cfg, self.mesh,
                                  batch_axes(self.mesh)) if self.zero1 \
            else ps
        from repro.optim.adamw import AdamWState
        return AdamWState(
            step=NamedSharding(self.mesh, P()),
            m=moments, v=jax.tree.map(lambda x: x, moments))

    def batch_shardings(self, shape: ShapeSpec):
        ax = batch_axes(self.mesh)
        sh = NamedSharding(self.mesh, P(ax if len(ax) > 1 else ax[0]))
        return input_specs(self.cfg, shape, batch_sharding=sh)

    def step_fn(self, shape: Optional[ShapeSpec] = None):
        cfg = self.cfg
        micro = self.auto_microbatch(shape) if shape is not None else 1
        if cfg.cost_mode:
            micro = 1      # cost compiles measure one full-batch pass
        grad_sh = zero1_shardings(cfg, self.mesh, batch_axes(self.mesh)) \
            if self.zero1 else self.param_shardings()
        grad_specs = jax.tree.map(lambda s: s.spec, grad_sh)

        def grads_of(params, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: M.loss_fn(cfg, p, batch), has_aux=True)(params)
            # ZeRO: pin grads data-sharded -> GSPMD reduce-scatters the DP
            # gradient sync and the f32 buffers are 1/|data| per device
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, grad_sh)
            return loss, metrics, grads

        def step(params, opt_state, batch):
            if micro <= 1:
                loss, metrics, grads = grads_of(params, batch)
            else:
                def split(x):
                    b = x.shape[0]
                    return x.reshape(micro, b // micro, *x.shape[1:])

                mb = jax.tree.map(split, batch)

                def acc_step(carry, mbatch):
                    g_acc, l_acc = carry
                    loss, _, grads = grads_of(params, mbatch)
                    g_acc = jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32), g_acc,
                        grads)
                    return (g_acc, l_acc + loss), None

                g0 = jax.tree.map(
                    lambda p, s: jax.lax.with_sharding_constraint(
                        jnp.zeros(p.shape, jnp.float32), s),
                    params, grad_sh)
                (grads, loss_sum), _ = jax.lax.scan(
                    acc_step, (g0, jnp.zeros((), jnp.float32)), mb)
                grads = jax.tree.map(lambda g: g / micro, grads)
                loss = loss_sum / micro
                metrics = {}
            grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
            lr = cosine_schedule(opt_state.step, peak_lr=self.peak_lr,
                                 warmup=self.warmup, total=self.total_steps)
            params, opt_state = adamw_update(params, grads, opt_state,
                                             lr=lr)
            metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
            return params, opt_state, metrics

        return step

    def jitted(self, shape: ShapeSpec, donate: bool = True):
        ps = self.param_shardings()
        os = self.opt_shardings()
        bs = self.batch_shardings(shape)
        bsh = jax.tree.map(lambda s: s.sharding, bs)
        return jax.jit(
            self.step_fn(shape),
            in_shardings=(ps, os, bsh),
            out_shardings=(ps, os, None),
            donate_argnums=(0, 1) if donate else (),
        )

    def abstract_inputs(self, shape: ShapeSpec):
        """ShapeDtypeStructs for (params, opt_state, batch) — dry-run."""
        ps = self.param_shardings()
        params = jax.tree.map(
            lambda leaf, sh: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                                  sharding=sh),
            M.abstract_params(self.cfg), ps)
        os_sh = self.opt_shardings()
        from repro.optim.adamw import AdamWState
        opt = AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32,
                                      sharding=os_sh.step),
            m=jax.tree.map(lambda leaf, sh: jax.ShapeDtypeStruct(
                leaf.shape, jnp.float32, sharding=sh),
                M.abstract_params(self.cfg), os_sh.m),
            v=jax.tree.map(lambda leaf, sh: jax.ShapeDtypeStruct(
                leaf.shape, jnp.float32, sharding=sh),
                M.abstract_params(self.cfg), os_sh.v))
        batch = self.batch_shardings(shape)
        return params, opt, batch


@dataclasses.dataclass
class ServeStep:
    """Decode-step builder (one new token against a KV/SSM cache)."""
    cfg: ModelConfig
    mesh: Mesh
    shape: ShapeSpec

    def cache_shardings(self):
        cfg, mesh = self.cfg, self.mesh
        ax = batch_axes(mesh)
        dax = ax if len(ax) > 1 else ax[0]
        b = self.shape.global_batch
        seq_sharded = b == 1        # long_500k: shard the sequence instead

        def spec(name, shape):
            if name in ("k", "v"):
                # (L, B, T, KV, hd); when KV < |model| the fixup moves the
                # model axis onto hd
                if seq_sharded:
                    base = [None, None, dax, MODEL_AXIS, None]
                else:
                    base = [None, dax, None, MODEL_AXIS, None]
            elif name == "conv":
                base = [None] * (len(shape) - 1) + [MODEL_AXIS]
            elif name == "ssm":
                # (L, B, di, N) mamba1 / (G, K, B, nh, hd, N) mamba2
                base = [None] * len(shape)
                base[2 if self.cfg.mixer == "mamba1" else 3] = MODEL_AXIS
            else:
                base = [None] * len(shape)
            return P(*_fix_spec(mesh, shape, base))
        cache = M.init_cache(cfg, b, self.shape.seq_len, abstract=True)
        return {k: NamedSharding(mesh, spec(k, v.shape))
                for k, v in cache.items()}

    def abstract_inputs(self):
        cfg, mesh = self.cfg, self.mesh
        ax = batch_axes(mesh)
        b = self.shape.global_batch
        ps = param_shardings(cfg, mesh)
        params = jax.tree.map(
            lambda leaf, sh: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                                  sharding=sh),
            M.abstract_params(cfg), ps)
        csh = self.cache_shardings()
        cache = {
            k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=csh[k])
            for k, v in M.init_cache(cfg, b, self.shape.seq_len,
                                     abstract=True).items()}
        tok_sh = NamedSharding(
            mesh, P(ax if len(ax) > 1 else ax[0]) if b > 1 else P())
        token = jax.ShapeDtypeStruct((b,), jnp.int32, sharding=tok_sh)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        return params, token, cache, pos

    def step_fn(self):
        cfg = self.cfg

        def step(params, token, cache, pos):
            return M.decode_step(cfg, params, token, cache, pos)

        return step

    def jitted(self, donate: bool = True):
        return jax.jit(self.step_fn(),
                       donate_argnums=(2,) if donate else ())


def make_prefill_fn(cfg: ModelConfig, mesh: Mesh):
    """Full-sequence forward (inference-prefill shape)."""

    def prefill(params, batch):
        logits, _ = M.forward(cfg, params, batch, remat=False)
        return logits

    return jax.jit(prefill)
