"""Collate dry-run JSONs into the EXPERIMENTS.md §Dry-run/§Roofline tables.

    PYTHONPATH=src python -m repro.launch.report experiments/dryrun
"""
from __future__ import annotations

import json
import pathlib
import sys


def load(outdir) -> list[dict]:
    rows = []
    for p in sorted(pathlib.Path(outdir).glob("*.json")):
        rows.append(json.loads(p.read_text()))
    return rows


def fmt_mem(r) -> str:
    m = r["mem"]
    return (f"{m['argument_bytes']/2**30:.2f}+{m['temp_bytes']/2**30:.2f}"
            f"={m['peak_bytes']/2**30:.2f}")


def dryrun_table(rows) -> str:
    out = ["| arch | shape | mesh | compile | mem/dev GiB (args+temps) | "
           "collectives (counts) |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        cc = r.get("collective_counts") or {}
        cs = ", ".join(f"{k.replace('collective-','c-')}:{v}"
                       for k, v in cc.items()) or "-"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['t_compile_s']:.0f}s | {fmt_mem(r)} | {cs} |")
    return "\n".join(out)


def roofline_table(rows) -> str:
    out = ["| arch | shape | t_comp ms | t_mem ms | t_coll ms | bound | "
           "model GF | useful | MFU bound |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != "16x16" or "roofline" not in r:
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{rf['t_compute_s']*1e3:.2f} | {rf['t_memory_s']*1e3:.2f} | "
            f"{rf['t_collective_s']*1e3:.2f} | {rf['bottleneck']} | "
            f"{rf['model_gflops']:.0f} | "
            f"{rf['useful_fraction']*100:.0f}% | "
            f"{rf['mfu_bound']*100:.1f}% |")
    return "\n".join(out)


def main() -> None:
    outdir = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    rows = load(outdir)
    print(f"## Dry-run ({len(rows)} cells)\n")
    print(dryrun_table(rows))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(rows))


if __name__ == "__main__":
    main()
