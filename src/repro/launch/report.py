"""Collate dry-run JSONs into the EXPERIMENTS.md §Dry-run/§Roofline tables.

    PYTHONPATH=src python -m repro.launch.report experiments/dryrun

Passing a file instead of a directory renders it as a summary table:
a ``BENCH_mesh_comm.json`` artifact becomes the measured-communication
table, and any unified-metrics JSON (DESIGN.md §8 schema, as produced
by ``MetricSet.to_dict()`` / ``Plan.profile()``) becomes a per-counter
table.

    PYTHONPATH=src python -m repro.launch.report BENCH_mesh_comm.json
"""
from __future__ import annotations

import json
import pathlib
import sys


def load(outdir) -> list[dict]:
    rows = []
    for p in sorted(pathlib.Path(outdir).glob("*.json")):
        rows.append(json.loads(p.read_text()))
    return rows


def fmt_mem(r) -> str:
    m = r["mem"]
    return (f"{m['argument_bytes']/2**30:.2f}+{m['temp_bytes']/2**30:.2f}"
            f"={m['peak_bytes']/2**30:.2f}")


def dryrun_table(rows) -> str:
    out = ["| arch | shape | mesh | compile | mem/dev GiB (args+temps) | "
           "collectives (counts) |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        cc = r.get("collective_counts") or {}
        cs = ", ".join(f"{k.replace('collective-','c-')}:{v}"
                       for k, v in cc.items()) or "-"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['t_compile_s']:.0f}s | {fmt_mem(r)} | {cs} |")
    return "\n".join(out)


def roofline_table(rows) -> str:
    out = ["| arch | shape | t_comp ms | t_mem ms | t_coll ms | bound | "
           "model GF | useful | MFU bound |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != "16x16" or "roofline" not in r:
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{rf['t_compute_s']*1e3:.2f} | {rf['t_memory_s']*1e3:.2f} | "
            f"{rf['t_collective_s']*1e3:.2f} | {rf['bottleneck']} | "
            f"{rf['model_gflops']:.0f} | "
            f"{rf['useful_fraction']*100:.0f}% | "
            f"{rf['mfu_bound']*100:.1f}% |")
    return "\n".join(out)


def metrics_table(metric_sets) -> str:
    """Unified-metrics schema (DESIGN.md §8) -> one markdown table.

    Accepts ``MetricSet`` objects or their ``to_dict()`` documents; a
    ``Plan.profile()`` document's ``metrics`` list works directly.
    """
    out = ["| source | counter | unit | total | max/worker | workers |",
           "|---|---|---|---|---|---|"]
    for ms in metric_sets:
        doc = ms.to_dict() if hasattr(ms, "to_dict") else ms
        for c in doc["counters"]:
            pw = c["per_worker"]
            out.append(
                f"| {doc['source']} | {c['name']} | {c['unit']} | "
                f"{c['total']:g} | {max(pw):g} | {len(pw)} |")
    return "\n".join(out)


def mesh_comm_table(doc) -> str:
    """BENCH_mesh_comm.json artifact -> measured-communication table."""
    out = ["| scheme | p | N | max fetched B/dev | max collective B/dev "
           "| waves |",
           "|---|---|---|---|---|---|"]
    for r in doc["records"]:
        if r["scheme"] == "mesh":
            out.append(
                f"| mesh | {r['p']} | {r['n']} | "
                f"{r['max_fetched_bytes_per_dev']} | "
                f"{r['max_collective_bytes_per_dev']} | {r['waves']} |")
        else:
            out.append(
                f"| summa | {r['p']} | {r['n']} | - | "
                f"{r['coll_bytes_per_dev']} | pgrid {r['pgrid']} |")
    out.append("")
    out.append(f"mesh fetch growth 2->8 devs: "
               f"{doc['mesh_fetch_growth_2_to_8']:.2f}x "
               f"(flat within 2x: {doc['flat_2_to_8']}); "
               f"SpSUMMA collective growth 4->16 devs: "
               f"{doc['summa_coll_growth_4_to_16']:.2f}x")
    return "\n".join(out)


def serve_table(doc) -> str:
    """BENCH_serve.json artifact -> serving throughput/latency table."""
    out = ["| max_inflight | req/s | p50 ms | p95 ms | p99 ms | hit rate "
           "| merged waves | solo waves |",
           "|---|---|---|---|---|---|---|---|"]
    for r in doc["rows"]:
        out.append(
            f"| {r['max_inflight']} | {r['requests_per_s']:.1f} | "
            f"{r['p50_ms']:.1f} | {r['p95_ms']:.1f} | {r['p99_ms']:.1f} | "
            f"{r['hit_rate']*100:.0f}% | {r['merged_waves']} | "
            f"{r['solo_waves']} |")
    p = doc.get("params", {})
    out.append("")
    out.append(f"{doc['rows'][0]['requests']} requests, "
               f"n={p.get('n')}, {p.get('n_sessions')} sessions; "
               f"results pinned to serial per-plan execution")
    return "\n".join(out)


def fault_table(doc) -> str:
    """BENCH_fault.json artifact -> recovery-policy comparison table."""
    out = ["| pattern | policy | failures | degradation | recomputed "
           "| chunks lost | re-replicated B |",
           "|---|---|---|---|---|---|---|"]
    for r in doc["rows"]:
        out.append(
            f"| {r['pattern']} | {r['policy']} | {r['n_failures']} | "
            f"{r['degradation']:.2f}x | "
            f"{r['tasks_recomputed']}/{r['n_tasks']} | "
            f"{r['chunks_lost']} | {r['bytes_rereplicated']} |")
    p = doc.get("params", {})
    out.append("")
    out.append(f"p={p.get('p')}, replicas={p.get('replicas')}, "
               f"kills at {p.get('kill_at')} of the fault-free makespan; "
               f"every cell's result is bitwise identical to fault-free")
    return "\n".join(out)


def solvers_table(doc) -> str:
    """BENCH_solvers.json artifact -> factorization + chain tables."""
    out = ["| pattern | method | iters | residual | flops | mult tasks "
           "| comm demand B |",
           "|---|---|---|---|---|---|---|"]
    for r in doc["factor_rows"]:
        out.append(
            f"| {r['pattern']} | {r['method']} | {r['iterations']} | "
            f"{r['residual']:.2e} | {r['flops']:.3g} | "
            f"{r['multiply_tasks']} | {r['comm_demand_bytes']} |")
    out.append("")
    out.append("| chain target | accumulated bound | measured error "
               "| flops | pruned flops |")
    out.append("|---|---|---|---|---|")
    for r in doc["chain_rows"]:
        out.append(
            f"| {r['target']:g} | {r['accumulated_bound']:.2e} | "
            f"{r['measured_error']:.2e} | {r['flops']:.3g} | "
            f"{r['pruned_flops']:.3g} |")
    p = doc.get("params", {})
    out.append("")
    out.append(f"n={p.get('n')}, leaf_n={p.get('leaf_n')}, "
               f"bs={p.get('bs')}; every residual matched the dense "
               f"readback, localized touched fewer subtrees than global "
               f"on every pattern, and chain error <= bound <= target")
    return "\n".join(out)


def main() -> None:
    target = pathlib.Path(sys.argv[1] if len(sys.argv) > 1
                          else "experiments/dryrun")
    if target.is_file():
        doc = json.loads(target.read_text())
        if doc.get("bench") == "mesh_comm":
            print(f"## Measured mesh communication ({target.name})\n")
            print(mesh_comm_table(doc))
        elif doc.get("bench") == "serve":
            print(f"## Plan serving ({target.name})\n")
            print(serve_table(doc))
        elif doc.get("bench") == "fault":
            print(f"## Fault recovery ({target.name})\n")
            print(fault_table(doc))
        elif doc.get("bench") == "solvers":
            print(f"## Solver suite ({target.name})\n")
            print(solvers_table(doc))
        elif "counters" in doc:
            print(f"## Metrics ({target.name})\n")
            print(metrics_table([doc]))
        elif "metrics" in doc:       # a Plan.profile() document
            print(f"## Plan profile metrics ({target.name})\n")
            print(metrics_table(doc["metrics"]))
        else:
            sys.exit(f"unrecognized report input: {target}")
        return
    rows = load(target)
    print(f"## Dry-run ({len(rows)} cells)\n")
    print(dryrun_table(rows))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(rows))


if __name__ == "__main__":
    main()
