import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (the XLA flag above is read at first jax
init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/

For each cell this prints/records:
  * compiled.memory_analysis()  — proves the program fits per-device HBM;
  * compiled.cost_analysis()    — FLOPs / bytes for §Roofline;
  * collective bytes parsed from the optimized HLO (per collective kind);
  * the derived roofline terms (§Roofline in EXPERIMENTS.md).

COST CORRECTION: XLA's HLO cost analysis counts a while-loop body ONCE,
but the layer stack runs L times (lax.scan).  Verified empirically (olmo
train: reported flops ~= 1 layer + logits).  The dry-run therefore
compiles the SAME cell at two reduced depths (1 and 2 layer-groups, full
dims otherwise), fits the exact linear model cost(L) = a + b*L, and
reports the extrapolated true per-step cost.  memory_analysis and
compile-success always come from the full-depth compile.
"""
import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ALIASES, ARCH_IDS, get_config
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (ServeStep, TrainStep, batch_axes,
                                   make_prefill_fn, param_shardings)
from repro.models import model as M
from repro.models.config import SHAPES_BY_NAME, applicable_shapes


def model_flops(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode counts one new token."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # decode: 1 token


def lower_cell(cfg, shape, mesh):
    if shape.kind == "train":
        builder = TrainStep(cfg, mesh)
        args = builder.abstract_inputs(shape)
        return jax.jit(
            builder.step_fn(shape),
            in_shardings=jax.tree.map(lambda s: s.sharding, args),
            donate_argnums=(0, 1),
        ).lower(*args)
    if shape.kind == "prefill":
        ps = param_shardings(cfg, mesh)
        params = jax.tree.map(
            lambda leaf, sh: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                                  sharding=sh),
            M.abstract_params(cfg), ps)
        batch = TrainStep(cfg, mesh).batch_shardings(shape)
        return make_prefill_fn(cfg, mesh).lower(params, batch)
    builder = ServeStep(cfg, mesh, shape)
    args = builder.abstract_inputs()
    return builder.jitted().lower(*args)


def _raw_costs(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    coll, counts = RL.collective_bytes(compiled.as_text(), per_op=True)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "hbm": float(ca.get("bytes accessed", 0.0)),
        "coll": float(sum(coll.values())),
        "coll_per_op": coll,
        "coll_counts": counts,
    }


def corrected_costs(cfg, shape, mesh) -> dict:
    """Two-point extrapolation over layer depth (see module docstring)."""
    unit = cfg.attn_every if cfg.family == "hybrid" else 1
    n_groups = cfg.n_layers // unit
    if n_groups <= 2:
        return _raw_costs(lower_cell(cfg, shape, mesh).compile())
    pts = {}
    for g_cnt in (1, 2):
        cfg_k = dataclasses.replace(cfg, n_layers=unit * g_cnt,
                                    cost_mode=True)
        pts[g_cnt] = _raw_costs(lower_cell(cfg_k, shape, mesh).compile())
    out = {}
    for key in ("flops", "hbm", "coll"):
        b = pts[2][key] - pts[1][key]
        a = pts[1][key] - b
        out[key] = a + b * n_groups
    # per-op collective bytes extrapolated the same way
    per_op = {}
    for op in pts[1]["coll_per_op"]:
        b = pts[2]["coll_per_op"][op] - pts[1]["coll_per_op"][op]
        a = pts[1]["coll_per_op"][op] - b
        per_op[op] = max(0, int(a + b * n_groups))
    out["coll_per_op"] = per_op
    out["coll_counts"] = pts[2]["coll_counts"]
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, skip_costs: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
        "mem": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.temp_size_in_bytes),
        },
        "raw": _raw_costs(compiled) if verbose else None,
    }
    if result["raw"]:
        result["raw"].pop("coll_per_op", None)
        result["raw"].pop("coll_counts", None)

    if not skip_costs:
        costs = corrected_costs(cfg, shape, mesh)
        rf = RL.Roofline(flops=costs["flops"], hbm_bytes=costs["hbm"],
                         coll_bytes=costs["coll"], n_chips=n_chips,
                         hw=RL.Hardware(),
                         model_flops=model_flops(cfg, shape))
        result["collectives"] = {k: v for k, v in
                                 costs["coll_per_op"].items() if v}
        result["collective_counts"] = {k: v for k, v in
                                       costs["coll_counts"].items() if v}
        result["roofline"] = rf.row()

    if verbose:
        m = result["mem"]
        print(f"[{arch} x {shape_name} x {result['mesh']}] "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s")
        print(f"  memory/device: args {m['argument_bytes']/2**30:.2f} GiB"
              f" + temps {m['temp_bytes']/2**30:.2f} GiB"
              f" = {m['peak_bytes']/2**30:.2f} GiB  (HBM 16 GiB)")
        if "roofline" in result:
            r = result["roofline"]
            print(f"  roofline: compute {r['t_compute_s']*1e3:.2f} ms | "
                  f"memory {r['t_memory_s']*1e3:.2f} ms | "
                  f"collective {r['t_collective_s']*1e3:.2f} ms  "
                  f"-> {r['bottleneck']}-bound; useful flops "
                  f"{r['useful_fraction']*100:.0f}%, MFU bound "
                  f"{r['mfu_bound']*100:.1f}%")
        sys.stdout.flush()
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="arch id (see repro.configs) or 'all'")
    ap.add_argument("--shape", default=None,
                    help="train_4k|prefill_32k|decode_32k|long_500k|all")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                      "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    archs = list(ARCH_IDS) if (args.all or args.arch in (None, "all")) \
        else [args.arch]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = [s.name for s in applicable_shapes(cfg)] \
            if args.shape in (None, "all") else [args.shape]
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape_name}_{'mp' if mp else 'sp'}"
                if args.skip_existing and (outdir / f"{tag}.json").exists():
                    print(f"[{tag}] skipped (exists)")
                    continue
                try:
                    # roofline table is single-pod; multi-pod proves the
                    # pod axis shards (compile success + memory only)
                    res = run_cell(arch, shape_name, mp, skip_costs=mp)
                    (outdir / f"{tag}.json").write_text(
                        json.dumps(res, indent=1))
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, repr(e)))
                    print(f"[{tag}] FAILED: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} cells failed:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        return 1
    print("\nall cells compiled OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
