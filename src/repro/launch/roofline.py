"""Roofline terms from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

    compute term    = HLO_FLOPs      / (chips * peak_FLOP/s)
    memory term     = HLO_bytes      / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

``cost_analysis()`` supplies FLOPs and bytes accessed.  Collective bytes are
NOT in cost_analysis: :func:`collective_bytes` parses the optimized HLO text
and sums the **result-shape bytes** of every collective op (all-gather,
all-reduce, reduce-scatter, all-to-all, collective-permute; async *-start
variants counted once, *-done skipped).  The result shape is the data
landing on each participating device, which is the per-device traffic the
ICI link must carry up to the O(1) factors noted per-op below:

* collective-permute: result == bytes received (exact);
* reduce-scatter:     result == shard received (exact);
* all-gather:         result == full gathered buffer ~= received * g/(g-1);
* all-reduce:         result == tensor; ring traffic is 2(g-1)/g * size,
                      so the proxy is within 2x (we report the proxy).

Hardware constants default to TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (values from the task brief).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

# bytes per element for HLO dtypes
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one shape, e.g. f32[8,56,8,8]{3,2,1,0:...} or bf16[1024]
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

# HLO instruction: `%name = <result-shape(s)> <opname>(operands...)`
_INSTR_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([a-z0-9\-]+)\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_bytes(shapes: str) -> int:
    """Bytes of the result shape(s) text (may be a tuple)."""
    return sum(_shape_bytes(dt, dims)
               for dt, dims in _SHAPE_RE.findall(shapes))


def collective_bytes(hlo_text: str, per_op: bool = False):
    """Sum result-shape bytes of every collective in optimized HLO text."""
    totals: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    counts: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        shapes, op = m.group(1), m.group(2)
        if op.endswith("-done"):
            continue
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES:
            totals[base] += _result_bytes(shapes)
            counts[base] += 1
    if per_op:
        return totals, counts
    return sum(totals.values())


@dataclasses.dataclass
class Hardware:
    """Per-chip peaks (defaults: TPU v5e from the task brief)."""
    peak_flops: float = 197e12       # bf16 FLOP/s
    hbm_bw: float = 819e9            # B/s
    link_bw: float = 50e9            # B/s per ICI link


@dataclasses.dataclass
class Roofline:
    """Roofline terms.  SPMD modules report PER-DEVICE quantities (verified
    empirically: cost_analysis()['flops'] of an 8-way-sharded matmul equals
    2M^3/8), so flops/bytes here are per device and the terms below divide
    by single-chip peaks.  Equivalently: global_FLOPs / (chips * peak)."""
    flops: float                     # HLO flops per device
    hbm_bytes: float                 # bytes accessed per device
    coll_bytes: float                # collective bytes per device
    n_chips: int
    hw: Hardware
    model_flops: float = 0.0         # 6*N*D-style useful flops (GLOBAL)

    @property
    def t_compute(self) -> float:
        return self.flops / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / self.hw.link_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / global HLO_FLOPs — remat/redundancy waste."""
        total = self.flops * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-flop utilization if execution hits t_bound exactly."""
        if not self.model_flops or self.t_bound == 0:
            return 0.0
        return (self.model_flops
                / (self.n_chips * self.hw.peak_flops * self.t_bound))

    def row(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "dev_gflops": self.flops / 1e9,
            "dev_hbm_gb": self.hbm_bytes / 1e9,
            "dev_coll_gb": self.coll_bytes / 1e9,
            "model_gflops": self.model_flops / 1e9,
            "useful_fraction": self.useful_fraction,
            "mfu_bound": self.mfu_bound,
        }


def from_compiled(compiled, n_chips: int, hw: Optional[Hardware] = None,
                  model_flops: float = 0.0) -> Roofline:
    """Build roofline terms from a jax compiled artifact (SPMD module)."""
    hw = hw or Hardware()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    coll = float(collective_bytes(compiled.as_text()))
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=coll,
                    n_chips=n_chips, hw=hw, model_flops=model_flops)
