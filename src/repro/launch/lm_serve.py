"""LM-decode serving driver: batched prefill + decode loop with KV/SSM caches.

    PYTHONPATH=src python -m repro.launch.lm_serve --arch olmo-1b --smoke \
        --batch 4 --prompt-len 32 --gen 16

Implements the production decode loop shape-for-shape: requests are
padded into a fixed batch, prefill fills the cache via teacher-forced
decode steps (token-by-token; a fused prefill path exists via
M.forward for the prefill_32k shape), then greedy decode.  On the real
mesh the same builders lower to the decode_32k / long_500k cells of the
dry-run.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import model as M


def generate(cfg, params, prompts: np.ndarray, gen: int, max_len: int
             ) -> np.ndarray:
    """prompts: (B, P) int32. Greedy decode ``gen`` tokens."""
    b, plen = prompts.shape
    cache = M.init_cache(cfg, b, max_len)
    step = jax.jit(
        lambda p, t, c, pos: M.decode_step(cfg, p, t, c, pos),
        donate_argnums=(2,))
    out = np.zeros((b, gen), np.int32)
    tok = jnp.asarray(prompts[:, 0])
    logits = None
    for pos in range(plen + gen - 1):
        logits, cache = step(params, tok, cache, jnp.int32(pos))
        if pos + 1 < plen:
            tok = jnp.asarray(prompts[:, pos + 1])      # teacher-forced
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out[:, pos + 1 - plen] = np.asarray(tok)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else \
        get_config(args.arch)
    if cfg.is_encoder_only:
        raise SystemExit("encoder-only arch has no decode step")
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab,
                           (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = generate(cfg, params, prompts, args.gen,
                   args.prompt_len + args.gen)
    dt = time.time() - t0
    tput = args.batch * args.gen / dt
    print(f"arch={cfg.name} batch={args.batch} gen={args.gen} "
          f"-> {tput:.1f} tok/s ({dt:.1f}s)")
    print("sample:", out[0].tolist())
    assert np.isfinite(tput) and (out >= 0).all() and (out < cfg.vocab).all()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
