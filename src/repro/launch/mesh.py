"""Production meshes.

Mesh factories are FUNCTIONS so importing this module never touches jax
device state (device count is locked at first jax init; the dry-run sets
XLA_FLAGS before importing anything that imports jax).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_spmm_mesh(n_dev: int, *, axis: str = "dev"):
    """1-D mesh for the distributed block-sparse matmul engine."""
    return jax.make_mesh((n_dev,), (axis,))


def make_summa_mesh(pgrid: int | None = None):
    """2-D process grid for the SpSUMMA baseline.

    ``pgrid=None`` derives the grid from the visible device count, which
    must then be a perfect square — p=6 used to shard silently onto a
    2x2 sub-grid with two devices idle.  An explicit ``pgrid`` is
    validated against the device count for the same reason.
    """
    from repro.core.spsumma import summa_pgrid

    n_dev = jax.device_count()
    if pgrid is None:
        pgrid = summa_pgrid(n_dev)
    else:
        summa_pgrid(pgrid * pgrid)  # positive-int sanity
        if pgrid * pgrid > n_dev:
            raise ValueError(
                f"make_summa_mesh: pgrid={pgrid} needs {pgrid * pgrid} "
                f"devices but only {n_dev} are visible.")
        if pgrid * pgrid < n_dev:
            raise ValueError(
                f"make_summa_mesh: pgrid={pgrid} uses only "
                f"{pgrid * pgrid} of {n_dev} visible devices — SpSUMMA "
                f"would silently mis-shard. Pass pgrid=None to derive "
                f"the grid (device count must be a perfect square), or "
                f"restrict visible devices.")
    return jax.make_mesh((pgrid, pgrid), ("pr", "pc"))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that shard the batch (pod+data when multi-pod)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
