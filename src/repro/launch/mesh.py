"""Production meshes.

Mesh factories are FUNCTIONS so importing this module never touches jax
device state (device count is locked at first jax init; the dry-run sets
XLA_FLAGS before importing anything that imports jax).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_spmm_mesh(n_dev: int, *, axis: str = "dev"):
    """1-D mesh for the distributed block-sparse matmul engine."""
    return jax.make_mesh((n_dev,), (axis,))


def make_summa_mesh(pgrid: int):
    """2-D process grid for the SpSUMMA baseline."""
    return jax.make_mesh((pgrid, pgrid), ("pr", "pc"))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that shard the batch (pod+data when multi-pod)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
