"""Pure-jnp oracles for every Pallas kernel (the ref side of kernel tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def batched_gemm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """(P, bs, bs) @ (P, bs, bs) -> (P, bs, bs), f32 accumulation."""
    return jnp.einsum("pik,pkj->pij", a, b,
                      preferred_element_type=jnp.float32).astype(a.dtype)


def bsmm_pairs_ref(a_blocks: jax.Array, b_blocks: jax.Array,
                   sa: jax.Array, sb: jax.Array, seg: jax.Array,
                   cap_c: int) -> jax.Array:
    """Gather-GEMM-scatter oracle.

    a_blocks : (capA, bs, bs) packed A blocks
    b_blocks : (capB, bs, bs) packed B blocks
    sa, sb   : (P,) slot ids per pair (invalid pairs may point anywhere)
    seg      : (P,) output slot per pair, ascending; cap_c marks invalid
    returns  : (cap_c, bs, bs) accumulated C blocks
    """
    prods = batched_gemm_ref(a_blocks[sa], b_blocks[sb])
    prods = jnp.where((seg < cap_c)[:, None, None], prods, 0)
    seg = jnp.minimum(seg, cap_c)
    out = jax.ops.segment_sum(prods.astype(jnp.float32), seg,
                              num_segments=cap_c + 1)[:cap_c]
    return out.astype(a_blocks.dtype)


def banded_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         window: int, causal: bool = True) -> jax.Array:
    """Sliding-window attention oracle.

    q, k, v : (H, S, D); window counts key positions attended to the left
    (inclusive of self): position i attends keys in [i-window+1, i]
    (causal) or |i - j| < window (bidirectional).
    """
    h, s, d = q.shape
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(d).astype(q.dtype)
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    band = (qi - kj < window) & (qi - kj > -window)
    mask = band & (kj <= qi) if causal else band
    scores = jnp.where(mask[None], scores.astype(jnp.float32), -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", probs.astype(q.dtype), v)
