"""Jit'd public wrappers around the Pallas kernels with XLA fallbacks.

``use_pallas=None`` (default) selects the Pallas kernel on TPU and the XLA
reference elsewhere; ``interpret=True`` runs the kernel bodies in Python on
CPU (how kernels are validated in this repo's tests).  The contract of each
op is defined by kernels/ref.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .batched_gemm import batched_gemm as _batched_gemm_kernel
from .block_attention import banded_attention as _banded_attention_kernel
from .bsmm_pairs import bsmm_pairs as _bsmm_pairs_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def batched_gemm(a: jax.Array, b: jax.Array, *, block_t: int = 8,
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """C[p] = A[p] @ B[p]; (P, bs, bs) each.

    ``interpret=None`` auto-selects: compiled on TPU, interpret mode on CPU
    (so ``use_pallas=True`` exercises the kernel body everywhere).  The
    kernel zero-pads batches to a multiple of ``block_t`` internally.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return ref.batched_gemm_ref(a, b)
    if interpret is None:
        interpret = not _on_tpu()
    return _batched_gemm_kernel(a, b, block_t=block_t, interpret=interpret)


def bsmm_pairs(a_blocks: jax.Array, b_blocks: jax.Array, sa: jax.Array,
               sb: jax.Array, seg: jax.Array, *, cap_c: int,
               use_pallas: Optional[bool] = None,
               interpret: Optional[bool] = None) -> jax.Array:
    """C[seg[p]] += A[sa[p]] @ B[sb[p]]; seg ascending, cap_c = invalid.

    ``interpret=None`` auto-selects interpret mode off-TPU.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return ref.bsmm_pairs_ref(a_blocks, b_blocks, sa, sb, seg, cap_c)
    if interpret is None:
        interpret = not _on_tpu()
    sa = jnp.clip(sa, 0, a_blocks.shape[0] - 1)
    sb = jnp.clip(sb, 0, b_blocks.shape[0] - 1)
    out = _bsmm_pairs_kernel(a_blocks, b_blocks, sa, sb, seg,
                             cap_c=cap_c, interpret=interpret)
    # C slots that received no pair were never visited by the kernel: zero
    # them explicitly (segment_sum in the ref does this implicitly).
    visited = jnp.zeros((cap_c + 1,), bool).at[jnp.minimum(seg, cap_c)].set(
        True)[:cap_c]
    return jnp.where(visited[:, None, None], out, 0)


def banded_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     window: int, block_q: int = 128, block_kv: int = 128,
                     causal: bool = True,
                     use_pallas: Optional[bool] = None,
                     interpret: bool = False) -> jax.Array:
    """Sliding-window attention, (H, S, D) -> (H, S, D)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return ref.banded_attention_ref(q, k, v, window, causal=causal)
    return _banded_attention_kernel(
        q, k, v, window=window, block_q=block_q, block_kv=block_kv,
        causal=causal, interpret=interpret)
