"""Pallas TPU kernel: batched small-GEMM (the paper's leaf engine, §4.1).

The paper maps block-sparse leaf multiplication onto the cuBLAS *batched*
gemm API because individual 16-64 blocks are too small to fill a GPU.  The
TPU analogue: the MXU is a 128x128 systolic array, so we (a) retune the
default block size toward 128 and (b) tile the batch dimension so each grid
step feeds the MXU a (T*bs, bs) x (bs, bs)-shaped stream of work from VMEM.

BlockSpec layout: each grid step owns a (T, bs, bs) slab of A, B and C in
VMEM.  VMEM budget: 3 * T * bs^2 * 4B; with T=8, bs=128 that is 1.5 MiB —
comfortably inside the ~16 MiB VMEM of a TPU core while leaving room for
double buffering (the pipeline overlaps the HBM->VMEM copy of slab i+1 with
compute on slab i, which is exactly the paper's "overlap data transfers with
computation", §4.2, achieved structurally by the Pallas pipeline).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jax.lax.dot_general(
        a_ref[...], b_ref[...],
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def batched_gemm(a: jax.Array, b: jax.Array, *, block_t: int = 8,
                 interpret: bool = False) -> jax.Array:
    """C[p] = A[p] @ B[p] for p in [0, P).

    a, b : (P, bs, bs); returns (P, bs, bs) in a's dtype.  Batches that do
    not divide by ``block_t`` are zero-padded up to the next multiple (the
    padding feeds the MXU zero work and is sliced off) — shapes are static
    under jit, so the pad is resolved at trace time.
    """
    p, bs, _ = a.shape
    assert a.shape == b.shape and a.shape[1] == a.shape[2]
    if p == 0:
        return a
    pad = (-p) % block_t
    if pad:
        zeros = jnp.zeros((pad, bs, bs), a.dtype)
        a = jnp.concatenate([a, zeros])
        b = jnp.concatenate([b, zeros])
    out = pl.pallas_call(
        _kernel,
        grid=((p + pad) // block_t,),
        in_specs=[
            pl.BlockSpec((block_t, bs, bs), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_t, bs, bs), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, bs, bs), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((p + pad, bs, bs), a.dtype),
        interpret=interpret,
    )(a, b)
    return out[:p]
