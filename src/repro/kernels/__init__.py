"""Pallas TPU kernels for the perf-critical compute of the paper.

batched_gemm   — the leaf engine (paper §4.1 / Table 2)
bsmm_pairs     — fused gather-GEMM-scatter over surviving block pairs
banded_attention — the paper's banded case applied to sliding-window attention
"""
from .ops import banded_attention, batched_gemm, bsmm_pairs  # noqa: F401
