"""Pallas TPU kernel: gather-GEMM-scatter over block pairs (DESIGN.md §3).

This is the fused TPU rendering of steps 2-4 of the block-sparse multiply
(core/bsmm.py): for every surviving (A-slot, B-slot, C-slot) triple, gather
the two bs x bs blocks from the packed HBM arrays, multiply on the MXU, and
accumulate into the C slot.

TPU adaptation of the paper's leaf engine (§4.1): instead of cuBLAS batched
gemm + host-side scatter, we use **scalar prefetch** — the slot-id arrays
arrive in SMEM *before* the kernel body runs, and the BlockSpec index maps
read them to steer the HBM->VMEM DMA of each grid step.  Gather therefore
costs exactly one block DMA per pair (no materialized gathered copy in HBM),
and the Pallas pipeline overlaps pair p+1's DMA with pair p's MXU work —
the paper's §4.2 transfer/compute overlap, structurally.

Accumulation requirement: ``seg`` (output slot per pair) must be sorted
ascending, so all writes to one C block are consecutive grid steps; the
kernel zeroes the VMEM accumulator on first visit (pl.when) and the final
value is flushed to HBM when the output index map moves on.  Invalid /
padding pairs carry seg == cap_c and land in a trailing garbage block that
the wrapper slices off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(sa_ref, sb_ref, seg_ref, a_ref, b_ref, o_ref):
    p = pl.program_id(0)
    seg_here = seg_ref[p]
    seg_prev = seg_ref[jnp.maximum(p - 1, 0)]
    first_visit = jnp.logical_or(p == 0, seg_here != seg_prev)

    @pl.when(first_visit)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    prod = jax.lax.dot_general(
        a_ref[0], b_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)
    o_ref[...] += prod[None]


@functools.partial(jax.jit, static_argnames=("cap_c", "interpret"))
def bsmm_pairs(a_blocks: jax.Array, b_blocks: jax.Array,
               sa: jax.Array, sb: jax.Array, seg: jax.Array, *,
               cap_c: int, interpret: bool = False) -> jax.Array:
    """Accumulate C[seg[p]] += A[sa[p]] @ B[sb[p]] over all pairs.

    a_blocks : (capA, bs, bs); b_blocks : (capB, bs, bs)
    sa, sb   : (P,) int32 slot ids (clamped to valid range by caller)
    seg      : (P,) int32 ascending; cap_c marks invalid pairs
    returns  : (cap_c, bs, bs) accumulated C blocks (a_blocks.dtype)
    """
    (p_cnt,) = sa.shape
    bs = a_blocks.shape[1]
    if p_cnt == 0:     # static under jit: no pairs -> all-zero C
        return jnp.zeros((cap_c, bs, bs), a_blocks.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(p_cnt,),
        in_specs=[
            pl.BlockSpec((1, bs, bs),
                         lambda p, sa_r, sb_r, seg_r: (sa_r[p], 0, 0)),
            pl.BlockSpec((1, bs, bs),
                         lambda p, sa_r, sb_r, seg_r: (sb_r[p], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, bs),
                               lambda p, sa_r, sb_r, seg_r: (seg_r[p], 0, 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((cap_c + 1, bs, bs), a_blocks.dtype),
        interpret=interpret,
    )(sa, sb, jnp.minimum(seg, cap_c), a_blocks, b_blocks)
    return out[:cap_c]
