"""Batched dense triangular ops for the solver leaf payloads.

The solver task programs (:mod:`repro.core.triangular`) bottom out in two
leaf payload kinds — ``inv_chol`` (Z = U^{-1} for S = U^T U, the leaf
inverse Cholesky) and ``tri_solve`` (X = R^{-1} B with R upper
triangular).  The deferred Pallas engine batches every ready solve leaf
of one shape into a single call here, exactly like GEMM waves batch
through :func:`repro.kernels.ops.batched_gemm`.

Unlike the GEMM path there is no hand-written Pallas kernel body:
``cholesky`` and ``triangular_solve`` are XLA-native primitives with
accelerator lowerings (MXU-backed on TPU), so the batched wrappers here
*are* the accelerator path — a custom kernel would only re-derive what
XLA already emits for these small fixed-size factorizations.  The
``use_pallas``/``interpret`` keywords are accepted for signature parity
with :mod:`repro.kernels.ops` and ignored.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["batched_inv_chol", "batched_tri_solve", "batched_tri_inv"]


@partial(jax.jit, static_argnames=("lower",))
def _tri_inv(r: jax.Array, lower: bool) -> jax.Array:
    eye = jnp.broadcast_to(jnp.eye(r.shape[-1], dtype=r.dtype), r.shape)
    return jax.lax.linalg.triangular_solve(
        r, eye, left_side=True, lower=lower)


def batched_tri_inv(r: jax.Array, *, lower: bool = False,
                    use_pallas: Optional[bool] = None,
                    interpret: Optional[bool] = None) -> jax.Array:
    """inv(R[p]) for a stack of triangular matrices; (P, n, n) -> same."""
    del use_pallas, interpret
    return _tri_inv(r, lower)


@jax.jit
def _inv_chol(s: jax.Array) -> jax.Array:
    l = jnp.linalg.cholesky(s)          # S = L L^T, L lower
    u = jnp.swapaxes(l, -1, -2)         # S = U^T U, U upper
    return _tri_inv(u, False)


def batched_inv_chol(s: jax.Array, *,
                     use_pallas: Optional[bool] = None,
                     interpret: Optional[bool] = None) -> jax.Array:
    """Z[p] = inv(chol_upper(S[p])): upper triangular, Z^T S Z = I.

    ``S`` is a (P, n, n) stack of dense symmetric positive-definite
    leaves (full storage — callers expand symmetric upper storage first).
    """
    del use_pallas, interpret
    return _inv_chol(s)


@partial(jax.jit, static_argnames=("lower",))
def _tri_solve(r: jax.Array, b: jax.Array, lower: bool) -> jax.Array:
    return jax.lax.linalg.triangular_solve(
        r, b, left_side=True, lower=lower)


def batched_tri_solve(r: jax.Array, b: jax.Array, *, lower: bool = False,
                      use_pallas: Optional[bool] = None,
                      interpret: Optional[bool] = None) -> jax.Array:
    """X[p] = inv(R[p]) @ B[p] with R triangular; both (P, n, n)."""
    del use_pallas, interpret
    return _tri_solve(r, b, lower)
