"""Pallas TPU kernel: banded (sliding-window) block-sparse flash attention.

The paper's banded-matrix case (§5.1, eq (8)) applied to attention: a
sliding window of W key positions makes the (query x key) score matrix a
banded block-sparse matrix, so the quadtree/locality analysis transfers —
each query block touches only W/BQ + 1 key blocks, independent of sequence
length, giving the O(N) total work of eq (11) instead of O(N^2).

Implementation is a flash-style online-softmax kernel:
  grid = (heads, S/BQ, W/BKV + 1); the third axis walks the band.
  The k/v BlockSpec index maps clamp out-of-range band positions to block 0
  and the in-kernel mask kills their contribution.
  Running max/denominator/accumulator live in VMEM scratch; output is
  flushed on the band's last step.

VMEM budget per step: q,k,v,o slabs (4 * BQ * D * 4B) + scratch
(BQ * (D + 2) * 4B); BQ = BKV = 128, D = 128 -> ~0.3 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            n_kv_blocks: int, left: int, n_blocks: int, block_q: int,
            block_kv: int, window: int, causal: bool):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    jk_abs = iq - left + jk                  # absolute kv block index
    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = jk_abs * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = ((jk_abs >= 0) & (jk_abs < n_blocks)
            & (qpos - kpos < window) & (kpos - qpos < window))
    if causal:
        mask = mask & (kpos <= qpos)
    s = jnp.where(mask, s, _NEG)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    l_new = alpha * l_prev + p.sum(axis=-1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(jk == n_kv_blocks - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / (l_ref[...] + 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "block_q", "block_kv", "causal", "interpret"))
def banded_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     window: int, block_q: int = 128, block_kv: int = 128,
                     causal: bool = True, interpret: bool = False
                     ) -> jax.Array:
    """Sliding-window attention; q, k, v: (H, S, D) -> (H, S, D).

    ``window`` counts positions to each side (causal keeps the left side
    only), matching kernels/ref.py::banded_attention_ref.
    """
    h, s, d = q.shape
    assert block_q == block_kv, "kernel assumes square q/kv blocks"
    assert s % block_q == 0 and s % block_kv == 0
    assert window % block_kv == 0, "window must be a multiple of block_kv"
    left = window // block_kv
    n_kv_blocks = left + 1 if causal else 2 * left + 1
    n_blocks = s // block_kv

    kernel = functools.partial(
        _kernel, n_kv_blocks=n_kv_blocks, left=left, n_blocks=n_blocks,
        block_q=block_q, block_kv=block_kv, window=window, causal=causal)

    def kv_index(hh, iq, jk):
        jk_abs = iq - left + jk
        return (hh, jnp.clip(jk_abs, 0, n_blocks - 1), 0)

    return pl.pallas_call(
        kernel,
        grid=(h, s // block_q, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda hh, iq, jk: (hh, iq, 0)),
            pl.BlockSpec((1, block_kv, d), kv_index),
            pl.BlockSpec((1, block_kv, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda hh, iq, jk: (hh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
