"""State-space mixers: Mamba1 (falcon-mamba) and Mamba2/SSD (zamba2).

Hardware adaptation (DESIGN.md §3): the CUDA selective-scan kernel becomes a
**chunked scan** — an outer ``lax.scan`` over sequence chunks carrying the
SSM state, with a parallel associative combine *inside* each chunk.  The
per-timestep state tensor (B, d_inner, N) is materialized only within one
chunk (decay/drive are built inside the chunk body from the small per-token
projections), so activation memory is O(chunk * d_inner * N) rather than
O(S * d_inner * N); states are checkpointed at chunk boundaries for the
backward pass.  This is the TPU-idiomatic equivalent of the recurrence.

Both mixers expose:
  * ``*_forward``  — full-sequence training/prefill path;
  * ``*_step``     — single-token decode with explicit carried state
    (O(1) per token; this is why long_500k decode runs for SSM archs).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def causal_conv1d(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, C), w: (C, K) -> (B, S, C)."""
    k = w.shape[-1]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros(x.shape, jnp.float32)
    for j in range(k):
        out = out + pad[:, j:j + x.shape[1], :].astype(jnp.float32) * \
            w[:, j].astype(jnp.float32)
    return out.astype(x.dtype)


def conv_step(x_new: jax.Array, conv_state: jax.Array, w: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """Decode-time depthwise conv: x_new (B, C), conv_state (B, K-1, C)."""
    k = w.shape[-1]
    window = jnp.concatenate([conv_state, x_new[:, None, :]], axis=1)
    out = jnp.einsum("bkc,ck->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32)).astype(x_new.dtype)
    return out, window[:, 1:k, :]


def _assoc_combine(x, y):
    ax, bx = x
    ay, by = y
    return ax * ay, bx * ay + by


# ---------------------------------------------------------------------------
# Mamba1 (selective SSM) — falcon-mamba-7b
# params (per layer): in_proj (d, 2*di), conv (di, K), x_proj
# (di, dt_rank + 2*state), dt_proj (dt_rank, di) + dt_bias (di,),
# A_log (di, state), D (di,), out_proj (di, d)
# ---------------------------------------------------------------------------

class MambaState(NamedTuple):
    conv: jax.Array      # (B, K-1, di)
    ssm: jax.Array       # (B, di, state)


def mamba1_forward(p: dict, u: jax.Array, *, state: int,
                   chunk: int = 256, unroll: bool = False) -> jax.Array:
    """u: (B, S, d) -> (B, S, d)."""
    bsz, s, _ = u.shape
    xz = u @ p["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)                     # (B, S, di)
    x = causal_conv1d(x, p["conv"])
    x = jax.nn.silu(x)
    proj = x @ p["x_proj"]                               # (B,S,dtr+2N)
    dt_rank = p["dt_proj"].shape[0]
    dt, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + state], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])  # (B,S,di)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))         # (di, N)
    di = x.shape[-1]

    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    def to_chunks(t):   # (B, S, ...) -> (nc, B, chunk, ...)
        return t.reshape(bsz, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs = (to_chunks(dt), to_chunks(bmat), to_chunks(cmat), to_chunks(x))

    def outer(h, inp):
        dt_i, b_i, c_i, x_i = inp               # (B, chunk, ...)
        decay = jnp.exp(dt_i[..., None].astype(jnp.float32) * a)
        drive = (dt_i[..., None] * b_i[:, :, None, :] *
                 x_i[..., None]).astype(jnp.float32)     # (B,C,di,N)
        aa, bb = jax.lax.associative_scan(_assoc_combine, (decay, drive),
                                          axis=1)
        h_all = aa * h[:, None] + bb
        y_i = jnp.einsum("bsdn,bsn->bsd", h_all,
                         c_i.astype(jnp.float32))
        return h_all[:, -1], y_i

    h0 = jnp.zeros((bsz, di, state), jnp.float32)
    _, y_chunks = jax.lax.scan(outer, h0, xs, unroll=True if unroll else 1)
    y = y_chunks.swapaxes(0, 1).reshape(bsz, s, di).astype(u.dtype)
    y = y + x * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba1_step(p: dict, u_t: jax.Array, st: MambaState, *, state: int
                ) -> tuple[jax.Array, MambaState]:
    """u_t: (B, d) one token -> (y_t, new state). O(1) in sequence length."""
    xz = u_t @ p["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)                     # (B, di)
    x, conv_new = conv_step(x, st.conv, p["conv"])
    x = jax.nn.silu(x)
    proj = x @ p["x_proj"]
    dt_rank = p["dt_proj"].shape[0]
    dt, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + state], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt[..., None].astype(jnp.float32) * a)   # (B,di,N)
    drive = (dt[..., None] * bmat[:, None, :] * x[..., None]).astype(
        jnp.float32)
    h = decay * st.ssm + drive
    y = jnp.einsum("bdn,bn->bd", h, cmat.astype(jnp.float32)).astype(
        u_t.dtype)
    y = y + x * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], MambaState(conv_new, h)


# ---------------------------------------------------------------------------
# Mamba2 (SSD, scalar decay per head) — zamba2
# params: in_proj (d, 2*di + 2*state + nh), conv ((di + 2*state), K),
# A_log (nh,), D (nh,), dt_bias (nh,), norm_scale (di,), out_proj (di, d)
# ---------------------------------------------------------------------------

class Mamba2State(NamedTuple):
    conv: jax.Array      # (B, K-1, di + 2N)
    ssm: jax.Array       # (B, nh, hd, N)


def mamba2_forward(p: dict, u: jax.Array, *, state: int, head_dim: int,
                   chunk: int = 128, unroll: bool = False) -> jax.Array:
    bsz, s, _ = u.shape
    di = p["out_proj"].shape[0]
    nh = di // head_dim
    zxbcdt = u @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * state], axis=-1)
    xbc = jax.nn.silu(causal_conv1d(xbc, p["conv"]))
    x, bmat, cmat = jnp.split(xbc, [di, di + state], axis=-1)
    dt = jax.nn.softplus(dt + p["dt_bias"])              # (B, S, nh)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))         # (nh,)
    xh = x.reshape(bsz, s, nh, head_dim)

    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    def to_chunks(t):
        return t.reshape(bsz, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs = (to_chunks(dt), to_chunks(bmat), to_chunks(cmat), to_chunks(xh))

    def outer(h, inp):
        dt_i, b_i, c_i, x_i = inp
        cdt = x_i.dtype
        # SSD intra-chunk attention form: scalar decay per head.
        # §Perf iteration (zamba2 train_4k): the (B, C, C, nh) decay matrix
        # and its einsums ran in f32 (~19 s memory term); exp(gap) is in
        # (0, 1] and C.B products are O(1), so bf16 carries them safely —
        # accumulation stays f32 via preferred_element_type.
        logdec = dt_i.astype(jnp.float32) * a            # (B,C,nh) (<0)
        ell = jnp.cumsum(logdec, axis=1)                 # (B,C,nh)
        # M[t,tau] = exp(ell_t - ell_tau) * (C_t . B_tau), tau <= t
        cb = jnp.einsum("btn,bsn->bts", c_i, b_i,
                        preferred_element_type=jnp.float32)  # (B,C,C)
        # iteration 2: build the (B,t,s,nh) tensors in bf16 END-TO-END —
        # casting after a f32 exp still materializes the f32 intermediate
        # (measured: no change in bytes accessed); exp in bf16 with the
        # f32 cumsum ell keeps relative error ~1e-2 on (0,1] decays
        ell_c = ell.astype(cdt)
        gap = ell_c[:, :, None, :] - ell_c[:, None, :, :]  # (B,t,s,nh) bf16
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        m = (jnp.where(tri[None, :, :, None], jnp.exp(gap),
                       jnp.zeros((), cdt))
             * cb[..., None].astype(cdt))               # (B,t,s,nh)
        dx = (dt_i[..., None] * x_i.astype(jnp.float32)).astype(cdt)
        y_intra = jnp.einsum("btsh,bshp->bthp", m, dx,
                             preferred_element_type=jnp.float32)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bhpn,btn,bth->bthp", h,
                             c_i.astype(jnp.float32), jnp.exp(ell))
        # new carried state
        w = jnp.exp(ell[:, -1:, :] - ell).astype(cdt)    # decay to chunk end
        h_new = h * jnp.exp(ell[:, -1])[:, :, None, None] + jnp.einsum(
            "bth,bthp,btn->bhpn", w, dx, b_i.astype(cdt),
            preferred_element_type=jnp.float32)
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((bsz, nh, head_dim, state), jnp.float32)
    _, y_chunks = jax.lax.scan(outer, h0, xs, unroll=True if unroll else 1)
    y = y_chunks.swapaxes(0, 1).reshape(bsz, s, nh, head_dim)
    y = y + xh.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(bsz, s, di).astype(u.dtype)
    y = y * jax.nn.silu(z)
    # grouped RMSNorm before out-projection (mamba2 uses it)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5) *
         (1.0 + p["norm_scale"])).astype(u.dtype)
    return y @ p["out_proj"]


def mamba2_step(p: dict, u_t: jax.Array, st: Mamba2State, *, state: int,
                head_dim: int) -> tuple[jax.Array, Mamba2State]:
    bsz = u_t.shape[0]
    di = p["out_proj"].shape[0]
    nh = di // head_dim
    zxbcdt = u_t @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * state], axis=-1)
    xbc, conv_new = conv_step(xbc, st.conv, p["conv"])
    xbc = jax.nn.silu(xbc)
    x, bmat, cmat = jnp.split(xbc, [di, di + state], axis=-1)
    dt = jax.nn.softplus(dt + p["dt_bias"])              # (B, nh)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = x.reshape(bsz, nh, head_dim)
    decay = jnp.exp(dt.astype(jnp.float32) * a)          # (B, nh)
    drive = (dt[..., None, None] * xh[..., None] *
             bmat[:, None, None, :]).astype(jnp.float32)
    h = decay[..., None, None] * st.ssm + drive
    y = jnp.einsum("bhpn,bn->bhp", h, cmat.astype(jnp.float32))
    y = y + xh.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(bsz, di).astype(u_t.dtype)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5) *
         (1.0 + p["norm_scale"])).astype(u_t.dtype)
    return y @ p["out_proj"], Mamba2State(conv_new, h)
