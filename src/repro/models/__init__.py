from .config import (ModelConfig, ShapeSpec, ALL_SHAPES, SHAPES_BY_NAME,
                     applicable_shapes, input_specs)  # noqa: F401
