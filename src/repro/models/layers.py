"""Shared model layers: norms, RoPE, attention variants, MLP, MoE.

Attention comes in three production paths:

* ``chunked_attention`` — full (causal or bidirectional) attention computed
  blockwise with an online softmax (lax.scan over kv chunks).  Peak
  activation memory O(S * q_chunk) per head instead of O(S^2); this is the
  XLA-native flash pattern used for train/prefill shapes.
* ``windowed_attention`` — the paper's *banded block-sparse* case: each
  query block gathers only the W/BQ + 1 key blocks inside the sliding
  window, total work O(S * W) (eq (11)'s locality win applied to
  attention).  kernels/block_attention.py is the Pallas twin.
* ``decode_attention`` — single-position attention against a KV cache.

All functions are batched with vmap at the call site where needed and keep
f32 softmax numerics regardless of activation dtype.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

_NEG = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: Optional[jax.Array], eps: float = 1e-5
             ) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        out = out * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def nonparam_layer_norm(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo's non-parametric LayerNorm: no scale, no bias."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def apply_norm(kind: str, x: jax.Array, scale: Optional[jax.Array]
               ) -> jax.Array:
    if kind == "nonparam_ln":
        return nonparam_layer_norm(x)
    return rms_norm(x, scale)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float
               ) -> jax.Array:
    """x: (..., S, n_heads, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # head axis
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention variants
# ---------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q: (B, S, KV, G, hd), k: (B, T, KV, hd) -> (B, KV, G, S, T)."""
    return jnp.einsum("bsvgh,btvh->bvgst", q, k,
                      preferred_element_type=jnp.float32)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, q_chunk: int = 512,
                      kv_chunk: int = 1024,
                      unroll: bool = False) -> jax.Array:
    """Flash-pattern full attention.

    q: (B, S, KV, G, hd); k, v: (B, S, KV, hd).  Returns (B, S, KV, G, hd).
    Memory per step: O(q_chunk * kv_chunk) scores per (KV, G).
    """
    b, s, kvh, g, hd = q.shape
    t = k.shape[1]
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, t)
    assert s % q_chunk == 0 and t % kv_chunk == 0
    nq, nk = s // q_chunk, t // kv_chunk
    scale = 1.0 / (hd ** 0.5)

    qs = q.reshape(b, nq, q_chunk, kvh, g, hd)
    ks = k.reshape(b, nk, kv_chunk, kvh, hd)
    vs = v.reshape(b, nk, kv_chunk, kvh, hd)

    def q_step(_, iq):
        qi = qs[:, iq] * scale        # (B, qc, KV, G, hd)

        def kv_step(carry, jk):
            m, l, acc = carry
            kj = ks[:, jk]
            vj = vs[:, jk]
            s_ij = jnp.einsum("bqvgh,bkvh->bvgqk", qi, kj,
                              preferred_element_type=jnp.float32)
            if causal:
                qpos = iq * q_chunk + jnp.arange(q_chunk)[:, None]
                kpos = jk * kv_chunk + jnp.arange(kv_chunk)[None, :]
                s_ij = jnp.where(qpos >= kpos, s_ij, _NEG)
            m_new = jnp.maximum(m, s_ij.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s_ij - m_new[..., None])
            l_new = alpha * l + p.sum(-1)
            acc_new = alpha[..., None] * acc + jnp.einsum(
                "bvgqk,bkvh->bvgqh", p, vj.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, q_chunk), _NEG, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(nk), unroll=unroll)
        out = acc / (l[..., None] + 1e-30)       # (B, KV, G, qc, hd)
        return None, out.transpose(0, 3, 1, 2, 4).astype(q.dtype)

    _, chunks = jax.lax.scan(q_step, None, jnp.arange(nq), unroll=unroll)
    # chunks: (nq, B, qc, KV, G, hd)
    return chunks.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, kvh, g, hd)


def windowed_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       window: int, causal: bool = True,
                       block: int = 512) -> jax.Array:
    """Banded block-sparse attention (paper's banded case, §5.1).

    Each query block attends to the ``window // block (+1)`` key blocks
    inside the band — O(S * W) work/memory, sequence-length independent per
    block.  q: (B, S, KV, G, hd); k, v: (B, S, KV, hd).
    """
    b, s, kvh, g, hd = q.shape
    block = min(block, s)
    assert s % block == 0 and window % block == 0
    nb = s // block
    wb = window // block                        # full blocks to the left
    nwin = wb + 1 if causal else 2 * wb + 1
    scale = 1.0 / (hd ** 0.5)

    qs = q.reshape(b, nb, block, kvh, g, hd) * scale
    ks = k.reshape(b, nb, block, kvh, hd)
    vs = v.reshape(b, nb, block, kvh, hd)

    # gather the window of key blocks for every query block
    iq = jnp.arange(nb)[:, None]
    off = jnp.arange(nwin)[None, :] - wb        # [-wb .. 0 (.. +wb)]
    jk = iq + off                               # (nb, nwin)
    valid_blk = (jk >= 0) & (jk < nb)
    jk_c = jnp.clip(jk, 0, nb - 1)
    k_win = ks[:, jk_c]                         # (B, nb, nwin, block, KV, hd)
    v_win = vs[:, jk_c]

    s_ij = jnp.einsum("bnqvgh,bnwkvh->bnvgqwk", qs, k_win,
                      preferred_element_type=jnp.float32)
    # element positions, broadcast to (nb, nwin, q, k)
    qp = ((iq * block)[:, None] + jnp.arange(block)[None, :]) \
        .reshape(nb, 1, block, 1)
    kp = ((jk_c * block)[:, :, None] + jnp.arange(block)[None, None, :]) \
        .reshape(nb, nwin, 1, block)
    band = (qp - kp < window) & (kp - qp < window) & \
        valid_blk.reshape(nb, nwin, 1, 1)
    if causal:
        band = band & (kp <= qp)
    mask = band.transpose(0, 2, 1, 3)           # (nb, q, nwin, k)
    s_ij = jnp.where(mask[None, :, None, None], s_ij, _NEG)
    s_flat = s_ij.reshape(*s_ij.shape[:5], nwin * block)
    p = jax.nn.softmax(s_flat, axis=-1).reshape(s_ij.shape)
    out = jnp.einsum("bnvgqwk,bnwkvh->bnqvgh", p.astype(q.dtype), v_win)
    return out.reshape(b, s, kvh, g, hd)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window: int = 0) -> jax.Array:
    """One-token attention against a cache.

    q: (B, 1, KV, G, hd); caches: (B, T, KV, hd); pos: () current length.
    window > 0 restricts to the last ``window`` positions (SWA decode).
    """
    b, _, kvh, g, hd = q.shape
    t = k_cache.shape[1]
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bovgh,btvh->bvgt", q * scale, k_cache,
                   preferred_element_type=jnp.float32)
    idx = jnp.arange(t)
    valid = idx[None, :] <= pos
    if window:
        valid = valid & (idx[None, :] > pos - window)
    s = jnp.where(valid[:, None, None, :] if valid.ndim == 2
                  else valid, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bvgt,btvh->bvgh", p.astype(q.dtype), v_cache)
    return out[:, None].transpose(0, 1, 2, 3, 4).reshape(b, 1, kvh, g, hd)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x, w1, w2):
    return jax.nn.gelu(x @ w1) @ w2


_MOE_RESHARD_AXIS = [None]


def _moe_reshard_axis():
    return _MOE_RESHARD_AXIS[0]


def set_moe_reshard_axis(axis):
    """Launcher hook: reshard MoE hidden activations onto ``axis`` before
    the down-projection (requires an ambient mesh during tracing)."""
    _MOE_RESHARD_AXIS[0] = axis


# ---------------------------------------------------------------------------
# Mixture of Experts — capacity-bounded gather-GEMM-scatter dispatch.
# The same static-capacity pattern as core/bsmm.py: expert assignment is the
# dynamic block occupancy; tokens are gathered per expert, multiplied as one
# batched einsum over the stacked expert weights, and scattered back.
# ---------------------------------------------------------------------------

def moe_ffn_batched(x: jax.Array, router_w: jax.Array, w_gate: jax.Array,
                    w_up: jax.Array, w_down: jax.Array, *, top_k: int,
                    capacity_factor: float = 1.25
                    ) -> tuple[jax.Array, jax.Array]:
    """Per-batch-row dispatch: x (B, S, d) -> (B, S, d).

    §Perf iteration (mixtral train_4k): dispatching over the GLOBAL token
    set makes GSPMD reshuffle every token across the data axis (the
    dispatch buffer inherits no batch sharding) — measured 88 s of
    collectives per step.  vmapping the dispatch over the batch row keeps
    every token inside its data shard; the only cross-device traffic left
    is the expert weights' tensor-parallel reduction."""
    out, aux = jax.vmap(
        lambda row: moe_ffn(row, router_w, w_gate, w_up, w_down,
                            top_k=top_k, capacity_factor=capacity_factor)
    )(x)
    return out, aux.mean()


def moe_ffn(x: jax.Array, router_w: jax.Array, w_gate: jax.Array,
            w_up: jax.Array, w_down: jax.Array, *, top_k: int,
            capacity_factor: float = 1.25) -> tuple[jax.Array, jax.Array]:
    """x: (T, d); router_w: (d, E); expert weights: (E, d, ff)/(E, ff, d).

    Returns (out (T, d), aux_loss ()).  Tokens over capacity are dropped
    (contribute zero) — the standard static-shape MoE contract.
    """
    t, d = x.shape
    e = router_w.shape[1]
    cap = int(capacity_factor * top_k * t / e) + 1
    cap = ((cap + 15) // 16) * 16   # TP-shardable dispatch buffers

    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    gate_vals, exp_idx = jax.lax.top_k(probs, top_k)         # (T, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(0)
    ce = jnp.zeros((e,)).at[exp_idx.reshape(-1)].add(1.0) / (t * top_k)
    aux = e * jnp.sum(me * ce)

    # position of each (token, slot) within its expert, capacity-bounded
    flat_e = exp_idx.reshape(-1)                             # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)      # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1                # running count
    my_pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], 1)[:, 0]
    keep = my_pos < cap
    dest = jnp.where(keep, flat_e * cap + my_pos, e * cap)   # park dropped

    # dispatch: (E*cap+1, d) buffer
    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    src = jnp.repeat(x, top_k, axis=0)
    buf = buf.at[dest].add(src)
    xe = buf[:e * cap].reshape(e, cap, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate)) * \
        jnp.einsum("ecd,edf->ecf", xe, w_up)
    # §Perf iteration (mixtral train_4k, #2): with ff tensor-parallel the
    # down-projection emits (E, cap, d) PARTIAL sums whose all-reduce
    # dominates the step (measured 88 s of collectives).  Resharding h from
    # ff-sharded to cap-sharded first (one small all-to-all) makes the
    # partials cap-sharded, shrinking the all-reduce by the TP degree.
    if _moe_reshard_axis() is not None and cap % 16 == 0:
        from jax.sharding import PartitionSpec as _P
        h = jax.lax.with_sharding_constraint(
            h, _P(None, _moe_reshard_axis(), None))
    ye = jnp.einsum("ecf,efd->ecd", h, w_down)               # (E, cap, d)

    # combine: gather back and weight by gate
    flat_back = ye.reshape(e * cap, d)
    flat_back = jnp.concatenate(
        [flat_back, jnp.zeros((1, d), x.dtype)], axis=0)
    y = flat_back[dest] * gate_vals.reshape(-1)[:, None].astype(x.dtype)
    out = y.reshape(t, top_k, d).sum(axis=1)
    return out, aux
