"""Unified LM covering all 10 assigned architectures.

One parameter schema + three entry points:

* ``forward``      — full-sequence logits (train / prefill);
* ``loss_fn``      — causal (or frame-level) cross-entropy;
* ``decode_step``  — one token with KV / SSM caches (serve path).

Layers are stacked along a leading L axis and executed with ``lax.scan``
(compact HLO — essential for the 512-device dry-run compile times) with
``jax.checkpoint`` (remat) around each layer body for training memory.

Families:
  dense / audio / vlm : attention + (Swi)GLU blocks, uniform stack
  moe                 : attention + MoE FFN (capacity-bounded dispatch)
  ssm (mamba1)        : pure Mamba1 blocks, no attention anywhere
  hybrid (mamba2)     : Mamba2 stack with ONE shared attention+MLP block
                        applied every ``attn_every`` layers (zamba2-style;
                        the shared block has a single parameter set)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import layers as L
from . import ssm
from .config import ModelConfig

Params = dict


def _scan_layers(body, carry, xs, unroll: bool):
    """lax.scan; fully unrolled in cfg.cost_mode so HLO cost analysis
    counts every layer (XLA counts a while body once)."""
    return jax.lax.scan(body, carry, xs, unroll=True if unroll else 1)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _attn_param_shapes(cfg: ModelConfig, lead: tuple) -> dict:
    d, hd = cfg.d_model, cfg.hd
    return {
        "wq": lead + (d, cfg.n_heads * hd),
        "wk": lead + (d, cfg.n_kv_heads * hd),
        "wv": lead + (d, cfg.n_kv_heads * hd),
        "wo": lead + (cfg.n_heads * hd, d),
    }


def _mlp_param_shapes(cfg: ModelConfig, lead: tuple) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    if cfg.mlp == "swiglu":
        return {"w_gate": lead + (d, ff), "w_up": lead + (d, ff),
                "w_down": lead + (ff, d)}
    return {"w1": lead + (d, ff), "w2": lead + (ff, d)}


def _mamba1_shapes(cfg: ModelConfig, lead: tuple) -> dict:
    d, di, st, dr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    return {
        "in_proj": lead + (d, 2 * di),
        "conv": lead + (di, cfg.d_conv),
        "x_proj": lead + (di, dr + 2 * st),
        "dt_proj": lead + (dr, di),
        "dt_bias": lead + (di,),
        "A_log": lead + (di, st),
        "D": lead + (di,),
        "out_proj": lead + (di, d),
    }


def _mamba2_shapes(cfg: ModelConfig, lead: tuple) -> dict:
    d, di, st = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.n_ssm_heads
    return {
        "in_proj": lead + (d, 2 * di + 2 * st + nh),
        "conv": lead + (di + 2 * st, cfg.d_conv),
        "A_log": lead + (nh,),
        "D": lead + (nh,),
        "dt_bias": lead + (nh,),
        "norm_scale": lead + (di,),
        "out_proj": lead + (di, d),
    }


def param_shapes(cfg: ModelConfig) -> dict:
    """Nested dict of parameter shapes (schema single source of truth)."""
    d = cfg.d_model
    Lc = cfg.n_layers
    shapes: dict = {"embed": (cfg.vocab, d), "final_norm": (d,)}
    if not cfg.tie_embeddings:
        shapes["unembed"] = (cfg.vocab, d)
    if cfg.frontend == "patches":
        shapes["patch_proj"] = (d, d)

    if cfg.family == "hybrid":
        g = Lc // cfg.attn_every
        lead = (g, cfg.attn_every)
        shapes["layers"] = {**_mamba2_shapes(cfg, lead),
                            "norm_mixer": lead + (d,)}
        shapes["shared"] = {
            **_attn_param_shapes(cfg, ()),
            **_mlp_param_shapes(cfg, ()),
            "norm_attn": (d,), "norm_mlp": (d,),
        }
        return shapes

    lead = (Lc,)
    if cfg.mixer == "mamba1":
        shapes["layers"] = {**_mamba1_shapes(cfg, lead),
                            "norm_mixer": lead + (d,)}
        return shapes

    layer: dict = {**_attn_param_shapes(cfg, lead),
                   "norm_attn": lead + (d,), "norm_mlp": lead + (d,)}
    if cfg.n_experts:
        layer["router"] = lead + (d, cfg.n_experts)
        layer["w_gate"] = lead + (cfg.n_experts, d, cfg.d_ff)
        layer["w_up"] = lead + (cfg.n_experts, d, cfg.d_ff)
        layer["w_down"] = lead + (cfg.n_experts, cfg.d_ff, d)
    else:
        layer.update(_mlp_param_shapes(cfg, lead))
    shapes["layers"] = layer
    return shapes


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    shapes = param_shapes(cfg)
    is_shape = lambda x: isinstance(x, tuple)  # noqa: E731
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=is_shape)
    keys = jax.random.split(key, len(flat))
    out = []
    for (path, shape), k in zip(flat, keys):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if "norm" in name or name == "D" or name == "A_log":
            # A_log = 0 -> decay rate -1 (stable); norms start at identity
            out.append(jnp.zeros(shape, jnp.float32))
        elif name == "dt_bias":
            out.append(jnp.full(shape, -2.0, jnp.float32))
        else:
            out.append(_dense_init(k, shape, cfg.jdtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(shapes, is_leaf=is_shape), out)


def abstract_params(cfg: ModelConfig) -> Params:
    """ShapeDtypeStruct tree, no allocation (dry-run contract)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _attention(cfg: ModelConfig, p: dict, x: jax.Array,
               positions: jax.Array) -> jax.Array:
    b, s, d = x.shape
    kv, hd = cfg.n_kv_heads, cfg.hd
    g = cfg.n_heads // kv
    q = (x @ p["wq"]).reshape(b, s, kv, g, hd)
    k = (x @ p["wk"]).reshape(b, s, kv, hd)
    v = (x @ p["wv"]).reshape(b, s, kv, hd)
    q = L.apply_rope(q.reshape(b, s, kv * g, hd), positions,
                     cfg.rope_theta).reshape(b, s, kv, g, hd)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    if cfg.swa_window and cfg.swa_window < s:
        # §Perf iteration (danube train_4k): block=1024 halves the gathered
        # K/V window copies (nwin 9 -> 5) at ~equal score bytes
        out = L.windowed_attention(q, k, v, window=cfg.swa_window,
                                   causal=cfg.causal,
                                   block=min(1024, s, cfg.swa_window))
    else:
        # cost mode uses larger chunks: identical flop totals, far fewer
        # unrolled scan steps (compile-time bound for 32k sequences)
        qc = min(4096 if cfg.cost_mode else 512, s)
        kc = min(8192 if cfg.cost_mode else 1024, s)
        out = L.chunked_attention(q, k, v, causal=cfg.causal,
                                  q_chunk=qc, kv_chunk=kc,
                                  unroll=cfg.cost_mode)
    return out.reshape(b, s, kv * g * hd) @ p["wo"]


def _mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.mlp == "swiglu":
        return L.swiglu(x, p["w_gate"], p["w_up"], p["w_down"])
    return L.gelu_mlp(x, p["w1"], p["w2"])


def _attn_block(cfg: ModelConfig, p: dict, x: jax.Array,
                positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Pre-norm attention + FFN/MoE block. Returns (x, aux_loss)."""
    h = L.apply_norm(cfg.norm, x, p.get("norm_attn"))
    x = x + _attention(cfg, p, h, positions)
    h = L.apply_norm(cfg.norm, x, p.get("norm_mlp"))
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_experts:
        y, aux = L.moe_ffn_batched(h, p["router"], p["w_gate"],
                                   p["w_up"], p["w_down"],
                                   top_k=cfg.top_k,
                                   capacity_factor=cfg.moe_capacity_factor)
        x = x + y
    else:
        x = x + _mlp(cfg, p, h)
    return x, aux


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed_inputs(cfg: ModelConfig, params: Params, batch: dict
                  ) -> tuple[jax.Array, jax.Array]:
    """Returns (x (B,S,d), positions (B,S))."""
    if cfg.frontend == "frames":
        x = batch["frames"].astype(cfg.jdtype)
    elif cfg.frontend == "patches":
        tok = params["embed"][batch["tokens"]]
        pat = batch["patches"].astype(cfg.jdtype) @ params["patch_proj"]
        x = jnp.concatenate([pat, tok], axis=1)
    else:
        x = params["embed"][batch["tokens"]]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    return x.astype(cfg.jdtype), positions


def forward(cfg: ModelConfig, params: Params, batch: dict,
            remat: bool = True) -> tuple[jax.Array, jax.Array]:
    """Full-sequence logits. Returns (logits (B,S,V), aux_loss)."""
    x, positions = _embed_inputs(cfg, params, batch)

    # cost mode: fewer, larger chunks bound the unrolled-compile size;
    # mixer recurrence flops are elementwise (<5% of layer flops), so the
    # intra-chunk O(C) growth distorts totals negligibly
    ssm_chunk = 1024 if cfg.cost_mode else 256
    if cfg.family == "hybrid":
        x, aux = _hybrid_stack(cfg, params, x, positions, remat)
    elif cfg.mixer == "mamba1":
        def body(x, lp):
            h = L.apply_norm(cfg.norm, x, lp.get("norm_mixer"))
            y = ssm.mamba1_forward(lp, h, state=cfg.ssm_state,
                                   chunk=ssm_chunk, unroll=cfg.cost_mode)
            return x + y, jnp.zeros((), jnp.float32)
        if remat:
            body = jax.checkpoint(body)
        x, auxs = _scan_layers(body, x, params["layers"], cfg.cost_mode)
        aux = auxs.sum()
    else:
        def body(x, lp):
            x, aux = _attn_block(cfg, lp, x, positions)
            return x, aux
        if remat:
            body = jax.checkpoint(body)
        x, auxs = _scan_layers(body, x, params["layers"], cfg.cost_mode)
        aux = auxs.sum()

    x = L.apply_norm(cfg.norm, x, params.get("final_norm"))
    unembed = params.get("unembed", params["embed"])
    logits = x @ unembed.T.astype(x.dtype)
    return logits, aux


def _hybrid_stack(cfg: ModelConfig, params: Params, x: jax.Array,
                  positions: jax.Array, remat: bool
                  ) -> tuple[jax.Array, jax.Array]:
    shared = params["shared"]

    ssm_chunk = 512 if cfg.cost_mode else 128

    def group(x, gp):
        def mamba_body(x, lp):
            h = L.apply_norm(cfg.norm, x, lp.get("norm_mixer"))
            y = ssm.mamba2_forward(lp, h, state=cfg.ssm_state,
                                   head_dim=cfg.ssm_head_dim,
                                   chunk=ssm_chunk, unroll=cfg.cost_mode)
            return x + y, None
        if remat:
            mamba_body = jax.checkpoint(mamba_body)
        x, _ = _scan_layers(mamba_body, x, gp, cfg.cost_mode)
        # shared attention + MLP block (single parameter set, reused)
        h = L.apply_norm(cfg.norm, x, shared.get("norm_attn"))
        x = x + _attention(cfg, shared, h, positions)
        h = L.apply_norm(cfg.norm, x, shared.get("norm_mlp"))
        x = x + _mlp(cfg, shared, h)
        return x, jnp.zeros((), jnp.float32)

    if remat:
        group = jax.checkpoint(group)
    x, auxs = _scan_layers(group, x, params["layers"], cfg.cost_mode)
    return x, auxs.sum()


def loss_fn(cfg: ModelConfig, params: Params, batch: dict,
            remat: bool = True) -> tuple[jax.Array, dict]:
    logits, aux = forward(cfg, params, batch, remat=remat)
    targets = batch["targets"]
    if cfg.frontend == "patches":
        logits = logits[:, cfg.n_patches:]        # loss on text positions
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = nll.mean() + 0.01 * aux
    return loss, {"nll": nll.mean(), "aux": aux}


# ---------------------------------------------------------------------------
# Decode (serve path)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CacheSpec:
    """Shapes of the decode cache for one config."""
    cfg: ModelConfig
    batch: int
    max_len: int


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               abstract: bool = False) -> dict:
    """KV cache for attention layers and/or SSM state for mamba layers."""
    def mk(shape, dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    Lc, d, hd, kv = cfg.n_layers, cfg.d_model, cfg.hd, cfg.n_kv_heads
    cache: dict = {}
    if cfg.family == "hybrid":
        g = Lc // cfg.attn_every
        cache["k"] = mk((g, batch, max_len, kv, hd), cfg.jdtype)
        cache["v"] = mk((g, batch, max_len, kv, hd), cfg.jdtype)
        cache["conv"] = mk((g, cfg.attn_every, batch, cfg.d_conv - 1,
                            cfg.d_inner + 2 * cfg.ssm_state), cfg.jdtype)
        cache["ssm"] = mk((g, cfg.attn_every, batch, cfg.n_ssm_heads,
                           cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
    elif cfg.mixer == "mamba1":
        cache["conv"] = mk((Lc, batch, cfg.d_conv - 1, cfg.d_inner),
                           cfg.jdtype)
        cache["ssm"] = mk((Lc, batch, cfg.d_inner, cfg.ssm_state),
                          jnp.float32)
    else:
        # SWA archs only ever attend to the last ``window`` positions, so
        # the cache can be a ring buffer of that length (big win for
        # long_500k).  Full-attention archs need the whole sequence.
        cache["k"] = mk((Lc, batch, max_len, kv, hd), cfg.jdtype)
        cache["v"] = mk((Lc, batch, max_len, kv, hd), cfg.jdtype)
    return cache


def _decode_attention_layer(cfg: ModelConfig, p: dict, x: jax.Array,
                            k_cache, v_cache, pos):
    """x: (B, 1, d); caches (B, T, KV, hd). Returns (y, k_cache, v_cache)."""
    b, _, d = x.shape
    kv, hd = cfg.n_kv_heads, cfg.hd
    g = cfg.n_heads // kv
    q = (x @ p["wq"]).reshape(b, 1, kv, g, hd)
    k = (x @ p["wk"]).reshape(b, 1, kv, hd)
    v = (x @ p["wv"]).reshape(b, 1, kv, hd)
    posb = jnp.full((b, 1), pos)
    q = L.apply_rope(q.reshape(b, 1, kv * g, hd), posb,
                     cfg.rope_theta).reshape(b, 1, kv, g, hd)
    k = L.apply_rope(k, posb, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
    out = L.decode_attention(q, k_cache, v_cache, pos,
                             window=cfg.swa_window)
    y = out.reshape(b, 1, kv * g * hd) @ p["wo"]
    return y, k_cache, v_cache


def decode_step(cfg: ModelConfig, params: Params, token: jax.Array,
                cache: dict, pos: jax.Array) -> tuple[jax.Array, dict]:
    """One decode step.  token: (B,) int32; pos: () int32 current length.

    Returns (logits (B, V), new cache).
    """
    x = params["embed"][token][:, None, :].astype(cfg.jdtype)  # (B,1,d)
    b = x.shape[0]

    if cfg.family == "hybrid":
        def group(x, slices):
            gp, k_c, v_c, conv_c, ssm_c = slices

            def mamba_body(x, lp_state):
                lp, conv1, ssm1 = lp_state
                h = L.apply_norm(cfg.norm, x[:, 0], lp.get("norm_mixer"))
                y, new_state = ssm.mamba2_step(
                    lp, h, ssm.Mamba2State(conv1, ssm1),
                    state=cfg.ssm_state, head_dim=cfg.ssm_head_dim)
                return x + y[:, None], (new_state.conv, new_state.ssm)

            x, new_states = _scan_layers(mamba_body, x,
                                         (gp, conv_c, ssm_c),
                                         cfg.cost_mode)
            shared = params["shared"]
            h = L.apply_norm(cfg.norm, x, shared.get("norm_attn"))
            y, k_c, v_c = _decode_attention_layer(cfg, shared, h, k_c,
                                                  v_c, pos)
            x = x + y
            h = L.apply_norm(cfg.norm, x, shared.get("norm_mlp"))
            x = x + _mlp(cfg, shared, h)
            return x, (k_c, v_c, new_states[0], new_states[1])

        x, (ks, vs, convs, ssms) = _scan_layers(
            group, x, (params["layers"], cache["k"], cache["v"],
                       cache["conv"], cache["ssm"]), cfg.cost_mode)
        cache = {"k": ks, "v": vs, "conv": convs, "ssm": ssms}
    elif cfg.mixer == "mamba1":
        def body(x, lp_state):
            lp, conv1, ssm1 = lp_state
            h = L.apply_norm(cfg.norm, x[:, 0], lp.get("norm_mixer"))
            y, new_state = ssm.mamba1_step(lp, h,
                                           ssm.MambaState(conv1, ssm1),
                                           state=cfg.ssm_state)
            return x + y[:, None], (new_state.conv, new_state.ssm)

        x, (convs, ssms) = _scan_layers(
            body, x, (params["layers"], cache["conv"], cache["ssm"]),
            cfg.cost_mode)
        cache = {"conv": convs, "ssm": ssms}
    else:
        def body(x, lp_kv):
            lp, k_c, v_c = lp_kv
            h = L.apply_norm(cfg.norm, x, lp.get("norm_attn"))
            y, k_c, v_c = _decode_attention_layer(cfg, lp, h, k_c, v_c,
                                                  pos)
            x = x + y
            h = L.apply_norm(cfg.norm, x, lp.get("norm_mlp"))
            if cfg.n_experts:
                yff, _ = L.moe_ffn(h[:, 0], lp["router"], lp["w_gate"],
                                   lp["w_up"], lp["w_down"],
                                   top_k=cfg.top_k,
                                   capacity_factor=cfg.moe_capacity_factor)
                x = x + yff[:, None]
            else:
                x = x + _mlp(cfg, lp, h)
            return x, (k_c, v_c)

        x, (ks, vs) = _scan_layers(
            body, x, (params["layers"], cache["k"], cache["v"]),
            cfg.cost_mode)
        cache = {"k": ks, "v": vs}

    x = L.apply_norm(cfg.norm, x, params.get("final_norm"))
    unembed = params.get("unembed", params["embed"])
    logits = (x[:, 0] @ unembed.T.astype(x.dtype)).astype(jnp.float32)
    return logits, cache
