"""Model configuration — one frozen dataclass covers all 10 assigned
architectures (dense / MoE / SSM / hybrid / audio / VLM LM-family).

The actual per-arch configs live in src/repro/configs/<id>.py; this module
defines the schema, the four assigned input shapes, and ``input_specs``
(ShapeDtypeStruct stand-ins — no allocation, the dry-run contract).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                 # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # attention flavour
    swa_window: int = 0          # 0 = full attention; else sliding window
    causal: bool = True          # False = encoder-only (hubert)
    rope_theta: float = 500000.0
    norm: str = "rmsnorm"        # rmsnorm | nonparam_ln
    mlp: str = "swiglu"          # swiglu | gelu
    tie_embeddings: bool = True
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25
    # SSM
    mixer: str = "attention"     # attention | mamba1 | mamba2
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2              # d_inner = expand * d_model
    ssm_head_dim: int = 64       # mamba2 head dim
    # hybrid (zamba2-style): one shared attention block every attn_every
    attn_every: int = 0
    # modality frontend (audio/vlm): stub supplies embeddings directly
    frontend: str = "tokens"     # tokens | frames | patches
    n_patches: int = 256         # vlm: patch embeddings per image
    dtype: str = "bfloat16"
    # cost mode: unroll all layer/chunk loops so HLO cost analysis counts
    # every executed op (XLA counts while bodies ONCE — dry-run correction)
    cost_mode: bool = False

    # -- derived -------------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, self.d_model // 16)

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def attention_free(self) -> bool:
        return self.mixer == "mamba1"

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced config for smoke tests (same family, tiny dims)."""
        return dataclasses.replace(self, **kw)

    # -- parameter count (for 6ND roofline bookkeeping) ----------------------
    def param_count(self) -> int:
        d, ff, L, v = self.d_model, self.d_ff, self.n_layers, self.vocab
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv_heads
        emb = v * d
        per_layer = 0
        if self.mixer == "attention" or self.family == "hybrid":
            attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        else:
            attn = 0
        if self.mlp == "swiglu":
            mlp = 3 * d * ff
        else:
            mlp = 2 * d * ff
        if self.mixer == "attention":
            if self.n_experts:
                per_layer = attn + d * self.n_experts + self.n_experts * mlp
            else:
                per_layer = attn + mlp
        elif self.mixer == "mamba1":
            di, st, dr = self.d_inner, self.ssm_state, self.dt_rank
            per_layer = (d * 2 * di + di * self.d_conv
                         + di * (dr + 2 * st) + dr * di + di * st + di
                         + di * d)
        elif self.mixer == "mamba2":
            # hybrid: per-layer MLP lives in the shared block, not here
            di, st = self.d_inner, self.ssm_state
            nh_ssm = self.n_ssm_heads
            proj_in = d * (2 * di + 2 * st + nh_ssm)
            per_layer = (proj_in + (di + 2 * st) * self.d_conv
                         + nh_ssm * 2 + di * d + di)
        total = emb + L * per_layer
        if self.family == "hybrid" and self.attn_every:
            # one shared attention block (+MLP), applied repeatedly
            total += (d * nh * hd + 2 * d * nkv * hd + nh * hd * d
                      + 3 * d * ff)
        if not self.tie_embeddings:
            total += v * d
        return total

    def active_param_count(self) -> int:
        """MoE: params touched per token (for 6*N_active*D)."""
        if not self.n_experts:
            return self.param_count()
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        mlp = 3 * d * ff if self.mlp == "swiglu" else 2 * d * ff
        dense = self.param_count() - L * self.n_experts * mlp
        return dense + L * self.top_k * mlp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input shape (see task brief)."""
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeSpec("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeSpec("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeSpec("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeSpec("long_500k", "decode", 524288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def applicable_shapes(cfg: ModelConfig) -> list[ShapeSpec]:
    """Principled skips (DESIGN.md §Arch-applicability):

    * encoder-only archs have no decode step -> skip decode shapes;
    * ``long_500k`` needs sub-quadratic attention -> run only for SSM /
      hybrid / SWA archs.
    """
    out = [TRAIN_4K, PREFILL_32K]
    if not cfg.is_encoder_only:
        out.append(DECODE_32K)
        if cfg.mixer in ("mamba1", "mamba2") or cfg.swa_window:
            out.append(LONG_500K)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeSpec, *,
                batch_sharding=None, kv_sharding=None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train:   {tokens, targets} (or frames/patches for stub frontends)
    prefill: {tokens}
    decode:  {token, cache..., pos}  — built by launch/lm_serve.py helpers;
             here we return the new-token batch only.
    """
    b, s = shape.global_batch, shape.seq_len

    def arr(shp, dt=jnp.int32, sh=None):
        return jax.ShapeDtypeStruct(shp, dt, sharding=sh or batch_sharding)

    if cfg.frontend == "frames" and shape.kind in ("train", "prefill"):
        return {
            "frames": arr((b, s, cfg.d_model), jnp.bfloat16),
            "targets": arr((b, s)),
        }
    if cfg.frontend == "patches":
        s_text = s - cfg.n_patches
        base = {
            "tokens": arr((b, s_text)),
            "patches": arr((b, cfg.n_patches, cfg.d_model), jnp.bfloat16),
        }
        if shape.kind == "train":
            base["targets"] = arr((b, s_text))
        return base
    base = {"tokens": arr((b, s))}
    if shape.kind == "train":
        base["targets"] = arr((b, s))
    return base
