"""Session: one object owning the whole Chunks-and-Tasks machinery.

The paper's matrix library (made explicit in the follow-up "Chunks and
Tasks Matrix Library 2.0", arXiv:2011.11762) exposes matrices as objects
whose algebra hides chunk identifiers and task registration.  A
:class:`Session` is this repo's rendering of that front door: it owns the
:class:`~repro.core.tasks.CTGraph`, the leaf engine, the runtime
:class:`~repro.runtime.scheduler.Scheduler` (and through it the
:class:`~repro.core.chunks.ChunkStore`), the
:class:`~repro.core.tasks.CostModel` and the chunk placement policy, so a
paper experiment is a handful of lines::

    from repro import Session

    sess = Session(engine="pallas", placement="parent", leaf_n=64, bs=8)
    A = sess.from_dense(a)
    B = sess.from_dense(b)
    sess.simulate(p=8)                      # build phase places inputs
    C = A @ B
    rep = sess.simulate(fresh_stats=True)   # measured multiply phase
    C.to_dense(), rep.max_bytes_received, rep.crit.length_s

Every operation lowers through the expression IR (:mod:`repro.api.expr`)
onto the documented internal layer — the ``qt_*`` free functions of
:mod:`repro.core.quadtree` / :mod:`repro.core.multiply` — and adds no
graph structure of its own, so the paper's eq (1) task counts and the
numpy/pallas engine equivalence pin it exactly.  ``lazy=True`` defers
lowering to readback and reuses compiled :class:`~repro.api.plan.Plan`
objects — the front end that iterative algorithms (SP2 purification)
need::

    sess = Session(lazy=True)
    X = sess.from_dense(x0, name="X")
    plan = sess.compile(X @ X)
    Y = plan.run()                  # lowers + executes once
    Y = plan.run(X=Y)               # rebinds + replays: zero new tasks
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Optional, Union

import numpy as np

from repro.core.engine import LeafEngine
from repro.core.quadtree import (QTParams, qt_from_coo, qt_from_dense,
                                 qt_structure_fp)
from repro.core.tasks import CostModel, CTGraph
from repro.obs.metrics import MetricSet, from_engine_stats, from_sim_report
from repro.obs.tracer import Tracer, as_tracer
from repro.runtime.scheduler import PLACEMENTS

from .expr import (Expr, Transpose, expr_upper, fingerprint, rewrite)
from .lru import LRUCache
from .matrix import Matrix
from .plan import Plan, lower

#: default bound on a session's compiled-plan cache (LRU; 0 = unbounded)
PLAN_CACHE_CAP = 64

#: accepted spellings of the scheduler placement policies: every canonical
#: policy name passes through, plus shorthand aliases
PLACEMENT_ALIASES = {p: p for p in PLACEMENTS}
PLACEMENT_ALIASES.update({"parent": "parent-worker", "rr": "round-robin"})

#: engine spec strings resolvable by :func:`repro.core.engine.make_engine`
ENGINE_NAMES = ("numpy", "pallas", "mesh")


def _normalize_placement(placement: Optional[str]) -> Optional[str]:
    if placement is None:
        return None
    try:
        return PLACEMENT_ALIASES[placement]
    except KeyError:
        raise ValueError(
            f"unknown placement {placement!r}; pick one of "
            f"{sorted(set(PLACEMENT_ALIASES.values()))}") from None


def _validate_engine(engine: Any) -> Any:
    """Fail fast on bad engine specs instead of at first leaf task."""
    if engine is None or isinstance(engine, LeafEngine):
        return engine
    if isinstance(engine, str) and engine in ENGINE_NAMES:
        return engine
    raise ValueError(
        f"unknown leaf engine spec: {engine!r}; pick one of "
        f"{ENGINE_NAMES} or pass a LeafEngine instance")


class Session:
    """Owns graph + engine + simulator behind one constructor.

    Parameters
    ----------
    engine : ``"numpy"`` (reference, immediate), ``"pallas"`` (deferred,
        cross-leaf batched kernel waves), ``"mesh"`` (device-sharded
        wave execution with counted push/fetch collectives over a jax
        mesh — DESIGN.md §7) or a
        :class:`~repro.core.engine.LeafEngine` instance.  One stateful
        engine instance serves one session/graph; rebinding raises
        :class:`~repro.core.engine.EngineRebindError`.  Unknown specs
        raise here, not at the first leaf task.
    placement : default chunk placement for :meth:`simulate` —
        ``"parent"``/``"parent-worker"`` (the paper's locality model),
        ``"round-robin"`` or ``"random"``.
    leaf_n, bs : quadtree leaf dimension and leaf-internal blocksize used
        for matrices built by this session (per-matrix overrides via the
        ``leaf_n=``/``bs=`` kwargs of the constructors).
    p : default simulated worker count for :meth:`simulate`.
    tau : default SpAMM truncation threshold for ``A @ B`` /
        :meth:`Matrix.multiply` on plain operands (DESIGN.md §5).  The
        default 0.0 multiplies exactly; a positive tau prunes every
        recursive product with ``||A'||_F ||B'||_F < tau`` and records a
        worst-case error bound on the result
        (:attr:`~repro.api.matrix.Matrix.error_bound`).  The symmetric
        task programs are untruncated and *raise* under a nonzero
        effective tau (see :meth:`Matrix.sym_square`).
    lazy : ``False`` (default) lowers every operator call immediately —
        the classic eager facade.  ``True`` builds expression DAGs
        instead; readback (or :meth:`compile`) lowers them through the
        rewrite pipeline and caches the compiled :class:`Plan` for
        re-execution (DESIGN.md §6).
    cost, cache_bytes, seed, dedup : forwarded to the runtime
        :class:`~repro.runtime.scheduler.Scheduler` / chunk store
        (``dedup=True`` enables content-hash chunk deduplication).
    trace : ``False`` (default) keeps the shared no-op tracer — zero
        recording, no behavioural change.  ``True`` records structured
        spans (:mod:`repro.obs.tracer`) across the whole stack:
        ``session.simulate``, ``plan.compile``/``plan.run``,
        ``engine.wave``, ``kernel.dispatch``, ``collective.ppermute``.
        A :class:`~repro.obs.tracer.Tracer` instance is also accepted
        (shared across sessions).  See also :meth:`tracing` for scoped
        tracing and :meth:`metrics` for the unified counter view
        (DESIGN.md §8).
    plan_cache_cap : bound on the compiled-plan cache (LRU eviction past
        it; ``0`` = unbounded).  Hit/miss/eviction counters appear in
        :meth:`metrics` once the cache has been touched.
    """

    def __init__(self, engine: Any = "numpy",
                 placement: str = "parent-worker", leaf_n: int = 64,
                 bs: int = 8, p: Optional[int] = None,
                 cost: Optional[CostModel] = None,
                 cache_bytes: int = 1 << 62, seed: int = 0,
                 dedup: bool = False, tau: float = 0.0,
                 lazy: bool = False, trace: Any = False,
                 plan_cache_cap: int = PLAN_CACHE_CAP):
        self.graph = CTGraph(engine=_validate_engine(engine))
        self.tracer = as_tracer(trace)
        self.graph.tracer = self.tracer
        self.leaf_n = leaf_n
        self.bs = bs
        self.placement = _normalize_placement(placement)
        self.p = p
        self.cost = cost
        self.cache_bytes = cache_bytes
        self.seed = seed
        self.dedup = dedup
        self.tau = float(tau)
        self.lazy = bool(lazy)
        self._sched = None
        # node id -> materialised-transpose node id, shared by all handles
        # so a reused lazy .T registers its task program only once
        self._transpose_cache: dict[Optional[int], Optional[int]] = {}
        # compiled-plan cache: structural fingerprint -> Plan (DESIGN.md
        # §6).  LRU-bounded: under serving traffic unbounded growth is a
        # leak; hits/misses/evictions surface through metrics()
        self._plans: LRUCache = LRUCache(cap=plan_cache_cap)
        # serving hook: callables fired with each freshly compiled Plan
        # (the cross-session SharedPlanCache registers through this, so
        # recompile=True successors land there too — DESIGN.md §9)
        self._plan_observers: list = []
        # node id -> quadtree structure fingerprint (structure is final at
        # registration, so entries never go stale)
        self._structfp: dict[Optional[int], str] = {}
        # input root node id -> user-chosen plan slot name
        self._input_names: dict[int, str] = {}
        # most recent SimReport (feeds Session.metrics)
        self._last_report = None

    def __repr__(self) -> str:
        eng = getattr(self.graph, "_engine_spec", None)
        eng = getattr(eng, "name", eng) or "numpy"
        mode = ", lazy" if self.lazy else ""
        return (f"Session(engine={eng!r}, placement={self.placement!r}, "
                f"leaf_n={self.leaf_n}, bs={self.bs}{mode}, "
                f"tasks={len(self.graph.nodes)})")

    # -- matrix construction ------------------------------------------------
    def params_for(self, n: int, leaf_n: Optional[int] = None,
                   bs: Optional[int] = None) -> QTParams:
        """The :class:`QTParams` chunk this session uses for dimension n."""
        return QTParams(n, leaf_n or self.leaf_n, bs or self.bs)

    def from_dense(self, a: np.ndarray, upper: bool = False,
                   tol: float = 0.0, leaf_n: Optional[int] = None,
                   bs: Optional[int] = None,
                   name: Optional[str] = None) -> Matrix:
        """Build a quadtree matrix from a dense array (task program).

        ``name`` labels the matrix as a rebindable plan input slot:
        ``plan.run(name=new_values)`` (DESIGN.md §6).
        """
        a = np.asarray(a)
        params = self.params_for(a.shape[0], leaf_n, bs)
        nid = qt_from_dense(self.graph, a, params, upper=upper, tol=tol)
        return self._register_input(nid, params, upper, name)

    def from_pattern(self, rows: np.ndarray, cols: np.ndarray, n: int,
                     value_fn: Optional[Callable] = None,
                     upper: bool = False, leaf_n: Optional[int] = None,
                     bs: Optional[int] = None,
                     name: Optional[str] = None) -> Matrix:
        """Build from nonzero coordinates without a dense detour
        (:func:`~repro.core.quadtree.qt_from_coo`)."""
        params = self.params_for(n, leaf_n, bs)
        nid = qt_from_coo(self.graph, rows, cols, params,
                          value_fn=value_fn, upper=upper)
        return self._register_input(nid, params, upper, name)

    def zeros(self, n: int, upper: bool = False,
              leaf_n: Optional[int] = None, bs: Optional[int] = None
              ) -> Matrix:
        """The all-zero (NIL) matrix of dimension n."""
        return Matrix(self, None, self.params_for(n, leaf_n, bs),
                      upper=upper)

    def _register_input(self, nid: Optional[int], params: QTParams,
                        upper: bool, name: Optional[str]) -> Matrix:
        if name is not None and nid is not None:
            self._input_names[nid] = name
        return Matrix(self, nid, params, upper=upper, name=name)

    # -- expression lowering (both modes) -----------------------------------
    def _run_expr(self, e: Expr, params: QTParams) -> Matrix:
        """Eager mode: rewrite + lower one operator call immediately.

        Emits the identical ``qt_*`` registrations as the pre-IR facade:
        single-op expressions are already in normal form, transposes
        materialise through the session-wide cache, and a top-level
        transpose peels into the handle's lazy flag instead of a task.
        """
        upper = expr_upper(e)
        e = rewrite(e)
        t = False
        while isinstance(e, Transpose):
            t, e = not t, e.a
        reports: list = []
        n0 = len(self.graph.nodes)
        nid = lower(self, e, params, reports, use_transpose_cache=True)
        trunc = reports[0] if len(reports) == 1 else None
        m = Matrix(self, nid, params, t=t, upper=upper, trunc=trunc)
        # the producing program's nid range: lets Session.free release the
        # program's intermediate chunks (consumed multiply/add partials),
        # not just the result tree
        m._prog = range(n0, len(self.graph.nodes))
        return m

    def compile(self, target: Union[Matrix, Expr]) -> Plan:
        """Compile an expression into a cached, re-executable :class:`Plan`.

        ``target`` is a lazy (pending) :class:`Matrix` — the natural way
        to spell an expression, ``sess.compile(X @ X + C)`` — or a raw
        :class:`~repro.api.expr.Expr`.  Plans are cached by structural
        fingerprint (expression shape + QTParams + operand sparsity
        structure + per-node tau) *plus the identity of the bound
        inputs*: compiling the same expression twice returns the same
        plan, and running it again replays the recorded program with
        rebound inputs instead of registering new tasks.  Input identity
        is part of the key so that no plan ever rebinds a matrix the
        caller didn't pass to ``run`` — values move between iterations
        only through explicit ``plan.run(name=...)`` bindings.
        """
        if isinstance(target, Matrix):
            if target.session is not self:
                raise ValueError("compile: matrix belongs to a different "
                                 "Session")
            if target._expr is None:
                raise ValueError(
                    "compile: matrix is already materialised — build the "
                    "expression in a Session(lazy=True), e.g. "
                    "plan = sess.compile(X @ X)")
            e, params = target._expr, target.params
        elif isinstance(target, Expr):
            e = target
            inputs = _first_input_n(e)
            params = self.params_for(inputs)
        else:
            raise TypeError(f"compile: expected a Matrix or Expr, got "
                            f"{type(target)!r}")
        plan, _ = self._compile_expr(e, params)
        return plan

    def _fingerprint_expr(self, e: Expr, params: QTParams
                          ) -> tuple[str, str, list, bool, bool, Expr]:
        """Normalise + fingerprint an expression for plan-cache lookup.

        Returns ``(key, struct_key, slot_nids, t, upper, normal_form)``
        where ``struct_key`` covers the expression shape, tau, QTParams
        and operand *structures* (input-identity-free — the cross-session
        serving cache groups by it) and ``key`` appends the identity of
        the bound inputs (this session's full plan-cache key).
        """
        upper = expr_upper(e)
        e = rewrite(e)
        t = False
        while isinstance(e, Transpose):
            t, e = not t, e.a
        key, slot_nids = fingerprint(e, self._structure_fp, params)
        struct_key = f"{key}:t{int(t)}"
        # input identity is part of the cache key: a structurally
        # identical expression over *different* matrices compiles its own
        # program instead of silently rebinding (and overwriting) the
        # first plan's input chunks
        key = f"{struct_key}:b{tuple(slot_nids)}"
        return key, struct_key, slot_nids, t, upper, e

    def _compile_expr(self, e: Expr, params: QTParams
                      ) -> tuple[Plan, list]:
        key, struct_key, slot_nids, t, upper, expr = \
            self._fingerprint_expr(e, params)
        plan = self._plans.get(key)
        if plan is None:
            names: list = []
            for slot, nid in enumerate(slot_nids):
                name = self._input_names.get(nid, f"x{slot}")
                while name in names:    # keep every slot name bindable
                    name += "_"
                names.append(name)
            plan = Plan(self, expr, params, key, slot_nids, names,
                        struct_key=struct_key)
            plan.out_t = t
            plan.out_upper = upper
            self._plans.put(key, plan)
            for observer in list(self._plan_observers):
                observer(plan)
        return plan, slot_nids

    def _force(self, m: Matrix) -> None:
        """Materialise a pending lazy matrix through the plan cache.

        The cache key includes input identity, so a hit always has the
        expression's own inputs bound: forcing replays the recorded
        program against their *current* values and never rebinds (or
        overwrites) anything.  The plan's output chunks are refreshed in
        place, so handles from earlier runs of the same plan observe the
        new values.
        """
        plan, _ = self._compile_expr(m._expr, m.params)
        out = plan._run({})
        m.node, m._t, m._trunc = out.node, out._t, out._trunc
        m._expr = None

    def _structure_fp(self, nid: Optional[int]) -> str:
        fp = self._structfp.get(nid)
        if fp is None:
            fp = self._structfp[nid] = qt_structure_fp(self.graph, nid)
        return fp

    # -- execution ----------------------------------------------------------
    def flush(self) -> None:
        """Run deferred leaf-engine waves (readback does this for you)."""
        self.graph.flush()

    @property
    def scheduler(self):
        """The session's runtime simulator (created on first use)."""
        if self._sched is None:
            from repro.runtime.scheduler import Scheduler
            self._sched = Scheduler(cost=self.cost,
                                    cache_bytes=self.cache_bytes,
                                    seed=self.seed, dedup=self.dedup)
        return self._sched

    def simulate(self, p: Optional[int] = None,
                 placement: Optional[str] = None,
                 fresh_stats: bool = False, faults: Any = None):
        """Replay all not-yet-simulated tasks on the virtual cluster.

        The scheduler is persistent across calls (chunk placements from an
        earlier phase — e.g. the task program that *built* the inputs —
        carry over, paper §7).  ``fresh_stats=True`` zeroes the per-worker
        counters first so the returned
        :class:`~repro.runtime.scheduler.SimReport` isolates this phase's
        communication.  ``p``/``placement`` default to the session's and
        are pinned by the first call.  To re-simulate a compiled plan's
        fixed program use :meth:`Plan.simulate`, which replays through
        :meth:`~repro.runtime.scheduler.Scheduler.replay`.

        ``faults`` injects a deterministic
        :class:`~repro.runtime.recovery.FaultSchedule` (or an iterable of
        :class:`~repro.runtime.recovery.FaultEvent`) into this run's
        simulated timeline — worker deaths, stragglers, elastic
        join/leave — with lineage or replication recovery (DESIGN.md
        §10).  The returned report carries the recovery counters
        (``tasks_recomputed``, ``chunks_lost``, ``bytes_rereplicated``).
        Dead workers stay out of the pool for later calls.
        """
        sched = self.scheduler
        if fresh_stats:
            sched.reset_stats()
        placement = _normalize_placement(placement)
        if sched.store is None:     # first run: session defaults apply
            p = p or self.p
            placement = placement or self.placement
        if self.tracer.enabled:
            with self.tracer.span("session.simulate", track="session",
                                  p=p, placement=placement,
                                  fresh_stats=fresh_stats) as sp:
                rep = sched.run(self.graph, n_workers=p,
                                placement=placement, faults=faults)
                sp.set(makespan_s=rep.makespan,
                       tasks=sum(rep.tasks_per_worker),
                       bytes_received=sum(rep.bytes_received))
        else:
            rep = sched.run(self.graph, n_workers=p, placement=placement,
                            faults=faults)
        self._last_report = rep
        return rep

    def reset_stats(self) -> None:
        """Zero per-worker comm counters; placements persist (§7)."""
        self.scheduler.reset_stats()

    def free(self, matrix: Matrix) -> int:
        """Release a consumed matrix's chunks from the simulated store.

        Long iterative runs otherwise leak every intermediate into the
        :class:`~repro.core.chunks.ChunkStore` (owned-bytes accounting
        grows without bound).  Frees every chunk this session's scheduler
        placed for (a) the matrix's quadtree and (b) the task program
        that produced it — the consumed multiply/add partials that are
        not part of the result tree — and drops their placement entries;
        returns the number of owned bytes released.  With ``dedup=True``
        frees are reference counted — content shared with a live
        registration survives.  Without dedup, substructure shared
        through identifier-copy aliasing (e.g. an add with a NIL operand
        returns the other operand's chunks) is freed too, so only free
        matrices whose values you no longer read.  Compiled plans manage
        their own program chunks (:meth:`Plan.simulate` frees and
        re-places them per replay); :meth:`free` is for eager loops and
        consumed inputs.
        """
        if not isinstance(matrix, Matrix):
            raise TypeError(f"free: expected a Matrix, got {type(matrix)!r}")
        if matrix._expr is not None:
            return 0                    # never materialised: nothing placed
        from .plan import _subtree_nids
        targets = set(_subtree_nids(self.graph, matrix.node))
        targets.update(matrix._prog or ())
        # materialised transposes are shared session-wide through
        # _transpose_cache (an eager program that registered one may not
        # be its only consumer): keep their chunks and placements
        for tnid in self._transpose_cache.values():
            if tnid is not None:
                targets.difference_update(
                    _subtree_nids(self.graph, tnid))
        # engine hook *before* the scheduler early-return: the mesh
        # executor holds device-resident buffers and ownership/residency
        # entries for these leaves even when nothing was ever simulated
        if self.graph._engine is not None:
            self.graph._engine.free_chunks(self.graph, targets)
        sched = self._sched
        if sched is None or sched.store is None:
            return 0
        before = sum(s.owned_bytes for s in sched.store.stats)
        sched.release(self.graph, targets)
        # alias entries (identifier copies) pointing into the freed
        # chunks.  This scans the full placement map — an identity test,
        # deliberately not a chunk-id test, so dedup-shared cids owned by
        # other live matrices keep their entries; O(placements) per free
        # is fine for the simulator's bookkeeping.
        for k in [k for k, _ in list(sched.placement.items())
                  if self.graph.resolve(k) in targets]:
            sched.placement.pop(k, None)
        return before - sum(s.owned_bytes for s in sched.store.stats)

    # -- reporting ----------------------------------------------------------
    def task_counts(self) -> dict[str, int]:
        """Tasks registered so far, by kind (paper Figs 3-4 inputs)."""
        return self.graph.count_kinds()

    def tasks_per_level(self) -> dict[int, int]:
        """Multiplication tasks per quadtree level (eq (1) family)."""
        from repro.core.multiply import count_tasks_per_level
        return count_tasks_per_level(self.graph)

    @property
    def n_multiply_tasks(self) -> int:
        from repro.core.multiply import total_multiply_tasks
        return total_multiply_tasks(self.graph)

    @property
    def n_add_tasks(self) -> int:
        from repro.core.multiply import total_add_tasks
        return total_add_tasks(self.graph)

    @property
    def flops(self) -> float:
        from repro.core.multiply import total_flops
        return total_flops(self.graph)

    def engine_stats(self) -> dict:
        """Leaf-engine report (batched waves, padding, kernel wall time)."""
        self.flush()
        return self.graph.engine.stats()

    # -- observability (DESIGN.md §8) ----------------------------------------
    @contextlib.contextmanager
    def tracing(self, tracer: Optional[Tracer] = None):
        """Record spans for the enclosed block only.

        >>> sess = Session(engine="pallas")
        >>> with sess.tracing() as tr:          # doctest: +SKIP
        ...     C = (A @ B).to_dense()
        >>> tr.find("engine.wave")              # doctest: +SKIP

        The previous tracer (usually the shared no-op) is restored on
        exit, even on error.
        """
        prev = self.tracer
        tr = tracer if tracer is not None else Tracer()
        self._set_tracer(tr)
        try:
            yield tr
        finally:
            self._set_tracer(prev)

    def _set_tracer(self, tracer) -> None:
        self.tracer = tracer
        self.graph.tracer = tracer

    def metrics(self) -> list[MetricSet]:
        """Unified counter view of everything this session observed.

        One :class:`~repro.obs.metrics.MetricSet` per active source, all
        in the same ``{name, unit, per_worker[], total}`` schema: the
        leaf engine's wave/communication counters (measured per-device
        bytes under ``engine="mesh"`` — the Table-1 metric) and, when
        :meth:`simulate` has run, the simulator's per-worker counters
        from the most recent report (identical values to the legacy
        :class:`~repro.runtime.scheduler.SimReport` fields).
        """
        out = [from_engine_stats(self.engine_stats())]
        if self._last_report is not None:
            out.append(from_sim_report(self._last_report))
        pc = self._plan_cache_metrics()
        if pc is not None:
            out.append(pc)
        pr = self._plan_recompile_metrics()
        if pr is not None:
            out.append(pr)
        return out

    def _plan_cache_metrics(self) -> Optional[MetricSet]:
        """Plan-cache counters, or None while the cache is untouched.

        Aggregates the session cache with every cached plan's bounded
        ``_recompiled`` successor cache (the other LRU this session
        owns).  Eager sessions never touch either, so their metrics()
        sources are unchanged.
        """
        c = self._plans.counters()
        for plan in self._plans.values():
            rc = plan._recompiled.counters()
            for k in ("hits", "misses", "evictions"):
                c[k] += rc[k]
            c["size"] += rc["size"]
        if c["hits"] + c["misses"] + c["evictions"] == 0:
            return None
        ms = MetricSet(source="plan-cache")
        for k in ("hits", "misses", "evictions", "size"):
            ms.add(f"plan_cache_{k}", "count", [c[k]])
        return ms

    def _plan_recompile_metrics(self) -> Optional[MetricSet]:
        """Recompile-successor counters, or None while nothing recompiled.

        Changing-sparsity iterations run through
        ``plan.run(recompile=True)``: a *hit* is a structure-mismatch
        run served by an already-compiled successor's zero-task replay,
        a *miss* had to compile a fresh plan.  Mirrors the "plan-cache"
        source so drifting-structure chains are observable per session.
        """
        hits = sum(p._succ_hits for p in self._plans.values())
        misses = sum(p._succ_misses for p in self._plans.values())
        if hits + misses == 0:
            return None
        ms = MetricSet(source="plan-recompile")
        ms.add("plan_recompile_hits", "count", [hits])
        ms.add("plan_recompile_misses", "count", [misses])
        return ms


def _first_input_n(e: Expr) -> int:
    from .expr import expr_inputs
    inputs = expr_inputs(e)
    if not inputs:
        raise ValueError("compile: expression has no inputs")
    return inputs[0].n
