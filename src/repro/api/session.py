"""Session: one object owning the whole Chunks-and-Tasks machinery.

The paper's matrix library (made explicit in the follow-up "Chunks and
Tasks Matrix Library 2.0", arXiv:2011.11762) exposes matrices as objects
whose algebra hides chunk identifiers and task registration.  A
:class:`Session` is this repo's rendering of that front door: it owns the
:class:`~repro.core.tasks.CTGraph`, the leaf engine, the runtime
:class:`~repro.runtime.scheduler.Scheduler` (and through it the
:class:`~repro.core.chunks.ChunkStore`), the
:class:`~repro.core.tasks.CostModel` and the chunk placement policy, so a
paper experiment is a handful of lines::

    from repro import Session

    sess = Session(engine="pallas", placement="parent", leaf_n=64, bs=8)
    A = sess.from_dense(a)
    B = sess.from_dense(b)
    sess.simulate(p=8)                      # build phase places inputs
    C = A @ B
    rep = sess.simulate(fresh_stats=True)   # measured multiply phase
    C.to_dense(), rep.max_bytes_received, rep.crit.length_s

The facade *compiles to* the documented internal layer — the ``qt_*``
free functions of :mod:`repro.core.quadtree` / :mod:`repro.core.multiply`
— and adds no graph structure of its own, so the paper's eq (1) task
counts and the numpy/pallas engine equivalence pin it exactly.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import numpy as np

from repro.core.quadtree import QTParams, qt_from_coo, qt_from_dense
from repro.core.tasks import CostModel, CTGraph
from repro.runtime.scheduler import PLACEMENTS

from .matrix import Matrix

#: accepted spellings of the scheduler placement policies: every canonical
#: policy name passes through, plus shorthand aliases
PLACEMENT_ALIASES = {p: p for p in PLACEMENTS}
PLACEMENT_ALIASES.update({"parent": "parent-worker", "rr": "round-robin"})


def _normalize_placement(placement: Optional[str]) -> Optional[str]:
    if placement is None:
        return None
    try:
        return PLACEMENT_ALIASES[placement]
    except KeyError:
        raise ValueError(
            f"unknown placement {placement!r}; pick one of "
            f"{sorted(set(PLACEMENT_ALIASES.values()))}") from None


class Session:
    """Owns graph + engine + simulator behind one constructor.

    Parameters
    ----------
    engine : ``"numpy"`` (reference, immediate), ``"pallas"`` (deferred,
        cross-leaf batched kernel waves) or a
        :class:`~repro.core.engine.LeafEngine` instance.  One stateful
        engine instance serves one session/graph; rebinding raises
        :class:`~repro.core.engine.EngineRebindError`.
    placement : default chunk placement for :meth:`simulate` —
        ``"parent"``/``"parent-worker"`` (the paper's locality model),
        ``"round-robin"`` or ``"random"``.
    leaf_n, bs : quadtree leaf dimension and leaf-internal blocksize used
        for matrices built by this session (per-matrix overrides via the
        ``leaf_n=``/``bs=`` kwargs of the constructors).
    p : default simulated worker count for :meth:`simulate`.
    tau : default SpAMM truncation threshold for ``A @ B`` /
        :meth:`Matrix.multiply` on plain operands (DESIGN.md §5).  The
        default 0.0 multiplies exactly; a positive tau prunes every
        recursive product with ``||A'||_F ||B'||_F < tau`` and records a
        worst-case error bound on the result
        (:attr:`~repro.api.matrix.Matrix.error_bound`).
    cost, cache_bytes, seed, dedup : forwarded to the runtime
        :class:`~repro.runtime.scheduler.Scheduler` / chunk store
        (``dedup=True`` enables content-hash chunk deduplication).
    """

    def __init__(self, engine: Any = "numpy",
                 placement: str = "parent-worker", leaf_n: int = 64,
                 bs: int = 8, p: Optional[int] = None,
                 cost: Optional[CostModel] = None,
                 cache_bytes: int = 1 << 62, seed: int = 0,
                 dedup: bool = False, tau: float = 0.0):
        self.graph = CTGraph(engine=engine)
        self.leaf_n = leaf_n
        self.bs = bs
        self.placement = _normalize_placement(placement)
        self.p = p
        self.cost = cost
        self.cache_bytes = cache_bytes
        self.seed = seed
        self.dedup = dedup
        self.tau = float(tau)
        self._sched = None
        # node id -> materialised-transpose node id, shared by all handles
        # so a reused lazy .T registers its task program only once
        self._transpose_cache: dict[int, Optional[int]] = {}

    def __repr__(self) -> str:
        eng = getattr(self.graph, "_engine_spec", None)
        eng = getattr(eng, "name", eng) or "numpy"
        return (f"Session(engine={eng!r}, placement={self.placement!r}, "
                f"leaf_n={self.leaf_n}, bs={self.bs}, "
                f"tasks={len(self.graph.nodes)})")

    # -- matrix construction ------------------------------------------------
    def params_for(self, n: int, leaf_n: Optional[int] = None,
                   bs: Optional[int] = None) -> QTParams:
        """The :class:`QTParams` chunk this session uses for dimension n."""
        return QTParams(n, leaf_n or self.leaf_n, bs or self.bs)

    def from_dense(self, a: np.ndarray, upper: bool = False,
                   tol: float = 0.0, leaf_n: Optional[int] = None,
                   bs: Optional[int] = None) -> Matrix:
        """Build a quadtree matrix from a dense array (task program)."""
        a = np.asarray(a)
        params = self.params_for(a.shape[0], leaf_n, bs)
        nid = qt_from_dense(self.graph, a, params, upper=upper, tol=tol)
        return Matrix(self, nid, params, upper=upper)

    def from_pattern(self, rows: np.ndarray, cols: np.ndarray, n: int,
                     value_fn: Optional[Callable] = None,
                     upper: bool = False, leaf_n: Optional[int] = None,
                     bs: Optional[int] = None) -> Matrix:
        """Build from nonzero coordinates without a dense detour
        (:func:`~repro.core.quadtree.qt_from_coo`)."""
        params = self.params_for(n, leaf_n, bs)
        nid = qt_from_coo(self.graph, rows, cols, params,
                          value_fn=value_fn, upper=upper)
        return Matrix(self, nid, params, upper=upper)

    def zeros(self, n: int, upper: bool = False,
              leaf_n: Optional[int] = None, bs: Optional[int] = None
              ) -> Matrix:
        """The all-zero (NIL) matrix of dimension n."""
        return Matrix(self, None, self.params_for(n, leaf_n, bs),
                      upper=upper)

    # -- execution ----------------------------------------------------------
    def flush(self) -> None:
        """Run deferred leaf-engine waves (readback does this for you)."""
        self.graph.flush()

    @property
    def scheduler(self):
        """The session's runtime simulator (created on first use)."""
        if self._sched is None:
            from repro.runtime.scheduler import Scheduler
            self._sched = Scheduler(cost=self.cost,
                                    cache_bytes=self.cache_bytes,
                                    seed=self.seed, dedup=self.dedup)
        return self._sched

    def simulate(self, p: Optional[int] = None,
                 placement: Optional[str] = None,
                 fresh_stats: bool = False):
        """Replay all not-yet-simulated tasks on the virtual cluster.

        The scheduler is persistent across calls (chunk placements from an
        earlier phase — e.g. the task program that *built* the inputs —
        carry over, paper §7).  ``fresh_stats=True`` zeroes the per-worker
        counters first so the returned
        :class:`~repro.runtime.scheduler.SimReport` isolates this phase's
        communication.  ``p``/``placement`` default to the session's and
        are pinned by the first call.
        """
        sched = self.scheduler
        if fresh_stats:
            sched.reset_stats()
        placement = _normalize_placement(placement)
        if sched.store is None:     # first run: session defaults apply
            p = p or self.p
            placement = placement or self.placement
        return sched.run(self.graph, n_workers=p, placement=placement)

    def reset_stats(self) -> None:
        """Zero per-worker comm counters; placements persist (§7)."""
        self.scheduler.reset_stats()

    # -- reporting ----------------------------------------------------------
    def task_counts(self) -> dict[str, int]:
        """Tasks registered so far, by kind (paper Figs 3-4 inputs)."""
        return self.graph.count_kinds()

    def tasks_per_level(self) -> dict[int, int]:
        """Multiplication tasks per quadtree level (eq (1) family)."""
        from repro.core.multiply import count_tasks_per_level
        return count_tasks_per_level(self.graph)

    @property
    def n_multiply_tasks(self) -> int:
        from repro.core.multiply import total_multiply_tasks
        return total_multiply_tasks(self.graph)

    @property
    def n_add_tasks(self) -> int:
        from repro.core.multiply import total_add_tasks
        return total_add_tasks(self.graph)

    @property
    def flops(self) -> float:
        from repro.core.multiply import total_flops
        return total_flops(self.graph)

    def engine_stats(self) -> dict:
        """Leaf-engine report (batched waves, padding, kernel wall time)."""
        self.flush()
        return self.graph.engine.stats()
