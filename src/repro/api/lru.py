"""Bounded LRU mapping with hit/miss/eviction accounting.

The plan caches (``Session._plans``, ``Plan._recompiled``, and the
serving layer's cross-session :class:`~repro.serve.cache.SharedPlanCache`)
all grew without bound before the serving subsystem landed — a leak once
a server replays thousands of request shapes through one session.  This
is the one bounded mapping they share: insertion-ordered, recency-updated
on :meth:`get`, evicting the least-recently-used entry past ``cap``, with
counters the observability layer surfaces through ``Session.metrics()``.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

__all__ = ["LRUCache"]


class LRUCache:
    """A dict bounded to ``cap`` entries with LRU eviction + counters.

    ``cap <= 0`` means unbounded (counters still accumulate).  Eviction
    calls ``on_evict(key, value)`` when provided — the serving cache uses
    it to drop replica lists coherently.
    """

    def __init__(self, cap: int = 0,
                 on_evict: Optional[Callable] = None):
        self.cap = int(cap)
        self.on_evict = on_evict
        self._d: dict = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -- mapping surface -----------------------------------------------------
    def get(self, key, default=None):
        """Recency-updating lookup; counts a hit or a miss."""
        try:
            v = self._d.pop(key)
        except KeyError:
            self.misses += 1
            return default
        self._d[key] = v        # re-insert at most-recent position
        self.hits += 1
        return v

    def peek(self, key, default=None):
        """Lookup without touching recency or the hit/miss counters."""
        return self._d.get(key, default)

    def put(self, key, value) -> None:
        """Insert/overwrite at most-recent position; evict past cap."""
        self._d.pop(key, None)
        self._d[key] = value
        while self.cap > 0 and len(self._d) > self.cap:
            old_key = next(iter(self._d))
            old_val = self._d.pop(old_key)
            self.evictions += 1
            if self.on_evict is not None:
                self.on_evict(old_key, old_val)

    def pop(self, key, default=None):
        return self._d.pop(key, default)

    def setdefault(self, key, value):
        """Insert only if absent; returns the stored value (no counting).

        An existing key is refreshed to the most-recent position: the
        caller just used it, and leaving it at its original slot would
        let a hot entry (e.g. a Plan's recompiled successor re-fetched
        every run) be evicted at cap despite being the most-used one.
        """
        if key in self._d:
            v = self._d.pop(key)
            self._d[key] = v        # recency refresh, no hit/miss counting
            return v
        self.put(key, value)
        return value

    def __contains__(self, key) -> bool:
        return key in self._d

    def __len__(self) -> int:
        return len(self._d)

    def __iter__(self):
        return iter(self._d)

    def keys(self):
        return self._d.keys()

    def values(self):
        return self._d.values()

    def items(self):
        return self._d.items()

    # -- reporting -----------------------------------------------------------
    def counters(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "size": len(self._d),
                "cap": self.cap}

    def __repr__(self) -> str:
        return (f"LRUCache(cap={self.cap}, size={len(self._d)}, "
                f"hits={self.hits}, misses={self.misses}, "
                f"evictions={self.evictions})")
