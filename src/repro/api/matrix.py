"""Matrix: operator-overloaded handle over a quadtree chunk hierarchy.

A :class:`Matrix` wraps ``(session, root node id, QTParams)`` plus two
bits of algebraic state — a **lazy transpose flag** and the symmetric
**upper-storage** marker — and compiles every operation down to the
documented internal ``qt_*`` task programs:

* ``C = A @ B``   → :func:`~repro.core.multiply.qt_multiply` with the
  pending transpose flags folded into Algorithm 1's ``op(A) op(B)``;
  a symmetric upper-storage operand routes to
  :func:`~repro.core.multiply.qt_sym_multiply` automatically.
* ``A + B``       → :func:`~repro.core.multiply.qt_add`; mismatched lazy
  transposes materialise one side via
  :func:`~repro.core.multiply.qt_transpose` first.
* ``A.T``         → flips the lazy flag (no task); symmetric matrices
  return themselves (A = Aᵀ).
* ``A.sym_square()`` / ``A.syrk()`` / ``S.sym_multiply(B, side=...)`` —
  the §3.3 symmetric task programs.

Readback (:meth:`to_dense`, :meth:`frob2`, :meth:`nnz_blocks`,
:meth:`stats`) auto-flushes deferred Pallas leaf waves, so the handle is
always safe to inspect.  NIL (all-zero) matrices are first-class: their
root id is None and every operation short-circuits exactly as the
fallback-execute semantics of Algorithms 1-2 prescribe.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.multiply import (TruncationReport, qt_add, qt_multiply,
                                 qt_sym_multiply, qt_sym_square, qt_syrk,
                                 qt_transpose)
from repro.core.quadtree import (QTParams, qt_frob2, qt_norm2, qt_stats,
                                 qt_to_dense)


class Matrix:
    """Handle to a quadtree matrix registered in a session's task graph."""

    __slots__ = ("session", "node", "params", "_t", "upper", "_trunc")

    def __init__(self, session, node: Optional[int], params: QTParams,
                 t: bool = False, upper: bool = False,
                 trunc: Optional[TruncationReport] = None):
        self.session = session
        self.node = node            # root chunk's node id; None == NIL
        self.params = params
        self._t = t and not upper   # symmetric storage: A == Aᵀ
        self.upper = upper
        self._trunc = trunc         # TruncationReport of the producing multiply

    # -- construction (delegates to the session) ----------------------------
    @classmethod
    def from_dense(cls, session, a: np.ndarray, **kw) -> "Matrix":
        """``Matrix.from_dense(sess, a)`` == ``sess.from_dense(a)``."""
        return session.from_dense(a, **kw)

    @classmethod
    def from_pattern(cls, session, rows, cols, n: int, **kw) -> "Matrix":
        """Build from nonzero coordinates (no dense detour)."""
        return session.from_pattern(rows, cols, n, **kw)

    # -- bookkeeping ---------------------------------------------------------
    @property
    def n(self) -> int:
        """Global matrix dimension."""
        return self.params.n

    @property
    def is_nil(self) -> bool:
        """True for the all-zero matrix (NIL chunk id at the root)."""
        return self.session.graph.is_nil(self.node)

    def __repr__(self) -> str:
        flags = "".join([".T" if self._t else "",
                         ", upper" if self.upper else "",
                         ", NIL" if self.node is None else ""])
        return f"Matrix(n={self.n}, node={self.node}{flags})"

    def _check(self, other: "Matrix", op: str) -> None:
        if not isinstance(other, Matrix):
            raise TypeError(f"{op}: expected a Matrix, got {type(other)!r}")
        if other.session is not self.session:
            raise ValueError(f"{op}: operands belong to different Sessions")
        if other.params != self.params:
            raise ValueError(f"{op}: operand quadtree parameters differ "
                             f"({self.params} vs {other.params})")

    def _materialized(self) -> Optional[int]:
        """Root id with any pending lazy transpose materialised.

        Materialisations are cached per source node on the session, so a
        reused ``.T`` handle registers the transpose task program once.
        """
        if not self._t:
            return self.node
        cache = self.session._transpose_cache
        if self.node not in cache:
            cache[self.node] = qt_transpose(self.session.graph,
                                            self.params, self.node)
        return cache[self.node]

    # -- algebra -------------------------------------------------------------
    @property
    def T(self) -> "Matrix":
        """Lazy transpose: flips a flag, registers no task.  The flag is
        folded into the next multiply (Algorithm 1's op(A) op(B))."""
        if self.upper:
            return self             # symmetric: A == Aᵀ
        return Matrix(self.session, self.node, self.params, t=not self._t,
                      trunc=self._trunc)

    def transpose(self) -> "Matrix":
        return self.T

    def __matmul__(self, other: "Matrix") -> "Matrix":
        """C = A B; a ``Session(tau=...)`` default makes this the
        error-controlled truncated multiply (see :meth:`multiply`)."""
        return self.multiply(other)

    def multiply(self, other: "Matrix", tau: Optional[float] = None
                 ) -> "Matrix":
        """C = op(A) op(B) with SpAMM-style hierarchical norm truncation.

        ``tau`` (default: the session's ``tau``) prunes every recursive
        product — at any quadtree level and within leaf block pairs —
        whose Frobenius-norm product is below it.  The result carries a
        :class:`~repro.core.multiply.TruncationReport`; read the
        worst-case ``||C_exact - C_tau||_F`` bound via
        :attr:`error_bound`.  ``tau=0`` registers a task graph identical
        to the exact multiply.  Truncation applies to plain operands;
        symmetric upper-storage operands route to ``sym_multiply``
        untruncated (an explicit ``tau > 0`` then raises).
        """
        self._check(other, "@")
        g, p = self.session.graph, self.params
        explicit = tau is not None
        tau = float(self.session.tau if tau is None else tau)
        if self.upper and other.upper:
            raise ValueError(
                "@: both operands use symmetric upper storage; the library "
                "multiplies symmetric x plain (qt_sym_multiply). Rebuild "
                "one operand without upper=True")
        if self.upper or other.upper:
            if explicit and tau > 0.0:
                raise ValueError(
                    "multiply(tau=...): truncation needs plain (non-upper) "
                    "operands; sym_multiply is untruncated")
            # a session-default tau routes silently to the untruncated
            # symmetric task program
            if self.upper:      # C = S B
                nid = qt_sym_multiply(g, p, self.node,
                                      other._materialized(), side="left")
            else:               # C = B S
                nid = qt_sym_multiply(g, p, other.node,
                                      self._materialized(), side="right")
            return Matrix(self.session, nid, p)
        rep = TruncationReport(tau=tau)
        if tau > 0.0:
            nid = qt_multiply(g, p, self.node, other.node,
                              ta=self._t, tb=other._t, tau=tau, trunc=rep)
        else:
            # tau == 0: exact path, byte-for-byte the same registrations
            nid = qt_multiply(g, p, self.node, other.node,
                              ta=self._t, tb=other._t)
        return Matrix(self.session, nid, p, trunc=rep)

    def __add__(self, other: "Matrix") -> "Matrix":
        self._check(other, "+")
        if self.upper != other.upper:
            raise ValueError("+: cannot mix symmetric upper storage and "
                             "plain matrices; rebuild one operand")
        g, p = self.session.graph, self.params
        if self._t == other._t:
            nid = qt_add(g, p, self.node, other.node)
            return Matrix(self.session, nid, p, t=self._t,
                          upper=self.upper)
        # op mismatch: addition has no op(A) slot — materialise transposes
        nid = qt_add(g, p, self._materialized(), other._materialized())
        return Matrix(self.session, nid, p, upper=self.upper)

    def sym_square(self) -> "Matrix":
        """C = A² for symmetric A in upper storage (paper §3.3): half the
        multiplies of a general product."""
        if not self.upper:
            raise ValueError("sym_square needs symmetric upper storage: "
                             "build with from_dense(..., upper=True)")
        nid = qt_sym_square(self.session.graph, self.params, self.node)
        return Matrix(self.session, nid, self.params, upper=True)

    def syrk(self, trans: bool = False) -> "Matrix":
        """C = A Aᵀ (or Aᵀ A with ``trans=True``); C in upper storage."""
        if self.upper:
            raise ValueError("syrk of a symmetric matrix is sym_square")
        nid = qt_syrk(self.session.graph, self.params, self.node,
                      trans=trans != self._t)   # lazy .T folds into trans
        return Matrix(self.session, nid, self.params, upper=True)

    def sym_multiply(self, other: "Matrix", side: str = "left") -> "Matrix":
        """C = S B (``side="left"``) or B S (``side="right"``); self is the
        symmetric upper-storage S."""
        self._check(other, "sym_multiply")
        if not self.upper or other.upper:
            raise ValueError("sym_multiply: self must be symmetric upper "
                             "storage and other plain")
        nid = qt_sym_multiply(self.session.graph, self.params, self.node,
                              other._materialized(), side=side)
        return Matrix(self.session, nid, self.params)

    # -- readback (auto-flushes deferred engine waves) ----------------------
    def to_dense(self) -> np.ndarray:
        """Dense numpy array (symmetric storage expands to the full
        matrix); flushes pending Pallas waves first."""
        d = qt_to_dense(self.session.graph, self.node, self.params)
        return np.ascontiguousarray(d.T) if self._t else d

    def frob2(self) -> float:
        """Squared Frobenius norm (transpose-invariant)."""
        return qt_frob2(self.session.graph, self.node)

    def norm2(self) -> float:
        """Cached squared Frobenius norm (the SpAMM pruning quantity);
        numerically identical to :meth:`frob2`."""
        return qt_norm2(self.session.graph, self.node)

    # -- truncation readback --------------------------------------------------
    @property
    def truncation(self) -> Optional[TruncationReport]:
        """The :class:`~repro.core.multiply.TruncationReport` of the
        multiply that produced this matrix, or None for other origins."""
        return self._trunc

    @property
    def error_bound(self) -> float:
        """Worst-case ``||C_exact - C_tau||_F`` of the producing truncated
        multiply; 0.0 for exact results (tau=0 prunes nothing)."""
        return self._trunc.error_bound if self._trunc is not None else 0.0

    def stats(self) -> dict:
        """Chunk/occupancy statistics of the quadtree (leaf chunks,
        internal chunks, nonzero blocks, bytes, depth)."""
        self.session.flush()
        return qt_stats(self.session.graph, self.node)

    def nnz_blocks(self) -> int:
        """Number of nonzero leaf blocks."""
        return self.stats()["nnz_blocks"]
