"""Matrix: operator-overloaded handle over a quadtree chunk hierarchy.

A :class:`Matrix` wraps ``(session, root node id, QTParams)`` plus two
bits of algebraic state — a **lazy transpose flag** and the symmetric
**upper-storage** marker.  Operators no longer call the ``qt_*`` layer
directly: every operation builds an :mod:`~repro.api.expr` node and hands
it to the session, which lowers it through the rewrite pipeline of
:mod:`repro.api.plan` —

* **eagerly** (``Session(lazy=False)``, default): at once, registering a
  task program byte-identical to the pre-IR facade (pinned by
  tests/test_api.py and tests/test_expr_plan.py);
* **lazily** (``Session(lazy=True)``): on first readback, as a compiled,
  cached, re-executable :class:`~repro.api.plan.Plan`.

Operator surface:

* ``C = A @ B`` / ``A.multiply(B, tau=)`` — Algorithm 1 with transpose
  flags folded in; symmetric upper operands auto-route to sym_multiply.
* ``A + B``, ``A - B`` — Algorithm 2 (subtraction lowers through the
  ``scale`` task program).
* ``alpha * A`` / ``A * alpha`` / ``-A`` — scalar scaling.
* ``A.T`` — lazy flag (no task) on materialised handles, a folded
  ``Transpose`` node on pending ones; symmetric matrices return self.
* ``A.sym_square()`` / ``A.syrk()`` / ``S.sym_multiply(B, side=)`` — the
  §3.3 symmetric task programs.  These are **untruncated**: a nonzero
  effective tau (explicit or session default) raises instead of silently
  computing an exact result (see :meth:`sym_square`).

Readback (:meth:`to_dense`, :meth:`frob2`, :meth:`trace`,
:meth:`nnz_blocks`, :meth:`stats`) forces pending expressions and flushes
deferred Pallas leaf waves, so the handle is always safe to inspect.  NIL
(all-zero) matrices are first-class: their root id is None and every
operation short-circuits exactly as the fallback-execute semantics of
Algorithms 1-2 prescribe.
"""
from __future__ import annotations

import numbers
from typing import Optional

import numpy as np

from repro.core.multiply import TruncationReport
from repro.core.quadtree import (QTParams, qt_extract, qt_frob2, qt_norm2,
                                 qt_stats, qt_to_dense, qt_trace)

from .expr import (Add, Expr, Input, InvChol, MatMul, Scale, SymMul,
                   SymSquare, Syrk, Transpose, TriSolve, expr_upper)

_SYM_TAU_ERROR = (
    "{op}: the symmetric task programs are untruncated, but the effective "
    "truncation threshold is tau={tau!r} ({src}); pass tau=0 explicitly "
    "to compute exactly, or rebuild the operand as a plain (non-upper) "
    "matrix for a truncated multiply")


def _tau_src(explicit: bool) -> str:
    return "passed explicitly" if explicit else "from the Session default"


class Matrix:
    """Handle to a quadtree matrix registered in a session's task graph."""

    __slots__ = ("session", "node", "params", "_t", "upper", "_trunc",
                 "_expr", "name", "_prog")

    def __init__(self, session, node: Optional[int], params: QTParams,
                 t: bool = False, upper: bool = False,
                 trunc: Optional[TruncationReport] = None,
                 expr: Optional[Expr] = None, name: Optional[str] = None):
        self.session = session
        self.node = node            # root chunk's node id; None == NIL
        self.params = params
        self._t = t and not upper   # symmetric storage: A == Aᵀ
        self.upper = upper
        self._trunc = trunc         # TruncationReport of the producing multiply
        self._expr = expr           # pending Expr (lazy mode) or None
        self.name = name            # plan input-slot name (rebinding)
        self._prog = None           # eager producing-program nid range (free)

    # -- construction (delegates to the session) ----------------------------
    @classmethod
    def from_dense(cls, session, a: np.ndarray, **kw) -> "Matrix":
        """``Matrix.from_dense(sess, a)`` == ``sess.from_dense(a)``."""
        return session.from_dense(a, **kw)

    @classmethod
    def from_pattern(cls, session, rows, cols, n: int, **kw) -> "Matrix":
        """Build from nonzero coordinates (no dense detour)."""
        return session.from_pattern(rows, cols, n, **kw)

    # -- bookkeeping ---------------------------------------------------------
    @property
    def n(self) -> int:
        """Global matrix dimension."""
        return self.params.n

    @property
    def is_lazy(self) -> bool:
        """True while this handle is an unevaluated expression."""
        return self._expr is not None

    @property
    def is_nil(self) -> bool:
        """True for the all-zero matrix (NIL chunk id at the root)."""
        self._ensure()
        return self.session.graph.is_nil(self.node)

    def __repr__(self) -> str:
        if self._expr is not None:
            return (f"Matrix(n={self.n}, "
                    f"lazy {type(self._expr).__name__} expression)")
        flags = "".join([".T" if self._t else "",
                         ", upper" if self.upper else "",
                         ", NIL" if self.node is None else ""])
        return f"Matrix(n={self.n}, node={self.node}{flags})"

    def _check(self, other: "Matrix", op: str) -> None:
        if not isinstance(other, Matrix):
            raise TypeError(f"{op}: expected a Matrix, got {type(other)!r}")
        if other.session is not self.session:
            raise ValueError(f"{op}: operands belong to different Sessions")
        if other.params != self.params:
            raise ValueError(f"{op}: operand quadtree parameters differ "
                             f"({self.params} vs {other.params})")

    def _ensure(self) -> None:
        """Force a pending expression (lazy mode) before readback."""
        if self._expr is not None:
            self.session._force(self)

    def _as_expr(self) -> Expr:
        """This handle as an Expr operand (pending state or bound input)."""
        if self._expr is not None:
            return self._expr
        e: Expr = Input(self.node, self.params.n, self.upper)
        return Transpose(e) if self._t else e

    def _result(self, e: Expr) -> "Matrix":
        """Hand a freshly-built op expression to the session."""
        if self.session.lazy:
            return Matrix(self.session, None, self.params,
                          upper=expr_upper(e), expr=e)
        return self.session._run_expr(e, self.params)

    # -- algebra -------------------------------------------------------------
    @property
    def T(self) -> "Matrix":
        """Lazy transpose: flips a flag (materialised handles) or wraps a
        folded ``Transpose`` node (pending ones); registers no task.  The
        flag is folded into the next multiply (Algorithm 1's op(A) op(B)).
        """
        if self.upper:
            return self             # symmetric: A == Aᵀ
        if self._expr is not None:
            return Matrix(self.session, None, self.params,
                          expr=Transpose(self._expr))
        return Matrix(self.session, self.node, self.params, t=not self._t,
                      trunc=self._trunc)

    def transpose(self) -> "Matrix":
        return self.T

    def __matmul__(self, other: "Matrix") -> "Matrix":
        """C = A B; a ``Session(tau=...)`` default makes this the
        error-controlled truncated multiply (see :meth:`multiply`)."""
        return self.multiply(other)

    def multiply(self, other: "Matrix", tau: Optional[float] = None
                 ) -> "Matrix":
        """C = op(A) op(B) with SpAMM-style hierarchical norm truncation.

        ``tau`` (default: the session's ``tau``) prunes every recursive
        product — at any quadtree level and within leaf block pairs —
        whose Frobenius-norm product is below it.  The result carries a
        :class:`~repro.core.multiply.TruncationReport`; read the
        worst-case ``||C_exact - C_tau||_F`` bound via
        :attr:`error_bound`.  ``tau=0`` registers a task graph identical
        to the exact multiply.  Truncation applies to plain operands
        only; symmetric upper-storage operands route to the *untruncated*
        ``sym_multiply`` task program, so any nonzero effective tau —
        explicit or the session default — raises.
        """
        self._check(other, "@")
        explicit = tau is not None
        tau = float(self.session.tau if tau is None else tau)
        if self.upper and other.upper:
            raise ValueError(
                "@: both operands use symmetric upper storage; the library "
                "multiplies symmetric x plain (qt_sym_multiply). Rebuild "
                "one operand without upper=True")
        if self.upper or other.upper:
            if tau > 0.0:
                raise ValueError(
                    "multiply(tau=...): truncation needs plain (non-upper) "
                    "operands; " + _SYM_TAU_ERROR.format(
                        op="sym_multiply", tau=tau,
                        src=_tau_src(explicit)))
            return self._result(MatMul(self._as_expr(), other._as_expr()))
        return self._result(
            MatMul(self._as_expr(), other._as_expr(), tau=tau))

    def __add__(self, other: "Matrix") -> "Matrix":
        self._check(other, "+")
        if self.upper != other.upper:
            raise ValueError("+: cannot mix symmetric upper storage and "
                             "plain matrices; rebuild one operand")
        return self._result(Add((self._as_expr(), other._as_expr())))

    def __sub__(self, other: "Matrix") -> "Matrix":
        """C = A - B, lowered as A + (-1) * B (scale + add programs)."""
        self._check(other, "-")
        if self.upper != other.upper:
            raise ValueError("-: cannot mix symmetric upper storage and "
                             "plain matrices; rebuild one operand")
        return self._result(
            Add((self._as_expr(), Scale(-1.0, other._as_expr()))))

    def __mul__(self, alpha) -> "Matrix":
        """C = alpha * A for a scalar alpha (scale task program)."""
        if not isinstance(alpha, numbers.Number):
            return NotImplemented
        return self._result(Scale(float(alpha), self._as_expr()))

    __rmul__ = __mul__

    def __neg__(self) -> "Matrix":
        return self._result(Scale(-1.0, self._as_expr()))

    def sym_square(self, tau: Optional[float] = None) -> "Matrix":
        """C = A² for symmetric A in upper storage (paper §3.3): half the
        multiplies of a general product.

        The symmetric task programs are untruncated: if the session's
        ``tau`` default is nonzero this raises unless ``tau=0`` is passed
        explicitly — silently computing an exact result under a session
        configured for truncation would misreport the error bound.
        """
        if not self.upper:
            raise ValueError("sym_square needs symmetric upper storage: "
                             "build with from_dense(..., upper=True)")
        self._check_sym_tau(tau, "sym_square")
        return self._result(SymSquare(self._as_expr()))

    def syrk(self, trans: bool = False, tau: Optional[float] = None
             ) -> "Matrix":
        """C = A Aᵀ (or Aᵀ A with ``trans=True``); C in upper storage.
        Untruncated — see :meth:`sym_square` for the tau contract."""
        if self.upper:
            raise ValueError("syrk of a symmetric matrix is sym_square")
        self._check_sym_tau(tau, "syrk")
        return self._result(Syrk(self._as_expr(), trans=trans))

    def sym_multiply(self, other: "Matrix", side: str = "left",
                     tau: Optional[float] = None) -> "Matrix":
        """C = S B (``side="left"``) or B S (``side="right"``); self is the
        symmetric upper-storage S.  Untruncated — see :meth:`sym_square`
        for the tau contract."""
        self._check(other, "sym_multiply")
        if not self.upper or other.upper:
            raise ValueError("sym_multiply: self must be symmetric upper "
                             "storage and other plain")
        self._check_sym_tau(tau, "sym_multiply")
        return self._result(
            SymMul(self._as_expr(), other._as_expr(), side))

    # -- triangular algebra (solver-suite task programs) ---------------------
    def inv_chol(self) -> "Matrix":
        """Z with ``Z^T S Z = I`` — the recursive inverse Cholesky factor
        of an SPD matrix in symmetric upper storage (arXiv:1901.07993).
        The result is upper triangular in *plain* storage (strictly-lower
        quadrants NIL at every level); raises on a NIL (singular) input.
        """
        if not self.upper:
            raise ValueError("inv_chol needs symmetric upper storage: "
                             "build with from_dense(..., upper=True)")
        return self._result(InvChol(self._as_expr()))

    def tri_solve(self, b: "Matrix") -> "Matrix":
        """X = R^{-1} B with self an upper-triangular R in plain storage
        (e.g. a Cholesky factor); recursive back substitution."""
        self._check(b, "tri_solve")
        if self.upper or b.upper:
            raise ValueError("tri_solve: both operands must use plain "
                             "storage (R upper triangular, B general)")
        if self._t:
            raise ValueError("tri_solve: transposed R is not supported "
                             "(the recursion needs upper-triangular R)")
        return self._result(TriSolve(self._as_expr(), b._as_expr()))

    def principal_submatrix(self, path) -> "Matrix":
        """The principal submatrix at a quadrant ``path`` (sequence of
        indices 0..3 descending the quadtree), as a new Matrix over the
        smaller parameter set.  The extraction is a single alias task —
        subtree chunks (and their cached norms) are shared, not copied.
        Only the two diagonal quadrants (0 and 3) of a symmetric
        upper-storage matrix are themselves principal submatrices."""
        self._ensure()
        if self._t:
            raise ValueError("principal_submatrix: resolve the transpose "
                             "first (extract from the untransposed handle)")
        if self.upper and any(q not in (0, 3) for q in path):
            raise ValueError(
                "principal_submatrix: symmetric upper storage only has "
                "principal submatrices along the diagonal (quadrants 0/3)")
        nid, sub = qt_extract(self.session.graph, self.params, self.node,
                              path)
        return Matrix(self.session, nid, sub, upper=self.upper)

    def _check_sym_tau(self, tau: Optional[float], op: str) -> None:
        eff = float(self.session.tau if tau is None else tau)
        if eff > 0.0:
            raise ValueError(_SYM_TAU_ERROR.format(
                op=op, tau=eff, src=_tau_src(tau is not None)))

    # -- readback (forces lazy exprs, flushes deferred engine waves) ---------
    def to_dense(self) -> np.ndarray:
        """Dense numpy array (symmetric storage expands to the full
        matrix); forces pending expressions and flushes Pallas waves."""
        self._ensure()
        d = qt_to_dense(self.session.graph, self.node, self.params)
        return np.ascontiguousarray(d.T) if self._t else d

    def frob2(self) -> float:
        """Squared Frobenius norm (transpose-invariant)."""
        self._ensure()
        return qt_frob2(self.session.graph, self.node)

    def norm2(self) -> float:
        """Cached squared Frobenius norm (the SpAMM pruning quantity);
        numerically identical to :meth:`frob2`."""
        self._ensure()
        return qt_norm2(self.session.graph, self.node)

    def trace(self) -> float:
        """Trace, via a cached leaf-level diagonal reduction
        (:func:`~repro.core.quadtree.qt_trace`) — the SP2 purification
        control quantity.  Transpose-invariant."""
        self._ensure()
        return qt_trace(self.session.graph, self.node)

    # -- truncation readback --------------------------------------------------
    @property
    def truncation(self) -> Optional[TruncationReport]:
        """The :class:`~repro.core.multiply.TruncationReport` of the
        multiply that produced this matrix, or None for other origins."""
        self._ensure()
        return self._trunc

    @property
    def error_bound(self) -> float:
        """Worst-case ``||C_exact - C_tau||_F`` of the producing truncated
        multiply; 0.0 for exact results (tau=0 prunes nothing)."""
        self._ensure()
        return self._trunc.error_bound if self._trunc is not None else 0.0

    def stats(self) -> dict:
        """Chunk/occupancy statistics of the quadtree (leaf chunks,
        internal chunks, nonzero blocks, bytes, depth)."""
        self._ensure()
        self.session.flush()
        return qt_stats(self.session.graph, self.node)

    def nnz_blocks(self) -> int:
        """Number of nonzero leaf blocks."""
        return self.stats()["nnz_blocks"]
