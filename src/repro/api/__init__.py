"""Unified Session/Matrix facade — the public front door of the repo.

::

    from repro import Session

    sess = Session(engine="pallas", placement="parent", leaf_n=64, bs=8)
    A, B = sess.from_dense(a), sess.from_dense(b)
    sess.simulate(p=8)                       # build phase places inputs
    C = (A @ B).T + sess.from_dense(c)
    rep = sess.simulate(fresh_stats=True)    # measured phase (Figs 11-13)
    C.to_dense()

Everything compiles to the documented internal layer (``qt_*`` task
programs over a raw ``CTGraph``) — see DESIGN.md for the mapping and
README.md for the migration table from the free-function API.
"""
from .expr import (Add, Expr, Input, MatMul, Scale, SymMul, SymSquare,
                   Syrk, Transpose)
from .matrix import Matrix
from .plan import Plan, PlanStructureError
from .session import PLACEMENT_ALIASES, Session

__all__ = ["Session", "Matrix", "Plan", "PlanStructureError",
           "PLACEMENT_ALIASES", "Expr", "Input", "Transpose", "Scale",
           "Add", "MatMul", "SymSquare", "Syrk", "SymMul"]
