"""Expr: the lazy expression IR behind the Session/Matrix facade.

Matrix operators build a lightweight :class:`Expr` DAG instead of emitting
``qt_*`` tasks directly; :mod:`repro.api.plan` lowers a rewritten Expr into
the documented task programs.  Both facade modes share this layer:

* **eager** (``Session(lazy=False)``, the default): every operator call
  builds a one-op Expr over already-materialised operands and lowers it
  immediately — byte-for-byte the task registrations of the pre-IR facade.
* **lazy** (``Session(lazy=True)``): operators return unevaluated handles;
  readback (or an explicit :meth:`Session.compile`) runs the whole DAG
  through the rewrite pipeline below first, enabling cross-operation
  rewrites and compiled-:class:`~repro.api.plan.Plan` reuse.

Expr nodes are immutable (frozen dataclasses) and compare by value, which
is what makes common-subexpression elimination a dict lookup during
lowering and plan caching a fingerprint comparison.

Rewrite pipeline (:func:`rewrite`, bottom-up, confluent by construction):

* **generalized transpose folding** — ``T(T(x)) = x``; ``T`` of symmetric
  upper storage is the identity; ``T`` commutes with ``Scale``; ``T`` of a
  product folds into Algorithm 1's op flags (``(A B)^T = B^T A^T`` becomes
  an op-flag swap, no transpose tasks); ``T`` of ``SymSquare``/``Syrk``
  results (symmetric) is the identity; ``T`` of ``SymMul`` flips its side.
* **sym-routing** — a symmetric upper-storage operand of ``MatMul`` routes
  to ``SymMul`` exactly as the eager facade always did.
* **add-chain flattening** — nested ``Add`` terms flatten into one n-ary
  node (lowered left-associatively, matching the eager binary adds), and
  an all-transposed add hoists the transpose: ``T(a) + T(b) = T(a + b)``.
* **scale folding** — ``Scale(a, Scale(b, x)) = Scale(a*b, x)``;
  ``Scale(1, x) = x``.

Truncation is planned per node: every ``MatMul`` carries its own ``tau``
(resolved from the call site / session default at build time), so one
expression may mix exact and truncated products.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["Expr", "Input", "Transpose", "Scale", "Add", "MatMul",
           "SymSquare", "Syrk", "SymMul", "InvChol", "TriSolve", "rewrite",
           "expr_upper", "expr_inputs", "fingerprint"]


@dataclasses.dataclass(frozen=True)
class Expr:
    """Base of the expression IR; all nodes are immutable value types."""


@dataclasses.dataclass(frozen=True)
class Input(Expr):
    """A bound operand: the root node id of a materialised quadtree.

    ``nid is None`` is the NIL (all-zero) matrix.  Two Inputs are equal
    iff they reference the same chunk tree, so ``X @ X`` and ``X @ Y``
    compile to different plans even when X and Y share structure.
    """
    nid: Optional[int]
    n: int
    upper: bool = False


@dataclasses.dataclass(frozen=True)
class Transpose(Expr):
    a: Expr


@dataclasses.dataclass(frozen=True)
class Scale(Expr):
    alpha: float
    a: Expr


@dataclasses.dataclass(frozen=True)
class Add(Expr):
    terms: tuple     # >= 2 Exprs; lowered left-associatively


@dataclasses.dataclass(frozen=True)
class MatMul(Expr):
    a: Expr
    b: Expr
    ta: bool = False
    tb: bool = False
    tau: float = 0.0


@dataclasses.dataclass(frozen=True)
class SymSquare(Expr):
    a: Expr


@dataclasses.dataclass(frozen=True)
class Syrk(Expr):
    a: Expr
    trans: bool = False


@dataclasses.dataclass(frozen=True)
class SymMul(Expr):
    s: Expr
    b: Expr
    side: str = "left"


@dataclasses.dataclass(frozen=True)
class InvChol(Expr):
    """Inverse Cholesky factor Z of an SPD operand: Z^T a Z = I.

    ``a`` must lower to symmetric upper storage; the result is upper
    *triangular* in plain storage (strictly-lower quadrant NIL).
    """
    a: Expr


@dataclasses.dataclass(frozen=True)
class TriSolve(Expr):
    """X = r^{-1} b with r upper triangular (plain storage)."""
    r: Expr
    b: Expr


def expr_upper(e: Expr) -> bool:
    """Whether an expression's result uses symmetric upper storage."""
    if isinstance(e, Input):
        return e.upper
    if isinstance(e, (SymSquare, Syrk)):
        return True
    if isinstance(e, (MatMul, SymMul, InvChol, TriSolve)):
        return False
    if isinstance(e, Transpose):
        return expr_upper(e.a)
    if isinstance(e, Scale):
        return expr_upper(e.a)
    if isinstance(e, Add):
        return expr_upper(e.terms[0])
    raise TypeError(f"not an Expr: {e!r}")


def expr_inputs(e: Expr) -> list:
    """Distinct :class:`Input` nodes in deterministic first-visit order."""
    seen: dict[Input, None] = {}

    def walk(x: Expr) -> None:
        if isinstance(x, Input):
            seen.setdefault(x)
        elif isinstance(x, Transpose):
            walk(x.a)
        elif isinstance(x, Scale):
            walk(x.a)
        elif isinstance(x, Add):
            for t in x.terms:
                walk(t)
        elif isinstance(x, MatMul):
            walk(x.a)
            walk(x.b)
        elif isinstance(x, SymSquare):
            walk(x.a)
        elif isinstance(x, Syrk):
            walk(x.a)
        elif isinstance(x, SymMul):
            walk(x.s)
            walk(x.b)
        elif isinstance(x, InvChol):
            walk(x.a)
        elif isinstance(x, TriSolve):
            walk(x.r)
            walk(x.b)
        else:
            raise TypeError(f"not an Expr: {x!r}")

    walk(e)
    return list(seen)


# ---------------------------------------------------------------------------
# Rewrite pipeline
# ---------------------------------------------------------------------------

def rewrite(e: Expr) -> Expr:
    """Normalise an expression (see the module docstring for the rules).

    Idempotent; single-op expressions built by the eager facade are
    already in normal form, so eager lowering pays only the walk.
    """
    if isinstance(e, Input):
        return e
    if isinstance(e, Transpose):
        return _fold_transpose(rewrite(e.a))
    if isinstance(e, Scale):
        a = rewrite(e.a)
        alpha = e.alpha
        while isinstance(a, Scale):
            alpha *= a.alpha
            a = a.a
        if alpha == 1.0:
            return a
        if isinstance(a, Transpose):
            # keep transposes outermost so they peel into the handle's
            # lazy flag instead of materialising a transpose program
            return Transpose(Scale(alpha, a.a))
        return Scale(alpha, a)
    if isinstance(e, Add):
        terms: list = []
        for t in e.terms:
            t = rewrite(t)
            if isinstance(t, Add):
                terms.extend(t.terms)   # associativity: flatten the chain
            else:
                terms.append(t)
        if len(terms) > 1 and all(isinstance(t, Transpose) for t in terms):
            # T(a) + T(b) = T(a + b): one materialised transpose, not N
            return Transpose(Add(tuple(t.a for t in terms)))
        return Add(tuple(terms)) if len(terms) > 1 else terms[0]
    if isinstance(e, MatMul):
        a, ta = _strip_transpose(rewrite(e.a), e.ta)
        b, tb = _strip_transpose(rewrite(e.b), e.tb)
        if expr_upper(a) or expr_upper(b):
            if e.tau > 0.0:
                # mirror the facade contract for hand-built Exprs: the
                # symmetric task programs are untruncated, so a nonzero
                # tau must fail loudly, not be silently dropped
                raise ValueError(
                    "MatMul(tau>0) with a symmetric upper-storage "
                    "operand routes to the untruncated sym_multiply; "
                    "build the expression with tau=0 or plain operands")
            if expr_upper(a):   # sym-routing: C = S B (S^T = S, ta moot)
                return SymMul(a, Transpose(b) if tb else b, "left")
            return SymMul(b, Transpose(a) if ta else a, "right")  # C = B S
        return MatMul(a, b, ta=ta, tb=tb, tau=e.tau)
    if isinstance(e, SymSquare):
        return SymSquare(rewrite(e.a))
    if isinstance(e, Syrk):
        a, trans = _strip_transpose(rewrite(e.a), e.trans)
        return Syrk(a, trans=trans)
    if isinstance(e, SymMul):
        return SymMul(rewrite(e.s), rewrite(e.b), e.side)
    if isinstance(e, InvChol):
        return InvChol(rewrite(e.a))
    if isinstance(e, TriSolve):
        return TriSolve(rewrite(e.r), rewrite(e.b))
    raise TypeError(f"not an Expr: {e!r}")


def _strip_transpose(e: Expr, flag: bool) -> tuple[Expr, bool]:
    """Fold any leading Transpose chain into an op flag."""
    while isinstance(e, Transpose) and not expr_upper(e.a):
        e = e.a
        flag = not flag
    if isinstance(e, Transpose):    # transpose of symmetric storage: id
        e = e.a
    return e, flag


def _fold_transpose(a: Expr) -> Expr:
    """Normal form of ``Transpose(a)`` for an already-rewritten ``a``."""
    if expr_upper(a):
        return a                                # A = A^T
    if isinstance(a, Transpose):
        return a.a                              # T(T(x)) = x
    if isinstance(a, Scale):                    # (alpha x)^T = alpha x^T
        inner = _fold_transpose(a.a)
        if isinstance(inner, Transpose):        # keep T outermost
            return Transpose(Scale(a.alpha, inner.a))
        return Scale(a.alpha, inner)
    if isinstance(a, MatMul):                   # (A B)^T = B^T A^T
        return MatMul(a.b, a.a, ta=not a.tb, tb=not a.ta, tau=a.tau)
    if isinstance(a, SymMul):                   # (S B)^T = B^T S
        other = "right" if a.side == "left" else "left"
        return SymMul(a.s, _fold_transpose(a.b), other)
    return Transpose(a)


# ---------------------------------------------------------------------------
# Structural fingerprint (plan-cache key)
# ---------------------------------------------------------------------------

def fingerprint(e: Expr, structure_of, params) -> tuple[str, list]:
    """(cache key, input nids in slot order) of a rewritten expression.

    The key hashes the expression *shape* (ops, flags, per-node tau, and
    which slots coincide — ``X @ X`` is not ``X @ Y``) together with each
    distinct input's quadtree **structure** fingerprint
    (:func:`~repro.core.quadtree.qt_structure_fp` via ``structure_of``)
    and the session's :class:`~repro.core.quadtree.QTParams`.  Values are
    excluded: a cached :class:`~repro.api.plan.Plan` re-executes for any
    inputs with matching structure via rebinding.
    """
    import hashlib

    slots: dict[Optional[int], int] = {}
    toks: list[str] = []

    def walk(x: Expr) -> None:
        if isinstance(x, Input):
            s = slots.get(x.nid)
            if s is None:
                s = slots[x.nid] = len(slots)
                toks.append(f"def{s}:{structure_of(x.nid)}:{int(x.upper)}")
            toks.append(f"in{s}")
        elif isinstance(x, Transpose):
            toks.append("T(")
            walk(x.a)
            toks.append(")")
        elif isinstance(x, Scale):
            toks.append(f"S{x.alpha!r}(")
            walk(x.a)
            toks.append(")")
        elif isinstance(x, Add):
            toks.append("+(")
            for t in x.terms:
                walk(t)
                toks.append(",")
            toks.append(")")
        elif isinstance(x, MatMul):
            toks.append(f"@[{int(x.ta)}{int(x.tb)};{x.tau!r}](")
            walk(x.a)
            toks.append(",")
            walk(x.b)
            toks.append(")")
        elif isinstance(x, SymSquare):
            toks.append("ss(")
            walk(x.a)
            toks.append(")")
        elif isinstance(x, Syrk):
            toks.append(f"rk[{int(x.trans)}](")
            walk(x.a)
            toks.append(")")
        elif isinstance(x, SymMul):
            toks.append(f"sm[{x.side}](")
            walk(x.s)
            toks.append(",")
            walk(x.b)
            toks.append(")")
        elif isinstance(x, InvChol):
            toks.append("ic(")
            walk(x.a)
            toks.append(")")
        elif isinstance(x, TriSolve):
            toks.append("ts(")
            walk(x.r)
            toks.append(",")
            walk(x.b)
            toks.append(")")
        else:
            raise TypeError(f"not an Expr: {x!r}")

    walk(e)
    toks.append(f"|p{params.n}:{params.leaf_n}:{params.bs}")
    key = hashlib.sha1("".join(toks).encode()).hexdigest()
    return key, list(slots)
