"""Plan: a compiled, cached, re-executable expression program.

``Session.compile`` lowers a rewritten :class:`~repro.api.expr.Expr`
through the documented ``qt_*`` task programs exactly once; the resulting
:class:`Plan` then *replays* — ``plan.run(X=...)`` rebinds leaf inputs in
place (:func:`~repro.core.quadtree.qt_rebind_dense` /
:func:`~repro.core.quadtree.qt_rebind_from`) and re-executes the recorded
program through the leaf engine (:func:`~repro.core.multiply.qt_replay`)
**without registering a single task**.  That is the shape iterative
electronic-structure work needs (density-matrix purification executes the
same multiply structure every iteration): per-iteration graph size is
constant instead of linear in the iteration count.

Key invariants:

* **Pinned lowering** — for a single-op expression the emitted task
  program is identical (kinds, levels, schedule) to the eager facade's,
  which is itself pinned graph-for-graph to the free-function layer.
* **Structural identity** — a plan's cache key
  (:func:`~repro.api.expr.fingerprint`) covers the expression shape,
  per-node tau, the session's QTParams, every input's quadtree
  structure, and the identity of the bound inputs (so no plan is ever
  implicitly rebound to a matrix the caller didn't pass to ``run``).
  Rebinding therefore never changes the program: new values must live
  on the compiled structure (enforced by the rebind hooks).
* **Frozen truncation** — a plan compiled with ``tau > 0`` freezes its
  pruning decisions (subtree prunes are baked into the graph, leaf
  block-pair lists are recorded on the nodes): replays re-run the same
  program, and :attr:`reports` keeps the compile-time
  :class:`~repro.core.multiply.TruncationReport`\\ s.
* **In-place refresh** — a replay refreshes the *existing* output chunks.
  Handles returned by earlier runs of the same plan observe the new
  values (double-buffer semantics); read out what you need (a trace, a
  dense copy) before re-running.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.core.multiply import (TruncationReport, qt_add, qt_multiply,
                                 qt_replay, qt_scale, qt_sym_multiply,
                                 qt_sym_square, qt_syrk, qt_transpose)
from repro.core.quadtree import (PlanStructureError, qt_invalidate_caches,
                                 qt_rebind_dense, qt_rebind_from)
from repro.core.triangular import qt_inv_chol, qt_tri_solve
from repro.obs.metrics import from_engine_stats, from_truncation

from .expr import (Add, Expr, Input, InvChol, MatMul, Scale, SymMul,
                   SymSquare, Syrk, Transpose, TriSolve)
from .lru import LRUCache

__all__ = ["Plan", "PlanStructureError", "lower"]

#: recompile successors kept per plan (changing-sparsity iterations walk
#: a handful of structures; anything past this is a cold recompile again)
RECOMPILED_CAP = 8


def lower(session, expr: Expr, params, reports: list,
          use_transpose_cache: bool = True) -> Optional[int]:
    """Emit the ``qt_*`` task program of a rewritten expression.

    Common subexpressions are lowered once (the memo below — structural
    equality of the frozen dataclasses makes this a dict lookup).
    ``use_transpose_cache=True`` (eager mode) shares materialised
    transposes session-wide, preserving the eager facade's semantics;
    plan compilation passes False so every task the plan depends on is
    inside its replayed node range.
    """
    g = session.graph
    memo: dict[Expr, Optional[int]] = {}
    local_tcache: dict[Optional[int], Optional[int]] = {}

    def transpose_of(src: Optional[int]) -> Optional[int]:
        cache = (session._transpose_cache if use_transpose_cache
                 else local_tcache)
        if src not in cache:
            cache[src] = qt_transpose(g, params, src)
        return cache[src]

    def go(e: Expr) -> Optional[int]:
        if e in memo:
            return memo[e]
        if isinstance(e, Input):
            nid = e.nid
        elif isinstance(e, Transpose):
            nid = transpose_of(go(e.a))
        elif isinstance(e, Scale):
            nid = qt_scale(g, params, go(e.a), e.alpha)
        elif isinstance(e, Add):
            nid = go(e.terms[0])
            for t in e.terms[1:]:
                nid = qt_add(g, params, nid, go(t))
        elif isinstance(e, MatMul):
            na, nb = go(e.a), go(e.b)
            if e.tau > 0.0:
                rep = TruncationReport(tau=e.tau)
                reports.append(rep)
                nid = qt_multiply(g, params, na, nb, ta=e.ta, tb=e.tb,
                                  tau=e.tau, trunc=rep)
            else:
                reports.append(TruncationReport(tau=0.0))
                nid = qt_multiply(g, params, na, nb, ta=e.ta, tb=e.tb)
        elif isinstance(e, SymSquare):
            nid = qt_sym_square(g, params, go(e.a))
        elif isinstance(e, Syrk):
            nid = qt_syrk(g, params, go(e.a), trans=e.trans)
        elif isinstance(e, SymMul):
            nid = qt_sym_multiply(g, params, go(e.s), go(e.b), side=e.side)
        elif isinstance(e, InvChol):
            nid = qt_inv_chol(g, params, go(e.a))
        elif isinstance(e, TriSolve):
            nid = qt_tri_solve(g, params, go(e.r), go(e.b))
        else:
            raise TypeError(f"not an Expr: {e!r}")
        memo[e] = nid
        return nid

    return go(expr)


class Plan:
    """One compiled expression: lowered once, re-executable forever.

    Instances come from :meth:`Session.compile` (or implicitly from lazy
    readback) and are cached on the session by structural fingerprint.
    """

    def __init__(self, session, expr: Expr, params, key: str,
                 input_nids: list, names: list,
                 struct_key: Optional[str] = None):
        self.session = session
        self.expr = expr                    # rewritten normal form
        self.params = params
        self.key = key
        # input-identity-free prefix of ``key`` (fingerprint + tau): the
        # serving layer's cross-session cache groups replicas by it
        self.struct_key = struct_key if struct_key is not None else key
        self.input_nids = list(input_nids)  # slot order
        self.input_names = list(names)      # slot order, unique
        self.reports: list[TruncationReport] = []
        self.out_node: Optional[int] = None
        self.out_t = False
        self.out_upper = False
        self.nodes: Optional[range] = None  # registered nid range
        self.n_runs = 0
        # observability (DESIGN.md §8): wall time of the lowering run vs
        # each zero-task replay, and the engine wave-log index at first
        # execution so profile() can slice out this plan's waves
        self.compile_s = 0.0
        self.replay_s: list[float] = []
        self._wave0 = 0
        # plans this one delegated to after a structure-mismatch rebind
        # with recompile=True, keyed by their cache key: later runs with
        # the same new structure replay these instead of compiling again
        # (LRU-bounded — unbounded growth was a leak under serving
        # traffic; evictions roll up into Session.metrics())
        self._recompiled: LRUCache = LRUCache(cap=RECOMPILED_CAP)
        # successor reuse counters (Session.metrics() "plan-recompile"):
        # a hit is a structure-mismatch run served by an already-compiled
        # successor's zero-task replay; a miss had to compile fresh
        self._succ_hits = 0
        self._succ_misses = 0

    def __repr__(self) -> str:
        state = (f"tasks={len(self.nodes)}" if self.nodes is not None
                 else "uncompiled")
        return (f"Plan(inputs={self.input_names}, runs={self.n_runs}, "
                f"{state}, key={self.key[:10]})")

    # -- execution ----------------------------------------------------------
    def run(self, *, recompile: bool = False, flush: bool = True,
            **bindings) -> "Matrix":
        """Execute the program; returns the result handle.

        Keyword arguments rebind input slots by name (the ``name=`` given
        at matrix construction, else ``x0``, ``x1``, ... in first-use
        order) to a dense array or a structure-identical :class:`Matrix`
        — feeding a plan's own output back into an input slot is the
        supported iteration idiom (values are copied before the replay
        starts).  The first run lowers and executes the task program;
        every later run registers **zero tasks**: it refreshes the leaf
        inputs in place and replays the recorded program through the
        leaf engine.

        A rebound value whose sparsity structure differs from the
        structure frozen into this plan's fingerprint raises
        :class:`~repro.core.quadtree.PlanStructureError` (replaying the
        frozen program — including any truncation pair lists — against a
        different structure would silently drop contributions).
        ``recompile=True`` handles the changing-sparsity regime instead:
        on a structure mismatch the expression is recompiled through the
        session's plan cache against fresh inputs built from the new
        values, and that plan runs.  ``recompile`` and ``flush`` are
        reserved keywords: they are never treated as input-slot names.

        ``flush=False`` (deferred engines only) leaves the replayed
        numeric work pending on the engine instead of dispatching it —
        the serving front end runs several plans this way, then coalesces
        their compatible ready waves into shared batched kernel calls
        (DESIGN.md §9).  The returned handle must not be read back until
        the graph is flushed.
        """
        unknown = set(bindings) - set(self.input_names)
        if unknown:
            raise ValueError(
                f"unknown plan input(s) {sorted(unknown)}; this plan binds "
                f"{self.input_names}")
        by_slot = {self.input_names.index(k): v for k, v in bindings.items()}
        return self._run(by_slot, recompile=recompile, flush=flush)

    def _run(self, by_slot: dict, recompile: bool = False,
             flush: bool = True) -> "Matrix":
        tr = self.session.tracer
        if not tr.enabled:
            return self._run_inner(by_slot, recompile, None, flush)
        with tr.span("plan.run", track="plan", key=self.key[:10],
                     bound=len(by_slot)) as sp:
            return self._run_inner(by_slot, recompile, sp, flush)

    def _run_inner(self, by_slot: dict, recompile: bool,
                   sp, flush: bool = True) -> "Matrix":
        tr = self.session.tracer
        try:
            with tr.span("plan.rebind", track="plan", slots=len(by_slot)):
                self._rebind(by_slot)
        except PlanStructureError:
            # rebinds are atomic (validate-then-fill), so the compiled
            # inputs are untouched and this plan stays runnable
            if not recompile:
                raise
            return self._recompile_run(by_slot, flush=flush)
        first = self.nodes is None
        t0 = time.perf_counter()
        if first:
            with tr.span("plan.compile", track="plan") as csp:
                self._execute_first(flush=flush)
                csp.set(tasks=len(self.nodes))
            self.compile_s = time.perf_counter() - t0
        else:
            with tr.span("plan.replay", track="plan",
                         tasks=len(self.nodes)):
                self._replay(flush=flush)
            self.replay_s.append(time.perf_counter() - t0)
        if sp is not None:
            sp.set(first=first, tasks=len(self.nodes))
        self.n_runs += 1
        return self._handle()

    def _recompile_run(self, by_slot: dict, flush: bool = True
                       ) -> "Matrix":
        """Compile the same expression against fresh inputs and run it.

        Each bound slot whose value no longer fits the compiled structure
        gets a *new* input matrix built from the new values (dense
        arrays through ``Session.from_dense``; Matrix handles bind
        directly), the expression is rewritten over the substituted
        inputs, and the session's plan cache takes it from there — same
        structure next iteration hits the recompiled plan's fast replay
        path.  This plan itself is left fully intact.
        """
        sess = self.session
        # a prior recompile may already hold the new structure: rebinding
        # into it is a zero-task replay, so try those before building
        # fresh inputs (keeps iterating with recompile=True from growing
        # a new plan per call)
        for succ in list(self._recompiled.values()):
            try:
                out = succ._run(by_slot, flush=flush)
                self._succ_hits += 1
                return out
            except PlanStructureError:
                continue
        self._succ_misses += 1
        subst: dict = {}
        for slot, value in by_slot.items():
            if value is None:
                continue
            old = self.input_nids[slot]
            if hasattr(value, "_ensure"):       # a Matrix handle
                value._ensure()
                if value.session is not sess:
                    raise ValueError(
                        "plan rebind: operand belongs to a different "
                        "Session")
                if value.params != self.params:
                    raise ValueError(
                        "plan recompile: operand quadtree parameters "
                        f"{value.params} differ from the plan's "
                        f"{self.params}")
                if value._t:
                    m = sess.from_dense(value.to_dense(),
                                        upper=value.upper,
                                        leaf_n=self.params.leaf_n,
                                        bs=self.params.bs)
                else:
                    m = value
            else:
                m = sess.from_dense(np.asarray(value),
                                    leaf_n=self.params.leaf_n,
                                    bs=self.params.bs)
            # keep the user-facing slot name on the substituted input so
            # the recompiled plan binds the same names
            if m.node is not None:
                sess._input_names.setdefault(m.node,
                                             self.input_names[slot])
            subst[old] = Input(m.node, self.params.n, upper=m.upper)
        e = _substitute_inputs(self.expr, subst)
        if self.out_t:
            e = Transpose(e)    # restore the transpose peeled at compile
        plan, _ = sess._compile_expr(e, self.params)
        self._recompiled.setdefault(plan.key, plan)
        return plan._run({}, flush=flush)

    def _rebind(self, by_slot: dict) -> None:
        g = self.session.graph
        sched = self.session._sched
        for slot, value in by_slot.items():
            dst = self.input_nids[slot]
            if value is None:
                continue
            if hasattr(value, "_ensure"):       # a Matrix handle
                value._ensure()
                if value.session is not self.session:
                    raise ValueError(
                        "plan rebind: operand belongs to a different "
                        "Session")
                if value._t:
                    # honor a pending lazy transpose by rebinding the
                    # transposed values (dense detour: no tasks, and the
                    # support check still applies)
                    qt_rebind_dense(g, dst, value.to_dense(), self.params)
                elif value.node == dst:
                    continue                    # already the bound input
                else:
                    qt_rebind_from(g, dst, value.node)
            else:
                qt_rebind_dense(g, dst, np.asarray(value), self.params)
            if sched is not None and sched.store is not None:
                # the simulator's per-chunk-id caches (norms, dedup
                # fingerprints) are keyed to the old bytes; the rebound
                # subtree's values changed under those ids
                for nid in _subtree_nids(g, dst):
                    sched.store.invalidate_content(
                        sched.placement.get(nid))

    def _execute_first(self, flush: bool = True) -> None:
        sess, g = self.session, self.session.graph
        if flush:
            # drain earlier pending waves so the wave-log slice profile()
            # reads contains only this plan's work (a deferred-batch
            # caller forgoes that isolation to keep other plans' waves
            # coalescible)
            g.flush()
        self._wave0 = len(getattr(g.engine, "_waves", ()))
        n0 = len(g.nodes)
        self.out_node = lower(sess, self.expr, self.params, self.reports,
                              use_transpose_cache=False)
        self.nodes = range(n0, len(g.nodes))

    def _replay(self, flush: bool = True) -> None:
        g = self.session.graph
        qt_invalidate_caches(g, self.nodes)
        qt_replay(g, self.nodes, flush=flush)
        sched = self.session._sched
        if sched is not None and sched.store is not None:
            # program chunks already placed by an earlier simulate now
            # hold refreshed values: retire their store-side norm/dedup
            # caches (Scheduler.replay re-registers them at the next
            # Plan.simulate, but other registrations may come first)
            for nid in self.nodes:
                sched.store.invalidate_content(sched.placement.get(nid))

    def _handle(self) -> "Matrix":
        from .matrix import Matrix
        # eager parity: a handle carries a TruncationReport only when the
        # *producing op* is the multiply — the root of the plan's
        # rewritten expression.  Reports are appended post-order, so the
        # root multiply's is last.  Per-product reports and the summed
        # direct bound stay readable on the plan (reports / error_bound).
        trunc = None
        if isinstance(self.expr, MatMul) and self.reports:
            trunc = self.reports[-1]
        return Matrix(self.session, self.out_node, self.params,
                      t=self.out_t, upper=self.out_upper, trunc=trunc)

    # -- simulation ----------------------------------------------------------
    def simulate(self, p: Optional[int] = None,
                 placement: Optional[str] = None, fresh_stats: bool = True,
                 faults=None):
        """Simulate the plan's program on the session's virtual cluster.

        Both passes are restricted to the plan's own task program (plus
        any genuinely unsimulated prerequisites, e.g. an input build
        that was never simulated): other pending work — another
        compiled-but-not-yet-simulated plan, unrelated eager tasks —
        keeps its own report instead of being charged to this one.  The
        first call simulates the program; later calls *replay* it
        through :meth:`~repro.runtime.scheduler.Scheduler.replay` — the
        program's previous chunk placements are released and the same
        tasks run again, so each iteration of a purification loop gets
        its own communication/makespan report against persistent input
        placements.

        ``faults`` injects a deterministic fault schedule into this
        pass's simulated timeline (DESIGN.md §10) — the simulator never
        touches task values, so a failure-injected replay returns
        bitwise-identical results to the failure-free one.
        """
        sess, g = self.session, self.session.graph
        sched = sess.scheduler
        if self.nodes is None:
            raise RuntimeError("plan not executed yet: call run() first")
        if fresh_stats:
            sched.reset_stats()
        if sched.has_simulated(self.nodes):
            return sched.replay(g, self.nodes, faults=faults)
        from .session import _normalize_placement
        placement = _normalize_placement(placement)
        if sched.store is None:     # first-ever run: session defaults
            p = p or sess.p
            placement = placement or sess.placement
        return sched.run(g, n_workers=p, placement=placement,
                         only=sched.unsimulated_closure(g, self.nodes),
                         faults=faults)

    # -- reporting -----------------------------------------------------------
    def profile(self) -> dict:
        """Per-plan profile in the unified metrics schema (DESIGN.md §8).

        Returns compile vs replay wall time, the engine waves this plan's
        program produced (batch sizes, padding waste, bytes packed), and
        the unified counter sets — the leaf engine's (measured per-device
        bytes under ``engine="mesh"``) plus one per truncated product.
        Works on any engine; the wave list is empty on the immediate
        numpy backend.
        """
        sess = self.session
        sess.flush()
        stats = sess.graph.engine.stats()
        waves = list(stats.get("wave_log", ()))[self._wave0:]
        metric_sets = [from_engine_stats(stats)]
        metric_sets += [from_truncation(r) for r in self.reports
                        if r.tau > 0.0]
        return {
            "schema": 1,
            "plan": self.key[:16],
            "inputs": list(self.input_names),
            "runs": self.n_runs,
            "n_tasks": self.n_tasks,
            "compile_s": self.compile_s,
            "replay_s": list(self.replay_s),
            "waves": [{
                "kernel": w.get("kernel"), "bs": w.get("bs"),
                "tasks": w.get("tasks"), "pairs": w.get("pairs"),
                "padded_pairs": w.get("padded_pairs"),
                "padding_waste": (
                    (w.get("padded_pairs", 0) - w.get("pairs", 0))
                    / max(w.get("padded_pairs", 0), 1)),
                "bytes_packed": w.get("bytes_packed"),
                "wall_s": w.get("wall_s"),
            } for w in waves],
            "metrics": [ms.to_dict() for ms in metric_sets],
        }

    @property
    def n_tasks(self) -> int:
        """Tasks the compiled program registered (constant across runs)."""
        return 0 if self.nodes is None else len(self.nodes)

    @property
    def error_bound(self) -> float:
        """Summed worst-case truncation bound of all truncated products."""
        return sum(r.error_bound for r in self.reports)


def _substitute_inputs(e: Expr, subst: dict) -> Expr:
    """Rebuild an expression with some Input nids replaced.

    ``subst`` maps old input nid -> replacement :class:`Input`.  Nodes
    are immutable value types, so an untouched subtree is returned
    as-is (and common subexpressions stay shared by value equality).
    """
    if isinstance(e, Input):
        return subst.get(e.nid, e)
    if isinstance(e, Transpose):
        return Transpose(_substitute_inputs(e.a, subst))
    if isinstance(e, Scale):
        return Scale(e.alpha, _substitute_inputs(e.a, subst))
    if isinstance(e, Add):
        return Add(tuple(_substitute_inputs(t, subst) for t in e.terms))
    if isinstance(e, MatMul):
        return MatMul(_substitute_inputs(e.a, subst),
                      _substitute_inputs(e.b, subst),
                      ta=e.ta, tb=e.tb, tau=e.tau)
    if isinstance(e, SymSquare):
        return SymSquare(_substitute_inputs(e.a, subst))
    if isinstance(e, Syrk):
        return Syrk(_substitute_inputs(e.a, subst), trans=e.trans)
    if isinstance(e, SymMul):
        return SymMul(_substitute_inputs(e.s, subst),
                      _substitute_inputs(e.b, subst), e.side)
    if isinstance(e, InvChol):
        return InvChol(_substitute_inputs(e.a, subst))
    if isinstance(e, TriSolve):
        return TriSolve(_substitute_inputs(e.r, subst),
                        _substitute_inputs(e.b, subst))
    raise TypeError(f"not an Expr: {e!r}")


def _subtree_nids(g, nid: Optional[int]) -> list:
    """Resolved node ids of every chunk in a quadtree (root included)."""
    out: list[int] = []

    def walk(n: Optional[int]) -> None:
        chunk = g.value_of(n)
        if chunk is None:
            return
        out.append(g.resolve(n))
        if chunk.children is not None:
            for c in chunk.children:
                walk(c)

    walk(nid)
    return out
