"""Cross-plan wave coalescer: one fused kernel call for many plans.

The deferred engines already batch *within* a graph: every pending leaf
task whose operands are final joins one fused ``bsmm_pairs`` /
``batched_gemm`` dispatch at flush.  A serving front end runs several
plans per batch — possibly in *different* sessions, each with its own
engine — and flushing them one by one would dispatch one undersized wave
per plan.  :class:`WaveCoalescer` instead:

1. asks every engine for its ready kernel tasks grouped by
   :meth:`~repro.core.engine.PallasEngine.batch_key`
   (``(kernel, leaf_n, bs, dtype)``),
2. merges groups with equal keys across engines,
3. packs each merged group through the same
   :func:`~repro.core.engine.dispatch_packed_wave` the engines use
   themselves — one kernel call per key per round — and
4. commits each engine's share back so its wave log and pending set stay
   consistent.

Numerical identity with per-plan flushing is structural, not accidental:
output slots are numbered task-by-task, pair order within a task is
preserved, and the segment sort is stable — so every output block
accumulates exactly the pair products it would have accumulated alone,
in the same order, in float32 (see ``dispatch_packed_wave``).  Tests pin
this bitwise.

Only plain :class:`~repro.core.engine.PallasEngine` instances merge;
the mesh executor (device-resident buffers, counted collectives) and the
immediate numpy backend flush through their own paths.
"""
from __future__ import annotations

from typing import Optional

from repro.core.engine import PallasEngine, dispatch_packed_wave
from repro.obs.metrics import MetricSet
from repro.obs.tracer import NOOP

__all__ = ["WaveCoalescer"]


class WaveCoalescer:
    """Merge compatible ready waves across engines, dispatch once."""

    def __init__(self, tracer=NOOP):
        self.tracer = tracer
        # merged-wave log: one record per fused dispatch this coalescer ran
        self.waves: list[dict] = []
        self.merged_waves = 0       # dispatches serving >1 engine
        self.solo_waves = 0         # dispatches serving exactly 1 engine
        self.merged_tasks = 0       # tasks that shared a cross-engine wave

    # -- the batch flush ------------------------------------------------------
    def flush(self, graphs) -> int:
        """Drain all deferred work of ``graphs``, coalescing across them.

        Returns the number of fused dispatches run.  Engines that cannot
        merge (mesh, numpy/immediate) are flushed through their own
        ``flush`` unchanged.
        """
        mergeable: list[tuple] = []     # (graph, engine)
        rest: list = []
        for g in graphs:
            eng = g._engine
            # exactly PallasEngine: subclasses (the mesh executor) own
            # device state a foreign dispatch would bypass
            if type(eng) is PallasEngine:
                mergeable.append((g, eng))
            else:
                rest.append(g)
        for g in rest:
            g.flush()
        dispatches = 0
        while True:
            progressed = False
            for g, eng in mergeable:
                eng._bind(g)
                progressed |= eng.run_host_ready()
                # solve waves (triangular kinds) stay per-engine: they
                # dispatch dense stacked leaves, not GEMM pair streams
                progressed |= eng.run_solve_ready()
            merged: dict = {}
            for _, eng in mergeable:
                for key, tasks in eng.ready_wave().items():
                    # kernel params beyond the batch key must also agree
                    # for the shares to be dispatch-compatible
                    mk = (key, eng.block_t, eng.interpret)
                    merged.setdefault(mk, []).append((eng, tasks))
            for (key, block_t, interpret), parts in sorted(
                    merged.items(), key=lambda kv: kv[0][0]):
                self._dispatch(key, block_t, interpret, parts)
                dispatches += 1
                progressed = True
            if not any(eng._pending for _, eng in mergeable):
                break
            if not progressed:
                raise RuntimeError(
                    "wave coalescer deadlock: unresolvable leaf "
                    "dependencies across in-flight plans")
        return dispatches

    def _dispatch(self, key: tuple, block_t: int, interpret: bool,
                  parts: list) -> None:
        kernel, _, bs, _ = key
        all_tasks = [t for _, tasks in parts for t in tasks]
        with self.tracer.span("serve.wave", track="serve",
                              engines=len(parts), tasks=len(all_tasks),
                              kernel=kernel, bs=bs):
            record = dispatch_packed_wave(
                all_tasks, bs, kernel=kernel, block_t=block_t,
                interpret=interpret, tracer=self.tracer)
        record["batch_key"] = list(key)
        record["engines"] = len(parts)
        self.waves.append(record)
        if len(parts) > 1:
            self.merged_waves += 1
            self.merged_tasks += len(all_tasks)
        else:
            self.solo_waves += 1
        # each engine keeps its own share of the accounting: pair/task/
        # block counts are exact, wall time and bytes are attributed
        # proportionally by pair count so per-engine stats() still sum
        # to (approximately) the merged wave
        total_pairs = max(record["pairs"], 1)
        for eng, tasks in parts:
            pe_pairs = sum(len(t.pairs) for t in tasks)
            share = pe_pairs / total_pairs
            eng.commit_tasks(tasks, wave_record={
                "kernel": kernel, "bs": bs, "tasks": len(tasks),
                "pairs": int(pe_pairs), "padded_pairs": int(pe_pairs),
                "c_blocks": sum(len(t.out.blocks) for t in tasks),
                "wall_s": record["wall_s"] * share,
                "bytes_packed": int(record["bytes_packed"] * share),
                "batch_key": list(key), "coalesced": len(parts),
            })

    # -- reporting ------------------------------------------------------------
    def counters(self) -> dict:
        return {"merged_waves": self.merged_waves,
                "solo_waves": self.solo_waves,
                "merged_tasks": self.merged_tasks,
                "dispatches": len(self.waves)}

    def metrics(self) -> MetricSet:
        ms = MetricSet(source="serve-coalescer")
        for k, v in self.counters().items():
            ms.add(k, "count", [v])
        return ms

    def __repr__(self) -> str:
        return (f"WaveCoalescer(dispatches={len(self.waves)}, "
                f"merged={self.merged_waves}, solo={self.solo_waves})")
