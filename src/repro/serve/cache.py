"""Cross-session plan cache: compile a request shape once, serve forever.

A :class:`~repro.api.plan.Plan` is cached per :class:`~repro.api.session.
Session` under a key that includes the *identity* of its bound inputs —
the right contract for a single user, but a serving front end sees the
same request shape arrive against many different matrices and many
sessions.  The :class:`SharedPlanCache` groups plans by their
input-identity-free ``struct_key`` (the :func:`repro.api.expr.fingerprint`
of expression shape + tau + QTParams + operand quadtree structures): any
replica compiled anywhere in the server can serve any request with that
structure, because every serving run rebinds **all** input slots with the
request's effective values (DESIGN.md §9).

Registration is push-based: :meth:`attach` hooks a session's
``_plan_observers`` list, so every plan that session compiles — including
the successors ``plan.run(..., recompile=True)`` creates after a
structure-mismatch rebind — lands here without the server having to know
where compiles happen.
"""
from __future__ import annotations

from typing import Optional

from repro.api.lru import LRUCache
from repro.obs.metrics import MetricSet

__all__ = ["SharedPlanCache"]


class SharedPlanCache:
    """``struct_key`` -> list of Plan replicas, across serving sessions.

    Replica count per key is naturally bounded by the number of sessions:
    a session that already holds a plan for the (struct, inputs) pair
    returns it from its own cache instead of compiling a twin, so
    :meth:`attach`-observed registrations only ever add one replica per
    (session, template-inputs) combination.  The key space itself is
    LRU-bounded by ``cap``.
    """

    def __init__(self, cap: int = 128):
        self._by_struct: LRUCache = LRUCache(cap=cap)

    # -- wiring ---------------------------------------------------------------
    def attach(self, session) -> None:
        """Observe every plan ``session`` compiles from now on."""
        session._plan_observers.append(self.register)

    def register(self, plan) -> None:
        """Add a freshly compiled plan as a replica of its struct_key."""
        replicas = self._by_struct.peek(plan.struct_key)
        if replicas is None:
            replicas = []
            self._by_struct.put(plan.struct_key, replicas)
        if plan not in replicas:
            replicas.append(plan)

    # -- lookup ---------------------------------------------------------------
    def lookup(self, struct_key: str) -> list:
        """All replicas for a structure (LRU-touching; counts hit/miss)."""
        return self._by_struct.get(struct_key) or []

    def __len__(self) -> int:
        return len(self._by_struct)

    @property
    def n_replicas(self) -> int:
        return sum(len(r) for r in self._by_struct.values())

    # -- reporting ------------------------------------------------------------
    def counters(self) -> dict:
        c = self._by_struct.counters()
        c["replicas"] = self.n_replicas
        return c

    def metrics(self) -> MetricSet:
        ms = MetricSet(source="serve-cache")
        for k in ("hits", "misses", "evictions", "size", "replicas"):
            ms.add(f"shared_cache_{k}", "count", [self.counters()[k]])
        return ms

    def __repr__(self) -> str:
        return (f"SharedPlanCache(keys={len(self)}, "
                f"replicas={self.n_replicas}, "
                f"hits={self._by_struct.hits}, "
                f"misses={self._by_struct.misses})")
