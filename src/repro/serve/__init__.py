"""Plan-serving subsystem (DESIGN.md §9).

Concurrent multiply / SP2-purification requests over a pool of lazy
sessions: bounded admission, a cross-session plan cache keyed by
structural fingerprint, and a cross-plan wave coalescer that merges the
in-flight plans' ready leaf waves into shared batched kernel dispatches.

>>> from repro.serve import PlanServer, Request          # doctest: +SKIP
>>> srv = PlanServer(n_sessions=2, max_inflight=4)       # doctest: +SKIP
>>> srv.register("A", a); srv.register("B", b)           # doctest: +SKIP
>>> t = srv.submit(Request.multiply("A", "B"))           # doctest: +SKIP
>>> srv.drain(); t.result                                # doctest: +SKIP
"""
from .cache import SharedPlanCache
from .coalesce import WaveCoalescer
from .server import (AdmissionError, PlanServer, Request, ServeConfig,
                     Ticket)

__all__ = ["AdmissionError", "PlanServer", "Request", "ServeConfig",
           "SharedPlanCache", "Ticket", "WaveCoalescer"]
