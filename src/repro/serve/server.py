"""PlanServer: a concurrent front end over compiled, rebindable plans.

The ROADMAP's serving direction ("the same compiled plan substrate behind
a request front end") meets the paper's workloads here: clients submit
**multiply** requests (``C = A B`` over registered matrices) and **SP2
purification** requests (the iterated ``X²`` / ``2X − X²`` polynomial of
examples/sp2_purification.py), and the server runs them *in batches*:

1. **Admission control** — a bounded queue; ``submit`` rejects with a
   typed reason (:class:`AdmissionError`) instead of buffering without
   bound.  ``max_inflight`` requests advance per batch.
2. **Shared plan cache** — request shapes are matched to compiled plan
   replicas by structural fingerprint
   (:class:`~repro.serve.cache.SharedPlanCache`); a hit rebind-replays
   with **zero task registrations**, a miss compiles one replica in the
   least-busy session.  Every run rebinds *all* input slots with the
   request's effective values, so a replica compiled for one client's
   matrices safely serves another's.
3. **Cross-plan wave coalescing** — each in-flight request's unit runs
   with ``flush=False``, leaving its leaf kernel work deferred; one
   :class:`~repro.serve.coalesce.WaveCoalescer` pass then merges the
   compatible waves of *all* in-flight plans — across sessions — into
   single fused kernel dispatches before results are read back.

Per-request accounting (queue_s, compile_s vs cache hits, replay_s,
bytes) lives on the :class:`Ticket`; ``serve.request`` / ``serve.batch``
spans flow through the PR 7 tracer, and :meth:`PlanServer.metrics`
returns the unified counter sets (DESIGN.md §8, §9).

Single-process by design: requests are *batched*, not threaded, so
results are deterministic — a serving batch computes bitwise the same
answers as running its requests serially (tests/test_serve.py pins
this).  A multi-process front end and priority classes are the next
layer (ROADMAP).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Optional

import numpy as np

from repro.api.session import Session
from repro.obs.metrics import MetricSet
from repro.obs.tracer import Span, as_tracer

from .cache import SharedPlanCache
from .coalesce import WaveCoalescer

__all__ = ["AdmissionError", "PlanServer", "Request", "ServeConfig",
           "Ticket"]


class AdmissionError(RuntimeError):
    """A request the server refused to queue; ``reason`` is machine-readable.

    Reasons: ``"queue_full"`` (depth limit reached — retry later),
    ``"unknown_matrix"`` (an operand name was never registered),
    ``"bad_request"`` (malformed parameters).
    """

    def __init__(self, reason: str, message: str):
        super().__init__(message)
        self.reason = reason


@dataclasses.dataclass(frozen=True)
class Request:
    """One unit of client work; build via :meth:`multiply` / :meth:`sp2` /
    :meth:`congruence`."""
    kind: str                       # "multiply" | "sp2" | "congruence"
    a: str = ""                     # multiply/congruence: left operand name
    b: str = ""                     # multiply/congruence: right operand name
    x0: str = ""                    # sp2: starting-iterate name
    ne: float = 0.0                 # sp2: target trace (occupation)
    iters: int = 0                  # sp2: iteration count

    @classmethod
    def multiply(cls, a: str, b: str) -> "Request":
        """``C = A B`` over two registered matrices."""
        return cls(kind="multiply", a=a, b=b)

    @classmethod
    def congruence(cls, z: str, f: str) -> "Request":
        """``F_perp = Z^T F Z`` — the solver suite's basis change
        (:mod:`repro.solvers.scf`), served as one two-multiply unit."""
        return cls(kind="congruence", a=z, b=f)

    @classmethod
    def sp2(cls, x0: str, ne: float, iters: int) -> "Request":
        """``iters`` SP2 steps from registered iterate ``x0``.

        Each step squares the iterate and keeps ``X²`` when
        ``trace(X) > ne``, else applies ``2X − X²`` — the trace-correcting
        purification polynomial (examples/sp2_purification.py).
        """
        return cls(kind="sp2", x0=x0, ne=float(ne), iters=int(iters))


@dataclasses.dataclass
class Ticket:
    """Handle + accounting for one submitted request."""
    id: int
    request: Request
    status: str = "queued"          # queued | running | done | failed
    result: Optional[np.ndarray] = None
    error: Optional[str] = None
    # timings (perf_counter stamps; derived seconds below)
    t_submit: float = 0.0
    t_start: float = 0.0
    t_done: float = 0.0
    queue_s: float = 0.0            # submit -> first batch that ran it
    compile_s: float = 0.0          # plan lowering paid by this request
    replay_s: list = dataclasses.field(default_factory=list)  # per unit
    cache_hits: int = 0             # units served by an existing replica
    cache_misses: int = 0           # units that compiled a new replica
    bytes: int = 0                  # operand + result bytes moved
    batches: int = 0                # serving batches this request spanned

    @property
    def latency_s(self) -> float:
        """Submit-to-done wall time (0 until the request finishes)."""
        return max(self.t_done - self.t_submit, 0.0)

    @property
    def done(self) -> bool:
        return self.status == "done"


@dataclasses.dataclass
class ServeConfig:
    """Knobs of a :class:`PlanServer` (all have serving-scale defaults)."""
    engine: Any = "pallas"          # any Session engine spec
    n_sessions: int = 2             # worker sessions (one graph+engine each)
    max_inflight: int = 4           # requests advanced per batch
    max_queue: int = 16             # admission bound on queued requests
    leaf_n: int = 16                # quadtree leaf dimension
    bs: int = 4                     # leaf-internal blocksize
    shared_cache_cap: int = 128     # struct keys kept by the shared cache
    plan_cache_cap: int = 64        # per-session Session plan-cache bound
    trace: Any = False              # bool or a shared Tracer instance
    prewarm: bool = False           # compile plan replicas at register()


class PlanServer:
    """Batch-serving front end over a pool of lazy sessions."""

    def __init__(self, config: Optional[ServeConfig] = None, **overrides):
        cfg = config or ServeConfig()
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        self.config = cfg
        self.tracer = as_tracer(cfg.trace)
        self.sessions = [
            Session(engine=cfg.engine, lazy=True, leaf_n=cfg.leaf_n,
                    bs=cfg.bs, trace=self.tracer,
                    plan_cache_cap=cfg.plan_cache_cap)
            for _ in range(max(cfg.n_sessions, 1))]
        self.cache = SharedPlanCache(cap=cfg.shared_cache_cap)
        for s in self.sessions:
            self.cache.attach(s)
        self.coalescer = WaveCoalescer(tracer=self.tracer)
        self._matrices: dict[str, np.ndarray] = {}
        # (session index, name) -> template Matrix bound to compiled plans
        self._templates: dict[tuple, Any] = {}
        self._queue: deque[Ticket] = deque()
        self._inflight: list[Ticket] = []
        self._states: dict[int, dict] = {}      # ticket id -> unit state
        self._next_id = 0
        self._rr = 0                            # session round-robin tie-break
        self._busy: set = set()                 # id(plan) in use this batch
        self._fresh: list = []                  # (ticket, plan) compiled now
        self.counters = {"accepted": 0, "rejected": 0, "completed": 0,
                         "failed": 0, "batches": 0, "units": 0,
                         "cold_compiles": 0}

    # -- registration ---------------------------------------------------------
    def register(self, name: str, array: np.ndarray) -> None:
        """Register a named matrix clients may reference in requests.

        Builds one quadtree template per session up front, so replica
        compiles and structural-fingerprint lookups are cheap everywhere.
        """
        a = np.asarray(array, dtype=np.float64)
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"register: {name!r} must be square 2-D, "
                             f"got shape {a.shape}")
        self._matrices[name] = a
        for si, sess in enumerate(self.sessions):
            self._templates[(si, name)] = sess.from_dense(a, name=name)
        if self.config.prewarm:
            self._prewarm(name)

    def _prewarm(self, name: str) -> None:
        """Compile (and pay the deferred lowering of) one replica of the
        iterate shapes — ``sq`` (X X) and ``pol`` (2X − X²) — per pooled
        session, so the first SP2 request hits a warm replica everywhere.

        Lowering happens on a plan's *first run*, so prewarming executes
        each replica once against the registered values; the serving path
        then replays with zero task registrations and zero cold compiles
        (``counters["cold_compiles"]``).
        """
        a = self._matrices[name]
        for si in range(len(self.sessions)):
            for kind, ops in (("sq", [(name, a)]),
                              ("pol", [(name, a), (name + ".y", a)])):
                out = self._build_expr(si, kind, ops)
                plan = self.sessions[si].compile(out)
                if plan.nodes is None:
                    plan._run({})
                self.cache.register(plan)

    def _template(self, si: int, name: str, like: np.ndarray):
        """The (session, name) template, built from ``like`` on first use."""
        m = self._templates.get((si, name))
        if m is None:
            m = self.sessions[si].from_dense(like, name=name)
            self._templates[(si, name)] = m
        return m

    # -- admission ------------------------------------------------------------
    def submit(self, request: Request) -> Ticket:
        """Queue a request; returns its :class:`Ticket` or rejects."""
        names = ((request.a, request.b)
                 if request.kind in ("multiply", "congruence")
                 else (request.x0,))
        try:
            if request.kind in ("multiply", "congruence"):
                pass
            elif request.kind == "sp2":
                if request.iters < 1:
                    raise AdmissionError(
                        "bad_request", "sp2 request needs iters >= 1")
            else:
                raise AdmissionError(
                    "bad_request", f"unknown request kind {request.kind!r}")
            missing = [n for n in names if n not in self._matrices]
            if missing:
                raise AdmissionError(
                    "unknown_matrix",
                    f"operand(s) {missing} not registered; call "
                    f"server.register(name, array) first")
            if len(self._queue) >= self.config.max_queue:
                raise AdmissionError(
                    "queue_full",
                    f"queue depth {self.config.max_queue} reached "
                    f"({len(self._inflight)} in flight); retry later")
        except AdmissionError:
            self.counters["rejected"] += 1
            raise
        t = Ticket(id=self._next_id, request=request,
                   t_submit=time.perf_counter())
        self._next_id += 1
        self._queue.append(t)
        self.counters["accepted"] += 1
        return t

    # -- the batch loop -------------------------------------------------------
    def step(self) -> int:
        """Run one serving batch; returns the number of units executed.

        A batch admits queued requests up to ``max_inflight``, advances
        each in-flight request by one unit with deferred execution,
        coalesces the ready waves of every touched plan into shared
        kernel dispatches, then reads results back and completes
        finished requests.
        """
        now = time.perf_counter()
        while self._queue and len(self._inflight) < self.config.max_inflight:
            t = self._queue.popleft()
            t.status = "running"
            t.t_start = now
            t.queue_s = now - t.t_submit
            self._states[t.id] = self._init_state(t.request)
            self._inflight.append(t)
        if not self._inflight:
            return 0
        with self.tracer.span("serve.batch", track="serve",
                              inflight=len(self._inflight),
                              queued=len(self._queue)) as sp:
            units = self._run_batch()
            sp.set(units=units,
                   coalesced=self.coalescer.merged_waves)
        self.counters["batches"] += 1
        self.counters["units"] += units
        return units

    def drain(self) -> None:
        """Step until the queue and the in-flight set are both empty."""
        while self._queue or self._inflight:
            self.step()

    def _run_batch(self) -> int:
        self._busy.clear()
        self._fresh.clear()
        ran: list[tuple] = []       # (ticket, out handle, unit t0)
        for t in list(self._inflight):
            try:
                launched = self._launch_unit(t)
                if launched is not None:    # else: stalled on a busy replica
                    ran.append((t, *launched))
            except Exception as exc:        # noqa: BLE001 - per-request fault
                self._fail(t, exc)
        for t, plan in self._fresh:
            t.compile_s += plan.compile_s   # lowering paid during launch
        graphs = [s.graph for s in self.sessions]
        self.coalescer.flush(graphs)
        units = 0
        for t, out, t0 in ran:
            try:
                dense = out.to_dense()      # graph already flushed: no-op
                t.replay_s.append(time.perf_counter() - t0)
                t.bytes += int(dense.nbytes)
                t.batches += 1
                units += 1
                self._advance(t, dense)
            except Exception as exc:        # noqa: BLE001
                self._fail(t, exc)
        return units

    # -- unit state machines --------------------------------------------------
    def _init_state(self, req: Request) -> dict:
        if req.kind in ("multiply", "congruence"):
            return {}
        return {"x": self._matrices[req.x0], "it": 0, "phase": "sq",
                "y": None}

    def _launch_unit(self, t: Ticket) -> Optional[tuple]:
        """Run the ticket's next unit deferred; returns (out handle, t0).

        Returns ``None`` when every replica of the unit's structure is
        already serving another request this batch — the ticket stays
        in flight and retries next batch (replicas are per-plan mutable
        state, so two requests can never share one within a batch).
        """
        req, state = t.request, self._states[t.id]
        if req.kind in ("multiply", "congruence"):
            ops = self._distinct_ops([(req.a, self._matrices[req.a]),
                                      (req.b, self._matrices[req.b])])
            plan = self._acquire(
                t, "mm" if req.kind == "multiply" else "cong", ops)
        elif state["phase"] == "sq":
            ops = [(req.x0, state["x"])]
            plan = self._acquire(t, "sq", ops)
        else:
            ops = [(req.x0, state["x"]), (req.x0 + ".y", state["y"])]
            plan = self._acquire(t, "pol", ops)
        if plan is None:
            return None
        t0 = time.perf_counter()
        values = [v for _, v in ops]
        t.bytes += sum(int(v.nbytes) for v in values)
        bindings = {nm: values[i]
                    for i, nm in enumerate(plan.input_names)}
        return plan.run(flush=False, recompile=True, **bindings), t0

    def _advance(self, t: Ticket, dense: np.ndarray) -> None:
        req, state = t.request, self._states[t.id]
        if req.kind in ("multiply", "congruence"):
            return self._complete(t, dense)
        if state["phase"] == "sq":
            state["y"] = dense
            # SP2 branch on the iterate's trace vs the target occupation
            if np.trace(state["x"]) > req.ne:
                state["x"] = dense          # X <- X²
                state["it"] += 1
                state["phase"] = "sq"
            else:
                state["phase"] = "pol"      # X <- 2X − X² next unit
        else:
            state["x"] = dense
            state["y"] = None
            state["it"] += 1
            state["phase"] = "sq"
        if state["phase"] == "sq" and state["it"] >= req.iters:
            self._complete(t, state["x"])

    def _complete(self, t: Ticket, result: np.ndarray) -> None:
        t.result = result
        t.status = "done"
        t.t_done = time.perf_counter()
        self._inflight.remove(t)
        self._states.pop(t.id, None)
        self.counters["completed"] += 1
        self._request_span(t)

    def _fail(self, t: Ticket, exc: Exception) -> None:
        t.error = f"{type(exc).__name__}: {exc}"
        t.status = "failed"
        t.t_done = time.perf_counter()
        if t in self._inflight:
            self._inflight.remove(t)
        self._states.pop(t.id, None)
        self.counters["failed"] += 1
        self._request_span(t)

    def _request_span(self, t: Ticket) -> None:
        if not self.tracer.enabled:
            return
        ep = self.tracer.epoch
        self.tracer.spans.append(Span(
            "serve.request", t.t_submit - ep, t.t_done - ep, track="serve",
            attrs={"id": t.id, "kind": t.request.kind, "status": t.status,
                   "queue_s": t.queue_s, "compile_s": t.compile_s,
                   "replay_s": sum(t.replay_s), "bytes": t.bytes,
                   "cache_hits": t.cache_hits,
                   "cache_misses": t.cache_misses}))

    # -- replica acquisition --------------------------------------------------
    @staticmethod
    def _distinct_ops(ops: list) -> list:
        """Distinct (name, value) operands in first-use order.

        Mirrors the expression IR's slot semantics: ``A @ A`` fingerprints
        to one input slot, so the bound values list must dedup the same
        way.
        """
        out, seen = [], set()
        for name, v in ops:
            if name not in seen:
                seen.add(name)
                out.append((name, v))
        return out

    def _build_expr(self, si: int, kind: str, ops: list):
        """The unit's expression over session ``si``'s template matrices."""
        ms = [self._template(si, name, like=v) for name, v in ops]
        if kind == "mm":
            return ms[0] @ ms[-1]           # ms[-1]: A @ A dedups to one op
        if kind == "sq":
            return ms[0] @ ms[0]
        if kind == "cong":
            return (ms[0].T @ ms[-1]) @ ms[0]   # Z^T F Z (Z == F dedups)
        return 2.0 * ms[0] - ms[1]          # pol: 2X − X²

    def _acquire(self, t: Ticket, kind: str, ops: list):
        """A free plan replica for this unit's structure (compile on miss).

        Replicas are matched by input-identity-free ``struct_key``; every
        run rebinds all slots, so any replica fits.  A replica serves at
        most one request per batch (its input buffers and output chunks
        are per-plan state), so concurrent same-shape requests either
        spread across replicas in different sessions or queue behind one.
        """
        e0 = self._build_expr(0, kind, ops)
        sess0 = self.sessions[0]
        _, struct_key, _, _, _, _ = sess0._fingerprint_expr(
            e0._expr, e0.params)
        for plan in self.cache.lookup(struct_key):
            if id(plan) not in self._busy:
                self._busy.add(id(plan))
                t.cache_hits += 1
                return plan
        si = self._pick_session()
        plan = self.sessions[si].compile(self._build_expr(si, kind, ops))
        if id(plan) in self._busy:
            # the chosen session already holds this structure's replica
            # and it is serving another request this batch: running it
            # twice would overwrite its in-place buffers mid-flight, so
            # the unit stalls until the next batch frees the replica
            return None
        self.cache.register(plan)       # restore an LRU-evicted key too
        self._busy.add(id(plan))
        t.cache_misses += 1
        if plan.nodes is None:          # genuinely new: lowering pending
            self.counters["cold_compiles"] += 1
            self._fresh.append((t, plan))
        return plan

    def _pick_session(self) -> int:
        """Least busy session this batch; round-robin on ties."""
        load = [0] * len(self.sessions)
        for si, sess in enumerate(self.sessions):
            load[si] = sum(1 for p in sess._plans.values()
                           if id(p) in self._busy)
        lo = min(load)
        cands = [si for si, l in enumerate(load) if l == lo]
        self._rr += 1
        return cands[self._rr % len(cands)]

    # -- reporting ------------------------------------------------------------
    def task_count(self) -> int:
        """Total registered tasks across all sessions (warmup invariant:
        this number stops growing once every request shape has a replica)."""
        return sum(len(s.graph.nodes) for s in self.sessions)

    def metrics(self) -> list:
        """Unified counter sets: server, shared cache, coalescer, sessions."""
        ms = MetricSet(source="serve")
        for k, v in self.counters.items():
            ms.add(f"requests_{k}" if k in ("accepted", "rejected",
                                            "completed", "failed") else k,
                   "count", [v])
        out = [ms, self.cache.metrics(), self.coalescer.metrics()]
        for s in self.sessions:
            out.extend(s.metrics())
        return out

    def __repr__(self) -> str:
        return (f"PlanServer(sessions={len(self.sessions)}, "
                f"queued={len(self._queue)}, "
                f"inflight={len(self._inflight)}, "
                f"completed={self.counters['completed']})")
