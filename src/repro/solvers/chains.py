"""Accuracy-scaled multiply chains: pick each tau from a target bound.

Iterative electronic-structure algorithms evaluate long products
``P = A_1 A_2 ... A_m`` where every factor multiply may truncate.  The
parameterless-truncation line of work (arXiv:1906.08148) inverts the
usual knob: the user states a *target accumulated error* for the whole
chain and the library derives each step's tau.

Error propagation.  Let ``P_k`` be the exact prefix product and
``Ptilde_k`` the computed one, ``E_k = Ptilde_k - P_k``.  Step k computes
``Ptilde_k = trunc(Ptilde_{k-1} A_k)`` with that multiply's own
worst-case truncation bound ``b_k``
(:class:`~repro.core.multiply.TruncationReport`), so by
submultiplicativity (``||X A||_F <= ||X||_F ||A||_2 <= ||X||_F
||A||_F``):

.. math:: ||E_k||_F \\;\\le\\; ||E_{k-1}||_F \\, ||A_k||_F + b_k.

Unrolled: ``||E_m||_F <= sum_k b_k prod_{j>k} ||A_j||_F`` — the
**accumulated bound** the chain reports.  Every quantity on the right is
*measured* (actual report bounds, actual operand norms), so the final
``accumulated_bound`` is rigorous, not an estimate.

:class:`TauPolicy` chooses tau_k *before* each step: the remaining
headroom (target minus the already-committed, forward-amplified error)
is split evenly over the remaining steps, de-amplified by the norms of
the factors still to come, and divided by a safety factor times an
estimate of how many products will be pruned (each pruned product
contributes < tau to the bound).  Because the *actual* per-step bounds
feed back into the headroom, overshoot in one step tightens the next —
the policy adapts instead of trusting its own estimate.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.api.matrix import Matrix

__all__ = ["ChainReport", "TauPolicy", "multiply_chain"]


@dataclasses.dataclass
class TauPolicy:
    """Derives per-multiply truncation thresholds from a chain target.

    Parameters
    ----------
    target : bound on the accumulated ``||P_exact - P_computed||_F`` of
        the whole chain.
    safety : headroom divisor (> 1): the policy budgets each step at
        ``1/safety`` of its even share, so estimate error in the prune
        count rarely overruns the target.
    est_prunes : pruned-products-per-multiply estimate; the default is
        the worst case ``(n / bs)^3`` — every block product pruned, each
        contributing just under tau to the bound — which makes the
        derived taus conservative: the *accumulated* bound then stays
        below the target, not only the measured error.  Decaying
        matrices spread norms over many orders of magnitude, so even
        these taus prune substantially; pass a tighter estimate to trade
        guarantee margin for pruning.
    """
    target: float
    safety: float = 4.0
    est_prunes: Optional[int] = None

    def __post_init__(self):
        if self.target < 0.0:
            raise ValueError(f"TauPolicy: target must be >= 0, got "
                             f"{self.target!r}")
        if self.safety < 1.0:
            raise ValueError(f"TauPolicy: safety must be >= 1, got "
                             f"{self.safety!r}")

    def tau_for(self, step: int, steps: int, committed: float,
                amp_rest: Sequence[float], est_prunes: int) -> float:
        """tau for step ``step`` (0-based) of ``steps``.

        ``committed`` is the accumulated bound of the prefix already
        computed; ``amp_rest[k]`` is ``prod_{j>k} ||A_j||_F`` — the
        forward amplification of an error introduced at step k.
        """
        if self.target == 0.0:
            return 0.0
        headroom = self.target - committed * amp_rest[max(step - 1, 0)]
        if headroom <= 0.0:                 # budget spent: go exact
            return 0.0
        steps_left = steps - step
        budget = headroom / (steps_left * max(amp_rest[step], 1e-300))
        n_est = self.est_prunes if self.est_prunes is not None else est_prunes
        return budget / (self.safety * max(n_est, 1))


@dataclasses.dataclass
class ChainReport:
    """Per-step taus/bounds and the rigorous accumulated chain bound."""
    target: float                   # 0.0 when no policy was given
    taus: list = dataclasses.field(default_factory=list)
    step_bounds: list = dataclasses.field(default_factory=list)
    accumulated_bound: float = 0.0  # bound on ||P_exact - P_computed||_F
    flops: float = 0.0              # leaf flops the chain registered
    pruned_flops: float = 0.0       # leaf flops truncation avoided
    steps: int = 0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["schema"] = 1
        return d


def multiply_chain(matrices: Sequence[Matrix],
                   policy: Optional[TauPolicy] = None,
                   tau: float = 0.0) -> tuple[Matrix, ChainReport]:
    """Left-to-right product of ``matrices`` with per-step truncation.

    With a :class:`TauPolicy`, each step's tau is derived from the
    target (see module docstring) and the report's
    ``accumulated_bound <= policy.target`` holds whenever the policy's
    prune estimate was not exceeded — and is rigorous regardless, since
    it is built from the measured per-step bounds.  Without a policy,
    the fixed ``tau`` applies to every step (0.0 = exact chain).

    All operands must be plain (non-upper) matrices of one session.
    """
    ms = list(matrices)
    if len(ms) < 2:
        raise ValueError("multiply_chain: need at least two matrices")
    if any(not isinstance(m, Matrix) for m in ms):
        raise TypeError("multiply_chain: operands must be Matrix handles")
    if any(m.upper for m in ms):
        raise ValueError("multiply_chain: truncated chains need plain "
                         "(non-upper) operands")
    sess = ms[0].session
    flops0 = sess.flops
    steps = len(ms) - 1
    # forward amplification: amp_rest[k] = prod_{j>k} ||A_j||_F over the
    # *factor* list a_1..a_{steps} (a_j = ms[j]); measured norms
    norms = [math.sqrt(m.frob2()) for m in ms[1:]]
    amp_rest = [1.0] * steps
    for k in range(steps - 2, -1, -1):
        amp_rest[k] = amp_rest[k + 1] * norms[k + 1]
    grid = max(ms[0].n // ms[0].params.bs, 1)
    est_prunes = grid ** 3          # worst case: every block product pruned

    rep = ChainReport(target=policy.target if policy else 0.0)
    acc = 0.0
    p = ms[0]
    for k in range(steps):
        if policy is not None:
            tk = policy.tau_for(k, steps, acc, amp_rest, est_prunes)
        else:
            tk = tau
        p = p.multiply(ms[k + 1], tau=tk)
        b_k = p.error_bound                 # measured, not estimated
        acc = acc * norms[k] + b_k
        rep.taus.append(tk)
        rep.step_bounds.append(b_k)
        if p.truncation is not None:
            rep.pruned_flops += p.truncation.pruned_flops
    rep.accumulated_bound = acc
    rep.steps = steps
    rep.flops = sess.flops - flops0
    return p, rep
