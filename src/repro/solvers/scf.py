"""The density-matrix pipeline: S -> Z -> Z^T F Z -> SP2 -> D.

One self-consistent-field-style cycle of linear-scaling electronic
structure, composed entirely from the library's task programs:

1. **Inverse factorization** of the overlap matrix S
   (:func:`~repro.solvers.inverse_factor.inverse_factor`): Z with
   ``Z^T S Z = I``.
2. **Congruence transformation** ``F_perp = Z^T F Z`` — the Fock matrix
   in the orthonormalized basis, built as a lazy two-multiply expression.
3. **SP2 purification** (Niklasson's trace-correcting polynomials): map
   the spectrum into [0, 1] with Gershgorin bounds, then iterate
   ``X <- X^2`` or ``X <- 2X - X^2`` — whichever step moves ``tr(X)``
   toward the occupation count — until ``X`` is idempotent.  Both
   polynomials are **compiled plans** (``X @ X`` and ``2X - Y``): every
   iteration rebind-replays with zero task registrations while the
   sparsity structure holds, and a drifting structure (``filter_tol``
   thresholding between iterations) takes the
   ``plan.run(recompile=True)`` path, exercising the successor cache
   (DESIGN.md §6) — hits and misses are surfaced on the report.
4. **Back transformation** ``D = Z D_perp Z^T``.

The session must be lazy (``Session(lazy=True)``): the pipeline's whole
point is plan reuse across iterations.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.api.matrix import Matrix

from .inverse_factor import FactorReport, inverse_factor

__all__ = ["SCFReport", "scf_density"]


@dataclasses.dataclass
class SCFReport:
    """Account of one full density-matrix build (DESIGN.md §11)."""
    factor: FactorReport            # the S = (Z Z^T)^{-1} stage
    sp2_iterations: int
    idempotency: float              # ||X^2 - X||_F at exit (ortho basis)
    occupation: float               # tr(D_perp) — should be ~ n_occ
    converged: bool
    recompile_hits: int             # successor replays during drift
    recompile_misses: int           # fresh compiles during drift
    replay_tasks: int               # tasks registered by the *last*
                                    # unchanged-structure replay (0 = the
                                    # zero-task invariant held)
    traces: list = dataclasses.field(default_factory=list)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["factor"] = self.factor.to_dict()
        d["schema"] = 1
        return d


def _gershgorin(a: np.ndarray) -> tuple[float, float]:
    """Outer bounds on the spectrum from Gershgorin discs."""
    d = np.diag(a)
    r = np.sum(np.abs(a), axis=1) - np.abs(d)
    return float(np.min(d - r)), float(np.max(d + r))


def scf_density(session, f: np.ndarray, s: np.ndarray, n_occ: int,
                method: str = "recursive", tol: float = 1e-6,
                factor_tol: float = 1e-8, tau: float = 0.0,
                max_iters: int = 60, filter_tol: float = 0.0
                ) -> tuple[Matrix, SCFReport]:
    """Density matrix D of Fock matrix F / overlap S at occupation n_occ.

    Parameters
    ----------
    session : a ``Session(lazy=True)`` (any engine).
    f, s : dense Fock and SPD overlap matrices (s is symmetrized and
        stored upper; quadtrees use the session's leaf_n/bs).
    n_occ : occupied-orbital count — the target ``tr(D_perp)``.
    method, factor_tol, tau : forwarded to :func:`inverse_factor`.
    tol : SP2 exit threshold on ``||X^2 - X||_F``.
    max_iters : SP2 iteration cap.
    filter_tol : threshold applied to the iterate between SP2 steps;
        nonzero values drift the sparsity structure and route iterations
        through ``recompile=True`` (0.0 keeps one frozen structure — the
        zero-new-tasks replay regime).

    Returns ``(D, SCFReport)`` with D in the original (non-orthonormal)
    basis.
    """
    if not session.lazy:
        raise ValueError("scf_density: needs a Session(lazy=True) — the "
                         "SP2 loop runs through compiled plans")
    f = np.asarray(f, dtype=np.float64)
    s = np.asarray(s, dtype=np.float64)
    n = f.shape[0]
    if f.shape != (n, n) or s.shape != (n, n):
        raise ValueError("scf_density: F and S must be square and "
                         f"same-shape, got {f.shape} and {s.shape}")

    # 1. inverse factorization of the overlap
    S = session.from_dense((s + s.T) / 2.0, upper=True)
    Z, frep = inverse_factor(S, method=method, tol=factor_tol, tau=tau)

    # 2. congruence transform into the orthonormal basis
    F = session.from_dense(f, name="F")
    f_perp = (Z.T @ F @ Z).to_dense()

    # 3. SP2: map spectrum into [0, 1], purify with compiled plans
    lo, hi = _gershgorin(f_perp)
    hi = hi if hi > lo else lo + 1.0
    x = (hi * np.eye(n) - f_perp) / (hi - lo)
    if filter_tol > 0.0:
        # threshold the starting iterate too: the plans compile on the
        # *sparse* structure, so purification fill-in genuinely drifts
        # past it (otherwise every filtered iterate is a subset of the
        # full-support compile and no rebind ever mismatches)
        x = np.where(np.abs(x) < filter_tol, 0.0, x)

    xs = session.from_dense(x, name="X")
    plan_sq = session.compile(xs @ xs)
    ys = session.from_dense(x, name="Y")
    plan_pol = session.compile(2.0 * xs - ys)
    hits0 = plan_sq._succ_hits + plan_pol._succ_hits
    miss0 = plan_sq._succ_misses + plan_pol._succ_misses

    traces: list = []
    replay_tasks = 0

    def run_counted(plan, **bindings) -> np.ndarray:
        # once a plan is compiled, a structure-preserving run must
        # register zero tasks; accumulate any violation for the report
        nonlocal replay_tasks
        compiled = plan.nodes is not None
        n_before = len(session.graph.nodes)
        out = plan.run(recompile=True, **bindings).to_dense()
        if compiled and filter_tol == 0.0:
            replay_tasks += len(session.graph.nodes) - n_before
        return out

    idem = math.inf
    it = 0
    while it < max_iters:
        x2 = run_counted(plan_sq, X=x)
        tr_x = float(np.trace(x))
        tr_x2 = float(np.trace(x2))
        traces.append(tr_x)
        idem = float(np.linalg.norm(x2 - x))
        if idem <= tol:
            break
        # trace-correcting branch: keep X^2 when it moves tr toward
        # n_occ, else apply 2X - X^2
        if abs(tr_x2 - n_occ) <= abs(2.0 * tr_x - tr_x2 - n_occ):
            x = x2
        else:
            x = run_counted(plan_pol, X=x, Y=x2)
        if filter_tol > 0.0:
            x = np.where(np.abs(x) < filter_tol, 0.0, x)
        it += 1

    # 4. back transformation D = Z X Z^T
    D_perp = session.from_dense(x)
    D = Z @ D_perp @ Z.T

    report = SCFReport(
        factor=frep, sp2_iterations=it, idempotency=idem,
        occupation=float(np.trace(x)), converged=idem <= tol,
        recompile_hits=(plan_sq._succ_hits + plan_pol._succ_hits - hits0),
        recompile_misses=(plan_sq._succ_misses + plan_pol._succ_misses
                          - miss0),
        replay_tasks=replay_tasks, traces=traces)
    return D, report
