"""Inverse factorization of an SPD overlap matrix: Z with Z^T S Z = I.

Three methods over the same quadtree substrate (arXiv:1901.07993):

``recursive``
    One shot through the :func:`~repro.core.triangular.qt_inv_chol` task
    program — exact (up to leaf arithmetic), Z upper triangular.

``global``
    Iterative refinement from the scaled identity ``Z_0 = S /
    ||S||_F^{1/2}``-style guess ``Z_0 = c I`` with ``c = ||S||_F^{-1/2}``:

    .. math:: Z_{k+1} = Z_k (I + \\tfrac12 (I - M_k)),
              \\qquad M_k = Z_k^T S Z_k.

    Since ``lambda_max(S) <= ||S||_F`` the starting spectrum of ``M_0``
    lies in (0, 1], so ``||I - M_0||_2 < 1`` and the order-2 iteration
    converges for every SPD S (slowly when ill-conditioned — the point
    of the localized method).

``localized``
    The divide-and-conquer scheme: recursively factor the two diagonal
    principal submatrices (extracted as alias subtrees, no copies),
    stack them block-diagonally and run the *same* refinement — which
    now only has to build up the off-diagonal coupling.  With a decaying
    S the refinement multiplies are truncated (``tau``), so work
    concentrates near the diagonal: the report's ``multiply_tasks``
    ("touched subtrees") stays well below the global method's.

The refinement keeps S in symmetric upper storage (``S Z`` via the
untruncated sym_multiply program) and truncates the two plain products
``Z^T (S Z)`` and ``Z M`` — pruning follows Z's structure, where the
locality lives.  The residual is read back exactly:
``||M - I||_F^2 = ||M||_F^2 - 2 tr(M) + n`` (one frob2 + one trace, both
cached leaf reductions).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.api.matrix import Matrix
from repro.core.multiply import _level_of, _register_create

__all__ = ["FactorReport", "inverse_factor"]

#: accepted ``method=`` spellings
METHODS = ("recursive", "localized", "global")


@dataclasses.dataclass
class FactorReport:
    """Typed account of one inverse factorization (DESIGN.md §11)."""
    method: str
    iterations: int                 # refinement iterations (all levels)
    residual: float                 # measured ||Z^T S Z - I||_F at exit
    tol: float
    converged: bool
    tau: float                      # refinement truncation threshold
    flops: float                    # leaf flops registered while factoring
    multiply_tasks: int             # multiply tasks registered ("touched
                                    # subtrees" of the refinement sweeps)
    residuals: list = dataclasses.field(default_factory=list)
    splits: int = 0                 # localized: recursive bisections taken

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["schema"] = 1
        return d


def _eye(like: Matrix) -> Matrix:
    """The identity at ``like``'s dimension and chunk parameters."""
    return like.session.from_dense(np.eye(like.n),
                                   leaf_n=like.params.leaf_n,
                                   bs=like.params.bs)


def _residual(s: Matrix, z: Matrix, tau: float, eye: Matrix
              ) -> tuple[Matrix, float]:
    """(M, ||M - I||_F) for M = Z^T S Z; one sym_multiply + one multiply.

    The residual is read as ``||M - I||_F`` through an explicit
    subtraction: the algebraically equivalent ``||M||_F^2 - 2 tr(M) + n``
    cancels O(n) quantities against each other and loses the entire
    signal once the factor is accurate.
    """
    w = s.sym_multiply(z)                   # S Z, untruncated
    m = z.T.multiply(w, tau=tau)
    return m, math.sqrt(max((m - eye).frob2(), 0.0))


def _refine(s: Matrix, z: Matrix, tol: float, max_iters: int, tau: float,
            residuals: list) -> tuple[Matrix, int, bool]:
    """Order-2 refinement Z <- Z (I + (I - M)/2) until ||M - I||_F <= tol."""
    eye = _eye(s)
    m, resid = _residual(s, z, tau, eye)
    residuals.append(resid)
    it = 0
    while resid > tol and it < max_iters:
        # Z_{k+1} = 1.5 Z - 0.5 Z M
        z = 1.5 * z - 0.5 * z.multiply(m, tau=tau)
        m, resid = _residual(s, z, tau, eye)
        residuals.append(resid)
        it += 1
    return z, it, resid <= tol


def _block_diag(a: Matrix, d: Matrix, like: Matrix) -> Matrix:
    """Stack two half-size factors block-diagonally at ``like``'s size.

    A single creation-from-identifiers task (§3.2): the halves' subtrees
    are shared, not copied, so a localized starting guess costs one task.
    """
    sess = like.session
    a._ensure()
    d._ensure()
    nid = _register_create(
        sess.graph, like.n, (a.node, None, None, d.node), False,
        _level_of(like.params, like.n))
    return Matrix(sess, nid, like.params, upper=False)


def _localized(s: Matrix, tol: float, max_iters: int, tau: float,
               split_n: int, residuals: list, state: dict) -> Matrix:
    if s.n <= split_n:
        return s.inv_chol()
    state["splits"] += 1
    z00 = _localized(s.principal_submatrix([0]), tol, max_iters, tau,
                     split_n, residuals, state)
    z11 = _localized(s.principal_submatrix([3]), tol, max_iters, tau,
                     split_n, residuals, state)
    z0 = _block_diag(z00, z11, s)
    z, it, ok = _refine(s, z0, tol, max_iters, tau, residuals)
    state["iterations"] += it
    state["converged"] = state["converged"] and ok
    return z


def inverse_factor(s: Matrix, method: str = "recursive",
                   tol: float = 1e-6, max_iters: int = 50,
                   tau: float = 0.0, split_n: Optional[int] = None
                   ) -> tuple[Matrix, FactorReport]:
    """Inverse factor Z of an SPD matrix S (symmetric upper storage).

    Parameters
    ----------
    s : SPD :class:`Matrix` built with ``upper=True``.
    method : ``"recursive"`` (exact one-shot), ``"localized"``
        (divide-and-conquer + truncated refinement) or ``"global"``
        (refinement from a scaled identity) — see module docstring.
    tol : refinement exit threshold on ``||Z^T S Z - I||_F`` (the
        recursive method ignores it and just reports its residual).
    max_iters : refinement iteration cap **per level**.
    tau : truncation threshold of the refinement's plain multiplies
        (0.0 = exact refinement).
    split_n : localized only — dimension at or below which a subproblem
        is factored directly (default: the quadtree leaf dimension).

    Returns ``(Z, FactorReport)``; Z satisfies ``Z^T S Z = I`` up to the
    report's measured ``residual``.
    """
    if not isinstance(s, Matrix):
        raise TypeError(f"inverse_factor: expected a Matrix, got {type(s)!r}")
    if not s.upper:
        raise ValueError("inverse_factor: S must use symmetric upper "
                         "storage (from_dense(..., upper=True))")
    if method not in METHODS:
        raise ValueError(f"inverse_factor: unknown method {method!r}; "
                         f"pick one of {METHODS}")
    sess = s.session
    flops0 = sess.flops
    mults0 = sess.n_multiply_tasks
    residuals: list = []
    iterations = 0
    converged = True
    splits = 0

    if method == "recursive":
        z = s.inv_chol()
    elif method == "global":
        c = 1.0 / math.sqrt(math.sqrt(s.frob2()))   # Z0 = I / ||S||_F^{1/2}
        z0 = c * sess.from_dense(np.eye(s.n), leaf_n=s.params.leaf_n,
                                 bs=s.params.bs)
        z, iterations, converged = _refine(s, z0, tol, max_iters, tau,
                                           residuals)
    else:                                           # localized
        state = {"iterations": 0, "converged": True, "splits": 0}
        z = _localized(s, tol, max_iters, tau,
                       split_n or s.params.leaf_n, residuals, state)
        iterations = state["iterations"]
        converged = state["converged"]
        splits = state["splits"]

    _, resid = _residual(s, z, 0.0, _eye(s))        # exit residual, exact
    report = FactorReport(
        method=method, iterations=iterations, residual=resid, tol=tol,
        converged=converged if method != "recursive" else True, tau=tau,
        flops=sess.flops - flops0,
        multiply_tasks=sess.n_multiply_tasks - mults0,
        residuals=residuals, splits=splits)
    return z, report
