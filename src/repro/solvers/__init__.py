"""Electronic-structure solver suite over the quadtree matrix library.

The paper's block-sparse multiply exists to serve linear-scaling
electronic structure: congruence transformations, inverse factorization
of the overlap matrix and density-matrix purification, all running on
hierarchical matrix structures with error-controlled truncation.  This
package composes those workloads from the library's task programs:

* :mod:`~repro.solvers.inverse_factor` — recursive, localized and
  global-refinement inverse factorization ``Z^T S Z = I``
  (arXiv:1901.07993) with a typed :class:`FactorReport`.
* :mod:`~repro.solvers.chains` — accuracy-scaled multiply chains: a
  :class:`TauPolicy` picks per-multiply truncation thresholds from a
  target accumulated error bound (arXiv:1906.08148).
* :mod:`~repro.solvers.scf` — the full density-matrix pipeline
  S → Z → Z^T F Z → SP2 purification → D, compiled to rebindable
  plans so per-iteration structure drift exercises
  ``plan.run(recompile=True)`` successor caching.
"""
from .chains import ChainReport, TauPolicy, multiply_chain
from .inverse_factor import FactorReport, inverse_factor
from .scf import SCFReport, scf_density

__all__ = ["ChainReport", "FactorReport", "SCFReport", "TauPolicy",
           "inverse_factor", "multiply_chain", "scf_density"]
