from .adamw import (AdamWState, adamw_init, adamw_update,  # noqa: F401
                    clip_by_global_norm, cosine_schedule)
