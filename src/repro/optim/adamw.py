"""AdamW in plain JAX (no optax dependency), sharding-aware.

* moments are kept in f32 regardless of param dtype (bf16-safe);
* ``adamw_update`` is pure — it composes with jit/GSPMD, and the moment
  pytree inherits whatever sharding the caller pins (launch/sharding.py
  implements ZeRO-1 by sharding moments over the data axis);
* global-norm clipping and a cosine schedule with linear warmup included.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # () int32
    m: dict                  # f32 pytree like params
    v: dict                  # f32 pytree like params


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def adamw_update(params, grads, state: AdamWState, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    """Returns (new_params, new_state).  ``lr`` may be a scalar or a
    schedule value computed from state.step by the caller."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1.0 - b1) * gf
        v_new = b2 * v + (1.0 - b2) * jnp.square(gf)
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + \
            weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    outs = [upd(p, g, m, v) for p, g, m, v
            in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in outs])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    """Linear warmup then cosine decay to floor * peak_lr."""
    t = step.astype(jnp.float32)
    warm = peak_lr * t / max(warmup, 1)
    frac = jnp.clip((t - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor * peak_lr + (1 - floor) * peak_lr * 0.5 * \
        (1.0 + jnp.cos(jnp.pi * frac))
    return jnp.where(t < warmup, warm, cos)
