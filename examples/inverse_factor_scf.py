"""Inverse factorization + one SCF density build on the solver suite.

    PYTHONPATH=src python examples/inverse_factor_scf.py

The full linear-scaling electronic-structure pipeline (DESIGN.md §11)
in one walkthrough, on a banded SPD overlap matrix S and a decaying
Fock matrix F:

1. **Inverse factorization** — three routes to Z with ``Z^T S Z = I``:
   the one-shot recursive inverse Cholesky (``qt_inv_chol`` task
   program), iterative refinement from a scaled identity ("global"),
   and the divide-and-conquer "localized" scheme (arXiv:1901.07993)
   that factors the diagonal principal submatrices first and lets the
   truncated refinement build up only the off-diagonal coupling.  On a
   decaying S the localized method touches far fewer multiply subtrees.

2. **Accuracy-scaled chain** — the congruence ``Z^T F Z`` evaluated as
   a :func:`repro.solvers.multiply_chain` under a
   :class:`repro.solvers.TauPolicy`: state one target error for the
   whole product and each step's truncation threshold is derived, with
   the rigorous accumulated bound reported back.

3. **SCF density** — :func:`repro.solvers.scf_density` composes
   factorization, congruence, compiled-plan SP2 purification and back
   transformation; the unchanged-structure replays register zero new
   tasks.  The result is checked against a dense eigendecomposition.
"""
import numpy as np

from repro import Session
from repro.solvers import TauPolicy, inverse_factor, multiply_chain, \
    scf_density

N, LEAF_N, BS = 64, 16, 4


def make_overlap(n: int, seed: int = 0) -> np.ndarray:
    """Diagonally dominant banded SPD overlap with exponential decay."""
    rng = np.random.default_rng(seed)
    dist = np.abs(np.subtract.outer(np.arange(n), np.arange(n)))
    a = rng.standard_normal((n, n)) * 0.5 ** dist
    a = (a + a.T) / 2.0
    off = np.abs(a).sum(axis=1) - np.abs(np.diag(a))
    a *= 0.45 / max(off.max(), 1e-12)
    np.fill_diagonal(a, 1.0)
    return a


def make_fock(n: int, seed: int = 1) -> np.ndarray:
    idx = np.arange(n)
    f = -np.exp(-0.4 * np.abs(idx[:, None] - idx[None, :]))
    noise = np.random.default_rng(seed).standard_normal((n, n)) * 0.05
    return (f + f.T) / 2.0 + (noise + noise.T) / 2.0


def main() -> None:
    s = make_overlap(N)
    f = make_fock(N)
    n_occ = N // 4

    # --- 1. three inverse-factorization methods -------------------------
    print(f"inverse factorization of S (n={N}, banded SPD):")
    print("  method     iters  residual   multiply tasks")
    tasks = {}
    for method in ("recursive", "localized", "global"):
        sess = Session(leaf_n=LEAF_N, bs=BS)
        S = sess.from_dense(s, upper=True)
        z, rep = inverse_factor(S, method=method, tol=1e-4, tau=1e-7)
        zd = z.to_dense()
        resid = np.linalg.norm(zd.T @ s @ zd - np.eye(N))
        assert resid <= rep.residual + 1e-9
        tasks[method] = rep.multiply_tasks
        print(f"  {method:<10} {rep.iterations:>5}  {rep.residual:.2e}"
              f"   {rep.multiply_tasks}")
    assert tasks["localized"] < tasks["global"], \
        "localized refinement should touch fewer subtrees than global"
    print(f"  localized touched {tasks['localized']}/{tasks['global']} "
          f"of the global method's subtrees")

    # --- 2. accuracy-scaled congruence chain Z^T F Z --------------------
    sess = Session(leaf_n=LEAF_N, bs=BS)
    Z, _ = inverse_factor(sess.from_dense(s, upper=True))
    target = 1e-5
    prod, crep = multiply_chain(
        [Z.T, sess.from_dense(f), Z], policy=TauPolicy(target=target))
    zd = Z.to_dense()
    err = np.linalg.norm(prod.to_dense() - zd.T @ f @ zd)
    assert err <= crep.accumulated_bound <= target
    print(f"\ncongruence chain Z^T F Z under TauPolicy(target={target:g}):")
    print(f"  derived taus {['%.1e' % t for t in crep.taus]}, "
          f"accumulated bound {crep.accumulated_bound:.2e}, "
          f"measured error {err:.2e}")

    # --- 3. the full SCF density build ----------------------------------
    sess = Session(lazy=True, leaf_n=LEAF_N, bs=BS)
    D, rep = scf_density(sess, f, s, n_occ, tol=1e-8)
    d = D.to_dense()

    # dense reference: generalized eigenproblem via the Cholesky factor
    z_ref = np.linalg.solve(np.linalg.cholesky(s).T, np.eye(N))
    w, v = np.linalg.eigh(z_ref.T @ f @ z_ref)
    d_ref = z_ref @ v[:, :n_occ] @ v[:, :n_occ].T @ z_ref.T
    err = np.linalg.norm(d - d_ref)
    assert err < 1e-5, f"density matrix off by {err:.2e}"
    assert rep.converged and rep.replay_tasks == 0
    print(f"\nscf_density: {rep.sp2_iterations} SP2 iterations, "
          f"idempotency {rep.idempotency:.2e}, "
          f"occupation {rep.occupation:.4f} (target {n_occ})")
    print(f"  compiled-plan replays registered {rep.replay_tasks} new "
          f"tasks; ||D - D_eig||_F = {err:.2e}: OK")


if __name__ == "__main__":
    main()
