"""Serving concurrent multiply / purification requests over shared plans.

    PYTHONPATH=src python examples/serve_plans.py

The ROADMAP's serving direction made concrete (DESIGN.md §9): a
:class:`repro.serve.PlanServer` owns a pool of lazy sessions and accepts
concurrent requests against registered matrices.  Three mechanisms do the
work:

* **Admission control** — ``submit`` queues up to ``max_queue`` requests
  and rejects further ones with a typed reason; ``max_inflight`` requests
  advance per serving batch.
* **Cross-session plan cache** — request shapes are matched to compiled
  plan replicas by structural fingerprint.  The first request of a shape
  compiles; every later same-shape request rebind-replays with **zero
  new task registrations**, whichever session serves it.
* **Cross-plan wave coalescing** — in-flight plans run deferred, then
  one coalescer pass merges their compatible leaf waves — across
  sessions — into single fused ``bsmm_pairs`` kernel dispatches, and the
  results are bitwise identical to serving each request alone.

The script serves a mixed workload (matrix products + an SP2
purification request), then prints the per-request accounting and the
server's unified counters.
"""
import numpy as np

from repro.serve import AdmissionError, PlanServer, Request


def main() -> None:
    rng = np.random.default_rng(7)
    n = 64
    a = rng.standard_normal((n, n))
    b = rng.standard_normal((n, n))
    h = rng.standard_normal((n, n))
    h = (h + h.T) / 2
    w, v = np.linalg.eigh(h)
    x0 = v @ np.diag((w.max() - w) / (w.max() - w.min())) @ v.T

    srv = PlanServer(engine="pallas", n_sessions=2, max_inflight=4,
                     max_queue=8, leaf_n=16, bs=4, trace=True)
    srv.register("A", a)
    srv.register("B", b)
    srv.register("X", x0)

    # a mixed workload: products in both orders plus one purification
    tickets = [srv.submit(Request.multiply("A", "B")),
               srv.submit(Request.multiply("B", "A")),
               srv.submit(Request.sp2("X", ne=n / 2, iters=6)),
               srv.submit(Request.multiply("A", "A"))]
    srv.drain()
    tasks_warm = srv.task_count()

    # warm traffic: same shapes, different values -> pure rebind-replay
    warm = [srv.submit(Request.multiply("B", "B")),
            srv.submit(Request.multiply("A", "B"))]
    srv.drain()
    assert srv.task_count() == tasks_warm, "warm requests registered tasks"

    # admission control: overfill the queue
    rejected = 0
    try:
        for _ in range(20):
            srv.submit(Request.multiply("A", "B"))
    except AdmissionError as exc:
        rejected += 1
        print(f"rejected with reason={exc.reason!r}: {exc}")
    srv.drain()

    print(f"\n{srv!r}")
    print(f"{'ticket':>6} {'kind':>8} {'status':>6} {'hits':>4} "
          f"{'miss':>4} {'queue_ms':>8} {'compile_ms':>10} "
          f"{'replay_ms':>9} {'KiB':>8}")
    for t in tickets + warm:
        print(f"{t.id:>6} {t.request.kind:>8} {t.status:>6} "
              f"{t.cache_hits:>4} {t.cache_misses:>4} "
              f"{t.queue_s * 1e3:>8.2f} {t.compile_s * 1e3:>10.2f} "
              f"{sum(t.replay_s) * 1e3:>9.2f} {t.bytes / 1024:>8.1f}")

    np.testing.assert_allclose(tickets[0].result, a @ b, atol=1e-3)
    np.testing.assert_allclose(warm[0].result, b @ b, atol=1e-3)
    print("\nresults validated against dense numpy")

    print("\ncoalescer:", srv.coalescer.counters())
    print("shared cache:", srv.cache.counters())
    spans = [s.name for s in srv.tracer.spans]
    print("spans:", {nm: spans.count(nm) for nm in sorted(set(spans))
                     if nm.startswith("serve")})


if __name__ == "__main__":
    main()
