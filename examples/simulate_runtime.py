"""Walkthrough: the Chunks-and-Tasks runtime simulator (DESIGN.md §4).

Builds a banded matrix as a task program through the :class:`repro.Session`
facade, multiplies it with ``A @ B``, and replays the recorded DAG on a
simulated 8-worker cluster under the paper's locality-aware chunk
placement and the locality-oblivious baselines.  Prints per-worker
communication (the Figs 11-13 quantities), the critical-path decomposition
behind the weak-scaling claim (eq (13)/(14)), and an ASCII Gantt chart of
worker occupancy.

Run: PYTHONPATH=src python examples/simulate_runtime.py
"""
import numpy as np

from repro import Session
from repro.core import analysis as an
from repro.core.patterns import banded_mask, values_for_mask
from repro.runtime.scheduler import PLACEMENTS

P = 8
N, D, LEAF, BS = 1024, 24, 32, 8


def simulate(placement: str):
    a = values_for_mask(banded_mask(N, D), seed=1, symmetric=True)
    sess = Session(leaf_n=LEAF, bs=BS, p=P, placement=placement, seed=0)
    A = sess.from_dense(a)
    B = sess.from_dense(a)       # duplicated input, stored twice here —
    sess.simulate()              # opt-in Session(dedup=True) stores it once
    C = A @ B
    rep = sess.simulate(fresh_stats=True)            # measured multiply
    np.testing.assert_allclose(C.to_dense(), a @ a, atol=1e-12)
    return rep


def main() -> None:
    print(f"banded N={N} (half-bandwidth {D}) multiply on {P} simulated "
          f"workers\n")
    reports = {}
    print(f"{'placement':14s} {'avg MB':>8s} {'max MB':>8s} "
          f"{'pushed':>8s} {'steals':>6s} {'makespan':>9s} {'eff':>5s}")
    for placement in PLACEMENTS:
        rep = simulate(placement)
        reports[placement] = rep
        s = an.comm_summary(rep.bytes_received)
        print(f"{placement:14s} {s['avg_bytes'] / 1e6:8.3f} "
              f"{s['max_bytes'] / 1e6:8.3f} "
              f"{np.mean(rep.bytes_pushed) / 1e6:8.3f} "
              f"{rep.steals:6d} {rep.makespan * 1e3:7.2f}ms "
              f"{rep.parallel_efficiency:5.2f}")

    rep = reports["parent-worker"]
    gap = (max(reports["random"].bytes_received)
           / max(rep.bytes_received))
    print(f"\nlocality gap (random / parent-worker, max bytes): {gap:.2f}x")

    cp = rep.crit
    print(f"\ncritical path (parent-worker): T1={cp.work_s * 1e3:.2f}ms  "
          f"Tinf={cp.length_s * 1e3:.2f}ms  "
          f"avg parallelism={cp.avg_parallelism:.1f}  "
          f"Brent bound={cp.brent_bound(P) * 1e3:.2f}ms  "
          f"makespan={rep.makespan * 1e3:.2f}ms")
    kind_of = {ev.nid: ev.kind for ev in rep.trace.events}
    chain = [kind_of[nid] for nid in cp.path]
    compressed = [k for i, k in enumerate(chain)
                  if i == 0 or k != chain[i - 1]]
    print(f"critical chain ({len(cp.path)} tasks): "
          + " -> ".join(compressed))
    print("\nworker occupancy (parent-worker multiply phase; * = steal):")
    print(rep.trace.gantt(width=72))


if __name__ == "__main__":
    main()
