"""SP2 density-matrix purification on compiled, re-executable Plans.

    PYTHONPATH=src python examples/sp2_purification.py

The paper's headline workload is iterative electronic structure: the SP2
algorithm (Niklasson's trace-correcting purification) computes the density
matrix P — the spectral projector onto the n_occ lowest eigenstates of a
Hamiltonian H — purely with matrix multiplications:

    X_0   = (lam_max I - H) / (lam_max - lam_min)
    X_k+1 = X_k**2            if trace(X_k) > n_occ     (shrinks trace)
          = 2 X_k - X_k**2    otherwise                 (grows trace)

Every iteration executes the *same* two multiply structures.  The eager
facade would register a fresh task program per iteration — per-iteration
graph cost growing without bound.  The lazy expression layer compiles
each structure **once** into a :class:`repro.Plan` (DESIGN.md §6) and
every later iteration just rebinds the input values and replays:

* ``plan_sq  = sess.compile(X @ X)``       — Y = X²
* ``plan_pol = sess.compile(2*X - Y)``     — 2X − X² (scale+add programs)

The loop below checks, per iteration, that **zero new tasks** are
registered and that the simulated per-iteration task count on the virtual
cluster is flat (Plan.simulate replays the fixed program with fresh
stats), then validates the converged density matrix against a dense
eigendecomposition.
"""
import numpy as np

from repro import Session


def make_hamiltonian(n: int, seed: int = 0, rate: float = 4.0
                     ) -> np.ndarray:
    """Dense symmetric H with exponentially decaying off-diagonal weight
    (the shape of a localized-orbital Hamiltonian).  Full block support,
    so the SP2 iterates keep one sparsity structure — the precondition
    for rebinding one compiled plan across iterations."""
    rng = np.random.default_rng(seed)
    idx = np.arange(n)
    decay = np.exp(-np.abs(idx[:, None] - idx[None, :]) / rate)
    h = rng.standard_normal((n, n)) * decay
    return (h + h.T) / 2.0


def main() -> None:
    n, n_occ, iters = 128, 40, 40
    h = make_hamiltonian(n)
    lam = np.linalg.eigvalsh(h)
    x0 = (lam[-1] * np.eye(n) - h) / (lam[-1] - lam[0])

    sess = Session(lazy=True, leaf_n=32, bs=8, p=4, seed=0)
    X = sess.from_dense(x0, name="X")
    sess.simulate()                         # build phase places the input

    plan_sq = sess.compile(X @ X)           # Y = X^2
    Y = plan_sq.run()                       # first run lowers + executes
    plan_pol = sess.compile(2.0 * X - Y)    # Z = 2X - X^2 (binds X and Y)
    plan_pol.run()                          # lower the program up front

    print(f"SP2 purification: n={n}, n_occ={n_occ}")
    print(f"  plan_sq : {plan_sq.n_tasks} tasks, "
          f"inputs {plan_sq.input_names}")
    print(f"  plan_pol: {plan_pol.n_tasks} tasks (scale+add programs)")

    graph_sizes, sim_tasks, traces = [], [], []
    tr_x = float(np.trace(x0))              # trace of the current iterate
    Xc = None
    for it in range(iters):
        if it > 0:
            Y = plan_sq.run(X=Xc)           # rebind + replay: zero new tasks
        ntasks = plan_sq.simulate().n_tasks     # fixed-program replay
        if tr_x > n_occ:
            Xc = Y                          # X <- X^2       (trace shrinks)
        else:
            Xc = plan_pol.run()             # X <- 2X - X^2  (trace grows)
            ntasks += plan_pol.simulate().n_tasks
        tr_x = Xc.trace()
        traces.append(tr_x)
        graph_sizes.append(len(sess.graph.nodes))
        sim_tasks.append(ntasks)

    print(f"  final trace: {traces[-1]:.6f} (target {n_occ})")

    # --- the api_redesign's acceptance: flat per-iteration cost ---------
    assert len(set(graph_sizes)) == 1, \
        f"graph grew across iterations: {graph_sizes}"
    assert min(sim_tasks) == plan_sq.n_tasks > 0
    assert max(sim_tasks) <= plan_sq.n_tasks + plan_pol.n_tasks
    print(f"  graph size flat at {graph_sizes[-1]} nodes over "
          f"{iters} iterations; per-iteration simulated tasks in "
          f"[{min(sim_tasks)}, {max(sim_tasks)}] (sq / sq+poly)")

    # --- correctness: X converged to the spectral projector --------------
    x = Xc.to_dense()
    assert abs(Xc.trace() - n_occ) < 1e-6
    idem = np.linalg.norm(x @ x - x)
    assert idem < 1e-6, f"not idempotent: ||X^2 - X|| = {idem:.2e}"
    w, v = np.linalg.eigh(h)
    p_ref = v[:, :n_occ] @ v[:, :n_occ].T
    err = np.linalg.norm(x - p_ref)
    assert err < 1e-6, f"density matrix off by {err:.2e}"
    print(f"  ||X^2 - X||_F = {idem:.2e}, ||X - P_eig||_F = {err:.2e}: OK")

    # --- communication story (paper Figs 11-13, per iteration) -----------
    mb = np.asarray(plan_sq.simulate().bytes_received) / 1e6
    print(f"  per-iteration comm (X^2 replay, parent-worker, p=4): "
          f"avg {mb.mean():.3f} MB/worker, max {mb.max():.3f} MB")


if __name__ == "__main__":
    main()
