"""End-to-end LM training on CPU: data -> sharded step -> checkpoints ->
fault drill -> restart, using the same builders the 256-chip launcher uses.

    PYTHONPATH=src python examples/train_lm.py            # ~2 min
    PYTHONPATH=src python examples/train_lm.py --big      # ~100M params

Defaults train a reduced olmo-1b for 200 steps and assert the loss drops;
--big switches to a ~100M-parameter config (slower on CPU, same code).
A failure is injected mid-run to demonstrate checkpoint/restart.
"""
import argparse
import sys

from repro.launch import train as T


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--big", action="store_true",
                    help="~100M params instead of the tiny smoke config")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    if args.big:
        # ~100M-parameter llama-style config through the same path
        import repro.configs.olmo_1b as base
        import repro.configs as C
        big = base.CONFIG.scaled(n_layers=8, d_model=512, n_heads=8,
                                 n_kv_heads=8, d_ff=2048, vocab=32000,
                                 head_dim=64, dtype="float32")
        print(f"params ~= {big.param_count()/1e6:.0f}M")
        # monkeypatch the smoke config for the driver
        base.SMOKE_CONFIG = big
    argv = ["--arch", "olmo-1b", "--smoke",
            "--steps", str(args.steps), "--batch", "8", "--seq", "128",
            "--ckpt-dir", "/tmp/repro_example_ckpt",
            "--drill-fail-step", str(args.steps // 2)]
    return T.main(argv)


if __name__ == "__main__":
    sys.exit(main())
