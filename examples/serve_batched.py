"""Batched serving example: prefill + greedy decode with KV/SSM caches.

    PYTHONPATH=src python examples/serve_batched.py

Serves three architectures through the identical decode loop the
decode_32k / long_500k dry-run cells lower: a GQA dense model, a
sliding-window model (ring-buffer-able cache), and an attention-free SSM
(O(1) state — the long-context winner).
"""
import sys

from repro.launch import lm_serve as S


def main() -> int:
    for arch in ("llama3.2-3b", "h2o-danube-3-4b", "falcon-mamba-7b"):
        print(f"\n--- {arch} (reduced config) ---")
        rc = S.main(["--arch", arch, "--smoke", "--batch", "4",
                     "--prompt-len", "16", "--gen", "16"])
        if rc:
            return rc
    return 0


if __name__ == "__main__":
    sys.exit(main())
