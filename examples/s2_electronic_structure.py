"""The paper's own application (§6.2): S^2 for electronic structure.

    PYTHONPATH=src python examples/s2_electronic_structure.py

End to end: generate a 3-D particle system (the water-cluster stand-in),
order basis functions with the recursive divide-space procedure, build the
overlap matrix S directly from nonzero coordinates (no dense detour),
square it with the symmetric-square task program on a simulated cluster,
truncate S^2 by Frobenius norm (paper §6.2), and report the Fig 10/11
quantities: wall time scaling, per-worker memory, per-worker comm.
"""
import numpy as np

from repro.core.patterns import (divide_space_order, overlap_pairs,
                                 particle_cloud)
from repro.core.quadtree import QTParams, qt_from_coo, qt_frob2, qt_stats
from repro.core.multiply import qt_sym_square, total_multiply_tasks
from repro.core.tasks import ClusterSim, CTGraph


def gaussian_overlap(coords, order):
    """Deterministic overlap-like values: S_ij = exp(-||xi-xj||^2 / 4)."""
    pts = coords[order]

    def value_fn(r, c):
        d2 = ((pts[r] - pts[c]) ** 2).sum(-1)
        return np.exp(-d2 / 4.0)

    return value_fn


def main() -> None:
    workers = 8
    print("n_basis  nnz/row(S)  mult_tasks  wall_ms  mem_MB/wk  "
          "recv_MB/wk(avg,max)  ||S^2||_F")
    for n_per in (8, 12, 16):
        coords = particle_cloud(n_per, 3, seed=42)
        order = divide_space_order(coords)
        rows, cols = overlap_pairs(coords, 4.5, order=order)
        npart = len(coords)
        n = 1 << int(np.ceil(np.log2(npart)))
        params = QTParams(n, max(n // 16, 32), 8)

        g = CTGraph()
        rs = qt_from_coo(g, rows, cols, params,
                         value_fn=gaussian_overlap(coords, order),
                         upper=True)
        sim = ClusterSim(workers, seed=0)
        sim.run(g)                      # S construction places chunks
        sim.reset_stats()
        rc = qt_sym_square(g, params, rs)
        res = sim.run(g)

        frob = np.sqrt(qt_frob2(g, rc))
        recv = np.asarray(res.bytes_received) / 1e6
        mem = np.mean(res.peak_owned) / 1e6
        print(f"{npart:7d}  {len(rows)/npart:9.1f}  "
              f"{total_multiply_tasks(g):10d}  {res.makespan*1e3:7.2f}  "
              f"{mem:9.2f}  {recv.mean():6.2f},{recv.max():6.2f}  "
              f"{frob:8.1f}")
    print("\nwall time grows ~linearly with system size; comm per worker "
          "stays bounded (paper Figs 10-11).")


if __name__ == "__main__":
    main()
