"""The paper's own application (§6.2): S^2 for electronic structure.

    PYTHONPATH=src python examples/s2_electronic_structure.py

End to end through the :class:`repro.Session` facade: generate a 3-D
particle system (the water-cluster stand-in), order basis functions with
the recursive divide-space procedure, build the overlap matrix S directly
from nonzero coordinates (no dense detour, ``Session.from_pattern``),
square it with ``S.sym_square()`` on a simulated cluster, and report the
Fig 10/11 quantities: wall time scaling, per-worker memory, per-worker
comm.
"""
import numpy as np

from repro import Session
from repro.core.patterns import (divide_space_order, overlap_pairs,
                                 particle_cloud)


def gaussian_overlap(coords, order):
    """Deterministic overlap-like values: S_ij = exp(-||xi-xj||^2 / 4)."""
    pts = coords[order]

    def value_fn(r, c):
        d2 = ((pts[r] - pts[c]) ** 2).sum(-1)
        return np.exp(-d2 / 4.0)

    return value_fn


def main() -> None:
    workers = 8
    print("n_basis  nnz/row(S)  mult_tasks  wall_ms  mem_MB/wk  "
          "recv_MB/wk(avg,max)  ||S^2||_F")
    for n_per in (8, 12, 16):
        coords = particle_cloud(n_per, 3, seed=42)
        order = divide_space_order(coords)
        rows, cols = overlap_pairs(coords, 4.5, order=order)
        npart = len(coords)
        n = 1 << int(np.ceil(np.log2(npart)))

        sess = Session(leaf_n=max(n // 16, 32), bs=8, p=workers, seed=0)
        S = sess.from_pattern(rows, cols, n,
                              value_fn=gaussian_overlap(coords, order),
                              upper=True)
        sess.simulate()                 # S construction places chunks
        S2 = S.sym_square()
        res = sess.simulate(fresh_stats=True)

        frob = np.sqrt(S2.frob2())
        recv = np.asarray(res.bytes_received) / 1e6
        mem = np.mean(res.peak_owned) / 1e6
        print(f"{npart:7d}  {len(rows)/npart:9.1f}  "
              f"{sess.n_multiply_tasks:10d}  {res.makespan*1e3:7.2f}  "
              f"{mem:9.2f}  {recv.mean():6.2f},{recv.max():6.2f}  "
              f"{frob:8.1f}")
    print("\nwall time grows ~linearly with system size; comm per worker "
          "stays bounded (paper Figs 10-11).")


if __name__ == "__main__":
    main()
