"""Quickstart: locality-aware block-sparse matmul through the Session API.

    PYTHONPATH=src python examples/quickstart.py

1. Build banded matrices as sparse quadtrees of chunks (paper §3) with a
   :class:`repro.Session`, multiply with ``C = A @ B`` on a simulated
   8-worker cluster, and report the communication statistics that make
   the paper's point (locality => tiny comm per worker).
2. Re-run the multiply in a **pallas-engine session**
   (``Session(engine="pallas")``): leaf work across the whole quadtree is
   batched into fused Pallas kernel waves (paper §4.1 batched leaf-level
   work), and the flop/bytes report shows what was batched.
3. Run the same multiply through the static TPU engine (mask-pyramid
   enumeration + capacity-bounded gather-GEMM-scatter, DESIGN.md §3) and
   check everything against dense numpy.
"""
import numpy as np
import jax.numpy as jnp

from repro import Session
from repro.core import blocksparse as bsp
from repro.core.bsmm import bsmm
from repro.core.patterns import (banded_mask, block_mask_from_element_mask,
                                 values_for_mask)


def main() -> None:
    n, bs, d = 512, 8, 16
    a = values_for_mask(banded_mask(n, d), seed=1).astype(np.float32)
    b = values_for_mask(banded_mask(n, d), seed=2).astype(np.float32)
    want = a @ b

    # --- 1. the paper's library on a simulated cluster ------------------
    sess = Session(leaf_n=64, bs=bs, p=8, seed=0)
    A = sess.from_dense(a)
    B = sess.from_dense(b)
    sess.simulate()                    # construction task program places inputs
    C = A @ B
    res = sess.simulate(fresh_stats=True)
    np.testing.assert_allclose(C.to_dense(), want, atol=1e-3)
    print("quadtree multiply: OK")
    print(f"  multiply tasks: {sess.n_multiply_tasks}, "
          f"add tasks: {sess.n_add_tasks} (mult > add, paper §5)")
    print(f"  virtual makespan: {res.makespan*1e3:.2f} ms on 8 workers, "
          f"steals: {res.steals}")
    mb = np.asarray(res.bytes_received) / 1e6
    print(f"  comm per worker: avg {mb.mean():.2f} MB, max {mb.max():.2f}"
          " MB  <- locality keeps this flat as the cluster grows")

    # --- 2. same multiply, pallas leaf backend (batched kernel waves) ---
    sess2 = Session(engine="pallas", leaf_n=64, bs=bs)
    C2 = sess2.from_dense(a) @ sess2.from_dense(b)
    np.testing.assert_allclose(C2.to_dense(), want, atol=1e-3)
    st = sess2.engine_stats()
    print('leaf backend engine="pallas": OK (matches engine="numpy")')
    print(f"  flop/bytes report: {sess2.flops:.3g} useful flops in "
          f"{st['waves']} fused wave(s); {st['batched_pairs']} block pairs "
          f"batched ({st['padded_pairs'] - st['batched_pairs']} padding), "
          f"{st['bytes_packed'] / 1e6:.2f} MB packed, "
          f"kernel {st['kernel']} in {st['kernel_wall_s'] * 1e3:.1f} ms")

    # --- 3. the TPU engine (jit, static shapes) -------------------------
    ma = block_mask_from_element_mask(np.abs(a) > 0, bs)
    mb_ = block_mask_from_element_mask(np.abs(b) > 0, bs)
    caps = bsp.plan_caps(ma, mb_)
    A_ = bsp.from_dense(jnp.asarray(a), bs, int(ma.sum()) + 8)
    B_ = bsp.from_dense(jnp.asarray(b), bs, int(mb_.sum()) + 8)
    c, info = bsmm(A_, B_, pair_caps=caps, cap_c=bsp.plan_c_cap(ma, mb_))
    np.testing.assert_allclose(np.asarray(bsp.to_dense(c)), want,
                               atol=1e-2)
    print("TPU block-sparse engine: OK")
    print(f"  surviving block pairs: {int(info['n_pairs'])} "
          f"(the paper's leaf-level task count), "
          f"C blocks: {int(info['n_c_blocks'])}")


if __name__ == "__main__":
    main()
