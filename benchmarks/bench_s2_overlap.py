"""Paper Figs 10-11: S^2 symmetric square of overlap matrices.

3-D particle clouds (the water-cluster stand-in), divide-space ordering,
symmetric square on the simulated cluster — all through the
Session/Matrix facade.  Validates: near-linear time in system size,
per-worker memory/comm statistics.
CSV: n_basis,nnz_per_row_S,nnz_per_row_S2,wall_s,peak_mem_MB_avg,
recv_MB_avg,recv_MB_max.
"""
import numpy as np

from repro import Session
from repro.core.patterns import (divide_space_order, overlap_pairs,
                                 particle_cloud)


def main() -> None:
    print("n_basis,nnz_row_S,nnz_row_S2,wall_s,peak_mem_MB_avg,"
          "recv_MB_avg,recv_MB_max")
    workers = 8
    walls = []
    sizes = []
    for n_per in (8, 10, 13, 16):
        coords = particle_cloud(n_per, 3, seed=3)
        order = divide_space_order(coords)
        rows, cols = overlap_pairs(coords, 4.0, order=order)
        npart = len(coords)
        n = 1 << int(np.ceil(np.log2(npart)))
        sess = Session(leaf_n=max(n // 16, 32), bs=8, p=workers, seed=0)
        S = sess.from_pattern(rows, cols, n, upper=True)
        sess.simulate()
        S2 = S.sym_square()
        res = sess.simulate(fresh_stats=True)
        st = S2.stats()
        nnz_s = len(rows) / npart
        nnz_s2 = 0 if st["nnz_blocks"] == 0 else \
            st["nnz_blocks"] * sess.bs ** 2 / npart
        mem = np.mean(res.peak_owned) / 1e6
        recv = np.asarray(res.bytes_received) / 1e6
        walls.append(res.makespan)
        sizes.append(npart)
        print(f"{npart},{nnz_s:.0f},{nnz_s2:.0f},{res.makespan:.4f},"
              f"{mem:.2f},{recv.mean():.2f},{recv.max():.2f}")
    # near-linear scaling with system size (paper Fig 10 left)
    t_ratio = walls[-1] / walls[0]
    n_ratio = sizes[-1] / sizes[0]
    assert t_ratio < 2.5 * n_ratio, \
        f"time grew {t_ratio:.1f}x for {n_ratio:.1f}x size"


if __name__ == "__main__":
    main()
