"""Paper Figs 5-8: leaf block-sparse multiply throughput vs fill factor.

Host leaf engine (sum-of-outer-products batching, Fig 2 structure) on
randomly occupied block matrices, blocksizes 16/32/64, fill sweep.
CSV: bs,fill,gflops,block_multiplies,batches,useful_fraction.
"""
import time

import numpy as np

from repro.core.leaf import LeafMatrix, LeafStats, leaf_multiply


def main() -> None:
    print("bs,fill,gflops,block_multiplies,batches,useful_fraction")
    n = 1024
    rng = np.random.default_rng(0)
    for bs in (16, 32, 64):
        g = n // bs
        for fill in (0.01, 0.05, 0.2, 0.5, 1.0):
            mask = rng.random((g, g)) < fill
            a = LeafMatrix(n, bs)
            b = LeafMatrix(n, bs)
            for i, j in zip(*np.nonzero(mask)):
                a.blocks[(i, j)] = rng.standard_normal((bs, bs))
            mask_b = rng.random((g, g)) < fill
            for i, j in zip(*np.nonzero(mask_b)):
                b.blocks[(i, j)] = rng.standard_normal((bs, bs))
            st = LeafStats()
            t0 = time.perf_counter()
            c = leaf_multiply(a, b, stats=st)
            dt = time.perf_counter() - t0
            dense_flops = 2.0 * n ** 3
            useful = st.flops / dense_flops
            print(f"{bs},{fill},{st.flops / dt / 1e9:.2f},"
                  f"{st.block_multiplies},{st.batches},{useful:.4f}")
            assert not np.isnan(st.flops)
            del c


if __name__ == "__main__":
    main()
