"""Paper Figs 5-8: leaf block-sparse multiply throughput vs fill factor.

Default mode — host leaf engine (sum-of-outer-products batching, Fig 2
structure) on randomly occupied block matrices, blocksizes 16/32/64, fill
sweep.  CSV: bs,fill,gflops,block_multiplies,batches,useful_fraction.

``--compare-backends`` — run the same quadtree multiply once per leaf
backend (numpy reference vs pallas batched waves, both kernel modes) and
emit one JSON record with per-backend wall time and batched-pair counts:

    PYTHONPATH=src python benchmarks/bench_leaf_multiply.py \
        --compare-backends [--n 256] [--pattern banded|random]
"""
import argparse
import json
import time

import numpy as np

from repro.core.leaf import LeafMatrix, LeafStats, leaf_multiply


def csv_mode() -> None:
    print("bs,fill,gflops,block_multiplies,batches,useful_fraction")
    n = 1024
    rng = np.random.default_rng(0)
    for bs in (16, 32, 64):
        g = n // bs
        for fill in (0.01, 0.05, 0.2, 0.5, 1.0):
            mask = rng.random((g, g)) < fill
            a = LeafMatrix(n, bs)
            b = LeafMatrix(n, bs)
            for i, j in zip(*np.nonzero(mask)):
                a.blocks[(i, j)] = rng.standard_normal((bs, bs))
            mask_b = rng.random((g, g)) < fill
            for i, j in zip(*np.nonzero(mask_b)):
                b.blocks[(i, j)] = rng.standard_normal((bs, bs))
            st = LeafStats()
            t0 = time.perf_counter()
            c = leaf_multiply(a, b, stats=st)
            dt = time.perf_counter() - t0
            dense_flops = 2.0 * n ** 3
            useful = st.flops / dense_flops
            print(f"{bs},{fill},{st.flops / dt / 1e9:.2f},"
                  f"{st.block_multiplies},{st.batches},{useful:.4f}")
            assert not np.isnan(st.flops)
            del c


def compare_backends(n: int, pattern: str, leaf_n: int, bs: int,
                     seed: int) -> dict:
    """Quadtree multiply through every leaf backend; JSON-able record."""
    from repro import Session
    from repro.core.engine import PallasEngine
    from repro.core.patterns import banded_mask, random_mask, values_for_mask

    if pattern == "banded":
        mask = banded_mask(n, max(n // 32, 4))
    else:
        mask = random_mask(n, 0.08, seed=seed)
    a = values_for_mask(mask, seed=seed)
    b = values_for_mask(mask, seed=seed + 1)

    # engine instances bind to one graph, so each timed run gets a fresh one
    backends = {
        "numpy": lambda: "numpy",
        "pallas-pairs": lambda: PallasEngine(kernel="pairs"),
        "pallas-gemm": lambda: PallasEngine(kernel="gemm"),
    }
    record = {
        "mode": "compare-backends", "n": n, "leaf_n": leaf_n, "bs": bs,
        "pattern": pattern, "seed": seed, "backends": {},
    }
    ref = None
    for name, mk_engine in backends.items():
        # run twice: the first pays one-time jit trace/compile (reported as
        # wall_s_cold), the second is the steady-state comparison number
        walls = []
        for _ in range(2):
            sess = Session(engine=mk_engine(), leaf_n=leaf_n, bs=bs)
            A = sess.from_dense(a)
            B = sess.from_dense(b)
            t0 = time.perf_counter()
            C = A @ B
            sess.flush()
            walls.append(time.perf_counter() - t0)
        out = C.to_dense()
        if ref is None:
            ref = out
        else:
            np.testing.assert_allclose(out, ref, atol=1e-3, rtol=1e-3)
        entry = {
            "wall_s": walls[-1],
            "wall_s_cold": walls[0],
            "multiply_tasks": sess.n_multiply_tasks,
            "flops": sess.flops,
        }
        stats = sess.engine_stats()
        if stats:
            entry.update({
                "kernel": stats.get("kernel"),
                "waves": stats.get("waves"),
                "batched_pairs": stats.get("batched_pairs"),
                "padded_pairs": stats.get("padded_pairs"),
                "c_blocks": stats.get("c_blocks"),
                "kernel_wall_s": stats.get("kernel_wall_s"),
                "bytes_packed": stats.get("bytes_packed"),
            })
        record["backends"][name] = entry
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--compare-backends", action="store_true",
                    help="JSON backend comparison instead of the CSV sweep")
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--leaf-n", type=int, default=64)
    ap.add_argument("--bs", type=int, default=16)
    ap.add_argument("--pattern", choices=("banded", "random"),
                    default="banded")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.compare_backends:
        print(json.dumps(compare_backends(args.n, args.pattern, args.leaf_n,
                                          args.bs, args.seed), indent=2))
    else:
        csv_mode()


if __name__ == "__main__":
    main()
