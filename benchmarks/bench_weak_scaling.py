"""Paper Fig 9: weak scaling of banded multiply and symmetric square.

ClusterSim virtual wall time for matrix dimension proportional to node
count; the symmetric square should retain its ~2x advantage at every
scale, and wall time should grow only polylog (eq (14)).
CSV: op,nodes,N,wall_s,flops,speedup_vs_multiply.
"""
import numpy as np

from repro.core import analysis as an
from repro.core.patterns import banded_mask, values_for_mask
from repro.core.quadtree import QTParams, qt_from_dense
from repro.core.multiply import qt_multiply, qt_sym_square, total_flops
from repro.core.tasks import ClusterSim, CTGraph


def run(op, nodes, n_per, d, leaf_n, bs):
    n = n_per * nodes
    params = QTParams(n, leaf_n, bs)
    a = values_for_mask(banded_mask(n, d), seed=1, symmetric=True)
    g = CTGraph()
    sim = ClusterSim(nodes, seed=0)
    if op == "multiply":
        ra = qt_from_dense(g, a, params)
        rb = qt_from_dense(g, a, params)
        sim.run(g)
        sim.reset_stats()
        qt_multiply(g, params, ra, rb)
    else:
        rs = qt_from_dense(g, a, params, upper=True)
        sim.run(g)
        sim.reset_stats()
        qt_sym_square(g, params, rs)
    res = sim.run(g)
    return res.makespan, total_flops(g), n


def main() -> None:
    print("op,nodes,N,wall_s,gflop,speedup_vs_multiply")
    n_per, d = 256, 24
    walls = {}
    for op in ("multiply", "sym_square"):
        for nodes in (1, 2, 4, 8):
            wall, fl, n = run(op, nodes, n_per, d, 64, 8)
            walls[(op, nodes)] = wall
            speed = walls[("multiply", nodes)] / wall \
                if op == "sym_square" else 1.0
            print(f"{op},{nodes},{n},{wall:.4f},{fl/1e9:.3f},"
                  f"{speed:.2f}")
    # symmetric square ~2x faster (paper Fig 9 right)
    sp = walls[("multiply", 8)] / walls[("sym_square", 8)]
    assert sp > 1.4, f"sym square speedup only {sp:.2f}"
    # weak scaling: wall time grows far slower than the 8x work growth
    growth = walls[("multiply", 8)] / walls[("multiply", 1)]
    assert growth < 3.0, f"weak scaling wall grew {growth:.2f}x"


if __name__ == "__main__":
    main()
